"""L1 correctness: Pallas LIF kernel vs the pure-jnp oracle.

This is the core correctness signal for the device kernel: every behaviour
(subthreshold integration, spiking, reset, refractoriness, synaptic decay)
is asserted against ``ref.lif_update_ref``, plus hypothesis sweeps over
shapes and value ranges.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lif, ref
from compile.kernels.ref import LifParams, lif_update_ref


def _state(n, seed=0, v_range=(-5.0, 20.0)):
    rng = np.random.default_rng(seed)
    v = rng.uniform(*v_range, n).astype(np.float32)
    i_ex = rng.uniform(0.0, 500.0, n).astype(np.float32)
    i_in = rng.uniform(-500.0, 0.0, n).astype(np.float32)
    r = rng.integers(0, 4, n).astype(np.float32)
    w_ex = rng.uniform(0.0, 100.0, n).astype(np.float32)
    w_in = rng.uniform(-100.0, 0.0, n).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (v, i_ex, i_in, r, w_ex, w_in))


def _run_both(n, seed=0, params=None, block=None):
    p = (params or LifParams()).packed()
    args = _state(n, seed)
    block = block or min(lif.BLOCK, n)
    out_k = lif.lif_update(*args, p, block=block)
    out_r = lif_update_ref(*args, p)
    return out_k, out_r


@pytest.mark.parametrize("n", [1, 7, 64, 256, 1024, 4096])
def test_kernel_matches_ref(n):
    block = n if n < lif.BLOCK else lif.BLOCK
    out_k, out_r = _run_both(n, block=block)
    for k, r, name in zip(out_k, out_r, ["v", "i_ex", "i_in", "r", "spike"]):
        np.testing.assert_allclose(k, r, rtol=1e-6, atol=1e-6, err_msg=name)


def test_kernel_multi_block_grid():
    """Grid > 1: BlockSpec tiling must partition the state correctly."""
    out_k, out_r = _run_both(4 * 256, block=256)
    for k, r in zip(out_k, out_r):
        # fma/reassociation differences between the tiled and untiled
        # lowering show up at the last ulp of f32
        np.testing.assert_allclose(k, r, rtol=2e-5, atol=1e-6)


def test_subthreshold_decay_towards_rest():
    """With no input, V decays exponentially to 0 (= E_L) and never spikes."""
    p = LifParams()
    packed = p.packed()
    n = 128
    v = jnp.full((n,), 5.0, jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    props = p.propagators()
    for _ in range(50):
        v, _, _, _, s = lif.lif_update(v, z, z, z, z, z, packed, block=n)
        assert float(s.sum()) == 0.0
    expect = 5.0 * props["p22"] ** 50
    np.testing.assert_allclose(np.asarray(v), expect, rtol=1e-4)


def test_spike_and_reset_and_refractory():
    """Driving V over theta spikes once, resets, and stays clamped t_ref steps."""
    p = LifParams(t_ref=0.5)  # 5 steps at dt=0.1
    packed = p.packed()
    props = p.propagators()
    n = 8
    v = jnp.full((n,), props["theta"] + 1.0, jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    v, _, _, r, s = lif.lif_update(v, z, z, z, z, z, packed, block=n)
    assert float(s.sum()) == n  # all spiked
    np.testing.assert_allclose(np.asarray(v), props["v_reset"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), props["t_ref"])
    # during refractoriness no integration happens and no second spike occurs
    big = jnp.full((n,), 1e4, jnp.float32)
    for step in range(int(props["t_ref"])):
        v, _, _, r, s = lif.lif_update(v, big, z, r, z, z, packed, block=n)
        assert float(s.sum()) == 0.0, f"spiked during refractory step {step}"
        np.testing.assert_allclose(np.asarray(v), props["v_reset"], rtol=1e-6)


def test_synaptic_current_jump_and_decay():
    p = LifParams()
    packed = p.packed()
    props = p.propagators()
    n = 4
    z = jnp.zeros((n,), jnp.float32)
    w = jnp.full((n,), 40.0, jnp.float32)
    _, i_ex, i_in, _, _ = lif.lif_update(z, z, z, z, w, -w, packed, block=n)
    np.testing.assert_allclose(np.asarray(i_ex), 40.0)
    np.testing.assert_allclose(np.asarray(i_in), -40.0)
    _, i_ex2, i_in2, _, _ = lif.lif_update(z, i_ex, i_in, z, z, z, packed, block=n)
    np.testing.assert_allclose(np.asarray(i_ex2), 40.0 * props["p11ex"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(i_in2), -40.0 * props["p11in"], rtol=1e-6)


def test_constant_current_fixed_point():
    """With I_e only, V converges to tau_m/C_m * I_e (below threshold)."""
    p = LifParams(i_e=300.0)
    packed = p.packed()
    n = 16
    v = jnp.zeros((n,), jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    for _ in range(3000):
        v, _, _, _, _ = lif.lif_update(v, z, z, z, z, z, packed, block=n)
    np.testing.assert_allclose(np.asarray(v), p.tau_m / p.c_m * 300.0, rtol=1e-3)


def test_equal_time_constants_degenerate_propagator():
    p = LifParams(tau_syn_ex=10.0, tau_syn_in=10.0, tau_m=10.0)
    props = p.propagators()
    assert math.isfinite(props["p21ex"]) and props["p21ex"] > 0
    out_k, out_r = _run_both(64, params=p, block=64)
    for k, r in zip(out_k, out_r):
        np.testing.assert_allclose(k, r, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 3, 16, 100, 256]),
    seed=st.integers(0, 2**31 - 1),
    tau_m=st.floats(1.0, 50.0),
    tau_syn=st.floats(0.1, 10.0),
    t_ref=st.floats(0.0, 5.0),
)
def test_hypothesis_kernel_vs_ref(n, seed, tau_m, tau_syn, t_ref):
    p = LifParams(tau_m=tau_m, tau_syn_ex=tau_syn, tau_syn_in=tau_syn,
                  t_ref=t_ref)
    out_k, out_r = _run_both(n, seed=seed, params=p, block=n)
    for k, r in zip(out_k, out_r):
        np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_multi_step_trajectory(seed):
    """10-step closed-loop trajectory stays in lockstep with the oracle."""
    p = LifParams().packed()
    kv = rv = _state(64, seed)[:4]
    w = _state(64, seed + 1)[4:6]
    kv, rv = list(kv), list(rv)
    for _ in range(10):
        ko = lif.lif_update(*kv, *w, p, block=64)
        ro = lif_update_ref(*rv, *w, p)
        kv, rv = list(ko[:4]), list(ro[:4])
        np.testing.assert_allclose(ko[4], ro[4])
    for k, r in zip(kv, rv):
        np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-5)


def test_spike_flag_is_binary():
    out_k, _ = _run_both(1024, seed=3)
    s = np.asarray(out_k[4])
    assert set(np.unique(s)).issubset({0.0, 1.0})
