"""L2 model shape/semantics tests + AOT lowering round-trip."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import lif
from compile.kernels.ref import LifParams, lif_update_ref


def test_rank_step_shapes():
    n = 2048
    f = jnp.zeros((n,), jnp.float32)
    p = LifParams().packed()
    outs = model.rank_step(f, f, f, f, f, f, p)
    assert len(outs) == 5
    for o in outs:
        assert o.shape == (n,) and o.dtype == jnp.float32


def test_rank_step_matches_ref():
    n = 4096
    rng = np.random.default_rng(7)
    args = [jnp.asarray(rng.uniform(-1, 1, n).astype(np.float32))
            for _ in range(6)]
    p = LifParams().packed()
    out_m = model.rank_step(*args, p)
    out_r = lif_update_ref(*args, p)
    for m, r in zip(out_m, out_r):
        np.testing.assert_allclose(m, r, rtol=1e-6, atol=1e-6)


def test_rank_step_abstract_lowerable():
    fn, args = model.rank_step_abstract(256)
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # tuple of 5 f32[256] outputs
    assert text.count("f32[256]") >= 5


@pytest.mark.parametrize("n", [256, 1024])
def test_hlo_text_parses_back(n):
    """Round-trip: the emitted HLO text must parse back into an HloModule.

    This is the same text-parser path the Rust runtime uses
    (``HloModuleProto::from_text_file``); numerical execution of the artifact
    is validated on the Rust side (rust/tests/it_runtime.rs) against vectors
    produced by the oracle here.
    """
    from jax._src.lib import xla_client as xc

    text = aot.lower_block(n)
    mod = xc._xla.hlo_module_from_text(text)
    roundtrip = mod.to_string()
    assert "HloModule" in roundtrip
    # 6 state/input arrays of f32[n] + f32[NUM_PARAMS] parameters
    assert text.count(f"f32[{n}]") >= 11
    assert f"f32[{lif.NUM_PARAMS}]" in text


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--blocks", "64", "128"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["param_order"] == list(lif.PARAM_ORDER)
    assert [b["block"] for b in manifest["blocks"]] == [64, 128]
    for b in manifest["blocks"]:
        text = (out / b["file"]).read_text()
        assert "HloModule" in text
