"""Emit golden test vectors for the Rust PJRT runtime integration test.

Runs the L1 oracle on a deterministic input block and writes
``artifacts/testvec.json`` with inputs, packed params and expected outputs;
``rust/tests/it_runtime.rs`` loads the AOT artifact, executes it through the
PJRT CPU client and asserts allclose against these vectors.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from .kernels.ref import LifParams, lif_update_ref

N = 256  # must match one of the AOT block sizes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    rng = np.random.default_rng(20250710)
    v = rng.uniform(-5.0, 16.0, N).astype(np.float32)
    i_ex = rng.uniform(0.0, 400.0, N).astype(np.float32)
    i_in = rng.uniform(-400.0, 0.0, N).astype(np.float32)
    r = rng.integers(0, 3, N).astype(np.float32)
    w_ex = rng.uniform(0.0, 80.0, N).astype(np.float32)
    w_in = rng.uniform(-80.0, 0.0, N).astype(np.float32)
    params = np.asarray(LifParams().packed(), dtype=np.float32)

    outs = lif_update_ref(*(jnp.asarray(a) for a in (v, i_ex, i_in, r, w_ex, w_in)),
                          jnp.asarray(params))
    vec = {
        "block": N,
        "inputs": {
            "v": v.tolist(), "i_ex": i_ex.tolist(), "i_in": i_in.tolist(),
            "r": r.tolist(), "w_ex": w_ex.tolist(), "w_in": w_in.tolist(),
            "params": params.tolist(),
        },
        "outputs": {
            "v": np.asarray(outs[0]).tolist(),
            "i_ex": np.asarray(outs[1]).tolist(),
            "i_in": np.asarray(outs[2]).tolist(),
            "r": np.asarray(outs[3]).tolist(),
            "spike": np.asarray(outs[4]).tolist(),
        },
    }
    path = os.path.join(args.out, "testvec.json")
    with open(path, "w") as f:
        json.dump(vec, f)
    print(f"testvec: wrote {path}")


if __name__ == "__main__":
    main()
