"""Layer-1 Pallas kernel: fused iaf_psc_exp LIF state update + spike detection.

This is the per-timestep device hot spot of the simulator: given the state of
a block of neurons (membrane potential, exponential synaptic currents,
refractory counters) and the synaptic input accumulated for the current time
step (read from the spike ring buffers by the Rust coordinator), advance the
state by one step ``dt`` with the exact (propagator-based) integration scheme
used by NEST's ``iaf_psc_exp`` model, and emit a 0/1 spike flag per neuron.

Hardware adaptation (the paper targets CUDA): on TPU this is a pure VPU
elementwise kernel — there is no matmul so the MXU is idle and the kernel is
memory-bandwidth-bound. We tile the neuron state SoA into VMEM-resident
blocks via ``BlockSpec`` (``BLOCK`` f32 lanes per array; 7 inputs + 5 outputs
of 4 B each = 48 B of HBM traffic per neuron per step), which leaves ample
VMEM headroom for double buffering the HBM<->VMEM stream. The CUDA version's
one-thread-per-neuron mapping becomes a lane-per-neuron mapping here.

The kernel MUST run with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are validated
against the pure-jnp oracle in ``ref.py`` (pytest + hypothesis).

State layout (all ``f32[n]``):
    v     membrane potential, relative to E_L (mV)
    i_ex  excitatory synaptic current (pA)
    i_in  inhibitory synaptic current (pA)
    r     remaining refractory steps (integer-valued f32)
Inputs (``f32[n]``):
    w_ex  summed excitatory synaptic weight arriving this step (pA jump)
    w_in  summed inhibitory synaptic weight arriving this step (pA jump, <=0)
Parameters (``f32[NUM_PARAMS]``, see PARAM_ORDER; broadcast over the block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Order of the packed scalar-parameter vector. The Rust runtime
# (rust/src/runtime/params.rs) packs parameters in exactly this order; keep
# the two lists in sync (checked by artifacts/manifest.json at load time).
PARAM_ORDER = (
    "p22",     # exp(-dt / tau_m)
    "p21ex",   # exact propagator: i_ex -> v
    "p21in",   # exact propagator: i_in -> v
    "p20",     # exact propagator: constant current I_e -> v
    "p11ex",   # exp(-dt / tau_syn_ex)
    "p11in",   # exp(-dt / tau_syn_in)
    "theta",   # spike threshold, relative to E_L (mV)
    "v_reset", # reset potential, relative to E_L (mV)
    "t_ref",   # refractory period in steps (integer-valued)
    "i_e",     # constant input current (pA)
)
NUM_PARAMS = len(PARAM_ORDER)

# Default block width: one VMEM tile of the neuron SoA. 12 arrays x 1024 x 4 B
# = 48 KiB per tile, far below the ~16 MiB VMEM budget -> allows aggressive
# double-buffering on real hardware.
BLOCK = 1024


def _lif_kernel(v_ref, iex_ref, iin_ref, r_ref, wex_ref, win_ref, p_ref,
                v_out, iex_out, iin_out, r_out, spike_out):
    """Pallas kernel body: one fused elementwise LIF update over a block."""
    v = v_ref[...]
    i_ex = iex_ref[...]
    i_in = iin_ref[...]
    r = r_ref[...]
    w_ex = wex_ref[...]
    w_in = win_ref[...]

    p22 = p_ref[0]
    p21ex = p_ref[1]
    p21in = p_ref[2]
    p20 = p_ref[3]
    p11ex = p_ref[4]
    p11in = p_ref[5]
    theta = p_ref[6]
    v_reset = p_ref[7]
    t_ref = p_ref[8]
    i_e = p_ref[9]

    not_ref = r <= 0.0
    # Exact subthreshold propagation (NEST iaf_psc_exp ordering: V first,
    # using the currents of the previous step, then current decay + input).
    v_prop = p22 * v + p21ex * i_ex + p21in * i_in + p20 * i_e
    v_new = jnp.where(not_ref, v_prop, v)

    i_ex_new = p11ex * i_ex + w_ex
    i_in_new = p11in * i_in + w_in

    spike = jnp.logical_and(not_ref, v_new >= theta)
    v_new = jnp.where(spike, v_reset, v_new)
    r_new = jnp.where(spike, t_ref, jnp.maximum(r - 1.0, 0.0))

    v_out[...] = v_new
    iex_out[...] = i_ex_new
    iin_out[...] = i_in_new
    r_out[...] = r_new
    spike_out[...] = spike.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def lif_update(v, i_ex, i_in, r, w_ex, w_in, params, *, block: int = BLOCK):
    """Advance a padded neuron block array one time step.

    All state/input arrays must share shape ``(n,)`` with ``n`` a multiple of
    ``block``; ``params`` is ``(NUM_PARAMS,)``. Returns
    ``(v', i_ex', i_in', r', spike)``.
    """
    n = v.shape[0]
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    grid = (n // block,)
    state_spec = pl.BlockSpec((block,), lambda i: (i,))
    # The parameter vector is broadcast to every grid step.
    param_spec = pl.BlockSpec((NUM_PARAMS,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(5)]
    return pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[state_spec] * 6 + [param_spec],
        out_specs=[state_spec] * 5,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(v, i_ex, i_in, r, w_ex, w_in, params)
