"""Pure-jnp oracle for the L1 Pallas LIF kernel.

This is the correctness reference for ``lif.py`` (and, transitively, for the
Rust native backend, which mirrors the same update): the exact-integration
iaf_psc_exp scheme written as plain jax.numpy, with the propagators computed
from the biophysical parameters.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp

from .lif import NUM_PARAMS, PARAM_ORDER


@dataclass(frozen=True)
class LifParams:
    """Biophysical iaf_psc_exp parameters (NEST defaults unless noted)."""

    tau_m: float = 10.0       # membrane time constant (ms)
    c_m: float = 250.0        # membrane capacitance (pF)
    tau_syn_ex: float = 0.5   # excitatory synaptic time constant (ms)
    tau_syn_in: float = 0.5   # inhibitory synaptic time constant (ms)
    e_l: float = -65.0        # resting potential (mV); state v is V_m - E_L
    v_th: float = -50.0       # spike threshold (mV, absolute)
    v_reset: float = -65.0    # reset potential (mV, absolute)
    t_ref: float = 2.0        # refractory period (ms)
    i_e: float = 0.0          # constant input current (pA)
    dt: float = 0.1           # integration step (ms)

    def propagators(self) -> dict:
        """Exact propagator matrix entries for step dt (as in NEST)."""
        h = self.dt
        p22 = math.exp(-h / self.tau_m)
        p11ex = math.exp(-h / self.tau_syn_ex)
        p11in = math.exp(-h / self.tau_syn_in)

        def p21(tau_syn: float, p11: float) -> float:
            if abs(tau_syn - self.tau_m) < 1e-9:
                # degenerate limit tau_syn -> tau_m: h/C * exp(-h/tau)
                return h / self.c_m * p22
            return (
                self.tau_m * tau_syn
                / (self.c_m * (self.tau_m - tau_syn))
                * (p22 - p11)
            )

        p21ex = p21(self.tau_syn_ex, p11ex)
        p21in = p21(self.tau_syn_in, p11in)
        p20 = self.tau_m / self.c_m * (1.0 - p22)
        return {
            "p22": p22,
            "p21ex": p21ex,
            "p21in": p21in,
            "p20": p20,
            "p11ex": p11ex,
            "p11in": p11in,
            "theta": self.v_th - self.e_l,
            "v_reset": self.v_reset - self.e_l,
            "t_ref": round(self.t_ref / h),
            "i_e": self.i_e,
        }

    def packed(self) -> jnp.ndarray:
        """Parameter vector in PARAM_ORDER, as consumed by the kernel."""
        props = self.propagators()
        return jnp.asarray([props[k] for k in PARAM_ORDER], dtype=jnp.float32)


def lif_update_ref(v, i_ex, i_in, r, w_ex, w_in, params):
    """Reference LIF update; semantics identical to kernels.lif._lif_kernel."""
    assert params.shape == (NUM_PARAMS,)
    p22, p21ex, p21in, p20, p11ex, p11in, theta, v_reset, t_ref, i_e = [
        params[i] for i in range(NUM_PARAMS)
    ]
    not_ref = r <= 0.0
    v_prop = p22 * v + p21ex * i_ex + p21in * i_in + p20 * i_e
    v_new = jnp.where(not_ref, v_prop, v)
    i_ex_new = p11ex * i_ex + w_ex
    i_in_new = p11in * i_in + w_in
    spike = jnp.logical_and(not_ref, v_new >= theta)
    v_new = jnp.where(spike, v_reset, v_new)
    r_new = jnp.where(spike, t_ref, jnp.maximum(r - 1.0, 0.0))
    return v_new, i_ex_new, i_in_new, r_new, spike.astype(jnp.float32)
