"""Layer-2 JAX model: the per-rank, per-timestep neuron-state update.

The paper's state-propagation loop interleaves (a) spike delivery through the
connection structures — owned by the Rust Layer-3 coordinator — and (b) the
device-side neuron dynamics update — this module. ``rank_step`` is the
computation the coordinator calls once per time step per state block: it
wraps the Layer-1 Pallas kernel so that the lowered HLO contains the kernel
body inline.

This module is build-time only. ``aot.py`` lowers ``rank_step`` once per
block size to HLO text under ``artifacts/``; Python never runs on the
request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import lif
from .kernels.lif import NUM_PARAMS


def rank_step(v, i_ex, i_in, r, w_ex, w_in, params):
    """One propagation step for a block of neurons.

    Thin by design: the coordination (ring buffers, spike routing, MPI) is
    Layer 3's contribution in this paper; the device kernel is the fused LIF
    update. Returns ``(v', i_ex', i_in', r', spike)``.
    """
    return lif.lif_update(v, i_ex, i_in, r, w_ex, w_in, params,
                          block=min(lif.BLOCK, v.shape[0]))


def rank_step_abstract(n: int):
    """(lowerable_fn, example_args) for a block array of ``n`` neurons."""
    f32 = jnp.float32
    state = jax.ShapeDtypeStruct((n,), f32)
    params = jax.ShapeDtypeStruct((NUM_PARAMS,), f32)

    def fn(v, i_ex, i_in, r, w_ex, w_in, p):
        return rank_step(v, i_ex, i_in, r, w_ex, w_in, p)

    return fn, (state, state, state, state, state, state, params)
