"""AOT bridge: lower the L2 model to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts

Writes ``lif_b<N>.hlo.txt`` for each block size plus ``manifest.json``
recording block sizes and the parameter packing order the Rust side must use.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.lif import NUM_PARAMS, PARAM_ORDER

# Block sizes to AOT-compile. The runtime picks the largest block <= the
# remaining padded neuron count, so a rank with 40k neurons does 4 calls at
# 8192 + 8 calls at 1024 rather than 40 calls at 1024.
BLOCK_SIZES = (256, 1024, 8192)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block(n: int) -> str:
    fn, args = model.rank_step_abstract(n)
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--blocks", type=int, nargs="*", default=list(BLOCK_SIZES))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for n in args.blocks:
        text = lower_block(n)
        fname = f"lif_b{n}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entries.append({"block": n, "file": fname})
        print(f"aot: wrote {fname} ({len(text)} chars)")

    manifest = {
        "kernel": "iaf_psc_exp",
        "version": 1,
        "num_params": NUM_PARAMS,
        "param_order": list(PARAM_ORDER),
        "blocks": entries,
        # 6 array inputs + params; 5 array outputs as a tuple.
        "inputs": ["v", "i_ex", "i_in", "r", "w_ex", "w_in", "params"],
        "outputs": ["v", "i_ex", "i_in", "r", "spike"],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"aot: wrote manifest.json ({len(entries)} blocks)")


if __name__ == "__main__":
    main()
