import os
import sys

# Allow `pytest python/tests` from the repo root as well as `pytest tests`
# from python/: make the `compile` package importable either way.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
