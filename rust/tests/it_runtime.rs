//! Integration: the PJRT runtime executes the AOT artifact (HLO text of the
//! L2 JAX model with the L1 Pallas kernel inlined) and matches both the
//! Python oracle's golden vectors (artifacts/testvec.json) and the native
//! Rust backend. Skips gracefully when artifacts have not been built
//! (`make artifacts`).

use std::path::PathBuf;

use nestgpu::memory::Tracker;
use nestgpu::node::neuron::{LifParams, NUM_PARAMS};
use nestgpu::runtime::{native::NativeBackend, pjrt::PjrtBackend, Backend, StateChunk};
use nestgpu::util::json::Json;
use nestgpu::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

fn approx(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn pjrt_matches_python_golden_vectors() {
    let Some(dir) = artifacts_dir() else { return };
    let vec = Json::parse_file(&dir.join("testvec.json")).expect("testvec.json");
    let n = vec.get("block").unwrap().as_usize().unwrap();
    let inputs = vec.get("inputs").unwrap();
    let outputs = vec.get("outputs").unwrap();
    let get = |o: &Json, k: &str| o.get(k).unwrap().as_f32_vec().unwrap();

    let mut tr = Tracker::new();
    let params_v = get(inputs, "params");
    let mut params = [0f32; NUM_PARAMS];
    params.copy_from_slice(&params_v);
    let mut chunk = StateChunk::new(n, params, &mut tr);
    chunk.v[..n].copy_from_slice(&get(inputs, "v"));
    chunk.i_ex[..n].copy_from_slice(&get(inputs, "i_ex"));
    chunk.i_in[..n].copy_from_slice(&get(inputs, "i_in"));
    chunk.r[..n].copy_from_slice(&get(inputs, "r"));
    chunk.w_ex[..n].copy_from_slice(&get(inputs, "w_ex"));
    chunk.w_in[..n].copy_from_slice(&get(inputs, "w_in"));

    let mut be = PjrtBackend::load(&dir).expect("load artifacts");
    be.step(&mut chunk).expect("pjrt step");

    approx(&chunk.v[..n], &get(outputs, "v"), 1e-5, "v");
    approx(&chunk.i_ex[..n], &get(outputs, "i_ex"), 1e-5, "i_ex");
    approx(&chunk.i_in[..n], &get(outputs, "i_in"), 1e-5, "i_in");
    approx(&chunk.r[..n], &get(outputs, "r"), 0.0, "r");
    approx(&chunk.spike[..n], &get(outputs, "spike"), 0.0, "spike");
}

#[test]
fn pjrt_and_native_agree_over_trajectory() {
    let Some(dir) = artifacts_dir() else { return };
    let mut tr = Tracker::new();
    let params = LifParams::default().packed(0.1);
    let n = 700; // pads to 768 -> exercises mixed block segments
    let mut a = StateChunk::new(n, params, &mut tr);
    let mut b = StateChunk::new(n, params, &mut tr);
    let mut rng = Rng::new(11);
    for i in 0..n {
        let v = rng.uniform_range(-5.0, 14.0) as f32;
        a.v[i] = v;
        b.v[i] = v;
    }
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut nat = NativeBackend::new();
    for step in 0..20 {
        for i in 0..n {
            let w = rng.uniform_range(0.0, 60.0) as f32;
            a.w_ex[i] = w;
            b.w_ex[i] = w;
        }
        pjrt.step(&mut a).unwrap();
        nat.step(&mut b).unwrap();
        assert_eq!(
            a.spiking().collect::<Vec<_>>(),
            b.spiking().collect::<Vec<_>>(),
            "spike sets diverged at step {step}"
        );
        approx(&a.v[..n], &b.v[..n], 2e-4, "v");
        approx(&a.i_ex[..n], &b.i_ex[..n], 2e-4, "i_ex");
    }
    assert!(pjrt.calls > 0);
}

#[test]
fn pjrt_uses_largest_blocks_greedily() {
    let Some(dir) = artifacts_dir() else { return };
    let mut tr = Tracker::new();
    let params = LifParams::default().packed(0.1);
    // 8192 + 1024 + 256 = 9472 neurons -> exactly 3 calls
    let mut c = StateChunk::new(9472, params, &mut tr);
    let mut be = PjrtBackend::load(&dir).unwrap();
    be.step(&mut c).unwrap();
    assert_eq!(be.calls, 3, "greedy segmentation should use 3 executions");
}
