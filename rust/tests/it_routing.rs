//! Integration: live multi-rank spike routing — delays, determinism, and
//! p2p ≡ collective equivalence.

use nestgpu::connection::{ConnRule, NodeSet, SynSpec};
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::node::LifParams;
use nestgpu::remote::GpuMemLevel;

/// A pacemaker on rank 0 (high constant current) drives a follower on
/// rank 1 through a remote connection with a known delay; the follower
/// must spike a fixed lag after the pacemaker, every time.
#[test]
fn remote_spikes_arrive_with_exact_delay() {
    const DELAY: u16 = 7;
    let cfg = SimConfig::default();
    let builder = |sim: &mut Simulator| {
        let mut p = LifParams::default();
        if sim.rank() == 0 {
            p.i_e = 600.0; // pacemaker drive
        }
        sim.create_neurons(1, &p);
        sim.remote_connect(
            0,
            &NodeSet::range(0, 1),
            1,
            &NodeSet::range(0, 1),
            &ConnRule::OneToOne,
            &SynSpec::new(20_000.0, DELAY as u32), // suprathreshold kick (dV ~ 40 mV)
            None,
        );
    };
    let results = run_cluster(2, &cfg, &builder, 100.0).unwrap();
    let spikes0: Vec<u32> = results[0].spikes.iter().map(|&(t, _)| t).collect();
    let spikes1: Vec<u32> = results[1].spikes.iter().map(|&(t, _)| t).collect();
    assert!(spikes0.len() >= 3, "pacemaker must fire repeatedly");
    assert!(!spikes1.is_empty(), "follower must fire");
    // every follower spike trails its pacemaker cause by a *constant* lag:
    // DELAY steps of transmission + 1 step of buffer hand-off + a few
    // steps of PSC integration up to threshold (deterministic dynamics)
    let lag = spikes1[0] - spikes0[0];
    assert!(
        lag > DELAY as u32 && lag <= DELAY as u32 + 8,
        "lag {lag} outside transmission window"
    );
    for (t0, t1) in spikes0.iter().zip(spikes1.iter()) {
        assert_eq!(
            *t1 - *t0,
            lag,
            "jittering lag: routing is not delay-faithful ({spikes0:?} vs {spikes1:?})"
        );
    }
}

fn balanced_like(sim: &mut Simulator, collective: bool) {
    let p = LifParams::default();
    sim.create_neurons(40, &p);
    let gen = sim.create_poisson(30_000.0);
    sim.connect(
        &gen,
        &NodeSet::range(0, 40),
        &ConnRule::AllToAll,
        &SynSpec::new(45.0, 1),
    );
    let group = collective.then(|| sim.register_group((0..sim.n_ranks()).collect()));
    let n_ranks = sim.n_ranks();
    for src in 0..n_ranks {
        for tgt in 0..n_ranks {
            if src == tgt {
                continue;
            }
            sim.remote_connect(
                src,
                &NodeSet::range(0, 40),
                tgt,
                &NodeSet::range(0, 40),
                &ConnRule::FixedIndegree { k: 5 },
                &SynSpec::new(20.0, 3),
                group,
            );
        }
    }
}

fn spike_sets(results: &[SimResult]) -> Vec<Vec<(u32, u32)>> {
    results.iter().map(|r| r.spikes.clone()).collect()
}

#[test]
fn p2p_and_collective_deliver_identical_spike_trains() {
    let cfg = SimConfig::default();
    let p2p = run_cluster(3, &cfg, &|s: &mut Simulator| balanced_like(s, false), 80.0).unwrap();
    let coll = run_cluster(3, &cfg, &|s: &mut Simulator| balanced_like(s, true), 80.0).unwrap();
    let (a, b) = (spike_sets(&p2p), spike_sets(&coll));
    assert!(a.iter().map(|s| s.len()).sum::<usize>() > 0, "network silent");
    assert_eq!(a, b, "p2p and collective must produce identical dynamics");
}

#[test]
fn runs_are_deterministic_given_seed() {
    let cfg = SimConfig::default();
    let a = run_cluster(3, &cfg, &|s: &mut Simulator| balanced_like(s, true), 50.0).unwrap();
    let b = run_cluster(3, &cfg, &|s: &mut Simulator| balanced_like(s, true), 50.0).unwrap();
    assert_eq!(spike_sets(&a), spike_sets(&b));
    // different seed -> different realization
    let mut cfg2 = cfg.clone();
    cfg2.seed += 1;
    let c = run_cluster(3, &cfg2, &|s: &mut Simulator| balanced_like(s, true), 50.0).unwrap();
    assert_ne!(spike_sets(&a), spike_sets(&c));
}

#[test]
fn all_gpu_memory_levels_produce_identical_dynamics() {
    // placement trades memory for speed; the spike trains must not change
    let mut reference: Option<Vec<Vec<(u32, u32)>>> = None;
    for level in [
        GpuMemLevel::L0,
        GpuMemLevel::L1,
        GpuMemLevel::L2,
        GpuMemLevel::L3,
    ] {
        let cfg = SimConfig {
            level,
            ..Default::default()
        };
        let r = run_cluster(3, &cfg, &|s: &mut Simulator| balanced_like(s, true), 60.0)
            .unwrap();
        let s = spike_sets(&r);
        match &reference {
            None => reference = Some(s),
            Some(want) => assert_eq!(&s, want, "level {level:?} diverged"),
        }
    }
}

#[test]
fn traffic_flows_only_where_connectivity_exists() {
    // star topology: rank 0 -> others only; others never send p2p traffic
    let cfg = SimConfig::default();
    let builder = |sim: &mut Simulator| {
        let mut p = LifParams::default();
        if sim.rank() == 0 {
            p.i_e = 600.0;
        }
        sim.create_neurons(5, &p);
        for tgt in 1..sim.n_ranks() {
            sim.remote_connect(
                0,
                &NodeSet::range(0, 5),
                tgt,
                &NodeSet::range(0, 5),
                &ConnRule::AllToAll,
                &SynSpec::new(10.0, 2),
                None,
            );
        }
    };
    let results = run_cluster(3, &cfg, &builder, 50.0).unwrap();
    assert!(results[0].p2p_bytes > 0, "hub must send");
    assert_eq!(results[1].p2p_bytes, 0, "leaf 1 must not send");
    assert_eq!(results[2].p2p_bytes, 0, "leaf 2 must not send");
}
