//! Integration: the estimation (dry-run) methodology — k live ranks
//! dry-running an n-rank world measure the same code path as the live run
//! (the basis of the paper's 4,096-node projections and Fig. 13).

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::{estimate_cluster, run_construction_only};
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::remote::levels::ALL_LEVELS;

fn bal() -> BalancedConfig {
    BalancedConfig {
        scale: 0.003,
        k_scale: 0.003,
        ..Default::default()
    }
}

#[test]
fn estimated_structures_equal_live_per_rank_all_levels() {
    for level in ALL_LEVELS {
        let cfg = SimConfig {
            level,
            ..Default::default()
        };
        let live =
            run_construction_only(4, &cfg, &|s: &mut Simulator| build_balanced(s, &bal()))
                .unwrap();
        let est = estimate_cluster(4, 4, &cfg, &|s: &mut Simulator| build_balanced(s, &bal()))
            .unwrap();
        for (l, e) in live.iter().zip(est.iter()) {
            assert_eq!(l.n_neurons, e.n_neurons, "{level:?}");
            assert_eq!(l.n_images, e.n_images, "{level:?}");
            assert_eq!(l.n_connections, e.n_connections, "{level:?}");
            assert_eq!(l.map_entries, e.map_entries, "{level:?}");
            assert_eq!(l.device_peak, e.device_peak, "{level:?} device peak");
        }
    }
}

#[test]
fn partial_estimation_samples_the_virtual_world() {
    // 2 live ranks of a virtual 8-rank world: per-rank structures must
    // match the corresponding ranks of the full live 8-rank run
    let cfg = SimConfig::default();
    let live = run_construction_only(8, &cfg, &|s: &mut Simulator| build_balanced(s, &bal()))
        .unwrap();
    let est = estimate_cluster(2, 8, &cfg, &|s: &mut Simulator| build_balanced(s, &bal()))
        .unwrap();
    for (l, e) in live.iter().take(2).zip(est.iter()) {
        assert_eq!(l.n_connections, e.n_connections);
        assert_eq!(l.n_images, e.n_images);
        assert_eq!(l.device_peak, e.device_peak);
    }
}

#[test]
fn estimation_scales_to_large_virtual_worlds() {
    // the whole point: one thread estimates a 512-rank configuration
    let cfg = SimConfig::default();
    let bal = BalancedConfig {
        scale: 0.001,
        k_scale: 0.001,
        ..Default::default()
    };
    let est = estimate_cluster(1, 512, &cfg, &move |s: &mut Simulator| {
        build_balanced(s, &bal)
    })
    .unwrap();
    let r = &est[0];
    assert!(r.n_connections > 0);
    // image count bounded by the used-source plateau (level 2: all-source
    // images) — with 512 ranks the remote population dwarfs local draws
    assert!(r.n_images > r.n_neurons);
}

#[test]
fn estimation_phase_times_populated() {
    let cfg = SimConfig::default();
    let est = estimate_cluster(2, 16, &cfg, &|s: &mut Simulator| build_balanced(s, &bal()))
        .unwrap();
    for r in &est {
        assert!(r.phases.preparation.as_nanos() > 0);
        assert!(r.phases.node_creation.as_nanos() > 0);
        assert_eq!(r.phases.propagation.as_nanos(), 0);
    }
}
