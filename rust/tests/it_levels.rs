//! Integration: GPU memory levels (§0.3.6) — placement, flagging and
//! memory-ordering behaviour on a live balanced workload.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_construction_only;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};

fn bal() -> BalancedConfig {
    BalancedConfig {
        scale: 0.004,
        k_scale: 0.004,
        ..Default::default()
    }
}

fn run_level(level: GpuMemLevel, ranks: usize) -> Vec<nestgpu::engine::SimResult> {
    let cfg = SimConfig {
        level,
        ..Default::default()
    };
    run_construction_only(ranks, &cfg, &|sim: &mut Simulator| build_balanced(sim, &bal()))
        .unwrap()
}

#[test]
fn device_memory_ordered_by_level() {
    let peaks: Vec<u64> = ALL_LEVELS
        .iter()
        .map(|&lvl| run_level(lvl, 4)[0].device_peak)
        .collect();
    // §0.3.6: "ordered by increasing GPU memory usage"
    for w in peaks.windows(2) {
        assert!(
            w[0] <= w[1],
            "device peaks not monotonically increasing: {peaks:?}"
        );
    }
    assert!(
        peaks[3] > peaks[0],
        "level 3 must use strictly more device memory than level 0: {peaks:?}"
    );
}

#[test]
fn level0_creates_fewer_images_when_sparse() {
    // the ξ heuristic flags only when the expected connections per source
    // fall below 1 (paper: K_in/P < ξ); so use K_in = 2 over 8 ranks —
    // most remote sources unused: level 0 flags them away, level 1+
    // images every source passed to RemoteConnect
    let mut bal = bal();
    bal.k_scale = 1e-6; // K_in,E = K_in,I = 1 (clamped minimum)
    const RANKS: usize = 8;
    let mk = |level| {
        let cfg = SimConfig {
            level,
            ..Default::default()
        };
        let b = bal.clone();
        run_construction_only(RANKS, &cfg, &move |sim: &mut Simulator| {
            build_balanced(sim, &b)
        })
        .unwrap()[0]
            .n_images
    };
    let l0 = mk(GpuMemLevel::L0);
    let l1 = mk(GpuMemLevel::L1);
    assert!(
        l0 < l1,
        "flagging must reduce image count (l0={l0}, l1={l1})"
    );
    // level 1 images the full remote populations: (ranks-1) * neurons
    assert_eq!(l1, (RANKS as u64 - 1) * bal.neurons_per_rank() as u64);
}

#[test]
fn host_memory_higher_on_low_levels() {
    let l0 = run_level(GpuMemLevel::L0, 4)[0].host_peak;
    let l3 = run_level(GpuMemLevel::L3, 4)[0].host_peak;
    assert!(
        l0 > l3,
        "levels 0/1 park map structures in host memory (l0={l0}, l3={l3})"
    );
}

#[test]
fn structure_counts_identical_across_levels_at_same_flagging() {
    // levels 1-3 differ only in placement: identical images, conns, maps
    let runs: Vec<_> = [GpuMemLevel::L1, GpuMemLevel::L2, GpuMemLevel::L3]
        .iter()
        .map(|&lvl| run_level(lvl, 4))
        .collect();
    for pair in runs.windows(2) {
        for (a, b) in pair[0].iter().zip(pair[1].iter()) {
            assert_eq!(a.n_images, b.n_images);
            assert_eq!(a.n_connections, b.n_connections);
            assert_eq!(a.map_entries, b.map_entries);
        }
    }
}
