//! Integration: procedural connectivity (DESIGN.md §16).
//!
//! The procedural mode records static connect calls as compact RNG-seeded
//! descriptors and regenerates each spiking neuron's fanout at delivery
//! time. The contract is *bit-identity*: spike trains (and plastic
//! weights, which stay materialized) must match the materialized mode
//! exactly —
//!
//! - for 1, 2 and 4 ranks, over both communication protocols, for static
//!   and STDP scenarios, over the thread and socket transports;
//! - through snapshot format v4 (descriptor store + captured RNG states
//!   travel in the `PROC` section; construction cache and mid-run
//!   checkpoints both resume bit-identically);
//! - while v3 containers (materialized by construction) still load;
//! - with >= 5x lower per-rank connectivity memory at a scale where the
//!   fanout cache's 64 KiB floor no longer dominates.

use std::path::PathBuf;

use nestgpu::comm::SocketConfig;
use nestgpu::connection::Connectivity;
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{
    free_loopback_addr, run_cluster, run_cluster_from_snapshot, run_cluster_socket,
    run_cluster_with_snapshot,
};
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::obs::{CounterId, ObsConfig};
use nestgpu::snapshot::format::tags;
use nestgpu::snapshot::{SnapshotReader, SnapshotWriter};
use nestgpu::util::table::fmt_bytes;

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("nestgpu_it_proc_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Small balanced network: 45 neurons per rank, K_in = 45.
fn small_bal(collective: bool, stdp: bool) -> BalancedConfig {
    BalancedConfig {
        scale: 0.004,
        k_scale: 0.004,
        collective,
        stdp: stdp.then(|| StdpScenario {
            lambda: 0.05,
            ..Default::default()
        }),
        ..Default::default()
    }
}

fn cfg_with(mode: Connectivity) -> SimConfig {
    SimConfig {
        connectivity: mode,
        ..Default::default()
    }
}

fn run_mode(
    mode: Connectivity,
    ranks: usize,
    collective: bool,
    stdp: bool,
    t_ms: f64,
) -> Vec<SimResult> {
    let bal = small_bal(collective, stdp);
    run_cluster(
        ranks,
        &cfg_with(mode),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

/// Per-rank (spike train, plastic-weight hash) — the bit-identity witness.
fn fingerprints(results: &[SimResult]) -> Vec<(&[(u32, u32)], Option<u64>)> {
    results
        .iter()
        .map(|r| (r.spikes.as_slice(), r.plastic.map(|p| p.hash)))
        .collect()
}

#[test]
fn procedural_matches_materialized_static_1_2_4_ranks_both_protocols() {
    for ranks in [1usize, 2, 4] {
        for collective in [true, false] {
            let mat = run_mode(Connectivity::Materialized, ranks, collective, false, 100.0);
            let proc_ = run_mode(Connectivity::Procedural, ranks, collective, false, 100.0);
            let spikes: u64 = mat.iter().map(|r| r.n_spikes).sum();
            assert!(
                spikes > 20,
                "{ranks} ranks: network must spike ({spikes})"
            );
            assert_eq!(
                fingerprints(&mat),
                fingerprints(&proc_),
                "{ranks} ranks, collective={collective}: procedural spike \
                 trains diverged from materialized"
            );
            for (m, p) in mat.iter().zip(proc_.iter()) {
                assert_eq!(
                    m.n_connections, p.n_connections,
                    "rank {}: connection counts diverged",
                    m.rank
                );
            }
        }
    }
}

#[test]
fn procedural_matches_materialized_with_stdp() {
    // plastic (STDP) synapses stay materialized in procedural mode; the
    // static remainder is regenerated — final weights must be bit-equal
    for ranks in [1usize, 2, 4] {
        for collective in [true, false] {
            let mat = run_mode(Connectivity::Materialized, ranks, collective, true, 80.0);
            let proc_ = run_mode(Connectivity::Procedural, ranks, collective, true, 80.0);
            for r in &proc_ {
                assert!(r.n_plastic > 0, "rank {} has no plastic synapses", r.rank);
            }
            assert_eq!(
                fingerprints(&mat),
                fingerprints(&proc_),
                "{ranks} ranks, collective={collective}: STDP procedural run \
                 diverged (spikes or plastic weights)"
            );
        }
    }
}

#[test]
fn procedural_socket_transport_matches_thread() {
    let mat = run_mode(Connectivity::Materialized, 2, true, false, 60.0);
    let scfg = SocketConfig::new(free_loopback_addr().unwrap(), 2);
    let bal = small_bal(true, false);
    let proc_ = run_cluster_socket(
        2,
        &cfg_with(Connectivity::Procedural),
        &scfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        60.0,
    )
    .unwrap();
    assert_eq!(
        fingerprints(&mat),
        fingerprints(&proc_),
        "procedural over TCP loopback diverged from materialized threads"
    );
}

#[test]
fn procedural_snapshot_v4_roundtrips() {
    let dir = tmp_dir("v4");
    let baseline = run_mode(Connectivity::Procedural, 2, true, false, 100.0);

    // construction cache: save right after prepare(), resume the full run
    run_cluster_with_snapshot(
        2,
        &cfg_with(Connectivity::Procedural),
        &|sim: &mut Simulator| build_balanced(sim, &small_bal(true, false)),
        0.0,
        &dir,
    )
    .unwrap();

    // the on-disk container is format v4 and carries the PROC section
    let bytes = std::fs::read(dir.join(nestgpu::snapshot::rank_file_name(0))).unwrap();
    let r = SnapshotReader::open(&bytes).unwrap();
    assert_eq!(r.version(), 4);
    assert!(r.try_section(tags::PROC).is_some(), "PROC section missing");

    let restored = run_cluster_from_snapshot(&dir, 100.0).unwrap();
    assert_eq!(fingerprints(&baseline), fingerprints(&restored));
    for r in &restored {
        assert_eq!(
            r.phases.construction().as_nanos(),
            0,
            "restored rank {} paid construction",
            r.rank
        );
    }

    // mid-run checkpoint: 50 ms + 50 ms resumed == 100 ms uninterrupted
    let dir2 = tmp_dir("v4mid");
    run_cluster_with_snapshot(
        2,
        &cfg_with(Connectivity::Procedural),
        &|sim: &mut Simulator| build_balanced(sim, &small_bal(true, false)),
        50.0,
        &dir2,
    )
    .unwrap();
    let resumed = run_cluster_from_snapshot(&dir2, 50.0).unwrap();
    assert_eq!(fingerprints(&baseline), fingerprints(&resumed));

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// Rewrite a v4 *materialized* snapshot as a genuine v3 container: strip
/// the trailing connectivity byte the v4 CONF appends and re-stamp the
/// version. Byte-exact, since v4 is a strict append over v3.
fn downgrade_to_v3(bytes: &[u8]) -> Vec<u8> {
    let r = SnapshotReader::open(bytes).unwrap();
    assert!(
        r.try_section(tags::PROC).is_none(),
        "materialized snapshot expected"
    );
    let mut w = SnapshotWriter::new();
    for tag in r.section_tags() {
        let mut payload = r.section(tag).unwrap().to_vec();
        if tag == tags::CONF {
            payload.truncate(payload.len() - 1);
        }
        w.section(tag, payload);
    }
    w.finish_with_version(3)
}

#[test]
fn v3_snapshots_still_load_as_materialized() {
    let dir = tmp_dir("v3");
    let baseline = run_mode(Connectivity::Materialized, 2, true, false, 100.0);
    run_cluster_with_snapshot(
        2,
        &SimConfig::default(),
        &|sim: &mut Simulator| build_balanced(sim, &small_bal(true, false)),
        0.0,
        &dir,
    )
    .unwrap();
    for rank in 0..2 {
        let path = dir.join(nestgpu::snapshot::rank_file_name(rank));
        let v3 = downgrade_to_v3(&std::fs::read(&path).unwrap());
        assert_eq!(SnapshotReader::open(&v3).unwrap().version(), 3);
        std::fs::write(&path, v3).unwrap();
    }
    let restored = run_cluster_from_snapshot(&dir, 100.0).unwrap();
    assert_eq!(fingerprints(&baseline), fingerprints(&restored));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn procedural_cuts_connectivity_memory_5x() {
    // large enough that est/4 bounds the fanout cache instead of its
    // 64 KiB floor: ~337 neurons, K_in ~337 -> ~110k connections per rank
    let bal = BalancedConfig {
        scale: 0.03,
        k_scale: 0.03,
        ..Default::default()
    };
    let run = |mode: Connectivity| -> Vec<SimResult> {
        let bal = bal.clone();
        run_cluster(
            1,
            &cfg_with(mode),
            &move |sim: &mut Simulator| build_balanced(sim, &bal),
            20.0,
        )
        .unwrap()
    };
    let mat = run(Connectivity::Materialized);
    let proc_ = run(Connectivity::Procedural);
    assert_eq!(fingerprints(&mat), fingerprints(&proc_));

    let (mb, pb) = (mat[0].conn_bytes, proc_[0].conn_bytes);
    let ratio = mb as f64 / pb.max(1) as f64;
    // steps/s regression is reported, not asserted (timing-noisy in CI)
    let steps_per_s = |r: &SimResult| 200.0 / r.phases.propagation.as_secs_f64().max(1e-9);
    println!(
        "connectivity memory {} -> {} ({ratio:.1}x); steps/s {:.0} -> {:.0}",
        fmt_bytes(mb),
        fmt_bytes(pb),
        steps_per_s(&mat[0]),
        steps_per_s(&proc_[0]),
    );
    assert!(
        ratio >= 5.0,
        "procedural mode must cut per-rank connectivity memory >= 5x \
         (materialized {mb} B, procedural {pb} B, {ratio:.1}x)"
    );
    assert!(
        proc_[0].device_peak < mat[0].device_peak,
        "procedural device peak must drop ({} vs {})",
        proc_[0].device_peak,
        mat[0].device_peak
    );
}

#[test]
fn procedural_regen_counters_are_recorded() {
    let cfg = SimConfig {
        connectivity: Connectivity::Procedural,
        obs: Some(ObsConfig {
            sample_interval: 5,
            label: "it-proc".into(),
            ..Default::default()
        }),
        ..Default::default()
    };
    let results = run_cluster(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal(true, false)),
        60.0,
    )
    .unwrap();
    let obs = results
        .iter()
        .find_map(|r| r.obs.as_ref())
        .expect("rank 0 carries the merged obs summary");
    let misses = obs.merged.counter(CounterId::RegenCacheMisses);
    let hits = obs.merged.counter(CounterId::RegenCacheHits);
    assert!(misses > 0, "a spiking procedural run must regenerate fanouts");
    assert!(
        hits > 0,
        "repeated spikes of the same neurons must hit the fanout cache"
    );
}
