//! Integration: the prepared delivery layout (DESIGN.md §14) is a pure
//! reorganization — the plan/queue path produces bit-identical ring
//! contents, plastic weights and spike trains versus the naive
//! creation-order delivery it replaced.
//!
//! - static random networks: slot-sorted queued delivery with batching
//!   lag shifts, driven over several full ring wraps at the headroom
//!   size `slots = max_delay + interval`, matches per-record `add`
//!   bitwise on every step's consumed row;
//! - plastic random networks: the creation-order plastic side lists
//!   enqueue the same arrival events as the per-connection branchy walk,
//!   so depression/potentiation leave bit-identical weights and deposit
//!   planes;
//! - end-to-end: the balanced network is bit-identical across 1/2/4
//!   ranks, both exchange protocols and static/STDP runs, at exchange
//!   interval 1 versus auto (the plan serves every delivery path).

use nestgpu::connection::Connections;
use nestgpu::engine::delivery::{DeliveryPlan, DeliveryQueue};
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::memory::Tracker;
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::node::{NodeSpace, RingBuffers};
use nestgpu::plasticity::{PlasticityEngine, StdpRule, WeightBound};
use nestgpu::util::rng::Rng;

fn bits(s: &[f32]) -> Vec<u32> {
    s.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------- static

const N: usize = 40;
const MAX_DELAY: u16 = 10;
const INTERVAL: u16 = 4;

/// Random static network: `N` neurons plus one device, identity
/// node→state LUT, delays in `[INTERVAL, MAX_DELAY]` so a batching lag
/// shift of up to `INTERVAL − 1` steps keeps every effective delay ≥ 1.
fn static_world(seed: u64) -> (Connections, NodeSpace, Vec<u32>, Tracker) {
    let mut tr = Tracker::new();
    let mut nodes = NodeSpace::new();
    nodes.create_neurons(0, N as u32);
    nodes.create_device(0);
    let mut lut: Vec<u32> = (0..N as u32).collect();
    lut.push(u32::MAX);
    let mut c = Connections::new();
    let mut rng = Rng::new(seed);
    for _ in 0..600 {
        c.push(
            rng.below(N as u32),
            rng.below(N as u32),
            rng.uniform_range(-4.0, 4.0) as f32,
            INTERVAL + rng.below((MAX_DELAY - INTERVAL + 1) as u32) as u16,
            rng.below(2) as u8,
            &mut tr,
        );
    }
    // device fanout rides along: its block must stay creation-ordered in
    // the plan without disturbing the neuron CSR
    for _ in 0..40 {
        c.push(
            N as u32,
            rng.below(N as u32),
            rng.uniform_range(0.5, 2.0) as f32,
            INTERVAL + rng.below((MAX_DELAY - INTERVAL + 1) as u32) as u16,
            0,
            &mut tr,
        );
    }
    c.sort_by_source(N + 1, &mut tr);
    (c, nodes, lut, tr)
}

#[test]
fn plan_delivery_matches_naive_reference_over_ring_wraps() {
    let (c, nodes, lut, mut tr) = static_world(0xC0FFEE);
    let plan = DeliveryPlan::build(&c, &nodes, &lut, N as u32, None);
    assert_eq!(plan.n_entries(), c.len());
    assert!(
        plan.n_runs() < plan.n_entries(),
        "delay sorting must coalesce entries into runs ({} runs / {} entries)",
        plan.n_runs(),
        plan.n_entries()
    );

    // headroom-size ring: slots = max_delay + interval, the remote-plane
    // configuration whose wrap arithmetic the shifts below exercise
    let mut rb_naive = RingBuffers::new(N, MAX_DELAY + INTERVAL - 1, &mut tr);
    let mut rb_plan = RingBuffers::new(N, MAX_DELAY + INTERVAL - 1, &mut tr);
    assert_eq!(rb_plan.n_slots(), (MAX_DELAY + INTERVAL) as usize);
    let mut q = DeliveryQueue::default();
    q.ensure_slots(rb_plan.n_slots());

    let mut rng = Rng::new(0xBEEF);
    let mut touched = false;
    // three full wraps of the ring
    for step in 0..3 * rb_plan.n_slots() as u32 {
        for _ in 0..3 {
            let node = rng.below(N as u32);
            let mult = 1 + rng.below(3) as u16;
            // emission-lag shift of a batched exchange round
            let shift = -(rng.below(INTERVAL as u32) as i32);
            let v = c.view(c.outgoing(node));
            for i in 0..v.target.len() {
                let d = (v.delay[i] as i32 + shift) as u16;
                rb_naive.add(lut[v.target[i] as usize], v.port[i], d, v.weight[i], mult);
            }
            for run in plan.runs_of(node) {
                let d = (run.delay as i32 + shift) as u16;
                q.push(rb_plan.slot_of(d), run.start, run.end, mult);
            }
        }
        q.drain_into(&mut rb_plan, &plan);
        let (ea, ia) = rb_naive.current();
        let (eb, ib) = rb_plan.current();
        assert_eq!(bits(ea), bits(eb), "ex plane diverged at step {step}");
        assert_eq!(bits(ia), bits(ib), "inh plane diverged at step {step}");
        touched |= ea.iter().chain(ia).any(|&x| x != 0.0);
        rb_naive.advance();
        rb_plan.advance();
    }
    assert!(touched, "the reference run never accumulated anything");
}

// --------------------------------------------------------------- plastic

const PN: usize = 12;
const P_MAX_DELAY: u16 = 5;

fn stdp_rule() -> StdpRule {
    StdpRule {
        tau_plus_ms: 20.0,
        tau_minus_ms: 20.0,
        a_plus: 0.5,
        a_minus: 0.4,
        w_min: 0.0,
        w_max: 6.0,
        bound: WeightBound::Additive,
    }
}

/// Random plastic network with static and plastic blocks *interleaved*
/// in creation order (two of each, ending plastic so the rule array
/// covers the store). Deterministic per seed: called twice to drive the
/// naive and the plan path over identical stores.
fn plastic_world(seed: u64) -> (Connections, NodeSpace, Vec<u32>, Tracker) {
    let mut tr = Tracker::new();
    let mut nodes = NodeSpace::new();
    nodes.create_neurons(0, PN as u32);
    let lut: Vec<u32> = (0..PN as u32).collect();
    let mut c = Connections::new();
    let rule_id = c.register_rule(stdp_rule());
    let mut rng = Rng::new(seed);
    for block in 0..4 {
        let start = c.len();
        for _ in 0..12 {
            let (w, port) = if block % 2 == 0 {
                (rng.uniform_range(-3.0, 3.0) as f32, rng.below(2) as u8)
            } else {
                // plastic weights start inside the rule's bounds
                (rng.uniform_range(1.0, 5.0) as f32, 0)
            };
            c.push(
                rng.below(PN as u32),
                rng.below(PN as u32),
                w,
                1 + rng.below(P_MAX_DELAY as u32) as u16,
                port,
                &mut tr,
            );
        }
        if block % 2 == 1 {
            c.attach_rule(start, rule_id, &mut tr);
        }
    }
    c.sort_by_source(PN, &mut tr);
    (c, nodes, lut, tr)
}

#[test]
fn plastic_plan_matches_naive_enqueue_order() {
    let seed = 0x5EED;
    let (mut ca, nodes, lut, mut tra) = plastic_world(seed);
    let (mut cb, _, _, mut trb) = plastic_world(seed);
    assert_eq!(bits(ca.weight.as_slice()), bits(cb.weight.as_slice()));

    let mut ea =
        PlasticityEngine::build(&ca, &nodes, &lut, PN, P_MAX_DELAY, 1, 0.1, &mut tra).unwrap();
    let mut eb =
        PlasticityEngine::build(&cb, &nodes, &lut, PN, P_MAX_DELAY, 1, 0.1, &mut trb).unwrap();
    assert!(ea.n_plastic() > 0);
    let plan = DeliveryPlan::build(&cb, &nodes, &lut, PN as u32, Some(&eb));
    assert_eq!(plan.n_entries() + ea.n_plastic(), cb.len());

    let mut rb_a = RingBuffers::new(PN, P_MAX_DELAY, &mut tra);
    let mut rb_b = RingBuffers::new(PN, P_MAX_DELAY, &mut trb);
    let mut q = DeliveryQueue::default();
    q.ensure_slots(rb_b.n_slots());

    let w0 = bits(ca.weight.as_slice());
    for step in 0..40u32 {
        ea.pre_update(step as i64, &mut ca, &lut);
        eb.pre_update(step as i64, &mut cb, &lut);
        let (pa_e, pa_i) = ea.plane();
        let (pb_e, pb_i) = eb.plane();
        assert_eq!(bits(pa_e), bits(pb_e), "plastic ex plane diverged at step {step}");
        assert_eq!(bits(pa_i), bits(pb_i), "plastic inh plane diverged at step {step}");
        let (ra_e, ra_i) = rb_a.current();
        let (rb_e, rb_i) = rb_b.current();
        assert_eq!(bits(ra_e), bits(rb_e), "static ex plane diverged at step {step}");
        assert_eq!(bits(ra_i), bits(rb_i), "static inh plane diverged at step {step}");

        // deterministic spiking pattern, ascending node order
        let spiking: Vec<u32> = (0..PN as u32).filter(|n| (step + n) % 4 == 0).collect();
        for &node in &spiking {
            // naive: branch per connection, creation order
            let out = ca.outgoing(node);
            let base = out.start;
            let v = ca.view(out);
            for i in 0..v.target.len() {
                match ea.plastic_slot(base + i) {
                    Some(slot) => ea.enqueue(v.delay[i] as usize, slot, step, 1, false),
                    None => {
                        rb_a.add(lut[v.target[i] as usize], v.port[i], v.delay[i], v.weight[i], 1)
                    }
                }
            }
            // plan: creation-order side list, then slot-sorted runs
            for link in plan.plastic_of(node) {
                eb.enqueue(link.delay as usize, link.slot, step, 1, false);
            }
            for run in plan.runs_of(node) {
                q.push(rb_b.slot_of(run.delay), run.start, run.end, 1);
            }
        }
        q.drain_into(&mut rb_b, &plan);

        ea.post_update(step as i64, &spiking, &mut ca, &lut);
        eb.post_update(step as i64, &spiking, &mut cb, &lut);
        assert_eq!(
            bits(ca.weight.as_slice()),
            bits(cb.weight.as_slice()),
            "weights diverged at step {step}"
        );
        ea.end_step();
        eb.end_step();
        rb_a.advance();
        rb_b.advance();
    }
    assert_ne!(bits(ca.weight.as_slice()), w0, "STDP never moved a weight");
}

// ------------------------------------------------------------ end-to-end

fn run_bal(
    interval: Option<u16>,
    ranks: usize,
    collective: bool,
    stdp: bool,
    t_ms: f64,
) -> Vec<SimResult> {
    let bal = BalancedConfig {
        scale: 0.01,
        k_scale: 0.01,
        collective,
        stdp: stdp.then(|| StdpScenario {
            lambda: 0.05,
            ..Default::default()
        }),
        ..Default::default()
    };
    run_cluster(
        ranks,
        &SimConfig {
            exchange_interval: interval,
            ..Default::default()
        },
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

#[test]
fn balanced_bit_identity_across_ranks_protocols_and_plasticity() {
    for ranks in [1usize, 2, 4] {
        for collective in [false, true] {
            for stdp in [false, true] {
                let a = run_bal(Some(1), ranks, collective, stdp, 30.0);
                let b = run_bal(None, ranks, collective, stdp, 30.0);
                let ctx = format!("ranks {ranks} collective {collective} stdp {stdp}");
                assert!(
                    a.iter().map(|r| r.n_spikes).sum::<u64>() > 0,
                    "{ctx}: network must spike"
                );
                let sp = |rs: &[SimResult]| -> Vec<&[(u32, u32)]> {
                    rs.iter().map(|r| r.spikes.as_slice()).collect()
                };
                assert_eq!(sp(&a), sp(&b), "{ctx}: spike trains diverged");
                if stdp {
                    let h = |rs: &[SimResult]| -> Vec<u64> {
                        rs.iter().map(|r| r.plastic.expect("plastic run").hash).collect()
                    };
                    assert_eq!(h(&a), h(&b), "{ctx}: plastic weights diverged");
                }
            }
        }
    }
}
