//! Integration: min-delay exchange batching is bit-identical to per-step
//! exchange (DESIGN.md §11) for both the balanced network and the MAM
//! model, over both communication protocols, and actually reduces the
//! message count.

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::models::mam::{MamConfig, MamModel};

fn cfg_with_interval(interval: Option<u16>) -> SimConfig {
    SimConfig {
        exchange_interval: interval,
        ..Default::default()
    }
}

fn spikes(results: &[SimResult]) -> Vec<&[(u32, u32)]> {
    results.iter().map(|r| r.spikes.as_slice()).collect()
}

fn run_balanced(interval: Option<u16>, collective: bool, ranks: usize, t_ms: f64) -> Vec<SimResult> {
    let bal = BalancedConfig {
        scale: 0.01,
        k_scale: 0.01,
        collective,
        ..Default::default()
    };
    run_cluster(
        ranks,
        &cfg_with_interval(interval),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

#[test]
fn balanced_p2p_batching_is_bit_identical() {
    let per_step = run_balanced(Some(1), false, 3, 40.0);
    let mid = run_balanced(Some(7), false, 3, 40.0);
    let auto = run_balanced(None, false, 3, 40.0);

    // the balanced model's only delay is 15 steps -> auto interval 15
    assert_eq!(per_step[0].exchange_interval, 1);
    assert_eq!(mid[0].exchange_interval, 7);
    assert_eq!(auto[0].exchange_interval, 15);

    assert!(per_step.iter().map(|r| r.n_spikes).sum::<u64>() > 50, "network must spike");
    assert_eq!(spikes(&per_step), spikes(&mid));
    assert_eq!(spikes(&per_step), spikes(&auto));
}

#[test]
fn balanced_p2p_batching_cuts_message_count() {
    // denser workload than the determinism tests: empty packets are not
    // counted as messages, so the reduction factor needs steps that
    // actually carry spikes (the paper-scale regime)
    let bal = BalancedConfig {
        scale: 0.1,
        k_scale: 0.01,
        collective: false,
        ..Default::default()
    };
    let run = |interval: Option<u16>| {
        let bal = bal.clone();
        run_cluster(
            3,
            &cfg_with_interval(interval),
            &move |sim: &mut Simulator| build_balanced(sim, &bal),
            40.0,
        )
        .unwrap()
    };
    let per_step = run(Some(1));
    let auto = run(None);
    let m1: u64 = per_step.iter().map(|r| r.p2p_messages).sum();
    let mb: u64 = auto.iter().map(|r| r.p2p_messages).sum();
    assert!(m1 > 0 && mb > 0);
    // 400 steps at interval 15 -> 27 exchange rounds; with dense spiking
    // the reduction approaches 15x, require at least 3x to stay robust
    assert!(
        mb * 3 <= m1,
        "batched exchange must cut p2p messages (got {m1} -> {mb})"
    );
    // payload volume stays in the same ballpark: same records, fewer
    // envelopes (record is 8 bytes, envelope 8 bytes)
    let b1: u64 = per_step.iter().map(|r| r.p2p_bytes).sum();
    let bb: u64 = auto.iter().map(|r| r.p2p_bytes).sum();
    assert!(bb <= b1, "batching must not inflate p2p bytes ({b1} -> {bb})");
}

#[test]
fn balanced_collective_batching_is_bit_identical() {
    let per_step = run_balanced(Some(1), true, 2, 40.0);
    let auto = run_balanced(None, true, 2, 40.0);
    assert_eq!(auto[0].exchange_interval, 15);
    assert!(per_step.iter().map(|r| r.n_spikes).sum::<u64>() > 50, "network must spike");
    assert_eq!(spikes(&per_step), spikes(&auto));
    let c1: u64 = per_step.iter().map(|r| r.coll_calls).sum();
    let cb: u64 = auto.iter().map(|r| r.coll_calls).sum();
    assert!(
        cb * 4 <= c1,
        "batching must cut allgather rounds (got {c1} -> {cb})"
    );
}

#[test]
fn explicit_interval_clamps_to_min_delay() {
    // asking for more batching than the min remote delay allows must clamp
    let clamped = run_balanced(Some(100), false, 2, 30.0);
    assert_eq!(clamped[0].exchange_interval, 15);
    let per_step = run_balanced(Some(1), false, 2, 30.0);
    assert_eq!(spikes(&per_step), spikes(&clamped));
}

#[test]
fn mam_batching_is_bit_identical() {
    let mc = MamConfig {
        n_scale: 0.001,
        k_scale: 0.02,
        chi: 1.9,
        kcc_base: 1500.0,
    };
    let run = |interval: Option<u16>| -> Vec<SimResult> {
        let mc = mc.clone();
        run_cluster(
            2,
            &cfg_with_interval(interval),
            &move |sim: &mut Simulator| {
                let m = MamModel::new(mc.clone());
                let p = m.pack(sim.n_ranks());
                m.build(sim, &p);
            },
            30.0,
        )
        .unwrap()
    };
    let per_step = run(Some(1));
    let auto = run(None);
    assert!(
        auto[0].exchange_interval >= 1,
        "auto interval must resolve ({})",
        auto[0].exchange_interval
    );
    assert!(per_step.iter().map(|r| r.n_spikes).sum::<u64>() > 0, "MAM must spike");
    assert_eq!(spikes(&per_step), spikes(&auto));
}

#[test]
fn step_phase_times_are_populated() {
    let r = run_balanced(None, false, 2, 20.0);
    let st = &r[0].step_phases;
    // dynamics runs every step; exchange at least once per interval
    assert!(st.dynamics > std::time::Duration::ZERO);
    assert!(st.total() > std::time::Duration::ZERO);
}
