//! Integration: the construction-cache service (DESIGN.md §17) end to
//! end over real TCP — cold-vs-warm bit-identity, single-flight
//! deduplication of identical concurrent submits, LRU eviction under a
//! tight byte budget, loud rejection of malformed and oversized frames,
//! and daemon survival across a client hangup mid-job.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use nestgpu::comm::wire::{read_frame, MsgType, WIRE_VERSION};
use nestgpu::serve::proto;
use nestgpu::serve::{JobOutcome, JobSpec, ServeClient, ServeConfig, Server, ServerHandle};
use nestgpu::util::json::Json;

fn tmp_dir(name: &str) -> PathBuf {
    let base = std::env::temp_dir();
    let dir = base.join(format!("nestgpu_it_serve_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A tiny 2-rank world (45 neurons per rank): construction is still a
/// measurable phase, so warm-vs-cold behavior stays observable while
/// every test runs in well under a second of simulated activity.
fn small_spec() -> JobSpec {
    JobSpec {
        t_ms: 60.0,
        scale: 0.004,
        k_scale: 0.004,
        ..Default::default()
    }
}

fn start_server(name: &str, cache_bytes: u64, max_jobs: usize) -> (ServerHandle, PathBuf) {
    let dir = tmp_dir(name);
    let server = Server::bind(ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        cache_dir: dir.clone(),
        cache_bytes,
        max_jobs,
        obs_dir: None,
    })
    .unwrap();
    (server.spawn(), dir)
}

fn stat(stats: &Json, key: &str) -> f64 {
    stats.get(key).and_then(Json::as_f64).unwrap_or(-1.0)
}

fn stop(handle: ServerHandle) {
    let mut c = ServeClient::connect(handle.addr()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

#[test]
fn cold_then_warm_submits_are_bit_identical() {
    let (handle, dir) = start_server("warm", 256 << 20, 2);
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    let spec = small_spec();

    let cold = client.submit(&spec).unwrap();
    assert!(!cold.hit, "first submit must construct");
    assert!(cold.construction_s > 0.0, "cold job must report construction time");
    let spikes = cold.result.get("n_spikes").and_then(Json::as_f64).unwrap_or(0.0);
    assert!(spikes > 0.0, "the world must spike for bit-identity to mean anything");

    let warm = client.submit(&spec).unwrap();
    assert!(warm.hit, "second identical submit must be served from the cache");
    assert_eq!(warm.construction_s, 0.0, "warm path must skip construction");
    assert_eq!(warm.world_hash, cold.world_hash, "warm run must be bit-identical");

    let stats = client.stats().unwrap();
    assert_eq!(stat(&stats, "hits"), 1.0);
    assert_eq!(stat(&stats, "misses"), 1.0);
    assert_eq!(stat(&stats, "constructions"), 1.0);
    assert_eq!(stat(&stats, "jobs_done"), 2.0);
    assert_eq!(stat(&stats, "entries"), 1.0);

    // t_ms is not part of the key: a longer run still resumes warm
    let longer = JobSpec {
        t_ms: spec.t_ms * 2.0,
        ..spec.clone()
    };
    assert!(client.submit(&longer).unwrap().hit, "t_ms must not be in the cache key");

    stop(handle);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_identical_submits_collapse_to_one_construction() {
    let (handle, dir) = start_server("flight", 256 << 20, 4);
    let addr = handle.addr().to_string();
    let spec = small_spec();
    let outcomes: Vec<JobOutcome> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..4)
            .map(|_| {
                let addr = addr.clone();
                let spec = spec.clone();
                s.spawn(move || ServeClient::connect(&addr).unwrap().submit(&spec).unwrap())
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    let hash = outcomes[0].world_hash;
    assert!(outcomes.iter().all(|o| o.world_hash == hash), "hashes diverged");
    let built = outcomes.iter().filter(|o| !o.hit).count();
    assert_eq!(built, 1, "exactly one submit pays the construction");

    let mut client = ServeClient::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    let dump = stats.to_string();
    assert_eq!(stat(&stats, "constructions"), 1.0, "single-flight must dedup: {dump}");
    assert_eq!(stat(&stats, "misses"), 1.0, "{dump}");
    assert_eq!(stat(&stats, "hits"), 3.0, "{dump}");
    assert_eq!(stat(&stats, "jobs_done"), 4.0, "{dump}");
    stop(handle);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lru_eviction_under_a_tight_byte_budget() {
    // probe: measure one cached entry's on-disk size with a roomy budget
    let (probe, probe_dir) = start_server("probe", 256 << 20, 2);
    let mut client = ServeClient::connect(probe.addr()).unwrap();
    let spec_a = small_spec();
    client.submit(&spec_a).unwrap();
    let entry_bytes = stat(&client.stats().unwrap(), "used_bytes");
    assert!(entry_bytes > 0.0, "cached snapshot must have nonzero size");
    stop(probe);
    let _ = std::fs::remove_dir_all(&probe_dir);

    // a budget with room for one such entry but not two
    let budget = (entry_bytes * 1.5) as u64;
    let (handle, dir) = start_server("evict", budget, 2);
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    let spec_b = JobSpec {
        seed: spec_a.seed + 1,
        ..spec_a.clone()
    };
    assert!(!client.submit(&spec_a).unwrap().hit);
    assert!(!client.submit(&spec_b).unwrap().hit);
    let stats = client.stats().unwrap();
    let dump = stats.to_string();
    assert!(stat(&stats, "evictions") >= 1.0, "admitting b must evict a: {dump}");
    assert_eq!(stat(&stats, "entries"), 1.0, "{dump}");
    // the survivor is warm; the evicted spec is cold again
    assert!(client.submit(&spec_b).unwrap().hit, "b must have survived");
    assert!(!client.submit(&spec_a).unwrap().hit, "a must have been evicted");
    stop(handle);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_and_oversized_frames_are_rejected_loudly() {
    let (handle, dir) = start_server("frames", 64 << 20, 1);
    let mut buf = [0u8; 16];

    // 24 bytes of garbage: a full-size header with a bad magic
    let mut sock = TcpStream::connect(handle.addr()).unwrap();
    sock.write_all(b"XXXXGARBAGE-NOT-A-FRAME!").unwrap();
    sock.flush().unwrap();
    let n = sock.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close a malformed connection without replying");
    drop(sock);

    // a valid header claiming a payload far beyond MAX_PAYLOAD_BYTES
    let mut sock = TcpStream::connect(handle.addr()).unwrap();
    let mut hdr = Vec::new();
    hdr.extend_from_slice(b"NGS1");
    hdr.push(WIRE_VERSION);
    hdr.push(MsgType::SubmitJob as u8);
    hdr.extend_from_slice(&0u16.to_le_bytes()); // reserved
    hdr.extend_from_slice(&0u32.to_le_bytes()); // channel
    hdr.extend_from_slice(&u32::MAX.to_le_bytes()); // payload_len: ~4 GiB
    hdr.extend_from_slice(&0u64.to_le_bytes()); // seq
    sock.write_all(&hdr).unwrap();
    sock.flush().unwrap();
    let n = sock.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must reject an oversized frame before allocating");
    drop(sock);

    // the daemon survived both: a normal client still gets served
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    assert!(!client.submit(&small_spec()).unwrap().hit);
    let stats = client.stats().unwrap();
    let dump = stats.to_string();
    assert!(stat(&stats, "proto_errors") >= 2.0, "{dump}");
    stop(handle);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn job_survives_client_disconnect_mid_run() {
    let (handle, dir) = start_server("hangup", 64 << 20, 1);
    let spec = small_spec();
    {
        // hand-rolled submit: send the job, wait for "running", hang up
        let mut sock = TcpStream::connect(handle.addr()).unwrap();
        let mut out = Vec::new();
        let body = spec.to_json();
        proto::send_json(&mut sock, &mut out, MsgType::SubmitJob, 0, 0, &body).unwrap();
        let mut payload = Vec::new();
        let hdr = read_frame(&mut sock, &mut payload).unwrap();
        assert_eq!(hdr.msg_type, MsgType::JobStatus);
    } // <- connection dropped while the job is still running

    // the daemon must finish and cache the orphaned job regardless
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    for _ in 0..600 {
        if stat(&client.stats().unwrap(), "jobs_done") >= 1.0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let outcome = client.submit(&spec).unwrap();
    assert!(outcome.hit, "the orphaned job's construction must still be cached");
    let stats = client.stats().unwrap();
    let dump = stats.to_string();
    assert_eq!(stat(&stats, "constructions"), 1.0, "{dump}");
    stop(handle);
    let _ = std::fs::remove_dir_all(&dir);
}
