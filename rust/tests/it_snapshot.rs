//! Integration: the snapshot subsystem end to end — randomized
//! encode→decode identity for every state-owning structure, construction
//! caching (restored runs skip construction and reproduce spike trains
//! bit-identically) and mid-run checkpoint determinism.

use std::path::PathBuf;
use std::time::Duration;

use nestgpu::connection::Connections;
use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::{run_cluster, run_cluster_from_snapshot, run_cluster_with_snapshot};
use nestgpu::memory::{MemKind, Tracker};
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::node::RingBuffers;
use nestgpu::remote::pair_map::PairMap;
use nestgpu::remote::tables::RoutingTables;
use nestgpu::snapshot::{Decoder, Encoder};
use nestgpu::util::rng::Rng;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nestgpu_it_snapshot_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_bal() -> BalancedConfig {
    BalancedConfig {
        scale: 0.004,  // 45 neurons per rank
        k_scale: 0.004,
        ..Default::default()
    }
}

// ---------------------------------------------------------------- codec
// property tests: encode→decode = identity over randomized instances

#[test]
fn prop_connection_store_roundtrip() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..30 {
        let n_nodes = 1 + rng.below(60) as usize;
        let n_conns = rng.below(500) as usize;
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        for _ in 0..n_conns {
            c.push(
                rng.below(n_nodes as u32),
                rng.below(n_nodes as u32),
                rng.uniform_range(-5.0, 5.0) as f32,
                1 + rng.below(30) as u16,
                rng.below(2) as u8,
                &mut tr,
            );
        }
        if rng.below(2) == 1 {
            c.sort_by_source(n_nodes, &mut tr);
        }
        let mut enc = Encoder::new();
        c.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = Decoder::new(&bytes);
        let d = Connections::snapshot_decode(&mut dec, &mut tr2, true).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.source.as_slice(), c.source.as_slice(), "case {case}");
        assert_eq!(d.target.as_slice(), c.target.as_slice(), "case {case}");
        assert_eq!(d.weight.as_slice(), c.weight.as_slice(), "case {case}");
        assert_eq!(d.delay.as_slice(), c.delay.as_slice(), "case {case}");
        assert_eq!(d.port.as_slice(), c.port.as_slice(), "case {case}");
        assert_eq!(d.is_sorted(), c.is_sorted(), "case {case}");
        if c.is_sorted() {
            for node in 0..n_nodes as u32 {
                assert_eq!(d.outgoing(node), c.outgoing(node), "case {case} node {node}");
            }
        }
    }
}

#[test]
fn prop_pair_map_and_routing_tables_roundtrip() {
    let mut rng = Rng::new(0xDECAF);
    for case in 0..30 {
        let mut tr = Tracker::new();

        // (R, L) map grown over several merge rounds
        let mut m = PairMap::new(MemKind::Device);
        let mut next_img = 1_000u32;
        for _ in 0..1 + rng.below(4) {
            let mut srcs: Vec<u32> = (0..rng.below(50)).map(|_| rng.below(800)).collect();
            srcs.sort_unstable();
            srcs.dedup();
            m.ensure_images(&srcs, &mut tr, || {
                let v = next_img;
                next_img += 1;
                v
            });
        }
        let mut enc = Encoder::new();
        m.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = Decoder::new(&bytes);
        let dm = PairMap::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(dm.r_slice(), m.r_slice(), "case {case}");
        assert_eq!(dm.l_slice(), m.l_slice(), "case {case}");
        assert!(dm.is_sorted());

        // routing tables over random sorted per-destination sequences
        let n_nodes = 80usize;
        let owned: Vec<(u16, Vec<u32>)> = (0..rng.below(4))
            .map(|d| {
                let mut v: Vec<u32> =
                    (0..rng.below(40)).map(|_| rng.below(n_nodes as u32)).collect();
                v.sort_unstable();
                v.dedup();
                (d as u16, v)
            })
            .collect();
        let refs: Vec<(u16, &[u32])> = owned.iter().map(|(d, v)| (*d, v.as_slice())).collect();
        let t = RoutingTables::build(n_nodes, &refs, MemKind::Device, &mut tr);
        let mut enc = Encoder::new();
        t.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = Decoder::new(&bytes);
        let dt = RoutingTables::snapshot_decode(&mut dec, MemKind::Device, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(dt.total_entries(), t.total_entries(), "case {case}");
        for node in 0..n_nodes as u32 {
            assert_eq!(
                dt.route(node).collect::<Vec<_>>(),
                t.route(node).collect::<Vec<_>>(),
                "case {case} node {node}"
            );
        }
    }
}

#[test]
fn prop_ring_buffer_roundtrip() {
    let mut rng = Rng::new(0xB0BA);
    for case in 0..30 {
        let n = 1 + rng.below(40) as usize;
        let max_delay = (1 + rng.below(20)) as u16;
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(n, max_delay, &mut tr);
        // random interleaving of deliveries and step advances
        for _ in 0..rng.below(200) {
            if rng.below(4) == 0 {
                rb.advance();
            } else {
                rb.add(
                    rng.below(n as u32),
                    rng.below(2) as u8,
                    1 + rng.below(max_delay as u32) as u16,
                    rng.uniform_range(-10.0, 10.0) as f32,
                    1 + rng.below(3) as u16,
                );
            }
        }
        let mut enc = Encoder::new();
        rb.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = Decoder::new(&bytes);
        let mut restored = RingBuffers::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        // identical playout over a full wrap-around
        for step in 0..2 * rb.n_slots() {
            assert_eq!(restored.current(), rb.current(), "case {case} step {step}");
            restored.advance();
            rb.advance();
        }
    }
}

// ------------------------------------------------ cluster-level behavior

#[test]
fn construction_cache_restores_bit_identical_runs() {
    let cfg = SimConfig::default();
    let dir = tmp_dir("cache");

    // from-scratch baseline with the same seed
    let baseline = run_cluster(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal()),
        100.0,
    )
    .unwrap();

    // build + prepare, save immediately (construction cache), restore, run
    run_cluster_with_snapshot(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal()),
        0.0,
        &dir,
    )
    .unwrap();
    let restored = run_cluster_from_snapshot(&dir, 100.0).unwrap();

    assert_eq!(baseline.len(), restored.len());
    for (b, r) in baseline.iter().zip(restored.iter()) {
        assert!(b.n_spikes > 0, "baseline must spike to make the test meaningful");
        assert_eq!(b.spikes, r.spikes, "rank {}: spike trains diverged", b.rank);
        assert_eq!(b.n_connections, r.n_connections);
        assert_eq!(b.n_neurons, r.n_neurons);
        assert_eq!(b.n_images, r.n_images);
        assert_eq!(b.map_entries, r.map_entries);
    }
    // the restored run must not have paid any construction phase
    for r in &restored {
        assert_eq!(r.phases.node_creation, Duration::ZERO);
        assert_eq!(r.phases.local_connection, Duration::ZERO);
        assert_eq!(r.phases.remote_connection, Duration::ZERO);
        assert_eq!(r.phases.preparation, Duration::ZERO);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn midrun_checkpoint_resumes_bit_identically() {
    let cfg = SimConfig::default();
    let dir = tmp_dir("midrun");

    // uninterrupted 100 ms
    let full = run_cluster(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal()),
        100.0,
    )
    .unwrap();

    // 50 ms, checkpoint, resume for the remaining 50 ms
    let first_half = run_cluster_with_snapshot(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal()),
        50.0,
        &dir,
    )
    .unwrap();
    let resumed = run_cluster_from_snapshot(&dir, 50.0).unwrap();

    for ((f, h), r) in full.iter().zip(first_half.iter()).zip(resumed.iter()) {
        // the recorder travels inside the snapshot, so the resumed result
        // carries the full pre+post checkpoint history
        assert_eq!(f.spikes, r.spikes, "rank {}: resumed train diverged", f.rank);
        assert!(
            r.spikes.len() >= h.spikes.len(),
            "resume lost pre-checkpoint events"
        );
        assert!(f.n_spikes > 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn p2p_exchange_survives_checkpoint() {
    // same determinism check with point-to-point instead of collective
    // exchange (exercises the TP tables + (R, L) maps through the codec)
    let cfg = SimConfig::default();
    let dir = tmp_dir("p2p");
    let bal = BalancedConfig {
        collective: false,
        ..small_bal()
    };
    let mk = {
        let bal = bal.clone();
        move |sim: &mut Simulator| build_balanced(sim, &bal)
    };
    let full = run_cluster(2, &cfg, &mk, 80.0).unwrap();
    run_cluster_with_snapshot(2, &cfg, &mk, 40.0, &dir).unwrap();
    let resumed = run_cluster_from_snapshot(&dir, 40.0).unwrap();
    for (f, r) in full.iter().zip(resumed.iter()) {
        assert_eq!(f.spikes, r.spikes, "rank {}", f.rank);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_snapshot_is_rejected() {
    let cfg = SimConfig::default();
    let dir = tmp_dir("corrupt");
    run_cluster_with_snapshot(
        1,
        &cfg,
        &|sim: &mut Simulator| {
            use nestgpu::connection::{ConnRule, SynSpec};
            use nestgpu::node::LifParams;
            let n = sim.create_neurons(5, &LifParams::default());
            sim.connect(&n, &n, &ConnRule::OneToOne, &SynSpec::new(1.0, 1));
        },
        0.0,
        &dir,
    )
    .unwrap();
    let path = dir.join(nestgpu::snapshot::rank_file_name(0));
    let mut bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    bytes[n - 3] ^= 0x40; // flip one payload bit
    std::fs::write(&path, &bytes).unwrap();
    // the flipped bit lands in a section payload, so the container-level
    // checksum rejects the file before any state is deserialized
    let err = run_cluster_from_snapshot(&dir, 10.0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_rank_files_fail_with_a_count() {
    let cfg = SimConfig::default();
    let dir = tmp_dir("partial");
    run_cluster_with_snapshot(
        2,
        &cfg,
        &|sim: &mut Simulator| build_balanced(sim, &small_bal()),
        0.0,
        &dir,
    )
    .unwrap();
    // simulate an interrupted save: rank 1's file is gone
    std::fs::remove_file(dir.join(nestgpu::snapshot::rank_file_name(1))).unwrap();
    let err = run_cluster_from_snapshot(&dir, 10.0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("found 1 of 2 rank snapshots"), "{msg}");
    assert!(msg.contains("missing rank(s) 1"), "{msg}");
    // an empty directory names the expected file pattern instead
    let empty = tmp_dir("empty");
    std::fs::create_dir_all(&empty).unwrap();
    let err = run_cluster_from_snapshot(&empty, 10.0).unwrap_err();
    assert!(format!("{err:#}").contains("no rank snapshots"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&empty);
}
