//! Integration: the socket transport (DESIGN.md §15) is bit-identical to
//! the in-process thread transport — same spike trains, same plastic
//! weights — across rank counts, exchange protocols and exchange
//! intervals, and its failure detectors (connect retry, receive timeout)
//! behave as specified.

use std::time::Duration;

use nestgpu::comm::{Communicator, SocketComm, SocketConfig, SpikeRecord};
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{free_loopback_addr, run_cluster, run_cluster_socket};
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::stats::{combine_rank_hashes, spike_hash};

fn cfg_with_interval(interval: Option<u16>) -> SimConfig {
    SimConfig {
        exchange_interval: interval,
        ..Default::default()
    }
}

fn balanced(collective: bool, stdp: bool) -> BalancedConfig {
    BalancedConfig {
        scale: 0.01,
        k_scale: 0.01,
        collective,
        stdp: stdp.then(StdpScenario::default),
        ..Default::default()
    }
}

fn run_thread(
    bal: &BalancedConfig,
    interval: Option<u16>,
    ranks: usize,
    t_ms: f64,
) -> Vec<SimResult> {
    let bal = bal.clone();
    run_cluster(
        ranks,
        &cfg_with_interval(interval),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

fn run_socket(
    bal: &BalancedConfig,
    interval: Option<u16>,
    ranks: usize,
    t_ms: f64,
) -> Vec<SimResult> {
    let bal = bal.clone();
    run_cluster_socket(
        ranks,
        &cfg_with_interval(interval),
        &SocketConfig::new(free_loopback_addr().unwrap(), ranks),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

fn world_hash(results: &[SimResult]) -> u64 {
    let hashes: Vec<u64> = results.iter().map(|r| spike_hash(&r.spikes)).collect();
    combine_rank_hashes(&hashes)
}

/// Per-rank spike trains AND the folded world hash must match exactly.
fn assert_bit_identical(thread: &[SimResult], socket: &[SimResult], label: &str) {
    assert_eq!(thread.len(), socket.len(), "{label}: world size");
    assert!(
        thread.iter().map(|r| r.n_spikes).sum::<u64>() > 50,
        "{label}: network must spike for the comparison to mean anything"
    );
    for (t, s) in thread.iter().zip(socket.iter()) {
        assert_eq!(t.spikes, s.spikes, "{label}: rank {} spike train", t.rank);
    }
    assert_eq!(world_hash(thread), world_hash(socket), "{label}: world hash");
}

#[test]
fn socket_matches_thread_p2p_two_ranks() {
    let bal = balanced(false, false);
    for interval in [Some(1), None] {
        let thread = run_thread(&bal, interval, 2, 30.0);
        let socket = run_socket(&bal, interval, 2, 30.0);
        assert_bit_identical(&thread, &socket, &format!("p2p interval {interval:?}"));
    }
}

#[test]
fn socket_matches_thread_collective_two_ranks() {
    let bal = balanced(true, false);
    for interval in [Some(1), None] {
        let thread = run_thread(&bal, interval, 2, 30.0);
        let socket = run_socket(&bal, interval, 2, 30.0);
        assert_bit_identical(
            &thread,
            &socket,
            &format!("collective interval {interval:?}"),
        );
        // the collective protocol must actually exercise the allgather path
        assert!(socket[0].coll_calls > 0, "collective run must allgather");
    }
}

#[test]
fn socket_matches_thread_four_ranks_both_protocols() {
    for collective in [false, true] {
        let bal = balanced(collective, false);
        let thread = run_thread(&bal, None, 4, 30.0);
        let socket = run_socket(&bal, None, 4, 30.0);
        assert_bit_identical(&thread, &socket, &format!("4 ranks collective={collective}"));
    }
}

#[test]
fn socket_matches_thread_with_stdp() {
    let bal = balanced(false, true);
    let thread = run_thread(&bal, None, 2, 40.0);
    let socket = run_socket(&bal, None, 2, 40.0);
    assert_bit_identical(&thread, &socket, "stdp");
    for (t, s) in thread.iter().zip(socket.iter()) {
        let (tp, sp) = (t.plastic.as_ref().unwrap(), s.plastic.as_ref().unwrap());
        assert!(tp.n > 0, "rank {} must own plastic synapses", t.rank);
        assert_eq!(tp.hash, sp.hash, "rank {} plastic weight hash", t.rank);
    }
}

/// Socket traffic accounts whole frames (24-byte headers, empty-round
/// framing included), so its byte counters must strictly exceed the
/// thread transport's payload-only accounting on the same run.
#[test]
fn socket_wire_accounting_exceeds_thread_accounting() {
    let bal = balanced(false, false);
    let thread = run_thread(&bal, None, 2, 30.0);
    let socket = run_socket(&bal, None, 2, 30.0);
    for (t, s) in thread.iter().zip(socket.iter()) {
        assert!(
            s.p2p_bytes > t.p2p_bytes,
            "rank {}: socket {} must exceed thread {}",
            t.rank,
            s.p2p_bytes,
            t.p2p_bytes
        );
        // non-empty packet counts are defined identically on both
        assert_eq!(s.p2p_messages, t.p2p_messages, "rank {}", t.rank);
    }
}

/// Start order is free: a rank may dial the rendezvous before rank 0 has
/// bound it — the bounded retry/backoff must absorb the gap.
#[test]
fn connect_retries_until_rendezvous_binds() {
    let rdv = free_loopback_addr().unwrap();
    let results: Vec<anyhow::Result<(usize, Vec<SpikeRecord>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|rank| {
                let cfg = SocketConfig {
                    rank: Some(rank),
                    connect_timeout: Duration::from_secs(10),
                    ..SocketConfig::new(rdv.clone(), 2)
                };
                s.spawn(move || -> anyhow::Result<(usize, Vec<SpikeRecord>)> {
                    if rank == 0 {
                        // rendezvous host binds late; rank 1 is already dialing
                        std::thread::sleep(Duration::from_millis(300));
                    }
                    let mut comm = SocketComm::connect(&cfg)?;
                    let rec = SpikeRecord { pos: 7 + rank as u32, mult: 1, lag: 0 };
                    let mut out = vec![Vec::new(); 2];
                    out[1 - rank] = vec![rec];
                    let got = comm.exchange(out);
                    Ok((comm.rank(), got[1 - rank].clone()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    });
    for (rank, res) in results.into_iter().enumerate() {
        let (got_rank, received) = res.unwrap();
        assert_eq!(got_rank, rank);
        let peer = 1 - rank;
        assert_eq!(
            received,
            vec![SpikeRecord { pos: 7 + peer as u32, mult: 1, lag: 0 }],
            "rank {rank} must receive the peer's record through the late mesh"
        );
    }
}

/// A peer that goes silent mid-protocol must surface as a rank-tagged
/// receive-timeout error, never as a hang.
#[test]
fn recv_timeout_is_rank_tagged() {
    let rdv = free_loopback_addr().unwrap();
    let payload = std::thread::scope(|s| {
        let silent = {
            let cfg = SocketConfig {
                rank: Some(0),
                ..SocketConfig::new(rdv.clone(), 2)
            };
            s.spawn(move || {
                let comm = SocketComm::connect(&cfg).unwrap();
                // hold the mesh open without ever exchanging, then hang up
                std::thread::sleep(Duration::from_millis(1000));
                drop(comm);
            })
        };
        let victim = {
            let cfg = SocketConfig {
                rank: Some(1),
                recv_timeout: Duration::from_millis(100),
                ..SocketConfig::new(rdv.clone(), 2)
            };
            s.spawn(move || {
                let mut comm = SocketComm::connect(&cfg).unwrap();
                let _ = comm.exchange(vec![Vec::new(), Vec::new()]);
            })
        };
        let err = victim.join().expect_err("exchange against a silent peer must fail");
        silent.join().unwrap();
        err
    });
    let msg = if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        String::new()
    };
    assert!(msg.contains("socket comm rank 1"), "rank tag missing: {msg}");
    assert!(msg.contains("timed out"), "timeout cause missing: {msg}");
}
