//! Integration: the paper's central invariant (Eq. 1) at the Simulator
//! level — the source-side S sequences equal the target-side R maps after
//! arbitrary interleavings of RemoteConnect calls, with zero communication.
//!
//! Both rank views are instantiated in one thread with NullComm (valid
//! because construction is communication-free by design).

use nestgpu::comm::NullComm;
use nestgpu::connection::{ConnRule, NodeSet, SynSpec};
use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::node::LifParams;
use nestgpu::remote::GpuMemLevel;

fn pair(level: GpuMemLevel, seed: u64) -> (Simulator, Simulator) {
    let cfg = SimConfig {
        seed,
        level,
        ..Default::default()
    };
    let a = Simulator::new(Box::new(NullComm::new(0, 2)), cfg.clone());
    let b = Simulator::new(Box::new(NullComm::new(1, 2)), cfg);
    (a, b)
}

/// SPMD helper: issue the same call on both rank views.
fn spmd_remote(
    a: &mut Simulator,
    b: &mut Simulator,
    src: usize,
    s: &NodeSet,
    tgt: usize,
    t: &NodeSet,
    rule: &ConnRule,
) {
    let syn = SynSpec::new(1.0, 1);
    a.remote_connect(src, s, tgt, t, rule, &syn, None);
    b.remote_connect(src, s, tgt, t, rule, &syn, None);
}

#[test]
fn s_equals_r_for_interleaved_probabilistic_calls() {
    for level in [GpuMemLevel::L0, GpuMemLevel::L2] {
        let (mut r0, mut r1) = pair(level, 99);
        let p = LifParams::default();
        r0.create_neurons(100, &p);
        r1.create_neurons(100, &p);
        // interleave directions and rules across many calls
        for call in 0..6u32 {
            let s = NodeSet::range(0, 60);
            let t = NodeSet::range(call * 10, 10);
            spmd_remote(&mut r0, &mut r1, 0, &s, 1, &t, &ConnRule::FixedIndegree { k: 2 });
            spmd_remote(
                &mut r0,
                &mut r1,
                1,
                &NodeSet::range(10, 30),
                0,
                &t,
                &ConnRule::FixedTotalNumber { n: 25 },
            );
        }
        // Eq. 1: S on the source == R on the target, both directions
        assert_eq!(
            r0.remote.p2p_s[1].as_slice(),
            r1.remote.p2p_maps[0].r_slice(),
            "level {level:?}: S[1] on rank0 != R[1,0] on rank1"
        );
        assert_eq!(
            r1.remote.p2p_s[0].as_slice(),
            r0.remote.p2p_maps[1].r_slice(),
            "level {level:?}: S[0] on rank1 != R[0,1] on rank0"
        );
        // Eq. 3: sortedness
        assert!(r1.remote.p2p_maps[0].is_sorted());
        assert!(r0.remote.p2p_s[1].is_sorted());
    }
}

#[test]
fn alignment_survives_deterministic_and_assigned_rules() {
    let (mut r0, mut r1) = pair(GpuMemLevel::L0, 3);
    let p = LifParams::default();
    r0.create_neurons(50, &p);
    r1.create_neurons(50, &p);
    let s = NodeSet::List(vec![5, 9, 17, 30, 44]);
    spmd_remote(
        &mut r0,
        &mut r1,
        0,
        &s,
        1,
        &NodeSet::range(0, 5),
        &ConnRule::OneToOne,
    );
    spmd_remote(
        &mut r0,
        &mut r1,
        0,
        &NodeSet::range(20, 8),
        1,
        &NodeSet::range(5, 4),
        &ConnRule::AssignedNodes(vec![(0, 0), (3, 1), (3, 2), (7, 3)]),
    );
    spmd_remote(
        &mut r0,
        &mut r1,
        0,
        &NodeSet::range(0, 10),
        1,
        &NodeSet::range(9, 10),
        &ConnRule::FixedOutdegree { k: 3 },
    );
    assert_eq!(
        r0.remote.p2p_s[1].as_slice(),
        r1.remote.p2p_maps[0].r_slice()
    );
    // assigned-nodes with flagging: only used sources (positions 0, 3, 7)
    // of the second call got images
    assert!(r1.remote.p2p_maps[0].lookup(20).is_some());
    assert!(r1.remote.p2p_maps[0].lookup(23).is_some());
    assert!(r1.remote.p2p_maps[0].lookup(27).is_some());
    assert!(r1.remote.p2p_maps[0].lookup(21).is_none());
}

#[test]
fn tp_positions_match_target_map_positions() {
    // Eqs. 8-9: the position P sent over the wire must index the right
    // entry of the target's (R, L) map
    let (mut r0, mut r1) = pair(GpuMemLevel::L2, 17);
    let p = LifParams::default();
    r0.create_neurons(40, &p);
    r1.create_neurons(40, &p);
    for k in [1u32, 3] {
        spmd_remote(
            &mut r0,
            &mut r1,
            0,
            &NodeSet::range(0, 40),
            1,
            &NodeSet::range(0, 20),
            &ConnRule::FixedIndegree { k },
        );
    }
    r0.prepare().unwrap();
    r1.prepare().unwrap();
    let tp = r0.remote.tp.as_ref().unwrap();
    let map = &r1.remote.p2p_maps[0];
    for node in 0..40u32 {
        for (tau, pos) in tp.route(node) {
            assert_eq!(tau, 1);
            // the map entry at the routed position must be this neuron
            assert_eq!(
                map.r_slice()[pos as usize],
                node,
                "position {pos} routes to the wrong map entry"
            );
            // and resolves to an image node on the target
            let img = map.l_at(pos);
            assert!(r1.nodes.is_image(img));
        }
    }
}

#[test]
fn collective_h_mirrored_and_i_consistent() {
    let cfg = SimConfig::default();
    let mut sims: Vec<Simulator> = (0..3)
        .map(|r| Simulator::new(Box::new(NullComm::new(r, 3)), cfg.clone()))
        .collect();
    let p = LifParams::default();
    for sim in sims.iter_mut() {
        sim.create_neurons(30, &p);
        sim.register_group(vec![0, 1, 2]);
    }
    // SPMD: all ranks observe all calls
    let calls = [
        (0usize, NodeSet::range(0, 20), 1usize),
        (0, NodeSet::range(10, 15), 2),
        (2, NodeSet::List(vec![1, 4, 9]), 0),
    ];
    for (src, s, tgt) in &calls {
        for sim in sims.iter_mut() {
            sim.remote_connect(
                *src,
                s,
                *tgt,
                &NodeSet::range(0, 10),
                &ConnRule::FixedIndegree { k: 2 },
                &SynSpec::new(1.0, 1),
                Some(0),
            );
        }
    }
    for sim in sims.iter_mut() {
        sim.prepare().unwrap();
    }
    // Eq. 12-13: H mirrored identically on every member
    for member in 0..3 {
        let h0 = &sims[0].remote.groups[0].h[member];
        for sim in &sims[1..] {
            assert_eq!(h0, &sim.remote.groups[0].h[member]);
        }
        assert!(h0.windows(2).all(|w| w[0] < w[1]), "H must be sorted");
    }
    // H[0] = union of rank-0 source args = [0,20) ∪ [10,25) = [0,25)
    assert_eq!(
        sims[1].remote.groups[0].h[0],
        (0u32..25).collect::<Vec<_>>()
    );
    // Eq. 14: I aligned with H; −1 exactly for sources without an image
    for tgt in 0..3usize {
        for src_member in 0..3usize {
            if src_member == tgt {
                continue;
            }
            let gs = &sims[tgt].remote.groups[0];
            let h = &gs.h[src_member];
            let i = &gs.i_arr[src_member];
            assert_eq!(h.len(), i.len());
            let map = &gs.maps[src_member];
            for (pos, (&sid, &img)) in h.iter().zip(i.iter()).enumerate() {
                match map.lookup(sid) {
                    Some(l) => assert_eq!(img, l as i32, "pos {pos}"),
                    None => assert_eq!(img, -1, "pos {pos}"),
                }
            }
        }
    }
}
