//! Integration: model-level structural invariants (balanced network and
//! MAM) on live multi-rank builds.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::{run_cluster, run_construction_only};
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::models::mam::{MamConfig, MamModel, N_AREAS, TH};

fn bal(scale: f64) -> BalancedConfig {
    BalancedConfig {
        scale,
        k_scale: scale,
        ..Default::default()
    }
}

#[test]
fn balanced_connection_count_independent_of_rank_count() {
    // weak scaling: per-rank synapses must be constant across world sizes
    let cfg = SimConfig::default();
    let mut per_rank = Vec::new();
    for ranks in [1usize, 2, 4] {
        let r = run_construction_only(ranks, &cfg, &|sim: &mut Simulator| {
            build_balanced(sim, &bal(0.004))
        })
        .unwrap();
        per_rank.push(r[0].n_connections);
        // all ranks identical
        assert!(r.iter().all(|x| x.n_connections == r[0].n_connections));
    }
    assert_eq!(per_rank[0], per_rank[1]);
    assert_eq!(per_rank[1], per_rank[2]);
}

#[test]
fn balanced_sources_distributed_over_all_ranks() {
    // with enough draws every remote rank must contribute images
    let cfg = SimConfig::default();
    let r = run_construction_only(4, &cfg, &|sim: &mut Simulator| {
        build_balanced(sim, &bal(0.004))
    })
    .unwrap();
    for res in &r {
        // images exist from all 3 remote ranks: total entries == images
        assert!(res.n_images > 0);
        assert_eq!(res.map_entries, res.n_images);
    }
}

#[test]
fn mam_packing_covers_all_areas_and_layout_is_consistent() {
    let m = MamModel::new(MamConfig::default());
    for ranks in [2usize, 4, 8] {
        let packing = m.pack(ranks);
        let layout = m.layout(&packing);
        let mut seen = vec![false; N_AREAS];
        for a in 0..N_AREAS {
            assert!(layout.rank_of_area[a] < ranks);
            seen[a] = true;
            // populations laid out contiguously and ascending within a rank
            let sizes = m.area_sizes(a);
            for p in 0..7 {
                assert_eq!(
                    layout.pop_base[a][p] + sizes[p],
                    layout.pop_base[a][p + 1],
                    "area {a} pop {p} layout gap"
                );
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}

#[test]
fn mam_live_build_matches_layout_node_counts() {
    let cfg = SimConfig::default();
    let results = run_cluster(
        4,
        &cfg,
        &|sim: &mut Simulator| {
            let m = MamModel::new(MamConfig {
                n_scale: 0.001,
                k_scale: 0.02,
                chi: 1.9,
                kcc_base: 1500.0,
            });
            let p = m.pack(sim.n_ranks());
            m.build(sim, &p);
        },
        0.0,
    )
    .unwrap();
    let m = MamModel::new(MamConfig {
        n_scale: 0.001,
        k_scale: 0.02,
        chi: 1.9,
        kcc_base: 1500.0,
    });
    let packing = m.pack(4);
    for (rank, r) in results.iter().enumerate() {
        let expect: u64 = packing.areas_of(rank).iter().map(|&a| m.area_neurons(a)).sum();
        assert_eq!(r.n_neurons, expect, "rank {rank} neuron count");
    }
    // TH exists somewhere and contributes no L4
    let th_rank = packing.gpu_of_area[TH];
    assert!(results[th_rank].n_neurons > 0);
}

#[test]
fn mam_metastable_has_higher_cc_weight_than_ground() {
    let ground = MamModel::new(MamConfig {
        chi: 1.0,
        ..MamConfig::default()
    });
    let meta = MamModel::new(MamConfig {
        chi: 1.9,
        ..MamConfig::default()
    });
    // χ scales cc weights only; structure identical
    assert_eq!(ground.kcc(3, 5), meta.kcc(3, 5));
    assert_eq!(ground.area_sizes(0), meta.area_sizes(0));
}
