//! Hand-rolled property tests (proptest is not in the offline crate set):
//! seeded randomized sweeps over the coordinator's core invariants —
//! alignment, map sortedness, routing consistency, ring-buffer mass
//! conservation, EMD metric properties.

use nestgpu::comm::NullComm;
use nestgpu::connection::{ConnRule, NodeSet, SynSpec};
use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::memory::Tracker;
use nestgpu::node::{LifParams, RingBuffers};
use nestgpu::remote::levels::ALL_LEVELS;
use nestgpu::stats::emd;
use nestgpu::util::rng::Rng;

fn random_rule(rng: &mut Rng, ns: usize, nt: usize) -> ConnRule {
    match rng.below(6) {
        0 => ConnRule::AllToAll,
        1 => ConnRule::FixedIndegree {
            k: 1 + rng.below(6),
        },
        2 => ConnRule::FixedOutdegree {
            k: 1 + rng.below(6),
        },
        3 => ConnRule::FixedTotalNumber {
            n: 1 + rng.below(40) as u64,
        },
        4 => {
            let n = 1 + rng.below(30);
            ConnRule::AssignedNodes(
                (0..n)
                    .map(|_| (rng.below(ns as u32), rng.below(nt as u32)))
                    .collect(),
            )
        }
        _ => ConnRule::FixedIndegree { k: 1 },
    }
}

fn random_node_set(rng: &mut Rng, universe: u32) -> NodeSet {
    if rng.below(2) == 0 {
        let n = 2 + rng.below(universe - 2);
        let start = rng.below(universe - n);
        NodeSet::range(start, n)
    } else {
        // random sorted unique list
        let n = (2 + rng.below(universe / 2)) as usize;
        let mut ids: Vec<u32> = (0..universe).collect();
        rng.shuffle(&mut ids);
        let mut v: Vec<u32> = ids[..n].to_vec();
        v.sort_unstable();
        NodeSet::List(v)
    }
}

/// Property: Eq. 1 (S == R) holds for arbitrary random call sequences at
/// every GPU memory level.
#[test]
fn prop_alignment_random_call_sequences() {
    for case in 0..25u64 {
        let level = ALL_LEVELS[(case % 4) as usize];
        let cfg = SimConfig {
            seed: 5000 + case,
            level,
            ..Default::default()
        };
        let mut r0 = Simulator::new(Box::new(NullComm::new(0, 2)), cfg.clone());
        let mut r1 = Simulator::new(Box::new(NullComm::new(1, 2)), cfg);
        let p = LifParams::default();
        r0.create_neurons(64, &p);
        r1.create_neurons(64, &p);
        let mut rng = Rng::new(777 + case);
        for _ in 0..5 {
            let s = random_node_set(&mut rng, 64);
            let t = random_node_set(&mut rng, 64);
            let rule = random_rule(&mut rng, s.len(), t.len());
            let syn = SynSpec::new(1.0, 1);
            let (src, tgt) = if rng.below(2) == 0 { (0, 1) } else { (1, 0) };
            r0.remote_connect(src, &s, tgt, &t, &rule, &syn, None);
            r1.remote_connect(src, &s, tgt, &t, &rule, &syn, None);
        }
        assert_eq!(
            r0.remote.p2p_s[1].as_slice(),
            r1.remote.p2p_maps[0].r_slice(),
            "case {case} ({level:?}): 0->1 diverged"
        );
        assert_eq!(
            r1.remote.p2p_s[0].as_slice(),
            r0.remote.p2p_maps[1].r_slice(),
            "case {case} ({level:?}): 1->0 diverged"
        );
        assert!(r0.remote.p2p_maps[1].is_sorted());
        assert!(r1.remote.p2p_maps[0].is_sorted());
    }
}

/// Property: every connection created by a remote call has an image source
/// whose map entry resolves back to a source in the `s` argument.
#[test]
fn prop_every_remote_conn_sources_an_image() {
    for case in 0..15u64 {
        let cfg = SimConfig {
            seed: 9000 + case,
            ..Default::default()
        };
        let mut sim = Simulator::new(Box::new(NullComm::new(1, 2)), cfg);
        sim.create_neurons(32, &LifParams::default());
        let mut rng = Rng::new(31 + case);
        let s = random_node_set(&mut rng, 200);
        let t = random_node_set(&mut rng, 32);
        let rule = random_rule(&mut rng, s.len(), t.len());
        sim.remote_connect(0, &s, 1, &t, &rule, &SynSpec::new(1.0, 1), None);
        let s_ids: Vec<u32> = s.iter().collect();
        let map = &sim.remote.p2p_maps[0];
        for k in 0..sim.conns.len() {
            let src = sim.conns.source.as_slice()[k];
            assert!(sim.nodes.is_image(src), "case {case}: conn {k} source not an image");
            // the image's R entry is one of the call's source arguments
            let pos = map
                .l_slice()
                .iter()
                .position(|&l| l == src)
                .expect("image in map");
            assert!(
                s_ids.contains(&map.r_slice()[pos]),
                "case {case}: image resolves outside the source set"
            );
        }
    }
}

/// Property: ring buffers conserve mass — everything added with delay d is
/// read exactly once, d steps later, and nothing else appears.
#[test]
fn prop_ring_buffer_mass_conservation() {
    for case in 0..20u64 {
        let mut rng = Rng::new(100 + case);
        let n = 1 + rng.below(50) as usize;
        let max_delay = 1 + rng.below(20) as u16;
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(n, max_delay, &mut tr);
        let steps = 60;
        let mut expected = vec![0.0f64; steps + max_delay as usize + 2];
        let mut added = 0.0;
        let mut consumed = 0.0;
        for step in 0..steps {
            // random additions
            for _ in 0..rng.below(8) {
                let neuron = rng.below(n as u32);
                let delay = 1 + rng.below(max_delay as u32) as u16;
                let w = rng.uniform_range(0.1, 2.0) as f32;
                let mult = 1 + rng.below(3) as u16;
                rb.add(neuron, 0, delay, w, mult);
                expected[step + delay as usize] += (w * mult as f32) as f64;
                added += (w * mult as f32) as f64;
            }
            let (ex, _) = rb.current();
            let got: f64 = ex.iter().map(|&x| x as f64).sum();
            assert!(
                (got - expected[step]).abs() < 1e-4,
                "case {case} step {step}: got {got}, want {}",
                expected[step]
            );
            consumed += got;
            rb.advance();
        }
        // drain the tail
        for step in steps..steps + max_delay as usize + 1 {
            let (ex, _) = rb.current();
            consumed += ex.iter().map(|&x| x as f64).sum::<f64>();
            let want = expected[step];
            let got: f64 = ex.iter().map(|&x| x as f64).sum();
            assert!((got - want).abs() < 1e-4);
            rb.advance();
        }
        assert!(
            (added - consumed).abs() < 1e-3,
            "case {case}: mass not conserved ({added} vs {consumed})"
        );
    }
}

/// Property: EMD is a metric on point clouds (symmetry, identity,
/// triangle inequality on random samples).
#[test]
fn prop_emd_metric_properties() {
    let mut rng = Rng::new(5);
    for _ in 0..30 {
        let n = 5 + rng.below(50) as usize;
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal() + 0.5).collect();
        let c: Vec<f64> = (0..n).map(|_| rng.normal() - 0.3).collect();
        let ab = emd(&a, &b);
        let ba = emd(&b, &a);
        assert!((ab - ba).abs() < 1e-9, "symmetry");
        assert!(emd(&a, &a) < 1e-12, "identity");
        assert!(ab >= 0.0);
        let (ac, cb) = (emd(&a, &c), emd(&c, &b));
        assert!(ab <= ac + cb + 1e-9, "triangle: {ab} > {ac} + {cb}");
    }
}

/// Property: the flagging compaction never changes *which* connections are
/// created — only which images exist (levels 0 vs 1 build identical
/// connection multisets modulo image renumbering).
#[test]
fn prop_flagging_preserves_connectivity() {
    for case in 0..10u64 {
        let mut conn_sets = Vec::new();
        for level in [ALL_LEVELS[0], ALL_LEVELS[1]] {
            let cfg = SimConfig {
                seed: 4242 + case,
                level,
                ..Default::default()
            };
            let mut sim = Simulator::new(Box::new(NullComm::new(1, 2)), cfg);
            sim.create_neurons(32, &LifParams::default());
            let mut rng = Rng::new(88 + case);
            let s = random_node_set(&mut rng, 300);
            let t = random_node_set(&mut rng, 32);
            sim.remote_connect(
                0,
                &s,
                1,
                &t,
                &ConnRule::FixedIndegree { k: 2 },
                &SynSpec::new(1.0, 1),
                None,
            );
            // resolve image sources back to remote ids for comparison
            let map = &sim.remote.p2p_maps[0];
            let mut resolved: Vec<(u32, u32)> = (0..sim.conns.len())
                .map(|k| {
                    let img = sim.conns.source.as_slice()[k];
                    let pos = map.l_slice().iter().position(|&l| l == img).unwrap();
                    (map.r_slice()[pos], sim.conns.target.as_slice()[k])
                })
                .collect();
            resolved.sort_unstable();
            conn_sets.push(resolved);
        }
        assert_eq!(
            conn_sets[0], conn_sets[1],
            "case {case}: levels 0/1 built different connectivity"
        );
    }
}
