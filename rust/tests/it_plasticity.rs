//! Integration: the STDP plasticity subsystem (DESIGN.md §12).
//!
//! - determinism: a plastic balanced run produces bit-identical final
//!   weights (and spikes) at exchange interval 1 and auto, for 1, 2 and
//!   4 ranks, over both communication protocols, and across re-runs;
//! - weight bounds hold end-to-end for both bound modes;
//! - snapshot format v3 round-trips mid-run plastic state bit-identically;
//! - format-v2 snapshots still load, as all-static networks;
//! - unknown newer versions are rejected naming found vs. supported.

use std::path::PathBuf;

use nestgpu::comm::CommWorld;
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{run_cluster, run_cluster_from_snapshot, run_cluster_with_snapshot};
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::plasticity::NO_RULE;
use nestgpu::snapshot::format::tags;
use nestgpu::snapshot::{Encoder, SnapshotReader, SnapshotWriter};
use nestgpu::stats::weights::histogram;

fn tmp_dir(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("nestgpu_it_plast_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Small plastic balanced network: 45 neurons per rank, K_in = 45, STDP
/// on the recurrent E synapses with a learning rate large enough that
/// 100 ms visibly moves the weights.
fn plastic_bal(multiplicative: bool, collective: bool) -> BalancedConfig {
    BalancedConfig {
        scale: 0.004,
        k_scale: 0.004,
        collective,
        stdp: Some(StdpScenario {
            lambda: 0.05,
            multiplicative,
            ..Default::default()
        }),
        ..Default::default()
    }
}

fn cfg_with_interval(interval: Option<u16>) -> SimConfig {
    SimConfig {
        exchange_interval: interval,
        ..Default::default()
    }
}

fn run_plastic(
    interval: Option<u16>,
    ranks: usize,
    t_ms: f64,
    multiplicative: bool,
    collective: bool,
) -> Vec<SimResult> {
    let bal = plastic_bal(multiplicative, collective);
    run_cluster(
        ranks,
        &cfg_with_interval(interval),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

/// Per-rank (weight hash, spike train) — the full bit-identity witness.
fn fingerprints(results: &[SimResult]) -> Vec<(u64, &[(u32, u32)])> {
    results
        .iter()
        .map(|r| (r.plastic.expect("plastic run").hash, r.spikes.as_slice()))
        .collect()
}

#[test]
fn plastic_batching_bit_identical_for_1_2_4_ranks() {
    for ranks in [1usize, 2, 4] {
        let per_step = run_plastic(Some(1), ranks, 100.0, false, true);
        let auto = run_plastic(None, ranks, 100.0, false, true);
        if ranks > 1 {
            assert_eq!(per_step[0].exchange_interval, 1);
            // the model's only delay is 15 steps -> auto interval 15
            assert_eq!(auto[0].exchange_interval, 15);
        }
        let spikes: u64 = per_step.iter().map(|r| r.n_spikes).sum();
        assert!(spikes > 20, "{ranks} ranks: network must spike ({spikes})");
        for r in &per_step {
            assert!(r.n_plastic > 0, "rank {} has no plastic synapses", r.rank);
            let p = r.plastic.unwrap();
            assert!(
                p.sd > 0.0,
                "rank {}: STDP left every weight identical (sd = 0)",
                r.rank
            );
        }
        assert_eq!(
            fingerprints(&per_step),
            fingerprints(&auto),
            "{ranks} ranks: batched exchange changed a plastic run"
        );
    }
}

#[test]
fn plastic_batching_bit_identical_p2p() {
    let per_step = run_plastic(Some(1), 2, 100.0, false, false);
    let auto = run_plastic(None, 2, 100.0, false, false);
    assert_eq!(auto[0].exchange_interval, 15);
    assert!(per_step.iter().map(|r| r.n_spikes).sum::<u64>() > 20);
    assert_eq!(fingerprints(&per_step), fingerprints(&auto));
}

#[test]
fn plastic_run_reproducible_across_reruns() {
    let a = run_plastic(None, 2, 60.0, false, true);
    let b = run_plastic(None, 2, 60.0, false, true);
    assert_eq!(fingerprints(&a), fingerprints(&b));
}

#[test]
fn plastic_weights_respect_bounds_end_to_end() {
    for multiplicative in [false, true] {
        let bal = plastic_bal(multiplicative, true);
        let rule = bal.stdp_rule().unwrap();
        let results = run_plastic(None, 2, 100.0, multiplicative, true);
        for r in &results {
            let p = r.plastic.unwrap();
            assert!(p.n == r.n_plastic && p.n > 0);
            assert!(
                p.min >= rule.w_min && p.max <= rule.w_max,
                "rank {}: weights [{}, {}] escaped [{}, {}] (mult = \
                 {multiplicative})",
                r.rank,
                p.min,
                p.max,
                rule.w_min,
                rule.w_max
            );
        }
    }
}

#[test]
fn engine_invariants_bounds_and_weight_histogram() {
    // drive a live plastic simulator and check the engine-level
    // invariants directly: per-rule bounds via `bounds_ok`, and the
    // weight-distribution histogram covering every plastic synapse
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let mut sim = Simulator::new(Box::new(comm), SimConfig::default());
    let bal = plastic_bal(false, true);
    let rule = bal.stdp_rule().unwrap();
    build_balanced(&mut sim, &bal);
    sim.prepare().unwrap();
    for _ in 0..300 {
        sim.step_once().unwrap();
    }
    let eng = sim.plasticity_engine().unwrap();
    assert!(eng.n_plastic() > 0);
    assert!(
        eng.bounds_ok(&sim.conns),
        "a plastic weight escaped its rule's bounds"
    );
    let plastic_weights = || {
        sim.conns
            .rule_slice()
            .unwrap()
            .iter()
            .zip(sim.conns.weight.as_slice())
            .filter(|(&rid, _)| rid != NO_RULE)
            .map(|(_, &w)| w)
    };
    let h = histogram(plastic_weights(), rule.w_min, rule.w_max, 8);
    assert_eq!(h.iter().sum::<u64>(), eng.n_plastic() as u64);
    assert!(
        h.iter().filter(|&&c| c > 0).count() > 1,
        "STDP should spread the weights across bins: {h:?}"
    );
}

#[test]
fn static_run_reports_no_plastic_state() {
    let bal = BalancedConfig {
        scale: 0.004,
        k_scale: 0.004,
        ..Default::default()
    };
    let results = run_cluster(
        2,
        &SimConfig::default(),
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        20.0,
    )
    .unwrap();
    for r in &results {
        assert_eq!(r.n_plastic, 0);
        assert!(r.plastic.is_none());
        assert_eq!(r.step_phases.pre_update, std::time::Duration::ZERO);
        assert_eq!(r.step_phases.post_update, std::time::Duration::ZERO);
    }
}

#[test]
fn snapshot_v3_roundtrips_midrun_plastic_state() {
    let cfg = SimConfig::default();
    let dir = tmp_dir("v3_midrun");

    // uninterrupted 100 ms
    let bal = plastic_bal(false, true);
    let b2 = bal.clone();
    let full = run_cluster(
        2,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &b2),
        100.0,
    )
    .unwrap();

    // 50 ms, checkpoint (flushes the exchange interval mid-flight), resume
    // another 50 ms — spikes and evolved weights must match bit-exactly
    let b3 = bal.clone();
    let half = run_cluster_with_snapshot(
        2,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &b3),
        50.0,
        &dir,
    )
    .unwrap();
    for r in &half {
        assert!(r.n_plastic > 0);
    }
    let resumed = run_cluster_from_snapshot(&dir, 50.0).unwrap();

    assert_eq!(full.len(), resumed.len());
    for (f, r) in full.iter().zip(resumed.iter()) {
        assert!(f.n_spikes > 10, "rank {} barely spiked", f.rank);
        assert_eq!(f.spikes, r.spikes, "rank {}: spike trains diverged", f.rank);
        assert_eq!(
            f.plastic.unwrap().hash,
            r.plastic.unwrap().hash,
            "rank {}: resumed plastic weights diverged",
            f.rank
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Build a prepared single-rank *static* simulator and return it.
fn static_single() -> Simulator {
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let mut sim = Simulator::new(Box::new(comm), SimConfig::default());
    let bal = BalancedConfig {
        scale: 0.004,
        k_scale: 0.004,
        ..Default::default()
    };
    build_balanced(&mut sim, &bal);
    sim.prepare().unwrap();
    sim
}

/// Rewrite a v4 snapshot of a *static, materialized* network as a genuine
/// v2 container: strip the (empty) rules block appended to CONN, the
/// trailing connectivity byte appended to CONF, and stamp version 2. This
/// is byte-exact: both v3 and v4 additions are strict appends, so the
/// truncated payloads are exactly what a v2 writer would have produced.
fn downgrade_to_v2(bytes: &[u8]) -> Vec<u8> {
    let r = SnapshotReader::open(bytes).unwrap();
    assert!(r.try_section(tags::PLAS).is_none(), "static snapshot expected");
    assert!(
        r.try_section(tags::PROC).is_none(),
        "materialized snapshot expected"
    );
    let mut empty_rules = Encoder::new();
    empty_rules.seq_len(0);
    empty_rules.bool(false);
    let strip = empty_rules.len();
    let mut w = SnapshotWriter::new();
    for tag in r.section_tags() {
        let mut payload = r.section(tag).unwrap().to_vec();
        if tag == tags::CONN {
            payload.truncate(payload.len() - strip);
        }
        if tag == tags::CONF {
            // v4 appended one connectivity byte at the very end of CONF
            payload.truncate(payload.len() - 1);
        }
        w.section(tag, payload);
    }
    w.finish_with_version(2)
}

#[test]
fn v2_snapshot_loads_as_all_static_and_continues_identically() {
    let mut sim = static_single();
    for _ in 0..50 {
        sim.step_once().unwrap();
    }
    sim.flush_exchange().unwrap();
    let v2 = downgrade_to_v2(&sim.snapshot_to_bytes().unwrap());

    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let mut restored = Simulator::load_snapshot_bytes(Box::new(comm), &v2).unwrap();
    assert!(restored.plasticity_engine().is_none(), "v2 loads all-static");
    for _ in 0..100 {
        sim.step_once().unwrap();
        restored.step_once().unwrap();
    }
    assert_eq!(restored.recorder.events, sim.recorder.events);
    assert!(sim.recorder.events.len() > 5, "network must actually spike");
}

#[test]
fn newer_snapshot_version_rejected_naming_versions() {
    let sim = static_single();
    let mut bytes = sim.snapshot_to_bytes().unwrap();
    bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let err = Simulator::load_snapshot_bytes(Box::new(comm), &bytes)
        .unwrap_err()
        .to_string();
    assert!(err.contains("version 9"), "{err}");
    assert!(err.contains("2..=4"), "{err}");
}

#[test]
fn plastic_snapshot_rejected_without_plas_section() {
    // a v3 plastic snapshot whose PLAS section is dropped must fail the
    // load with a descriptive error, not resume silently static
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let mut sim = Simulator::new(Box::new(comm), SimConfig::default());
    build_balanced(&mut sim, &plastic_bal(false, true));
    sim.prepare().unwrap();
    for _ in 0..20 {
        sim.step_once().unwrap();
    }
    sim.flush_exchange().unwrap();
    let bytes = sim.snapshot_to_bytes().unwrap();
    let r = SnapshotReader::open(&bytes).unwrap();
    assert!(r.try_section(tags::PLAS).is_some());
    let mut w = SnapshotWriter::new();
    for tag in r.section_tags() {
        if tag == tags::PLAS {
            continue;
        }
        w.section(tag, r.section(tag).unwrap().to_vec());
    }
    let crippled = w.finish();
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let err = Simulator::load_snapshot_bytes(Box::new(comm), &crippled)
        .unwrap_err()
        .to_string();
    assert!(err.contains("PLAS"), "{err}");
}
