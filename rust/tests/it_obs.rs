//! Integration: the observability subsystem (DESIGN.md §13) — obs on vs
//! off is bit-identical (spikes AND comm metrics), the merged cross-rank
//! summary is bit-stable over reruns for 1/2/4 ranks on both exchange
//! protocols, and a traced 4-rank run round-trips through
//! `obs::report::read_trace_dir` with per-rank per-phase statistics,
//! comm/memory series and a hash-verified manifest.

use std::path::PathBuf;

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::obs::metrics::{ALL_COUNTERS, ALL_GAUGES, N_BUCKETS};
use nestgpu::obs::report::read_trace_dir;
use nestgpu::obs::{CounterId, HistId, MetricsRegistry, ObsConfig};
use nestgpu::util::timer::StepPhase;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nestgpu_it_obs_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spikes(results: &[SimResult]) -> Vec<&[(u32, u32)]> {
    results.iter().map(|r| r.spikes.as_slice()).collect()
}

fn run_balanced(
    obs: Option<ObsConfig>,
    collective: bool,
    ranks: usize,
    t_ms: f64,
) -> Vec<SimResult> {
    let bal = BalancedConfig {
        scale: 0.01,
        k_scale: 0.01,
        collective,
        ..Default::default()
    };
    let cfg = SimConfig {
        obs,
        ..Default::default()
    };
    run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .unwrap()
}

/// The wall-clock-free projection of a registry: every counter and gauge,
/// plus the full bucket state of the value histograms. The per-phase ns
/// histograms are excluded — they measure wall clock and legitimately
/// differ between reruns.
fn deterministic_key(r: &MetricsRegistry) -> Vec<u64> {
    let mut k = Vec::new();
    for c in ALL_COUNTERS {
        k.push(r.counter(c));
    }
    for g in ALL_GAUGES {
        k.push(r.gauge(g));
    }
    for h in [
        HistId::SpikesPerStep,
        HistId::RecordsPerExchange,
        HistId::BytesPerExchange,
    ] {
        let hist = r.hist(h);
        k.push(hist.count);
        k.push(hist.sum);
        k.push(hist.max);
        for b in 0..N_BUCKETS {
            k.push(hist.bucket_count(b));
        }
    }
    k
}

#[test]
fn obs_on_is_bit_identical_to_obs_off() {
    for collective in [false, true] {
        let off = run_balanced(None, collective, 2, 30.0);
        let dir = tmp_dir(if collective { "identity_coll" } else { "identity_p2p" });
        let obs = ObsConfig {
            trace_dir: Some(dir.clone()),
            sample_interval: 3,
            ..ObsConfig::default()
        };
        let on = run_balanced(Some(obs), collective, 2, 30.0);

        assert!(
            off.iter().map(|r| r.n_spikes).sum::<u64>() > 0,
            "network must spike"
        );
        assert_eq!(spikes(&off), spikes(&on), "collective={collective}");
        // the run's comm metrics must be untouched by observability: the
        // finalize-time aggregation allgather happens after the result is
        // collected, and the obs world group never joins the exchange
        for (a, b) in off.iter().zip(on.iter()) {
            assert_eq!(a.p2p_messages, b.p2p_messages);
            assert_eq!(a.p2p_bytes, b.p2p_bytes);
            assert_eq!(a.coll_calls, b.coll_calls);
            assert_eq!(a.coll_bytes, b.coll_bytes);
        }
        // merged summary lands on rank 0 only
        assert!(on[0].obs.is_some());
        assert!(on[1].obs.is_none());
        assert!(off[0].obs.is_none(), "obs off must not produce a summary");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn merged_summary_deterministic_subset_is_bit_stable() {
    for collective in [false, true] {
        for ranks in [1usize, 2, 4] {
            let a = run_balanced(Some(ObsConfig::default()), collective, ranks, 25.0);
            let b = run_balanced(Some(ObsConfig::default()), collective, ranks, 25.0);
            let sa = a[0].obs.as_ref().expect("rank 0 carries the summary");
            let sb = b[0].obs.as_ref().expect("rank 0 carries the summary");
            assert_eq!(sa.n_ranks, ranks);
            assert_eq!(
                deterministic_key(&sa.merged),
                deterministic_key(&sb.merged),
                "collective={collective} ranks={ranks}"
            );
            // 25 ms at dt 0.1 = 250 steps per rank; counters add on merge
            assert_eq!(sa.merged.counter(CounterId::Steps), 250 * ranks as u64);
            assert!(sa.merged.counter(CounterId::SpikesEmitted) > 0);
            assert!(sa.merged.counter(CounterId::Exchanges) > 0);
            // the phase histograms fed every step on every rank
            let dynamics = sa.merged.hist(HistId::PhaseNs(StepPhase::Dynamics));
            assert_eq!(dynamics.count, 250 * ranks as u64);
        }
    }
}

#[test]
fn four_rank_trace_report_end_to_end() {
    let dir = tmp_dir("report4");
    let obs = ObsConfig {
        trace_dir: Some(dir.clone()),
        sample_interval: 2,
        label: "it-obs".to_string(),
        ..ObsConfig::default()
    };
    let results = run_balanced(Some(obs), false, 4, 30.0);
    assert!(results.iter().map(|r| r.n_spikes).sum::<u64>() > 0);

    let rep = read_trace_dir(&dir).unwrap();
    let manifest = rep
        .manifest
        .as_ref()
        .expect("manifest.json present and hash-clean");
    assert_eq!(manifest.get("n_ranks").unwrap().as_usize(), Some(4));
    assert_eq!(manifest.get("label").unwrap().as_str(), Some("it-obs"));
    assert_eq!(manifest.get("sample_interval").unwrap().as_usize(), Some(2));

    assert_eq!(rep.ranks.len(), 4, "one trace per rank");
    for (i, r) in rep.ranks.iter().enumerate() {
        assert_eq!(r.rank, i);
        assert!(r.samples > 0);
        // dynamics runs every step on every rank: populated and ordered
        let dynamics = &r.phase_ns[StepPhase::Dynamics.index()];
        assert_eq!(dynamics.count, r.samples);
        assert!(dynamics.max > 0);
        for s in &r.phase_ns {
            assert!(s.p50 <= s.p95 && s.p95 <= s.max);
        }
        // comm and memory series are populated (p2p run, host tracker)
        assert!(r.p2p_bytes > 0, "rank {i} p2p bytes");
        assert!(r.host_peak > 0, "rank {i} host peak");
        assert!(r.summary.is_some(), "rank {i} summary record");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
