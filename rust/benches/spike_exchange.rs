//! Spike-exchange batching: the balanced network (point-to-point mode)
//! at exchange interval 1 vs the auto interval (= minimum remote synaptic
//! delay, 15 steps for this model), plus the same batched workload over
//! the multi-process socket transport (DESIGN.md §15) — one OS process
//! per rank, real TCP loopback, whole-frame wire accounting.
//!
//! Reports steps/s, exchanged records/s, p2p message counts and bytes per
//! step, and writes `BENCH_spike_exchange.json` at the repository root so
//! the perf trajectory of the exchange path has machine-readable data
//! points. Expected shape: p2p messages drop by ~interval×, payload bytes
//! stay within ~1× (same records, fewer envelopes), step rate does not
//! regress; socket wire bytes exceed thread bytes (24-byte frame headers,
//! empty rounds framed) while the record stream stays bit-identical.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;

use nestgpu::comm::{SocketComm, SocketConfig, MSG_HEADER_BYTES, SPIKE_RECORD_BYTES};
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{free_loopback_addr, run_cluster};
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, Table};

/// Env protocol for the self-spawned socket rank processes: when
/// `NESTGPU_BENCH_SOCKET_RANK` is set, this binary runs as that rank of
/// the socket world instead of as the bench driver.
const ENV_RANK: &str = "NESTGPU_BENCH_SOCKET_RANK";
const ENV_WORLD: &str = "NESTGPU_BENCH_SOCKET_WORLD";
const ENV_RDV: &str = "NESTGPU_BENCH_SOCKET_RDV";
const CHILD_PREFIX: &str = "BENCH_CHILD ";

struct Point {
    label: String,
    interval: u16,
    steps_per_s: f64,
    p2p_messages: u64,
    p2p_bytes: u64,
    bytes_per_step: f64,
    coll_calls: u64,
}

/// The workload shared by the driver and the socket rank children —
/// deriving it from `SMOKE` alone keeps the processes in agreement
/// without passing model knobs through the environment.
fn bench_params(smoke: bool) -> (usize, f64, BalancedConfig) {
    let ranks = if smoke { 2 } else { 4 };
    let t_ms = if smoke { 50.0 } else { 200.0 };
    // dense enough that most steps carry spikes on every rank pair — the
    // regime where batching approaches the full interval-x reduction
    // (empty packets are never counted as messages)
    let bal = BalancedConfig {
        scale: if smoke { 0.01 } else { 0.1 },
        k_scale: 0.01,
        collective: false, // point-to-point exchange
        ..Default::default()
    };
    (ranks, t_ms, bal)
}

fn bench_sim_config() -> SimConfig {
    SimConfig {
        record_spikes: false, // benchmarking runs, as in the paper
        exchange_interval: None,
        ..Default::default()
    }
}

fn measure(
    label: &str,
    interval: Option<u16>,
    ranks: usize,
    bal: &BalancedConfig,
    t_ms: f64,
) -> Point {
    let cfg = SimConfig {
        exchange_interval: interval,
        ..bench_sim_config()
    };
    let b = bal.clone();
    let results: Vec<SimResult> = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &b),
        t_ms,
    )
    .expect("bench run");
    let steps = (t_ms / cfg.dt_ms).round();
    let prop_s = results
        .iter()
        .map(|r| r.phases.propagation.as_secs_f64())
        .fold(0.0, f64::max)
        .max(1e-9);
    let p2p_messages: u64 = results.iter().map(|r| r.p2p_messages).sum();
    let p2p_bytes: u64 = results.iter().map(|r| r.p2p_bytes).sum();
    let coll_calls: u64 = results.iter().map(|r| r.coll_calls).sum();
    Point {
        label: label.to_string(),
        interval: results[0].exchange_interval,
        steps_per_s: steps / prop_s,
        p2p_messages,
        p2p_bytes,
        bytes_per_step: p2p_bytes as f64 / steps,
        coll_calls,
    }
}

/// One socket rank process: connect, run the batched workload, print a
/// single machine-readable record for the driver, exit.
fn child_rank_main(rank: usize) -> ! {
    let world: usize = std::env::var(ENV_WORLD)
        .expect("child env: world")
        .parse()
        .expect("child env: world size");
    let rdv = std::env::var(ENV_RDV).expect("child env: rendezvous");
    let smoke = std::env::var("SMOKE").is_ok();
    let (_, t_ms, bal) = bench_params(smoke);
    let scfg = SocketConfig {
        rank: Some(rank),
        ..SocketConfig::new(rdv, world)
    };
    let comm = SocketComm::connect(&scfg).expect("socket connect");
    let mut sim = Simulator::new(Box::new(comm), bench_sim_config());
    build_balanced(&mut sim, &bal);
    sim.prepare().expect("prepare");
    let res = sim.simulate(t_ms).expect("simulate");
    let record = Json::obj(vec![
        ("rank", Json::num(rank as f64)),
        ("interval", Json::num(res.exchange_interval as f64)),
        (
            "propagation_s",
            Json::num(res.phases.propagation.as_secs_f64()),
        ),
        ("p2p_messages", Json::num(res.p2p_messages as f64)),
        ("p2p_bytes", Json::num(res.p2p_bytes as f64)),
        ("coll_calls", Json::num(res.coll_calls as f64)),
    ]);
    println!("{CHILD_PREFIX}{record}");
    std::process::exit(0);
}

/// The batched workload over `ranks` OS processes on the socket
/// transport: spawn this binary once per rank, aggregate their records.
fn measure_socket(ranks: usize, t_ms: f64, steps: f64) -> Point {
    let rdv = free_loopback_addr().expect("loopback rendezvous");
    let exe = std::env::current_exe().expect("own executable");
    let children: Vec<std::process::Child> = (0..ranks)
        .map(|rank| {
            std::process::Command::new(&exe)
                .env(ENV_RANK, rank.to_string())
                .env(ENV_WORLD, ranks.to_string())
                .env(ENV_RDV, &rdv)
                .stdout(std::process::Stdio::piped())
                .spawn()
                .expect("spawn socket rank")
        })
        .collect();
    let mut interval = 0u16;
    let mut prop_s = 1e-9f64;
    let (mut p2p_messages, mut p2p_bytes, mut coll_calls) = (0u64, 0u64, 0u64);
    // children all run concurrently; each prints one short record, so
    // sequential collection cannot back up a pipe
    for (rank, child) in children.into_iter().enumerate() {
        let out = child.wait_with_output().expect("collect socket rank");
        assert!(out.status.success(), "socket rank {rank} failed: {}", out.status);
        let stdout = String::from_utf8_lossy(&out.stdout);
        let line = stdout
            .lines()
            .find_map(|l| l.strip_prefix(CHILD_PREFIX))
            .unwrap_or_else(|| panic!("socket rank {rank} printed no bench record"));
        let j = Json::parse(line).expect("bench record JSON");
        let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        interval = f("interval") as u16;
        prop_s = prop_s.max(f("propagation_s"));
        p2p_messages += f("p2p_messages") as u64;
        p2p_bytes += f("p2p_bytes") as u64;
        coll_calls += f("coll_calls") as u64;
    }
    Point {
        label: format!("socket {ranks} procs"),
        interval,
        steps_per_s: steps / prop_s,
        p2p_messages,
        p2p_bytes,
        bytes_per_step: p2p_bytes as f64 / steps,
        coll_calls,
    }
}

impl Point {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("interval", Json::num(self.interval as f64)),
            ("steps_per_s", Json::num(self.steps_per_s)),
            ("p2p_messages", Json::num(self.p2p_messages as f64)),
            ("p2p_bytes", Json::num(self.p2p_bytes as f64)),
            ("bytes_per_step", Json::num(self.bytes_per_step)),
            ("coll_calls", Json::num(self.coll_calls as f64)),
        ])
    }
}

fn main() {
    if let Ok(rank) = std::env::var(ENV_RANK) {
        child_rank_main(rank.parse().expect("child env: rank index"));
    }
    let smoke = std::env::var("SMOKE").is_ok();
    let (ranks, t_ms, bal) = bench_params(smoke);
    println!(
        "balanced (p2p), {ranks} ranks x {} neurons, {t_ms} ms, delay {} steps{}",
        bal.neurons_per_rank(),
        bal.delay_steps,
        if smoke { " [smoke]" } else { "" }
    );

    let per_step = measure("interval 1", Some(1), ranks, &bal, t_ms);
    let batched = measure("interval min_delay", None, ranks, &bal, t_ms);
    let steps = (t_ms / SimConfig::default().dt_ms).round();
    let socket = measure_socket(ranks, t_ms, steps);

    let mut t = Table::new(
        "spike exchange: per-step vs min-delay batching vs socket procs",
        &["config", "interval", "steps/s", "p2p msgs", "p2p bytes", "bytes/step"],
    );
    for p in [&per_step, &batched, &socket] {
        t.row(vec![
            p.label.clone(),
            p.interval.to_string(),
            format!("{:.0}", p.steps_per_s),
            p.p2p_messages.to_string(),
            fmt_bytes(p.p2p_bytes),
            format!("{:.1}", p.bytes_per_step),
        ]);
    }
    t.print();

    let reduction = per_step.p2p_messages as f64 / batched.p2p_messages.max(1) as f64;
    println!(
        "\np2p message reduction: {reduction:.1}x (interval {}); paper shape check: \
         ~interval x fewer messages, no step-rate regression at interval 1",
        batched.interval
    );
    assert!(
        batched.p2p_messages < per_step.p2p_messages,
        "batching must reduce the p2p message count"
    );

    // the record stream is bit-identical across transports (the socket
    // ranks run the same seeds), so the exchanged-record count derives
    // from the thread run's payload-only accounting; socket bytes add the
    // 24-byte frame headers and the empty-round framing on top
    let records = batched
        .p2p_bytes
        .saturating_sub(batched.p2p_messages * MSG_HEADER_BYTES)
        / SPIKE_RECORD_BYTES;
    let thread_records_per_s = records as f64 * batched.steps_per_s / steps;
    let socket_records_per_s = records as f64 * socket.steps_per_s / steps;
    let wire_factor = socket.p2p_bytes as f64 / batched.p2p_bytes.max(1) as f64;
    println!(
        "socket transport: {socket_records_per_s:.0} records/s over {} procs \
         (thread: {thread_records_per_s:.0}); wire bytes {:.2}x thread payload bytes",
        ranks, wire_factor
    );
    assert!(
        socket.p2p_bytes > batched.p2p_bytes,
        "socket wire accounting must include frame overhead"
    );

    let fields = vec![
        ("model", Json::str("balanced-p2p")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        ("smoke", Json::Bool(smoke)),
        ("min_delay", Json::num(batched.interval as f64)),
        ("interval_1", per_step.to_json()),
        ("interval_min_delay", batched.to_json()),
        ("socket_procs", socket.to_json()),
        ("p2p_message_reduction", Json::num(reduction)),
        ("exchange_records", Json::num(records as f64)),
        ("thread_records_per_s", Json::num(thread_records_per_s)),
        ("socket_records_per_s", Json::num(socket_records_per_s)),
        ("socket_wire_bytes_vs_thread", Json::num(wire_factor)),
    ];
    // at the repository root (one directory above the rust package);
    // stamped with schema version / timestamp / git revision, and
    // refuses to clobber a newer-schema file (obs::stamp)
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_spike_exchange.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
