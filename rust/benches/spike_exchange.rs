//! Spike-exchange batching: the balanced network (point-to-point mode)
//! at exchange interval 1 vs the auto interval (= minimum remote synaptic
//! delay, 15 steps for this model).
//!
//! Reports steps/s, p2p message counts and bytes per step, and writes
//! `BENCH_spike_exchange.json` at the repository root so the perf
//! trajectory of the exchange path has machine-readable data points.
//! Expected shape: p2p messages drop by ~interval×, payload bytes stay
//! within ~1× (same records, fewer envelopes), step rate does not regress.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, Table};

struct Point {
    label: &'static str,
    interval: u16,
    steps_per_s: f64,
    p2p_messages: u64,
    p2p_bytes: u64,
    bytes_per_step: f64,
    coll_calls: u64,
}

fn measure(
    label: &'static str,
    interval: Option<u16>,
    ranks: usize,
    bal: &BalancedConfig,
    t_ms: f64,
) -> Point {
    let cfg = SimConfig {
        record_spikes: false, // benchmarking runs, as in the paper
        exchange_interval: interval,
        ..Default::default()
    };
    let b = bal.clone();
    let results: Vec<SimResult> = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &b),
        t_ms,
    )
    .expect("bench run");
    let steps = (t_ms / cfg.dt_ms).round();
    let prop_s = results
        .iter()
        .map(|r| r.phases.propagation.as_secs_f64())
        .fold(0.0, f64::max)
        .max(1e-9);
    let p2p_messages: u64 = results.iter().map(|r| r.p2p_messages).sum();
    let p2p_bytes: u64 = results.iter().map(|r| r.p2p_bytes).sum();
    let coll_calls: u64 = results.iter().map(|r| r.coll_calls).sum();
    Point {
        label,
        interval: results[0].exchange_interval,
        steps_per_s: steps / prop_s,
        p2p_messages,
        p2p_bytes,
        bytes_per_step: p2p_bytes as f64 / steps,
        coll_calls,
    }
}

impl Point {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("interval", Json::num(self.interval as f64)),
            ("steps_per_s", Json::num(self.steps_per_s)),
            ("p2p_messages", Json::num(self.p2p_messages as f64)),
            ("p2p_bytes", Json::num(self.p2p_bytes as f64)),
            ("bytes_per_step", Json::num(self.bytes_per_step)),
            ("coll_calls", Json::num(self.coll_calls as f64)),
        ])
    }
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let ranks = if smoke { 2 } else { 4 };
    let t_ms = if smoke { 50.0 } else { 200.0 };
    // dense enough that most steps carry spikes on every rank pair — the
    // regime where batching approaches the full interval-x reduction
    // (empty packets are never counted as messages)
    let bal = BalancedConfig {
        scale: if smoke { 0.01 } else { 0.1 },
        k_scale: 0.01,
        collective: false, // point-to-point exchange
        ..Default::default()
    };
    println!(
        "balanced (p2p), {ranks} ranks x {} neurons, {t_ms} ms, delay {} steps{}",
        bal.neurons_per_rank(),
        bal.delay_steps,
        if smoke { " [smoke]" } else { "" }
    );

    let per_step = measure("interval 1", Some(1), ranks, &bal, t_ms);
    let batched = measure("interval min_delay", None, ranks, &bal, t_ms);

    let mut t = Table::new(
        "spike exchange: per-step vs min-delay batching",
        &["config", "interval", "steps/s", "p2p msgs", "p2p bytes", "bytes/step"],
    );
    for p in [&per_step, &batched] {
        t.row(vec![
            p.label.to_string(),
            p.interval.to_string(),
            format!("{:.0}", p.steps_per_s),
            p.p2p_messages.to_string(),
            fmt_bytes(p.p2p_bytes),
            format!("{:.1}", p.bytes_per_step),
        ]);
    }
    t.print();

    let reduction = per_step.p2p_messages as f64 / batched.p2p_messages.max(1) as f64;
    println!(
        "\np2p message reduction: {reduction:.1}x (interval {}); paper shape check: \
         ~interval x fewer messages, no step-rate regression at interval 1",
        batched.interval
    );
    assert!(
        batched.p2p_messages < per_step.p2p_messages,
        "batching must reduce the p2p message count"
    );

    let fields = vec![
        ("model", Json::str("balanced-p2p")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        ("smoke", Json::Bool(smoke)),
        ("min_delay", Json::num(batched.interval as f64)),
        ("interval_1", per_step.to_json()),
        ("interval_min_delay", batched.to_json()),
        ("p2p_message_reduction", Json::num(reduction)),
    ];
    // at the repository root (one directory above the rust package);
    // stamped with schema version / timestamp / git revision, and
    // refuses to clobber a newer-schema file (obs::stamp)
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_spike_exchange.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
