//! §Perf microbenches: the coordinator's hot paths, measured in isolation.
//!
//! 1. connection sort-by-source (the dominant preparation cost, Fig. 6b);
//! 2. spike delivery inner loop (ring-buffer accumulate);
//! 3. (R, L) map merge (`RemoteConnect`'s ensure_images);
//! 4. p2p exchange round-trip (2-rank world);
//! 5. PJRT kernel call overhead vs the native backend, per block size.
//!
//! Results feed the EXPERIMENTS.md §Perf before/after log.

use std::time::Instant;

use nestgpu::comm::{CommWorld, Communicator, SpikeRecord};
use nestgpu::connection::Connections;
use nestgpu::memory::{MemKind, Tracker};
use nestgpu::node::neuron::LifParams;
use nestgpu::node::RingBuffers;
use nestgpu::remote::pair_map::PairMap;
use nestgpu::runtime::{native::NativeBackend, Backend, StateChunk};
use nestgpu::util::json::Json;
use nestgpu::util::rng::Rng;
use nestgpu::util::table::{fmt_secs, Table};

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn bench_sort(n_conns: usize, n_nodes: usize) -> (f64, f64) {
    let mut rng = Rng::new(7);
    let secs = time(3, || {
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        for _ in 0..n_conns {
            c.push(
                rng.below(n_nodes as u32),
                rng.below(n_nodes as u32),
                1.0,
                1,
                0,
                &mut tr,
            );
        }
        let t0 = Instant::now();
        c.sort_by_source(n_nodes, &mut tr);
        std::hint::black_box(t0.elapsed());
    });
    // measure the sort alone
    let mut tr = Tracker::new();
    let mut c = Connections::new();
    for _ in 0..n_conns {
        c.push(rng.below(n_nodes as u32), 0, 1.0, 1, 0, &mut tr);
    }
    let t0 = Instant::now();
    c.sort_by_source(n_nodes, &mut tr);
    let sort_only = t0.elapsed().as_secs_f64();
    (secs, n_conns as f64 / sort_only)
}

fn bench_delivery(n_targets: usize) -> f64 {
    let mut tr = Tracker::new();
    let mut conns = Connections::new();
    let mut rng = Rng::new(3);
    for _ in 0..n_targets {
        conns.push(0, rng.below(10_000), 1.0, 1 + (rng.below(14) as u16), 0, &mut tr);
    }
    conns.sort_by_source(10_001, &mut tr);
    let lut: Vec<u32> = (0..10_001).collect();
    let mut rb = RingBuffers::new(10_001, 16, &mut tr);
    let per_call = time(200, || {
        let rng_range = conns.outgoing(0);
        let targets = &conns.target.as_slice()[rng_range.clone()];
        let ports = &conns.port.as_slice()[rng_range.clone()];
        let delays = &conns.delay.as_slice()[rng_range.clone()];
        let weights = &conns.weight.as_slice()[rng_range];
        for i in 0..targets.len() {
            rb.add(lut[targets[i] as usize], ports[i], delays[i], weights[i], 1);
        }
        rb.advance();
    });
    n_targets as f64 / per_call // synapse events per second
}

fn bench_map_merge(map_size: usize, batch: usize) -> f64 {
    let mut tr = Tracker::new();
    let mut map = PairMap::new(MemKind::Device);
    let mut next = 0u32;
    let base: Vec<u32> = (0..map_size as u32).map(|i| i * 3).collect();
    map.ensure_images(&base, &mut tr, || {
        let v = next;
        next += 1;
        v
    });
    let news: Vec<u32> = (0..batch as u32).map(|i| i * 3 + 1).collect();
    time(20, || {
        let mut m2 = PairMap::new(MemKind::Device);
        let mut nx = 0u32;
        m2.ensure_images(&base, &mut tr, || {
            let v = nx;
            nx += 1;
            v
        });
        m2.ensure_images(&news, &mut tr, || {
            let v = nx;
            nx += 1;
            v
        });
    })
}

fn bench_exchange(packet_len: usize) -> f64 {
    let world = CommWorld::new(2);
    let mut comms = world.communicators();
    let c1 = comms.pop().unwrap();
    let mut c0 = comms.pop().unwrap();
    let handle = std::thread::spawn(move || {
        let mut c1 = c1;
        for _ in 0..201 {
            let out = vec![vec![], vec![]];
            let _ = c1.exchange(out);
        }
    });
    let pkt: Vec<SpikeRecord> = (0..packet_len as u32)
        .map(|i| SpikeRecord {
            pos: i,
            mult: 1,
            lag: 0,
        })
        .collect();
    let per_round = time(200, || {
        let out = vec![vec![], pkt.clone()];
        let _ = c0.exchange(out);
    });
    handle.join().unwrap();
    per_round
}

fn bench_backends() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let params = LifParams::default().packed(0.1);
    let mut tr = Tracker::new();
    for &n in &[1024usize, 8192] {
        let mut chunk = StateChunk::new(n, params, &mut tr);
        let mut nat = NativeBackend::new();
        let t = time(50, || {
            nat.step(&mut chunk).unwrap();
        });
        out.push((format!("native n={n}"), n as f64 / t));
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let mut pjrt = nestgpu::runtime::pjrt::PjrtBackend::load(&dir).unwrap();
        for &n in &[1024usize, 8192] {
            let mut chunk = StateChunk::new(n, params, &mut tr);
            let t = time(50, || {
                pjrt.step(&mut chunk).unwrap();
            });
            out.push((format!("pjrt   n={n}"), n as f64 / t));
        }
    } else {
        println!("(skipping PJRT backend bench: run `make artifacts`)");
    }
    out
}

fn main() {
    let mut t = Table::new("§Perf — coordinator hot paths", &["path", "metric", "value"]);
    let mut json = Vec::new();

    let (_, sort_rate) = bench_sort(2_000_000, 100_000);
    t.row(vec![
        "connection sort-by-source".into(),
        "conns/s".into(),
        format!("{:.2e}", sort_rate),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("sort")),
        ("conns_per_s", Json::num(sort_rate)),
    ]));

    let deliv = bench_delivery(10_000);
    t.row(vec![
        "spike delivery (10k fanout)".into(),
        "syn events/s".into(),
        format!("{:.2e}", deliv),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("delivery")),
        ("events_per_s", Json::num(deliv)),
    ]));

    let merge = bench_map_merge(100_000, 10_000);
    t.row(vec![
        "map merge (100k + 10k)".into(),
        "s/call".into(),
        fmt_secs(merge),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("map_merge")),
        ("secs", Json::num(merge)),
    ]));

    let xch = bench_exchange(1_000);
    t.row(vec![
        "p2p exchange round (1k spikes)".into(),
        "s/round".into(),
        fmt_secs(xch),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("exchange")),
        ("secs_per_round", Json::num(xch)),
    ]));

    for (name, rate) in bench_backends() {
        t.row(vec![
            format!("backend step {name}"),
            "neuron updates/s".into(),
            format!("{:.2e}", rate),
        ]);
        json.push(Json::obj(vec![
            ("path", Json::str(&format!("backend {name}"))),
            ("updates_per_s", Json::num(rate)),
        ]));
    }

    t.print();
    nestgpu::harness::experiments::write_result("perf_hotpaths", &Json::Arr(json));
}
