//! §Perf microbenches: the coordinator's hot paths, measured in isolation.
//!
//! 1. connection sort-by-source (the dominant preparation cost, Fig. 6b);
//! 2. spike delivery: naive per-record scatter vs the prepared
//!    [`DeliveryPlan`] + slot-bucketed [`DeliveryQueue`] (DESIGN.md §14);
//! 3. fused accumulation-plane merge (`merge_planes`) throughput;
//! 4. (R, L) map merge (`RemoteConnect`'s ensure_images);
//! 5. p2p exchange round-trip (2-rank world);
//! 6. LIF dynamics (native SIMD-shaped backend; PJRT too when artifacts
//!    are present), per block size.
//!
//! Results feed the EXPERIMENTS.md §Perf before/after log and are written
//! to `BENCH_perf_hotpaths.json` at the repository root for the CI ±15%
//! regression gate (`scripts/check_bench_regression.py`).
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use nestgpu::comm::{CommWorld, Communicator, SpikeRecord};
use nestgpu::connection::Connections;
use nestgpu::engine::delivery::{merge_planes, DeliveryPlan, DeliveryQueue};
use nestgpu::memory::{MemKind, Tracker};
use nestgpu::node::neuron::LifParams;
use nestgpu::node::{NodeSpace, RingBuffers};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::remote::pair_map::PairMap;
use nestgpu::runtime::{native::NativeBackend, Backend, StateChunk};
use nestgpu::util::json::Json;
use nestgpu::util::rng::Rng;
use nestgpu::util::table::{fmt_secs, Table};

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn bench_sort(n_conns: usize, n_nodes: usize) -> (f64, f64) {
    let mut rng = Rng::new(7);
    let secs = time(3, || {
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        for _ in 0..n_conns {
            c.push(
                rng.below(n_nodes as u32),
                rng.below(n_nodes as u32),
                1.0,
                1,
                0,
                &mut tr,
            );
        }
        let t0 = Instant::now();
        c.sort_by_source(n_nodes, &mut tr);
        std::hint::black_box(t0.elapsed());
    });
    // measure the sort alone
    let mut tr = Tracker::new();
    let mut c = Connections::new();
    for _ in 0..n_conns {
        c.push(rng.below(n_nodes as u32), 0, 1.0, 1, 0, &mut tr);
    }
    let t0 = Instant::now();
    c.sort_by_source(n_nodes, &mut tr);
    let sort_only = t0.elapsed().as_secs_f64();
    (secs, n_conns as f64 / sort_only)
}

/// One high-fanout node delivering into the ring buffers: the naive
/// per-record path (LUT lookup + port branch + per-record slot math) vs
/// the prepared plan (port-baked runs through the slot-bucketed queue).
/// Returns (naive records/s, plan records/s).
fn bench_delivery(n_targets: usize, reps: usize) -> (f64, f64) {
    let n_state = 10_001u32;
    let mut tr = Tracker::new();
    let mut conns = Connections::new();
    let mut rng = Rng::new(3);
    for _ in 0..n_targets {
        conns.push(
            0,
            rng.below(10_000),
            1.0,
            1 + (rng.below(14) as u16),
            rng.below(2) as u8,
            &mut tr,
        );
    }
    conns.sort_by_source(n_state as usize, &mut tr);
    let mut nodes = NodeSpace::new();
    nodes.create_neurons(0, n_state);
    let lut: Vec<u32> = (0..n_state).collect();
    let plan = DeliveryPlan::build(&conns, &nodes, &lut, n_state, None);
    let mut rb = RingBuffers::new(n_state as usize, 16, &mut tr);
    let naive = time(reps, || {
        let v = conns.view(conns.outgoing(0));
        for i in 0..v.target.len() {
            rb.add(lut[v.target[i] as usize], v.port[i], v.delay[i], v.weight[i], 1);
        }
        rb.advance();
    });
    let mut q = DeliveryQueue::default();
    q.ensure_slots(rb.n_slots());
    let planned = time(reps, || {
        for run in plan.runs_of(0) {
            q.push(rb.slot_of(run.delay), run.start, run.end, 1);
        }
        q.drain_into(&mut rb, &plan);
        rb.advance();
    });
    (n_targets as f64 / naive, n_targets as f64 / planned)
}

/// Fused three-plane merge throughput in GB/s (3 plane reads + 1 store,
/// 4 bytes each).
fn bench_merge(n: usize, reps: usize) -> f64 {
    let mut rng = Rng::new(9);
    let mut mk = || (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect::<Vec<f32>>();
    let (local, remote, plastic) = (mk(), mk(), mk());
    let mut dst = vec![0.0f32; n];
    let secs = time(reps, || {
        merge_planes(&mut dst, &local, Some(&remote), Some(&plastic));
        std::hint::black_box(&dst);
    });
    (4.0 * 4.0 * n as f64) / secs / 1e9
}

fn bench_map_merge(map_size: usize, batch: usize) -> f64 {
    let mut tr = Tracker::new();
    let mut map = PairMap::new(MemKind::Device);
    let mut next = 0u32;
    let base: Vec<u32> = (0..map_size as u32).map(|i| i * 3).collect();
    map.ensure_images(&base, &mut tr, || {
        let v = next;
        next += 1;
        v
    });
    let news: Vec<u32> = (0..batch as u32).map(|i| i * 3 + 1).collect();
    time(20, || {
        let mut m2 = PairMap::new(MemKind::Device);
        let mut nx = 0u32;
        m2.ensure_images(&base, &mut tr, || {
            let v = nx;
            nx += 1;
            v
        });
        m2.ensure_images(&news, &mut tr, || {
            let v = nx;
            nx += 1;
            v
        });
    })
}

fn bench_exchange(packet_len: usize) -> f64 {
    let world = CommWorld::new(2);
    let mut comms = world.communicators();
    let c1 = comms.pop().unwrap();
    let mut c0 = comms.pop().unwrap();
    let handle = std::thread::spawn(move || {
        let mut c1 = c1;
        for _ in 0..201 {
            let out = vec![vec![], vec![]];
            let _ = c1.exchange(out);
        }
    });
    let pkt: Vec<SpikeRecord> = (0..packet_len as u32)
        .map(|i| SpikeRecord {
            pos: i,
            mult: 1,
            lag: 0,
        })
        .collect();
    let per_round = time(200, || {
        let out = vec![vec![], pkt.clone()];
        let _ = c0.exchange(out);
    });
    handle.join().unwrap();
    per_round
}

/// LIF dynamics throughput per block size, native backend (plus PJRT when
/// the AOT artifacts are present). Returns (label, block, neurons/s).
fn bench_backends(blocks: &[usize]) -> Vec<(String, usize, f64)> {
    let mut out = Vec::new();
    let params = LifParams::default().packed(0.1);
    let mut tr = Tracker::new();
    for &n in blocks {
        let mut chunk = StateChunk::new(n, params, &mut tr);
        let mut nat = NativeBackend::new();
        let t = time(50, || {
            nat.step(&mut chunk).unwrap();
        });
        out.push(("native".to_string(), n, n as f64 / t));
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let mut pjrt = nestgpu::runtime::pjrt::PjrtBackend::load(&dir).unwrap();
        for &n in blocks {
            let mut chunk = StateChunk::new(n, params, &mut tr);
            let t = time(50, || {
                pjrt.step(&mut chunk).unwrap();
            });
            out.push(("pjrt".to_string(), n, n as f64 / t));
        }
    } else {
        println!("(skipping PJRT backend bench: run `make artifacts`)");
    }
    out
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let mut t = Table::new("§Perf — coordinator hot paths", &["path", "metric", "value"]);
    let mut json = Vec::new();

    let (sort_n, sort_nodes) = if smoke {
        (200_000, 10_000)
    } else {
        (2_000_000, 100_000)
    };
    let (_, sort_rate) = bench_sort(sort_n, sort_nodes);
    t.row(vec![
        "connection sort-by-source".into(),
        "conns/s".into(),
        format!("{:.2e}", sort_rate),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("sort")),
        ("conns_per_s", Json::num(sort_rate)),
    ]));

    let fanout = 10_000usize;
    let (naive, planned) = bench_delivery(fanout, if smoke { 50 } else { 200 });
    let speedup = planned / naive;
    t.row(vec![
        "delivery naive (10k fanout)".into(),
        "records/s".into(),
        format!("{:.2e}", naive),
    ]);
    t.row(vec![
        "delivery plan  (10k fanout)".into(),
        "records/s".into(),
        format!("{:.2e} ({speedup:.2}x)", planned),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("delivery")),
        ("naive_records_per_s", Json::num(naive)),
        ("plan_records_per_s", Json::num(planned)),
        ("speedup", Json::num(speedup)),
    ]));

    let merge_n = if smoke { 262_144 } else { 1 << 20 };
    let merge_gbps = bench_merge(merge_n, if smoke { 20 } else { 50 });
    t.row(vec![
        format!("plane merge ({merge_n} f32)"),
        "GB/s".into(),
        format!("{merge_gbps:.1}"),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("plane_merge")),
        ("gb_per_s", Json::num(merge_gbps)),
    ]));

    let (map_n, map_b) = if smoke {
        (20_000, 2_000)
    } else {
        (100_000, 10_000)
    };
    let merge = bench_map_merge(map_n, map_b);
    t.row(vec![
        format!("map merge ({map_n} + {map_b})"),
        "s/call".into(),
        fmt_secs(merge),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("map_merge")),
        ("secs", Json::num(merge)),
    ]));

    let xch = bench_exchange(1_000);
    t.row(vec![
        "p2p exchange round (1k spikes)".into(),
        "s/round".into(),
        fmt_secs(xch),
    ]);
    json.push(Json::obj(vec![
        ("path", Json::str("exchange")),
        ("secs_per_round", Json::num(xch)),
    ]));

    let blocks: &[usize] = if smoke {
        &[1024, 8192]
    } else {
        &[1024, 8192, 65_536]
    };
    let mut lif = BTreeMap::new();
    for (name, n, rate) in bench_backends(blocks) {
        t.row(vec![
            format!("backend step {name} n={n}"),
            "neuron updates/s".into(),
            format!("{:.2e}", rate),
        ]);
        json.push(Json::obj(vec![
            ("path", Json::str(&format!("backend {name} n={n}"))),
            ("updates_per_s", Json::num(rate)),
        ]));
        lif.insert(
            format!("{name}_n{n}"),
            Json::obj(vec![("neurons_per_s", Json::num(rate))]),
        );
    }

    t.print();
    nestgpu::harness::experiments::write_result("perf_hotpaths", &Json::Arr(json));

    let fields = vec![
        ("smoke", Json::Bool(smoke)),
        (
            "delivery",
            Json::obj(vec![
                ("fanout", Json::num(fanout as f64)),
                ("naive_records_per_s", Json::num(naive)),
                ("plan_records_per_s", Json::num(planned)),
                ("speedup", Json::num(speedup)),
            ]),
        ),
        ("lif", Json::Obj(lif)),
        ("plane_merge", Json::obj(vec![("gb_per_s", Json::num(merge_gbps))])),
        ("sort", Json::obj(vec![("conns_per_s", Json::num(sort_rate))])),
    ];
    // at the repository root, stamped like the other BENCH files
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_perf_hotpaths.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
