//! Snapshot bench: construction cache vs full reconstruction.
//!
//! The paper's headline metric is network-construction time; the snapshot
//! subsystem converts it into a one-time cost. This bench measures, for a
//! mid-size balanced network, (1) full construction (Create/Connect/
//! RemoteConnect + preparation) and (2) restoring the same prepared state
//! from per-rank snapshot files — the target is a >= 10x reload speedup.
//!
//!     cargo bench --bench snapshot_reload

use std::time::Instant;

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::experiments::write_result;
use nestgpu::harness::{
    run_cluster_from_snapshot, run_cluster_with_snapshot, run_construction_only,
};
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, Table};

fn main() {
    let ranks = 2usize;
    let reps = 3usize;
    let bal = BalancedConfig {
        scale: 0.08,   // 900 neurons/rank
        k_scale: 0.08, // K_in = 900 -> ~810k connections/rank
        ..Default::default()
    };
    let cfg = SimConfig {
        record_spikes: false,
        ..Default::default()
    };
    let n_conns = bal.synapses_per_rank();
    println!(
        "snapshot_reload: {ranks} ranks x {} neurons, ~{n_conns} synapses/rank, best of {reps}",
        bal.neurons_per_rank()
    );
    let builder = {
        let bal = bal.clone();
        move |sim: &mut Simulator| build_balanced(sim, &bal)
    };

    // (1) full construction + preparation, from scratch
    let mut t_build = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        run_construction_only(ranks, &cfg, &builder).expect("construction run");
        t_build = t_build.min(t0.elapsed().as_secs_f64());
    }

    // (2) snapshot once, then restore repeatedly. The checkpointing run
    // pays construction *plus* the save, so the save cost is reported as
    // the overhead over the best plain-construction time.
    let dir = std::env::temp_dir().join(format!("nestgpu_snapshot_bench_{}", std::process::id()));
    let t0 = Instant::now();
    run_cluster_with_snapshot(ranks, &cfg, &builder, 0.0, &dir).expect("snapshot save");
    let t_construct_save = t0.elapsed().as_secs_f64();
    let t_save = (t_construct_save - t_build).max(0.0);
    let snap_bytes: u64 = (0..ranks)
        .map(|r| {
            std::fs::metadata(dir.join(nestgpu::snapshot::rank_file_name(r)))
                .map(|m| m.len())
                .unwrap_or(0)
        })
        .sum();
    let mut t_load = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        run_cluster_from_snapshot(&dir, 0.0).expect("snapshot restore");
        t_load = t_load.min(t0.elapsed().as_secs_f64());
    }
    let _ = std::fs::remove_dir_all(&dir);

    let speedup = t_build / t_load;
    let mut t = Table::new(
        "snapshot reload vs reconstruction",
        &["path", "time", "notes"],
    );
    t.row(vec![
        "construct (build+prepare)".into(),
        fmt_secs(t_build),
        format!("{ranks} ranks, ~{n_conns} conns/rank"),
    ]);
    t.row(vec![
        "construct + save".into(),
        fmt_secs(t_construct_save),
        format!(
            "save overhead ~{} for {:.1} MiB",
            fmt_secs(t_save),
            snap_bytes as f64 / (1024.0 * 1024.0)
        ),
    ]);
    t.row(vec![
        "snapshot restore".into(),
        fmt_secs(t_load),
        format!("{speedup:.1}x faster than reconstruction"),
    ]);
    t.print();
    println!(
        "snapshot reload speedup: {speedup:.1}x (target >= 10x: {})",
        if speedup >= 10.0 { "PASS" } else { "MISS" }
    );

    write_result(
        "snapshot_reload",
        &Json::obj(vec![
            ("ranks", Json::num(ranks as f64)),
            ("conns_per_rank", Json::num(n_conns as f64)),
            ("construct_s", Json::num(t_build)),
            ("construct_save_s", Json::num(t_construct_save)),
            ("save_overhead_s", Json::num(t_save)),
            ("restore_s", Json::num(t_load)),
            ("speedup", Json::num(speedup)),
            ("snapshot_bytes", Json::num(snap_bytes as f64)),
        ]),
    );
}
