//! Figs. 10–11 (Appendix C): the Fig. 6 construction breakdown repeated at
//! different network scales (paper: scale 10 and 30 vs the main text's 20;
//! here proportionally smaller workloads with the same 1:2:3 ratios).

use nestgpu::engine::SimConfig;
use nestgpu::harness::experiments::{balanced_weak_scaling, write_result};
use nestgpu::models::balanced::BalancedConfig;
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, Table};

const RANKS: [usize; 4] = [2, 4, 8, 16];
const MAX_LIVE: usize = 8;

fn main() {
    let mut all = Vec::new();
    for (fig, scale) in [("fig10 (scale 10)", 0.01), ("fig11 (scale 30)", 0.03)] {
        let bal = BalancedConfig {
            scale,
            k_scale: scale,
            ..Default::default()
        };
        let cfg = SimConfig::default();
        println!(
            "{fig}: {} neurons/rank, {} synapses/rank",
            bal.neurons_per_rank(),
            bal.synapses_per_rank()
        );
        let pts = balanced_weak_scaling(&RANKS, &ALL_LEVELS, &bal, &cfg, MAX_LIVE, 1, 2, 0.0);
        let mut t = Table::new(
            &format!("{fig} — creation+connection / preparation vs ranks"),
            &["ranks", "level", "creation+conn", "preparation", "mode"],
        );
        for p in &pts {
            t.row(vec![
                p.virtual_ranks.to_string(),
                p.level.name().into(),
                fmt_secs(p.agg.creation_and_connection_s),
                fmt_secs(p.agg.preparation_s),
                if p.estimated { "estimated".into() } else { "simulated".into() },
            ]);
            all.push(Json::obj(vec![
                ("figure", Json::str(fig)),
                ("ranks", Json::num(p.virtual_ranks as f64)),
                ("level", Json::str(p.level.name())),
                (
                    "creation_and_connection_s",
                    Json::num(p.agg.creation_and_connection_s),
                ),
                ("preparation_s", Json::num(p.agg.preparation_s)),
                ("estimated", Json::Bool(p.estimated)),
            ]));
        }
        t.print();
        println!();
        let _ = GpuMemLevel::L0;
    }
    println!("paper shape check: times scale ~linearly with the scale parameter");
    write_result("fig10_11", &Json::Arr(all));
}
