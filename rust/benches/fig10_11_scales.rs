//! Figs. 10–11 (Appendix C): the Fig. 6 construction breakdown repeated at
//! different network scales (paper: scale 10 and 30 vs the main text's 20;
//! here proportionally smaller workloads with the same 1:2:3 ratios), plus
//! the per-scale communication volume of a short live propagation window
//! (batched min-delay exchange).

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::experiments::{aggregate, balanced_weak_scaling, write_result};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, fmt_secs, Table};

const RANKS: [usize; 4] = [2, 4, 8, 16];
const MAX_LIVE: usize = 8;
/// live window for the communication-volume measurement
const COMM_T_MS: f64 = 25.0;

fn main() {
    let mut all = Vec::new();
    for (fig, scale) in [("fig10 (scale 10)", 0.01), ("fig11 (scale 30)", 0.03)] {
        let bal = BalancedConfig {
            scale,
            k_scale: scale,
            ..Default::default()
        };
        let cfg = SimConfig::default();
        println!(
            "{fig}: {} neurons/rank, {} synapses/rank",
            bal.neurons_per_rank(),
            bal.synapses_per_rank()
        );
        let pts = balanced_weak_scaling(&RANKS, &ALL_LEVELS, &bal, &cfg, MAX_LIVE, 1, 2, 0.0);
        let mut t = Table::new(
            &format!("{fig} — creation+connection / preparation vs ranks"),
            &["ranks", "level", "creation+conn", "preparation", "mode"],
        );
        for p in &pts {
            t.row(vec![
                p.virtual_ranks.to_string(),
                p.level.name().into(),
                fmt_secs(p.agg.creation_and_connection_s),
                fmt_secs(p.agg.preparation_s),
                if p.estimated { "estimated".into() } else { "simulated".into() },
            ]);
            all.push(Json::obj(vec![
                ("figure", Json::str(fig)),
                ("ranks", Json::num(p.virtual_ranks as f64)),
                ("level", Json::str(p.level.name())),
                (
                    "creation_and_connection_s",
                    Json::num(p.agg.creation_and_connection_s),
                ),
                ("preparation_s", Json::num(p.agg.preparation_s)),
                ("estimated", Json::Bool(p.estimated)),
            ]));
        }
        t.print();

        // communication volume: one short live window per world size
        let mut tv = Table::new(
            &format!("{fig} — communication volume ({COMM_T_MS} ms live, mean/rank)"),
            &["ranks", "xchg interval", "p2p msgs", "p2p bytes", "coll calls", "coll bytes"],
        );
        for &vr in RANKS.iter().filter(|&&v| v <= MAX_LIVE) {
            let b = bal.clone();
            let runs = run_cluster(
                vr,
                &cfg,
                &move |sim: &mut Simulator| build_balanced(sim, &b),
                COMM_T_MS,
            )
            .expect("live comm-volume run");
            let agg = aggregate(&[runs]);
            tv.row(vec![
                vr.to_string(),
                format!("{:.0}", agg.exchange_interval),
                format!("{:.0}", agg.p2p_messages),
                fmt_bytes(agg.p2p_bytes as u64),
                format!("{:.0}", agg.coll_calls),
                fmt_bytes(agg.coll_bytes as u64),
            ]);
            all.push(Json::obj(vec![
                ("figure", Json::str(fig)),
                ("ranks", Json::num(vr as f64)),
                ("comm_t_ms", Json::num(COMM_T_MS)),
                ("comm", agg.to_json()),
            ]));
        }
        tv.print();
        println!();
        let _ = GpuMemLevel::L0;
    }
    println!("paper shape check: times scale ~linearly with the scale parameter");
    write_result("fig10_11", &Json::Arr(all));
}
