//! Procedural connectivity: per-rank connectivity memory and throughput,
//! procedural vs materialized, on the identical balanced network.
//!
//! The procedural mode (DESIGN.md §16) keeps static connectivity as
//! compact connect-call descriptors and regenerates each spiking neuron's
//! fanout from captured RNG state at delivery time, trading construction
//! memory for a bounded regeneration cost. This bench measures both sides
//! of that trade: the connectivity-state bytes per rank (`conn_bytes`:
//! materialized store + delivery plan, or descriptor store + fanout-cache
//! residency) and steps/s, and writes `BENCH_procedural.json` at the
//! repository root. The full-size run asserts the >= 5x memory-reduction
//! acceptance bar; the ratio is size-dependent (the fanout cache has a
//! 64 KiB floor that dominates at toy scale), so the CI smoke run only
//! records it.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;

use nestgpu::connection::Connectivity;
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, Table};

struct Point {
    label: &'static str,
    steps_per_s: f64,
    /// connectivity-state bytes, max over ranks
    conn_bytes: u64,
    /// tracker device peak, max over ranks
    device_peak: u64,
    n_connections: u64,
    construction_s: f64,
}

fn measure(
    label: &'static str,
    mode: Connectivity,
    ranks: usize,
    t_ms: f64,
    scale: f64,
) -> Point {
    let cfg = SimConfig {
        record_spikes: false, // benchmarking runs, as in the paper
        connectivity: mode,
        ..Default::default()
    };
    let bal = BalancedConfig {
        scale,
        k_scale: scale,
        ..Default::default()
    };
    let results: Vec<SimResult> = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .expect("bench run");
    let steps = (t_ms / cfg.dt_ms).round();
    let prop_s = results
        .iter()
        .map(|r| r.phases.propagation.as_secs_f64())
        .fold(0.0, f64::max)
        .max(1e-9);
    Point {
        label,
        steps_per_s: steps / prop_s,
        conn_bytes: results.iter().map(|r| r.conn_bytes).max().unwrap_or(0),
        device_peak: results.iter().map(|r| r.device_peak).max().unwrap_or(0),
        n_connections: results.iter().map(|r| r.n_connections).sum(),
        construction_s: results
            .iter()
            .map(|r| r.phases.construction().as_secs_f64())
            .fold(0.0, f64::max),
    }
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let ranks = 2usize;
    let t_ms = if smoke { 50.0 } else { 200.0 };
    let scale = if smoke { 0.01 } else { 0.04 };

    let mat = measure("materialized", Connectivity::Materialized, ranks, t_ms, scale);
    let proc_ = measure("procedural", Connectivity::Procedural, ranks, t_ms, scale);
    println!(
        "balanced, {ranks} ranks, {t_ms} ms, scale {scale}{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut t = Table::new(
        "procedural connectivity: memory and throughput vs materialized",
        &["mode", "steps/s", "conn bytes/rank", "dev peak/rank", "conns", "constr s"],
    );
    for p in [&mat, &proc_] {
        t.row(vec![
            p.label.to_string(),
            format!("{:.0}", p.steps_per_s),
            fmt_bytes(p.conn_bytes),
            fmt_bytes(p.device_peak),
            p.n_connections.to_string(),
            format!("{:.3}", p.construction_s),
        ]);
    }
    t.print();

    // the same network must exist in both modes (the spike-hash identity
    // itself is asserted by tests/it_procedural.rs and the CI launch smoke)
    assert_eq!(
        mat.n_connections, proc_.n_connections,
        "procedural run must describe the same connection count"
    );

    let mem_ratio = mat.conn_bytes as f64 / proc_.conn_bytes.max(1) as f64;
    let slowdown = mat.steps_per_s / proc_.steps_per_s.max(1e-9);
    println!(
        "\nconnectivity memory: {} -> {} per rank ({mem_ratio:.1}x lower); \
         throughput: {slowdown:.2}x slowdown",
        fmt_bytes(mat.conn_bytes),
        fmt_bytes(proc_.conn_bytes),
    );
    // acceptance bar (full size only: the cache's 64 KiB floor dominates
    // the toy smoke network, see module docs)
    if !smoke {
        assert!(
            mem_ratio >= 5.0,
            "procedural mode must cut per-rank connectivity memory >= 5x \
             (got {mem_ratio:.1}x)"
        );
    }

    let fields = vec![
        ("model", Json::str("balanced-procedural")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        ("scale", Json::num(scale)),
        ("smoke", Json::Bool(smoke)),
        ("materialized_steps_per_s", Json::num(mat.steps_per_s)),
        ("procedural_steps_per_s", Json::num(proc_.steps_per_s)),
        // tracked lower-is-better by check_bench_regression.py
        ("overhead_ratio", Json::num(slowdown)),
        ("conn_bytes_materialized", Json::num(mat.conn_bytes as f64)),
        ("conn_bytes_procedural", Json::num(proc_.conn_bytes as f64)),
        ("conn_mem_ratio", Json::num(mem_ratio)),
        ("device_peak_materialized", Json::num(mat.device_peak as f64)),
        ("device_peak_procedural", Json::num(proc_.device_peak as f64)),
        ("construction_s_materialized", Json::num(mat.construction_s)),
        ("construction_s_procedural", Json::num(proc_.construction_s)),
    ];
    // at the repository root (one directory above the rust package);
    // stamped with schema version / timestamp / git revision (obs::stamp)
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_procedural.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
