//! Fig. 12 (Appendix D): balanced network with the `in_degree_scale`
//! parameter — fewer neurons per rank, proportionally higher in-degree,
//! constant synapse count and constant total input (weights divided by the
//! in-degree scale). GPU memory level 0, as in the paper.
//!
//! Expected shape: node creation and simulation preparation times
//! *decrease* with in_degree_scale (fewer neurons ⇒ fewer image nodes ⇒
//! smaller maps to build and sort).

use nestgpu::engine::SimConfig;
use nestgpu::harness::experiments::{balanced_weak_scaling, write_result};
use nestgpu::models::balanced::BalancedConfig;
use nestgpu::remote::levels::GpuMemLevel;
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, Table};

const RANKS: [usize; 3] = [2, 4, 8];
const IDS: [f64; 4] = [1.0, 2.0, 5.0, 10.0];

fn main() {
    let mut t = Table::new(
        "Fig. 12 — in-degree scale sweep (GPU memory level 0)",
        &[
            "ids",
            "ranks",
            "neurons/rank",
            "K_in",
            "creation+conn",
            "preparation",
        ],
    );
    let mut rows = Vec::new();
    for &ids in &IDS {
        let bal = BalancedConfig {
            scale: 0.02,
            k_scale: 0.02,
            in_degree_scale: ids,
            ..Default::default()
        };
        let cfg = SimConfig {
            level: GpuMemLevel::L0,
            ..Default::default()
        };
        let pts = balanced_weak_scaling(
            &RANKS,
            &[GpuMemLevel::L0],
            &bal,
            &cfg,
            8,
            1,
            2,
            0.0,
        );
        for p in &pts {
            t.row(vec![
                format!("{ids}"),
                p.virtual_ranks.to_string(),
                bal.neurons_per_rank().to_string(),
                (bal.kin_e() + bal.kin_i()).to_string(),
                fmt_secs(p.agg.creation_and_connection_s),
                fmt_secs(p.agg.preparation_s),
            ]);
            rows.push(Json::obj(vec![
                ("in_degree_scale", Json::num(ids)),
                ("ranks", Json::num(p.virtual_ranks as f64)),
                (
                    "creation_and_connection_s",
                    Json::num(p.agg.creation_and_connection_s),
                ),
                ("preparation_s", Json::num(p.agg.preparation_s)),
                ("synapses_per_rank", Json::num(bal.synapses_per_rank() as f64)),
            ]));
        }
    }
    t.print();
    println!(
        "\npaper shape check: synapses/rank constant across ids; creation and \
         preparation times shrink as in_degree_scale grows"
    );
    write_result("fig12", &Json::Arr(rows));
}
