//! Fig. 4: weak scaling of the balanced network — network construction (a)
//! and state propagation RTF (b) vs number of nodes, for all four GPU
//! memory levels, plus level 3 with spike recording disabled.
//!
//! The paper runs 32–256 Leonardo nodes (128–1024 GPUs) at scale 20; here
//! the workload is scaled down and worlds above MAX_LIVE ranks use the
//! paper's estimation methodology (construction/preparation only).
//! Expected shape: higher levels construct faster and propagate faster;
//! disabling recording cuts ~20% of propagation.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::experiments::{aggregate, balanced_weak_scaling, write_result};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, fmt_secs, Table};

const RANKS: [usize; 5] = [2, 4, 8, 16, 32];
const MAX_LIVE: usize = 8;
const T_MS: f64 = 50.0;

fn bal() -> BalancedConfig {
    BalancedConfig {
        scale: 0.02,
        k_scale: 0.02,
        ..Default::default()
    }
}

fn main() {
    let cfg = SimConfig {
        record_spikes: true,
        ..Default::default()
    };
    println!(
        "balanced network, scale {:.3} ({} neurons/rank), live up to {MAX_LIVE} ranks\n",
        bal().scale,
        bal().neurons_per_rank()
    );
    let pts = balanced_weak_scaling(&RANKS, &ALL_LEVELS, &bal(), &cfg, MAX_LIVE, 2, 2, T_MS);

    let mut ta = Table::new(
        "Fig. 4a — network construction time vs ranks",
        &["ranks", "level0", "level1", "level2", "level3", "mode"],
    );
    for &vr in &RANKS {
        let cell = |lvl: GpuMemLevel| {
            pts.iter()
                .find(|p| p.virtual_ranks == vr && p.level == lvl)
                .map(|p| fmt_secs(p.agg.construction_s))
                .unwrap_or_default()
        };
        let est = pts
            .iter()
            .find(|p| p.virtual_ranks == vr)
            .map(|p| p.estimated)
            .unwrap_or(false);
        ta.row(vec![
            vr.to_string(),
            cell(GpuMemLevel::L0),
            cell(GpuMemLevel::L1),
            cell(GpuMemLevel::L2),
            cell(GpuMemLevel::L3),
            if est { "estimated".into() } else { "simulated".into() },
        ]);
    }
    ta.print();

    // Fig. 4b: RTF (live runs only) + level 3 without recording
    let mut tb = Table::new(
        "Fig. 4b — state propagation (RTF) vs ranks (live runs)",
        &["ranks", "level0", "level1", "level2", "level3", "L3 no-rec"],
    );
    for &vr in RANKS.iter().filter(|&&v| v <= MAX_LIVE) {
        let cell = |lvl: GpuMemLevel| {
            pts.iter()
                .find(|p| p.virtual_ranks == vr && p.level == lvl)
                .map(|p| format!("{:.2}", p.agg.rtf))
                .unwrap_or_default()
        };
        // level 3 with recording disabled
        let mut cfg_norec = cfg.clone();
        cfg_norec.record_spikes = false;
        cfg_norec.level = GpuMemLevel::L3;
        let b = bal();
        let norec = run_cluster(
            vr,
            &cfg_norec,
            &move |sim: &mut Simulator| build_balanced(sim, &b),
            T_MS,
        )
        .expect("no-rec run");
        let norec_agg = aggregate(&[norec]);
        tb.row(vec![
            vr.to_string(),
            cell(GpuMemLevel::L0),
            cell(GpuMemLevel::L1),
            cell(GpuMemLevel::L2),
            cell(GpuMemLevel::L3),
            format!("{:.2}", norec_agg.rtf),
        ]);
    }
    tb.print();

    // communication volume of the live runs (batched exchange: one
    // all-to-all / allgather round per min-delay interval, §DESIGN 11)
    let mut tc = Table::new(
        "Fig. 4 — communication volume (live runs, mean per rank, level 2)",
        &["ranks", "xchg interval", "p2p msgs", "p2p bytes", "coll calls", "coll bytes"],
    );
    for &vr in RANKS.iter().filter(|&&v| v <= MAX_LIVE) {
        if let Some(p) = pts
            .iter()
            .find(|p| p.virtual_ranks == vr && p.level == GpuMemLevel::L2)
        {
            tc.row(vec![
                vr.to_string(),
                format!("{:.0}", p.agg.exchange_interval),
                format!("{:.0}", p.agg.p2p_messages),
                fmt_bytes(p.agg.p2p_bytes as u64),
                format!("{:.0}", p.agg.coll_calls),
                fmt_bytes(p.agg.coll_bytes as u64),
            ]);
        }
    }
    tc.print();
    println!("\npaper shape check: higher levels faster; no-recording ~20% faster RTF");

    let rows: Vec<Json> = pts
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("ranks", Json::num(p.virtual_ranks as f64)),
                ("level", Json::str(p.level.name())),
                ("estimated", Json::Bool(p.estimated)),
                ("agg", p.agg.to_json()),
            ])
        })
        .collect();
    write_result("fig4", &Json::Arr(rows));
}
