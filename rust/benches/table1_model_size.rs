//! Table 1: scalable balanced network model size as a function of the
//! number of compute nodes (scale 20, 4 GPUs per node, K_in = 11,250).
//!
//! Regenerates the paper's rows exactly (these are analytic — the paper's
//! table documents the weak-scaling workload, not a measurement).

use nestgpu::memory::model::table1_row;
use nestgpu::util::json::Json;
use nestgpu::util::table::Table;

fn main() {
    let nodes = [32u64, 64, 96, 128, 192, 256];
    let mut t = Table::new(
        "Table 1 — balanced network size vs compute nodes (scale = 20)",
        &["Nodes", "GPUs", "Neurons (x1e6)", "Synapses (x1e12)"],
    );
    let mut rows = Vec::new();
    for &n in &nodes {
        let (nodes, gpus, neurons, synapses) = table1_row(n, 4, 20.0);
        t.row(vec![
            nodes.to_string(),
            gpus.to_string(),
            format!("{:.1}", neurons as f64 / 1e6),
            format!("{:.2}", synapses as f64 / 1e12),
        ]);
        rows.push(Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("gpus", Json::num(gpus as f64)),
            ("neurons", Json::num(neurons as f64)),
            ("synapses", Json::num(synapses as f64)),
        ]));
    }
    t.print();
    println!(
        "paper check: 32 nodes -> 28.8e6 neurons / 0.32e12 synapses; \
         256 nodes -> 230.4e6 / 2.59e12"
    );
    nestgpu::harness::experiments::write_result("table1", &Json::Arr(rows));
}
