//! STDP overhead: the plastic balanced network (trace-based STDP on the
//! recurrent excitatory synapses) vs. the identical static network.
//!
//! Plasticity adds two pipeline phases (pre_update / post_update), an
//! arrival event ring and a third accumulation plane (DESIGN.md §12); the
//! acceptance bar is plastic-run throughput within 2× of the static
//! baseline. Reports steps/s for both runs plus the per-phase plasticity
//! cost, and writes `BENCH_stdp_overhead.json` at the repository root.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::util::json::Json;
use nestgpu::util::table::Table;

struct Point {
    label: &'static str,
    steps_per_s: f64,
    n_plastic: u64,
    pre_update_s: f64,
    post_update_s: f64,
    weight_sd: f64,
}

fn measure(
    label: &'static str,
    stdp: Option<StdpScenario>,
    ranks: usize,
    t_ms: f64,
    scale: f64,
) -> Point {
    let cfg = SimConfig {
        record_spikes: false, // benchmarking runs, as in the paper
        ..Default::default()
    };
    let bal = BalancedConfig {
        scale,
        k_scale: 0.01,
        stdp,
        ..Default::default()
    };
    let results: Vec<SimResult> = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )
    .expect("bench run");
    let steps = (t_ms / cfg.dt_ms).round();
    let prop_s = results
        .iter()
        .map(|r| r.phases.propagation.as_secs_f64())
        .fold(0.0, f64::max)
        .max(1e-9);
    Point {
        label,
        steps_per_s: steps / prop_s,
        n_plastic: results.iter().map(|r| r.n_plastic).sum(),
        pre_update_s: results
            .iter()
            .map(|r| r.step_phases.pre_update.as_secs_f64())
            .sum(),
        post_update_s: results
            .iter()
            .map(|r| r.step_phases.post_update.as_secs_f64())
            .sum(),
        weight_sd: results
            .iter()
            .filter_map(|r| r.plastic.map(|p| p.sd))
            .fold(0.0, f64::max),
    }
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let ranks = 2usize;
    let t_ms = if smoke { 50.0 } else { 200.0 };
    let scale = if smoke { 0.01 } else { 0.05 };

    let stat = measure("static", None, ranks, t_ms, scale);
    let plast = measure(
        "stdp (additive)",
        Some(StdpScenario::default()),
        ranks,
        t_ms,
        scale,
    );
    println!(
        "balanced, {ranks} ranks, {t_ms} ms, scale {scale}{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut t = Table::new(
        "STDP overhead: static vs plastic balanced network",
        &["config", "steps/s", "plastic syn", "pre_update s", "post_update s", "weight sd"],
    );
    for p in [&stat, &plast] {
        t.row(vec![
            p.label.to_string(),
            format!("{:.0}", p.steps_per_s),
            p.n_plastic.to_string(),
            format!("{:.3}", p.pre_update_s),
            format!("{:.3}", p.post_update_s),
            format!("{:.2}", p.weight_sd),
        ]);
    }
    t.print();

    let ratio = stat.steps_per_s / plast.steps_per_s.max(1e-9);
    println!(
        "\nplastic-run slowdown: {ratio:.2}x (acceptance bar: within 2x of the \
         static baseline)"
    );
    assert!(plast.n_plastic > 0, "plastic run must carry plastic synapses");
    assert!(
        plast.weight_sd > 0.0,
        "STDP must actually move the weights during the bench"
    );
    // the 2x acceptance bar is asserted only on the full-size run: the
    // CI smoke configuration measures milliseconds of wall clock, where
    // shared-runner scheduling jitter alone can cross the threshold (the
    // smoke JSON still records `within_2x` for the trajectory)
    if !smoke {
        assert!(
            ratio < 2.0,
            "plastic run is {ratio:.2}x slower than static (bar: < 2x)"
        );
    }

    let fields = vec![
        ("model", Json::str("balanced-stdp")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        ("scale", Json::num(scale)),
        ("smoke", Json::Bool(smoke)),
        ("static_steps_per_s", Json::num(stat.steps_per_s)),
        ("plastic_steps_per_s", Json::num(plast.steps_per_s)),
        ("overhead_ratio", Json::num(ratio)),
        ("within_2x", Json::Bool(ratio < 2.0)),
        ("n_plastic", Json::num(plast.n_plastic as f64)),
        ("pre_update_s", Json::num(plast.pre_update_s)),
        ("post_update_s", Json::num(plast.post_update_s)),
        ("weight_sd", Json::num(plast.weight_sd)),
    ];
    // at the repository root (one directory above the rust package);
    // stamped with schema version / timestamp / git revision, and
    // refuses to clobber a newer-schema file (obs::stamp)
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_stdp_overhead.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
