//! Fig. 9 (Appendix B): MAM area packing on 2–32 ranks.
//!
//! (a) absolute wall-clock of construction + propagation, (b) RTF,
//! (c) construction breakdown — as the 32 areas are packed onto fewer
//! GPUs by the knapsack algorithm of §0.4.1.
//!
//! Expected shape: time-to-solution grows as fewer ranks host more areas;
//! RTF plateaus once communication dominates; packing imbalance stays low.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::experiments::{aggregate, write_result};
use nestgpu::harness::run_cluster;
use nestgpu::models::mam::{MamConfig, MamModel};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, Table};

const RANK_COUNTS: [usize; 5] = [2, 4, 8, 16, 32];
const T_MS: f64 = 100.0;

fn mam() -> MamModel {
    MamModel::new(MamConfig {
        n_scale: 0.001,
        k_scale: 0.01,
        chi: 1.9,
        kcc_base: 1500.0,
    })
}

fn main() {
    let m0 = mam();
    let mut t = Table::new(
        "Fig. 9 — MAM with area packing",
        &[
            "ranks",
            "areas/rank",
            "imbalance",
            "construction",
            "propagation",
            "RTF",
        ],
    );
    let mut rows = Vec::new();
    for &ranks in &RANK_COUNTS {
        let packing = m0.pack(ranks);
        let imb = packing.imbalance(&m0.packing_weights());
        let cfg = SimConfig {
            record_spikes: false,
            ..Default::default()
        };
        let builder = move |sim: &mut Simulator| {
            let m = mam();
            let p = m.pack(sim.n_ranks());
            m.build(sim, &p);
        };
        let results = run_cluster(ranks, &cfg, &builder, T_MS).expect("mam run");
        let agg = aggregate(&[results]);
        t.row(vec![
            ranks.to_string(),
            format!("{:.1}", 32.0 / ranks as f64),
            format!("{imb:.2}"),
            fmt_secs(agg.construction_s),
            fmt_secs(agg.rtf * T_MS / 1e3),
            format!("{:.2}", agg.rtf),
        ]);
        rows.push(Json::obj(vec![
            ("ranks", Json::num(ranks as f64)),
            ("imbalance", Json::num(imb)),
            ("construction_s", Json::num(agg.construction_s)),
            ("rtf", Json::num(agg.rtf)),
        ]));
    }
    t.print();
    println!(
        "\npaper shape check: the model runs down to 2 ranks with longer \
         time-to-solution; RTF comparable from ~8 ranks on (plateau)"
    );
    write_result("fig9", &Json::Arr(rows));
}
