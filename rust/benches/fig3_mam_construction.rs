//! Fig. 3: offboard vs onboard construction of the Multi-Area Model.
//!
//! Panel (a): network-construction time split into its subtasks
//! (initialization, neuron+device creation, local connection, remote
//! connection, simulation preparation) for both construction methods.
//! Panel (b): state propagation as real-time factor (box statistics over
//! seeds).
//!
//! Paper reference (32 V100s, natural density): offboard 686 s vs onboard
//! 55.5 s (>10x), with local/remote connection speedups of 20x/9x and
//! comparable RTF (~16 vs ~15). Our substrate is a simulated device on one
//! CPU, so absolute numbers differ; the comparison *shape* (onboard wins
//! construction, RTF unchanged) is the reproduction target.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::experiments::{aggregate, write_result};
use nestgpu::harness::run_cluster;
use nestgpu::models::mam::{MamConfig, MamModel};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, mean_std, median_iqr, Table};

const RANKS: usize = 8;
const SEEDS: u64 = 3;
const T_MS: f64 = 50.0;

fn mam() -> MamModel {
    MamModel::new(MamConfig {
        n_scale: 0.003,
        k_scale: 0.04,
        chi: 1.9,
        kcc_base: 1500.0,
    })
}

fn run(offboard: bool) -> (nestgpu::harness::experiments::Agg, Vec<f64>) {
    let mut runs = Vec::new();
    let mut rtfs = Vec::new();
    for seed in 0..SEEDS {
        let cfg = SimConfig {
            seed: 1000 + seed,
            offboard,
            record_spikes: false,
            ..Default::default()
        };
        let builder = move |sim: &mut Simulator| {
            let m = mam();
            let packing = m.pack(RANKS);
            m.build(sim, &packing);
        };
        let results = run_cluster(RANKS, &cfg, &builder, T_MS).expect("mam run");
        rtfs.extend(results.iter().map(|r| r.rtf));
        runs.push(results);
    }
    (aggregate(&runs), rtfs)
}

fn main() {
    println!("MAM: 32 areas packed on {RANKS} ranks, {SEEDS} seeds, T={T_MS} ms\n");
    let (off, off_rtf) = run(true);
    let (on, on_rtf) = run(false);

    let mut t = Table::new(
        "Fig. 3a — construction time by subtask (mean over ranks & seeds)",
        &["subtask", "offboard", "onboard", "speedup"],
    );
    let row = |name: &str, a: f64, b: f64| {
        vec![
            name.to_string(),
            fmt_secs(a),
            fmt_secs(b),
            format!("{:.1}x", a / b.max(1e-9)),
        ]
    };
    t.row(row("neuron+device creation", off.node_creation_s, on.node_creation_s));
    t.row(row("local connection", off.local_conn_s, on.local_conn_s));
    t.row(row("remote connection", off.remote_conn_s, on.remote_conn_s));
    t.row(row("simulation preparation", off.preparation_s, on.preparation_s));
    t.row(row("TOTAL construction", off.construction_s, on.construction_s));
    t.print();

    let (off_mean, off_sd) = mean_std(&off_rtf);
    let (on_mean, on_sd) = mean_std(&on_rtf);
    let (off_med, _, _) = median_iqr(&off_rtf);
    let (on_med, _, _) = median_iqr(&on_rtf);
    let mut t2 = Table::new(
        "Fig. 3b — state propagation (real-time factor)",
        &["version", "mean", "sd", "median"],
    );
    t2.row(vec![
        "offboard".into(),
        format!("{off_mean:.2}"),
        format!("{off_sd:.2}"),
        format!("{off_med:.2}"),
    ]);
    t2.row(vec![
        "onboard".into(),
        format!("{on_mean:.2}"),
        format!("{on_sd:.2}"),
        format!("{on_med:.2}"),
    ]);
    t2.print();
    println!(
        "\npaper shape check: onboard construction {:.1}x faster; RTF ratio {:.2} (expect ~1)",
        off.construction_s / on.construction_s.max(1e-9),
        off_mean / on_mean.max(1e-9)
    );

    write_result(
        "fig3",
        &Json::obj(vec![
            ("offboard", off.to_json()),
            ("onboard", on.to_json()),
            ("offboard_rtf", Json::arr_f64(&off_rtf)),
            ("onboard_rtf", Json::arr_f64(&on_rtf)),
        ]),
    );
}
