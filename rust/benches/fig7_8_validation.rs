//! Figs. 7–8 (Appendix A): statistical validation of the onboard
//! construction method against the offboard baseline on the cortical
//! microcircuit.
//!
//! Three sets of runs (two offboard with different seeds, one onboard):
//! for each the per-population distributions of firing rate, CV ISI and
//! pairwise Pearson correlation are computed; Fig. 8 compares the pairwise
//! EMD between code paths against the EMD between seeds — compatible when
//! the code-vs-code distances fall within the seed-vs-seed spread.

use nestgpu::connection::{ConnRule, NodeSet, SynSpec};
use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_single;
use nestgpu::models::microcircuit::{Microcircuit, BG_RATE_HZ};
use nestgpu::node::LifParams;
use nestgpu::stats::validate::{StatDistributions, ValidationReport};
use nestgpu::stats::SpikeData;
use nestgpu::util::json::Json;
use nestgpu::util::table::{mean_std, median_iqr, Table};

const SEEDS_PER_SET: u64 = 4;
const T_MS: f64 = 500.0;

fn build_microcircuit(sim: &mut Simulator, mc: &Microcircuit) {
    let sizes = mc.sizes();
    let params = LifParams::default();
    let mut bases = [0u32; 8];
    for p in 0..8 {
        let set = sim.create_neurons(sizes[p], &params);
        if let NodeSet::Range { start, .. } = set {
            bases[p] = start;
        }
    }
    for p in 0..8 {
        let gen = sim.create_poisson(mc.k_ext(p) as f64 * BG_RATE_HZ);
        sim.connect(
            &gen,
            &NodeSet::range(bases[p], sizes[p]),
            &ConnRule::AllToAll,
            &SynSpec::new(mc.weight_ext(), 1),
        );
    }
    for t in 0..8 {
        for s in 0..8 {
            let k = mc.indegree(t, s);
            if k == 0 {
                continue;
            }
            sim.connect(
                &NodeSet::range(bases[s], sizes[s]),
                &NodeSet::range(bases[t], sizes[t]),
                &ConnRule::FixedIndegree { k },
                &SynSpec::new(mc.weight(t, s), mc.delay_steps(s, 0.1) as u32),
            );
        }
    }
}

fn run_set(offboard: bool, seed0: u64) -> Vec<StatDistributions> {
    let mc = Microcircuit::new(0.02, 0.02);
    let n_total = mc.total_neurons() as u32;
    (0..SEEDS_PER_SET)
        .map(|i| {
            let cfg = SimConfig {
                seed: seed0 + i,
                offboard,
                record_spikes: true,
                ..Default::default()
            };
            let r = run_single(
                &cfg,
                &|sim: &mut Simulator| build_microcircuit(sim, &Microcircuit::new(0.02, 0.02)),
                T_MS,
            )
            .expect("microcircuit run");
            let data = SpikeData::from_events(&r.spikes, 0, n_total, (T_MS / 0.1) as u32, 0.1);
            StatDistributions::from_spikes(&data, 200, 2.0)
        })
        .collect()
}

fn main() {
    println!(
        "microcircuit (2% scale), {SEEDS_PER_SET} seeds per set, T={T_MS} ms\n"
    );
    let ref_a = run_set(true, 100);
    let ref_b = run_set(true, 200);
    let new = run_set(false, 300);

    // Fig. 7: population statistics summary (first set of each code path)
    let mut t7 = Table::new(
        "Fig. 7 — distribution summaries (offboard vs onboard)",
        &["statistic", "offboard mean", "onboard mean"],
    );
    let m = |xs: &Vec<f64>| mean_std(xs).0;
    t7.row(vec![
        "firing rate (sp/s)".into(),
        format!("{:.2}", m(&ref_a[0].rates)),
        format!("{:.2}", m(&new[0].rates)),
    ]);
    t7.row(vec![
        "CV ISI".into(),
        format!("{:.3}", m(&ref_a[0].cv_isi)),
        format!("{:.3}", m(&new[0].cv_isi)),
    ]);
    t7.row(vec![
        "Pearson correlation".into(),
        format!("{:.4}", m(&ref_a[0].correlations)),
        format!("{:.4}", m(&new[0].correlations)),
    ]);
    t7.print();

    // Fig. 8: EMD box comparison
    let report = ValidationReport::build(&ref_a, &ref_b, &new);
    let mut t8 = Table::new(
        "Fig. 8 — EMD: code-vs-code vs seed-vs-seed (median)",
        &["statistic", "code-vs-code", "seed-vs-seed", "compatible"],
    );
    let emd_row = |name: &str, c: &nestgpu::stats::validate::EmdComparison| {
        vec![
            name.to_string(),
            format!("{:.4}", median_iqr(&c.cross_code).0),
            format!("{:.4}", median_iqr(&c.cross_seed).0),
            format!("{}", c.compatible(2.0)),
        ]
    };
    t8.row(emd_row("firing rate", &report.rates));
    t8.row(emd_row("CV ISI", &report.cv_isi));
    t8.row(emd_row("correlation", &report.correlations));
    t8.print();
    println!(
        "\npaper check: onboard adds no variability beyond seed changes -> all compatible: {}",
        report.all_compatible(2.0)
    );

    write_result_json(&report);
}

fn write_result_json(report: &ValidationReport) {
    let cmp = |c: &nestgpu::stats::validate::EmdComparison| {
        Json::obj(vec![
            ("cross_code", Json::arr_f64(&c.cross_code)),
            ("cross_seed", Json::arr_f64(&c.cross_seed)),
        ])
    };
    nestgpu::harness::experiments::write_result(
        "fig7_8",
        &Json::obj(vec![
            ("rates", cmp(&report.rates)),
            ("cv_isi", cmp(&report.cv_isi)),
            ("correlations", cmp(&report.correlations)),
            ("all_compatible", Json::Bool(report.all_compatible(2.0))),
        ]),
    );
}
