//! Fig. 5: peak GPU memory per rank for the balanced network vs number of
//! nodes, for the four GPU memory levels — measured (simulated + estimated
//! at small scale) plus the analytic full-scale extrapolation at the
//! paper's scale 20 including the A100 64 GB line and the level-0 plateau
//! beyond P ≈ K_in.

use nestgpu::engine::SimConfig;
use nestgpu::harness::experiments::{balanced_weak_scaling, fig5_model_rows, write_result};
use nestgpu::memory::model::A100_BYTES;
use nestgpu::models::balanced::BalancedConfig;
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, Table};

const RANKS: [usize; 5] = [2, 4, 8, 16, 32];
const MAX_LIVE: usize = 8;

fn main() {
    let bal = BalancedConfig {
        scale: 0.02,
        k_scale: 0.02,
        ..Default::default()
    };
    let cfg = SimConfig::default();
    let pts = balanced_weak_scaling(&RANKS, &ALL_LEVELS, &bal, &cfg, MAX_LIVE, 1, 2, 0.0);

    let mut t = Table::new(
        "Fig. 5 (measured) — device memory peak per rank vs ranks",
        &["ranks", "level0", "level1", "level2", "level3", "mode"],
    );
    for &vr in &RANKS {
        let cell = |lvl: GpuMemLevel| {
            pts.iter()
                .find(|p| p.virtual_ranks == vr && p.level == lvl)
                .map(|p| fmt_bytes(p.agg.device_peak as u64))
                .unwrap_or_default()
        };
        let est = pts
            .iter()
            .find(|p| p.virtual_ranks == vr)
            .map(|p| p.estimated)
            .unwrap_or(false);
        t.row(vec![
            vr.to_string(),
            cell(GpuMemLevel::L0),
            cell(GpuMemLevel::L1),
            cell(GpuMemLevel::L2),
            cell(GpuMemLevel::L3),
            if est { "estimated".into() } else { "simulated".into() },
        ]);
    }
    t.print();

    // host-side counterpart (memory/tracker.rs): the levels trade device
    // residency for host staging, so the host peak moves opposite to the
    // device peak across levels
    let mut th = Table::new(
        "Fig. 5 (measured) — host memory peak per rank vs ranks",
        &["ranks", "level0", "level1", "level2", "level3", "mode"],
    );
    for &vr in &RANKS {
        let cell = |lvl: GpuMemLevel| {
            pts.iter()
                .find(|p| p.virtual_ranks == vr && p.level == lvl)
                .map(|p| fmt_bytes(p.agg.host_peak as u64))
                .unwrap_or_default()
        };
        let est = pts
            .iter()
            .find(|p| p.virtual_ranks == vr)
            .map(|p| p.estimated)
            .unwrap_or(false);
        th.row(vec![
            vr.to_string(),
            cell(GpuMemLevel::L0),
            cell(GpuMemLevel::L1),
            cell(GpuMemLevel::L2),
            cell(GpuMemLevel::L3),
            if est { "estimated".into() } else { "simulated".into() },
        ]);
    }
    th.print();

    // full-scale analytic extrapolation (the paper's dashed curves)
    let nodes = [32u64, 64, 128, 256, 512, 1024, 2048, 3072, 4096];
    let mut t2 = Table::new(
        "Fig. 5 (analytic, scale 20) — predicted per-GPU peak vs Leonardo nodes",
        &["nodes", "level0", "level1", "level2", "level3", "fits A100?"],
    );
    let mut model_json = Vec::new();
    for &n in &nodes {
        let mut cells = vec![n.to_string()];
        let mut fits = Vec::new();
        for lvl in ALL_LEVELS {
            let (_, peak) = fig5_model_rows(&[n], lvl, 20.0)[0];
            cells.push(fmt_bytes(peak));
            fits.push(peak <= A100_BYTES);
            model_json.push(Json::obj(vec![
                ("nodes", Json::num(n as f64)),
                ("level", Json::str(lvl.name())),
                ("peak_bytes", Json::num(peak as f64)),
            ]));
        }
        cells.push(
            ALL_LEVELS
                .iter()
                .zip(&fits)
                .map(|(l, &f)| format!("{}{}", l.name().trim_start_matches("level"), if f { "y" } else { "N" }))
                .collect::<Vec<_>>()
                .join(" "),
        );
        t2.row(cells);
    }
    t2.print();
    println!(
        "A100 limit = {}; paper shape check: level-0 plateaus from ~3072 nodes \
         (P > K_in) and reaches 4096 nodes within the A100 budget",
        fmt_bytes(A100_BYTES)
    );

    let measured: Vec<Json> = pts
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("ranks", Json::num(p.virtual_ranks as f64)),
                ("level", Json::str(p.level.name())),
                ("estimated", Json::Bool(p.estimated)),
                ("device_peak", Json::num(p.agg.device_peak)),
                ("device_peak_sd", Json::num(p.agg.device_peak_sd)),
                ("host_peak", Json::num(p.agg.host_peak)),
                ("host_peak_sd", Json::num(p.agg.host_peak_sd)),
                ("host_current", Json::num(p.agg.host_current)),
            ])
        })
        .collect();
    write_result(
        "fig5",
        &Json::obj(vec![
            ("measured", Json::Arr(measured)),
            ("model_scale20", Json::Arr(model_json)),
        ]),
    );
}
