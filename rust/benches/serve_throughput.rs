//! Construction-cache service throughput: cold vs warm jobs through a
//! live `nestgpu serve` daemon (DESIGN.md §17).
//!
//! Three phases against one in-process server on an ephemeral port:
//! (1) *cold* — distinct seeds, every job constructs; (2) *warm* — the
//! same specs resubmitted, every job resumes from the snapshot cache;
//! (3) *hammer* — several client threads replaying a mixed schedule
//! over the now-warm keys, measuring the served hit rate under
//! concurrency. Writes a stamped `BENCH_serve.json` at the repository
//! root; `cold_jobs_per_s` / `warm_jobs_per_s` ride the CI regression
//! gate. On the full-size run the warm path must clear >= 2x the cold
//! throughput — the payable-once construction claim, end to end.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;
use std::time::Instant;

use nestgpu::obs::stamp::write_bench_json;
use nestgpu::serve::{JobSpec, ServeClient, ServeConfig, Server};
use nestgpu::util::json::Json;
use nestgpu::util::table::Table;

fn spec(scale: f64, seed: u64) -> JobSpec {
    JobSpec {
        t_ms: 10.0,
        scale,
        k_scale: scale,
        seed,
        ..Default::default()
    }
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    // full size matches benches/snapshot_reload.rs (900 neurons/rank,
    // ~810k synapses/rank): construction dominates, as in the paper
    let scale = if smoke { 0.02 } else { 0.08 };
    let n_specs = if smoke { 3usize } else { 4 };
    let warm_rounds = if smoke { 2usize } else { 3 };
    let hammer_threads = if smoke { 2usize } else { 4 };
    let hammer_jobs = if smoke { 4usize } else { 8 };

    let base = std::env::temp_dir();
    let cache_dir = base.join(format!("nestgpu_serve_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let server = Server::bind(ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        cache_dir: cache_dir.clone(),
        cache_bytes: 1 << 30,
        max_jobs: 2,
        obs_dir: None,
    })
    .expect("bind serve daemon");
    let handle = server.spawn();
    let addr = handle.addr().to_string();
    println!(
        "serve_throughput: daemon at {addr}, {n_specs} specs at scale {scale}{}",
        if smoke { " [smoke]" } else { "" }
    );

    let specs: Vec<JobSpec> = (0..n_specs).map(|i| spec(scale, 1000 + i as u64)).collect();
    let mut client = ServeClient::connect(&addr).expect("connect");

    // (1) cold: every spec constructs
    let t0 = Instant::now();
    for s in &specs {
        let o = client.submit(s).expect("cold submit");
        assert!(!o.hit, "cold phase must construct (seed {})", s.seed);
    }
    let cold_s = t0.elapsed().as_secs_f64();
    let cold_jobs_per_s = n_specs as f64 / cold_s.max(1e-9);

    // (2) warm: the same specs resume from the cache
    let t0 = Instant::now();
    for _ in 0..warm_rounds {
        for s in &specs {
            let o = client.submit(s).expect("warm submit");
            assert!(o.hit, "warm phase must hit (seed {})", s.seed);
        }
    }
    let warm_s = t0.elapsed().as_secs_f64();
    let warm_jobs = warm_rounds * n_specs;
    let warm_jobs_per_s = warm_jobs as f64 / warm_s.max(1e-9);

    // (3) hammer: concurrent clients replaying a mixed schedule — the
    // warm keys plus one fresh seed per thread, so the measured hit
    // rate reflects a realistic warm/cold traffic mix
    let before = client.stats().expect("stats");
    std::thread::scope(|scope| {
        for t in 0..hammer_threads {
            let addr = addr.clone();
            let specs = &specs;
            scope.spawn(move || {
                let mut c = ServeClient::connect(&addr).expect("hammer connect");
                for j in 0..hammer_jobs {
                    let s = &specs[(t + j) % specs.len()];
                    c.submit(s).expect("hammer submit");
                }
                let fresh = spec(scale, 2000 + t as u64);
                c.submit(&fresh).expect("hammer cold submit");
            });
        }
    });
    let after = client.stats().expect("stats");
    let count = |j: &Json, k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let hits = count(&after, "hits") - count(&before, "hits");
    let misses = count(&after, "misses") - count(&before, "misses");
    let hammer_hit_rate = hits / (hits + misses).max(1.0);

    let mut c = ServeClient::connect(&addr).expect("connect for shutdown");
    c.shutdown().expect("shutdown");
    handle.join().expect("server exits cleanly");
    let _ = std::fs::remove_dir_all(&cache_dir);

    let warm_over_cold = warm_jobs_per_s / cold_jobs_per_s.max(1e-9);
    let mut t = Table::new(
        "serve throughput: cold construction vs warm cache",
        &["phase", "jobs", "jobs/s"],
    );
    t.row(vec![
        "cold (construct+save)".into(),
        format!("{n_specs}"),
        format!("{cold_jobs_per_s:.2}"),
    ]);
    t.row(vec![
        "warm (cache resume)".into(),
        format!("{warm_jobs}"),
        format!("{warm_jobs_per_s:.2}"),
    ]);
    t.row(vec![
        "hammer hit rate".into(),
        format!("{}", hammer_threads * (hammer_jobs + 1)),
        format!("{:.0}%", hammer_hit_rate * 100.0),
    ]);
    t.print();
    println!(
        "\nwarm/cold throughput: {warm_over_cold:.1}x (target >= 2x: {})",
        if warm_over_cold >= 2.0 { "PASS" } else { "MISS" }
    );
    // asserted only at full size; smoke worlds construct in milliseconds
    // where runner noise alone can cross the bar
    if !smoke {
        assert!(
            warm_over_cold >= 2.0,
            "warm jobs/s must be >= 2x cold (got {warm_over_cold:.2}x)"
        );
    }

    let fields = vec![
        ("model", Json::str("balanced-serve")),
        ("scale", Json::num(scale)),
        ("n_specs", Json::num(n_specs as f64)),
        ("smoke", Json::Bool(smoke)),
        ("cold_jobs_per_s", Json::num(cold_jobs_per_s)),
        ("warm_jobs_per_s", Json::num(warm_jobs_per_s)),
        ("warm_over_cold", Json::num(warm_over_cold)),
        ("hammer_hit_rate", Json::num(hammer_hit_rate)),
    ];
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_serve.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
