//! Fig. 6: balanced-network construction-time breakdown vs number of nodes
//! per GPU memory level — (a) neuron/device creation + connection, (b)
//! simulation preparation — with both estimated (bars) and simulated
//! (markers) values.
//!
//! Expected shape (paper): level 0 scales worst in (a); in (b) levels 0
//! and 1 behave alike (host-resident maps) while levels 2/3 profit from
//! device-side sorting of the maps.

use nestgpu::engine::SimConfig;
use nestgpu::harness::experiments::{balanced_weak_scaling, write_result, ScalingPoint};
use nestgpu::models::balanced::BalancedConfig;
use nestgpu::remote::levels::{GpuMemLevel, ALL_LEVELS};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_secs, Table};

const RANKS: [usize; 5] = [2, 4, 8, 16, 32];
const MAX_LIVE: usize = 8;

fn print_panel(pts: &[ScalingPoint], title: &str, get: impl Fn(&ScalingPoint) -> f64) {
    let mut t = Table::new(
        title,
        &["ranks", "level0", "level1", "level2", "level3", "mode"],
    );
    for &vr in &RANKS {
        let cell = |lvl: GpuMemLevel| {
            pts.iter()
                .find(|p| p.virtual_ranks == vr && p.level == lvl)
                .map(|p| fmt_secs(get(p)))
                .unwrap_or_default()
        };
        let est = pts
            .iter()
            .find(|p| p.virtual_ranks == vr)
            .map(|p| p.estimated)
            .unwrap_or(false);
        t.row(vec![
            vr.to_string(),
            cell(GpuMemLevel::L0),
            cell(GpuMemLevel::L1),
            cell(GpuMemLevel::L2),
            cell(GpuMemLevel::L3),
            if est { "estimated".into() } else { "simulated".into() },
        ]);
    }
    t.print();
}

fn main() {
    let bal = BalancedConfig {
        scale: 0.02,
        k_scale: 0.02,
        ..Default::default()
    };
    let cfg = SimConfig::default();
    // construction only (t_ms = 0): both live and estimated points measure
    // the same code path
    let pts = balanced_weak_scaling(&RANKS, &ALL_LEVELS, &bal, &cfg, MAX_LIVE, 2, 2, 0.0);

    print_panel(
        &pts,
        "Fig. 6a — neuron & device creation + connection time",
        |p| p.agg.creation_and_connection_s,
    );
    println!();
    print_panel(&pts, "Fig. 6b — simulation preparation time", |p| {
        p.agg.preparation_s
    });

    let rows: Vec<Json> = pts
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("ranks", Json::num(p.virtual_ranks as f64)),
                ("level", Json::str(p.level.name())),
                ("estimated", Json::Bool(p.estimated)),
                (
                    "creation_and_connection_s",
                    Json::num(p.agg.creation_and_connection_s),
                ),
                ("preparation_s", Json::num(p.agg.preparation_s)),
            ])
        })
        .collect();
    write_result("fig6", &Json::Arr(rows));
}
