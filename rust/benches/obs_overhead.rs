//! Observability overhead: the balanced network with the telemetry
//! subsystem off vs. on (per-step metrics registry + JSONL trace sink
//! sampling every 10 steps). The acceptance bar is <2% steps/s cost
//! with obs on (DESIGN.md §13).
//!
//! Both sides take the best of N repeats to suppress scheduler jitter;
//! the <2% assertion runs only on the full-size configuration (smoke
//! runs measure milliseconds of wall clock, where runner noise alone
//! can cross the bar — the smoke JSON still records `within_2pct` for
//! the trajectory). Writes a stamped `BENCH_obs_overhead.json` at the
//! repository root.
//!
//! Set `SMOKE=1` for the CI-sized run.

use std::path::PathBuf;

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::obs::stamp::write_bench_json;
use nestgpu::obs::ObsConfig;
use nestgpu::util::json::Json;
use nestgpu::util::table::Table;

fn steps_per_s(results: &[SimResult], steps: f64) -> f64 {
    let prop_s = results
        .iter()
        .map(|r| r.phases.propagation.as_secs_f64())
        .fold(0.0, f64::max)
        .max(1e-9);
    steps / prop_s
}

fn measure(
    obs: Option<ObsConfig>,
    ranks: usize,
    bal: &BalancedConfig,
    t_ms: f64,
    repeats: usize,
) -> f64 {
    let mut best = 0.0f64;
    for _ in 0..repeats {
        let cfg = SimConfig {
            record_spikes: false, // benchmarking runs, as in the paper
            obs: obs.clone(),
            ..Default::default()
        };
        let steps = (t_ms / cfg.dt_ms).round();
        let b = bal.clone();
        let results: Vec<SimResult> = run_cluster(
            ranks,
            &cfg,
            &move |sim: &mut Simulator| build_balanced(sim, &b),
            t_ms,
        )
        .expect("bench run");
        best = best.max(steps_per_s(&results, steps));
    }
    best
}

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let ranks = 2usize;
    let t_ms = if smoke { 50.0 } else { 400.0 };
    let repeats = if smoke { 2 } else { 5 };
    let bal = BalancedConfig {
        scale: if smoke { 0.01 } else { 0.05 },
        k_scale: 0.01,
        ..Default::default()
    };

    let trace_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("obs_overhead_trace");
    let obs_cfg = ObsConfig {
        trace_dir: Some(trace_dir.clone()),
        sample_interval: 10,
        label: "obs-overhead".to_string(),
        ..Default::default()
    };

    println!(
        "balanced, {ranks} ranks, {t_ms} ms, best of {repeats}{}",
        if smoke { " [smoke]" } else { "" }
    );

    let off = measure(None, ranks, &bal, t_ms, repeats);
    let on = measure(Some(obs_cfg), ranks, &bal, t_ms, repeats);
    let overhead = (off - on) / off.max(1e-9);

    let mut t = Table::new(
        "observability overhead: metrics + trace off vs on",
        &["config", "steps/s"],
    );
    t.row(vec!["obs off".to_string(), format!("{off:.0}")]);
    t.row(vec!["obs on (interval 10)".to_string(), format!("{on:.0}")]);
    t.print();

    println!(
        "\nobs overhead: {:.2}% of steps/s (acceptance bar: < 2%)",
        overhead * 100.0
    );
    assert!(
        trace_dir.join("rank0000.jsonl").exists(),
        "obs run must leave a per-rank trace behind"
    );
    // asserted only on the full-size run (see module docs)
    if !smoke {
        assert!(
            overhead < 0.02,
            "obs on costs {:.2}% steps/s (bar: < 2%)",
            overhead * 100.0
        );
    }

    let fields = vec![
        ("model", Json::str("balanced-obs")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        ("repeats", Json::num(repeats as f64)),
        ("smoke", Json::Bool(smoke)),
        ("steps_per_s_off", Json::num(off)),
        ("steps_per_s_on", Json::num(on)),
        ("overhead_frac", Json::num(overhead)),
        ("within_2pct", Json::Bool(overhead < 0.02)),
    ];
    // at the repository root (one directory above the rust package)
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_obs_overhead.json");
    if let Err(e) = write_bench_json(&path, fields) {
        eprintln!("cannot write {}: {e}", path.display());
        std::process::exit(1);
    }
    println!("[written {}]", path.display());
}
