//! Fig. 13 (Appendix E): difference between simulated and estimated
//! neuron/device creation + connection times at GPU memory level 0, as a
//! percentage and in absolute terms with a linear fit over rank count.
//!
//! The paper observes <10% divergence at 256 nodes, growing with system
//! size (jitter, thread migration); the estimator measures the same code
//! path, so small differences are expected on this substrate too.

use nestgpu::engine::SimConfig;
use nestgpu::harness::experiments::{balanced_weak_scaling, write_result};
use nestgpu::models::balanced::BalancedConfig;
use nestgpu::remote::levels::GpuMemLevel;
use nestgpu::util::json::Json;
use nestgpu::util::table::Table;

const RANKS: [usize; 3] = [2, 4, 8];

fn main() {
    let bal = BalancedConfig {
        scale: 0.02,
        k_scale: 0.02,
        ..Default::default()
    };
    let cfg = SimConfig {
        level: GpuMemLevel::L0,
        ..Default::default()
    };
    let mut t = Table::new(
        "Fig. 13 — simulated vs estimated creation+connection time (level 0)",
        &["ranks", "simulated (s)", "estimated (s)", "diff (s)", "diff (%)"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut rows = Vec::new();
    for &vr in &RANKS {
        // live (simulated)
        let sim_pts =
            balanced_weak_scaling(&[vr], &[GpuMemLevel::L0], &bal, &cfg, 64, 2, 2, 0.0);
        // estimated: force estimation mode by setting max_live below vr
        let est_pts =
            balanced_weak_scaling(&[vr], &[GpuMemLevel::L0], &bal, &cfg, 0, 1, 2, 0.0);
        let s = sim_pts[0].agg.creation_and_connection_s;
        let e = est_pts[0].agg.creation_and_connection_s;
        let diff = s - e;
        let pct = 100.0 * diff / e.max(1e-12);
        t.row(vec![
            vr.to_string(),
            format!("{s:.4}"),
            format!("{e:.4}"),
            format!("{diff:+.4}"),
            format!("{pct:+.1}%"),
        ]);
        xs.push(vr as f64);
        ys.push(diff);
        rows.push(Json::obj(vec![
            ("ranks", Json::num(vr as f64)),
            ("simulated_s", Json::num(s)),
            ("estimated_s", Json::num(e)),
            ("diff_s", Json::num(diff)),
            ("diff_pct", Json::num(pct)),
        ]));
    }
    t.print();

    // linear fit diff = a + b * ranks (the paper extrapolates to 4096 nodes)
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx).max(1e-12);
    let a = (sy - b * sx) / n;
    let extrapolated = a + b * 4096.0;
    println!(
        "\nlinear fit: diff(ranks) = {a:.4} + {b:.6} * ranks; extrapolation to \
         4096 ranks: {extrapolated:.2} s (paper: ~14 s at 4096 nodes)"
    );

    write_result(
        "fig13",
        &Json::obj(vec![
            ("rows", Json::Arr(rows)),
            ("fit_a", Json::num(a)),
            ("fit_b", Json::num(b)),
            ("extrapolated_4096", Json::num(extrapolated)),
        ]),
    );
}
