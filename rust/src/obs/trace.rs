//! Bounded, buffered per-rank JSONL trace sink.
//!
//! The step pipeline appends pre-formatted JSON lines into an in-memory
//! buffer; actual filesystem writes happen only at exchange boundaries and
//! at finalize (`maybe_flush`/`flush`), keeping `write(2)` off the per-step
//! hot path. The sink is bounded two ways: a record cap (`max_records`,
//! excess records are counted and dropped, never silently) and a byte
//! backstop that forces a flush if a pathological sampling config fills
//! the buffer between exchanges.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Force a flush if the pending buffer exceeds this many bytes even
/// between exchange boundaries (backstop, not the normal path).
const FLUSH_BACKSTOP_BYTES: usize = 8 << 20;

/// Buffered writer for one rank's `rank<NNNN>.jsonl` trace file.
pub struct TraceSink {
    path: PathBuf,
    file: Option<File>,
    buf: String,
    records: u64,
    dropped: u64,
    max_records: u64,
}

impl TraceSink {
    /// Standard per-rank trace file name inside a trace directory.
    pub fn rank_file(dir: &Path, rank: usize) -> PathBuf {
        dir.join(format!("rank{rank:04}.jsonl"))
    }

    /// Create (truncate) the rank's trace file. The directory must exist.
    pub fn create(dir: &Path, rank: usize, max_records: u64) -> anyhow::Result<Self> {
        let path = Self::rank_file(dir, rank);
        let file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("create trace file {}: {e}", path.display()))?;
        Ok(Self {
            path,
            file: Some(file),
            buf: String::with_capacity(64 << 10),
            records: 0,
            dropped: 0,
            max_records,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
    /// Records accepted so far (== lines that will reach the file).
    pub fn records(&self) -> u64 {
        self.records
    }
    /// Records rejected at the bound.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Append one JSONL record (`line` must be a single JSON value without
    /// a trailing newline). Returns whether the record was accepted.
    pub fn push_line(&mut self, line: &str) -> bool {
        if self.records >= self.max_records {
            self.dropped += 1;
            return false;
        }
        self.records += 1;
        self.buf.push_str(line);
        self.buf.push('\n');
        if self.buf.len() >= FLUSH_BACKSTOP_BYTES {
            self.flush();
        }
        true
    }

    /// Flush if anything is pending. Called at exchange boundaries so the
    /// write syscall amortizes over the exchange interval.
    pub fn maybe_flush(&mut self) {
        if !self.buf.is_empty() {
            self.flush();
        }
    }

    /// Write the pending buffer out. Trace I/O is best-effort telemetry:
    /// a failing disk must not kill the simulation, so errors drop the
    /// file handle (stopping further writes) instead of propagating.
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        if let Some(f) = self.file.as_mut() {
            if f.write_all(self.buf.as_bytes()).is_err() {
                self.file = None;
            }
        }
        self.buf.clear();
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "nestgpu_obs_trace_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn buffers_until_flush_then_appends() {
        let dir = tmp_dir("buffer");
        let mut sink = TraceSink::create(&dir, 0, 100).unwrap();
        assert!(sink.push_line(r#"{"step":0}"#));
        assert!(sink.push_line(r#"{"step":10}"#));
        // nothing on disk before the flush
        assert_eq!(std::fs::read_to_string(sink.path()).unwrap(), "");
        sink.maybe_flush();
        let text = std::fs::read_to_string(sink.path()).unwrap();
        assert_eq!(text, "{\"step\":0}\n{\"step\":10}\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bounded_drops_are_counted_not_silent() {
        let dir = tmp_dir("bound");
        let mut sink = TraceSink::create(&dir, 3, 2).unwrap();
        assert!(sink.push_line("{}"));
        assert!(sink.push_line("{}"));
        assert!(!sink.push_line("{}"));
        assert!(!sink.push_line("{}"));
        assert_eq!(sink.records(), 2);
        assert_eq!(sink.dropped(), 2);
        sink.flush();
        let text = std::fs::read_to_string(sink.path()).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_flushes_pending_lines() {
        let dir = tmp_dir("drop");
        let path;
        {
            let mut sink = TraceSink::create(&dir, 7, 10).unwrap();
            path = sink.path().to_path_buf();
            sink.push_line(r#"{"a":1}"#);
        }
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"a\":1}\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rank_file_naming() {
        let p = TraceSink::rank_file(Path::new("/tmp/t"), 12);
        assert_eq!(p, Path::new("/tmp/t/rank0012.jsonl"));
    }
}
