//! Run manifest: a `manifest.json` written by rank 0 into the trace
//! directory so a trace is self-describing — which config, seed, rank
//! layout, exchange interval and code revision produced it. The manifest
//! carries an FNV-1a content hash over its own serialized fields (hash
//! field excluded) so tooling can detect truncated or hand-edited files.

use std::collections::BTreeMap;
use std::path::Path;

use crate::snapshot::format::{fnv1a64_fold, FNV1A64_OFFSET};
use crate::util::json::Json;

/// Manifest schema version (bump on field changes).
pub const MANIFEST_SCHEMA: u64 = 1;

/// The run facts a manifest records.
#[derive(Clone, Debug)]
pub struct ManifestInfo {
    /// free-form run label (CLI subcommand / bench name)
    pub label: String,
    pub n_ranks: usize,
    pub t_ms: f64,
    pub dt_ms: f32,
    pub seed: u64,
    pub level: u8,
    pub backend: String,
    pub exchange_interval: u16,
    pub sample_interval: u64,
    pub max_delay_steps: u16,
    pub record_spikes: bool,
    /// connectivity mode ("materialized", "procedural")
    pub connectivity: String,
    /// communicator backend the run used ("thread", "socket", "null")
    pub transport: String,
    /// rank-ordered wire endpoints (empty for in-process transports)
    pub endpoints: Vec<String>,
}

/// Git revision of the working tree, or "unknown" outside a checkout.
pub fn git_revision() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// ISO-8601 UTC timestamp (`YYYY-MM-DDThh:mm:ssZ`) from the system clock,
/// without a date/time dependency: civil-from-days per Howard Hinnant's
/// algorithm.
pub fn iso8601_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    iso8601_from_unix(secs)
}

/// `YYYY-MM-DDThh:mm:ssZ` for a unix timestamp (UTC).
pub fn iso8601_from_unix(secs: i64) -> String {
    let days = secs.div_euclid(86_400);
    let sod = secs.rem_euclid(86_400);
    let (h, m, s) = (sod / 3600, (sod % 3600) / 60, sod % 60);
    // civil-from-days
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let mon = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if mon <= 2 { y + 1 } else { y };
    format!("{y:04}-{mon:02}-{d:02}T{h:02}:{m:02}:{s:02}Z")
}

fn manifest_json(info: &ManifestInfo) -> Json {
    Json::obj(vec![
        ("schema", Json::num(MANIFEST_SCHEMA as f64)),
        ("label", Json::str(&info.label)),
        ("n_ranks", Json::num(info.n_ranks as f64)),
        ("t_ms", Json::num(info.t_ms)),
        ("dt_ms", Json::num(info.dt_ms as f64)),
        ("seed", Json::num(info.seed as f64)),
        ("level", Json::num(info.level as f64)),
        ("backend", Json::str(&info.backend)),
        ("exchange_interval", Json::num(info.exchange_interval as f64)),
        ("sample_interval", Json::num(info.sample_interval as f64)),
        ("max_delay_steps", Json::num(info.max_delay_steps as f64)),
        ("record_spikes", Json::Bool(info.record_spikes)),
        ("connectivity", Json::str(&info.connectivity)),
        ("transport", Json::str(&info.transport)),
        (
            "endpoints",
            Json::Arr(info.endpoints.iter().map(|e| Json::str(e)).collect()),
        ),
        ("crate_version", Json::str(env!("CARGO_PKG_VERSION"))),
        ("git_rev", Json::str(&git_revision())),
        ("created", Json::str(&iso8601_now())),
    ])
}

/// FNV-1a over the canonical serialization (BTreeMap key order makes it
/// deterministic for identical field values).
pub fn content_hash(j: &Json) -> u64 {
    fnv1a64_fold(FNV1A64_OFFSET, j.to_string().as_bytes())
}

/// Write `manifest.json` into `dir`. Returns the serialized JSON.
pub fn write_manifest(dir: &Path, info: &ManifestInfo) -> anyhow::Result<Json> {
    let body = manifest_json(info);
    let hash = content_hash(&body);
    let full = match body {
        Json::Obj(mut m) => {
            m.insert("content_hash".to_string(), Json::str(&format!("{hash:016x}")));
            Json::Obj(m)
        }
        other => other,
    };
    let path = dir.join("manifest.json");
    std::fs::write(&path, full.to_string())
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
    Ok(full)
}

/// Load and verify a manifest; `Ok(json)` when present and hash-clean.
pub fn read_manifest(dir: &Path) -> anyhow::Result<Json> {
    let path = dir.join("manifest.json");
    let j = Json::parse_file(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
    let stored = j
        .get("content_hash")
        .and_then(|h| h.as_str())
        .ok_or_else(|| anyhow::anyhow!("{}: missing content_hash", path.display()))?;
    let body = match &j {
        Json::Obj(m) => {
            let mut m2: BTreeMap<String, Json> = m.clone();
            m2.remove("content_hash");
            Json::Obj(m2)
        }
        other => other.clone(),
    };
    let expect = format!("{:016x}", content_hash(&body));
    if stored != expect {
        anyhow::bail!(
            "{}: content hash mismatch (stored {stored}, computed {expect})",
            path.display()
        );
    }
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn info() -> ManifestInfo {
        ManifestInfo {
            label: "test".into(),
            n_ranks: 4,
            t_ms: 100.0,
            dt_ms: 0.1,
            seed: 12345,
            level: 1,
            backend: "reference".into(),
            exchange_interval: 8,
            sample_interval: 10,
            max_delay_steps: 32,
            record_spikes: false,
            connectivity: "materialized".into(),
            transport: "thread".into(),
            endpoints: Vec::new(),
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "nestgpu_obs_manifest_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn iso8601_known_values() {
        assert_eq!(iso8601_from_unix(0), "1970-01-01T00:00:00Z");
        assert_eq!(iso8601_from_unix(951_786_000), "2000-02-29T01:00:00Z");
        assert_eq!(iso8601_from_unix(1_754_611_200), "2025-08-08T00:00:00Z");
    }

    #[test]
    fn manifest_roundtrips_and_verifies() {
        let dir = tmp_dir("roundtrip");
        let written = write_manifest(&dir, &info()).unwrap();
        let read = read_manifest(&dir).unwrap();
        assert_eq!(written, read);
        assert_eq!(read.get("n_ranks").unwrap().as_usize(), Some(4));
        assert_eq!(read.get("exchange_interval").unwrap().as_usize(), Some(8));
        assert_eq!(read.get("transport").unwrap().as_str(), Some("thread"));
        assert_eq!(
            read.get("connectivity").unwrap().as_str(),
            Some("materialized")
        );
        assert_eq!(read.get("endpoints").unwrap().as_arr().map(|a| a.len()), Some(0));
        assert_eq!(read.get("schema").unwrap().as_usize(), Some(MANIFEST_SCHEMA as usize));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampering_is_detected() {
        let dir = tmp_dir("tamper");
        write_manifest(&dir, &info()).unwrap();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"seed\":12345", "\"seed\":99")).unwrap();
        assert!(read_manifest(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
