//! Provenance stamping for `BENCH_*.json` outputs.
//!
//! Every bench result file carries a schema version, an ISO-8601
//! timestamp and the git revision, so committed baselines and CI
//! artifacts are comparable across time. Writing refuses to clobber a
//! file whose schema version is *newer* than this binary understands —
//! an old binary on a new checkout must not silently destroy data the
//! new schema added.

use std::path::Path;

use crate::obs::manifest::{git_revision, iso8601_now};
use crate::util::json::Json;

/// Current schema for stamped bench files.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Stamp `fields` with provenance and write them to `path`.
///
/// Fails (leaving the existing file untouched) when `path` already holds
/// a stamped result with `schema_version > BENCH_SCHEMA_VERSION`.
pub fn write_bench_json(path: &Path, fields: Vec<(&str, Json)>) -> anyhow::Result<()> {
    if path.exists() {
        if let Ok(existing) = Json::parse_file(path) {
            if let Some(v) = existing.get("schema_version").and_then(|v| v.as_f64()) {
                if v as u64 > BENCH_SCHEMA_VERSION {
                    anyhow::bail!(
                        "{}: existing schema_version {} is newer than supported {}; \
                         refusing to overwrite (delete the file to regenerate)",
                        path.display(),
                        v as u64,
                        BENCH_SCHEMA_VERSION
                    );
                }
            }
        }
    }
    let mut all = vec![
        ("schema_version", Json::num(BENCH_SCHEMA_VERSION as f64)),
        ("generated_at", Json::str(&iso8601_now())),
        ("git_rev", Json::str(&git_revision())),
    ];
    all.extend(fields);
    let j = Json::obj(all);
    std::fs::write(path, j.to_string())
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_file(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "nestgpu_obs_stamp_{name}_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn stamps_provenance_fields() {
        let p = tmp_file("stamp");
        write_bench_json(&p, vec![("steps_per_s", Json::num(123.0))]).unwrap();
        let j = Json::parse_file(&p).unwrap();
        assert_eq!(
            j.get("schema_version").unwrap().as_usize(),
            Some(BENCH_SCHEMA_VERSION as usize)
        );
        assert!(j.get("generated_at").unwrap().as_str().unwrap().ends_with('Z'));
        assert!(j.get("git_rev").is_some());
        assert_eq!(j.get("steps_per_s").unwrap().as_f64(), Some(123.0));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn refuses_newer_schema_keeps_file() {
        let p = tmp_file("newer");
        let newer = format!(
            "{{\"schema_version\": {}, \"keep\": true}}",
            BENCH_SCHEMA_VERSION + 1
        );
        std::fs::write(&p, &newer).unwrap();
        let err = write_bench_json(&p, vec![("x", Json::num(1.0))]).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        // original content untouched
        assert_eq!(std::fs::read_to_string(&p).unwrap(), newer);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn overwrites_same_or_older_schema() {
        let p = tmp_file("older");
        std::fs::write(&p, "{\"schema_version\": 0}").unwrap();
        write_bench_json(&p, vec![("x", Json::num(2.0))]).unwrap();
        let j = Json::parse_file(&p).unwrap();
        assert_eq!(j.get("x").unwrap().as_f64(), Some(2.0));
        // unparseable files are treated as legacy and replaced
        std::fs::write(&p, "not json").unwrap();
        write_bench_json(&p, vec![("x", Json::num(3.0))]).unwrap();
        assert_eq!(
            Json::parse_file(&p).unwrap().get("x").unwrap().as_f64(),
            Some(3.0)
        );
        let _ = std::fs::remove_file(&p);
    }
}
