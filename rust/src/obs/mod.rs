//! Observability subsystem (DESIGN.md §13).
//!
//! Layered so each piece is independently testable:
//!
//! - [`metrics`] — allocation-free counters/gauges/log-bucket histograms
//!   with a `u32`-word wire format for cross-rank aggregation;
//! - [`trace`] — bounded, buffered per-rank JSONL sink (flushed at
//!   exchange boundaries, never per step);
//! - [`manifest`] — self-describing `manifest.json` per trace directory,
//!   hashed with the snapshot FNV-1a;
//! - [`report`] — offline trace-dir analysis behind `nestgpu report`;
//! - [`stamp`] — provenance stamping for `BENCH_*.json` outputs.
//!
//! [`ObsState`] is the engine-facing facade: `Simulator` owns an
//! `Option<ObsState>` (exactly like the plasticity engine) and feeds it
//! from `step_once`. With `SimConfig::obs == None` the entire layer is a
//! handful of `Option::is_some` branch checks; `benches/obs_overhead.rs`
//! holds the enabled path under a <2% steps/s budget.

pub mod manifest;
pub mod metrics;
pub mod report;
pub mod stamp;
pub mod trace;

use std::fmt::Write as _;
use std::path::PathBuf;

use crate::comm::TrafficStats;
use crate::util::timer::{StepPhase, ALL_STEP_PHASES};

pub use metrics::{CounterId, GaugeId, HistId, MetricsRegistry, ObsSummary};
pub use trace::TraceSink;

/// Schema version of the JSONL step records.
pub const TRACE_SCHEMA: u64 = 1;

/// Observability configuration (part of `SimConfig`; must be identical on
/// every rank, like the rest of the config — SPMD).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// where to write `rank*.jsonl` + `manifest.json`; `None` = metrics
    /// only (registry + merged summary, no trace files)
    pub trace_dir: Option<PathBuf>,
    /// sample a JSONL step record every this many steps
    pub sample_interval: u64,
    /// per-rank trace record bound (drops are counted, never silent)
    pub max_trace_records: u64,
    /// free-form run label recorded in the manifest
    pub label: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace_dir: None,
            sample_interval: 10,
            max_trace_records: 1_000_000,
            label: "run".to_string(),
        }
    }
}

/// Everything `step_once` hands to [`ObsState::end_step`] — plain counts
/// read off the simulator, assembled only when observability is on.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepSample {
    pub step: u32,
    pub time_ms: f64,
    /// local spikes this step
    pub spikes: u64,
    /// p2p records waiting in scratch packets
    pub pkt_backlog: u64,
    /// collective spikes waiting in scratch group buffers
    pub grp_backlog: u64,
    pub dev_current: u64,
    pub dev_peak: u64,
    pub host_current: u64,
    pub host_peak: u64,
    /// cumulative comm counters at this step
    pub traffic: TrafficStats,
}

/// Per-rank observability state, owned by the simulator.
pub struct ObsState {
    pub cfg: ObsConfig,
    pub registry: MetricsRegistry,
    sink: Option<TraceSink>,
    /// reusable formatting buffer for one JSONL line
    line: String,
    /// this step's per-phase ns (reset by `begin_step`); phases that do
    /// not run this step (exchange off-cadence, static plasticity) stay 0
    /// in the trace record but are *not* recorded into the histograms
    cur_phase_ns: [u64; ALL_STEP_PHASES.len()],
    /// comm-world group id for the finalize-time aggregation allgather
    pub world_group: Option<usize>,
}

impl ObsState {
    /// Build the rank's observability state; creates the trace directory
    /// and this rank's JSONL file when tracing is configured.
    pub fn new(cfg: ObsConfig, rank: usize) -> anyhow::Result<Self> {
        let sink = match &cfg.trace_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .map_err(|e| anyhow::anyhow!("create trace dir {}: {e}", dir.display()))?;
                Some(TraceSink::create(dir, rank, cfg.max_trace_records)?)
            }
            None => None,
        };
        Ok(Self {
            cfg,
            registry: MetricsRegistry::new(),
            sink,
            line: String::with_capacity(512),
            cur_phase_ns: [0; ALL_STEP_PHASES.len()],
            world_group: None,
        })
    }

    /// Record fixed ring-plane capacities (known at `prepare()`).
    pub fn set_ring_gauges(&mut self, local_slots: u64, remote_slots: u64) {
        self.registry.set(GaugeId::LocalRingSlots, local_slots);
        self.registry.set(GaugeId::RemoteRingSlots, remote_slots);
    }

    /// Reset the per-step phase scratch.
    #[inline]
    pub fn begin_step(&mut self) {
        self.cur_phase_ns = [0; ALL_STEP_PHASES.len()];
    }

    /// One pipeline phase ran for `ns` this step.
    #[inline]
    pub fn phase(&mut self, p: StepPhase, ns: u64) {
        self.cur_phase_ns[p.index()] += ns;
        self.registry.record(HistId::PhaseNs(p), ns);
    }

    /// An exchange round completed: `records_out`/`records_in` remote
    /// spike records, `delta_bytes` comm bytes this round. Also the flush
    /// point for the trace sink — one buffered write per interval, not
    /// per step.
    pub fn on_exchange(&mut self, records_out: u64, records_in: u64, delta_bytes: u64) {
        self.registry.add(CounterId::Exchanges, 1);
        self.registry.add(CounterId::RecordsSent, records_out);
        self.registry.add(CounterId::RecordsReceived, records_in);
        self.registry.record(HistId::RecordsPerExchange, records_in);
        self.registry.record(HistId::BytesPerExchange, delta_bytes);
        if let Some(s) = self.sink.as_mut() {
            s.maybe_flush();
        }
    }

    /// Close out one step: counters, gauges, and (on the sampling cadence)
    /// one JSONL record into the sink buffer.
    pub fn end_step(&mut self, s: &StepSample) {
        let r = &mut self.registry;
        r.add(CounterId::Steps, 1);
        r.add(CounterId::SpikesEmitted, s.spikes);
        r.record(HistId::SpikesPerStep, s.spikes);
        // backlogs are high-water gauges; memory gauges track the tracker
        let pkt = r.gauge(GaugeId::PacketBacklog).max(s.pkt_backlog);
        r.set(GaugeId::PacketBacklog, pkt);
        let grp = r.gauge(GaugeId::GroupBacklog).max(s.grp_backlog);
        r.set(GaugeId::GroupBacklog, grp);
        r.set(GaugeId::DeviceCurrent, s.dev_current);
        r.set(GaugeId::DevicePeak, s.dev_peak);
        r.set(GaugeId::HostCurrent, s.host_current);
        r.set(GaugeId::HostPeak, s.host_peak);
        if self.sink.is_some() && s.step as u64 % self.cfg.sample_interval == 0 {
            self.write_step_record(s);
        }
    }

    /// Format one `{"t":"step",…}` record into the reusable line buffer
    /// and push it into the sink.
    fn write_step_record(&mut self, s: &StepSample) {
        self.line.clear();
        let _ = write!(
            self.line,
            r#"{{"t":"step","step":{},"time_ms":{:.3},"phase_ns":{{"#,
            s.step, s.time_ms
        );
        for (i, p) in ALL_STEP_PHASES.iter().enumerate() {
            let _ = write!(
                self.line,
                "{}\"{}\":{}",
                if i > 0 { "," } else { "" },
                p.name(),
                self.cur_phase_ns[i]
            );
        }
        let _ = write!(
            self.line,
            r#"}},"spikes":{},"pkt_backlog":{},"grp_backlog":{},"dev_cur":{},"dev_peak":{},"host_cur":{},"host_peak":{},"p2p_msgs":{},"p2p_bytes":{},"coll_calls":{},"coll_bytes":{}}}"#,
            s.spikes,
            s.pkt_backlog,
            s.grp_backlog,
            s.dev_current,
            s.dev_peak,
            s.host_current,
            s.host_peak,
            s.traffic.p2p_messages,
            s.traffic.p2p_bytes,
            s.traffic.coll_calls,
            s.traffic.coll_bytes
        );
        if let Some(sink) = self.sink.as_mut() {
            sink.push_line(&self.line);
        }
    }

    /// End of run: stamp the trace counters, append the summary record,
    /// flush everything. Must run before the registries are aggregated so
    /// every rank's trace counters are final.
    pub fn finalize(&mut self, rank: usize) {
        if let Some(sink) = self.sink.as_ref() {
            // finalize runs once, so adding onto zero sets the counters;
            // the summary record written below is intentionally not counted
            let recs = sink.records();
            let dropped = sink.dropped();
            self.registry.add(CounterId::TraceRecords, recs);
            self.registry.add(CounterId::TraceDropped, dropped);
        }
        if self.sink.is_some() {
            self.line.clear();
            let _ = write!(
                self.line,
                r#"{{"t":"summary","schema":{TRACE_SCHEMA},"rank":{rank},"registry":"#
            );
            self.line.push_str(&self.registry.to_json().to_string());
            self.line.push('}');
            let line = std::mem::take(&mut self.line);
            if let Some(sink) = self.sink.as_mut() {
                sink.push_line(&line);
                sink.flush();
            }
            self.line = line;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracing_still_collects_metrics() {
        let mut o = ObsState::new(ObsConfig::default(), 0).unwrap();
        o.begin_step();
        o.phase(StepPhase::Dynamics, 1000);
        o.end_step(&StepSample {
            step: 0,
            spikes: 5,
            ..StepSample::default()
        });
        assert_eq!(o.registry.counter(CounterId::Steps), 1);
        assert_eq!(o.registry.counter(CounterId::SpikesEmitted), 5);
        assert_eq!(
            o.registry.hist(HistId::PhaseNs(StepPhase::Dynamics)).count,
            1
        );
        o.finalize(0); // no sink: must be a no-op, not a crash
    }

    #[test]
    fn step_records_land_on_the_sampling_cadence() {
        let dir = std::env::temp_dir().join(format!(
            "nestgpu_obs_mod_cadence_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ObsConfig {
            trace_dir: Some(dir.clone()),
            sample_interval: 5,
            ..ObsConfig::default()
        };
        let mut o = ObsState::new(cfg, 2).unwrap();
        for step in 0..12u32 {
            o.begin_step();
            o.phase(StepPhase::Input, 10 + step as u64);
            o.end_step(&StepSample {
                step,
                spikes: step as u64,
                ..StepSample::default()
            });
        }
        o.finalize(2);
        let text =
            std::fs::read_to_string(TraceSink::rank_file(&dir, 2)).unwrap();
        // steps 0, 5, 10 sampled + 1 summary line
        assert_eq!(text.lines().count(), 4);
        assert!(text.lines().last().unwrap().contains("\"t\":\"summary\""));
        let first = crate::util::json::Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("t").unwrap().as_str(), Some("step"));
        assert_eq!(
            first.get("phase_ns").unwrap().get("input").unwrap().as_f64(),
            Some(10.0)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
