//! Offline analysis of a trace directory: `nestgpu report <trace-dir>`.
//!
//! Reads the run manifest plus every `rank*.jsonl` trace (schema in
//! DESIGN.md §13) and produces per-rank, per-phase latency statistics
//! (exact nearest-rank p50/p95/max over the sampled steps — unlike the
//! in-process histograms these are computed from the raw samples), comm
//! byte/message totals, and memory peaks. `TraceReport::to_json` is the
//! machine-readable summary.

use std::path::Path;

use crate::util::json::Json;
use crate::util::timer::ALL_STEP_PHASES;

/// Statistics over one sampled series (per-phase ns, spikes, …).
#[derive(Clone, Debug, Default)]
pub struct SeriesStat {
    pub count: usize,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub max: u64,
}

impl SeriesStat {
    /// Exact nearest-rank percentiles over the raw samples.
    pub fn from_samples(mut samples: Vec<u64>) -> SeriesStat {
        if samples.is_empty() {
            return SeriesStat::default();
        }
        samples.sort_unstable();
        let n = samples.len();
        let pick = |q: f64| -> u64 {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            samples[rank - 1]
        };
        SeriesStat {
            count: n,
            mean: samples.iter().map(|&x| x as f64).sum::<f64>() / n as f64,
            p50: pick(0.50),
            p95: pick(0.95),
            max: samples[n - 1],
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50 as f64)),
            ("p95", Json::num(self.p95 as f64)),
            ("max", Json::num(self.max as f64)),
        ])
    }
}

/// Everything extracted from one rank's JSONL trace.
#[derive(Clone, Debug, Default)]
pub struct RankReport {
    pub rank: usize,
    pub samples: usize,
    /// indexed like [`ALL_STEP_PHASES`]
    pub phase_ns: Vec<SeriesStat>,
    pub spikes: SeriesStat,
    /// cumulative comm counters from the last sampled step
    pub p2p_bytes: u64,
    pub coll_bytes: u64,
    pub p2p_messages: u64,
    pub coll_calls: u64,
    /// memory tracker maxima over the sampled series
    pub dev_peak: u64,
    pub host_peak: u64,
    /// the finalize-time registry dump, when the trace has one
    pub summary: Option<Json>,
}

impl RankReport {
    pub fn to_json(&self) -> Json {
        let phases: Vec<(&str, Json)> = ALL_STEP_PHASES
            .iter()
            .zip(self.phase_ns.iter())
            .map(|(p, s)| (p.name(), s.to_json()))
            .collect();
        let mut fields = vec![
            ("rank", Json::num(self.rank as f64)),
            ("samples", Json::num(self.samples as f64)),
            ("phase_ns", Json::obj(phases)),
            ("spikes_per_step", self.spikes.to_json()),
            (
                "comm",
                Json::obj(vec![
                    ("p2p_bytes", Json::num(self.p2p_bytes as f64)),
                    ("coll_bytes", Json::num(self.coll_bytes as f64)),
                    ("p2p_messages", Json::num(self.p2p_messages as f64)),
                    ("coll_calls", Json::num(self.coll_calls as f64)),
                ]),
            ),
            (
                "memory",
                Json::obj(vec![
                    ("dev_peak", Json::num(self.dev_peak as f64)),
                    ("host_peak", Json::num(self.host_peak as f64)),
                ]),
            ),
        ];
        if let Some(s) = &self.summary {
            fields.push(("summary", s.clone()));
        }
        Json::obj(fields)
    }
}

/// A fully parsed trace directory.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub manifest: Option<Json>,
    pub ranks: Vec<RankReport>,
}

impl TraceReport {
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(m) = &self.manifest {
            fields.push(("manifest", m.clone()));
        }
        fields.push((
            "ranks",
            Json::Arr(self.ranks.iter().map(|r| r.to_json()).collect()),
        ));
        Json::obj(fields)
    }
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
}

fn parse_rank_trace(path: &Path, rank: usize) -> anyhow::Result<RankReport> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut phase_samples: Vec<Vec<u64>> = vec![Vec::new(); ALL_STEP_PHASES.len()];
    let mut spike_samples: Vec<u64> = Vec::new();
    let mut out = RankReport {
        rank,
        ..RankReport::default()
    };
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow::anyhow!("{}:{}: bad JSONL record: {e}", path.display(), lineno + 1)
        })?;
        match j.get("t").and_then(|t| t.as_str()) {
            Some("step") => {
                out.samples += 1;
                if let Some(ph) = j.get("phase_ns") {
                    for (i, p) in ALL_STEP_PHASES.iter().enumerate() {
                        phase_samples[i].push(get_u64(ph, p.name()));
                    }
                }
                spike_samples.push(get_u64(&j, "spikes"));
                out.p2p_bytes = get_u64(&j, "p2p_bytes");
                out.coll_bytes = get_u64(&j, "coll_bytes");
                out.p2p_messages = get_u64(&j, "p2p_msgs");
                out.coll_calls = get_u64(&j, "coll_calls");
                out.dev_peak = out.dev_peak.max(get_u64(&j, "dev_peak"));
                out.host_peak = out.host_peak.max(get_u64(&j, "host_peak"));
            }
            Some("summary") => {
                out.summary = j.get("registry").cloned();
            }
            _ => {} // unknown record types are forward-compatible noise
        }
    }
    out.phase_ns = phase_samples
        .into_iter()
        .map(SeriesStat::from_samples)
        .collect();
    out.spikes = SeriesStat::from_samples(spike_samples);
    Ok(out)
}

/// Parse a whole trace directory (manifest optional, traces required).
pub fn read_trace_dir(dir: &Path) -> anyhow::Result<TraceReport> {
    if !dir.is_dir() {
        anyhow::bail!("{} is not a directory", dir.display());
    }
    let manifest = crate::obs::manifest::read_manifest(dir).ok();
    let mut rank_files: Vec<(usize, std::path::PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?
    {
        let entry = entry.map_err(|e| anyhow::anyhow!("read dir entry: {e}"))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name
            .strip_prefix("rank")
            .and_then(|s| s.strip_suffix(".jsonl"))
        {
            if let Ok(rank) = num.parse::<usize>() {
                rank_files.push((rank, entry.path()));
            }
        }
    }
    if rank_files.is_empty() {
        anyhow::bail!("{}: no rank*.jsonl trace files found", dir.display());
    }
    rank_files.sort_by_key(|(r, _)| *r);
    let ranks = rank_files
        .into_iter()
        .map(|(r, p)| parse_rank_trace(&p, r))
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(TraceReport { manifest, ranks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "nestgpu_obs_report_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        let s = SeriesStat::from_samples((1..=100).collect());
        assert_eq!(s.p50, 50);
        assert_eq!(s.p95, 95);
        assert_eq!(s.max, 100);
        assert_eq!(s.mean, 50.5);
        let s1 = SeriesStat::from_samples(vec![7u64]);
        assert_eq!((s1.p50, s1.p95, s1.max), (7, 7, 7));
        assert_eq!(SeriesStat::from_samples(Vec::new()).count, 0);
    }

    #[test]
    fn parses_step_and_summary_records() {
        let dir = tmp_dir("parse");
        let lines = [
            r#"{"t":"step","step":0,"phase_ns":{"input":10,"pre_update":0,"dynamics":100,"collect":5,"post_update":0,"route":7,"exchange":50,"deliver":20},"spikes":3,"p2p_bytes":64,"coll_bytes":0,"p2p_msgs":2,"coll_calls":0,"dev_peak":1000,"host_peak":500}"#,
            r#"{"t":"step","step":10,"phase_ns":{"input":20,"pre_update":0,"dynamics":200,"collect":5,"post_update":0,"route":9,"exchange":70,"deliver":30},"spikes":5,"p2p_bytes":128,"coll_bytes":0,"p2p_msgs":4,"coll_calls":0,"dev_peak":1200,"host_peak":500}"#,
            r#"{"t":"summary","rank":0,"registry":{"counters":{"steps":20}}}"#,
        ];
        std::fs::write(dir.join("rank0000.jsonl"), lines.join("\n")).unwrap();
        let rep = read_trace_dir(&dir).unwrap();
        assert_eq!(rep.ranks.len(), 1);
        let r = &rep.ranks[0];
        assert_eq!(r.samples, 2);
        // dynamics is phase index 2
        assert_eq!(r.phase_ns[2].max, 200);
        assert_eq!(r.phase_ns[2].p50, 100);
        assert_eq!(r.spikes.max, 5);
        assert_eq!(r.p2p_bytes, 128, "comm counters take the last sample");
        assert_eq!(r.dev_peak, 1200);
        assert!(r.summary.is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_and_empty_dir_error() {
        let dir = tmp_dir("empty");
        assert!(read_trace_dir(&dir.join("nope")).is_err());
        assert!(read_trace_dir(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
