//! Allocation-free metrics registry: counters, gauges and fixed
//! log-bucket histograms over a compile-time metric catalog.
//!
//! The registry is a plain struct of fixed-size arrays — recording a value
//! is an array index plus a handful of integer ops, so the step pipeline
//! can feed it every step without heap traffic. Histograms use power-of-two
//! buckets (bucket `b ≥ 1` holds `[2^(b-1), 2^b − 1]`, bucket 0 holds the
//! exact value 0, the last bucket saturates), which keeps quantile
//! estimates within a factor of two — plenty for the "where does step time
//! go" question of the paper's Fig. 3a/6 and for Pronold-style per-phase
//! hot-spot hunting, at a per-record cost of one `leading_zeros`.
//!
//! Registries serialize to `u32` words so a whole rank's metrics travel
//! through the existing `Communicator::allgather_into` at run end; merging
//! is integer-only (counters add, gauges take the max, histogram buckets
//! add), so the cross-rank merged summary is bit-stable for any rank count
//! and either exchange protocol.

use crate::util::json::Json;
use crate::util::timer::{StepPhase, ALL_STEP_PHASES};

/// Number of log buckets (covers the full `u64` range).
pub const N_BUCKETS: usize = 64;

/// Fixed log-bucket histogram with exact count/sum/max sidecars.
#[derive(Clone, Copy, Debug)]
pub struct Histogram {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    buckets: [u64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0,
            max: 0,
            buckets: [0; N_BUCKETS],
        }
    }
}

impl Histogram {
    /// Bucket index of a value: 0 for 0, else `64 − leading_zeros`,
    /// saturating at the last bucket. Bucket `b ≥ 1` therefore covers
    /// `[2^(b−1), 2^b − 1]`; the last bucket covers everything from
    /// `2^(N_BUCKETS−2)` up.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(N_BUCKETS - 1)
        }
    }

    /// Inclusive upper edge of a bucket (what quantile estimates report).
    pub fn bucket_upper(b: usize) -> u64 {
        match b {
            0 => 0,
            b if b >= N_BUCKETS - 1 => u64::MAX,
            b => (1u64 << b) - 1,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        if v > self.max {
            self.max = v;
        }
        self.buckets[Self::bucket_of(v)] += 1;
    }

    pub fn bucket_count(&self, b: usize) -> u64 {
        self.buckets[b]
    }

    /// Quantile estimate: the upper edge of the bucket where the
    /// cumulative count first reaches `⌈q·count⌉`, clamped to the exact
    /// observed max (so `quantile(1.0) == max`). Zero if empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper(b).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &Histogram) {
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.p50() as f64)),
            ("p95", Json::num(self.p95() as f64)),
            ("max", Json::num(self.max as f64)),
        ])
    }
}

/// Monotonic event counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterId {
    /// integration steps executed
    Steps,
    /// spikes emitted by local neurons (collect phase)
    SpikesEmitted,
    /// remote spike records routed out (p2p records + collective spikes)
    RecordsSent,
    /// remote spike records received and delivered
    RecordsReceived,
    /// exchange rounds performed
    Exchanges,
    /// JSONL trace records written
    TraceRecords,
    /// JSONL trace records dropped at the bound
    TraceDropped,
    /// procedural fanouts served from the regeneration cache
    RegenCacheHits,
    /// procedural fanouts rematerialized (cache misses)
    RegenCacheMisses,
    /// serve jobs answered from the construction snapshot cache
    CacheHits,
    /// serve jobs that had to construct (cache misses)
    CacheMisses,
    /// snapshot cache entries evicted under the byte budget
    CacheEvictions,
}

pub const ALL_COUNTERS: [CounterId; 12] = [
    CounterId::Steps,
    CounterId::SpikesEmitted,
    CounterId::RecordsSent,
    CounterId::RecordsReceived,
    CounterId::Exchanges,
    CounterId::TraceRecords,
    CounterId::TraceDropped,
    CounterId::RegenCacheHits,
    CounterId::RegenCacheMisses,
    CounterId::CacheHits,
    CounterId::CacheMisses,
    CounterId::CacheEvictions,
];

impl CounterId {
    pub fn name(self) -> &'static str {
        match self {
            CounterId::Steps => "steps",
            CounterId::SpikesEmitted => "spikes_emitted",
            CounterId::RecordsSent => "records_sent",
            CounterId::RecordsReceived => "records_received",
            CounterId::Exchanges => "exchanges",
            CounterId::TraceRecords => "trace_records",
            CounterId::TraceDropped => "trace_dropped",
            CounterId::RegenCacheHits => "regen_cache_hits",
            CounterId::RegenCacheMisses => "regen_cache_misses",
            CounterId::CacheHits => "cache_hits",
            CounterId::CacheMisses => "cache_misses",
            CounterId::CacheEvictions => "cache_evictions",
        }
    }
    fn index(self) -> usize {
        ALL_COUNTERS.iter().position(|&c| c == self).unwrap()
    }
}

/// Last-sampled values (merged across ranks with `max`, so the world
/// summary reports the worst rank — the scaling-cliff question).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GaugeId {
    /// p2p spike records waiting for the next exchange (scratch backlog)
    PacketBacklog,
    /// collective spikes waiting for the next exchange (scratch backlog)
    GroupBacklog,
    /// local-plane ring slots (capacity; fixed after prepare)
    LocalRingSlots,
    /// remote-plane ring slots (0 on ranks without image neurons)
    RemoteRingSlots,
    /// device bytes currently allocated (memory/tracker.rs)
    DeviceCurrent,
    /// device bytes peak
    DevicePeak,
    /// host bytes currently allocated
    HostCurrent,
    /// host bytes peak
    HostPeak,
    /// snapshot cache resident bytes (serve)
    CacheBytes,
}

pub const ALL_GAUGES: [GaugeId; 9] = [
    GaugeId::PacketBacklog,
    GaugeId::GroupBacklog,
    GaugeId::LocalRingSlots,
    GaugeId::RemoteRingSlots,
    GaugeId::DeviceCurrent,
    GaugeId::DevicePeak,
    GaugeId::HostCurrent,
    GaugeId::HostPeak,
    GaugeId::CacheBytes,
];

impl GaugeId {
    pub fn name(self) -> &'static str {
        match self {
            GaugeId::PacketBacklog => "pkt_backlog",
            GaugeId::GroupBacklog => "grp_backlog",
            GaugeId::LocalRingSlots => "local_ring_slots",
            GaugeId::RemoteRingSlots => "remote_ring_slots",
            GaugeId::DeviceCurrent => "dev_cur",
            GaugeId::DevicePeak => "dev_peak",
            GaugeId::HostCurrent => "host_cur",
            GaugeId::HostPeak => "host_peak",
            GaugeId::CacheBytes => "cache_bytes",
        }
    }
    fn index(self) -> usize {
        ALL_GAUGES.iter().position(|&g| g == self).unwrap()
    }
}

/// Histogram catalog: one per pipeline phase (recorded when the phase
/// runs — exchange/deliver at exchange cadence), plus per-step spike
/// counts and per-exchange record/byte volumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HistId {
    /// wall-clock ns of one execution of a pipeline phase
    PhaseNs(StepPhase),
    /// spikes emitted per step
    SpikesPerStep,
    /// remote records received per exchange round
    RecordsPerExchange,
    /// comm bytes (p2p + collective) sent per exchange round
    BytesPerExchange,
}

pub const N_HISTS: usize = ALL_STEP_PHASES.len() + 3;

pub const ALL_HISTS: [HistId; N_HISTS] = [
    HistId::PhaseNs(StepPhase::Input),
    HistId::PhaseNs(StepPhase::PreUpdate),
    HistId::PhaseNs(StepPhase::Dynamics),
    HistId::PhaseNs(StepPhase::Collect),
    HistId::PhaseNs(StepPhase::PostUpdate),
    HistId::PhaseNs(StepPhase::Route),
    HistId::PhaseNs(StepPhase::Exchange),
    HistId::PhaseNs(StepPhase::Deliver),
    HistId::PhaseNs(StepPhase::Regen),
    HistId::SpikesPerStep,
    HistId::RecordsPerExchange,
    HistId::BytesPerExchange,
];

impl HistId {
    pub fn name(self) -> &'static str {
        match self {
            HistId::PhaseNs(p) => p.name(),
            HistId::SpikesPerStep => "spikes_per_step",
            HistId::RecordsPerExchange => "records_per_exchange",
            HistId::BytesPerExchange => "bytes_per_exchange",
        }
    }
    #[inline]
    fn index(self) -> usize {
        match self {
            HistId::PhaseNs(p) => p.index(),
            HistId::SpikesPerStep => ALL_STEP_PHASES.len(),
            HistId::RecordsPerExchange => ALL_STEP_PHASES.len() + 1,
            HistId::BytesPerExchange => ALL_STEP_PHASES.len() + 2,
        }
    }
}

/// Wire-format version of [`MetricsRegistry::encode_words`].
const REGISTRY_WIRE_VERSION: u32 = 1;

/// The per-rank metrics registry: fixed arrays indexed by the catalogs
/// above, so recording never allocates.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: [u64; ALL_COUNTERS.len()],
    gauges: [u64; ALL_GAUGES.len()],
    hists: [Histogram; N_HISTS],
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, c: CounterId, n: u64) {
        self.counters[c.index()] += n;
    }
    #[inline]
    pub fn counter(&self, c: CounterId) -> u64 {
        self.counters[c.index()]
    }
    #[inline]
    pub fn set(&mut self, g: GaugeId, v: u64) {
        self.gauges[g.index()] = v;
    }
    #[inline]
    pub fn gauge(&self, g: GaugeId) -> u64 {
        self.gauges[g.index()]
    }
    #[inline]
    pub fn record(&mut self, h: HistId, v: u64) {
        self.hists[h.index()].record(v);
    }
    #[inline]
    pub fn hist(&self, h: HistId) -> &Histogram {
        &self.hists[h.index()]
    }

    /// Merge another rank's registry: counters add, gauges take the max
    /// (worst rank), histograms add bucket-wise. Integer-only, so merge
    /// order cannot change the result.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += *b;
        }
        for (a, b) in self.gauges.iter_mut().zip(other.gauges.iter()) {
            *a = (*a).max(*b);
        }
        for (a, b) in self.hists.iter_mut().zip(other.hists.iter()) {
            a.merge(b);
        }
    }

    /// Serialize to `u32` words for `Communicator::allgather_into`.
    pub fn encode_words(&self) -> Vec<u32> {
        let mut w = Vec::with_capacity(
            4 + 2 * (self.counters.len() + self.gauges.len() + N_HISTS * (3 + N_BUCKETS)),
        );
        w.push(REGISTRY_WIRE_VERSION);
        w.push(self.counters.len() as u32);
        w.push(self.gauges.len() as u32);
        w.push(N_HISTS as u32);
        let mut push_u64 = |w: &mut Vec<u32>, v: u64| {
            w.push(v as u32);
            w.push((v >> 32) as u32);
        };
        for &c in &self.counters {
            push_u64(&mut w, c);
        }
        for &g in &self.gauges {
            push_u64(&mut w, g);
        }
        for h in &self.hists {
            push_u64(&mut w, h.count);
            push_u64(&mut w, h.sum);
            push_u64(&mut w, h.max);
            for &b in &h.buckets {
                push_u64(&mut w, b);
            }
        }
        w
    }

    /// Inverse of [`MetricsRegistry::encode_words`].
    pub fn decode_words(words: &[u32]) -> anyhow::Result<Self> {
        let mut i = 0usize;
        let mut next = |words: &[u32]| -> anyhow::Result<u32> {
            let v = *words
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("metrics payload truncated at word {i}"))?;
            i += 1;
            Ok(v)
        };
        let version = next(words)?;
        if version != REGISTRY_WIRE_VERSION {
            anyhow::bail!(
                "metrics wire version {version} != supported {REGISTRY_WIRE_VERSION}"
            );
        }
        let (nc, ng, nh) = (next(words)?, next(words)?, next(words)?);
        if nc as usize != ALL_COUNTERS.len()
            || ng as usize != ALL_GAUGES.len()
            || nh as usize != N_HISTS
        {
            anyhow::bail!(
                "metrics catalog mismatch: got {nc}/{ng}/{nh} counters/gauges/hists, \
                 expected {}/{}/{}",
                ALL_COUNTERS.len(),
                ALL_GAUGES.len(),
                N_HISTS
            );
        }
        let mut next_u64 = |words: &[u32]| -> anyhow::Result<u64> {
            let lo = next(words)? as u64;
            let hi = next(words)? as u64;
            Ok(lo | (hi << 32))
        };
        let mut out = Self::default();
        for c in out.counters.iter_mut() {
            *c = next_u64(words)?;
        }
        for g in out.gauges.iter_mut() {
            *g = next_u64(words)?;
        }
        for h in out.hists.iter_mut() {
            h.count = next_u64(words)?;
            h.sum = next_u64(words)?;
            h.max = next_u64(words)?;
            for b in h.buckets.iter_mut() {
                *b = next_u64(words)?;
            }
        }
        Ok(out)
    }

    /// Full registry dump (summary JSONL record, `nestgpu report` input).
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> = ALL_COUNTERS
            .iter()
            .map(|&c| (c.name(), Json::num(self.counter(c) as f64)))
            .collect();
        let gauges: Vec<(&str, Json)> = ALL_GAUGES
            .iter()
            .map(|&g| (g.name(), Json::num(self.gauge(g) as f64)))
            .collect();
        let hists: Vec<(&str, Json)> = ALL_HISTS
            .iter()
            .map(|&h| (h.name(), self.hist(h).to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("hists", Json::obj(hists)),
        ])
    }
}

/// Cross-rank summary attached to rank 0's `SimResult` when observability
/// is on: every rank's registry merged in member order.
#[derive(Clone, Debug)]
pub struct ObsSummary {
    pub n_ranks: usize,
    pub merged: MetricsRegistry,
}

impl ObsSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_ranks", Json::num(self.n_ranks as f64)),
            ("merged", self.merged.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_follow_powers_of_two() {
        // exact log-bucket edges: 0 | [1,1] | [2,3] | [4,7] | [8,15] | …
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(7), 3);
        assert_eq!(Histogram::bucket_of(8), 4);
        for b in 1..N_BUCKETS - 1 {
            let lo = 1u64 << (b - 1);
            let hi = (1u64 << b) - 1;
            assert_eq!(Histogram::bucket_of(lo), b, "lower edge of bucket {b}");
            assert_eq!(Histogram::bucket_of(hi), b, "upper edge of bucket {b}");
        }
    }

    #[test]
    fn bucket_saturates_at_max() {
        assert_eq!(Histogram::bucket_of(u64::MAX), N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(1u64 << 62), N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(1u64 << 63), N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_upper(N_BUCKETS - 1), u64::MAX);
        let mut h = Histogram::default();
        h.record(u64::MAX);
        h.record(1u64 << 63);
        assert_eq!(h.bucket_count(N_BUCKETS - 1), 2);
        assert_eq!(h.max, u64::MAX);
        // saturating sum must not wrap
        assert_eq!(h.sum, u64::MAX);
    }

    #[test]
    fn quantiles_clamp_to_observed_max() {
        let mut h = Histogram::default();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        assert_eq!(h.count, 5);
        // p50 lands in bucket [16,31] -> upper edge 31
        assert_eq!(h.p50(), 31);
        // p95/p100 land in the 1000 bucket [512,1023], clamped to max 1000
        assert_eq!(h.quantile(1.0), 1000);
        assert_eq!(h.p95(), 1000);
        assert_eq!(h.mean(), 220.0);
        let empty = Histogram::default();
        assert_eq!(empty.p50(), 0);
        assert_eq!(empty.quantile(1.0), 0);
    }

    #[test]
    fn registry_roundtrips_through_words() {
        let mut r = MetricsRegistry::new();
        r.add(CounterId::SpikesEmitted, 42);
        r.add(CounterId::Steps, 1000);
        r.set(GaugeId::DevicePeak, u64::MAX - 1);
        r.record(HistId::SpikesPerStep, 7);
        r.record(HistId::PhaseNs(StepPhase::Dynamics), 1_000_000_007);
        let words = r.encode_words();
        let back = MetricsRegistry::decode_words(&words).unwrap();
        assert_eq!(back.counter(CounterId::SpikesEmitted), 42);
        assert_eq!(back.gauge(GaugeId::DevicePeak), u64::MAX - 1);
        assert_eq!(back.hist(HistId::SpikesPerStep).count, 1);
        assert_eq!(
            back.hist(HistId::PhaseNs(StepPhase::Dynamics)).max,
            1_000_000_007
        );
        assert!(MetricsRegistry::decode_words(&words[..8]).is_err());
        let mut bad = words.clone();
        bad[0] = 99;
        assert!(MetricsRegistry::decode_words(&bad).is_err());
    }

    #[test]
    fn merge_is_order_insensitive() {
        let mut a = MetricsRegistry::new();
        a.add(CounterId::SpikesEmitted, 10);
        a.set(GaugeId::HostPeak, 100);
        a.record(HistId::SpikesPerStep, 5);
        let mut b = MetricsRegistry::new();
        b.add(CounterId::SpikesEmitted, 32);
        b.set(GaugeId::HostPeak, 70);
        b.record(HistId::SpikesPerStep, 900);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.encode_words(), ba.encode_words());
        assert_eq!(ab.counter(CounterId::SpikesEmitted), 42);
        assert_eq!(ab.gauge(GaugeId::HostPeak), 100, "gauges merge with max");
        assert_eq!(ab.hist(HistId::SpikesPerStep).count, 2);
        assert_eq!(ab.hist(HistId::SpikesPerStep).max, 900);
    }
}
