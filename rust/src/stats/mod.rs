//! Spiking statistics and the validation protocol (§0.6, Appendix A).
//!
//! Three per-population distributions characterize the network dynamics:
//! time-averaged single-neuron firing rates, coefficients of variation of
//! inter-spike intervals (CV ISI), and pairwise Pearson correlations of
//! binned spike trains over a neuron subset. Distribution differences are
//! quantified with the Earth Mover's Distance (first Wasserstein distance),
//! comparing seed-vs-seed fluctuations against code-vs-code fluctuations.

//!
//! Plastic runs add a fourth characterization: the evolved weight
//! distribution ([`weights`]) — moments, range and an order-sensitive hash
//! used by the STDP determinism tests.

pub mod emd;
pub mod spikes;
pub mod validate;
pub mod weights;

pub use emd::emd;
pub use spikes::{combine_rank_hashes, spike_hash, SpikeData};
pub use weights::WeightSummary;
