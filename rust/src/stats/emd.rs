//! Earth Mover's Distance (first Wasserstein distance) between 1-D sample
//! sets — the metric of the validation protocol (Appendix A), equivalent to
//! `scipy.stats.wasserstein_distance` with unit weights.

/// EMD between two samples (unit weights). O(n log n).
pub fn emd(a: &[f64], b: &[f64]) -> f64 {
    if a.is_empty() || b.is_empty() {
        return if a.is_empty() && b.is_empty() {
            0.0
        } else {
            f64::INFINITY
        };
    }
    let mut xa = a.to_vec();
    let mut xb = b.to_vec();
    xa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    xb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    // integrate |F_a(x) - F_b(x)| over the merged support
    let mut all: Vec<f64> = xa.iter().chain(xb.iter()).copied().collect();
    all.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (na, nb) = (xa.len() as f64, xb.len() as f64);
    let (mut ia, mut ib) = (0usize, 0usize);
    let mut dist = 0.0;
    for w in all.windows(2) {
        let (x0, x1) = (w[0], w[1]);
        while ia < xa.len() && xa[ia] <= x0 {
            ia += 1;
        }
        while ib < xb.len() && xb[ib] <= x0 {
            ib += 1;
        }
        let fa = ia as f64 / na;
        let fb = ib as f64 / nb;
        dist += (fa - fb).abs() * (x1 - x0);
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_samples_zero() {
        let a = vec![1.0, 2.0, 3.0];
        assert!(emd(&a, &a) < 1e-12);
    }

    #[test]
    fn point_masses() {
        // EMD between delta(0) and delta(d) is d
        assert!((emd(&[0.0], &[2.5]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn translation_equals_shift() {
        let a = vec![0.0, 1.0, 2.0, 3.0];
        let b: Vec<f64> = a.iter().map(|x| x + 0.7).collect();
        assert!((emd(&a, &b) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn equal_size_samples_match_mean_transport() {
        // for equal-size samples EMD = mean |sorted_a - sorted_b|
        let mut r = Rng::new(5);
        let a: Vec<f64> = (0..200).map(|_| r.uniform()).collect();
        let b: Vec<f64> = (0..200).map(|_| r.uniform() + 0.1).collect();
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let direct: f64 = sa
            .iter()
            .zip(&sb)
            .map(|(x, y)| (x - y).abs())
            .sum::<f64>()
            / 200.0;
        assert!((emd(&a, &b) - direct).abs() < 1e-9);
    }

    #[test]
    fn unequal_sizes_supported() {
        let a = vec![0.0, 0.0, 0.0, 0.0];
        let b = vec![1.0];
        assert!((emd(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scipy_golden_value() {
        // scipy.stats.wasserstein_distance([3.4,3.9,7.5,7.8],[4.5,1.4]) == 2.7
        let d = emd(&[3.4, 3.9, 7.5, 7.8], &[4.5, 1.4]);
        assert!((d - 2.7).abs() < 1e-9, "d={d}");
    }

    #[test]
    fn empty_handling() {
        assert_eq!(emd(&[], &[]), 0.0);
        assert!(emd(&[1.0], &[]).is_infinite());
    }
}
