//! Validation protocol (Appendix A): compare the spiking statistics of the
//! *onboard* and *offboard* construction methods.
//!
//! Because the new construction method changes the random number streams,
//! network instances differ even under the same seed; validation is
//! therefore statistical. For each population and each statistic (rate,
//! CV ISI, Pearson correlation) the protocol compares:
//!   - **seed-vs-seed**: pairwise EMD between runs of the *same* code with
//!     different seeds (the intrinsic fluctuation scale), and
//!   - **code-vs-code**: pairwise EMD between runs of the two code paths.
//! The methods are compatible when the code-vs-code EMDs fall within the
//! seed-vs-seed distribution (Fig. 8).

use super::emd::emd;
use super::spikes::SpikeData;

/// The three per-population statistic distributions of §0.6.
#[derive(Clone, Debug, Default)]
pub struct StatDistributions {
    pub rates: Vec<f64>,
    pub cv_isi: Vec<f64>,
    pub correlations: Vec<f64>,
}

impl StatDistributions {
    pub fn from_spikes(data: &SpikeData, corr_subset: usize, bin_ms: f64) -> Self {
        Self {
            rates: data.rates(),
            cv_isi: data.cv_isi(),
            correlations: data.pearson_correlations(corr_subset, bin_ms),
        }
    }
}

/// Pairwise EMDs between two sets of distribution samples.
fn pairwise_emd<'a>(
    a: impl Iterator<Item = &'a Vec<f64>> + Clone,
    b: impl Iterator<Item = &'a Vec<f64>>,
) -> Vec<f64> {
    let mut out = Vec::new();
    for (i, y) in b.enumerate() {
        // pair i-th of b with i-th of a (paper: pairwise fashion, one EMD
        // per simulation pair)
        if let Some(x) = a.clone().nth(i) {
            out.push(emd(x, y));
        }
    }
    out
}

/// EMD comparison summary for one statistic.
#[derive(Clone, Debug, Default)]
pub struct EmdComparison {
    /// pairwise EMDs between the two code paths (code-vs-code)
    pub cross_code: Vec<f64>,
    /// pairwise EMDs between same-code different-seed runs (seed-vs-seed)
    pub cross_seed: Vec<f64>,
}

impl EmdComparison {
    /// The validation verdict: the code-vs-code median must not exceed the
    /// seed-vs-seed median by more than `tolerance_factor`.
    pub fn compatible(&self, tolerance_factor: f64) -> bool {
        let med = |xs: &[f64]| crate::util::table::median_iqr(xs).0;
        if self.cross_seed.is_empty() || self.cross_code.is_empty() {
            return false;
        }
        let seed_med = med(&self.cross_seed);
        let code_med = med(&self.cross_code);
        code_med <= seed_med * tolerance_factor + f64::EPSILON
    }
}

/// Full validation outcome over the three statistics.
#[derive(Clone, Debug, Default)]
pub struct ValidationReport {
    pub rates: EmdComparison,
    pub cv_isi: EmdComparison,
    pub correlations: EmdComparison,
}

impl ValidationReport {
    /// Build the report from three sets of runs (Appendix A):
    /// `ref_a`, `ref_b` — two sets from the reference (offboard) code with
    /// different seeds; `new` — the set from the new (onboard) code.
    pub fn build(
        ref_a: &[StatDistributions],
        ref_b: &[StatDistributions],
        new: &[StatDistributions],
    ) -> Self {
        let cmp = |pick: fn(&StatDistributions) -> &Vec<f64>| EmdComparison {
            cross_seed: pairwise_emd(ref_a.iter().map(pick), ref_b.iter().map(pick)),
            cross_code: pairwise_emd(ref_a.iter().map(pick), new.iter().map(pick)),
        };
        Self {
            rates: cmp(|d| &d.rates),
            cv_isi: cmp(|d| &d.cv_isi),
            correlations: cmp(|d| &d.correlations),
        }
    }

    pub fn all_compatible(&self, tolerance_factor: f64) -> bool {
        self.rates.compatible(tolerance_factor)
            && self.cv_isi.compatible(tolerance_factor)
            && self.correlations.compatible(tolerance_factor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fake_dist(seed: u64, shift: f64) -> StatDistributions {
        let mut r = Rng::new(seed);
        StatDistributions {
            rates: (0..300).map(|_| r.normal_ms(8.0 + shift, 2.0)).collect(),
            cv_isi: (0..300).map(|_| r.normal_ms(0.9 + shift, 0.1)).collect(),
            correlations: (0..300).map(|_| r.normal_ms(shift, 0.05)).collect(),
        }
    }

    #[test]
    fn same_process_is_compatible() {
        let ref_a: Vec<_> = (0..5).map(|i| fake_dist(i, 0.0)).collect();
        let ref_b: Vec<_> = (10..15).map(|i| fake_dist(i, 0.0)).collect();
        let new: Vec<_> = (20..25).map(|i| fake_dist(i, 0.0)).collect();
        let rep = ValidationReport::build(&ref_a, &ref_b, &new);
        assert!(rep.all_compatible(2.0));
    }

    #[test]
    fn shifted_process_is_detected() {
        let ref_a: Vec<_> = (0..5).map(|i| fake_dist(i, 0.0)).collect();
        let ref_b: Vec<_> = (10..15).map(|i| fake_dist(i, 0.0)).collect();
        // the "new code" fires 3 Hz higher: must fail validation
        let new: Vec<_> = (20..25).map(|i| fake_dist(i, 3.0)).collect();
        let rep = ValidationReport::build(&ref_a, &ref_b, &new);
        assert!(!rep.rates.compatible(2.0));
        assert!(!rep.all_compatible(2.0));
    }

    #[test]
    fn empty_runs_are_incompatible() {
        let rep = ValidationReport::build(&[], &[], &[]);
        assert!(!rep.all_compatible(2.0));
    }
}
