//! Weight-distribution summaries for plasticity validation (DESIGN.md
//! §12). A plastic run is characterized by what STDP did to the weights:
//! the moments and range say whether the distribution drifted, spread or
//! saturated at a bound, and the order-sensitive FNV-1a hash gives a
//! one-word bit-identity check for determinism tests (equal hashes over
//! the same synapse order ⇔ bit-identical weight arrays, up to hash
//! collision).

use crate::snapshot::format::{fnv1a64_fold, FNV1A64_OFFSET};

/// Summary of one rank's plastic-weight distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightSummary {
    pub n: u64,
    pub mean: f64,
    /// population standard deviation
    pub sd: f64,
    pub min: f32,
    pub max: f32,
    /// FNV-1a 64 over the little-endian f32 bytes, in iteration order
    /// (the same hash the snapshot checksums use)
    pub hash: u64,
}

impl WeightSummary {
    /// Summarize weights in iteration order (the order feeds the hash).
    pub fn from_weights(weights: impl Iterator<Item = f32>) -> Self {
        let mut n = 0u64;
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        let mut hash = FNV1A64_OFFSET;
        for w in weights {
            n += 1;
            sum += w as f64;
            sum_sq += (w as f64) * (w as f64);
            min = min.min(w);
            max = max.max(w);
            hash = fnv1a64_fold(hash, &w.to_le_bytes());
        }
        if n == 0 {
            return Self {
                n: 0,
                mean: 0.0,
                sd: 0.0,
                min: 0.0,
                max: 0.0,
                hash,
            };
        }
        let mean = sum / n as f64;
        let var = (sum_sq / n as f64 - mean * mean).max(0.0);
        Self {
            n,
            mean,
            sd: var.sqrt(),
            min,
            max,
            hash,
        }
    }
}

/// Fixed-range histogram of a weight population (`bins` equal-width bins
/// over `[lo, hi]`; out-of-range samples clamp into the edge bins, so the
/// counts always sum to the population size).
pub fn histogram(weights: impl Iterator<Item = f32>, lo: f32, hi: f32, bins: usize) -> Vec<u64> {
    assert!(bins >= 1 && hi > lo);
    let mut out = vec![0u64; bins];
    let width = (hi - lo) as f64 / bins as f64;
    for w in weights {
        let i = (((w - lo) as f64 / width) as isize).clamp(0, bins as isize - 1);
        out[i as usize] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments_and_range() {
        let s = WeightSummary::from_weights([1.0f32, 2.0, 3.0, 4.0].into_iter());
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.sd - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn empty_summary() {
        let s = WeightSummary::from_weights(std::iter::empty());
        assert_eq!(s.n, 0);
        assert_eq!(s.hash, FNV1A64_OFFSET);
    }

    #[test]
    fn hash_is_order_sensitive_and_matches_bitwise_equality() {
        let a = WeightSummary::from_weights([1.0f32, 2.0].into_iter());
        let b = WeightSummary::from_weights([1.0f32, 2.0].into_iter());
        let c = WeightSummary::from_weights([2.0f32, 1.0].into_iter());
        assert_eq!(a.hash, b.hash);
        assert_ne!(a.hash, c.hash);
        // -0.0 and 0.0 differ bitwise, so their hashes must differ too
        let z = WeightSummary::from_weights([0.0f32].into_iter());
        let nz = WeightSummary::from_weights([-0.0f32].into_iter());
        assert_ne!(z.hash, nz.hash);
    }

    #[test]
    fn histogram_covers_and_clamps() {
        let h = histogram([-1.0f32, 0.1, 0.9, 0.5, 2.0].into_iter(), 0.0, 1.0, 2);
        assert_eq!(h.iter().sum::<u64>(), 5);
        assert_eq!(h, vec![2, 3]); // -1.0 clamps low, 2.0 clamps high
    }
}
