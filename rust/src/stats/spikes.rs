//! Spike-train statistics: rates, CV ISI, pairwise Pearson correlation,
//! and the order-sensitive spike-train hash of the cross-transport
//! bit-identity checks.

use crate::snapshot::format::{fnv1a64_fold, FNV1A64_OFFSET};

/// Order-sensitive FNV-1a hash of a rank's recorded `(step, node)` spike
/// events — the compact bit-identity witness used when full spike lists
/// cannot be compared in one process (multi-process socket runs, CI
/// cross-transport smoke checks).
pub fn spike_hash(events: &[(u32, u32)]) -> u64 {
    let mut h = FNV1A64_OFFSET;
    for &(step, node) in events {
        h = fnv1a64_fold(h, &step.to_le_bytes());
        h = fnv1a64_fold(h, &node.to_le_bytes());
    }
    h
}

/// Fold per-rank spike hashes (rank order) into one world hash. Two runs
/// agree on this value iff every rank's spike train matched.
pub fn combine_rank_hashes(hashes: &[u64]) -> u64 {
    let mut h = FNV1A64_OFFSET;
    for &rh in hashes {
        h = fnv1a64_fold(h, &rh.to_le_bytes());
    }
    h
}

/// Spike data for one population over a recording window.
pub struct SpikeData {
    /// spike times (steps) per neuron, each ascending
    pub trains: Vec<Vec<u32>>,
    /// recording window in steps
    pub t_steps: u32,
    /// integration step (ms)
    pub dt_ms: f64,
}

impl SpikeData {
    /// Split a flat `(step, node)` event list into per-neuron trains for
    /// nodes `[first, first + n)`.
    pub fn from_events(
        events: &[(u32, u32)],
        first: u32,
        n: u32,
        t_steps: u32,
        dt_ms: f64,
    ) -> Self {
        let mut trains = vec![Vec::new(); n as usize];
        for &(step, node) in events {
            if node >= first && node < first + n {
                trains[(node - first) as usize].push(step);
            }
        }
        for t in trains.iter_mut() {
            t.sort_unstable();
        }
        Self {
            trains,
            t_steps,
            dt_ms,
        }
    }

    /// Time-averaged firing rate per neuron (spikes/s).
    pub fn rates(&self) -> Vec<f64> {
        let t_s = self.t_steps as f64 * self.dt_ms * 1e-3;
        self.trains
            .iter()
            .map(|t| t.len() as f64 / t_s.max(1e-12))
            .collect()
    }

    /// Population mean rate (spikes/s).
    pub fn mean_rate(&self) -> f64 {
        let r = self.rates();
        if r.is_empty() {
            0.0
        } else {
            r.iter().sum::<f64>() / r.len() as f64
        }
    }

    /// CV of inter-spike intervals per neuron (neurons with < 3 spikes are
    /// skipped, as is conventional).
    pub fn cv_isi(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for t in &self.trains {
            if t.len() < 3 {
                continue;
            }
            let isis: Vec<f64> = t.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
            let n = isis.len() as f64;
            let mean = isis.iter().sum::<f64>() / n;
            if mean <= 0.0 {
                continue;
            }
            let var = isis.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
            out.push(var.sqrt() / mean);
        }
        out
    }

    /// Pairwise Pearson correlations of binned spike trains for the first
    /// `subset` neurons with at least one spike (the paper uses 200),
    /// bin width `bin_ms`.
    pub fn pearson_correlations(&self, subset: usize, bin_ms: f64) -> Vec<f64> {
        let bin_steps = (bin_ms / self.dt_ms).round().max(1.0) as u32;
        let n_bins = (self.t_steps / bin_steps).max(1) as usize;
        let active: Vec<&Vec<u32>> = self
            .trains
            .iter()
            .filter(|t| !t.is_empty())
            .take(subset)
            .collect();
        let binned: Vec<Vec<f64>> = active
            .iter()
            .map(|t| {
                let mut b = vec![0.0; n_bins];
                for &s in t.iter() {
                    let i = ((s / bin_steps) as usize).min(n_bins - 1);
                    b[i] += 1.0;
                }
                b
            })
            .collect();
        // standardize
        let stats: Vec<(f64, f64)> = binned
            .iter()
            .map(|b| {
                let mean = b.iter().sum::<f64>() / n_bins as f64;
                let var = b.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n_bins as f64;
                (mean, var.sqrt())
            })
            .collect();
        let mut out = Vec::new();
        for i in 0..binned.len() {
            for j in (i + 1)..binned.len() {
                let (mi, si) = stats[i];
                let (mj, sj) = stats[j];
                if si <= 0.0 || sj <= 0.0 {
                    continue;
                }
                let cov = binned[i]
                    .iter()
                    .zip(&binned[j])
                    .map(|(a, b)| (a - mi) * (b - mj))
                    .sum::<f64>()
                    / n_bins as f64;
                out.push(cov / (si * sj));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spike_hash_is_order_and_content_sensitive() {
        let a = vec![(1u32, 2u32), (3, 4)];
        let swapped = vec![(3u32, 4u32), (1, 2)];
        let tweaked = vec![(1u32, 2u32), (3, 5)];
        assert_eq!(spike_hash(&a), spike_hash(&a.clone()));
        assert_ne!(spike_hash(&a), spike_hash(&swapped));
        assert_ne!(spike_hash(&a), spike_hash(&tweaked));
        assert_ne!(spike_hash(&a), spike_hash(&a[..1]));
        // the empty train hashes to the FNV offset basis, not 0
        assert_eq!(spike_hash(&[]), crate::snapshot::format::FNV1A64_OFFSET);
    }

    #[test]
    fn combined_hash_distinguishes_rank_assignment() {
        let (h0, h1) = (spike_hash(&[(1, 2)]), spike_hash(&[(3, 4)]));
        assert_eq!(combine_rank_hashes(&[h0, h1]), combine_rank_hashes(&[h0, h1]));
        assert_ne!(combine_rank_hashes(&[h0, h1]), combine_rank_hashes(&[h1, h0]));
        assert_ne!(combine_rank_hashes(&[h0]), combine_rank_hashes(&[h0, h1]));
    }

    #[test]
    fn rates_from_events() {
        // 2 neurons over 1000 steps at 0.1 ms = 100 ms
        let events = vec![(10, 5), (20, 5), (30, 6), (40, 5)];
        let d = SpikeData::from_events(&events, 5, 2, 1000, 0.1);
        let r = d.rates();
        assert!((r[0] - 30.0).abs() < 1e-9); // 3 spikes / 0.1 s
        assert!((r[1] - 10.0).abs() < 1e-9);
        assert!((d.mean_rate() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn events_outside_population_ignored() {
        let events = vec![(1, 0), (2, 99)];
        let d = SpikeData::from_events(&events, 5, 2, 100, 0.1);
        assert_eq!(d.trains[0].len(), 0);
        assert_eq!(d.trains[1].len(), 0);
    }

    #[test]
    fn cv_isi_regular_vs_irregular() {
        // perfectly regular train -> CV 0
        let regular: Vec<(u32, u32)> = (1..50).map(|i| (i * 10, 0)).collect();
        let d = SpikeData::from_events(&regular, 0, 1, 1000, 0.1);
        let cv = d.cv_isi();
        assert_eq!(cv.len(), 1);
        assert!(cv[0] < 1e-12);
        // two-interval alternation -> CV > 0
        let mut t = 0;
        let irregular: Vec<(u32, u32)> = (0..50)
            .map(|i| {
                t += if i % 2 == 0 { 2 } else { 18 };
                (t, 0)
            })
            .collect();
        let d = SpikeData::from_events(&irregular, 0, 1, 2000, 0.1);
        assert!(d.cv_isi()[0] > 0.5);
    }

    #[test]
    fn cv_isi_skips_sparse_trains() {
        let d = SpikeData::from_events(&[(1, 0), (2, 0)], 0, 1, 100, 0.1);
        assert!(d.cv_isi().is_empty());
    }

    #[test]
    fn correlation_of_identical_trains_is_one() {
        let ev: Vec<(u32, u32)> = (0..40)
            .flat_map(|i| vec![(i * 25, 0), (i * 25, 1)])
            .collect();
        let d = SpikeData::from_events(&ev, 0, 2, 1000, 0.1);
        let c = d.pearson_correlations(2, 2.0);
        assert_eq!(c.len(), 1);
        assert!((c[0] - 1.0).abs() < 1e-9, "c={}", c[0]);
    }

    #[test]
    fn correlation_of_disjoint_trains_is_negative() {
        // alternating activity in disjoint bins
        let mut ev = Vec::new();
        for i in 0..50u32 {
            if i % 2 == 0 {
                ev.push((i * 20, 0));
            } else {
                ev.push((i * 20, 1));
            }
        }
        let d = SpikeData::from_events(&ev, 0, 2, 1000, 0.1);
        let c = d.pearson_correlations(2, 2.0);
        assert!(c[0] < 0.0);
    }
}
