//! `nestgpu` — launcher CLI for the reproduction.
//!
//! Subcommands (argument parsing is in-tree; clap is not in the offline
//! crate set):
//!
//!   nestgpu info
//!   nestgpu balanced  [--ranks N] [--scale S] [--k-scale K] [--level 0..3]
//!                     [--t-ms T] [--seed X] [--p2p] [--pjrt] [--offboard]
//!                     [--exchange-interval I] [--stdp ...]
//!   nestgpu mam       [--ranks N] [--n-scale S] [--k-scale K] [--chi C]
//!                     [--t-ms T] [--seed X] [--pjrt] [--offboard]
//!                     [--exchange-interval I]
//!   nestgpu estimate  [--live K] [--ranks N] [--scale S] [--level 0..3]
//!   nestgpu validate  [--seeds N] [--t-ms T]
//!   nestgpu phases    [same knobs as balanced] [--json-out PATH]
//!                     [--compare BASE.json] — run the balanced model and
//!                     dump `SimResult::step_phases` as JSON (per-rank
//!                     per-phase ns) for bench trajectories; `--compare`
//!                     prints per-phase deltas vs a baseline captured
//!                     earlier with `--json-out`
//!   nestgpu snapshot save    --dir D [--ranks N] [--scale S] [--k-scale K]
//!                            [--t-ms T] [--level 0..3] [--seed X] [--p2p]
//!                            [--stdp ...]
//!   nestgpu snapshot resume  --dir D [--t-ms T]
//!   nestgpu report <trace-dir> [--json-out PATH] — analyze the JSONL
//!                            traces of a run started with --obs-dir:
//!                            per-rank/per-phase p50/p95/max tables plus
//!                            comm and memory series, and a
//!                            machine-readable summary JSON
//!
//! Observability (DESIGN.md §13): `--obs-dir D` writes per-rank JSONL
//! traces + a run manifest into D; `--obs-interval N` samples a trace
//! record every N steps (default 10). Either flag enables the metrics
//! registry and the merged cross-rank summary printed after the run.
//!
//! `--exchange-interval I` batches remote spike exchange to once every I
//! steps (I is clamped to the minimum remote synaptic delay; 0 or absent =
//! auto, i.e. the min delay itself — bit-identical to per-step exchange).
//!
//! `--stdp` enables trace-based STDP on the recurrent excitatory synapses
//! of the balanced model (DESIGN.md §12). Knobs: `--stdp-lambda L`
//! (learning rate), `--stdp-alpha A` (depression asymmetry),
//! `--stdp-tau-plus MS` / `--stdp-tau-minus MS` (trace time constants),
//! `--stdp-wmax-factor F` (w_max = F · w_E), `--stdp-mult`
//! (multiplicative soft bounds instead of additive + clamp).

use std::collections::HashMap;
use std::path::PathBuf;

use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{
    estimate_cluster, run_cluster, run_cluster_from_snapshot, run_cluster_with_snapshot,
};
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::models::mam::{MamConfig, MamModel};
use nestgpu::obs::{report::read_trace_dir, CounterId, HistId, ObsConfig};
use nestgpu::remote::GpuMemLevel;
use nestgpu::runtime::BackendKind;
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, fmt_secs, Table};
use nestgpu::util::timer::ALL_STEP_PHASES;

struct Args {
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(name.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Self { flags, bools }
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

fn backend(args: &Args) -> BackendKind {
    if args.has("pjrt") {
        let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        BackendKind::Pjrt { artifacts }
    } else {
        BackendKind::Native
    }
}

/// The `--stdp*` knobs of the balanced model (`None` without `--stdp`).
fn stdp_scenario(args: &Args) -> Option<StdpScenario> {
    if !args.has("stdp") {
        return None;
    }
    let d = StdpScenario::default();
    Some(StdpScenario {
        lambda: args.get("stdp-lambda", d.lambda),
        alpha: args.get("stdp-alpha", d.alpha),
        tau_plus_ms: args.get("stdp-tau-plus", d.tau_plus_ms),
        tau_minus_ms: args.get("stdp-tau-minus", d.tau_minus_ms),
        w_max_factor: args.get("stdp-wmax-factor", d.w_max_factor),
        multiplicative: args.has("stdp-mult"),
    })
}

/// Fail fast on invalid `--stdp*` knobs and knob conflicts, before any
/// rank thread launches (the construction-time checks inside the ranks
/// would surface as a worker panic instead of a clean CLI error).
fn check_stdp(args: &Args, bal: &BalancedConfig) -> anyhow::Result<()> {
    if bal.stdp.is_some() && args.has("offboard") {
        return Err(anyhow::anyhow!(
            "--stdp cannot be combined with --offboard (the offboard construction \
             baseline does not support plastic synapses)"
        ));
    }
    if let Some(rule) = bal.stdp_rule() {
        rule.validate()
            .map_err(|e| e.context("invalid --stdp configuration"))?;
        let w0 = bal.w_e() as f32;
        if w0 < rule.w_min || w0 > rule.w_max {
            return Err(anyhow::anyhow!(
                "--stdp-wmax-factor puts the initial E weight {w0} pA outside \
                 the STDP bounds [{}, {}] pA",
                rule.w_min,
                rule.w_max
            ));
        }
    }
    Ok(())
}

/// The balanced-model knobs shared by `balanced`, `phases` and
/// `snapshot save`.
fn balanced_config(args: &Args) -> BalancedConfig {
    BalancedConfig {
        scale: args.get("scale", 0.01f64),
        k_scale: args.get("k-scale", 0.01f64),
        in_degree_scale: args.get("in-degree-scale", 1.0f64),
        j_pa: args.get("j", BalancedConfig::default().j_pa),
        g: args.get("g", BalancedConfig::default().g),
        rate_ext_hz: args.get("rate-ext", BalancedConfig::default().rate_ext_hz),
        j_ext_pa: args.get("j-ext", BalancedConfig::default().j_ext_pa),
        collective: !args.has("p2p"),
        stdp: stdp_scenario(args),
        ..Default::default()
    }
}

/// The `--obs-*` knobs: observability is on when either `--obs-dir` or
/// `--obs-interval` is given.
fn obs_config(args: &Args, label: &str) -> Option<ObsConfig> {
    let trace_dir = args.flags.get("obs-dir").map(PathBuf::from);
    let interval = args.get("obs-interval", 0u64);
    if trace_dir.is_none() && interval == 0 {
        return None;
    }
    let d = ObsConfig::default();
    let sample_interval = if interval == 0 {
        d.sample_interval
    } else {
        interval
    };
    Some(ObsConfig {
        trace_dir,
        sample_interval,
        label: label.to_string(),
        ..d
    })
}

fn sim_config(args: &Args) -> SimConfig {
    sim_config_labeled(args, "cli")
}

fn sim_config_labeled(args: &Args, label: &str) -> SimConfig {
    SimConfig {
        seed: args.get("seed", 123u64),
        level: GpuMemLevel::from_index(args.get("level", 2usize)).unwrap_or_default(),
        backend: backend(args),
        offboard: args.has("offboard"),
        record_spikes: !args.has("no-record"),
        exchange_interval: match args.get("exchange-interval", 0u16) {
            0 => None, // auto: once per minimum remote synaptic delay
            k => Some(k),
        },
        obs: obs_config(args, label),
        ..Default::default()
    }
}

fn print_results(results: &[SimResult], t_ms: f64) {
    if t_ms > 0.0 {
        if let Some(r0) = results.first() {
            println!(
                "spike exchange: every {} step(s); rank 0 comm volume: {} p2p msgs / {}, \
                 {} allgathers / {}",
                r0.exchange_interval,
                r0.p2p_messages,
                fmt_bytes(r0.p2p_bytes),
                r0.coll_calls,
                fmt_bytes(r0.coll_bytes),
            );
        }
    }
    let mut t = Table::new(
        "results",
        &["rank", "neurons", "conns", "images", "spikes", "rate/s", "RTF", "constr", "dev peak"],
    );
    for r in results {
        let rate = if t_ms > 0.0 {
            r.n_spikes as f64 / r.n_neurons.max(1) as f64 / (t_ms / 1e3)
        } else {
            0.0
        };
        t.row(vec![
            r.rank.to_string(),
            r.n_neurons.to_string(),
            r.n_connections.to_string(),
            r.n_images.to_string(),
            r.n_spikes.to_string(),
            format!("{rate:.1}"),
            format!("{:.2}", r.rtf),
            fmt_secs(r.phases.construction().as_secs_f64()),
            fmt_bytes(r.device_peak),
        ]);
    }
    t.print();
    // merged cross-rank observability summary (rank 0 carries it)
    if let Some(obs) = results.iter().find_map(|r| r.obs.as_ref()) {
        let m = &obs.merged;
        println!(
            "obs: {} ranks merged; {} steps, {} spikes, {} exchanges, {} records in",
            obs.n_ranks,
            m.counter(CounterId::Steps),
            m.counter(CounterId::SpikesEmitted),
            m.counter(CounterId::Exchanges),
            m.counter(CounterId::RecordsReceived),
        );
        let mut t = Table::new(
            "merged phase histograms (ns/step, all ranks)",
            &["phase", "count", "p50", "p95", "max"],
        );
        for &p in &ALL_STEP_PHASES {
            let h = m.hist(HistId::PhaseNs(p));
            if h.count == 0 {
                continue;
            }
            t.row(vec![
                p.name().to_string(),
                h.count.to_string(),
                h.p50().to_string(),
                h.p95().to_string(),
                h.max.to_string(),
            ]);
        }
        t.print();
    }
    if results.iter().any(|r| r.n_plastic > 0) {
        let mut t = Table::new(
            "plastic weights (STDP)",
            &["rank", "synapses", "mean", "sd", "min", "max", "hash"],
        );
        for r in results {
            if let Some(p) = &r.plastic {
                t.row(vec![
                    r.rank.to_string(),
                    p.n.to_string(),
                    format!("{:.3}", p.mean),
                    format!("{:.3}", p.sd),
                    format!("{:.3}", p.min),
                    format!("{:.3}", p.max),
                    format!("{:016x}", p.hash),
                ]);
            }
        }
        t.print();
    }
}

fn cmd_balanced(args: &Args) -> anyhow::Result<()> {
    let ranks = args.get("ranks", 2usize);
    let bal = balanced_config(args);
    check_stdp(args, &bal)?;
    let t_ms = args.get("t-ms", 100.0f64);
    println!(
        "balanced: {ranks} ranks x {} neurons, K_in {}, {} exchange, level {}{}",
        bal.neurons_per_rank(),
        bal.kin_e() + bal.kin_i(),
        if bal.collective { "collective" } else { "p2p" },
        sim_config(args).level.name(),
        if bal.stdp.is_some() { ", STDP on E synapses" } else { "" },
    );
    let cfg = sim_config_labeled(args, "balanced");
    let results = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )?;
    print_results(&results, t_ms);
    Ok(())
}

fn cmd_mam(args: &Args) -> anyhow::Result<()> {
    let ranks = args.get("ranks", 4usize);
    let mam_cfg = MamConfig {
        n_scale: args.get("n-scale", 0.001f64),
        k_scale: args.get("k-scale", 0.01f64),
        chi: args.get("chi", 1.9f64),
        kcc_base: 1500.0,
    };
    let t_ms = args.get("t-ms", 100.0f64);
    let m = MamModel::new(mam_cfg.clone());
    println!(
        "MAM: {} neurons over 32 areas on {ranks} ranks (chi {}), p2p exchange",
        m.total_neurons(),
        mam_cfg.chi
    );
    let cfg = sim_config_labeled(args, "mam");
    let results = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| {
            let m = MamModel::new(mam_cfg.clone());
            let p = m.pack(sim.n_ranks());
            m.build(sim, &p);
        },
        t_ms,
    )?;
    print_results(&results, t_ms);
    Ok(())
}

fn cmd_estimate(args: &Args) -> anyhow::Result<()> {
    let live = args.get("live", 2usize);
    let ranks = args.get("ranks", 1024usize);
    let bal = BalancedConfig {
        scale: args.get("scale", 0.01f64),
        k_scale: args.get("k-scale", 0.01f64),
        ..Default::default()
    };
    println!(
        "estimation: {live} live ranks dry-running a {ranks}-rank world \
         (construction + preparation only)"
    );
    let cfg = sim_config(args);
    let results = estimate_cluster(
        live,
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
    )?;
    print_results(&results, 0.0);
    Ok(())
}

/// `nestgpu phases`: run the balanced model and dump the per-rank
/// step-phase breakdown as JSON, so bench trajectories can track where
/// propagation time goes as pipeline phases are added.
fn cmd_phases(args: &Args) -> anyhow::Result<()> {
    let ranks = args.get("ranks", 2usize);
    let bal = balanced_config(args);
    check_stdp(args, &bal)?;
    let t_ms = args.get("t-ms", 100.0f64);
    let cfg = sim_config_labeled(args, "phases");
    let stdp_on = bal.stdp.is_some();
    let protocol = if bal.collective { "collective" } else { "p2p" };
    let results = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )?;
    let per_rank: Vec<Json> = results
        .iter()
        .map(|r| {
            let phases: Vec<(&str, Json)> = ALL_STEP_PHASES
                .iter()
                .map(|&p| (p.name(), Json::num(r.step_phases.get(p).as_nanos() as f64)))
                .collect();
            Json::obj(vec![
                ("rank", Json::num(r.rank as f64)),
                ("step_phases_ns", Json::obj(phases)),
                (
                    "propagation_ns",
                    Json::num(r.phases.propagation.as_nanos() as f64),
                ),
                ("rtf", Json::num(r.rtf)),
                ("n_plastic", Json::num(r.n_plastic as f64)),
            ])
        })
        .collect();
    let out = Json::obj(vec![
        ("model", Json::str("balanced")),
        ("ranks", Json::num(ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        (
            "exchange_interval",
            Json::num(results.first().map_or(0.0, |r| r.exchange_interval as f64)),
        ),
        ("protocol", Json::str(protocol)),
        ("stdp", Json::Bool(stdp_on)),
        ("per_rank", Json::Arr(per_rank)),
    ]);
    let text = out.to_string();
    println!("{text}");
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, &text)
            .map_err(|e| anyhow::anyhow!("write --json-out {path}: {e}"))?;
        eprintln!("phases JSON written to {path}");
    }
    if let Some(base) = args.flags.get("compare") {
        print_phase_compare(&out, std::path::Path::new(base))?;
    }
    Ok(())
}

/// `nestgpu phases --compare BASE.json`: per-phase deltas of the current
/// run vs a baseline captured earlier with `--json-out` (ns summed over
/// ranks) — the before/after proof table for delivery/dynamics perf work.
fn print_phase_compare(current: &Json, base_path: &std::path::Path) -> anyhow::Result<()> {
    let base = Json::parse_file(base_path)
        .map_err(|e| anyhow::anyhow!("--compare {}: {e}", base_path.display()))?;
    let sum_phase = |doc: &Json, phase: &str| -> f64 {
        doc.get("per_rank").and_then(|p| p.as_arr()).map_or(0.0, |ranks| {
            ranks
                .iter()
                .filter_map(|r| r.get("step_phases_ns")?.get(phase)?.as_f64())
                .sum()
        })
    };
    let mut t = Table::new(
        &format!("phase deltas vs {}", base_path.display()),
        &["phase", "baseline", "current", "delta"],
    );
    let (mut b_total, mut c_total) = (0.0, 0.0);
    for p in ALL_STEP_PHASES {
        let (b, c) = (sum_phase(&base, p.name()), sum_phase(current, p.name()));
        b_total += b;
        c_total += c;
        if b == 0.0 && c == 0.0 {
            continue; // phase inactive in both runs (e.g. plasticity off)
        }
        t.row(vec![
            p.name().to_string(),
            fmt_phase_ns(b),
            fmt_phase_ns(c),
            fmt_delta(b, c),
        ]);
    }
    t.row(vec![
        "TOTAL".to_string(),
        fmt_phase_ns(b_total),
        fmt_phase_ns(c_total),
        fmt_delta(b_total, c_total),
    ]);
    t.print();
    Ok(())
}

fn fmt_phase_ns(ns: f64) -> String {
    fmt_secs(ns / 1e9)
}

fn fmt_delta(base: f64, cur: f64) -> String {
    if base <= 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (cur - base) / base * 100.0)
}

/// `nestgpu report <trace-dir>`: render the per-rank/per-phase latency,
/// comm and memory statistics extracted from a run's JSONL traces, and
/// write the machine-readable summary JSON.
fn cmd_report(argv: &[String]) -> anyhow::Result<()> {
    // first positional (non-flag, non-flag-value) argument is the dir;
    // `--dir D` also accepted
    let args = Args::parse(argv);
    let mut positional: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if a.starts_with("--") {
            // skip the flag and its value (mirrors Args::parse)
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 2;
            } else {
                i += 1;
            }
        } else {
            positional = Some(a.clone());
            break;
        }
    }
    let dir = positional
        .or_else(|| args.flags.get("dir").cloned())
        .map(PathBuf::from)
        .ok_or_else(|| {
            anyhow::anyhow!("usage: nestgpu report <trace-dir> [--json-out PATH]")
        })?;
    let rep = read_trace_dir(&dir)?;

    if let Some(m) = &rep.manifest {
        println!(
            "run '{}': {} ranks, {} ms, exchange every {} step(s), sampled every {} step(s), \
             rev {} ({})",
            m.get("label").and_then(|v| v.as_str()).unwrap_or("?"),
            m.get("n_ranks").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("t_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("exchange_interval").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("sample_interval").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("git_rev").and_then(|v| v.as_str()).unwrap_or("?"),
            m.get("created").and_then(|v| v.as_str()).unwrap_or("?"),
        );
    } else {
        println!("(no valid manifest.json in {})", dir.display());
    }

    let mut t = Table::new(
        "per-rank phase latency (ns per sampled step)",
        &["rank", "phase", "p50", "p95", "max", "mean"],
    );
    for r in &rep.ranks {
        for (p, s) in ALL_STEP_PHASES.iter().zip(r.phase_ns.iter()) {
            if s.count == 0 || s.max == 0 {
                continue;
            }
            t.row(vec![
                r.rank.to_string(),
                p.name().to_string(),
                s.p50.to_string(),
                s.p95.to_string(),
                s.max.to_string(),
                format!("{:.0}", s.mean),
            ]);
        }
    }
    t.print();

    let mut t = Table::new(
        "per-rank comm + memory",
        &[
            "rank", "samples", "spikes p95", "p2p msgs", "p2p", "allgathers", "coll",
            "dev peak", "host peak",
        ],
    );
    for r in &rep.ranks {
        t.row(vec![
            r.rank.to_string(),
            r.samples.to_string(),
            r.spikes.p95.to_string(),
            r.p2p_messages.to_string(),
            fmt_bytes(r.p2p_bytes),
            r.coll_calls.to_string(),
            fmt_bytes(r.coll_bytes),
            fmt_bytes(r.dev_peak),
            fmt_bytes(r.host_peak),
        ]);
    }
    t.print();

    let out_path = args
        .flags
        .get("json-out")
        .map(PathBuf::from)
        .unwrap_or_else(|| dir.join("report.json"));
    std::fs::write(&out_path, rep.to_json().to_string())
        .map_err(|e| anyhow::anyhow!("write {}: {e}", out_path.display()))?;
    println!("summary JSON written to {}", out_path.display());
    Ok(())
}

fn cmd_snapshot(argv: &[String]) -> anyhow::Result<()> {
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let dir = PathBuf::from(
        args.flags
            .get("dir")
            .cloned()
            .unwrap_or_else(|| "snapshots".to_string()),
    );
    match sub {
        "save" => {
            let ranks = args.get("ranks", 2usize);
            let bal = balanced_config(&args);
            check_stdp(&args, &bal)?;
            // model time to propagate before checkpointing; 0 = pure
            // construction cache (save right after prepare())
            let t_ms = args.get("t-ms", 0.0f64);
            let cfg = sim_config(&args);
            println!(
                "snapshot save: {ranks} ranks x {} neurons, {t_ms} ms pre-roll -> {}/rank_<r>.snap",
                bal.neurons_per_rank(),
                dir.display()
            );
            let results = run_cluster_with_snapshot(
                ranks,
                &cfg,
                &move |sim: &mut Simulator| build_balanced(sim, &bal),
                t_ms,
                &dir,
            )?;
            print_results(&results, t_ms);
            Ok(())
        }
        "resume" => {
            let t_ms = args.get("t-ms", 100.0f64);
            let (_, n_ranks, step) = nestgpu::engine::peek_world(
                &dir.join(nestgpu::snapshot::rank_file_name(0)),
            )?;
            println!(
                "snapshot resume: {n_ranks} ranks from {} (checkpoint at step {step}), {t_ms} ms",
                dir.display()
            );
            let results = run_cluster_from_snapshot(&dir, t_ms)?;
            print_results(&results, t_ms);
            Ok(())
        }
        other => {
            eprintln!(
                "unknown snapshot subcommand '{other}'; try: snapshot save | snapshot resume"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_info() {
    println!("nestgpu-rs — Scalable Construction of Spiking Neural Networks (CS.DC 2025)");
    println!("three-layer reproduction: Rust coordinator / JAX model / Pallas kernel (AOT via PJRT)");
    println!();
    println!("GPU memory levels: 0..3 (default 2); communication: p2p + collective");
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!(
        "artifacts: {} ({})",
        artifacts.display(),
        if artifacts.join("manifest.json").exists() {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("info");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "balanced" => cmd_balanced(&args),
        "mam" => cmd_mam(&args),
        "estimate" => cmd_estimate(&args),
        "phases" => cmd_phases(&args),
        "report" => cmd_report(&argv[1.min(argv.len())..]),
        "snapshot" => cmd_snapshot(&argv[1.min(argv.len())..]),
        "info" | "--help" | "-h" => {
            cmd_info();
            Ok(())
        }
        other => {
            eprintln!(
                "unknown subcommand '{other}'; try: info | balanced | mam | estimate | \
                 phases | report | snapshot"
            );
            std::process::exit(2);
        }
    }
}
