//! `nestgpu` — launcher CLI for the reproduction.
//!
//! Subcommands (argument parsing is in-tree; clap is not in the offline
//! crate set):
//!
//!   nestgpu info
//!   nestgpu balanced  [--ranks N] [--scale S] [--k-scale K] [--level 0..3]
//!                     [--t-ms T] [--seed X] [--p2p] [--pjrt] [--offboard]
//!                     [--exchange-interval I] [--stdp ...]
//!                     [--connectivity materialized|procedural]
//!   nestgpu mam       [--ranks N] [--n-scale S] [--k-scale K] [--chi C]
//!                     [--t-ms T] [--seed X] [--pjrt] [--offboard]
//!                     [--exchange-interval I]
//!   nestgpu estimate  [--live K] [--ranks N] [--scale S] [--level 0..3]
//!   nestgpu phases    [same knobs as balanced] [--json-out PATH]
//!                     [--compare BASE.json] — run the balanced model and
//!                     dump `SimResult::step_phases` as JSON (per-rank
//!                     per-phase ns) for bench trajectories; `--compare`
//!                     prints per-phase deltas vs a baseline captured
//!                     earlier with `--json-out`
//!   nestgpu snapshot save    --dir D [--ranks N] [--scale S] [--k-scale K]
//!                            [--t-ms T] [--level 0..3] [--seed X] [--p2p]
//!                            [--stdp ...]
//!   nestgpu snapshot resume  --dir D [--t-ms T]
//!   nestgpu report <trace-dir> [--json-out PATH] — analyze the JSONL
//!                            traces of a run started with --obs-dir:
//!                            per-rank/per-phase p50/p95/max tables plus
//!                            comm and memory series, and a
//!                            machine-readable summary JSON
//!   nestgpu launch    [--ranks N] [--rendezvous HOST:PORT]
//!                     <balanced|phases|snapshot> [args...] — spawn N
//!                            local processes of the given subcommand over
//!                            the socket transport (loopback rendezvous
//!                            picked automatically unless given) and
//!                            verify their world spike hashes agree
//!   nestgpu serve     [--listen HOST:PORT] [--cache-dir D] [--cache-bytes B]
//!                     [--max-jobs J] [--obs-dir D] — construction-cache
//!                            daemon (DESIGN.md §17): serves balanced-model
//!                            jobs from a content-addressed snapshot cache,
//!                            so repeated submits of the same construction
//!                            resume instead of rebuilding
//!   nestgpu submit    [--server HOST:PORT] balanced [--ranks N] [--scale S]
//!                     [--k-scale K] [--t-ms T] [--seed X] [--level 0..3]
//!                     [--exchange-interval I] [--connectivity ...] [--p2p]
//!                     [--stdp ...] — submit one job to a serve daemon and
//!                            print its outcome: cache hit/miss plus the
//!                            world spike hash; `--stats` / `--shutdown`
//!                            query or stop the daemon instead
//!
//! Flag parsing is strict: each subcommand declares its flag vocabulary,
//! a valued flag must be followed by a value, and an unknown or
//! misspelled flag aborts with a `did you mean --...?` hint instead of
//! silently falling back to a default.
//!
//! Transport (DESIGN.md §15): every simulation subcommand accepts
//! `--comm socket --rank R --world N --rendezvous HOST:PORT` to run as one
//! rank of a multi-process world over TCP instead of in-process threads
//! (`--connect-timeout-ms` / `--recv-timeout-ms` tune the failure
//! detectors). `nestgpu launch` wires those flags up for N local
//! processes; spreading the same commands across machines only changes
//! the rendezvous host. Spike trains are bit-identical across transports;
//! after propagation every rank prints the world-combined spike hash.
//!
//! Observability (DESIGN.md §13): `--obs-dir D` writes per-rank JSONL
//! traces + a run manifest into D; `--obs-interval N` samples a trace
//! record every N steps (default 10). Either flag enables the metrics
//! registry and the merged cross-rank summary printed after the run.
//!
//! `--exchange-interval I` batches remote spike exchange to once every I
//! steps (I is clamped to the minimum remote synaptic delay; 0 or absent =
//! auto, i.e. the min delay itself — bit-identical to per-step exchange).
//!
//! `--connectivity procedural` (DESIGN.md §16) keeps static connectivity
//! as compact connect-call descriptors and regenerates each spiking
//! neuron's fanout from the captured RNG state at delivery time, instead
//! of materializing every synapse at construction. Spike trains are
//! bit-identical to the default `materialized` mode; plastic (STDP)
//! synapses stay materialized in both modes. Accepted by `balanced`,
//! `phases` and `snapshot save` (the mode travels inside snapshots).
//!
//! `--stdp` enables trace-based STDP on the recurrent excitatory synapses
//! of the balanced model (DESIGN.md §12). Knobs: `--stdp-lambda L`
//! (learning rate), `--stdp-alpha A` (depression asymmetry),
//! `--stdp-tau-plus MS` / `--stdp-tau-minus MS` (trace time constants),
//! `--stdp-wmax-factor F` (w_max = F · w_E), `--stdp-mult`
//! (multiplicative soft bounds instead of additive + clamp).

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use nestgpu::comm::{Communicator, SocketComm, SocketConfig};
use nestgpu::connection::Connectivity;
use nestgpu::engine::{SimConfig, SimResult, Simulator};
use nestgpu::harness::{
    estimate_cluster, free_loopback_addr, run_cluster, run_cluster_from_snapshot,
    run_cluster_processes, run_cluster_with_snapshot, run_rank, run_rank_from_snapshot,
    run_rank_with_snapshot, snapshot_world,
};
use nestgpu::models::balanced::{build_balanced, BalancedConfig, StdpScenario};
use nestgpu::models::mam::{MamConfig, MamModel};
use nestgpu::obs::{report::read_trace_dir, CounterId, HistId, ObsConfig};
use nestgpu::remote::GpuMemLevel;
use nestgpu::runtime::BackendKind;
use nestgpu::serve::{JobSpec, ServeClient, ServeConfig, Server};
use nestgpu::stats::{combine_rank_hashes, spike_hash};
use nestgpu::util::json::Json;
use nestgpu::util::table::{fmt_bytes, fmt_secs, Table};
use nestgpu::util::timer::ALL_STEP_PHASES;

struct Args {
    flags: HashMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
}

/// Flag vocabulary groups: each subcommand passes the union of the
/// groups it understands to [`Args::parse_checked`], so a flag that one
/// subcommand accepts is still a hard error on another.
const COMM_VALUED: &[&str] =
    &["comm", "rank", "world", "rendezvous", "connect-timeout-ms", "recv-timeout-ms"];
const OBS_VALUED: &[&str] = &["obs-dir", "obs-interval"];
const SIM_VALUED: &[&str] = &["seed", "level", "exchange-interval", "connectivity"];
const SIM_BOOLEAN: &[&str] = &["pjrt", "offboard", "no-record"];
const STDP_VALUED: &[&str] = &[
    "stdp-lambda", "stdp-alpha", "stdp-tau-plus", "stdp-tau-minus", "stdp-wmax-factor",
];
const STDP_BOOLEAN: &[&str] = &["stdp", "stdp-mult"];
const BALANCED_VALUED: &[&str] = &[
    "ranks", "t-ms", "scale", "k-scale", "in-degree-scale", "j", "g", "rate-ext", "j-ext",
];
const BALANCED_BOOLEAN: &[&str] = &["p2p"];
const MAM_VALUED: &[&str] = &["ranks", "n-scale", "k-scale", "chi", "t-ms"];
const ESTIMATE_VALUED: &[&str] = &["live", "ranks", "scale", "k-scale"];
const SUBMIT_VALUED: &[&str] = &[
    "ranks", "t-ms", "scale", "k-scale", "seed", "level", "exchange-interval", "connectivity",
];

/// Default `nestgpu serve` / `nestgpu submit` endpoint (loopback);
/// override with `--listen` / `--server`.
const DEFAULT_SERVE_ADDR: &str = "127.0.0.1:9123";

/// The full flag vocabulary `(valued, boolean)` of the balanced-model
/// simulation subcommands (`balanced`, `phases`, `snapshot save`).
fn balanced_flags() -> (Vec<&'static str>, Vec<&'static str>) {
    (
        [BALANCED_VALUED, STDP_VALUED, SIM_VALUED, OBS_VALUED, COMM_VALUED].concat(),
        [BALANCED_BOOLEAN, STDP_BOOLEAN, SIM_BOOLEAN].concat(),
    )
}

impl Args {
    /// Parse `argv` against an explicit flag vocabulary: `valued` flags
    /// consume the next token, `boolean` flags never do, and anything
    /// else starting with `--` is rejected with a hint naming the
    /// closest known flag — a misspelled `--connectivty` must abort the
    /// run, not silently fall back to a default.
    fn parse_checked(argv: &[String], valued: &[&str], boolean: &[&str]) -> anyhow::Result<Args> {
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                positional.push(a.clone());
                i += 1;
                continue;
            };
            if valued.contains(&name) {
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(name.to_string(), v.clone());
                        i += 2;
                    }
                    _ => anyhow::bail!("flag --{name} requires a value"),
                }
            } else if boolean.contains(&name) {
                bools.push(name.to_string());
                i += 1;
            } else {
                return Err(unknown_flag(name, valued, boolean));
            }
        }
        Ok(Args { flags, bools, positional })
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    /// Bail on stray positional tokens for subcommands that take none.
    fn no_positionals(&self, cmd: &str) -> anyhow::Result<()> {
        if let Some(p) = self.positional.first() {
            anyhow::bail!("unexpected argument {p:?} to `nestgpu {cmd}`");
        }
        Ok(())
    }
}

/// The reject-with-hint error for an unknown flag: names the closest
/// known flag by edit distance, when one is reasonably close.
fn unknown_flag(name: &str, valued: &[&str], boolean: &[&str]) -> anyhow::Error {
    let best = valued
        .iter()
        .chain(boolean)
        .min_by_key(|k| edit_distance(name, k))
        .copied();
    match best {
        Some(b) if edit_distance(name, b) <= 1 + name.len() / 3 => {
            anyhow::anyhow!("unknown flag --{name} (did you mean --{b}?)")
        }
        _ => anyhow::anyhow!("unknown flag --{name}"),
    }
}

/// Levenshtein distance, two-row DP — powers the did-you-mean hint.
fn edit_distance(a: &str, b: &str) -> usize {
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.chars().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn backend(args: &Args) -> BackendKind {
    if args.has("pjrt") {
        let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        BackendKind::Pjrt { artifacts }
    } else {
        BackendKind::Native
    }
}

/// The `--stdp*` knobs of the balanced model (`None` without `--stdp`).
fn stdp_scenario(args: &Args) -> Option<StdpScenario> {
    if !args.has("stdp") {
        return None;
    }
    let d = StdpScenario::default();
    Some(StdpScenario {
        lambda: args.get("stdp-lambda", d.lambda),
        alpha: args.get("stdp-alpha", d.alpha),
        tau_plus_ms: args.get("stdp-tau-plus", d.tau_plus_ms),
        tau_minus_ms: args.get("stdp-tau-minus", d.tau_minus_ms),
        w_max_factor: args.get("stdp-wmax-factor", d.w_max_factor),
        multiplicative: args.has("stdp-mult"),
    })
}

/// Fail fast on invalid `--stdp*` knobs and knob conflicts, before any
/// rank thread launches (the construction-time checks inside the ranks
/// would surface as a worker panic instead of a clean CLI error).
fn check_stdp(args: &Args, bal: &BalancedConfig) -> anyhow::Result<()> {
    if bal.stdp.is_some() && args.has("offboard") {
        return Err(anyhow::anyhow!(
            "--stdp cannot be combined with --offboard (the offboard construction \
             baseline does not support plastic synapses)"
        ));
    }
    if let Some(rule) = bal.stdp_rule() {
        rule.validate()
            .map_err(|e| e.context("invalid --stdp configuration"))?;
        let w0 = bal.w_e() as f32;
        if w0 < rule.w_min || w0 > rule.w_max {
            return Err(anyhow::anyhow!(
                "--stdp-wmax-factor puts the initial E weight {w0} pA outside \
                 the STDP bounds [{}, {}] pA",
                rule.w_min,
                rule.w_max
            ));
        }
    }
    Ok(())
}

/// The balanced-model knobs shared by `balanced`, `phases` and
/// `snapshot save`.
fn balanced_config(args: &Args) -> BalancedConfig {
    BalancedConfig {
        scale: args.get("scale", 0.01f64),
        k_scale: args.get("k-scale", 0.01f64),
        in_degree_scale: args.get("in-degree-scale", 1.0f64),
        j_pa: args.get("j", BalancedConfig::default().j_pa),
        g: args.get("g", BalancedConfig::default().g),
        rate_ext_hz: args.get("rate-ext", BalancedConfig::default().rate_ext_hz),
        j_ext_pa: args.get("j-ext", BalancedConfig::default().j_ext_pa),
        collective: !args.has("p2p"),
        stdp: stdp_scenario(args),
        ..Default::default()
    }
}

/// The `--obs-*` knobs: observability is on when either `--obs-dir` or
/// `--obs-interval` is given.
fn obs_config(args: &Args, label: &str) -> Option<ObsConfig> {
    let trace_dir = args.flags.get("obs-dir").map(PathBuf::from);
    let interval = args.get("obs-interval", 0u64);
    if trace_dir.is_none() && interval == 0 {
        return None;
    }
    let d = ObsConfig::default();
    let sample_interval = if interval == 0 {
        d.sample_interval
    } else {
        interval
    };
    Some(ObsConfig {
        trace_dir,
        sample_interval,
        label: label.to_string(),
        ..d
    })
}

/// The `--comm` knobs: `Some(SocketConfig)` iff this process should run as
/// one rank of a multi-process socket world (`--comm socket --rank R
/// --world N --rendezvous HOST:PORT`); `None` selects the in-process
/// thread transport (the default, also spelled `--comm thread`).
fn socket_config(args: &Args) -> anyhow::Result<Option<SocketConfig>> {
    match args.flags.get("comm").map(String::as_str) {
        None | Some("thread") => Ok(None),
        Some("socket") => {
            let rendezvous = args.flags.get("rendezvous").cloned().ok_or_else(|| {
                anyhow::anyhow!("--comm socket requires --rendezvous HOST:PORT")
            })?;
            let world = args.get("world", 0usize);
            anyhow::ensure!(world >= 1, "--comm socket requires --world N (N >= 1)");
            let mut cfg = SocketConfig::new(rendezvous, world);
            if let Some(r) = args.flags.get("rank") {
                let r: usize = r
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--rank must be a rank index"))?;
                anyhow::ensure!(r < world, "--rank {r} outside --world {world}");
                cfg.rank = Some(r);
            }
            let connect_ms = args.get("connect-timeout-ms", 0u64);
            if connect_ms > 0 {
                cfg.connect_timeout = Duration::from_millis(connect_ms);
            }
            let recv_ms = args.get("recv-timeout-ms", 0u64);
            if recv_ms > 0 {
                cfg.recv_timeout = Duration::from_millis(recv_ms);
            }
            Ok(Some(cfg))
        }
        Some(other) => anyhow::bail!("unknown --comm backend '{other}' (thread | socket)"),
    }
}

/// Connect this process's rank to the socket world, with a banner naming
/// the endpoint (start order is free — the rendezvous retries/blocks).
fn connect_socket(scfg: &SocketConfig) -> anyhow::Result<SocketComm> {
    let comm = SocketComm::connect(scfg)?;
    println!(
        "socket transport: rank {} of {} via rendezvous {}",
        comm.rank(),
        comm.size(),
        scfg.rendezvous
    );
    Ok(comm)
}

const WORLD_HASH_PREFIX: &str = "world spike hash: ";

/// The cross-transport bit-identity witness line; `nestgpu launch` and CI
/// compare this value across transports and process layouts.
fn print_world_hash(hash: u64) {
    println!("{WORLD_HASH_PREFIX}{hash:016x}");
}

/// World hash of an in-process run: fold the per-rank spike-train hashes
/// in rank order (identical to the collective gather the socket ranks do).
fn world_hash_of(results: &[SimResult]) -> u64 {
    let hashes: Vec<u64> = results.iter().map(|r| spike_hash(&r.spikes)).collect();
    combine_rank_hashes(&hashes)
}

/// The `--connectivity` knob (default: materialized). Rejected early when
/// combined with `--offboard` — the offboard construction baseline always
/// materializes, so the combination would only panic inside a rank thread.
fn connectivity(args: &Args) -> anyhow::Result<Connectivity> {
    let mode = match args.flags.get("connectivity") {
        None => Connectivity::Materialized,
        Some(v) => Connectivity::parse(v).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown --connectivity mode '{v}' (materialized | procedural)"
            )
        })?,
    };
    if mode == Connectivity::Procedural && args.has("offboard") {
        anyhow::bail!(
            "--connectivity procedural cannot be combined with --offboard \
             (the offboard construction baseline materializes every synapse)"
        );
    }
    Ok(mode)
}

fn sim_config(args: &Args) -> anyhow::Result<SimConfig> {
    sim_config_labeled(args, "cli")
}

fn sim_config_labeled(args: &Args, label: &str) -> anyhow::Result<SimConfig> {
    Ok(SimConfig {
        seed: args.get("seed", 123u64),
        level: GpuMemLevel::from_index(args.get("level", 2usize)).unwrap_or_default(),
        backend: backend(args),
        offboard: args.has("offboard"),
        record_spikes: !args.has("no-record"),
        exchange_interval: match args.get("exchange-interval", 0u16) {
            0 => None, // auto: once per minimum remote synaptic delay
            k => Some(k),
        },
        connectivity: connectivity(args)?,
        obs: obs_config(args, label),
        ..Default::default()
    })
}

fn print_results(results: &[SimResult], t_ms: f64) {
    if t_ms > 0.0 {
        if let Some(r0) = results.first() {
            println!(
                "spike exchange: every {} step(s); rank 0 comm volume: {} p2p msgs / {}, \
                 {} allgathers / {}",
                r0.exchange_interval,
                r0.p2p_messages,
                fmt_bytes(r0.p2p_bytes),
                r0.coll_calls,
                fmt_bytes(r0.coll_bytes),
            );
        }
    }
    let mut t = Table::new(
        "results",
        &["rank", "neurons", "conns", "images", "spikes", "rate/s", "RTF", "constr", "dev peak"],
    );
    for r in results {
        let rate = if t_ms > 0.0 {
            r.n_spikes as f64 / r.n_neurons.max(1) as f64 / (t_ms / 1e3)
        } else {
            0.0
        };
        t.row(vec![
            r.rank.to_string(),
            r.n_neurons.to_string(),
            r.n_connections.to_string(),
            r.n_images.to_string(),
            r.n_spikes.to_string(),
            format!("{rate:.1}"),
            format!("{:.2}", r.rtf),
            fmt_secs(r.phases.construction().as_secs_f64()),
            fmt_bytes(r.device_peak),
        ]);
    }
    t.print();
    // merged cross-rank observability summary (rank 0 carries it)
    if let Some(obs) = results.iter().find_map(|r| r.obs.as_ref()) {
        let m = &obs.merged;
        println!(
            "obs: {} ranks merged; {} steps, {} spikes, {} exchanges, {} records in",
            obs.n_ranks,
            m.counter(CounterId::Steps),
            m.counter(CounterId::SpikesEmitted),
            m.counter(CounterId::Exchanges),
            m.counter(CounterId::RecordsReceived),
        );
        let mut t = Table::new(
            "merged phase histograms (ns/step, all ranks)",
            &["phase", "count", "p50", "p95", "max"],
        );
        for &p in &ALL_STEP_PHASES {
            let h = m.hist(HistId::PhaseNs(p));
            if h.count == 0 {
                continue;
            }
            t.row(vec![
                p.name().to_string(),
                h.count.to_string(),
                h.p50().to_string(),
                h.p95().to_string(),
                h.max.to_string(),
            ]);
        }
        t.print();
    }
    if results.iter().any(|r| r.n_plastic > 0) {
        let mut t = Table::new(
            "plastic weights (STDP)",
            &["rank", "synapses", "mean", "sd", "min", "max", "hash"],
        );
        for r in results {
            if let Some(p) = &r.plastic {
                t.row(vec![
                    r.rank.to_string(),
                    p.n.to_string(),
                    format!("{:.3}", p.mean),
                    format!("{:.3}", p.sd),
                    format!("{:.3}", p.min),
                    format!("{:.3}", p.max),
                    format!("{:016x}", p.hash),
                ]);
            }
        }
        t.print();
    }
}

fn cmd_balanced(argv: &[String]) -> anyhow::Result<()> {
    let (valued, boolean) = balanced_flags();
    let parsed = Args::parse_checked(argv, &valued, &boolean)?;
    let args = &parsed;
    args.no_positionals("balanced")?;
    let ranks = args.get("ranks", 2usize);
    let bal = balanced_config(args);
    check_stdp(args, &bal)?;
    let t_ms = args.get("t-ms", 100.0f64);
    let cfg = sim_config_labeled(args, "balanced")?;
    if let Some(scfg) = socket_config(args)? {
        let comm = connect_socket(&scfg)?;
        let model = {
            let bal = bal.clone();
            move |sim: &mut Simulator| build_balanced(sim, &bal)
        };
        let (res, hash) = run_rank(Box::new(comm), &cfg, &model, t_ms)?;
        print_results(&[res], t_ms);
        print_world_hash(hash);
        return Ok(());
    }
    println!(
        "balanced: {ranks} ranks x {} neurons, K_in {}, {} exchange, level {}, {} connectivity{}",
        bal.neurons_per_rank(),
        bal.kin_e() + bal.kin_i(),
        if bal.collective { "collective" } else { "p2p" },
        cfg.level.name(),
        cfg.connectivity.name(),
        if bal.stdp.is_some() { ", STDP on E synapses" } else { "" },
    );
    let results = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        t_ms,
    )?;
    print_results(&results, t_ms);
    if t_ms > 0.0 {
        print_world_hash(world_hash_of(&results));
    }
    Ok(())
}

fn cmd_mam(argv: &[String]) -> anyhow::Result<()> {
    let valued = [MAM_VALUED, SIM_VALUED, OBS_VALUED].concat();
    let parsed = Args::parse_checked(argv, &valued, SIM_BOOLEAN)?;
    let args = &parsed;
    args.no_positionals("mam")?;
    let ranks = args.get("ranks", 4usize);
    let mam_cfg = MamConfig {
        n_scale: args.get("n-scale", 0.001f64),
        k_scale: args.get("k-scale", 0.01f64),
        chi: args.get("chi", 1.9f64),
        kcc_base: 1500.0,
    };
    let t_ms = args.get("t-ms", 100.0f64);
    let m = MamModel::new(mam_cfg.clone());
    println!(
        "MAM: {} neurons over 32 areas on {ranks} ranks (chi {}), p2p exchange",
        m.total_neurons(),
        mam_cfg.chi
    );
    let cfg = sim_config_labeled(args, "mam")?;
    let results = run_cluster(
        ranks,
        &cfg,
        &move |sim: &mut Simulator| {
            let m = MamModel::new(mam_cfg.clone());
            let p = m.pack(sim.n_ranks());
            m.build(sim, &p);
        },
        t_ms,
    )?;
    print_results(&results, t_ms);
    Ok(())
}

fn cmd_estimate(argv: &[String]) -> anyhow::Result<()> {
    let valued = [ESTIMATE_VALUED, SIM_VALUED, OBS_VALUED].concat();
    let parsed = Args::parse_checked(argv, &valued, SIM_BOOLEAN)?;
    let args = &parsed;
    args.no_positionals("estimate")?;
    let live = args.get("live", 2usize);
    let ranks = args.get("ranks", 1024usize);
    let bal = BalancedConfig {
        scale: args.get("scale", 0.01f64),
        k_scale: args.get("k-scale", 0.01f64),
        ..Default::default()
    };
    println!(
        "estimation: {live} live ranks dry-running a {ranks}-rank world \
         (construction + preparation only)"
    );
    let cfg = sim_config(args)?;
    let results = estimate_cluster(
        live,
        ranks,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
    )?;
    print_results(&results, 0.0);
    Ok(())
}

/// `nestgpu phases`: run the balanced model and dump the per-rank
/// step-phase breakdown as JSON, so bench trajectories can track where
/// propagation time goes as pipeline phases are added.
fn cmd_phases(argv: &[String]) -> anyhow::Result<()> {
    let (mut valued, boolean) = balanced_flags();
    valued.extend_from_slice(&["json-out", "compare"]);
    let parsed = Args::parse_checked(argv, &valued, &boolean)?;
    let args = &parsed;
    args.no_positionals("phases")?;
    let ranks = args.get("ranks", 2usize);
    let bal = balanced_config(args);
    check_stdp(args, &bal)?;
    let t_ms = args.get("t-ms", 100.0f64);
    let cfg = sim_config_labeled(args, "phases")?;
    let stdp_on = bal.stdp.is_some();
    let protocol = if bal.collective { "collective" } else { "p2p" };
    let conn_mode = cfg.connectivity.name();
    let scfg = socket_config(args)?;
    let world_ranks = scfg.as_ref().map_or(ranks, |s| s.world);
    // socket mode: this process is one rank — `per_rank` carries only the
    // local breakdown; the world hash is still the collective one
    let (results, world_hash) = match scfg {
        Some(scfg) => {
            let comm = connect_socket(&scfg)?;
            let model = {
                let bal = bal.clone();
                move |sim: &mut Simulator| build_balanced(sim, &bal)
            };
            let (res, hash) = run_rank(Box::new(comm), &cfg, &model, t_ms)?;
            (vec![res], Some(hash))
        }
        None => {
            let results = run_cluster(
                ranks,
                &cfg,
                &move |sim: &mut Simulator| build_balanced(sim, &bal),
                t_ms,
            )?;
            let hash = (t_ms > 0.0).then(|| world_hash_of(&results));
            (results, hash)
        }
    };
    let per_rank: Vec<Json> = results
        .iter()
        .map(|r| {
            let phases: Vec<(&str, Json)> = ALL_STEP_PHASES
                .iter()
                .map(|&p| (p.name(), Json::num(r.step_phases.get(p).as_nanos() as f64)))
                .collect();
            Json::obj(vec![
                ("rank", Json::num(r.rank as f64)),
                ("step_phases_ns", Json::obj(phases)),
                (
                    "propagation_ns",
                    Json::num(r.phases.propagation.as_nanos() as f64),
                ),
                ("rtf", Json::num(r.rtf)),
                ("n_plastic", Json::num(r.n_plastic as f64)),
            ])
        })
        .collect();
    let out = Json::obj(vec![
        ("model", Json::str("balanced")),
        ("ranks", Json::num(world_ranks as f64)),
        ("t_ms", Json::num(t_ms)),
        (
            "exchange_interval",
            Json::num(results.first().map_or(0.0, |r| r.exchange_interval as f64)),
        ),
        ("protocol", Json::str(protocol)),
        ("stdp", Json::Bool(stdp_on)),
        ("connectivity", Json::str(conn_mode)),
        ("per_rank", Json::Arr(per_rank)),
    ]);
    let text = out.to_string();
    println!("{text}");
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, &text)
            .map_err(|e| anyhow::anyhow!("write --json-out {path}: {e}"))?;
        eprintln!("phases JSON written to {path}");
    }
    if let Some(base) = args.flags.get("compare") {
        print_phase_compare(&out, std::path::Path::new(base))?;
    }
    if let Some(hash) = world_hash {
        print_world_hash(hash);
    }
    Ok(())
}

/// `nestgpu phases --compare BASE.json`: per-phase deltas of the current
/// run vs a baseline captured earlier with `--json-out` (ns summed over
/// ranks) — the before/after proof table for delivery/dynamics perf work.
fn print_phase_compare(current: &Json, base_path: &std::path::Path) -> anyhow::Result<()> {
    let base = Json::parse_file(base_path)
        .map_err(|e| anyhow::anyhow!("--compare {}: {e}", base_path.display()))?;
    let sum_phase = |doc: &Json, phase: &str| -> f64 {
        doc.get("per_rank").and_then(|p| p.as_arr()).map_or(0.0, |ranks| {
            ranks
                .iter()
                .filter_map(|r| r.get("step_phases_ns")?.get(phase)?.as_f64())
                .sum()
        })
    };
    let mut t = Table::new(
        &format!("phase deltas vs {}", base_path.display()),
        &["phase", "baseline", "current", "delta"],
    );
    let (mut b_total, mut c_total) = (0.0, 0.0);
    for p in ALL_STEP_PHASES {
        let (b, c) = (sum_phase(&base, p.name()), sum_phase(current, p.name()));
        b_total += b;
        c_total += c;
        if b == 0.0 && c == 0.0 {
            continue; // phase inactive in both runs (e.g. plasticity off)
        }
        t.row(vec![
            p.name().to_string(),
            fmt_phase_ns(b),
            fmt_phase_ns(c),
            fmt_delta(b, c),
        ]);
    }
    t.row(vec![
        "TOTAL".to_string(),
        fmt_phase_ns(b_total),
        fmt_phase_ns(c_total),
        fmt_delta(b_total, c_total),
    ]);
    t.print();
    Ok(())
}

fn fmt_phase_ns(ns: f64) -> String {
    fmt_secs(ns / 1e9)
}

fn fmt_delta(base: f64, cur: f64) -> String {
    if base <= 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (cur - base) / base * 100.0)
}

/// `nestgpu report <trace-dir>`: render the per-rank/per-phase latency,
/// comm and memory statistics extracted from a run's JSONL traces, and
/// write the machine-readable summary JSON.
fn cmd_report(argv: &[String]) -> anyhow::Result<()> {
    // first positional argument is the trace dir; `--dir D` also accepted
    let args = Args::parse_checked(argv, &["dir", "json-out"], &[])?;
    let dir = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.flags.get("dir").cloned())
        .map(PathBuf::from)
        .ok_or_else(|| {
            anyhow::anyhow!("usage: nestgpu report <trace-dir> [--json-out PATH]")
        })?;
    let rep = read_trace_dir(&dir)?;

    if let Some(m) = &rep.manifest {
        println!(
            "run '{}': {} ranks, {} ms, exchange every {} step(s), sampled every {} step(s), \
             rev {} ({})",
            m.get("label").and_then(|v| v.as_str()).unwrap_or("?"),
            m.get("n_ranks").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("t_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("exchange_interval").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("sample_interval").and_then(|v| v.as_f64()).unwrap_or(0.0),
            m.get("git_rev").and_then(|v| v.as_str()).unwrap_or("?"),
            m.get("created").and_then(|v| v.as_str()).unwrap_or("?"),
        );
        // pre-v16 manifests carry no connectivity field; they were all
        // materialized by construction
        let connectivity = m
            .get("connectivity")
            .and_then(|v| v.as_str())
            .unwrap_or("materialized");
        println!("connectivity: {connectivity}");
        let transport = m.get("transport").and_then(|v| v.as_str()).unwrap_or("thread");
        let endpoints: Vec<&str> = m
            .get("endpoints")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|e| e.as_str()).collect())
            .unwrap_or_default();
        if endpoints.is_empty() {
            println!("transport: {transport} (in-process)");
        } else {
            println!("transport: {transport}; rank endpoints: {}", endpoints.join(", "));
        }
    } else {
        println!("(no valid manifest.json in {})", dir.display());
    }

    let mut t = Table::new(
        "per-rank phase latency (ns per sampled step)",
        &["rank", "phase", "p50", "p95", "max", "mean"],
    );
    for r in &rep.ranks {
        for (p, s) in ALL_STEP_PHASES.iter().zip(r.phase_ns.iter()) {
            if s.count == 0 || s.max == 0 {
                continue;
            }
            t.row(vec![
                r.rank.to_string(),
                p.name().to_string(),
                s.p50.to_string(),
                s.p95.to_string(),
                s.max.to_string(),
                format!("{:.0}", s.mean),
            ]);
        }
    }
    t.print();

    let mut t = Table::new(
        "per-rank comm + memory",
        &[
            "rank", "samples", "spikes p95", "p2p msgs", "p2p", "allgathers", "coll",
            "dev peak", "host peak",
        ],
    );
    for r in &rep.ranks {
        t.row(vec![
            r.rank.to_string(),
            r.samples.to_string(),
            r.spikes.p95.to_string(),
            r.p2p_messages.to_string(),
            fmt_bytes(r.p2p_bytes),
            r.coll_calls.to_string(),
            fmt_bytes(r.coll_bytes),
            fmt_bytes(r.dev_peak),
            fmt_bytes(r.host_peak),
        ]);
    }
    t.print();

    let out_path = args
        .flags
        .get("json-out")
        .map(PathBuf::from)
        .unwrap_or_else(|| dir.join("report.json"));
    std::fs::write(&out_path, rep.to_json().to_string())
        .map_err(|e| anyhow::anyhow!("write {}: {e}", out_path.display()))?;
    println!("summary JSON written to {}", out_path.display());
    Ok(())
}

/// `--dir` with the historical default.
fn snapshot_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.flags.get("dir").cloned().unwrap_or_else(|| "snapshots".to_string()))
}

fn cmd_snapshot(argv: &[String]) -> anyhow::Result<()> {
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("");
    let rest = &argv[1.min(argv.len())..];
    match sub {
        "save" => {
            let (mut valued, boolean) = balanced_flags();
            valued.push("dir");
            let args = Args::parse_checked(rest, &valued, &boolean)?;
            args.no_positionals("snapshot save")?;
            let dir = snapshot_dir(&args);
            let ranks = args.get("ranks", 2usize);
            let bal = balanced_config(&args);
            check_stdp(&args, &bal)?;
            // model time to propagate before checkpointing; 0 = pure
            // construction cache (save right after prepare())
            let t_ms = args.get("t-ms", 0.0f64);
            let cfg = sim_config(&args)?;
            if let Some(scfg) = socket_config(&args)? {
                let comm = connect_socket(&scfg)?;
                let model = {
                    let bal = bal.clone();
                    move |sim: &mut Simulator| build_balanced(sim, &bal)
                };
                let (res, hash) =
                    run_rank_with_snapshot(Box::new(comm), &cfg, &model, t_ms, &dir)?;
                print_results(&[res], t_ms);
                print_world_hash(hash);
                return Ok(());
            }
            println!(
                "snapshot save: {ranks} ranks x {} neurons, {t_ms} ms pre-roll -> {}/rank_<r>.snap",
                bal.neurons_per_rank(),
                dir.display()
            );
            let results = run_cluster_with_snapshot(
                ranks,
                &cfg,
                &move |sim: &mut Simulator| build_balanced(sim, &bal),
                t_ms,
                &dir,
            )?;
            print_results(&results, t_ms);
            if t_ms > 0.0 {
                print_world_hash(world_hash_of(&results));
            }
            Ok(())
        }
        "resume" => {
            let valued = [&["dir", "t-ms"][..], COMM_VALUED].concat();
            let args = Args::parse_checked(rest, &valued, &[])?;
            args.no_positionals("snapshot resume")?;
            let dir = snapshot_dir(&args);
            let t_ms = args.get("t-ms", 100.0f64);
            if let Some(scfg) = socket_config(&args)? {
                let comm = connect_socket(&scfg)?;
                let (res, hash) = run_rank_from_snapshot(Box::new(comm), &dir, t_ms)?;
                print_results(&[res], t_ms);
                print_world_hash(hash);
                return Ok(());
            }
            // completeness is checked up front (missing/partial rank
            // files give the `found K of N rank snapshots` error instead
            // of a worker panic mid-restore)
            let (n_ranks, step) = snapshot_world(&dir)?;
            println!(
                "snapshot resume: {n_ranks} ranks from {} (checkpoint at step {step}), {t_ms} ms",
                dir.display()
            );
            let results = run_cluster_from_snapshot(&dir, t_ms)?;
            print_results(&results, t_ms);
            if t_ms > 0.0 {
                print_world_hash(world_hash_of(&results));
            }
            Ok(())
        }
        other => {
            eprintln!(
                "unknown snapshot subcommand '{other}'; try: snapshot save | snapshot resume"
            );
            std::process::exit(2);
        }
    }
}

/// `nestgpu launch`: spawn N local rank processes of a simulation
/// subcommand over the socket transport (DESIGN.md §15) and verify that
/// every rank reports the same world spike hash — the multi-process
/// counterpart of the in-process thread cluster.
fn cmd_launch(argv: &[String]) -> anyhow::Result<()> {
    // flags before the first non-flag token belong to `launch`; everything
    // from that token on is the child subcommand line, forwarded verbatim
    let mut split = argv.len();
    let mut i = 0;
    while i < argv.len() {
        if argv[i].starts_with("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 2;
            } else {
                i += 1;
            }
        } else {
            split = i;
            break;
        }
    }
    let own = Args::parse_checked(&argv[..split], &["ranks", "rendezvous"], &[])?;
    let child: Vec<String> = argv[split..].to_vec();
    let sub = child.first().map(String::as_str).unwrap_or("");
    if !matches!(sub, "balanced" | "phases" | "snapshot") {
        anyhow::bail!(
            "usage: nestgpu launch [--ranks N] [--rendezvous HOST:PORT] \
             <balanced|phases|snapshot> [args...]"
        );
    }
    let ranks = own.get("ranks", 2usize);
    anyhow::ensure!(ranks >= 1, "--ranks must be >= 1");
    let rendezvous = match own.flags.get("rendezvous") {
        Some(r) => r.clone(),
        None => free_loopback_addr()?,
    };
    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("locate own executable: {e}"))?;
    println!(
        "launch: {ranks} process ranks of `nestgpu {}` via rendezvous {rendezvous}",
        child.join(" ")
    );
    let outputs = run_cluster_processes(&exe, ranks, &child, &rendezvous)?;
    let mut hashes: Vec<String> = Vec::new();
    for (rank, out) in outputs.iter().enumerate() {
        let stdout = String::from_utf8_lossy(&out.stdout);
        for line in stdout.lines() {
            println!("[rank {rank}] {line}");
        }
        for line in String::from_utf8_lossy(&out.stderr).lines() {
            eprintln!("[rank {rank}] {line}");
        }
        let hash = stdout
            .lines()
            .rev()
            .find_map(|l| l.strip_prefix(WORLD_HASH_PREFIX))
            .ok_or_else(|| {
                anyhow::anyhow!("rank {rank} printed no '{WORLD_HASH_PREFIX}' line")
            })?;
        hashes.push(hash.to_string());
    }
    for (rank, hash) in hashes.iter().enumerate() {
        anyhow::ensure!(
            hash == &hashes[0],
            "world spike hash mismatch: rank 0 reports {}, rank {rank} reports {hash} — \
             the ranks disagree on the world spike train",
            hashes[0]
        );
    }
    println!("launch: {ranks} ranks agree; world spike hash {}", hashes[0]);
    Ok(())
}

/// `nestgpu serve`: run the construction-cache daemon (DESIGN.md §17)
/// until a client asks for shutdown (`nestgpu submit --shutdown`).
fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let valued: &[&str] = &["listen", "cache-dir", "cache-bytes", "max-jobs", "obs-dir"];
    let args = Args::parse_checked(argv, valued, &[])?;
    args.no_positionals("serve")?;
    let d = ServeConfig::default();
    let cfg = ServeConfig {
        listen: args
            .flags
            .get("listen")
            .cloned()
            .unwrap_or_else(|| DEFAULT_SERVE_ADDR.to_string()),
        cache_dir: args
            .flags
            .get("cache-dir")
            .map(PathBuf::from)
            .unwrap_or(d.cache_dir),
        cache_bytes: args.get("cache-bytes", d.cache_bytes),
        max_jobs: args.get("max-jobs", d.max_jobs).max(1),
        obs_dir: args.flags.get("obs-dir").map(PathBuf::from),
    };
    let server = Server::bind(cfg.clone())?;
    println!(
        "serve: listening on {} (cache {}, budget {}, max {} concurrent job(s))",
        server.local_addr(),
        cfg.cache_dir.display(),
        fmt_bytes(cfg.cache_bytes),
        cfg.max_jobs,
    );
    server.run()
}

/// `nestgpu submit`: submit one balanced-model job to a serve daemon
/// (or query `--stats` / request `--shutdown`). The `cache: hit|miss`
/// line plus the standard world-spike-hash line are the CI-greppable
/// witnesses that a warm resubmit skipped construction yet reproduced
/// the cold spike train bit-identically.
fn cmd_submit(argv: &[String]) -> anyhow::Result<()> {
    let valued = [&["server"][..], SUBMIT_VALUED, STDP_VALUED].concat();
    let boolean = [&["stats", "shutdown", "p2p"][..], STDP_BOOLEAN].concat();
    let args = Args::parse_checked(argv, &valued, &boolean)?;
    let server = args
        .flags
        .get("server")
        .cloned()
        .unwrap_or_else(|| DEFAULT_SERVE_ADDR.to_string());
    let mut client = ServeClient::connect(&server)?;
    if args.has("stats") {
        let stats = client.stats()?.to_string();
        println!("{stats}");
        return Ok(());
    }
    if args.has("shutdown") {
        client.shutdown()?;
        println!("submit: shutdown requested at {server}");
        return Ok(());
    }
    if args.positional.len() != 1 || args.positional[0] != "balanced" {
        anyhow::bail!(
            "usage: nestgpu submit [--server HOST:PORT] balanced [--ranks N] [--scale S] \
             [--k-scale K] [--t-ms T] [--seed X] [--level 0..3] [--exchange-interval I] \
             [--connectivity ...] [--p2p] [--stdp ...] — or --stats / --shutdown"
        );
    }
    let d = JobSpec::default();
    let spec = JobSpec {
        ranks: args.get("ranks", d.ranks),
        t_ms: args.get("t-ms", d.t_ms),
        scale: args.get("scale", d.scale),
        k_scale: args.get("k-scale", d.k_scale),
        seed: args.get("seed", d.seed),
        level: args.get("level", d.level),
        exchange_interval: match args.get("exchange-interval", 0u16) {
            0 => None, // auto: once per minimum remote synaptic delay
            k => Some(k),
        },
        connectivity: connectivity(&args)?,
        collective: !args.has("p2p"),
        stdp: stdp_scenario(&args),
    };
    println!("submit: {} -> {server}", spec.describe());
    let outcome = client.submit_with(&spec, |state, detail| {
        if detail.is_empty() {
            println!("submit: job {state}");
        } else {
            println!("submit: job {state} ({detail})");
        }
    })?;
    println!(
        "cache: {}{}; construction {:.3}s, wall {:.3}s",
        if outcome.hit { "hit" } else { "miss" },
        if outcome.coalesced { " (coalesced)" } else { "" },
        outcome.construction_s,
        outcome.wall_s,
    );
    let result = outcome.result.to_string();
    println!("result: {result}");
    print_world_hash(outcome.world_hash);
    Ok(())
}

fn cmd_info() {
    println!("nestgpu-rs — Scalable Construction of Spiking Neural Networks (CS.DC 2025)");
    println!("three-layer reproduction: Rust coordinator / JAX model / Pallas kernel (AOT via PJRT)");
    println!();
    println!("GPU memory levels: 0..3 (default 2); communication: p2p + collective");
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!(
        "artifacts: {} ({})",
        artifacts.display(),
        if artifacts.join("manifest.json").exists() {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
    println!();
    println!(
        "subcommands: info | balanced | mam | estimate | phases | report | snapshot | \
         launch | serve | submit"
    );
    println!("construction cache: `nestgpu serve` + `nestgpu submit balanced` (DESIGN.md §17)");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phases_doc(phases: &[(&str, f64)]) -> Json {
        let obj: Vec<(&str, Json)> =
            phases.iter().map(|&(n, v)| (n, Json::num(v))).collect();
        Json::obj(vec![(
            "per_rank",
            Json::Arr(vec![Json::obj(vec![("step_phases_ns", Json::obj(obj))])]),
        )])
    }

    /// `--compare` must tolerate baselines whose phase set differs from
    /// the current run's — e.g. a JSON captured before the `regen` phase
    /// existed, or a materialized baseline compared against a procedural
    /// run. Missing phases count as 0 ns, never panic.
    #[test]
    fn phase_compare_tolerates_differing_phase_sets() {
        let base = phases_doc(&[("deliver", 100.0), ("input", 50.0)]);
        let current = phases_doc(&[("deliver", 80.0), ("regen", 40.0)]);
        let path = std::env::temp_dir().join(format!(
            "nestgpu_phase_cmp_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, base.to_string()).unwrap();
        print_phase_compare(&current, &path).unwrap();
        // symmetric direction: the current run lacks phases the baseline has
        std::fs::write(&path, current.to_string()).unwrap();
        print_phase_compare(&base, &path).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    fn parse_bal(s: &str) -> Args {
        let argv: Vec<String> = s.split(' ').map(String::from).collect();
        let (valued, boolean) = balanced_flags();
        Args::parse_checked(&argv, &valued, &boolean).unwrap()
    }

    #[test]
    fn connectivity_flag_parses_and_rejects() {
        assert_eq!(
            connectivity(&parse_bal("--connectivity procedural")).unwrap(),
            Connectivity::Procedural
        );
        assert_eq!(
            connectivity(&parse_bal("--connectivity materialized")).unwrap(),
            Connectivity::Materialized
        );
        assert_eq!(connectivity(&parse_bal("--t-ms 10")).unwrap(), Connectivity::Materialized);
        assert!(connectivity(&parse_bal("--connectivity lazy")).is_err());
        assert!(connectivity(&parse_bal("--connectivity procedural --offboard")).is_err());
    }

    /// Satellite guarantee: a misspelled flag aborts with a hint naming
    /// the closest known flag instead of silently running defaults.
    #[test]
    fn unknown_flags_are_rejected_with_a_hint() {
        let (valued, boolean) = balanced_flags();
        let argv = vec!["--connectivty".to_string(), "procedural".to_string()];
        let err = Args::parse_checked(&argv, &valued, &boolean).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --connectivty"), "{msg}");
        assert!(msg.contains("did you mean --connectivity?"), "{msg}");
        // a flag with no plausible neighbour gets no misleading hint
        let argv = vec!["--frobnicate-quux".to_string()];
        let err = Args::parse_checked(&argv, &valued, &boolean).unwrap_err();
        assert!(!err.to_string().contains("did you mean"), "{err}");
    }

    #[test]
    fn valued_flags_require_values_and_boolean_flags_take_none() {
        let (valued, boolean) = balanced_flags();
        let argv = vec!["--seed".to_string()];
        let err = Args::parse_checked(&argv, &valued, &boolean).unwrap_err();
        assert!(err.to_string().contains("--seed requires a value"), "{err}");
        // a boolean flag must not swallow the token after it
        let argv = vec!["--stdp".to_string(), "stray".to_string()];
        let args = Args::parse_checked(&argv, &valued, &boolean).unwrap();
        assert!(args.has("stdp"));
        assert_eq!(args.positional, vec!["stray".to_string()]);
        assert!(args.no_positionals("balanced").is_err());
    }

    #[test]
    fn edit_distance_is_levenshtein() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("connectivty", "connectivity"), 1);
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("info");
    let rest = &argv[1.min(argv.len())..];
    match cmd {
        "balanced" => cmd_balanced(rest),
        "mam" => cmd_mam(rest),
        "estimate" => cmd_estimate(rest),
        "phases" => cmd_phases(rest),
        "report" => cmd_report(rest),
        "snapshot" => cmd_snapshot(rest),
        "launch" => cmd_launch(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "info" | "--help" | "-h" => {
            cmd_info();
            Ok(())
        }
        other => {
            eprintln!(
                "unknown subcommand '{other}'; try: info | balanced | mam | estimate | \
                 phases | report | snapshot | launch | serve | submit"
            );
            std::process::exit(2);
        }
    }
}
