//! The aligned per-(σ, τ) generator array `RNG[σ,τ]` (§0.3.1).
//!
//! Both the source MPI process σ and the target MPI process τ seed the same
//! generator for their pair from the master seed — **never** communicating
//! — and consume it *exclusively* for the source-neuron indexes of remote
//! connections. This keeps the source-side `S` sequence and the target-side
//! `(R, L)` map aligned (Eq. 1) across any number of `RemoteConnect` calls,
//! because each call advances the pair's stream identically on both sides.

use crate::util::rng::Rng;

const ALIGNED_TAG: u64 = 0x616C69676E; // "align"

/// Lazily instantiated array of aligned generators for one rank.
pub struct AlignedRngs {
    master: u64,
    n_ranks: usize,
    /// flattened [σ * n + τ], lazily seeded
    rngs: Vec<Option<Rng>>,
}

impl AlignedRngs {
    pub fn new(master: u64, n_ranks: usize) -> Self {
        Self {
            master,
            n_ranks,
            rngs: (0..n_ranks * n_ranks).map(|_| None).collect(),
        }
    }

    /// The generator for the (source σ, target τ) pair. The same call on
    /// rank σ and rank τ yields generators in identical states as long as
    /// both sides have performed the same sequence of draws for this pair.
    pub fn pair(&mut self, sigma: usize, tau: usize) -> &mut Rng {
        assert!(sigma < self.n_ranks && tau < self.n_ranks);
        let idx = sigma * self.n_ranks + tau;
        let master = self.master;
        self.rngs[idx].get_or_insert_with(|| {
            Rng::stream(master, &[ALIGNED_TAG, sigma as u64, tau as u64])
        })
    }

    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    /// Serialize the master seed and the state of every *instantiated*
    /// pair stream (lazily-seeded pairs that were never drawn from are
    /// stored as absent and re-derived on demand after restore).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.u64(self.master);
        enc.u64(self.n_ranks as u64);
        enc.seq_len(self.rngs.len());
        for slot in &self.rngs {
            match slot {
                None => enc.bool(false),
                Some(rng) => {
                    enc.bool(true);
                    enc.rng(rng);
                }
            }
        }
    }

    pub fn snapshot_decode(dec: &mut crate::snapshot::Decoder) -> anyhow::Result<Self> {
        let master = dec.u64()?;
        let n_ranks = dec.u64()? as usize;
        let n = dec.seq_len(1)?;
        if n != n_ranks * n_ranks {
            anyhow::bail!(
                "aligned-RNG snapshot has {n} slots for a {n_ranks}-rank world"
            );
        }
        let mut rngs = Vec::with_capacity(n);
        for _ in 0..n {
            rngs.push(if dec.bool()? { Some(dec.rng()?) } else { None });
        }
        Ok(Self {
            master,
            n_ranks,
            rngs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_sides_see_identical_streams() {
        // rank 0's view of pair (0 -> 3) vs rank 3's view of pair (0 -> 3)
        let mut on_rank0 = AlignedRngs::new(1234, 4);
        let mut on_rank3 = AlignedRngs::new(1234, 4);
        let a: Vec<u64> = (0..100).map(|_| on_rank0.pair(0, 3).next_u64()).collect();
        let b: Vec<u64> = (0..100).map(|_| on_rank3.pair(0, 3).next_u64()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn pairs_are_independent_streams() {
        let mut r = AlignedRngs::new(1234, 3);
        let a = r.pair(0, 1).next_u64();
        let b = r.pair(1, 0).next_u64();
        let c = r.pair(0, 2).next_u64();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn stream_state_persists_across_calls() {
        // successive RemoteConnect calls continue the pair stream
        let mut r = AlignedRngs::new(9, 2);
        let x1 = r.pair(0, 1).next_u64();
        let x2 = r.pair(0, 1).next_u64();
        let mut fresh = AlignedRngs::new(9, 2);
        assert_eq!(fresh.pair(0, 1).next_u64(), x1);
        assert_eq!(fresh.pair(0, 1).next_u64(), x2);
    }

    #[test]
    fn snapshot_continues_consumed_and_lazy_pairs() {
        let mut r = AlignedRngs::new(51, 3);
        // consume pair (0, 2); leave the rest lazy
        for _ in 0..40 {
            r.pair(0, 2).next_u64();
        }
        let mut enc = crate::snapshot::Encoder::new();
        r.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let mut d = AlignedRngs::snapshot_decode(&mut dec).unwrap();
        dec.finish().unwrap();
        // consumed pair continues mid-stream
        assert_eq!(d.pair(0, 2).next_u64(), r.pair(0, 2).next_u64());
        // untouched pair re-derives from the master seed identically
        assert_eq!(d.pair(1, 0).next_u64(), r.pair(1, 0).next_u64());
    }

    #[test]
    fn master_seed_changes_everything() {
        let mut a = AlignedRngs::new(1, 2);
        let mut b = AlignedRngs::new(2, 2);
        assert_ne!(a.pair(0, 1).next_u64(), b.pair(0, 1).next_u64());
    }
}
