//! GPU memory levels (§0.3.6): four placement/algorithm trade-offs between
//! device-memory footprint and time-to-solution for the remote-connection
//! structures. Level 2 is the NEST GPU default.

use crate::memory::MemKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GpuMemLevel {
    /// Maps of remote source neurons, maps to local images, first index and
    /// out-degree count of each remote neuron all in **CPU memory**; only
    /// source neurons *actually used* by at least one connection get an
    /// image (ξ-flagging always on).
    L0,
    /// Same placement as level 0, but **every** source neuron passed to
    /// `RemoteConnect` gets an image without checking use — faster remote
    /// connection creation, some wasted memory once the number of processes
    /// approaches the out-degree.
    L1,
    /// Maps and first index in **GPU memory**; the out-degree of a remote
    /// neuron is computed on the fly from the sorted connection array.
    L2,
    /// Maps, first index and out-degree count all in **GPU memory**.
    L3,
}

pub const ALL_LEVELS: [GpuMemLevel; 4] = [
    GpuMemLevel::L0,
    GpuMemLevel::L1,
    GpuMemLevel::L2,
    GpuMemLevel::L3,
];

impl GpuMemLevel {
    /// Where the (R, L) maps live.
    pub fn map_residency(self) -> MemKind {
        match self {
            GpuMemLevel::L0 | GpuMemLevel::L1 => MemKind::Host,
            _ => MemKind::Device,
        }
    }

    /// Where the per-image first-connection index lives.
    pub fn first_index_residency(self) -> MemKind {
        self.map_residency()
    }

    /// Whether the per-image out-degree count is stored at all (level 2
    /// computes it on the fly from the source-sorted connection array).
    pub fn stores_out_count(self) -> bool {
        !matches!(self, GpuMemLevel::L2)
    }

    /// Where the stored out-degree count lives (if stored).
    pub fn count_residency(self) -> MemKind {
        match self {
            GpuMemLevel::L0 | GpuMemLevel::L1 => MemKind::Host,
            _ => MemKind::Device,
        }
    }

    /// Whether `RemoteConnect` flags actually-used source neurons before
    /// creating images (§0.3.3's `b`/`ũ`/`s̃` compaction). From level 1 on,
    /// all sources passed to the call get images.
    pub fn flags_used_sources(self) -> bool {
        matches!(self, GpuMemLevel::L0)
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuMemLevel::L0 => "level0",
            GpuMemLevel::L1 => "level1",
            GpuMemLevel::L2 => "level2",
            GpuMemLevel::L3 => "level3",
        }
    }

    pub fn from_index(i: usize) -> Option<Self> {
        ALL_LEVELS.get(i).copied()
    }
}

impl Default for GpuMemLevel {
    /// NEST GPU's default for simulations.
    fn default() -> Self {
        GpuMemLevel::L2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_matrix_matches_paper() {
        use MemKind::*;
        assert_eq!(GpuMemLevel::L0.map_residency(), Host);
        assert_eq!(GpuMemLevel::L1.map_residency(), Host);
        assert_eq!(GpuMemLevel::L2.map_residency(), Device);
        assert_eq!(GpuMemLevel::L3.map_residency(), Device);
        assert!(GpuMemLevel::L0.flags_used_sources());
        assert!(!GpuMemLevel::L1.flags_used_sources());
        assert!(!GpuMemLevel::L2.stores_out_count());
        assert!(GpuMemLevel::L3.stores_out_count());
        assert_eq!(GpuMemLevel::default(), GpuMemLevel::L2);
    }

    #[test]
    fn ordering_by_gpu_usage() {
        assert!(GpuMemLevel::L0 < GpuMemLevel::L1);
        assert!(GpuMemLevel::L1 < GpuMemLevel::L2);
        assert!(GpuMemLevel::L2 < GpuMemLevel::L3);
    }
}
