//! The (R, L) map: remote source neuron index → local image neuron index.
//!
//! One such map exists on every target MPI process per possible source
//! process (§0.3.1), or per (group, member) for collective communication
//! (§0.3.4, Eq. 10). The map is kept sorted ascending by `R` (Eq. 3) after
//! every `RemoteConnect` call; positions in the map are the routing tokens
//! exchanged over MPI.

use crate::memory::tracker::{TrackedVec, Tracker};
use crate::memory::MemKind;

/// A sorted (R, L) pair map.
pub struct PairMap {
    /// remote source neuron indexes (sorted ascending)
    r: TrackedVec<u32>,
    /// local image neuron indexes, aligned with `r`
    l: TrackedVec<u32>,
}

impl PairMap {
    pub fn new(kind: MemKind) -> Self {
        Self {
            r: TrackedVec::new(kind),
            l: TrackedVec::new(kind),
        }
    }

    pub fn len(&self) -> usize {
        self.r.len()
    }
    pub fn is_empty(&self) -> bool {
        self.r.is_empty()
    }
    pub fn r_slice(&self) -> &[u32] {
        self.r.as_slice()
    }
    pub fn l_slice(&self) -> &[u32] {
        self.l.as_slice()
    }
    pub fn residency(&self) -> MemKind {
        self.r.kind()
    }

    /// Image index for remote source `s`, if mapped.
    #[inline]
    pub fn lookup(&self, s: u32) -> Option<u32> {
        self.r
            .as_slice()
            .binary_search(&s)
            .ok()
            .map(|i| self.l.as_slice()[i])
    }

    /// Image index at map position `i` (the spike-delivery path: the wire
    /// carries positions, Appendix F).
    #[inline]
    pub fn l_at(&self, pos: u32) -> u32 {
        self.l.as_slice()[pos as usize]
    }

    /// Eq. 5/6: ensure every source in `sorted_sources` (ascending, unique)
    /// has an image. Existing entries are reused; missing entries are
    /// appended with image indexes handed out by `new_image` (which
    /// increments the node count `M`), then the map is re-sorted by `R`.
    ///
    /// Returns the image index for each input source, in input order.
    pub fn ensure_images(
        &mut self,
        sorted_sources: &[u32],
        tr: &mut Tracker,
        mut new_image: impl FnMut() -> u32,
    ) -> Vec<u32> {
        debug_assert!(sorted_sources.windows(2).all(|w| w[0] < w[1]));
        let r_old = self.r.as_slice();
        let l_old = self.l.as_slice();
        let mut out = Vec::with_capacity(sorted_sources.len());
        // merge pass: both inputs sorted -> new sorted arrays
        let mut merged_r: Vec<u32> = Vec::with_capacity(r_old.len() + sorted_sources.len());
        let mut merged_l: Vec<u32> = Vec::with_capacity(merged_r.capacity());
        let (mut i, mut j) = (0usize, 0usize);
        while i < r_old.len() || j < sorted_sources.len() {
            if j >= sorted_sources.len()
                || (i < r_old.len() && r_old[i] < sorted_sources[j])
            {
                merged_r.push(r_old[i]);
                merged_l.push(l_old[i]);
                i += 1;
            } else if i < r_old.len() && r_old[i] == sorted_sources[j] {
                // existing image (Eq. 5)
                merged_r.push(r_old[i]);
                merged_l.push(l_old[i]);
                out.push(l_old[i]);
                i += 1;
                j += 1;
            } else {
                // new image (Eq. 6)
                let img = new_image();
                merged_r.push(sorted_sources[j]);
                merged_l.push(img);
                out.push(img);
                j += 1;
            }
        }
        self.r.replace(merged_r, tr);
        self.l.replace(merged_l, tr);
        out
    }

    /// Verify Eq. 3 (sorted ascending, unique).
    pub fn is_sorted(&self) -> bool {
        self.r.as_slice().windows(2).all(|w| w[0] < w[1])
    }

    pub fn device_bytes(&self) -> u64 {
        if self.residency() == MemKind::Device {
            self.r.bytes() + self.l.bytes()
        } else {
            0
        }
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        self.r.release(tr);
        self.l.release(tr);
    }

    /// Serialize residency + both aligned arrays.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.mem_kind(self.residency());
        enc.slice_u32(self.r.as_slice());
        enc.slice_u32(self.l.as_slice());
    }

    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let kind = dec.mem_kind()?;
        let mut m = PairMap::new(kind);
        m.r.extend_from_slice(&dec.vec_u32()?, tr);
        m.l.extend_from_slice(&dec.vec_u32()?, tr);
        if m.r.len() != m.l.len() {
            anyhow::bail!("(R, L) map snapshot has mismatched array lengths");
        }
        Ok(m)
    }
}

/// The source-side `S` sequence (one per target process, §0.3.1): the local
/// source neuron indexes with images on that target, sorted ascending —
/// element-wise equal to the target's `R` (Eq. 1).
pub struct SourceSeq {
    s: TrackedVec<u32>,
}

impl SourceSeq {
    pub fn new(kind: MemKind) -> Self {
        Self {
            s: TrackedVec::new(kind),
        }
    }

    pub fn len(&self) -> usize {
        self.s.len()
    }
    pub fn is_empty(&self) -> bool {
        self.s.is_empty()
    }
    pub fn as_slice(&self) -> &[u32] {
        self.s.as_slice()
    }

    /// Eq. 7: set-union merge of new (sorted, unique) sources.
    pub fn merge(&mut self, sorted_sources: &[u32], tr: &mut Tracker) {
        let mut v = self.s.as_slice().to_vec();
        crate::util::sort::merge_sorted_unique(&mut v, sorted_sources);
        self.s.replace(v, tr);
    }

    pub fn is_sorted(&self) -> bool {
        self.s.as_slice().windows(2).all(|w| w[0] < w[1])
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        self.s.release(tr);
    }

    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.mem_kind(self.s.kind());
        enc.slice_u32(self.s.as_slice());
    }

    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let kind = dec.mem_kind()?;
        let mut seq = SourceSeq::new(kind);
        seq.s.extend_from_slice(&dec.vec_u32()?, tr);
        Ok(seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> (PairMap, Tracker, u32) {
        (PairMap::new(MemKind::Device), Tracker::new(), 100)
    }

    #[test]
    fn images_created_then_reused() {
        let (mut m, mut tr, mut next) = mk();
        let imgs = m.ensure_images(&[3, 7, 9], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        assert_eq!(imgs, vec![100, 101, 102]);
        assert!(m.is_sorted());
        // second call: 7 reused, 5 and 11 new
        let imgs = m.ensure_images(&[5, 7, 11], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        assert_eq!(imgs, vec![103, 101, 104]);
        assert_eq!(m.r_slice(), &[3, 5, 7, 9, 11]);
        assert_eq!(m.l_slice(), &[100, 103, 101, 102, 104]);
        assert!(m.is_sorted());
    }

    #[test]
    fn lookup_and_position_access() {
        let (mut m, mut tr, mut next) = mk();
        m.ensure_images(&[10, 20, 30], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        assert_eq!(m.lookup(20), Some(101));
        assert_eq!(m.lookup(25), None);
        assert_eq!(m.l_at(0), 100);
        assert_eq!(m.l_at(2), 102);
    }

    #[test]
    fn interleaved_merge_keeps_alignment() {
        let (mut m, mut tr, mut next) = mk();
        m.ensure_images(&[2, 8], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        m.ensure_images(&[1, 5, 9], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        // R sorted; each L still the image created for its R
        assert_eq!(m.r_slice(), &[1, 2, 5, 8, 9]);
        assert_eq!(m.lookup(2), Some(100));
        assert_eq!(m.lookup(8), Some(101));
        assert_eq!(m.lookup(1), Some(102));
        assert_eq!(m.lookup(5), Some(103));
        assert_eq!(m.lookup(9), Some(104));
    }

    #[test]
    fn source_seq_matches_pair_map_r() {
        // Eq. 1: S (source side) must equal R (target side) under the same
        // update sequence
        let (mut m, mut tr, mut next) = mk();
        let mut s = SourceSeq::new(MemKind::Device);
        for batch in [&[4u32, 9][..], &[1, 9, 12][..], &[2][..]] {
            m.ensure_images(batch, &mut tr, || {
                let v = next;
                next += 1;
                v
            });
            s.merge(batch, &mut tr);
        }
        assert_eq!(s.as_slice(), m.r_slice());
        assert!(s.is_sorted());
    }

    #[test]
    fn host_residency_accounts_host_bytes() {
        let mut tr = Tracker::new();
        let mut m = PairMap::new(MemKind::Host);
        let mut next = 0;
        m.ensure_images(&[1, 2, 3], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        assert_eq!(m.device_bytes(), 0);
        assert!(tr.current(MemKind::Host) > 0);
        assert_eq!(tr.current(MemKind::Device), 0);
    }

    #[test]
    fn pair_map_snapshot_roundtrip() {
        let (mut m, mut tr, mut next) = mk();
        m.ensure_images(&[3, 8, 21], &mut tr, || {
            let v = next;
            next += 1;
            v
        });
        let mut enc = crate::snapshot::Encoder::new();
        m.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = PairMap::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.r_slice(), m.r_slice());
        assert_eq!(d.l_slice(), m.l_slice());
        assert_eq!(d.residency(), m.residency());
        assert_eq!(d.lookup(8), Some(101));
    }

    #[test]
    fn source_seq_snapshot_roundtrip() {
        let mut tr = Tracker::new();
        let mut s = SourceSeq::new(MemKind::Device);
        s.merge(&[2, 5, 11], &mut tr);
        let mut enc = crate::snapshot::Encoder::new();
        s.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = SourceSeq::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.as_slice(), s.as_slice());
    }

    #[test]
    fn empty_input_is_noop() {
        let (mut m, mut tr, _) = mk();
        let imgs = m.ensure_images(&[], &mut tr, || unreachable!());
        assert!(imgs.is_empty());
        assert!(m.is_empty());
    }
}
