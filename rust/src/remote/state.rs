//! Per-rank remote-connection state and the `RemoteConnect` algorithm
//! (§0.3.3–§0.3.4): the target-side map construction, the source-side
//! variant, the collective host arrays, and simulation preparation.

use super::aligned::AlignedRngs;
use super::levels::GpuMemLevel;
use super::pair_map::{PairMap, SourceSeq};
use super::tables::RoutingTables;
use crate::comm::GroupId;
use crate::connection::{ConnRule, Connections, NodeSet, SynSpec};
use crate::memory::{MemKind, Tracker};
use crate::node::NodeSpace;
use crate::util::rng::Rng;
use crate::util::sort::merge_sorted_unique;

/// Result of one `RemoteConnect` call on the target side.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteConnectOutcome {
    pub conns_created: u64,
    pub new_images: u64,
    /// whether the ξ-flagging compaction path was taken
    pub flagged: bool,
}

/// Result of a *procedural* target-side `RemoteConnect`
/// ([`RemoteState::connect_target_procedural`]): the map/image state is
/// updated exactly as in the materialized path, but instead of pushing
/// connections the call hands back everything the engine needs to record
/// a [`crate::connection::ConnCallDescriptor`].
pub struct ProceduralRemoteCall {
    pub outcome: RemoteConnectOutcome,
    /// the `l` array of §0.3.1: source position → image node id
    /// (`u32::MAX` for positions the rule never used)
    pub images: Vec<u32>,
    /// raw state of the aligned `RNG[σ,τ]` stream, captured before
    /// `generate` consumed the call's source draws
    pub src_state: [u64; 4],
    pub src_gauss: Option<f64>,
    /// raw state of the target rank's private stream, captured before
    /// `generate` (feeds target-position draws and parameter draws)
    pub local_state: [u64; 4],
    pub local_gauss: Option<f64>,
}

/// Collective-communication state for one MPI group (§0.3.2, §0.3.4).
pub struct GroupState {
    /// communicator group handle (for MPI_Allgather)
    pub comm_group: GroupId,
    /// world ranks of the members, in group order
    pub members: Vec<usize>,
    /// (R, L) maps per source member (Eq. 10; this rank as target)
    pub maps: Vec<PairMap>,
    /// host arrays `H[α,σ]` per member σ: sorted union of all source ids
    /// passed to RemoteConnect calls in this group (Eq. 12–13); mirrored on
    /// every member
    pub h: Vec<Vec<u32>>,
    /// image arrays `I[α,τ=me,σ]`, aligned with `h` (−1 = no image here)
    pub i_arr: Vec<Vec<i32>>,
    h_bytes: u64,
    i_bytes: u64,
}

impl GroupState {
    /// Position of a world rank in the member list.
    pub fn member_index(&self, rank: usize) -> Option<usize> {
        self.members.iter().position(|&m| m == rank)
    }
}

/// All remote-connection structures of one rank.
pub struct RemoteState {
    pub level: GpuMemLevel,
    /// ξ threshold of §0.3.3 (default 1.0 as in the paper)
    pub xi: f64,
    me: usize,
    n_ranks: usize,
    /// p2p target side: (R, L) map per source rank σ
    pub p2p_maps: Vec<PairMap>,
    /// p2p source side: S sequence per target rank τ (Eq. 1/7)
    pub p2p_s: Vec<SourceSeq>,
    pub groups: Vec<GroupState>,
    aligned: AlignedRngs,
    /// (N, T, P) tables, built at preparation (p2p routing)
    pub tp: Option<RoutingTables>,
    /// (N, G, Q) tables, built at preparation (collective routing)
    pub gq: Option<RoutingTables>,
    /// SPMD-consistent lower bound on every remote synaptic delay: folded
    /// over the `SynSpec` of every `RemoteConnect` call, which every rank
    /// executes with identical arguments — so the bound (and hence the
    /// exchange-batching interval derived from it at preparation) agrees
    /// across the world without any communication. `None` = no remote
    /// connectivity. Not persisted: snapshots carry the resolved interval.
    delay_bound: Option<u16>,
    prepared: bool,
}

impl RemoteState {
    pub fn new(master_seed: u64, me: usize, n_ranks: usize, level: GpuMemLevel, xi: f64) -> Self {
        let res = level.map_residency();
        Self {
            level,
            xi,
            me,
            n_ranks,
            p2p_maps: (0..n_ranks).map(|_| PairMap::new(res)).collect(),
            p2p_s: (0..n_ranks).map(|_| SourceSeq::new(MemKind::Device)).collect(),
            groups: Vec::new(),
            aligned: AlignedRngs::new(master_seed, n_ranks),
            tp: None,
            gq: None,
            delay_bound: None,
            prepared: false,
        }
    }

    pub fn me(&self) -> usize {
        self.me
    }
    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }
    pub fn is_prepared(&self) -> bool {
        self.prepared
    }

    /// Fold one `RemoteConnect` call's minimum possible delay into the
    /// world-consistent bound (called on *every* rank for every call).
    pub fn note_remote_delay_bound(&mut self, min_delay: u16) {
        self.delay_bound = Some(match self.delay_bound {
            None => min_delay,
            Some(d) => d.min(min_delay),
        });
    }

    /// The folded minimum remote delay bound (`None` = no remote
    /// connectivity anywhere in the world).
    pub fn remote_delay_bound(&self) -> Option<u16> {
        self.delay_bound
    }

    /// Register an MPI group for collective spike communication. Must be
    /// called in the same order on all ranks (SPMD).
    pub fn register_group(&mut self, comm_group: GroupId, members: Vec<usize>) -> usize {
        let res = self.level.map_residency();
        let n = members.len();
        self.groups.push(GroupState {
            comm_group,
            members,
            maps: (0..n).map(|_| PairMap::new(res)).collect(),
            h: vec![Vec::new(); n],
            i_arr: vec![Vec::new(); n],
            h_bytes: 0,
            i_bytes: 0,
        });
        self.groups.len() - 1
    }

    /// Whether the ξ-flagging path applies for this call (§0.3.3/§0.3.6).
    fn use_flagging(&self, rule: &ConnRule, n_source: usize, n_target: usize) -> bool {
        self.level.flags_used_sources()
            && rule.may_skip_sources()
            && rule.source_use_ratio(n_source, n_target) < self.xi
    }

    /// Target-side `RemoteConnect`: create the connections outgoing from
    /// image neurons and keep the (R, L) map sorted and aligned.
    ///
    /// `group = None` selects point-to-point communication (α = −1 in the
    /// paper's convention); `Some(g)` the collective map set of group `g`.
    #[allow(clippy::too_many_arguments)]
    pub fn connect_target(
        &mut self,
        src_rank: usize,
        s: &NodeSet,
        t: &NodeSet,
        rule: &ConnRule,
        syn: &SynSpec,
        group: Option<usize>,
        nodes: &mut NodeSpace,
        conns: &mut Connections,
        local_rng: &mut Rng,
        tr: &mut Tracker,
    ) -> RemoteConnectOutcome {
        assert!(!self.prepared, "RemoteConnect after prepare()");
        assert_ne!(src_rank, self.me, "use Connect for local connections");
        let n_src = s.len();
        let n_tgt = t.len();
        let conn_start = conns.len();
        let flagged = self.use_flagging(rule, n_src, n_tgt);

        // temporary arrays of §0.3.3: l (image indexes) and b (used flags);
        // accounted as a transient device allocation (contributes to the
        // Fig. 5 peak but not the steady state)
        let transient_bytes = (n_src * (4 + 1)) as u64;
        tr.alloc(MemKind::Device, transient_bytes);
        tr.transient_events += 1;

        let mut b = vec![false; n_src];
        // 3) create connections using temporary source ids = positions in
        //    s; aligned generator for source draws only. The generated
        //    (source_pos, target_pos) pairs are staged in a device buffer
        //    (transient; part of the construction peak) before the synaptic
        //    parameters are drawn with the local generator.
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(rule.conn_count(n_src, n_tgt) as usize);
        {
            // split borrows: the aligned generator is distinct from local_rng
            let aligned = self.aligned.pair(src_rank, self.me);
            rule.generate(n_src, n_tgt, aligned, local_rng, |sp, tp| {
                pairs.push((sp, tp));
            });
        }
        let stage_bytes = (pairs.len() * 8) as u64;
        tr.alloc(MemKind::Device, stage_bytes);
        let n_conns = pairs.len() as u64;
        for (sp, tp) in pairs {
            b[sp as usize] = true;
            let (w, d) = syn.draw(local_rng);
            conns.push(sp, t.get(tp), w, d, syn.port, tr);
        }
        tr.free(MemKind::Device, stage_bytes);

        // 4–5) ũ / s̃ compaction: used positions and their source ids
        let mut us: Vec<(u32, u32)> = if flagged {
            (0..n_src as u32)
                .filter(|&u| b[u as usize])
                .map(|u| (s.get(u), u))
                .collect()
        } else {
            (0..n_src as u32).map(|u| (s.get(u), u)).collect()
        };
        // sort by source id (already sorted for the consecutive-range fast
        // path of §0.3.3)
        if !s.is_sorted() {
            us.sort_unstable();
        }
        debug_assert!(
            us.windows(2).all(|w| w[0].0 < w[1].0),
            "source node sets must not contain duplicate ids"
        );
        let s_tilde: Vec<u32> = us.iter().map(|&(sid, _)| sid).collect();

        // 6) map update (Eqs. 5–6): reuse or create image neurons
        let map = match group {
            None => &mut self.p2p_maps[src_rank],
            Some(g) => {
                let gs = &mut self.groups[g];
                let mi = gs
                    .member_index(src_rank)
                    .expect("source rank not in group");
                &mut gs.maps[mi]
            }
        };
        let images_before = nodes.n_images();
        let imgs = map.ensure_images(&s_tilde, tr, || nodes.create_image(src_rank as u16));
        let n_new_images = (nodes.n_images() - images_before) as u64;

        // l array: position in s -> image index
        let mut l = vec![u32::MAX; n_src];
        for (k, &(_, u)) in us.iter().enumerate() {
            l[u as usize] = imgs[k];
        }

        // 7) rewrite the temporary source ids with the image indexes
        conns.remap_sources(conn_start, &l);
        tr.free(MemKind::Device, transient_bytes);

        RemoteConnectOutcome {
            conns_created: n_conns,
            new_images: n_new_images,
            flagged,
        }
    }

    /// Procedural twin of [`RemoteState::connect_target`] (DESIGN.md §16):
    /// consumes the exact same randomness (full pair stream from the
    /// aligned generator + local target/parameter draws), performs the
    /// same ξ-flagging, ũ/s̃ compaction and map/image updates — but skips
    /// connection materialization, returning the captured RNG states and
    /// the `l` array so the caller records a descriptor instead.
    #[allow(clippy::too_many_arguments)]
    pub fn connect_target_procedural(
        &mut self,
        src_rank: usize,
        s: &NodeSet,
        t: &NodeSet,
        rule: &ConnRule,
        syn: &SynSpec,
        group: Option<usize>,
        nodes: &mut NodeSpace,
        local_rng: &mut Rng,
        tr: &mut Tracker,
    ) -> ProceduralRemoteCall {
        assert!(!self.prepared, "RemoteConnect after prepare()");
        assert_ne!(src_rank, self.me, "use Connect for local connections");
        let n_src = s.len();
        let n_tgt = t.len();
        let flagged = self.use_flagging(rule, n_src, n_tgt);

        // same l + b transient as the materialized path; the l array that
        // survives in the descriptor is accounted by the descriptor store
        let transient_bytes = (n_src * (4 + 1)) as u64;
        tr.alloc(MemKind::Device, transient_bytes);
        tr.transient_events += 1;

        let (src_state, src_gauss) = self.aligned.pair(src_rank, self.me).raw_state();
        let (local_state, local_gauss) = local_rng.raw_state();
        let mut b = vec![false; n_src];
        let mut n_conns = 0u64;
        {
            let aligned = self.aligned.pair(src_rank, self.me);
            rule.generate(n_src, n_tgt, aligned, local_rng, |sp, _tp| {
                b[sp as usize] = true;
                n_conns += 1;
            });
        }
        // the materialized path draws one (weight, delay) per pair after
        // the full pair stream; consume the identical randomness so the
        // local generator leaves this call in the same state
        if syn.weight.is_random() || syn.delay.is_random() {
            for _ in 0..n_conns {
                syn.draw(local_rng);
            }
        }

        // ũ / s̃ compaction and map update, identical to connect_target
        let mut us: Vec<(u32, u32)> = if flagged {
            (0..n_src as u32)
                .filter(|&u| b[u as usize])
                .map(|u| (s.get(u), u))
                .collect()
        } else {
            (0..n_src as u32).map(|u| (s.get(u), u)).collect()
        };
        if !s.is_sorted() {
            us.sort_unstable();
        }
        debug_assert!(
            us.windows(2).all(|w| w[0].0 < w[1].0),
            "source node sets must not contain duplicate ids"
        );
        let s_tilde: Vec<u32> = us.iter().map(|&(sid, _)| sid).collect();

        let map = match group {
            None => &mut self.p2p_maps[src_rank],
            Some(g) => {
                let gs = &mut self.groups[g];
                let mi = gs
                    .member_index(src_rank)
                    .expect("source rank not in group");
                &mut gs.maps[mi]
            }
        };
        let images_before = nodes.n_images();
        let imgs = map.ensure_images(&s_tilde, tr, || nodes.create_image(src_rank as u16));
        let n_new_images = (nodes.n_images() - images_before) as u64;

        let mut l = vec![u32::MAX; n_src];
        for (k, &(_, u)) in us.iter().enumerate() {
            l[u as usize] = imgs[k];
        }
        tr.free(MemKind::Device, transient_bytes);

        ProceduralRemoteCall {
            outcome: RemoteConnectOutcome {
                conns_created: n_conns,
                new_images: n_new_images,
                flagged,
            },
            images: l,
            src_state,
            src_gauss,
            local_state,
            local_gauss,
        }
    }

    /// Source-side `RemoteConnect` variant (§0.3.1/§0.3.3): replay only the
    /// source-index stream from the aligned generator and update `S[τ]`
    /// (point-to-point only; collective mode needs no source-side state).
    pub fn connect_source(
        &mut self,
        tgt_rank: usize,
        s: &NodeSet,
        t_len: usize,
        rule: &ConnRule,
        group: Option<usize>,
        tr: &mut Tracker,
    ) {
        assert!(!self.prepared, "RemoteConnect after prepare()");
        assert_ne!(tgt_rank, self.me);
        if group.is_some() {
            // collective: no S sequence and no aligned draws on the source
            // side (the H update is handled by note_group_call on every
            // member, and Eq. 14 uses the target-side map only)
            return;
        }
        let n_src = s.len();
        let flagged = self.use_flagging(rule, n_src, t_len);
        let transient_bytes = n_src as u64;
        tr.alloc(MemKind::Device, transient_bytes);
        tr.transient_events += 1;
        let mut b = vec![false; n_src];
        {
            let aligned = self.aligned.pair(self.me, tgt_rank);
            rule.replay_sources(n_src, t_len, aligned, |sp| {
                b[sp as usize] = true;
            });
        }
        let mut s_tilde: Vec<u32> = if flagged {
            (0..n_src as u32)
                .filter(|&u| b[u as usize])
                .map(|u| s.get(u))
                .collect()
        } else {
            s.iter().collect()
        };
        if !s.is_sorted() {
            s_tilde.sort_unstable();
        }
        self.p2p_s[tgt_rank].merge(&s_tilde, tr);
        tr.free(MemKind::Device, transient_bytes);
    }

    /// Eq. 12: every member of a group records the source arguments of
    /// every `RemoteConnect` call within the group into `H[α,σ]` —
    /// executable without communication because model scripts are SPMD.
    pub fn note_group_call(&mut self, group: usize, src_rank: usize, s: &NodeSet, tr: &mut Tracker) {
        let residency = self.level.map_residency();
        let gs = &mut self.groups[group];
        let mi = gs.member_index(src_rank).expect("source rank not in group");
        let mut sorted: Vec<u32> = s.iter().collect();
        if !s.is_sorted() {
            sorted.sort_unstable();
        }
        merge_sorted_unique(&mut gs.h[mi], &sorted);
        let new_bytes = (gs.h.iter().map(|v| v.len()).sum::<usize>() * 4) as u64;
        if new_bytes != gs.h_bytes {
            tr.realloc(residency, gs.h_bytes, new_bytes);
            gs.h_bytes = new_bytes;
        }
    }

    /// Simulation preparation (§0.5): build the (N, T, P) tables from the
    /// S sequences (Eqs. 8–9), the image arrays `I` from the (R, L) maps
    /// (Eq. 14) and the (N, G, Q) tables from `H` (Eqs. 15–16).
    pub fn prepare(&mut self, n_nodes: usize, tr: &mut Tracker) {
        assert!(!self.prepared, "prepare() called twice");
        // ---- point-to-point: (N, T, P) from S
        let seqs: Vec<(u16, &[u32])> = (0..self.n_ranks)
            .filter(|&tau| tau != self.me && !self.p2p_s[tau].is_empty())
            .map(|tau| (tau as u16, self.p2p_s[tau].as_slice()))
            .collect();
        self.tp = Some(RoutingTables::build(n_nodes, &seqs, MemKind::Device, tr));

        // ---- collective: I arrays (Eq. 14) and (N, G, Q) (Eqs. 15–16)
        let residency = self.level.map_residency();
        let me = self.me;
        for gs in self.groups.iter_mut() {
            let my_idx = gs.member_index(me);
            for (mi, member) in gs.members.clone().into_iter().enumerate() {
                if member == me {
                    continue;
                }
                let map = &gs.maps[mi];
                gs.i_arr[mi] = gs.h[mi]
                    .iter()
                    .map(|&sid| map.lookup(sid).map(|l| l as i32).unwrap_or(-1))
                    .collect();
            }
            let new_i_bytes =
                (gs.i_arr.iter().map(|v| v.len()).sum::<usize>() * 4) as u64;
            tr.realloc(residency, gs.i_bytes, new_i_bytes);
            gs.i_bytes = new_i_bytes;
            let _ = my_idx;
        }
        let gq_seqs: Vec<(u16, Vec<u32>)> = self
            .groups
            .iter()
            .enumerate()
            .filter_map(|(g, gs)| {
                gs.member_index(me).map(|mi| (g as u16, gs.h[mi].clone()))
            })
            .collect();
        let gq_refs: Vec<(u16, &[u32])> = gq_seqs
            .iter()
            .map(|(g, v)| (*g, v.as_slice()))
            .collect();
        self.gq = Some(RoutingTables::build(n_nodes, &gq_refs, MemKind::Device, tr));
        self.prepared = true;
    }

    /// Serialize all remote-connection structures of this rank: maps,
    /// source sequences, group state, prepared routing tables and the
    /// aligned generator array.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.u8(crate::remote::levels::ALL_LEVELS
            .iter()
            .position(|&l| l == self.level)
            .unwrap() as u8);
        enc.f64(self.xi);
        enc.u64(self.me as u64);
        enc.u64(self.n_ranks as u64);
        enc.bool(self.prepared);
        enc.seq_len(self.p2p_maps.len());
        for m in &self.p2p_maps {
            m.snapshot_encode(enc);
        }
        enc.seq_len(self.p2p_s.len());
        for s in &self.p2p_s {
            s.snapshot_encode(enc);
        }
        enc.seq_len(self.groups.len());
        for g in &self.groups {
            let members: Vec<u64> = g.members.iter().map(|&m| m as u64).collect();
            enc.slice_u64(&members);
            for m in &g.maps {
                m.snapshot_encode(enc);
            }
            for h in &g.h {
                enc.slice_u32(h);
            }
            for i_arr in &g.i_arr {
                enc.seq_len(i_arr.len());
                for &x in i_arr {
                    enc.u32(x as u32);
                }
            }
        }
        for table in [&self.tp, &self.gq] {
            match table {
                None => enc.bool(false),
                Some(t) => {
                    enc.bool(true);
                    t.snapshot_encode(enc);
                }
            }
        }
        self.aligned.snapshot_encode(enc);
    }

    /// Rebuild from [`RemoteState::snapshot_encode`] output. `register`
    /// re-binds each group to the *new* communicator (called once per
    /// group in the original registration order, so SPMD worlds restored
    /// from per-rank snapshots agree on group ids).
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
        register: &mut dyn FnMut(Vec<usize>) -> GroupId,
    ) -> anyhow::Result<Self> {
        let level = GpuMemLevel::from_index(dec.u8()? as usize)
            .ok_or_else(|| anyhow::anyhow!("invalid GPU memory level in snapshot"))?;
        let xi = dec.f64()?;
        let me = dec.u64()? as usize;
        let n_ranks = dec.u64()? as usize;
        let prepared = dec.bool()?;
        let n_maps = dec.seq_len(1)?;
        if n_maps != n_ranks {
            anyhow::bail!("snapshot has {n_maps} p2p maps for a {n_ranks}-rank world");
        }
        let mut p2p_maps = Vec::with_capacity(n_maps);
        for _ in 0..n_maps {
            p2p_maps.push(PairMap::snapshot_decode(dec, tr)?);
        }
        let n_seqs = dec.seq_len(1)?;
        if n_seqs != n_ranks {
            anyhow::bail!("snapshot has {n_seqs} S sequences for a {n_ranks}-rank world");
        }
        let mut p2p_s = Vec::with_capacity(n_seqs);
        for _ in 0..n_seqs {
            p2p_s.push(SourceSeq::snapshot_decode(dec, tr)?);
        }
        let residency = level.map_residency();
        let n_groups = dec.seq_len(1)?;
        let mut groups = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let members: Vec<usize> =
                dec.vec_u64()?.into_iter().map(|m| m as usize).collect();
            let n = members.len();
            let mut maps = Vec::with_capacity(n);
            for _ in 0..n {
                maps.push(PairMap::snapshot_decode(dec, tr)?);
            }
            let mut h = Vec::with_capacity(n);
            for _ in 0..n {
                h.push(dec.vec_u32()?);
            }
            let mut i_arr = Vec::with_capacity(n);
            for _ in 0..n {
                let len = dec.seq_len(4)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(dec.u32()? as i32);
                }
                i_arr.push(v);
            }
            let h_bytes = (h.iter().map(|v| v.len()).sum::<usize>() * 4) as u64;
            let i_bytes = (i_arr.iter().map(|v| v.len()).sum::<usize>() * 4) as u64;
            tr.alloc(residency, h_bytes + i_bytes);
            let comm_group = register(members.clone());
            groups.push(GroupState {
                comm_group,
                members,
                maps,
                h,
                i_arr,
                h_bytes,
                i_bytes,
            });
        }
        let tp = if dec.bool()? {
            Some(RoutingTables::snapshot_decode(dec, MemKind::Device, tr)?)
        } else {
            None
        };
        let gq = if dec.bool()? {
            Some(RoutingTables::snapshot_decode(dec, MemKind::Device, tr)?)
        } else {
            None
        };
        // routing destinations are indexed unchecked in the step hot loop
        if let Some(d) = tp.as_ref().and_then(|t| t.max_dest()) {
            if d as usize >= n_ranks {
                anyhow::bail!("(N, T, P) table routes to rank {d}, world has {n_ranks} ranks");
            }
        }
        if let Some(d) = gq.as_ref().and_then(|t| t.max_dest()) {
            if d as usize >= groups.len() {
                anyhow::bail!(
                    "(N, G, Q) table routes to group {d}, snapshot has {} groups",
                    groups.len()
                );
            }
        }
        let aligned = AlignedRngs::snapshot_decode(dec)?;
        if aligned.n_ranks() != n_ranks {
            anyhow::bail!("aligned-RNG world size disagrees with the snapshot header");
        }
        Ok(Self {
            level,
            xi,
            me,
            n_ranks,
            p2p_maps,
            p2p_s,
            groups,
            aligned,
            tp,
            gq,
            // not persisted: the simulator's CONF section carries the
            // resolved exchange interval, which is what a restore needs
            delay_bound: None,
            prepared,
        })
    }

    /// Total device bytes of the (R, L) maps (diagnostics for Fig. 5).
    pub fn map_device_bytes(&self) -> u64 {
        self.p2p_maps.iter().map(|m| m.device_bytes()).sum::<u64>()
            + self
                .groups
                .iter()
                .flat_map(|g| g.maps.iter())
                .map(|m| m.device_bytes())
                .sum::<u64>()
    }

    /// Total image-map entries across all maps.
    pub fn total_map_entries(&self) -> usize {
        self.p2p_maps.iter().map(|m| m.len()).sum::<usize>()
            + self
                .groups
                .iter()
                .flat_map(|g| g.maps.iter())
                .map(|m| m.len())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(level: GpuMemLevel) -> (RemoteState, NodeSpace, Connections, Tracker, Rng) {
        let st = RemoteState::new(42, 1, 3, level, 1.0);
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 10); // local nodes 0..10
        (st, nodes, Connections::new(), Tracker::new(), Rng::new(7))
    }

    #[test]
    fn target_creates_images_and_rewrites_sources() {
        let (mut st, mut nodes, mut conns, mut tr, mut rng) = setup(GpuMemLevel::L2);
        let s = NodeSet::range(100, 4); // remote ids 100..104 on rank 0
        let t = NodeSet::range(0, 4);
        let out = st.connect_target(
            0,
            &s,
            &t,
            &ConnRule::OneToOne,
            &SynSpec::new(1.0, 1),
            None,
            &mut nodes,
            &mut conns,
            &mut rng,
            &mut tr,
        );
        assert_eq!(out.conns_created, 4);
        assert_eq!(out.new_images, 4);
        assert!(!out.flagged); // one-to-one uses all sources
        // image nodes appended after the 10 local ones
        assert_eq!(nodes.m(), 14);
        assert!(nodes.is_image(10));
        // connection sources rewritten to image indexes (not 0..4)
        for &src in conns.source.as_slice() {
            assert!(src >= 10 && src < 14);
        }
        let map = &st.p2p_maps[0];
        assert_eq!(map.r_slice(), &[100, 101, 102, 103]);
        assert_eq!(map.l_slice(), &[10, 11, 12, 13]);
    }

    #[test]
    fn repeated_calls_reuse_images() {
        let (mut st, mut nodes, mut conns, mut tr, mut rng) = setup(GpuMemLevel::L2);
        let syn = SynSpec::new(1.0, 1);
        let s = NodeSet::range(50, 3);
        st.connect_target(
            0, &s, &NodeSet::range(0, 3), &ConnRule::OneToOne, &syn, None,
            &mut nodes, &mut conns, &mut rng, &mut tr,
        );
        let m_before = nodes.m();
        let out = st.connect_target(
            0, &s, &NodeSet::range(3, 3), &ConnRule::OneToOne, &syn, None,
            &mut nodes, &mut conns, &mut rng, &mut tr,
        );
        assert_eq!(out.new_images, 0, "same sources must reuse images");
        assert_eq!(nodes.m(), m_before);
        assert_eq!(st.p2p_maps[0].len(), 3);
    }

    #[test]
    fn source_and_target_stay_aligned_probabilistic() {
        // Eq. 1: run target side on "rank 1" and source side on "rank 0"
        // with the same master seed; S[1] on rank 0 must equal R[1,0] on 1.
        let mut target = RemoteState::new(42, 1, 2, GpuMemLevel::L0, 1.0);
        let mut source = RemoteState::new(42, 0, 2, GpuMemLevel::L0, 1.0);
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 20);
        let mut conns = Connections::new();
        let mut tr = Tracker::new();
        let mut rng = Rng::new(777);
        let s = NodeSet::range(0, 50);
        // low use ratio -> flagging active on level 0
        let rule = ConnRule::FixedIndegree { k: 2 };
        for call in 0..3 {
            let t = NodeSet::range(call * 5, 5);
            let out = target.connect_target(
                0, &s, &t, &rule, &SynSpec::new(1.0, 1), None,
                &mut nodes, &mut conns, &mut rng, &mut tr,
            );
            assert!(out.flagged);
            source.connect_source(1, &s, 5, &rule, None, &mut tr);
        }
        assert_eq!(
            source.p2p_s[1].as_slice(),
            target.p2p_maps[0].r_slice(),
            "S and R diverged"
        );
        // and strictly fewer images than sources (flagging worked)
        assert!(target.p2p_maps[0].len() < 50);
    }

    #[test]
    fn level1_creates_images_for_all_sources() {
        let (mut st, mut nodes, mut conns, mut tr, mut rng) = setup(GpuMemLevel::L1);
        let s = NodeSet::range(0, 40);
        let out = st.connect_target(
            0,
            &s,
            &NodeSet::range(0, 2),
            &ConnRule::FixedIndegree { k: 1 }, // uses at most 2 sources
            &SynSpec::new(1.0, 1),
            None,
            &mut nodes,
            &mut conns,
            &mut rng,
            &mut tr,
        );
        assert!(!out.flagged);
        assert_eq!(out.new_images, 40, "level >= 1: all sources get images");
    }

    #[test]
    fn xi_threshold_disables_flagging_for_dense_calls() {
        let (mut st, mut nodes, mut conns, mut tr, mut rng) = setup(GpuMemLevel::L0);
        // ratio = k * n_t / n_s = 10*10/10 = 10 >= ξ=1 -> no flagging
        let out = st.connect_target(
            0,
            &NodeSet::range(0, 10),
            &NodeSet::range(0, 10),
            &ConnRule::FixedIndegree { k: 10 },
            &SynSpec::new(1.0, 1),
            None,
            &mut nodes,
            &mut conns,
            &mut rng,
            &mut tr,
        );
        assert!(!out.flagged);
        assert_eq!(out.new_images, 10);
    }

    #[test]
    fn preparation_builds_tp_from_s() {
        // source side on rank 1 (me), images on ranks 0 and 2
        let mut st = RemoteState::new(9, 1, 3, GpuMemLevel::L2, 1.0);
        let mut tr = Tracker::new();
        let s = NodeSet::List(vec![4, 7]);
        st.connect_source(0, &s, 2, &ConnRule::AllToAll, None, &mut tr);
        st.connect_source(2, &NodeSet::List(vec![7]), 1, &ConnRule::AllToAll, None, &mut tr);
        st.prepare(10, &mut tr);
        let tp = st.tp.as_ref().unwrap();
        assert_eq!(tp.route(4).collect::<Vec<_>>(), vec![(0, 0)]);
        assert_eq!(tp.route(7).collect::<Vec<_>>(), vec![(0, 1), (2, 0)]);
        assert_eq!(tp.fanout(5), 0);
    }

    #[test]
    fn collective_h_i_gq_roundtrip() {
        // group of ranks {0, 1}; me = 1 (target); sources live on rank 0
        let mut st = RemoteState::new(42, 1, 2, GpuMemLevel::L3, 1.0);
        let g = st.register_group(0, vec![0, 1]);
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 5);
        let mut conns = Connections::new();
        let mut tr = Tracker::new();
        let mut rng = Rng::new(3);
        let s = NodeSet::List(vec![2, 3, 9]);
        let t = NodeSet::range(0, 3);
        st.note_group_call(g, 0, &s, &mut tr);
        st.connect_target(
            0, &s, &t, &ConnRule::OneToOne, &SynSpec::new(1.0, 1), Some(g),
            &mut nodes, &mut conns, &mut rng, &mut tr,
        );
        st.prepare(nodes.m() as usize, &mut tr);
        let gs = &st.groups[g];
        assert_eq!(gs.h[0], vec![2, 3, 9]);
        // I aligned with H: every source has an image here
        assert_eq!(gs.i_arr[0].len(), 3);
        assert!(gs.i_arr[0].iter().all(|&i| i >= 5));
        // an unused remote source would map to -1: simulate by extending H
        // on another group — covered in engine tests
        // me (=rank 1, member 1) has no sources in H -> empty gq
        let gq = st.gq.as_ref().unwrap();
        assert_eq!(gq.total_entries(), 0);
    }

    #[test]
    fn collective_source_member_gets_gq_routes() {
        // me = 0 is the source member of group {0, 1}
        let mut st = RemoteState::new(42, 0, 2, GpuMemLevel::L3, 1.0);
        let g = st.register_group(0, vec![0, 1]);
        let mut tr = Tracker::new();
        let s = NodeSet::List(vec![1, 4]);
        st.note_group_call(g, 0, &s, &mut tr);
        st.connect_source(1, &s, 2, &ConnRule::OneToOne, Some(g), &mut tr);
        st.prepare(10, &mut tr);
        let gq = st.gq.as_ref().unwrap();
        assert_eq!(gq.route(1).collect::<Vec<_>>(), vec![(0, 0)]);
        assert_eq!(gq.route(4).collect::<Vec<_>>(), vec![(0, 1)]);
    }

    #[test]
    fn snapshot_roundtrip_preserves_maps_tables_and_groups() {
        // build: one collective group + one p2p connect, then prepare
        let mut st = RemoteState::new(42, 1, 3, GpuMemLevel::L2, 1.0);
        let g = st.register_group(0, vec![0, 1]);
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 8);
        let mut conns = Connections::new();
        let mut tr = Tracker::new();
        let mut rng = Rng::new(3);
        let syn = SynSpec::new(1.0, 1);
        let s = NodeSet::List(vec![2, 3, 9]);
        st.note_group_call(g, 0, &s, &mut tr);
        st.connect_target(
            0, &s, &NodeSet::range(0, 3), &ConnRule::OneToOne, &syn, Some(g),
            &mut nodes, &mut conns, &mut rng, &mut tr,
        );
        st.connect_target(
            2, &NodeSet::range(40, 4), &NodeSet::range(0, 4), &ConnRule::OneToOne,
            &syn, None, &mut nodes, &mut conns, &mut rng, &mut tr,
        );
        st.connect_source(0, &NodeSet::List(vec![1, 5]), 2, &ConnRule::AllToAll, None, &mut tr);
        st.prepare(nodes.m() as usize, &mut tr);

        let mut enc = crate::snapshot::Encoder::new();
        st.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut registered: Vec<Vec<usize>> = Vec::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = RemoteState::snapshot_decode(&mut dec, &mut tr2, &mut |members| {
            registered.push(members);
            registered.len() - 1
        })
        .unwrap();
        dec.finish().unwrap();

        assert_eq!(registered, vec![vec![0, 1]], "groups re-registered in order");
        assert_eq!(d.me(), st.me());
        assert_eq!(d.n_ranks(), st.n_ranks());
        assert!(d.is_prepared());
        assert_eq!(d.level, st.level);
        for sigma in 0..3 {
            assert_eq!(d.p2p_maps[sigma].r_slice(), st.p2p_maps[sigma].r_slice());
            assert_eq!(d.p2p_maps[sigma].l_slice(), st.p2p_maps[sigma].l_slice());
            assert_eq!(d.p2p_s[sigma].as_slice(), st.p2p_s[sigma].as_slice());
        }
        assert_eq!(d.groups[g].members, st.groups[g].members);
        assert_eq!(d.groups[g].h, st.groups[g].h);
        assert_eq!(d.groups[g].i_arr, st.groups[g].i_arr);
        assert_eq!(d.groups[g].maps[0].r_slice(), st.groups[g].maps[0].r_slice());
        let (dtp, stp) = (d.tp.as_ref().unwrap(), st.tp.as_ref().unwrap());
        assert_eq!(dtp.total_entries(), stp.total_entries());
        for node in 0..nodes.m() {
            assert_eq!(
                dtp.route(node).collect::<Vec<_>>(),
                stp.route(node).collect::<Vec<_>>()
            );
        }
        assert_eq!(d.total_map_entries(), st.total_map_entries());
    }

    #[test]
    fn delay_bound_folds_minimum() {
        let (mut st, ..) = setup(GpuMemLevel::L2);
        assert_eq!(st.remote_delay_bound(), None);
        st.note_remote_delay_bound(15);
        st.note_remote_delay_bound(20);
        assert_eq!(st.remote_delay_bound(), Some(15));
        st.note_remote_delay_bound(2);
        assert_eq!(st.remote_delay_bound(), Some(2));
    }

    #[test]
    #[should_panic(expected = "after prepare")]
    fn connect_after_prepare_panics() {
        let (mut st, mut nodes, mut conns, mut tr, mut rng) = setup(GpuMemLevel::L2);
        st.prepare(10, &mut tr);
        st.connect_target(
            0,
            &NodeSet::range(0, 1),
            &NodeSet::range(0, 1),
            &ConnRule::OneToOne,
            &SynSpec::new(1.0, 1),
            None,
            &mut nodes,
            &mut conns,
            &mut rng,
            &mut tr,
        );
    }
}
