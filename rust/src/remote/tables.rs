//! Per-source-neuron routing tables, built in *simulation preparation*.
//!
//! Point-to-point: the `(N, T, P)` tables (§0.3.3, Eqs. 8–9, Fig. 15a) —
//! for each local neuron `s`, the target ranks `T[s]` holding an image of
//! `s` and the *positions* `P[s]` of `s` in the corresponding (R, L) maps.
//!
//! Collective: the `(N, G, Q)` tables (§0.3.4, Eqs. 15–16, Fig. 2) — for
//! each local neuron `s`, the groups `G[s]` where `s` has images and the
//! positions `Q[s]` of `s` in the per-group host arrays `H`.
//!
//! Both are CSR layouts over the local node index space: contiguous flat
//! arrays (the paper stores them in GPU memory as fixed-size-blocked
//! arrays; contiguity is what makes the spike-routing kernel a pure gather).

use crate::memory::{MemKind, Tracker};

/// CSR routing table: for node `s`, `dest[first[s]..first[s+1]]` are the
/// destinations (ranks or groups) and `pos[..]` the aligned map positions.
#[derive(Debug, Default)]
pub struct RoutingTables {
    first: Vec<u32>,
    dest: Vec<u16>,
    pos: Vec<u32>,
    tracked: u64,
}

impl RoutingTables {
    /// Build from per-destination sorted source sequences:
    /// `seqs[d] = (destination id, slice of local source ids, sorted)`.
    /// The position of source `s` within its slice is the map position sent
    /// over the wire (Eq. 9 / Eq. 16).
    pub fn build(
        n_nodes: usize,
        seqs: &[(u16, &[u32])],
        kind: MemKind,
        tr: &mut Tracker,
    ) -> Self {
        let mut first = vec![0u32; n_nodes + 1];
        for (_, seq) in seqs {
            for &s in *seq {
                first[s as usize + 1] += 1;
            }
        }
        for i in 0..n_nodes {
            first[i + 1] += first[i];
        }
        let total = first[n_nodes] as usize;
        let mut dest = vec![0u16; total];
        let mut pos = vec![0u32; total];
        let mut cursor = first.clone();
        for (d, seq) in seqs {
            for (i, &s) in seq.iter().enumerate() {
                let c = cursor[s as usize] as usize;
                dest[c] = *d;
                pos[c] = i as u32;
                cursor[s as usize] += 1;
            }
        }
        let tracked = (first.len() * 4 + total * 6) as u64;
        tr.alloc(kind, tracked);
        Self {
            first,
            dest,
            pos,
            tracked,
        }
    }

    /// Destinations and positions for node `s`.
    #[inline]
    pub fn route(&self, s: u32) -> impl Iterator<Item = (u16, u32)> + '_ {
        let a = self.first[s as usize] as usize;
        let b = self.first[s as usize + 1] as usize;
        self.dest[a..b].iter().copied().zip(self.pos[a..b].iter().copied())
    }

    /// Emit node `s`'s (destination, position) entries into a
    /// caller-provided sink — the spike-routing hot path, which scatters
    /// straight into the caller's persistent packet buffers without any
    /// intermediate allocation.
    #[inline]
    pub fn route_into(&self, s: u32, mut emit: impl FnMut(u16, u32)) {
        let a = self.first[s as usize] as usize;
        let b = self.first[s as usize + 1] as usize;
        for (&d, &p) in self.dest[a..b].iter().zip(self.pos[a..b].iter()) {
            emit(d, p);
        }
    }

    /// Number of (destination, position) entries for node `s`.
    #[inline]
    pub fn fanout(&self, s: u32) -> usize {
        (self.first[s as usize + 1] - self.first[s as usize]) as usize
    }

    pub fn total_entries(&self) -> usize {
        self.dest.len()
    }

    /// Largest destination id referenced by any entry (None if empty);
    /// used to validate restored tables against the world shape.
    pub fn max_dest(&self) -> Option<u16> {
        self.dest.iter().copied().max()
    }

    pub fn n_nodes(&self) -> usize {
        self.first.len().saturating_sub(1)
    }

    pub fn release(&mut self, kind: MemKind, tr: &mut Tracker) {
        tr.free(kind, self.tracked);
        self.tracked = 0;
    }

    /// Serialize the CSR arrays.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.slice_u32(&self.first);
        enc.slice_u16(&self.dest);
        enc.slice_u32(&self.pos);
    }

    /// Rebuild from [`RoutingTables::snapshot_encode`] output; `kind` is
    /// where the table is accounted (tables are device-resident for every
    /// GPU memory level, but the parameter keeps the call sites honest).
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        kind: MemKind,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let first = dec.vec_u32()?;
        let dest = dec.vec_u16()?;
        let pos = dec.vec_u32()?;
        if first.is_empty() || dest.len() != pos.len() {
            anyhow::bail!("routing-table snapshot has inconsistent CSR arrays");
        }
        if *first.last().unwrap() as usize != dest.len() {
            anyhow::bail!(
                "routing-table snapshot CSR end {} does not match {} entries",
                first.last().unwrap(),
                dest.len()
            );
        }
        let tracked = (first.len() * 4 + dest.len() * 6) as u64;
        tr.alloc(kind, tracked);
        Ok(Self {
            first,
            dest,
            pos,
            tracked,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_matches_paper_example() {
        // Paper Fig. 1, rank 2 (yellow): neurons 0 and 2 have images on
        // ranks 0 and 1. S[0,2] = [0, 2], S[1,2] = [0, 2] (both sorted).
        let s_tau0: &[u32] = &[0, 2];
        let s_tau1: &[u32] = &[0, 2];
        let mut tr = Tracker::new();
        let t = RoutingTables::build(
            3,
            &[(0, s_tau0), (1, s_tau1)],
            MemKind::Device,
            &mut tr,
        );
        // neuron 0: images on ranks 0 and 1, both at position 0
        assert_eq!(t.route(0).collect::<Vec<_>>(), vec![(0, 0), (1, 0)]);
        // neuron 1: no images
        assert_eq!(t.fanout(1), 0);
        // neuron 2: both at position 1
        assert_eq!(t.route(2).collect::<Vec<_>>(), vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn positions_index_into_the_sequence() {
        // appendix-F style: S[1,0] = [57, 480, 742], S[2,0] = [742]
        let mut tr = Tracker::new();
        let t = RoutingTables::build(
            800,
            &[(1, &[57, 480, 742][..]), (2, &[742][..])],
            MemKind::Device,
            &mut tr,
        );
        assert_eq!(t.route(480).collect::<Vec<_>>(), vec![(1, 1)]);
        assert_eq!(t.route(742).collect::<Vec<_>>(), vec![(1, 2), (2, 0)]);
        assert_eq!(t.total_entries(), 4);
    }

    #[test]
    fn route_into_matches_route() {
        let mut tr = Tracker::new();
        let t = RoutingTables::build(
            800,
            &[(1, &[57, 480, 742][..]), (2, &[742][..])],
            MemKind::Device,
            &mut tr,
        );
        for s in [0u32, 57, 480, 742, 799] {
            let mut sunk = Vec::new();
            t.route_into(s, |d, p| sunk.push((d, p)));
            assert_eq!(sunk, t.route(s).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_tables() {
        let mut tr = Tracker::new();
        let t = RoutingTables::build(5, &[], MemKind::Device, &mut tr);
        assert_eq!(t.total_entries(), 0);
        assert_eq!(t.fanout(4), 0);
    }

    #[test]
    fn snapshot_roundtrip_preserves_routes() {
        let mut tr = Tracker::new();
        let t = RoutingTables::build(
            800,
            &[(1, &[57, 480, 742][..]), (2, &[742][..])],
            MemKind::Device,
            &mut tr,
        );
        let mut enc = crate::snapshot::Encoder::new();
        t.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = RoutingTables::snapshot_decode(&mut dec, MemKind::Device, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.n_nodes(), t.n_nodes());
        assert_eq!(d.total_entries(), t.total_entries());
        for s in [57u32, 480, 742, 0, 799] {
            assert_eq!(d.route(s).collect::<Vec<_>>(), t.route(s).collect::<Vec<_>>());
        }
        assert_eq!(tr2.current(MemKind::Device), tr.current(MemKind::Device));
    }

    #[test]
    fn memory_accounted_and_released() {
        let mut tr = Tracker::new();
        let mut t =
            RoutingTables::build(4, &[(0, &[1, 2][..])], MemKind::Host, &mut tr);
        assert!(tr.current(MemKind::Host) > 0);
        t.release(MemKind::Host, &mut tr);
        assert_eq!(tr.current(MemKind::Host), 0);
    }
}
