//! Remote connections: the paper's core contribution (§0.3).
//!
//! - [`pair_map`]: the sorted (R, L) maps and source-side S sequences;
//! - [`aligned`]: the per-(σ, τ) aligned generator array;
//! - [`tables`]: the (N, T, P) and (N, G, Q) routing tables;
//! - [`state`]: the `RemoteConnect` algorithm (target + source variant),
//!   collective host arrays, and simulation preparation;
//! - [`levels`]: the four GPU memory levels (§0.3.6).

pub mod aligned;
pub mod levels;
pub mod pair_map;
pub mod state;
pub mod tables;

pub use levels::GpuMemLevel;
pub use state::{GroupState, ProceduralRemoteCall, RemoteConnectOutcome, RemoteState};
