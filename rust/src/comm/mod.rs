//! Simulated MPI layer.
//!
//! The paper drives spike exchange with MPI point-to-point sends (§0.3.1)
//! or `MPI_Allgather` within process groups (§0.3.2), one MPI process per
//! GPU. This module reproduces those semantics inside one OS process: each
//! rank is a thread holding a [`Communicator`] handle; point-to-point
//! exchange is an all-to-all-v over shared slots, and collective exchange
//! is an allgather-v over group-scoped slots. Payload byte counts are
//! tracked so benches can report the communication volumes the paper
//! discusses, even though the wire is shared memory here.
//!
//! The construction algorithm (the paper's contribution) never calls into
//! this module — network construction is communication-free by design; only
//! state propagation and the final validation gathers exchange data.
//!
//! Two live transports implement the trait: [`ThreadComm`] (every rank a
//! thread of one process, the shared-memory wire of the original
//! reproduction) and [`SocketComm`] (every rank its own OS process, spike
//! packets and collectives framed over TCP — see [`wire`] and DESIGN.md
//! §15; CLI: `--comm socket`, `nestgpu launch`). Both are held to the
//! repo's bit-identity bar (`tests/it_transport.rs`).

mod socket_comm;
mod thread_comm;
pub mod wire;

pub use socket_comm::{SocketComm, SocketConfig};
pub use thread_comm::{CommWorld, ThreadComm};

/// MPI rank index.
pub type Rank = usize;

/// Group handle returned by [`Communicator::register_group`].
pub type GroupId = usize;

/// One remote spike in a point-to-point packet: the *position* of the
/// source neuron in the (R, L) map of the target process (not the neuron
/// id! — Appendix F), plus the spike multiplicity and the emission lag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpikeRecord {
    /// position `i` in the target's `(R[τ,σ,i], L[τ,σ,i])` map
    pub pos: u32,
    /// spike multiplicity (≥1; >1 for aggregated spikes)
    pub mult: u16,
    /// emission step within the current exchange interval (0-based).
    /// With per-step exchange (interval 1) this is always 0; with
    /// min-delay batching the receiver shifts the ring-buffer slot by
    /// `lag + 1 − interval_len` so batched delivery stays bit-identical
    /// (see `rust/DESIGN.md` §11).
    pub lag: u16,
}

/// Wire size of one spike record (u32 position + u16 multiplicity +
/// u16 lag). Every traffic-accounting site must derive from this constant.
pub const SPIKE_RECORD_BYTES: u64 = 8;
/// Per-message envelope cost we account for non-empty packets.
pub const MSG_HEADER_BYTES: u64 = 8;
/// Collective spikes travel as pairs of u32 words in the allgather
/// payload: `[pos, (lag << 16) | mult]`.
pub const COLL_WORDS_PER_SPIKE: usize = 2;
/// Wire size of one u32 word of a collective payload.
pub const COLL_WORD_BYTES: u64 = 4;

/// Pack the second word of a collective spike record.
#[inline]
pub fn coll_pack(lag: u16, mult: u16) -> u32 {
    ((lag as u32) << 16) | mult as u32
}

/// Unpack the second word of a collective spike record into (lag, mult).
#[inline]
pub fn coll_unpack(word: u32) -> (u16, u16) {
    ((word >> 16) as u16, (word & 0xFFFF) as u16)
}

/// Accumulated communication volume for one rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficStats {
    pub p2p_messages: u64,
    pub p2p_bytes: u64,
    pub coll_calls: u64,
    pub coll_bytes: u64,
}

impl TrafficStats {
    pub fn total_bytes(&self) -> u64 {
        self.p2p_bytes + self.coll_bytes
    }
}

/// MPI-like communicator owned exclusively by one rank's thread.
pub trait Communicator: Send {
    fn rank(&self) -> Rank;
    fn size(&self) -> usize;

    /// Synchronous all-to-all-v of spike packets: `outgoing[τ]` is the
    /// packet for rank τ (empty packets are not accounted as messages);
    /// returns `incoming[σ]` = packet sent by rank σ to this rank.
    ///
    /// This models one round of the paper's point-to-point protocol, where
    /// within a time step every process posts its sends and drains its
    /// receives before spike delivery proceeds.
    fn exchange(&mut self, outgoing: Vec<Vec<SpikeRecord>>) -> Vec<Vec<SpikeRecord>>;

    /// Collectively register an MPI group. Must be called by *all* ranks of
    /// the world in the same order with the same member list (SPMD model
    /// scripts guarantee this, as in the paper's reference implementation).
    fn register_group(&mut self, members: Vec<Rank>) -> GroupId;

    /// `MPI_Allgatherv` within a group: contribute `data`, receive every
    /// member's contribution in `out`, indexed by member position. Must be
    /// called by every member of the group; panics if this rank is not a
    /// member. `out` is resized to the member count if shorter; its inner
    /// buffers are reused (cleared, then filled), so a caller that keeps
    /// `out` alive across calls performs no steady-state allocation.
    fn allgather_into(&mut self, group: GroupId, data: &[u32], out: &mut Vec<Vec<u32>>);

    /// Allocating convenience wrapper around [`Communicator::allgather_into`].
    fn allgather(&mut self, group: GroupId, data: &[u32]) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        self.allgather_into(group, data, &mut out);
        out
    }

    /// `MPI_Allreduce(MIN)` over the whole world: every rank contributes a
    /// value and receives the global minimum. (The engine derives the
    /// exchange-batching interval from the SPMD remote-delay bound instead
    /// of this call, keeping preparation communication-free; the primitive
    /// is provided for model scripts and diagnostics.)
    fn allreduce_min(&mut self, value: u32) -> u32;

    /// Barrier over the whole world.
    fn barrier(&mut self);

    fn traffic(&self) -> TrafficStats;

    /// Short name of the transport backend ("thread", "socket", "null"),
    /// recorded in run manifests and report headers.
    fn transport_name(&self) -> &'static str;

    /// Advertised per-rank wire endpoints, rank-ordered. Empty for
    /// in-process transports, which have no wire.
    fn endpoints(&self) -> Vec<String> {
        Vec::new()
    }
}

/// Communicator for estimation (dry-run) mode: the rank behaves as rank
/// `rank` of a *virtual* world of `size` ranks, but never communicates —
/// valid because network construction and simulation preparation are
/// communication-free (the paper estimates 4,096-node configurations with
/// 4 live processes exactly this way).
#[derive(Debug)]
pub struct NullComm {
    rank: Rank,
    size: usize,
    groups: Vec<Vec<Rank>>,
}

impl NullComm {
    pub fn new(rank: Rank, size: usize) -> Self {
        assert!(rank < size);
        Self {
            rank,
            size,
            groups: Vec::new(),
        }
    }
}

impl Communicator for NullComm {
    fn rank(&self) -> Rank {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }
    fn exchange(&mut self, _outgoing: Vec<Vec<SpikeRecord>>) -> Vec<Vec<SpikeRecord>> {
        panic!("NullComm cannot exchange spikes: estimation mode covers construction and preparation only")
    }
    fn register_group(&mut self, members: Vec<Rank>) -> GroupId {
        self.groups.push(members);
        self.groups.len() - 1
    }
    fn allgather_into(&mut self, _group: GroupId, _data: &[u32], _out: &mut Vec<Vec<u32>>) {
        panic!("NullComm cannot allgather: estimation mode covers construction and preparation only")
    }
    fn allreduce_min(&mut self, value: u32) -> u32 {
        // estimation mode is communication-free: the local value stands in
        // for the world minimum (preparation stays a valid dry run)
        value
    }
    fn barrier(&mut self) {}
    fn traffic(&self) -> TrafficStats {
        TrafficStats::default()
    }
    fn transport_name(&self) -> &'static str {
        "null"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_comm_identity() {
        let mut c = NullComm::new(3, 1024);
        assert_eq!(c.rank(), 3);
        assert_eq!(c.size(), 1024);
        let g = c.register_group((0..1024).collect());
        assert_eq!(g, 0);
        c.barrier(); // no-op, must not block
    }

    #[test]
    #[should_panic(expected = "estimation mode")]
    fn null_comm_refuses_exchange() {
        NullComm::new(0, 4).exchange(vec![vec![]; 4]);
    }

    #[test]
    fn null_comm_allreduce_min_is_identity() {
        assert_eq!(NullComm::new(0, 4).allreduce_min(17), 17);
        assert_eq!(NullComm::new(1, 2).allreduce_min(u32::MAX), u32::MAX);
    }

    #[test]
    fn collective_word_packing_roundtrips() {
        for (lag, mult) in [(0u16, 1u16), (14, 1), (3, 40_000), (u16::MAX, u16::MAX)] {
            assert_eq!(coll_unpack(coll_pack(lag, mult)), (lag, mult));
        }
    }
}
