//! Framed wire protocol of the socket transport (DESIGN.md §15).
//!
//! Every message on a socket-comm connection — handshake and data alike —
//! travels as one *frame*: a fixed 24-byte header followed by a payload of
//! [`SpikeRecord`]s, `u32` words, or raw handshake bytes. The header
//! carries a magic number, a protocol version, the message type, a channel
//! (the group id for collectives, 0 otherwise) and a per-(type, channel)
//! sequence number, so a torn frame, a short read, or a frame arriving out
//! of round fails loudly instead of silently corrupting an exchange round.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic       WIRE_MAGIC
//!      4     1  version     WIRE_VERSION
//!      5     1  msg_type    MsgType as u8
//!      6     2  reserved    0
//!      8     4  channel     group id (collectives) / sender rank (Ident)
//!     12     4  payload_len bytes following the header
//!     16     8  seq         per-(type, channel) round counter
//! ```

use std::io::Read;

use super::{coll_pack, coll_unpack, SpikeRecord};

/// Frame magic: `b"NGS1"` read as a little-endian u32.
pub const WIRE_MAGIC: u32 = u32::from_le_bytes(*b"NGS1");
/// Wire protocol version; bump on any layout change.
pub const WIRE_VERSION: u8 = 1;
/// Fixed frame-header size on the wire.
pub const FRAME_HEADER_BYTES: usize = 24;
/// Upper bound on a single frame's payload; a length field above this is
/// rejected before any allocation (a corrupt header must not OOM the rank).
pub const MAX_PAYLOAD_BYTES: u32 = 64 << 20;

/// Wire size of one [`SpikeRecord`] in an `Exchange` payload.
pub const RECORD_WIRE_BYTES: usize = 8;

/// Frame message types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgType {
    /// client -> rendezvous: claimed rank, world size, mesh-listener addr
    Hello = 1,
    /// rendezvous -> client: assigned rank, world size, endpoint map
    Welcome = 2,
    /// mesh connector -> acceptor: the connector's rank (in `channel`)
    Ident = 3,
    /// one point-to-point spike packet of an exchange round
    Exchange = 4,
    /// one member's contribution to a group allgather
    Allgather = 5,
    /// one rank's value of an `allreduce_min` round
    ReduceMin = 6,
    /// one rank's arrival at a barrier
    Barrier = 7,
    /// serve client -> server: a job spec (JSON payload, DESIGN.md §17)
    SubmitJob = 8,
    /// serve server -> client: job state transition or error (JSON)
    JobStatus = 9,
    /// serve server -> client: final job outcome (JSON)
    JobResult = 10,
    /// serve client <-> server: cache statistics request / reply (JSON)
    CacheStats = 11,
    /// serve client -> server: orderly daemon shutdown request
    Shutdown = 12,
}

impl MsgType {
    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => MsgType::Hello,
            2 => MsgType::Welcome,
            3 => MsgType::Ident,
            4 => MsgType::Exchange,
            5 => MsgType::Allgather,
            6 => MsgType::ReduceMin,
            7 => MsgType::Barrier,
            8 => MsgType::SubmitJob,
            9 => MsgType::JobStatus,
            10 => MsgType::JobResult,
            11 => MsgType::CacheStats,
            12 => MsgType::Shutdown,
            _ => return None,
        })
    }
}

/// Decoded frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub msg_type: MsgType,
    pub channel: u32,
    pub payload_len: u32,
    pub seq: u64,
}

/// Everything that can go wrong while decoding a frame. A short read
/// surfaces as `Io(UnexpectedEof)`; everything else names the field that
/// failed validation.
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    BadMagic(u32),
    BadVersion(u8),
    BadType(u8),
    Oversized { len: u32, max: u32 },
    /// payload length is not a whole number of `unit`-byte elements
    TornPayload { len: usize, unit: usize },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (expected {WIRE_MAGIC:#010x})")
            }
            WireError::BadVersion(v) => {
                write!(f, "wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::BadType(t) => write!(f, "unknown message type {t}"),
            WireError::Oversized { len, max } => {
                write!(f, "payload length {len} exceeds the {max}-byte frame limit")
            }
            WireError::TornPayload { len, unit } => {
                write!(f, "torn payload: {len} bytes is not a multiple of {unit}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Append a frame header to `buf` with a zero payload length; returns the
/// header's start offset for [`finish_frame`]. The begin/finish split lets
/// callers serialize payloads straight into the same buffer — the hot
/// exchange path reuses one send buffer with no intermediate allocation.
pub fn begin_frame(buf: &mut Vec<u8>, msg_type: MsgType, channel: u32, seq: u64) -> usize {
    let start = buf.len();
    buf.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    buf.push(WIRE_VERSION);
    buf.push(msg_type as u8);
    buf.extend_from_slice(&0u16.to_le_bytes());
    buf.extend_from_slice(&channel.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes()); // payload_len, patched below
    buf.extend_from_slice(&seq.to_le_bytes());
    debug_assert_eq!(buf.len() - start, FRAME_HEADER_BYTES);
    start
}

/// Patch the payload length of the frame begun at `start` (everything
/// appended to `buf` after its header is the payload).
pub fn finish_frame(buf: &mut Vec<u8>, start: usize) {
    let len = (buf.len() - start - FRAME_HEADER_BYTES) as u32;
    buf[start + 12..start + 16].copy_from_slice(&len.to_le_bytes());
}

/// Decode and validate a frame header.
pub fn decode_header(bytes: &[u8; FRAME_HEADER_BYTES]) -> Result<FrameHeader, WireError> {
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if bytes[4] != WIRE_VERSION {
        return Err(WireError::BadVersion(bytes[4]));
    }
    let msg_type = MsgType::from_u8(bytes[5]).ok_or(WireError::BadType(bytes[5]))?;
    let channel = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let payload_len = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    if payload_len > MAX_PAYLOAD_BYTES {
        return Err(WireError::Oversized {
            len: payload_len,
            max: MAX_PAYLOAD_BYTES,
        });
    }
    let seq = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    Ok(FrameHeader {
        msg_type,
        channel,
        payload_len,
        seq,
    })
}

/// Read one whole frame: header, validation, then exactly `payload_len`
/// bytes into `payload` (cleared first). `read_exact` loops over partial
/// reads, so arbitrary TCP segmentation reassembles correctly; a
/// connection that dies mid-frame yields `Io(UnexpectedEof)` — loud, never
/// a half-filled payload.
pub fn read_frame<R: Read>(r: &mut R, payload: &mut Vec<u8>) -> Result<FrameHeader, WireError> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut hdr)?;
    let header = decode_header(&hdr)?;
    payload.clear();
    payload.resize(header.payload_len as usize, 0);
    r.read_exact(payload)?;
    Ok(header)
}

/// Append spike records to a payload (8 bytes each, little-endian).
pub fn push_records(buf: &mut Vec<u8>, records: &[SpikeRecord]) {
    for r in records {
        buf.extend_from_slice(&r.pos.to_le_bytes());
        // the (lag, mult) pair packs exactly like a collective word
        buf.extend_from_slice(&coll_pack(r.lag, r.mult).to_le_bytes());
    }
}

/// Decode an `Exchange` payload into `out` (cleared first).
pub fn decode_records(payload: &[u8], out: &mut Vec<SpikeRecord>) -> Result<(), WireError> {
    if payload.len() % RECORD_WIRE_BYTES != 0 {
        return Err(WireError::TornPayload {
            len: payload.len(),
            unit: RECORD_WIRE_BYTES,
        });
    }
    out.clear();
    out.reserve(payload.len() / RECORD_WIRE_BYTES);
    for chunk in payload.chunks_exact(RECORD_WIRE_BYTES) {
        let pos = u32::from_le_bytes(chunk[0..4].try_into().unwrap());
        let (lag, mult) = coll_unpack(u32::from_le_bytes(chunk[4..8].try_into().unwrap()));
        out.push(SpikeRecord { pos, mult, lag });
    }
    Ok(())
}

/// Append `u32` words to a payload (collective contributions).
pub fn push_words(buf: &mut Vec<u8>, words: &[u32]) {
    for w in words {
        buf.extend_from_slice(&w.to_le_bytes());
    }
}

/// Decode an `Allgather`/`ReduceMin` payload into `out` (cleared first).
pub fn decode_words(payload: &[u8], out: &mut Vec<u32>) -> Result<(), WireError> {
    if payload.len() % 4 != 0 {
        return Err(WireError::TornPayload {
            len: payload.len(),
            unit: 4,
        });
    }
    out.clear();
    out.reserve(payload.len() / 4);
    for chunk in payload.chunks_exact(4) {
        out.push(u32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A reader that hands out the underlying bytes in random-sized chunks,
    /// emulating arbitrary TCP segmentation of a frame stream.
    struct SplitReader<'a> {
        data: &'a [u8],
        pos: usize,
        rng: Rng,
    }

    impl<'a> SplitReader<'a> {
        fn new(data: &'a [u8], seed: u64) -> Self {
            Self {
                data,
                pos: 0,
                rng: Rng::new(seed),
            }
        }
    }

    impl Read for SplitReader<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let left = self.data.len() - self.pos;
            let max = buf.len().min(left);
            if max == 0 {
                return Ok(0);
            }
            let n = 1 + (self.rng.next_u64() as usize) % max;
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn random_records(rng: &mut Rng, n: usize) -> Vec<SpikeRecord> {
        (0..n)
            .map(|_| SpikeRecord {
                pos: rng.next_u64() as u32,
                mult: rng.next_u64() as u16,
                lag: rng.next_u64() as u16,
            })
            .collect()
    }

    fn frame_with_records(records: &[SpikeRecord], channel: u32, seq: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        let start = begin_frame(&mut buf, MsgType::Exchange, channel, seq);
        push_records(&mut buf, records);
        finish_frame(&mut buf, start);
        buf
    }

    #[test]
    fn record_frame_roundtrips() {
        let mut rng = Rng::new(7);
        for n in [0usize, 1, 3, 257] {
            let records = random_records(&mut rng, n);
            let buf = frame_with_records(&records, 9, 42);
            let mut payload = Vec::new();
            let hdr = read_frame(&mut &buf[..], &mut payload).unwrap();
            assert_eq!(hdr.msg_type, MsgType::Exchange);
            assert_eq!(hdr.channel, 9);
            assert_eq!(hdr.seq, 42);
            assert_eq!(hdr.payload_len as usize, n * RECORD_WIRE_BYTES);
            let mut out = Vec::new();
            decode_records(&payload, &mut out).unwrap();
            assert_eq!(out, records);
        }
    }

    #[test]
    fn word_frame_roundtrips() {
        let words: Vec<u32> = vec![0, 1, u32::MAX, 0xDEAD_BEEF, 7];
        let mut buf = Vec::new();
        let start = begin_frame(&mut buf, MsgType::Allgather, 3, 11);
        push_words(&mut buf, &words);
        finish_frame(&mut buf, start);
        let mut payload = Vec::new();
        let hdr = read_frame(&mut &buf[..], &mut payload).unwrap();
        assert_eq!(hdr.msg_type, MsgType::Allgather);
        let mut out = Vec::new();
        decode_words(&payload, &mut out).unwrap();
        assert_eq!(out, words);
    }

    #[test]
    fn truncation_at_every_prefix_is_rejected() {
        let records = random_records(&mut Rng::new(1), 5);
        let buf = frame_with_records(&records, 0, 0);
        let mut payload = Vec::new();
        for cut in 0..buf.len() {
            let err = read_frame(&mut &buf[..cut], &mut payload).unwrap_err();
            match err {
                WireError::Io(e) => {
                    assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof, "cut {cut}")
                }
                other => panic!("cut {cut}: unexpected error {other}"),
            }
        }
        // the untruncated frame still parses
        assert!(read_frame(&mut &buf[..], &mut payload).is_ok());
    }

    #[test]
    fn bad_magic_version_type_and_oversize_are_rejected() {
        let good = frame_with_records(&[], 0, 0);

        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        let mut payload = Vec::new();
        assert!(matches!(
            read_frame(&mut &bad[..], &mut payload),
            Err(WireError::BadMagic(_))
        ));

        let mut bad = good.clone();
        bad[4] = WIRE_VERSION + 1;
        assert!(matches!(
            read_frame(&mut &bad[..], &mut payload),
            Err(WireError::BadVersion(_))
        ));

        let mut bad = good.clone();
        bad[5] = 0xEE;
        assert!(matches!(
            read_frame(&mut &bad[..], &mut payload),
            Err(WireError::BadType(0xEE))
        ));

        let mut bad = good;
        bad[12..16].copy_from_slice(&(MAX_PAYLOAD_BYTES + 1).to_le_bytes());
        // rejected from the header alone — no payload bytes are consumed
        assert!(matches!(
            read_frame(&mut &bad[..], &mut payload),
            Err(WireError::Oversized { .. })
        ));
    }

    #[test]
    fn torn_payload_lengths_are_rejected() {
        let mut out = Vec::new();
        assert!(matches!(
            decode_records(&[0u8; 12], &mut out),
            Err(WireError::TornPayload { len: 12, unit: 8 })
        ));
        let mut words = Vec::new();
        assert!(matches!(
            decode_words(&[0u8; 7], &mut words),
            Err(WireError::TornPayload { len: 7, unit: 4 })
        ));
    }

    #[test]
    fn random_split_reassembly() {
        // a stream of several frames, delivered in random-sized chunks,
        // must reassemble into exactly the original frames
        let mut rng = Rng::new(0xF00D);
        let mut stream = Vec::new();
        let mut expect = Vec::new();
        for seq in 0..20u64 {
            let records = random_records(&mut rng, (rng.next_u64() % 64) as usize);
            stream.extend_from_slice(&frame_with_records(&records, seq as u32, seq));
            expect.push(records);
        }
        for trial in 0..10u64 {
            let mut r = SplitReader::new(&stream, 0xBEEF + trial);
            let mut payload = Vec::new();
            for (seq, records) in expect.iter().enumerate() {
                let hdr = read_frame(&mut r, &mut payload).unwrap();
                assert_eq!(hdr.seq, seq as u64);
                let mut out = Vec::new();
                decode_records(&payload, &mut out).unwrap();
                assert_eq!(&out, records);
            }
            // stream fully consumed
            assert_eq!(r.pos, stream.len());
        }
    }
}
