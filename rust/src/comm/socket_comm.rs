//! TCP socket transport: a real inter-process [`Communicator`] backend.
//!
//! Each rank is its own OS process; all traffic — handshake, point-to-point
//! spike packets, collectives — travels as frames of the wire protocol in
//! [`super::wire`] (DESIGN.md §15). The topology is a full mesh of TCP
//! connections established through a rank-0 *rendezvous*:
//!
//! 1. the rank-0 process listens on the rendezvous address; every other
//!    process connects to it (with bounded retry/backoff, so start order
//!    does not matter), sends `Hello` (claimed rank or "assign me", world
//!    size, its own mesh-listener address) and receives `Welcome` (its
//!    assigned rank plus the rank-ordered endpoint map);
//! 2. mesh: rank `i` connects to every rank `j < i` (announcing itself
//!    with `Ident`) and accepts connections from every rank `j > i`.
//!
//! After the handshake, one *reader thread per peer* drains incoming frames
//! into an in-process channel. This is what makes the blocking all-to-all
//! in [`Communicator::exchange`] deadlock-free: every rank's inbound
//! direction always makes progress, so a cycle of ranks blocked on
//! `write_all` against full kernel socket buffers cannot form. The main
//! thread consumes its peers' inboxes with `recv_timeout`, which is also
//! where the configured receive timeout turns a silent peer into a loud,
//! rank-tagged failure instead of a hang.
//!
//! The SPMD contract of the [`Communicator`] trait (every rank issues the
//! same collective calls in the same order) plus per-connection FIFO
//! ordering is what makes sequential frame matching sound: the next frame
//! from a peer within an operation *is* that operation's frame, and the
//! header's (type, channel, seq) triple is validated against the expected
//! round to catch any violation.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::wire::{
    begin_frame, decode_records, decode_words, finish_frame, push_records, push_words,
    read_frame, FrameHeader, MsgType, WireError,
};
use super::{Communicator, GroupId, Rank, SpikeRecord, TrafficStats};

/// Socket-transport configuration (CLI: `--comm socket --rank R --world N
/// --rendezvous HOST:PORT [--connect-timeout-ms T] [--recv-timeout-ms T]`).
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// rendezvous address the rank-0 process listens on
    pub rendezvous: String,
    /// this process's rank; `None` lets the rendezvous assign one (the
    /// rank-0 process must always claim rank 0 — it hosts the rendezvous)
    pub rank: Option<Rank>,
    /// world size (must agree on every process)
    pub world: usize,
    /// total budget for establishing any single outbound connection,
    /// retried with exponential backoff (covers peers that bind late)
    pub connect_timeout: Duration,
    /// how long a blocking receive may wait for a peer's frame
    pub recv_timeout: Duration,
}

impl SocketConfig {
    pub fn new(rendezvous: impl Into<String>, world: usize) -> Self {
        Self {
            rendezvous: rendezvous.into(),
            rank: None,
            world,
            connect_timeout: Duration::from_secs(10),
            recv_timeout: Duration::from_secs(30),
        }
    }
}

/// Sentinel claimed-rank value in `Hello`: "assign me any rank".
const RANK_ASSIGN: u32 = u32::MAX;

/// One established mesh connection: the writer half stays with the main
/// thread; a dedicated reader thread owns a clone of the stream and feeds
/// decoded frames (or the first wire error, then exits) into `inbox`.
struct Peer {
    writer: TcpStream,
    inbox: Receiver<std::result::Result<(FrameHeader, Vec<u8>), WireError>>,
}

impl Drop for Peer {
    fn drop(&mut self) {
        // unblock the reader thread even if the remote end keeps the
        // connection open; it exits on the resulting i/o error
        let _ = self.writer.shutdown(std::net::Shutdown::Both);
    }
}

/// The socket-backed communicator. See the module docs for the protocol.
pub struct SocketComm {
    rank: Rank,
    size: usize,
    recv_timeout: Duration,
    /// `peers[r]` is `None` only for `r == rank`
    peers: Vec<Option<Peer>>,
    /// advertised mesh endpoints, rank-ordered (from the rendezvous map)
    endpoints: Vec<String>,
    groups: Vec<Vec<Rank>>,
    /// per-group allgather round counters (the frame `seq`)
    group_seqs: Vec<u64>,
    exchange_seq: u64,
    reduce_seq: u64,
    barrier_seq: u64,
    traffic: TrafficStats,
    /// recycled frame-serialization buffer of the send paths
    send_buf: Vec<u8>,
}

/// Connect with bounded retry/backoff: loopback/LAN peers refuse instantly
/// until they bind, so retrying inside `timeout` makes start order
/// irrelevant (the delayed-bind case in `tests/it_transport.rs`).
fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(5);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() + backoff >= deadline {
                    bail!("connect to {addr} failed after {timeout:?} of retries: {e}");
                }
                thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(200));
            }
        }
    }
}

fn read_u32_at(payload: &[u8], off: usize, what: &str) -> Result<u32> {
    ensure!(payload.len() >= off + 4, "short {what} payload");
    Ok(u32::from_le_bytes(payload[off..off + 4].try_into().unwrap()))
}

/// Read one frame directly off a stream (handshake phase, before reader
/// threads exist), checking the expected type.
fn read_handshake(stream: &mut TcpStream, expect: MsgType) -> Result<(FrameHeader, Vec<u8>)> {
    let mut payload = Vec::new();
    let hdr = read_frame(stream, &mut payload).map_err(|e| anyhow::anyhow!("{e}"))?;
    ensure!(
        hdr.msg_type == expect,
        "handshake expected {:?}, peer sent {:?}",
        expect,
        hdr.msg_type
    );
    Ok((hdr, payload))
}

impl SocketComm {
    /// Establish the full mesh for this process per the module docs.
    /// Blocks until every connection is up or a timeout/protocol error
    /// fails it. The rank-0 process (claimed rank `Some(0)`) hosts the
    /// rendezvous; everyone else connects to it.
    pub fn connect(cfg: &SocketConfig) -> Result<SocketComm> {
        ensure!(cfg.world >= 1, "world size must be at least 1");
        if let Some(r) = cfg.rank {
            ensure!(r < cfg.world, "rank {r} outside world of {}", cfg.world);
        }
        if cfg.world == 1 {
            ensure!(cfg.rank.unwrap_or(0) == 0, "single-rank world must be rank 0");
            return Ok(SocketComm {
                rank: 0,
                size: 1,
                recv_timeout: cfg.recv_timeout,
                peers: vec![None],
                endpoints: vec!["local".to_string()],
                groups: Vec::new(),
                group_seqs: Vec::new(),
                exchange_seq: 0,
                reduce_seq: 0,
                barrier_seq: 0,
                traffic: TrafficStats::default(),
                send_buf: Vec::new(),
            });
        }
        let (rank, endpoints, mesh) = if cfg.rank == Some(0) {
            Self::rendezvous_host(cfg).context("rendezvous host")?
        } else {
            Self::rendezvous_client(cfg).context("rendezvous client")?
        };
        let streams = Self::build_mesh(cfg, rank, &endpoints, mesh)
            .with_context(|| format!("rank {rank}: mesh establishment"))?;
        let peers = streams
            .into_iter()
            .enumerate()
            .map(|(peer_rank, s)| s.map(|s| Self::spawn_reader(s, rank, peer_rank)).transpose())
            .collect::<Result<Vec<_>>>()?;
        Ok(SocketComm {
            rank,
            size: cfg.world,
            recv_timeout: cfg.recv_timeout,
            peers,
            endpoints,
            groups: Vec::new(),
            group_seqs: Vec::new(),
            exchange_seq: 0,
            reduce_seq: 0,
            barrier_seq: 0,
            traffic: TrafficStats::default(),
            send_buf: Vec::new(),
        })
    }

    /// Rank 0: host the rendezvous, collect every `Hello`, assign ranks,
    /// distribute the endpoint map via `Welcome`.
    fn rendezvous_host(cfg: &SocketConfig) -> Result<(Rank, Vec<String>, TcpListener)> {
        let rdv = TcpListener::bind(&cfg.rendezvous)
            .with_context(|| format!("bind rendezvous {}", cfg.rendezvous))?;
        let host_ip = rdv.local_addr()?.ip();
        let mesh = TcpListener::bind((host_ip, 0)).context("bind mesh listener")?;
        let my_addr = mesh.local_addr()?.to_string();

        let mut pending: Vec<(u32, String, TcpStream)> = Vec::new();
        for _ in 1..cfg.world {
            let (mut s, from) = rdv.accept().context("rendezvous accept")?;
            s.set_read_timeout(Some(cfg.recv_timeout))?;
            s.set_nodelay(true)?;
            let (_, payload) = read_handshake(&mut s, MsgType::Hello)
                .with_context(|| format!("hello from {from}"))?;
            let claimed = read_u32_at(&payload, 0, "hello")?;
            let world = read_u32_at(&payload, 4, "hello")?;
            ensure!(
                world as usize == cfg.world,
                "peer at {from} expects world {world}, this run has {}",
                cfg.world
            );
            let addr = String::from_utf8(payload[8..].to_vec()).context("hello address")?;
            pending.push((claimed, addr, s));
        }

        // slot the claimed ranks, then fill the rest in arrival order
        let mut endpoints = vec![String::new(); cfg.world];
        endpoints[0] = my_addr;
        let mut streams: Vec<Option<TcpStream>> = (0..cfg.world).map(|_| None).collect();
        let mut unclaimed = Vec::new();
        for (claimed, addr, s) in pending {
            if claimed == RANK_ASSIGN {
                unclaimed.push((addr, s));
                continue;
            }
            let r = claimed as usize;
            ensure!(r > 0 && r < cfg.world, "peer claimed invalid rank {r}");
            ensure!(streams[r].is_none(), "two peers claimed rank {r}");
            endpoints[r] = addr;
            streams[r] = Some(s);
        }
        let mut next = unclaimed.into_iter();
        for r in 1..cfg.world {
            if streams[r].is_none() {
                let (addr, s) = next.next().expect("world-count peers connected");
                endpoints[r] = addr;
                streams[r] = Some(s);
            }
        }

        let map = endpoints.join("\n");
        let mut buf = Vec::new();
        for (r, s) in streams.iter_mut().enumerate().skip(1) {
            let s = s.as_mut().unwrap();
            buf.clear();
            let start = begin_frame(&mut buf, MsgType::Welcome, 0, 0);
            buf.extend_from_slice(&(r as u32).to_le_bytes());
            buf.extend_from_slice(&(cfg.world as u32).to_le_bytes());
            buf.extend_from_slice(map.as_bytes());
            finish_frame(&mut buf, start);
            s.write_all(&buf)
                .with_context(|| format!("send welcome to rank {r}"))?;
        }
        // rendezvous streams close here; mesh connections replace them
        Ok((0, endpoints, mesh))
    }

    /// Non-zero ranks: connect to the rendezvous (retrying while rank 0
    /// binds), send `Hello`, learn the assigned rank and the endpoint map.
    fn rendezvous_client(cfg: &SocketConfig) -> Result<(Rank, Vec<String>, TcpListener)> {
        let mut s = connect_retry(&cfg.rendezvous, cfg.connect_timeout)?;
        s.set_read_timeout(Some(cfg.recv_timeout))?;
        s.set_nodelay(true)?;
        // the interface this host reaches the rendezvous through is the
        // one peers can reach back — advertise the mesh listener on it
        let local_ip = s.local_addr()?.ip();
        let mesh = TcpListener::bind((local_ip, 0)).context("bind mesh listener")?;
        let my_addr = mesh.local_addr()?.to_string();

        let claimed = cfg.rank.map_or(RANK_ASSIGN, |r| r as u32);
        let mut buf = Vec::new();
        let start = begin_frame(&mut buf, MsgType::Hello, 0, 0);
        buf.extend_from_slice(&claimed.to_le_bytes());
        buf.extend_from_slice(&(cfg.world as u32).to_le_bytes());
        buf.extend_from_slice(my_addr.as_bytes());
        finish_frame(&mut buf, start);
        s.write_all(&buf).context("send hello")?;

        let (_, payload) = read_handshake(&mut s, MsgType::Welcome)?;
        let rank = read_u32_at(&payload, 0, "welcome")? as usize;
        let world = read_u32_at(&payload, 4, "welcome")? as usize;
        ensure!(world == cfg.world, "welcome names world {world}, expected {}", cfg.world);
        ensure!(rank > 0 && rank < world, "welcome assigned invalid rank {rank}");
        if let Some(r) = cfg.rank {
            ensure!(rank == r, "claimed rank {r} but was assigned {rank}");
        }
        let endpoints: Vec<String> = String::from_utf8(payload[8..].to_vec())
            .context("welcome endpoint map")?
            .split('\n')
            .map(str::to_string)
            .collect();
        ensure!(
            endpoints.len() == world,
            "endpoint map has {} entries for a world of {world}",
            endpoints.len()
        );
        Ok((rank, endpoints, mesh))
    }

    /// Full mesh: connect to every lower rank (announcing with `Ident`),
    /// accept from every higher rank. Lower-before-accept avoids the
    /// connect/accept cycle: rank 0 only accepts, the top rank only
    /// connects.
    fn build_mesh(
        cfg: &SocketConfig,
        rank: Rank,
        endpoints: &[String],
        mesh: TcpListener,
    ) -> Result<Vec<Option<TcpStream>>> {
        let mut streams: Vec<Option<TcpStream>> = (0..cfg.world).map(|_| None).collect();
        let mut buf = Vec::new();
        for (j, addr) in endpoints.iter().enumerate().take(rank) {
            let mut s = connect_retry(addr, cfg.connect_timeout)
                .with_context(|| format!("mesh connect to rank {j}"))?;
            s.set_nodelay(true)?;
            buf.clear();
            let start = begin_frame(&mut buf, MsgType::Ident, rank as u32, 0);
            finish_frame(&mut buf, start);
            s.write_all(&buf)
                .with_context(|| format!("send ident to rank {j}"))?;
            streams[j] = Some(s);
        }
        for _ in rank + 1..cfg.world {
            let (mut s, from) = mesh.accept().context("mesh accept")?;
            s.set_read_timeout(Some(cfg.recv_timeout))?;
            s.set_nodelay(true)?;
            let (hdr, _) = read_handshake(&mut s, MsgType::Ident)
                .with_context(|| format!("ident from {from}"))?;
            let peer = hdr.channel as usize;
            ensure!(
                peer > rank && peer < cfg.world,
                "mesh peer announced rank {peer}, expected one of {}..{}",
                rank + 1,
                cfg.world
            );
            ensure!(streams[peer].is_none(), "rank {peer} connected twice");
            streams[peer] = Some(s);
        }
        Ok(streams)
    }

    /// Wrap an established stream in a [`Peer`]: a detached reader thread
    /// owns a clone and pumps frames into the inbox until the connection
    /// dies or the `SocketComm` drops (which shuts the socket down).
    fn spawn_reader(stream: TcpStream, my_rank: Rank, peer_rank: Rank) -> Result<Peer> {
        // reader threads block indefinitely on the socket; receive
        // timeouts are enforced at the inbox instead
        stream.set_read_timeout(None)?;
        let mut reader = stream.try_clone().context("clone stream for reader")?;
        let (tx, inbox) = mpsc::channel();
        thread::Builder::new()
            .name(format!("sockcomm-{my_rank}-from-{peer_rank}"))
            .spawn(move || {
                let mut payload = Vec::new();
                loop {
                    match read_frame(&mut reader, &mut payload) {
                        Ok(hdr) => {
                            if tx.send(Ok((hdr, std::mem::take(&mut payload)))).is_err() {
                                return; // comm dropped
                            }
                        }
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            })
            .context("spawn reader thread")?;
        Ok(Peer {
            writer: stream,
            inbox,
        })
    }

    /// Next frame from `peer`, or a loud error on timeout / connection
    /// loss / wire corruption.
    fn recv_from(&mut self, peer: Rank) -> Result<(FrameHeader, Vec<u8>)> {
        let p = self.peers[peer].as_ref().expect("no connection to self");
        match p.inbox.recv_timeout(self.recv_timeout) {
            Ok(Ok(frame)) => Ok(frame),
            Ok(Err(e)) => bail!("wire error on the connection from rank {peer}: {e}"),
            Err(RecvTimeoutError::Timeout) => bail!(
                "receive from rank {peer} timed out after {:?}",
                self.recv_timeout
            ),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("connection from rank {peer} closed mid-run")
            }
        }
    }

    /// Validate a data frame against the expected round.
    fn check_frame(
        &self,
        hdr: &FrameHeader,
        from: Rank,
        ty: MsgType,
        channel: u32,
        seq: u64,
    ) -> Result<()> {
        ensure!(
            hdr.msg_type == ty && hdr.channel == channel && hdr.seq == seq,
            "protocol violation from rank {from}: frame is {:?} channel {} seq {}, \
             this rank is in {ty:?} channel {channel} seq {seq} (SPMD call order diverged?)",
            hdr.msg_type,
            hdr.channel,
            hdr.seq
        );
        Ok(())
    }

    /// Serialize one data frame into the recycled send buffer and write it
    /// to `to`; returns the frame's wire size (header + payload) for
    /// traffic accounting. An empty body is still a frame — every round
    /// sends one frame to every participating peer, which is what keeps
    /// rounds delimited and the sequence numbers checkable.
    fn send_frame(
        &mut self,
        ty: MsgType,
        channel: u32,
        seq: u64,
        to: Rank,
        body: FrameBody<'_>,
    ) -> Result<u64> {
        let mut buf = std::mem::take(&mut self.send_buf);
        buf.clear();
        let start = begin_frame(&mut buf, ty, channel, seq);
        match body {
            FrameBody::Records(r) => push_records(&mut buf, r),
            FrameBody::Words(w) => push_words(&mut buf, w),
        }
        finish_frame(&mut buf, start);
        let wire_bytes = buf.len() as u64;
        let res = self.peers[to]
            .as_mut()
            .expect("no connection to self")
            .writer
            .write_all(&buf)
            .with_context(|| format!("send {ty:?} to rank {to}"));
        self.send_buf = buf;
        res?;
        Ok(wire_bytes)
    }

    fn exchange_impl(
        &mut self,
        mut bufs: Vec<Vec<SpikeRecord>>,
    ) -> Result<Vec<Vec<SpikeRecord>>> {
        let n = self.size;
        assert_eq!(bufs.len(), n, "exchange() needs one packet per rank");
        let me = self.rank;
        let seq = self.exchange_seq;
        self.exchange_seq += 1;
        for t in 0..n {
            if t == me {
                continue;
            }
            let records = std::mem::take(&mut bufs[t]);
            let wire_bytes =
                self.send_frame(MsgType::Exchange, 0, seq, t, FrameBody::Records(&records))?;
            self.traffic.p2p_bytes += wire_bytes;
            if !records.is_empty() {
                self.traffic.p2p_messages += 1;
            }
            bufs[t] = records;
        }
        // own packet round-trips locally (same as the thread mailbox);
        // each peer's slot is recycled for that peer's incoming packet
        for s in 0..n {
            if s == me {
                continue;
            }
            let (hdr, payload) = self.recv_from(s)?;
            self.check_frame(&hdr, s, MsgType::Exchange, 0, seq)?;
            decode_records(&payload, &mut bufs[s])
                .map_err(|e| anyhow::anyhow!("exchange payload from rank {s}: {e}"))?;
        }
        Ok(bufs)
    }

    fn allgather_impl(&mut self, group: GroupId, data: &[u32], out: &mut Vec<Vec<u32>>) -> Result<()> {
        let members = std::mem::take(&mut self.groups[group]);
        let me_pos = members
            .iter()
            .position(|&r| r == self.rank)
            .unwrap_or_else(|| panic!("rank {} is not a member of group {group}", self.rank));
        let seq = self.group_seqs[group];
        self.group_seqs[group] += 1;
        if out.len() < members.len() {
            out.resize_with(members.len(), Vec::new);
        }
        self.traffic.coll_calls += 1;
        for &m in &members {
            if m == self.rank {
                continue;
            }
            let wire_bytes =
                self.send_frame(MsgType::Allgather, group as u32, seq, m, FrameBody::Words(data))?;
            self.traffic.coll_bytes += wire_bytes;
        }
        out[me_pos].clear();
        out[me_pos].extend_from_slice(data);
        for (pos, &m) in members.iter().enumerate() {
            if m == self.rank {
                continue;
            }
            let (hdr, payload) = self.recv_from(m)?;
            self.check_frame(&hdr, m, MsgType::Allgather, group as u32, seq)?;
            decode_words(&payload, &mut out[pos])
                .map_err(|e| anyhow::anyhow!("allgather payload from rank {m}: {e}"))?;
        }
        self.groups[group] = members;
        Ok(())
    }

    fn allreduce_min_impl(&mut self, value: u32) -> Result<u32> {
        let seq = self.reduce_seq;
        self.reduce_seq += 1;
        let word = [value];
        for t in 0..self.size {
            if t == self.rank {
                continue;
            }
            let wire_bytes =
                self.send_frame(MsgType::ReduceMin, 0, seq, t, FrameBody::Words(&word))?;
            self.traffic.coll_bytes += wire_bytes;
        }
        let mut min = value;
        let mut words = Vec::new();
        for s in 0..self.size {
            if s == self.rank {
                continue;
            }
            let (hdr, payload) = self.recv_from(s)?;
            self.check_frame(&hdr, s, MsgType::ReduceMin, 0, seq)?;
            decode_words(&payload, &mut words)
                .map_err(|e| anyhow::anyhow!("allreduce payload from rank {s}: {e}"))?;
            ensure!(words.len() == 1, "allreduce frame from rank {s} carries {} words", words.len());
            min = min.min(words[0]);
        }
        Ok(min)
    }

    fn barrier_impl(&mut self) -> Result<()> {
        let seq = self.barrier_seq;
        self.barrier_seq += 1;
        for t in 0..self.size {
            if t == self.rank {
                continue;
            }
            let wire_bytes = self.send_frame(MsgType::Barrier, 0, seq, t, FrameBody::Words(&[]))?;
            self.traffic.coll_bytes += wire_bytes;
        }
        for s in 0..self.size {
            if s == self.rank {
                continue;
            }
            let (hdr, _) = self.recv_from(s)?;
            self.check_frame(&hdr, s, MsgType::Barrier, 0, seq)?;
        }
        Ok(())
    }

    /// Convert an internal error into the rank-tagged panic the harness
    /// (`harness::join_ranks`) reports as an `anyhow::Error`. The trait's
    /// methods are infallible by signature; in a distributed run a comm
    /// failure is not locally recoverable anyway — the round is lost.
    fn fail(&self, e: anyhow::Error) -> ! {
        panic!("socket comm rank {}: {e:#}", self.rank)
    }
}

/// Payload of an outbound data frame.
enum FrameBody<'a> {
    Records(&'a [SpikeRecord]),
    Words(&'a [u32]),
}

impl Communicator for SocketComm {
    fn rank(&self) -> Rank {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }

    fn exchange(&mut self, outgoing: Vec<Vec<SpikeRecord>>) -> Vec<Vec<SpikeRecord>> {
        self.exchange_impl(outgoing)
            .unwrap_or_else(|e| self.fail(e))
    }

    fn register_group(&mut self, members: Vec<Rank>) -> GroupId {
        // purely local: the SPMD contract has every rank register the same
        // groups in the same order, so the positional id needs no wire round
        self.groups.push(members);
        self.group_seqs.push(0);
        self.groups.len() - 1
    }

    fn allgather_into(&mut self, group: GroupId, data: &[u32], out: &mut Vec<Vec<u32>>) {
        self.allgather_impl(group, data, out)
            .unwrap_or_else(|e| self.fail(e))
    }

    fn allreduce_min(&mut self, value: u32) -> u32 {
        self.allreduce_min_impl(value)
            .unwrap_or_else(|e| self.fail(e))
    }

    fn barrier(&mut self) {
        self.barrier_impl().unwrap_or_else(|e| self.fail(e))
    }

    fn traffic(&self) -> TrafficStats {
        self.traffic
    }

    fn transport_name(&self) -> &'static str {
        "socket"
    }

    fn endpoints(&self) -> Vec<String> {
        self.endpoints.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pick a free loopback address (bind port 0, read it back, release).
    fn free_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    }

    fn world(n: usize, rendezvous: &str) -> Vec<SocketComm> {
        let mut comms: Vec<Option<SocketComm>> = (0..n).map(|_| None).collect();
        thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|r| {
                    let cfg = SocketConfig {
                        rank: Some(r),
                        ..SocketConfig::new(rendezvous, n)
                    };
                    s.spawn(move || SocketComm::connect(&cfg).unwrap())
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                comms[r] = Some(h.join().unwrap());
            }
        });
        comms.into_iter().map(Option::unwrap).collect()
    }

    /// Run one closure per rank over an established world, in parallel.
    fn on_world<T: Send>(
        comms: Vec<SocketComm>,
        f: impl Fn(SocketComm) -> T + Sync,
    ) -> Vec<T> {
        let f = &f;
        thread::scope(|s| {
            let handles: Vec<_> = comms.into_iter().map(|c| s.spawn(move || f(c))).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn mesh_forms_and_ranks_are_assigned() {
        let comms = world(3, &free_addr());
        for (r, c) in comms.iter().enumerate() {
            assert_eq!(c.rank(), r);
            assert_eq!(c.size(), 3);
            assert_eq!(c.transport_name(), "socket");
            let eps = c.endpoints();
            assert_eq!(eps.len(), 3);
            // every rank agrees on the endpoint map
            assert_eq!(eps, comms[0].endpoints());
        }
    }

    #[test]
    fn unclaimed_ranks_are_assigned_by_the_rendezvous() {
        let addr = free_addr();
        let n = 3;
        let results = thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|i| {
                    let cfg = SocketConfig {
                        // only rank 0 claims (it must host); others get
                        // assigned whatever is free
                        rank: (i == 0).then_some(0),
                        ..SocketConfig::new(addr.as_str(), n)
                    };
                    s.spawn(move || {
                        if i > 0 {
                            // stagger so assignment order is exercised
                            thread::sleep(Duration::from_millis(10 * i as u64));
                        }
                        let c = SocketComm::connect(&cfg).unwrap();
                        let rank = c.rank();
                        // run a barrier so the mesh is actually exercised
                        let mut c = c;
                        c.barrier();
                        rank
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        });
        let mut ranks = results;
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2]);
    }

    #[test]
    fn exchange_routes_packets_and_counts_wire_bytes() {
        let comms = world(3, &free_addr());
        let results = on_world(comms, |mut c| {
            let me = c.rank() as u32;
            let outgoing: Vec<Vec<SpikeRecord>> = (0..3)
                .map(|t| {
                    // rank r sends one record {pos: 10r + t} to each t != r;
                    // rank 2 sends rank 0 an empty packet instead
                    if t == c.rank() || (c.rank() == 2 && t == 0) {
                        Vec::new()
                    } else {
                        vec![SpikeRecord {
                            pos: 10 * me + t as u32,
                            mult: 1 + t as u16,
                            lag: me as u16,
                        }]
                    }
                })
                .collect();
            let incoming = c.exchange(outgoing);
            (c.rank(), incoming, c.traffic())
        });
        for (rank, incoming, traffic) in &results {
            for (s, packet) in incoming.iter().enumerate() {
                let expect_empty = s == *rank || (s == 2 && *rank == 0);
                if expect_empty {
                    assert!(packet.is_empty(), "rank {rank} from {s}");
                } else {
                    assert_eq!(packet.len(), 1);
                    assert_eq!(packet[0].pos, 10 * s as u32 + *rank as u32);
                    assert_eq!(packet[0].mult, 1 + *rank as u16);
                    assert_eq!(packet[0].lag, s as u16);
                }
            }
            // every peer got a frame (2 each), but only non-empty packets
            // count as messages; bytes include the 24-byte frame headers
            let msgs = if *rank == 2 { 1 } else { 2 };
            assert_eq!(traffic.p2p_messages, msgs, "rank {rank}");
            let header_only = 2 - msgs;
            assert_eq!(
                traffic.p2p_bytes,
                (msgs * (super::super::wire::FRAME_HEADER_BYTES as u64 + 8))
                    + header_only * super::super::wire::FRAME_HEADER_BYTES as u64,
                "rank {rank}"
            );
        }
    }

    #[test]
    fn allgather_matches_thread_comm_semantics() {
        let comms = world(4, &free_addr());
        let results = on_world(comms, |mut c| {
            let g_all = c.register_group(vec![0, 1, 2, 3]);
            let g_even = c.register_group(vec![0, 2]);
            let me = c.rank() as u32;
            let all = c.allgather(g_all, &[me, me * 100]);
            let even = if c.rank() % 2 == 0 {
                Some(c.allgather(g_even, &[7 + me]))
            } else {
                None
            };
            // a second round on the same group must also line up (seq bump)
            let all2 = c.allgather(g_all, &[me + 1]);
            (c.rank(), all, even, all2, c.traffic())
        });
        for (rank, all, even, all2, traffic) in results {
            assert_eq!(all.len(), 4);
            for (pos, data) in all.iter().enumerate() {
                assert_eq!(data, &[pos as u32, pos as u32 * 100]);
            }
            for (pos, data) in all2.iter().enumerate() {
                assert_eq!(data, &[pos as u32 + 1]);
            }
            if rank % 2 == 0 {
                assert_eq!(even.unwrap(), vec![vec![7], vec![9]]);
                assert_eq!(traffic.coll_calls, 3);
            } else {
                assert!(even.is_none());
                assert_eq!(traffic.coll_calls, 2);
            }
            assert!(traffic.coll_bytes > 0);
            assert_eq!(traffic.p2p_messages, 0);
        }
    }

    #[test]
    fn allreduce_min_and_barrier() {
        let comms = world(3, &free_addr());
        let mins = on_world(comms, |mut c| {
            let m = c.allreduce_min(40 - c.rank() as u32);
            c.barrier();
            let m2 = c.allreduce_min(c.rank() as u32 + 5);
            (m, m2)
        });
        for (m, m2) in mins {
            assert_eq!(m, 38); // min over {40, 39, 38}
            assert_eq!(m2, 5); // min over {5, 6, 7}
        }
    }

    #[test]
    fn single_rank_world_needs_no_sockets() {
        let cfg = SocketConfig {
            rank: Some(0),
            ..SocketConfig::new("127.0.0.1:1", 1) // never dialed
        };
        let mut c = SocketComm::connect(&cfg).unwrap();
        let incoming = c.exchange(vec![vec![SpikeRecord {
            pos: 3,
            mult: 1,
            lag: 0,
        }]]);
        assert_eq!(incoming[0].len(), 1);
        let g = c.register_group(vec![0]);
        assert_eq!(c.allgather(g, &[42]), vec![vec![42]]);
        assert_eq!(c.allreduce_min(9), 9);
        c.barrier();
        assert_eq!(c.traffic(), TrafficStats::default());
    }

    #[test]
    fn world_size_disagreement_fails_handshake() {
        let addr = free_addr();
        let addr2 = addr.clone();
        let host = thread::spawn(move || {
            let cfg = SocketConfig {
                rank: Some(0),
                ..SocketConfig::new(addr2, 2)
            };
            SocketComm::connect(&cfg)
        });
        let cfg = SocketConfig {
            rank: Some(1),
            recv_timeout: Duration::from_secs(5),
            ..SocketConfig::new(addr, 3) // wrong world size
        };
        let client = SocketComm::connect(&cfg);
        assert!(client.is_err(), "client with wrong world must fail");
        let host = host.join().unwrap();
        assert!(host.is_err(), "host must reject the mismatched hello");
        let msg = format!("{:#}", host.unwrap_err());
        assert!(msg.contains("world"), "{msg}");
    }
}
