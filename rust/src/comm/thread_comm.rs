//! In-process MPI world: one thread per rank, shared-memory transport.

use std::sync::{Arc, Barrier, Condvar, Mutex};

use super::{
    Communicator, GroupId, Rank, SpikeRecord, TrafficStats, MSG_HEADER_BYTES,
    SPIKE_RECORD_BYTES,
};

/// Shared state of one communicator world.
struct Shared {
    n: usize,
    /// exchange mailbox: `slots[from][to]`
    slots: Mutex<Vec<Vec<Option<Vec<SpikeRecord>>>>>,
    barrier: Barrier,
    groups: Mutex<Vec<Arc<GroupShared>>>,
    group_gate: Condvar,
}

struct GroupShared {
    members: Vec<Rank>,
    slots: Mutex<Vec<Option<Vec<u32>>>>,
    barrier: Barrier,
}

/// Factory for a world of `n` thread-rank communicators.
pub struct CommWorld {
    shared: Arc<Shared>,
}

impl CommWorld {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            n,
            slots: Mutex::new(vec![vec![None; n]; n]),
            barrier: Barrier::new(n),
            groups: Mutex::new(Vec::new()),
            group_gate: Condvar::new(),
        });
        CommWorld { shared }
    }

    /// Handles for all ranks (consume and move each into its rank thread).
    pub fn communicators(&self) -> Vec<ThreadComm> {
        (0..self.shared.n)
            .map(|r| ThreadComm {
                rank: r,
                shared: Arc::clone(&self.shared),
                groups_registered: 0,
                traffic: TrafficStats::default(),
            })
            .collect()
    }
}

/// Per-rank communicator handle (exclusively owned by the rank's thread).
pub struct ThreadComm {
    rank: Rank,
    shared: Arc<Shared>,
    groups_registered: usize,
    traffic: TrafficStats,
}

impl Communicator for ThreadComm {
    fn rank(&self) -> Rank {
        self.rank
    }

    fn size(&self) -> usize {
        self.shared.n
    }

    fn exchange(&mut self, outgoing: Vec<Vec<SpikeRecord>>) -> Vec<Vec<SpikeRecord>> {
        assert_eq!(outgoing.len(), self.shared.n, "one packet slot per rank");
        // account sends (empty packets are suppressed: the paper's
        // point-to-point scheme only messages processes with spikes)
        for (to, pkt) in outgoing.iter().enumerate() {
            if to != self.rank && !pkt.is_empty() {
                self.traffic.p2p_messages += 1;
                self.traffic.p2p_bytes +=
                    MSG_HEADER_BYTES + pkt.len() as u64 * SPIKE_RECORD_BYTES;
            }
        }
        // post sends
        {
            let mut slots = self.shared.slots.lock().unwrap();
            for (to, pkt) in outgoing.into_iter().enumerate() {
                slots[self.rank][to] = Some(pkt);
            }
        }
        self.shared.barrier.wait();
        // drain receives
        let incoming = {
            let mut slots = self.shared.slots.lock().unwrap();
            (0..self.shared.n)
                .map(|from| slots[from][self.rank].take().unwrap_or_default())
                .collect::<Vec<_>>()
        };
        // second barrier: nobody may start the next round before all reads
        self.shared.barrier.wait();
        incoming
    }

    fn register_group(&mut self, members: Vec<Rank>) -> GroupId {
        assert!(
            members.iter().all(|&m| m < self.shared.n),
            "group member out of range"
        );
        let idx = self.groups_registered;
        self.groups_registered += 1;
        let mut groups = self.shared.groups.lock().unwrap();
        if groups.len() <= idx {
            // first rank to arrive creates the group
            groups.push(Arc::new(GroupShared {
                barrier: Barrier::new(members.len()),
                slots: Mutex::new(vec![None; members.len()]),
                members,
            }));
            self.shared.group_gate.notify_all();
        } else {
            assert_eq!(
                groups[idx].members, members,
                "collective group registration diverged between ranks"
            );
        }
        idx
    }

    fn allgather(&mut self, group: GroupId, data: &[u32]) -> Vec<Vec<u32>> {
        // wait until the group exists (another rank may still be registering)
        let g = {
            let mut groups = self.shared.groups.lock().unwrap();
            while groups.len() <= group {
                groups = self.shared.group_gate.wait(groups).unwrap();
            }
            Arc::clone(&groups[group])
        };
        let me = g
            .members
            .iter()
            .position(|&m| m == self.rank)
            .expect("allgather by non-member rank");
        self.traffic.coll_calls += 1;
        // MPI_Allgather cost model: each member's payload traverses the
        // wire to every other member.
        self.traffic.coll_bytes += MSG_HEADER_BYTES
            + data.len() as u64 * 4 * (g.members.len() as u64 - 1).max(0);
        {
            let mut slots = g.slots.lock().unwrap();
            slots[me] = Some(data.to_vec());
        }
        g.barrier.wait();
        let all = {
            let slots = g.slots.lock().unwrap();
            slots
                .iter()
                .map(|s| s.clone().unwrap_or_default())
                .collect::<Vec<_>>()
        };
        g.barrier.wait();
        // last pass clears own slot for the next call
        {
            let mut slots = g.slots.lock().unwrap();
            slots[me] = None;
        }
        g.barrier.wait();
        all
    }

    fn barrier(&mut self) {
        self.shared.barrier.wait();
    }

    fn traffic(&self) -> TrafficStats {
        self.traffic
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(ThreadComm) -> T + Send + Sync + Copy,
        T: Send,
    {
        let world = CommWorld::new(n);
        let comms = world.communicators();
        thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| s.spawn(move || f(c)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn exchange_routes_point_to_point() {
        let out = run_world(3, |mut c| {
            let me = c.rank();
            // rank r sends (pos = 100*r + to) to every other rank
            let outgoing: Vec<Vec<SpikeRecord>> = (0..3)
                .map(|to| {
                    if to == me {
                        vec![]
                    } else {
                        vec![SpikeRecord {
                            pos: (100 * me + to) as u32,
                            mult: 1,
                        }]
                    }
                })
                .collect();
            c.exchange(outgoing)
        });
        for (me, incoming) in out.iter().enumerate() {
            for (from, pkt) in incoming.iter().enumerate() {
                if from == me {
                    assert!(pkt.is_empty());
                } else {
                    assert_eq!(pkt.len(), 1);
                    assert_eq!(pkt[0].pos, (100 * from + me) as u32);
                }
            }
        }
    }

    #[test]
    fn exchange_multiple_rounds_no_crosstalk() {
        let out = run_world(4, |mut c| {
            let me = c.rank() as u32;
            let mut got = Vec::new();
            for round in 0..5u32 {
                let outgoing: Vec<Vec<SpikeRecord>> = (0..4)
                    .map(|_| {
                        vec![SpikeRecord {
                            pos: me * 1000 + round,
                            mult: 1,
                        }]
                    })
                    .collect();
                let incoming = c.exchange(outgoing);
                got.push(incoming);
            }
            got
        });
        for rounds in &out {
            for (round, incoming) in rounds.iter().enumerate() {
                for (from, pkt) in incoming.iter().enumerate() {
                    assert_eq!(pkt[0].pos, from as u32 * 1000 + round as u32);
                }
            }
        }
    }

    #[test]
    fn allgather_over_subgroup() {
        let out = run_world(4, |mut c| {
            let me = c.rank();
            // all ranks register the same group collectively
            let g = c.register_group(vec![1, 2, 3]);
            if me == 0 {
                return vec![];
            }
            let data = vec![me as u32; me]; // variable-length payloads
            let all = c.allgather(g, &data);
            assert_eq!(all.len(), 3);
            all.into_iter().flatten().collect::<Vec<u32>>()
        });
        for me in 1..4 {
            let expect: Vec<u32> = (1..4u32).flat_map(|m| vec![m; m as usize]).collect();
            assert_eq!(out[me], expect);
        }
        assert!(out[0].is_empty());
    }

    #[test]
    fn allgather_repeated_calls() {
        let out = run_world(2, |mut c| {
            let g = c.register_group(vec![0, 1]);
            let mut acc = Vec::new();
            for round in 0..3u32 {
                let all = c.allgather(g, &[c.rank() as u32 + 10 * round]);
                acc.extend(all.into_iter().flatten());
            }
            acc
        });
        assert_eq!(out[0], vec![0, 1, 10, 11, 20, 21]);
        assert_eq!(out[1], vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn traffic_accounting() {
        let out = run_world(2, |mut c| {
            let pkt = vec![SpikeRecord { pos: 1, mult: 1 }; 10];
            let mut outgoing = vec![vec![]; 2];
            outgoing[1 - c.rank()] = pkt;
            c.exchange(outgoing);
            c.traffic()
        });
        for t in out {
            assert_eq!(t.p2p_messages, 1);
            assert_eq!(t.p2p_bytes, MSG_HEADER_BYTES + 10 * SPIKE_RECORD_BYTES);
        }
    }

    #[test]
    fn empty_packets_not_counted() {
        let out = run_world(2, |mut c| {
            c.exchange(vec![vec![], vec![]]);
            c.traffic()
        });
        for t in out {
            assert_eq!(t.p2p_messages, 0);
            assert_eq!(t.p2p_bytes, 0);
        }
    }
}
