//! In-process MPI world: one thread per rank, shared-memory transport.
//!
//! The transport is written so the per-step spike path performs no
//! steady-state heap allocation: `exchange` moves packet buffers through
//! the mailbox (capacity circulates between ranks and is recycled by the
//! caller), and `allgather_into` copies into persistent per-member slots
//! and caller-provided output buffers.

use std::sync::{Arc, Barrier, Condvar, Mutex};

use super::{
    Communicator, GroupId, Rank, SpikeRecord, TrafficStats, COLL_WORD_BYTES, MSG_HEADER_BYTES,
    SPIKE_RECORD_BYTES,
};

/// Shared state of one communicator world.
struct Shared {
    n: usize,
    /// exchange mailbox: `slots[from][to]`
    slots: Mutex<Vec<Vec<Option<Vec<SpikeRecord>>>>>,
    /// per-rank contribution slots for `allreduce_min`
    reduce: Mutex<Vec<u32>>,
    barrier: Barrier,
    groups: Mutex<Vec<Arc<GroupShared>>>,
    group_gate: Condvar,
}

struct GroupShared {
    members: Vec<Rank>,
    /// persistent per-member payload slots (cleared and refilled each
    /// allgather round; capacity is retained across calls)
    slots: Mutex<Vec<Vec<u32>>>,
    barrier: Barrier,
}

/// Factory for a world of `n` thread-rank communicators.
pub struct CommWorld {
    shared: Arc<Shared>,
}

impl CommWorld {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            n,
            slots: Mutex::new(vec![vec![None; n]; n]),
            reduce: Mutex::new(vec![u32::MAX; n]),
            barrier: Barrier::new(n),
            groups: Mutex::new(Vec::new()),
            group_gate: Condvar::new(),
        });
        CommWorld { shared }
    }

    /// Handles for all ranks (consume and move each into its rank thread).
    pub fn communicators(&self) -> Vec<ThreadComm> {
        (0..self.shared.n)
            .map(|r| ThreadComm {
                rank: r,
                shared: Arc::clone(&self.shared),
                groups_registered: 0,
                traffic: TrafficStats::default(),
            })
            .collect()
    }
}

/// Per-rank communicator handle (exclusively owned by the rank's thread).
pub struct ThreadComm {
    rank: Rank,
    shared: Arc<Shared>,
    groups_registered: usize,
    traffic: TrafficStats,
}

impl Communicator for ThreadComm {
    fn rank(&self) -> Rank {
        self.rank
    }

    fn size(&self) -> usize {
        self.shared.n
    }

    fn exchange(&mut self, mut outgoing: Vec<Vec<SpikeRecord>>) -> Vec<Vec<SpikeRecord>> {
        assert_eq!(outgoing.len(), self.shared.n, "one packet slot per rank");
        // account sends (empty packets are suppressed: the paper's
        // point-to-point scheme only messages processes with spikes).
        // A batched interval still costs one message per destination: the
        // records of every emission step in the interval share one envelope.
        for (to, pkt) in outgoing.iter().enumerate() {
            if to != self.rank && !pkt.is_empty() {
                self.traffic.p2p_messages += 1;
                self.traffic.p2p_bytes +=
                    MSG_HEADER_BYTES + pkt.len() as u64 * SPIKE_RECORD_BYTES;
            }
        }
        // post sends: move the packet buffers into the mailbox (the outer
        // vec is kept and refilled with the receives below)
        {
            let mut slots = self.shared.slots.lock().unwrap();
            for (to, pkt) in outgoing.iter_mut().enumerate() {
                slots[self.rank][to] = Some(std::mem::take(pkt));
            }
        }
        self.shared.barrier.wait();
        // drain receives into the (now empty) outgoing vec
        {
            let mut slots = self.shared.slots.lock().unwrap();
            for (from, dst) in outgoing.iter_mut().enumerate() {
                *dst = slots[from][self.rank].take().unwrap_or_default();
            }
        }
        // second barrier: nobody may start the next round before all reads
        self.shared.barrier.wait();
        outgoing
    }

    fn register_group(&mut self, members: Vec<Rank>) -> GroupId {
        assert!(
            members.iter().all(|&m| m < self.shared.n),
            "group member out of range"
        );
        let idx = self.groups_registered;
        self.groups_registered += 1;
        let mut groups = self.shared.groups.lock().unwrap();
        if groups.len() <= idx {
            // first rank to arrive creates the group
            groups.push(Arc::new(GroupShared {
                barrier: Barrier::new(members.len()),
                slots: Mutex::new(vec![Vec::new(); members.len()]),
                members,
            }));
            self.shared.group_gate.notify_all();
        } else {
            assert_eq!(
                groups[idx].members, members,
                "collective group registration diverged between ranks"
            );
        }
        idx
    }

    fn allgather_into(&mut self, group: GroupId, data: &[u32], out: &mut Vec<Vec<u32>>) {
        // wait until the group exists (another rank may still be registering)
        let g = {
            let mut groups = self.shared.groups.lock().unwrap();
            while groups.len() <= group {
                groups = self.shared.group_gate.wait(groups).unwrap();
            }
            Arc::clone(&groups[group])
        };
        let me = g
            .members
            .iter()
            .position(|&m| m == self.rank)
            .expect("allgather by non-member rank");
        self.traffic.coll_calls += 1;
        // MPI_Allgather cost model: each member's payload traverses the
        // wire to every other member.
        self.traffic.coll_bytes += MSG_HEADER_BYTES
            + data.len() as u64 * COLL_WORD_BYTES * (g.members.len() as u64).saturating_sub(1);
        {
            let mut slots = g.slots.lock().unwrap();
            let slot = &mut slots[me];
            slot.clear();
            slot.extend_from_slice(data);
        }
        g.barrier.wait();
        {
            let slots = g.slots.lock().unwrap();
            if out.len() < slots.len() {
                out.resize_with(slots.len(), Vec::new);
            }
            for (dst, src) in out.iter_mut().zip(slots.iter()) {
                dst.clear();
                dst.extend_from_slice(src);
            }
        }
        // second barrier: all members must have copied their receives
        // before anyone overwrites its slot in the next round
        g.barrier.wait();
    }

    fn allreduce_min(&mut self, value: u32) -> u32 {
        {
            let mut r = self.shared.reduce.lock().unwrap();
            r[self.rank] = value;
        }
        self.shared.barrier.wait();
        let min = {
            let r = self.shared.reduce.lock().unwrap();
            r.iter().copied().min().unwrap()
        };
        // all ranks must read before any slot is reused by the next reduce
        self.shared.barrier.wait();
        min
    }

    fn barrier(&mut self) {
        self.shared.barrier.wait();
    }

    fn traffic(&self) -> TrafficStats {
        self.traffic
    }

    fn transport_name(&self) -> &'static str {
        "thread"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    fn run_world<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(ThreadComm) -> T + Send + Sync + Copy,
        T: Send,
    {
        let world = CommWorld::new(n);
        let comms = world.communicators();
        thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| s.spawn(move || f(c)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    fn rec(pos: u32) -> SpikeRecord {
        SpikeRecord {
            pos,
            mult: 1,
            lag: 0,
        }
    }

    #[test]
    fn exchange_routes_point_to_point() {
        let out = run_world(3, |mut c| {
            let me = c.rank();
            // rank r sends (pos = 100*r + to) to every other rank
            let outgoing: Vec<Vec<SpikeRecord>> = (0..3)
                .map(|to| {
                    if to == me {
                        vec![]
                    } else {
                        vec![rec((100 * me + to) as u32)]
                    }
                })
                .collect();
            c.exchange(outgoing)
        });
        for (me, incoming) in out.iter().enumerate() {
            for (from, pkt) in incoming.iter().enumerate() {
                if from == me {
                    assert!(pkt.is_empty());
                } else {
                    assert_eq!(pkt.len(), 1);
                    assert_eq!(pkt[0].pos, (100 * from + me) as u32);
                }
            }
        }
    }

    #[test]
    fn exchange_multiple_rounds_no_crosstalk() {
        let out = run_world(4, |mut c| {
            let me = c.rank() as u32;
            let mut got = Vec::new();
            for round in 0..5u32 {
                let outgoing: Vec<Vec<SpikeRecord>> =
                    (0..4).map(|_| vec![rec(me * 1000 + round)]).collect();
                let incoming = c.exchange(outgoing);
                got.push(incoming);
            }
            got
        });
        for rounds in &out {
            for (round, incoming) in rounds.iter().enumerate() {
                for (from, pkt) in incoming.iter().enumerate() {
                    assert_eq!(pkt[0].pos, from as u32 * 1000 + round as u32);
                }
            }
        }
    }

    #[test]
    fn exchange_recycles_buffer_capacity() {
        // the returned outer vec can be cleared and reused as the next
        // outgoing set — the engine's steady-state allocation-free loop
        let out = run_world(2, |mut c| {
            let mut packets: Vec<Vec<SpikeRecord>> = vec![Vec::new(); 2];
            let mut seen = Vec::new();
            for round in 0..4u32 {
                packets[1 - c.rank()].push(rec(round * 10 + c.rank() as u32));
                let mut incoming = c.exchange(packets);
                seen.push(incoming[1 - c.rank()][0].pos);
                for p in incoming.iter_mut() {
                    p.clear();
                }
                packets = incoming;
            }
            seen
        });
        assert_eq!(out[0], vec![1, 11, 21, 31]);
        assert_eq!(out[1], vec![0, 10, 20, 30]);
    }

    #[test]
    fn allgather_over_subgroup() {
        let out = run_world(4, |mut c| {
            let me = c.rank();
            // all ranks register the same group collectively
            let g = c.register_group(vec![1, 2, 3]);
            if me == 0 {
                return vec![];
            }
            let data = vec![me as u32; me]; // variable-length payloads
            let all = c.allgather(g, &data);
            assert_eq!(all.len(), 3);
            all.into_iter().flatten().collect::<Vec<u32>>()
        });
        for me in 1..4 {
            let expect: Vec<u32> = (1..4u32).flat_map(|m| vec![m; m as usize]).collect();
            assert_eq!(out[me], expect);
        }
        assert!(out[0].is_empty());
    }

    #[test]
    fn allgather_repeated_calls() {
        let out = run_world(2, |mut c| {
            let g = c.register_group(vec![0, 1]);
            let mut acc = Vec::new();
            // reuse one output buffer across rounds (steady-state path)
            let mut gathered: Vec<Vec<u32>> = Vec::new();
            for round in 0..3u32 {
                c.allgather_into(g, &[c.rank() as u32 + 10 * round], &mut gathered);
                for v in &gathered {
                    acc.extend_from_slice(v);
                }
            }
            acc
        });
        assert_eq!(out[0], vec![0, 1, 10, 11, 20, 21]);
        assert_eq!(out[1], vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn allgather_while_other_ranks_still_registering() {
        // Exercises the `group_gate` condvar path: ranks 0–2 call
        // `allgather` on a group id that no rank has registered yet and
        // must block until rank 3 (the late registrar) creates it.
        let out = run_world(4, |mut c| {
            if c.rank() == 3 {
                thread::sleep(Duration::from_millis(30));
                let g = c.register_group(vec![0, 1, 2, 3]);
                c.allgather(g, &[c.rank() as u32])
            } else {
                // group 0 does not exist yet: waits on the condvar
                c.allgather(0, &[c.rank() as u32])
            }
        });
        for all in &out {
            let flat: Vec<u32> = all.iter().flatten().copied().collect();
            assert_eq!(flat, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn interleaved_allgathers_across_two_groups() {
        // Two disjoint groups allgather concurrently in loops, with the
        // pairs deliberately desynchronized — rounds must never mix.
        let out = run_world(4, |mut c| {
            let ga = c.register_group(vec![0, 1]);
            let gb = c.register_group(vec![2, 3]);
            let (g, base) = if c.rank() < 2 { (ga, 100) } else { (gb, 200) };
            let mut acc = Vec::new();
            for round in 0..20u32 {
                if c.rank() % 2 == 0 && round % 3 == 0 {
                    thread::sleep(Duration::from_millis(1));
                }
                let all = c.allgather(g, &[base + 10 * round + c.rank() as u32]);
                acc.push(all.into_iter().flatten().collect::<Vec<u32>>());
            }
            acc
        });
        for (me, rounds) in out.iter().enumerate() {
            let peers: [u32; 2] = if me < 2 { [100, 101] } else { [202, 203] };
            for (round, got) in rounds.iter().enumerate() {
                let expect: Vec<u32> = peers.iter().map(|p| p + 10 * round as u32).collect();
                assert_eq!(got, &expect, "rank {me} round {round}");
            }
        }
    }

    #[test]
    fn interleaved_membership_one_rank_in_both_groups() {
        // rank 0 belongs to both groups and alternates between them while
        // the other members run their own loops
        let out = run_world(3, |mut c| {
            let ga = c.register_group(vec![0, 1]);
            let gb = c.register_group(vec![0, 2]);
            let mut acc = Vec::new();
            for round in 0..10u32 {
                let tag = c.rank() as u32 * 1000 + round;
                match c.rank() {
                    0 => {
                        // interleave: ga, gb, ga, gb, … within each round
                        acc.extend(c.allgather(ga, &[tag]).into_iter().flatten());
                        acc.extend(c.allgather(gb, &[tag]).into_iter().flatten());
                    }
                    1 => acc.extend(c.allgather(ga, &[tag]).into_iter().flatten()),
                    _ => acc.extend(c.allgather(gb, &[tag]).into_iter().flatten()),
                }
            }
            acc
        });
        for round in 0..10u32 {
            let r0 = &out[0][(round as usize) * 4..(round as usize) * 4 + 4];
            assert_eq!(r0, &[round, 1000 + round, round, 2000 + round]);
            let r1 = &out[1][(round as usize) * 2..(round as usize) * 2 + 2];
            assert_eq!(r1, &[round, 1000 + round]);
            let r2 = &out[2][(round as usize) * 2..(round as usize) * 2 + 2];
            assert_eq!(r2, &[round, 2000 + round]);
        }
    }

    #[test]
    fn allreduce_min_agrees_everywhere() {
        let out = run_world(4, |mut c| {
            let a = c.allreduce_min([17u32, 4, 9, u32::MAX][c.rank()]);
            // back-to-back reduces must not interfere
            let b = c.allreduce_min([40u32, 33, 50, 60][c.rank()]);
            (a, b)
        });
        for &(a, b) in &out {
            assert_eq!(a, 4);
            assert_eq!(b, 33);
        }
    }

    #[test]
    fn traffic_accounting() {
        let out = run_world(2, |mut c| {
            let pkt = vec![rec(1); 10];
            let mut outgoing = vec![vec![]; 2];
            outgoing[1 - c.rank()] = pkt;
            c.exchange(outgoing);
            c.traffic()
        });
        for t in out {
            assert_eq!(t.p2p_messages, 1);
            assert_eq!(t.p2p_bytes, MSG_HEADER_BYTES + 10 * SPIKE_RECORD_BYTES);
        }
    }

    #[test]
    fn empty_packets_not_counted() {
        let out = run_world(2, |mut c| {
            c.exchange(vec![vec![], vec![]]);
            c.traffic()
        });
        for t in out {
            assert_eq!(t.p2p_messages, 0);
            assert_eq!(t.p2p_bytes, 0);
        }
    }
}
