//! # nestgpu-rs
//!
//! Reproduction of *"Scalable Construction of Spiking Neural Networks using
//! up to thousands of GPUs"* (CS.DC 2025): the NEST GPU onboard network
//! construction method — communication-free per-rank construction of the
//! point-to-point and collective spike-routing maps — implemented as a
//! three-layer Rust + JAX + Pallas stack. See `DESIGN.md` for the full
//! system inventory and the hardware substitutions.
//!
//! State propagation runs as a phase-structured, allocation-free pipeline
//! with *min-delay exchange batching*: remote spike exchange happens once
//! per minimum remote synaptic delay instead of every step, with
//! bit-identical results (`DESIGN.md` §11). Control it with
//! [`engine::SimConfig::exchange_interval`] or the CLI's
//! `--exchange-interval` flag (default: auto = the min delay).
//!
//! Synapses can be plastic: attach a trace-based STDP rule to a connect
//! call through [`connection::SynSpec::stdp`] (CLI: the `--stdp` knobs of
//! the balanced model) and the [`plasticity`] subsystem evolves the
//! weights during propagation — delay-aware for remote synapses, so
//! batched exchange stays bit-identical (`DESIGN.md` §12). Snapshots
//! carry the plastic state (format v3; v2 files still load as
//! all-static).
//!
//! Static connectivity can be *procedural*: with
//! [`engine::SimConfig::connectivity`] set to
//! [`connection::Connectivity::Procedural`] (CLI: `--connectivity
//! procedural`), connect calls are recorded as compact RNG-seeded
//! descriptors and each spiking neuron's fanout is regenerated on demand
//! behind a bounded LRU cache, instead of materializing every synapse at
//! construction — breaking the per-rank connectivity memory wall at
//! scale. Spike trains are bit-identical to the materialized default;
//! plastic synapses stay materialized; snapshots carry the descriptors
//! (format v4; v2/v3 files still load) (`DESIGN.md` §16).
//!
//! Every run can be observed without perturbing it: setting
//! [`engine::SimConfig::obs`] (CLI: `--obs-dir` / `--obs-interval`)
//! turns on the [`obs`] subsystem — an allocation-free metrics registry
//! (per-phase latency histograms, spike/record/byte volumes, ring and
//! memory occupancy), a bounded per-rank JSONL trace sink with a
//! hash-verified run manifest, and a merged cross-rank summary on rank
//! 0's `SimResult`. `nestgpu report <trace-dir>` analyzes the traces
//! offline. Results are bit-identical with observability on or off, at
//! <2% steps/s overhead (`DESIGN.md` §13).
//!
//! Ranks can be real OS processes: the socket transport
//! ([`comm::SocketComm`]) implements the full [`comm::Communicator`]
//! contract over TCP with a framed wire protocol, a rank-0 rendezvous
//! handshake and a full connection mesh (`DESIGN.md` §15). Select it per
//! process with `--comm socket --rank R --world N --rendezvous HOST:PORT`,
//! or let `nestgpu launch --ranks N <subcommand...>` spawn and wire up N
//! local rank processes. Spike trains are bit-identical across transports;
//! every simulation subcommand prints a world-combined spike hash
//! ([`stats::spike_hash`] folded over ranks) as the cross-process witness.
//!
//! Construction can be *served*, not just cached: `nestgpu serve` runs
//! the multi-tenant construction-cache daemon ([`serve`]). Jobs are
//! content-addressed by [`serve::JobSpec::cache_key`] — an FNV-1a fold
//! of every construction-relevant parameter — and served from a
//! byte-capped LRU of snapshot worlds on disk: the first submit
//! constructs and admits, identical concurrent submits collapse to that
//! one construction (single-flight), and later submits resume warm,
//! skipping construction entirely. `nestgpu submit balanced ...` is the
//! blocking client; every reply carries the world spike hash, so a warm
//! hit is checkably bit-identical to its cold run (`DESIGN.md` §17).

pub mod comm;
pub mod connection;
pub mod engine;
pub mod harness;
pub mod memory;
pub mod models;
pub mod node;
pub mod obs;
pub mod plasticity;
pub mod remote;
pub mod runtime;
pub mod serve;
pub mod snapshot;
pub mod stats;
pub mod util;
