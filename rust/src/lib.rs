//! # nestgpu-rs
//!
//! Reproduction of *"Scalable Construction of Spiking Neural Networks using
//! up to thousands of GPUs"* (CS.DC 2025): the NEST GPU onboard network
//! construction method — communication-free per-rank construction of the
//! point-to-point and collective spike-routing maps — implemented as a
//! three-layer Rust + JAX + Pallas stack. See `DESIGN.md` for the full
//! system inventory and the hardware substitutions.

pub mod comm;
pub mod connection;
pub mod engine;
pub mod harness;
pub mod memory;
pub mod models;
pub mod node;
pub mod remote;
pub mod runtime;
pub mod snapshot;
pub mod stats;
pub mod util;
