//! Stimulation and recording devices.
//!
//! Devices occupy node indexes like neurons do (so `Connect` works on them
//! uniformly) but have no membrane dynamics: the engine services them once
//! per step. A Poisson generator emits spikes with step-wise multiplicity
//! `Poisson(rate · dt)` through its outgoing connections; a spike recorder
//! stores `(step, node)` events for the statistics pipeline.

use crate::util::rng::Rng;

/// Poisson spike generator (one per population is typical: NEST-style, the
/// generator's outgoing connections fan its spikes out to the targets, each
/// target seeing an *independent* realization, as in NEST's
/// `poisson_generator` semantics).
#[derive(Clone, Debug)]
pub struct PoissonGenerator {
    /// emission rate per target (spikes/s)
    pub rate_hz: f64,
    /// node index of this device
    pub node: u32,
    /// private generator (device draws never touch construction streams)
    pub rng: Rng,
}

impl PoissonGenerator {
    pub fn new(node: u32, rate_hz: f64, rng: Rng) -> Self {
        Self { rate_hz, node, rng }
    }

    /// Spike multiplicity for one target in a step of `dt_ms`.
    #[inline]
    pub fn draw_mult(&mut self, dt_ms: f64) -> u16 {
        let lambda = self.rate_hz * dt_ms * 1e-3;
        self.rng.poisson(lambda).min(u16::MAX as u64) as u16
    }

    /// Serialize rate, node binding and the *consumed* RNG stream — the
    /// stream position is what makes a resumed run bit-identical.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.f64(self.rate_hz);
        enc.u32(self.node);
        enc.rng(&self.rng);
    }

    pub fn snapshot_decode(dec: &mut crate::snapshot::Decoder) -> anyhow::Result<Self> {
        let rate_hz = dec.f64()?;
        let node = dec.u32()?;
        let rng = dec.rng()?;
        Ok(Self { rate_hz, node, rng })
    }
}

/// Spike recorder: collects (step, node) pairs.
#[derive(Clone, Debug, Default)]
pub struct SpikeRecorder {
    pub events: Vec<(u32, u32)>,
    pub enabled: bool,
}

impl SpikeRecorder {
    pub fn new(enabled: bool) -> Self {
        Self {
            events: Vec::new(),
            enabled,
        }
    }

    #[inline]
    pub fn record(&mut self, step: u32, node: u32) {
        if self.enabled {
            self.events.push((step, node));
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Serialize the recorder, events included, so a resumed run reports
    /// the *full* spike history (pre- plus post-checkpoint).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.bool(self.enabled);
        enc.seq_len(self.events.len());
        for &(step, node) in &self.events {
            enc.u32(step);
            enc.u32(node);
        }
    }

    pub fn snapshot_decode(dec: &mut crate::snapshot::Decoder) -> anyhow::Result<Self> {
        let enabled = dec.bool()?;
        let n = dec.seq_len(8)?;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let step = dec.u32()?;
            let node = dec.u32()?;
            events.push((step, node));
        }
        Ok(Self { events, enabled })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_statistics() {
        let mut g = PoissonGenerator::new(0, 8000.0, Rng::new(5));
        // 8000 Hz at dt=0.1 ms -> lambda = 0.8 per step
        let n = 50_000;
        let total: u64 = (0..n).map(|_| g.draw_mult(0.1) as u64).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 0.8).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zero_rate_never_fires() {
        let mut g = PoissonGenerator::new(0, 0.0, Rng::new(5));
        assert!((0..1000).all(|_| g.draw_mult(0.1) == 0));
    }

    #[test]
    fn snapshot_resumes_poisson_stream_exactly() {
        let mut g = PoissonGenerator::new(3, 12_000.0, Rng::new(77));
        for _ in 0..500 {
            g.draw_mult(0.1);
        }
        let mut enc = crate::snapshot::Encoder::new();
        g.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let mut restored = PoissonGenerator::snapshot_decode(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(restored.node, 3);
        assert_eq!(restored.rate_hz, 12_000.0);
        for _ in 0..500 {
            assert_eq!(restored.draw_mult(0.1), g.draw_mult(0.1));
        }
    }

    #[test]
    fn recorder_snapshot_roundtrip() {
        let mut r = SpikeRecorder::new(true);
        r.record(1, 2);
        r.record(9, 0);
        let mut enc = crate::snapshot::Encoder::new();
        r.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = SpikeRecorder::snapshot_decode(&mut dec).unwrap();
        dec.finish().unwrap();
        assert!(d.enabled);
        assert_eq!(d.events, r.events);
    }

    #[test]
    fn recorder_gating() {
        let mut r = SpikeRecorder::new(false);
        r.record(1, 2);
        assert!(r.is_empty());
        let mut r = SpikeRecorder::new(true);
        r.record(1, 2);
        r.record(3, 4);
        assert_eq!(r.events, vec![(1, 2), (3, 4)]);
    }
}
