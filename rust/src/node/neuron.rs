//! iaf_psc_exp neuron parameters and the exact-integration propagators.
//!
//! This mirrors `python/compile/kernels/ref.py` exactly: the same parameter
//! set, the same propagator formulas, the same packed order consumed by the
//! AOT-compiled kernel (checked against `artifacts/manifest.json` at load
//! time by the PJRT runtime).

/// Number of packed scalar parameters (must match kernels/lif.py).
pub const NUM_PARAMS: usize = 10;

/// Packed parameter order (must match `PARAM_ORDER` in kernels/lif.py).
pub const PARAM_ORDER: [&str; NUM_PARAMS] = [
    "p22", "p21ex", "p21in", "p20", "p11ex", "p11in", "theta", "v_reset", "t_ref", "i_e",
];

/// Biophysical iaf_psc_exp parameters (NEST defaults unless noted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifParams {
    /// membrane time constant (ms)
    pub tau_m: f64,
    /// membrane capacitance (pF)
    pub c_m: f64,
    /// excitatory synaptic time constant (ms)
    pub tau_syn_ex: f64,
    /// inhibitory synaptic time constant (ms)
    pub tau_syn_in: f64,
    /// resting potential (mV); state v is V_m - E_L
    pub e_l: f64,
    /// spike threshold (mV, absolute)
    pub v_th: f64,
    /// reset potential (mV, absolute)
    pub v_reset: f64,
    /// refractory period (ms)
    pub t_ref: f64,
    /// constant input current (pA)
    pub i_e: f64,
}

impl Default for LifParams {
    fn default() -> Self {
        Self {
            tau_m: 10.0,
            c_m: 250.0,
            tau_syn_ex: 0.5,
            tau_syn_in: 0.5,
            e_l: -65.0,
            v_th: -50.0,
            v_reset: -65.0,
            t_ref: 2.0,
            i_e: 0.0,
        }
    }
}

impl LifParams {
    /// Exact propagators for step `dt` (ms), packed in `PARAM_ORDER`.
    pub fn packed(&self, dt: f64) -> [f32; NUM_PARAMS] {
        let h = dt;
        let p22 = (-h / self.tau_m).exp();
        let p11ex = (-h / self.tau_syn_ex).exp();
        let p11in = (-h / self.tau_syn_in).exp();
        let p21 = |tau_syn: f64, p11: f64| -> f64 {
            if (tau_syn - self.tau_m).abs() < 1e-9 {
                h / self.c_m * p22
            } else {
                self.tau_m * tau_syn / (self.c_m * (self.tau_m - tau_syn)) * (p22 - p11)
            }
        };
        let p21ex = p21(self.tau_syn_ex, p11ex);
        let p21in = p21(self.tau_syn_in, p11in);
        let p20 = self.tau_m / self.c_m * (1.0 - p22);
        [
            p22 as f32,
            p21ex as f32,
            p21in as f32,
            p20 as f32,
            p11ex as f32,
            p11in as f32,
            (self.v_th - self.e_l) as f32,
            (self.v_reset - self.e_l) as f32,
            (self.t_ref / h).round() as f32,
            self.i_e as f32,
        ]
    }

    /// Spike threshold relative to E_L.
    pub fn theta(&self) -> f64 {
        self.v_th - self.e_l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn propagators_positive_and_bounded() {
        let p = LifParams::default().packed(0.1);
        let (p22, p21ex, p21in, p20, p11ex, p11in) = (p[0], p[1], p[2], p[3], p[4], p[5]);
        assert!(p22 > 0.0 && p22 < 1.0);
        assert!(p11ex > 0.0 && p11ex < 1.0);
        assert!(p11in > 0.0 && p11in < 1.0);
        assert!(p21ex > 0.0, "excitatory propagator must be positive");
        assert!(p21in > 0.0);
        assert!(p20 > 0.0);
    }

    #[test]
    fn packed_matches_python_oracle() {
        // golden values from python: LifParams().packed() (ref.py defaults)
        let p = LifParams::default().packed(0.1);
        let expect: [f32; NUM_PARAMS] = [
            0.99004984,   // p22 = exp(-0.01)
            3.6067175e-4, // p21ex
            3.6067175e-4, // p21in
            3.9800664e-4, // p20
            0.8187308,    // p11ex = exp(-0.2)
            0.8187308,    // p11in
            15.0,         // theta
            0.0,          // v_reset
            20.0,         // t_ref steps
            0.0,          // i_e
        ];
        for (i, (a, b)) in p.iter().zip(expect.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-6 * (1.0 + b.abs()),
                "param {i} ({}): {a} vs {b}",
                PARAM_ORDER[i]
            );
        }
    }

    #[test]
    fn degenerate_tau_limit_finite() {
        let mut lp = LifParams::default();
        lp.tau_syn_ex = lp.tau_m;
        let p = lp.packed(0.1);
        assert!(p[1].is_finite() && p[1] > 0.0);
    }

    #[test]
    fn theta_relative() {
        assert_eq!(LifParams::default().theta(), 15.0);
    }
}
