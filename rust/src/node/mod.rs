//! Node space, neuron parameters, spike ring buffers and devices.

pub mod buffers;
pub mod device;
pub mod neuron;

pub use buffers::RingBuffers;
pub use neuron::LifParams;

/// What a local node index refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// A real neuron with dynamic state; `chunk`/`offset` locate its state
    /// in the runtime state chunks (populations share a chunk).
    Neuron { chunk: u16, offset: u32 },
    /// An image (proxy) of a remote source neuron (§0.3): no state, only
    /// outgoing connections; `src_rank` records where the real neuron is.
    Image { src_rank: u16 },
    /// A stimulation/recording device (Poisson generator, spike recorder).
    Device { dev: u16 },
}

/// The per-rank node index space: real neurons, devices and image neurons
/// share one index range `0..M` (image neurons are appended by
/// `RemoteConnect` as in Eq. 6: `l := M; M <- M + 1`).
#[derive(Debug, Default)]
pub struct NodeSpace {
    kinds: Vec<NodeKind>,
    n_neurons: u32,
    n_images: u32,
    n_devices: u32,
}

impl NodeSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total number of local node indexes (the paper's `M_σ`).
    pub fn m(&self) -> u32 {
        self.kinds.len() as u32
    }

    pub fn n_neurons(&self) -> u32 {
        self.n_neurons
    }
    pub fn n_images(&self) -> u32 {
        self.n_images
    }
    pub fn n_devices(&self) -> u32 {
        self.n_devices
    }

    pub fn kind(&self, idx: u32) -> NodeKind {
        self.kinds[idx as usize]
    }

    /// Append `n` neurons belonging to state chunk `chunk`; returns the
    /// first index.
    pub fn create_neurons(&mut self, chunk: u16, n: u32) -> u32 {
        let first = self.m();
        for offset in 0..n {
            self.kinds.push(NodeKind::Neuron { chunk, offset });
        }
        self.n_neurons += n;
        first
    }

    /// Append one device; returns its node index.
    pub fn create_device(&mut self, dev: u16) -> u32 {
        let idx = self.m();
        self.kinds.push(NodeKind::Device { dev });
        self.n_devices += 1;
        idx
    }

    /// Append one image neuron for a remote source on `src_rank`; returns
    /// its local index (the `L` value of the new map entry).
    pub fn create_image(&mut self, src_rank: u16) -> u32 {
        let idx = self.m();
        self.kinds.push(NodeKind::Image { src_rank });
        self.n_images += 1;
        idx
    }

    pub fn is_image(&self, idx: u32) -> bool {
        matches!(self.kind(idx), NodeKind::Image { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_space_is_shared_and_sequential() {
        let mut ns = NodeSpace::new();
        let a = ns.create_neurons(0, 3);
        let d = ns.create_device(0);
        let i = ns.create_image(2);
        let b = ns.create_neurons(1, 2);
        assert_eq!(a, 0);
        assert_eq!(d, 3);
        assert_eq!(i, 4);
        assert_eq!(b, 5);
        assert_eq!(ns.m(), 7);
        assert_eq!(ns.n_neurons(), 5);
        assert_eq!(ns.n_images(), 1);
        assert_eq!(ns.n_devices(), 1);
        assert!(ns.is_image(4));
        assert!(!ns.is_image(0));
        assert_eq!(ns.kind(5), NodeKind::Neuron { chunk: 1, offset: 0 });
        assert_eq!(ns.kind(4), NodeKind::Image { src_rank: 2 });
    }
}
