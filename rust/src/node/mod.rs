//! Node space, neuron parameters, spike ring buffers, plasticity trace
//! buffers and devices.

pub mod buffers;
pub mod device;
pub mod neuron;
pub mod traces;

pub use buffers::RingBuffers;
pub use neuron::LifParams;
pub use traces::TraceBuffers;

/// What a local node index refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// A real neuron with dynamic state; `chunk`/`offset` locate its state
    /// in the runtime state chunks (populations share a chunk).
    Neuron { chunk: u16, offset: u32 },
    /// An image (proxy) of a remote source neuron (§0.3): no state, only
    /// outgoing connections; `src_rank` records where the real neuron is.
    Image { src_rank: u16 },
    /// A stimulation/recording device (Poisson generator, spike recorder).
    Device { dev: u16 },
}

/// The per-rank node index space: real neurons, devices and image neurons
/// share one index range `0..M` (image neurons are appended by
/// `RemoteConnect` as in Eq. 6: `l := M; M <- M + 1`).
#[derive(Debug, Default)]
pub struct NodeSpace {
    kinds: Vec<NodeKind>,
    n_neurons: u32,
    n_images: u32,
    n_devices: u32,
}

impl NodeSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total number of local node indexes (the paper's `M_σ`).
    pub fn m(&self) -> u32 {
        self.kinds.len() as u32
    }

    pub fn n_neurons(&self) -> u32 {
        self.n_neurons
    }
    pub fn n_images(&self) -> u32 {
        self.n_images
    }
    pub fn n_devices(&self) -> u32 {
        self.n_devices
    }

    pub fn kind(&self, idx: u32) -> NodeKind {
        self.kinds[idx as usize]
    }

    /// Append `n` neurons belonging to state chunk `chunk`; returns the
    /// first index.
    pub fn create_neurons(&mut self, chunk: u16, n: u32) -> u32 {
        let first = self.m();
        for offset in 0..n {
            self.kinds.push(NodeKind::Neuron { chunk, offset });
        }
        self.n_neurons += n;
        first
    }

    /// Append one device; returns its node index.
    pub fn create_device(&mut self, dev: u16) -> u32 {
        let idx = self.m();
        self.kinds.push(NodeKind::Device { dev });
        self.n_devices += 1;
        idx
    }

    /// Append one image neuron for a remote source on `src_rank`; returns
    /// its local index (the `L` value of the new map entry).
    pub fn create_image(&mut self, src_rank: u16) -> u32 {
        let idx = self.m();
        self.kinds.push(NodeKind::Image { src_rank });
        self.n_images += 1;
        idx
    }

    pub fn is_image(&self, idx: u32) -> bool {
        matches!(self.kind(idx), NodeKind::Image { .. })
    }

    /// Serialize the node index space (one tagged entry per node).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.seq_len(self.kinds.len());
        for k in &self.kinds {
            match *k {
                NodeKind::Neuron { chunk, offset } => {
                    enc.u8(0);
                    enc.u16(chunk);
                    enc.u32(offset);
                }
                NodeKind::Image { src_rank } => {
                    enc.u8(1);
                    enc.u16(src_rank);
                }
                NodeKind::Device { dev } => {
                    enc.u8(2);
                    enc.u16(dev);
                }
            }
        }
    }

    /// Rebuild from [`NodeSpace::snapshot_encode`] output (counts are
    /// recomputed from the entries).
    pub fn snapshot_decode(dec: &mut crate::snapshot::Decoder) -> anyhow::Result<Self> {
        let n = dec.seq_len(3)?;
        let mut ns = NodeSpace::new();
        ns.kinds.reserve(n);
        for _ in 0..n {
            match dec.u8()? {
                0 => {
                    let chunk = dec.u16()?;
                    let offset = dec.u32()?;
                    ns.kinds.push(NodeKind::Neuron { chunk, offset });
                    ns.n_neurons += 1;
                }
                1 => {
                    let src_rank = dec.u16()?;
                    ns.kinds.push(NodeKind::Image { src_rank });
                    ns.n_images += 1;
                }
                2 => {
                    let dev = dec.u16()?;
                    ns.kinds.push(NodeKind::Device { dev });
                    ns.n_devices += 1;
                }
                tag => anyhow::bail!("unknown node-kind tag {tag} in snapshot"),
            }
        }
        Ok(ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_space_is_shared_and_sequential() {
        let mut ns = NodeSpace::new();
        let a = ns.create_neurons(0, 3);
        let d = ns.create_device(0);
        let i = ns.create_image(2);
        let b = ns.create_neurons(1, 2);
        assert_eq!(a, 0);
        assert_eq!(d, 3);
        assert_eq!(i, 4);
        assert_eq!(b, 5);
        assert_eq!(ns.m(), 7);
        assert_eq!(ns.n_neurons(), 5);
        assert_eq!(ns.n_images(), 1);
        assert_eq!(ns.n_devices(), 1);
        assert!(ns.is_image(4));
        assert!(!ns.is_image(0));
        assert_eq!(ns.kind(5), NodeKind::Neuron { chunk: 1, offset: 0 });
        assert_eq!(ns.kind(4), NodeKind::Image { src_rank: 2 });
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut ns = NodeSpace::new();
        ns.create_neurons(0, 3);
        ns.create_device(1);
        ns.create_image(7);
        ns.create_neurons(2, 2);
        let mut enc = crate::snapshot::Encoder::new();
        ns.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = NodeSpace::snapshot_decode(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.m(), ns.m());
        assert_eq!(d.n_neurons(), ns.n_neurons());
        assert_eq!(d.n_images(), ns.n_images());
        assert_eq!(d.n_devices(), ns.n_devices());
        for i in 0..ns.m() {
            assert_eq!(d.kind(i), ns.kind(i));
        }
    }
}
