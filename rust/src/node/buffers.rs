//! Input spike ring buffers (Appendix F, Fig. 16c).
//!
//! Each neuron has a circular buffer per receptor port; a delivered spike
//! is accumulated into the slot shifted from the current time step by its
//! delay, adding `multiplicity × weight`. The layout is a single merged
//! array, slot-major with the two ports interleaved per slot
//! (`[slot][port][neuron]`): reading the current step's input for all
//! neurons — the hand-off to the device kernel — is one contiguous row
//! split in half, and the delivery hot path addresses a cell by a single
//! precomputed *port-baked destination* `port · n + neuron` inside a slot
//! row (no port branch, see `engine/delivery.rs` and DESIGN.md §14).

use crate::memory::{MemKind, Tracker};

/// Ring buffers for `n` neurons, `slots` delay slots and 2 receptor ports.
pub struct RingBuffers {
    n: usize,
    slots: usize,
    cursor: usize,
    /// merged accumulation, `[slot][port][neuron]` flattened — each slot
    /// row is `2n` wide: excitatory half, then inhibitory half
    data: Vec<f32>,
    tracked: u64,
}

impl RingBuffers {
    /// `max_delay` in steps (the buffer needs max_delay + 1 slots so that a
    /// delay of `max_delay` lands on a slot not yet consumed).
    ///
    /// With min-delay exchange batching the simulator passes
    /// `cfg.max_delay_steps + exchange_interval − 1` for the *remote*
    /// delivery plane, so that ring covers `max_delay + interval` slots.
    /// The lag shift keeps every effective delay ≤ `max_delay`; the extra
    /// `interval − 1` slots are defensive headroom so a batching
    /// accounting bug fails the [`RingBuffers::supports`] debug assert
    /// instead of silently aliasing the slot being consumed.
    pub fn new(n: usize, max_delay: u16, tr: &mut Tracker) -> Self {
        let slots = max_delay as usize + 1;
        let bytes = (n * slots * 2 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        Self {
            n,
            slots,
            cursor: 0,
            data: vec![0.0; n * slots * 2],
            tracked: bytes,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn n_slots(&self) -> usize {
        self.slots
    }

    /// Whether a delivery `delay` (after any batching lag shift) lands on
    /// a slot this ring can hold without aliasing the current step.
    #[inline]
    pub fn supports(&self, delay: u16) -> bool {
        delay >= 1 && (delay as usize) < self.slots
    }

    /// The ring slot a delivery `delay` steps from now lands in.
    #[inline]
    pub fn slot_of(&self, delay: u16) -> usize {
        debug_assert!(self.supports(delay));
        (self.cursor + delay as usize) % self.slots
    }

    /// One slot's full accumulation row (`2n` cells: excitatory half then
    /// inhibitory half), addressed by port-baked destination indexes —
    /// the delivery queue's streaming write target.
    #[inline]
    pub fn row_mut(&mut self, slot: usize) -> &mut [f32] {
        debug_assert!(slot < self.slots);
        let a = slot * 2 * self.n;
        &mut self.data[a..a + 2 * self.n]
    }

    /// Accumulate a spike: `delay` steps from now, on `port`, adding
    /// `weight * mult`. Delays must satisfy `1 <= delay <= max_delay`.
    #[inline]
    pub fn add(&mut self, neuron: u32, port: u8, delay: u16, weight: f32, mult: u16) {
        debug_assert!((neuron as usize) < self.n);
        let dest = u32::from(port) * self.n as u32 + neuron;
        self.add_dest(dest, delay, weight, mult);
    }

    /// Accumulate by port-baked destination `port · n + neuron` (the
    /// prepared-plan fast path: no port branch, no LUT lookup).
    #[inline]
    pub fn add_dest(&mut self, dest: u32, delay: u16, weight: f32, mult: u16) {
        debug_assert!((dest as usize) < 2 * self.n);
        let idx = self.slot_of(delay) * 2 * self.n + dest as usize;
        self.data[idx] += weight * mult as f32;
    }

    /// The input slices for the current step (to feed the device kernel):
    /// `(excitatory, inhibitory)`.
    pub fn current(&self) -> (&[f32], &[f32]) {
        let a = self.cursor * 2 * self.n;
        let row = &self.data[a..a + 2 * self.n];
        row.split_at(self.n)
    }

    /// Zero the consumed slot and advance the cursor by one step.
    pub fn advance(&mut self) {
        let a = self.cursor * 2 * self.n;
        self.data[a..a + 2 * self.n].fill(0.0);
        self.cursor = (self.cursor + 1) % self.slots;
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(MemKind::Device, self.tracked);
        self.tracked = 0;
    }

    /// Serialize the buffers including the cursor and every pending slot —
    /// restoring mid-run means spikes already in flight (delivered but not
    /// yet consumed) must survive the checkpoint.
    ///
    /// The byte layout is the original plane-major format (all excitatory
    /// slots, then all inhibitory slots), kept stable across the internal
    /// move to the merged `[slot][port][neuron]` array so existing
    /// snapshot files load unchanged.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.u64(self.n as u64);
        enc.u64(self.slots as u64);
        enc.u64(self.cursor as u64);
        let mut plane = vec![0.0f32; self.n * self.slots];
        for s in 0..self.slots {
            let a = s * 2 * self.n;
            plane[s * self.n..(s + 1) * self.n].copy_from_slice(&self.data[a..a + self.n]);
        }
        enc.slice_f32(&plane);
        for s in 0..self.slots {
            let a = s * 2 * self.n + self.n;
            plane[s * self.n..(s + 1) * self.n].copy_from_slice(&self.data[a..a + self.n]);
        }
        enc.slice_f32(&plane);
    }

    /// Rebuild from [`RingBuffers::snapshot_encode`] output.
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let n = dec.u64()? as usize;
        let slots = dec.u64()? as usize;
        let cursor = dec.u64()? as usize;
        let ex = dec.vec_f32()?;
        let inh = dec.vec_f32()?;
        if ex.len() != n * slots || inh.len() != n * slots || (slots > 0 && cursor >= slots) {
            anyhow::bail!(
                "ring-buffer snapshot inconsistent: n={n} slots={slots} cursor={cursor} \
                 ex={} inh={}",
                ex.len(),
                inh.len()
            );
        }
        let bytes = (n * slots * 2 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        let mut data = vec![0.0f32; n * slots * 2];
        for s in 0..slots {
            let a = s * 2 * n;
            data[a..a + n].copy_from_slice(&ex[s * n..(s + 1) * n]);
            data[a + n..a + 2 * n].copy_from_slice(&inh[s * n..(s + 1) * n]);
        }
        Ok(Self {
            n,
            slots,
            cursor,
            data,
            tracked: bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivery_lands_after_delay_steps() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(4, 5, &mut tr);
        rb.add(2, 0, 3, 1.5, 1);
        for step in 0..6 {
            let (ex, _) = rb.current();
            if step == 3 {
                assert_eq!(ex[2], 1.5, "arrives exactly at t+3");
            } else {
                assert!(ex.iter().all(|&x| x == 0.0), "step {step}: {ex:?}");
            }
            rb.advance();
        }
    }

    #[test]
    fn accumulation_and_ports() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(2, 3, &mut tr);
        rb.add(0, 0, 1, 2.0, 1);
        rb.add(0, 0, 1, 3.0, 2); // multiplicity 2
        rb.add(0, 1, 1, -4.0, 1);
        rb.advance();
        let (ex, inh) = rb.current();
        assert_eq!(ex[0], 8.0); // 2 + 3*2
        assert_eq!(inh[0], -4.0);
        assert_eq!(ex[1], 0.0);
    }

    #[test]
    fn slot_reuse_after_wraparound() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(1, 2, &mut tr);
        rb.add(0, 0, 2, 1.0, 1);
        rb.advance(); // t=1
        rb.advance(); // t=2, current now holds the spike
        assert_eq!(rb.current().0[0], 1.0);
        rb.advance(); // consumed slot zeroed
        // wrap all the way around again: nothing ghosts
        for _ in 0..6 {
            assert_eq!(rb.current().0[0], 0.0);
            rb.advance();
        }
    }

    #[test]
    fn interval_headroom_slots_are_usable() {
        // a ring sized max_delay + interval − 1 (as the simulator does for
        // exchange batching) accepts deliveries across the whole range
        let mut tr = Tracker::new();
        let (max_delay, interval) = (6u16, 4u16);
        let mut rb = RingBuffers::new(2, max_delay + interval - 1, &mut tr);
        assert_eq!(rb.n_slots(), (max_delay + interval) as usize);
        assert!(rb.supports(1) && rb.supports(max_delay + interval - 1));
        assert!(!rb.supports(0) && !rb.supports(max_delay + interval));
        rb.add(1, 0, max_delay + interval - 1, 2.5, 1);
        for _ in 0..(max_delay + interval - 1) {
            assert_eq!(rb.current().0[1], 0.0);
            rb.advance();
        }
        assert_eq!(rb.current().0[1], 2.5);
    }

    #[test]
    fn max_delay_is_usable() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(1, 4, &mut tr);
        rb.add(0, 0, 4, 9.0, 1);
        for _ in 0..4 {
            rb.advance();
        }
        assert_eq!(rb.current().0[0], 9.0);
    }

    #[test]
    fn slot_arithmetic_wraps_at_interval_headroom_size() {
        // wrap arithmetic at the batched-remote ring size
        // slots = max_delay + interval, over two full wraps
        let mut tr = Tracker::new();
        let (max_delay, interval) = (5u16, 3u16);
        let mut rb = RingBuffers::new(1, max_delay + interval - 1, &mut tr);
        let slots = rb.n_slots();
        assert_eq!(slots, (max_delay + interval) as usize);
        for step in 0..(2 * slots) {
            for d in 1..(max_delay + interval) {
                assert_eq!(
                    rb.slot_of(d),
                    (step + d as usize) % slots,
                    "step {step} delay {d}"
                );
            }
            rb.advance();
        }
    }

    #[test]
    fn add_dest_bakes_the_port() {
        let mut tr = Tracker::new();
        let n = 3u32;
        let mut a = RingBuffers::new(n as usize, 4, &mut tr);
        let mut b = RingBuffers::new(n as usize, 4, &mut tr);
        for (neuron, port, delay, w, mult) in
            [(0u32, 0u8, 1u16, 1.25f32, 1u16), (2, 1, 3, -0.5, 2), (1, 1, 4, 2.0, 1)]
        {
            a.add(neuron, port, delay, w, mult);
            b.add_dest(u32::from(port) * n + neuron, delay, w, mult);
        }
        for _ in 0..5 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn row_mut_writes_land_like_add() {
        let mut tr = Tracker::new();
        let mut a = RingBuffers::new(2, 3, &mut tr);
        let mut b = RingBuffers::new(2, 3, &mut tr);
        a.add(1, 1, 2, 4.0, 1);
        let slot = b.slot_of(2);
        b.row_mut(slot)[2 + 1] += 4.0; // inhibitory half starts at n = 2
        for _ in 0..4 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn snapshot_preserves_in_flight_spikes() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(3, 6, &mut tr);
        rb.add(0, 0, 2, 1.5, 1);
        rb.add(2, 1, 5, -3.0, 2);
        rb.advance(); // move the cursor off zero
        rb.add(1, 0, 1, 7.0, 1);
        let mut enc = crate::snapshot::Encoder::new();
        rb.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let mut restored = RingBuffers::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(restored.n(), rb.n());
        assert_eq!(restored.n_slots(), rb.n_slots());
        // both must now play out identically for a full wrap-around
        for _ in 0..2 * rb.n_slots() {
            assert_eq!(restored.current(), rb.current());
            restored.advance();
            rb.advance();
        }
        assert_eq!(tr2.current(MemKind::Device), tr.current(MemKind::Device));
    }

    #[test]
    fn snapshot_byte_format_is_plane_major() {
        // the on-disk layout predates the merged array: header, then the
        // full excitatory plane ([slot][neuron]), then the inhibitory one
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(2, 1, &mut tr); // 2 slots
        rb.add(0, 0, 1, 1.0, 1); // ex, slot 1, neuron 0
        rb.add(1, 1, 1, 2.0, 1); // inh, slot 1, neuron 1
        let mut enc = crate::snapshot::Encoder::new();
        rb.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        assert_eq!(dec.u64().unwrap(), 2); // n
        assert_eq!(dec.u64().unwrap(), 2); // slots
        assert_eq!(dec.u64().unwrap(), 0); // cursor
        assert_eq!(dec.vec_f32().unwrap(), vec![0.0, 0.0, 1.0, 0.0]); // ex plane
        assert_eq!(dec.vec_f32().unwrap(), vec![0.0, 0.0, 0.0, 2.0]); // inh plane
        dec.finish().unwrap();
    }

    #[test]
    fn memory_tracked_and_released() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(100, 15, &mut tr);
        assert_eq!(tr.current(MemKind::Device), 100 * 16 * 2 * 4);
        rb.release(&mut tr);
        assert_eq!(tr.current(MemKind::Device), 0);
    }
}
