//! Input spike ring buffers (Appendix F, Fig. 16c).
//!
//! Each neuron has a circular buffer per receptor port; a delivered spike
//! is accumulated into the slot shifted from the current time step by its
//! delay, adding `multiplicity × weight`. The layout is slot-major
//! (`[slot][neuron]`) so that reading the current step's input for all
//! neurons of a rank — the hand-off to the device kernel — is a contiguous
//! slice per port.

use crate::memory::{MemKind, Tracker};

/// Ring buffers for `n` neurons, `slots` delay slots and 2 receptor ports.
pub struct RingBuffers {
    n: usize,
    slots: usize,
    cursor: usize,
    /// excitatory accumulation, `[slot][neuron]` flattened
    ex: Vec<f32>,
    /// inhibitory accumulation
    inh: Vec<f32>,
    tracked: u64,
}

impl RingBuffers {
    /// `max_delay` in steps (the buffer needs max_delay + 1 slots so that a
    /// delay of `max_delay` lands on a slot not yet consumed).
    ///
    /// With min-delay exchange batching the simulator passes
    /// `cfg.max_delay_steps + exchange_interval − 1` for the *remote*
    /// delivery plane, so that ring covers `max_delay + interval` slots.
    /// The lag shift keeps every effective delay ≤ `max_delay`; the extra
    /// `interval − 1` slots are defensive headroom so a batching
    /// accounting bug fails the [`RingBuffers::supports`] debug assert
    /// instead of silently aliasing the slot being consumed.
    pub fn new(n: usize, max_delay: u16, tr: &mut Tracker) -> Self {
        let slots = max_delay as usize + 1;
        let bytes = (n * slots * 2 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        Self {
            n,
            slots,
            cursor: 0,
            ex: vec![0.0; n * slots],
            inh: vec![0.0; n * slots],
            tracked: bytes,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn n_slots(&self) -> usize {
        self.slots
    }

    /// Whether a delivery `delay` (after any batching lag shift) lands on
    /// a slot this ring can hold without aliasing the current step.
    #[inline]
    pub fn supports(&self, delay: u16) -> bool {
        delay >= 1 && (delay as usize) < self.slots
    }

    /// Accumulate a spike: `delay` steps from now, on `port`, adding
    /// `weight * mult`. Delays must satisfy `1 <= delay <= max_delay`.
    #[inline]
    pub fn add(&mut self, neuron: u32, port: u8, delay: u16, weight: f32, mult: u16) {
        debug_assert!(delay >= 1 && (delay as usize) < self.slots);
        debug_assert!((neuron as usize) < self.n);
        let slot = (self.cursor + delay as usize) % self.slots;
        let idx = slot * self.n + neuron as usize;
        let w = weight * mult as f32;
        if port == 0 {
            self.ex[idx] += w;
        } else {
            self.inh[idx] += w;
        }
    }

    /// The input slices for the current step (to feed the device kernel).
    pub fn current(&self) -> (&[f32], &[f32]) {
        let a = self.cursor * self.n;
        (&self.ex[a..a + self.n], &self.inh[a..a + self.n])
    }

    /// Zero the consumed slot and advance the cursor by one step.
    pub fn advance(&mut self) {
        let a = self.cursor * self.n;
        self.ex[a..a + self.n].fill(0.0);
        self.inh[a..a + self.n].fill(0.0);
        self.cursor = (self.cursor + 1) % self.slots;
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(MemKind::Device, self.tracked);
        self.tracked = 0;
    }

    /// Serialize the buffers including the cursor and every pending slot —
    /// restoring mid-run means spikes already in flight (delivered but not
    /// yet consumed) must survive the checkpoint.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.u64(self.n as u64);
        enc.u64(self.slots as u64);
        enc.u64(self.cursor as u64);
        enc.slice_f32(&self.ex);
        enc.slice_f32(&self.inh);
    }

    /// Rebuild from [`RingBuffers::snapshot_encode`] output.
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let n = dec.u64()? as usize;
        let slots = dec.u64()? as usize;
        let cursor = dec.u64()? as usize;
        let ex = dec.vec_f32()?;
        let inh = dec.vec_f32()?;
        if ex.len() != n * slots || inh.len() != n * slots || (slots > 0 && cursor >= slots) {
            anyhow::bail!(
                "ring-buffer snapshot inconsistent: n={n} slots={slots} cursor={cursor} \
                 ex={} inh={}",
                ex.len(),
                inh.len()
            );
        }
        let bytes = (n * slots * 2 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        Ok(Self {
            n,
            slots,
            cursor,
            ex,
            inh,
            tracked: bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delivery_lands_after_delay_steps() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(4, 5, &mut tr);
        rb.add(2, 0, 3, 1.5, 1);
        for step in 0..6 {
            let (ex, _) = rb.current();
            if step == 3 {
                assert_eq!(ex[2], 1.5, "arrives exactly at t+3");
            } else {
                assert!(ex.iter().all(|&x| x == 0.0), "step {step}: {ex:?}");
            }
            rb.advance();
        }
    }

    #[test]
    fn accumulation_and_ports() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(2, 3, &mut tr);
        rb.add(0, 0, 1, 2.0, 1);
        rb.add(0, 0, 1, 3.0, 2); // multiplicity 2
        rb.add(0, 1, 1, -4.0, 1);
        rb.advance();
        let (ex, inh) = rb.current();
        assert_eq!(ex[0], 8.0); // 2 + 3*2
        assert_eq!(inh[0], -4.0);
        assert_eq!(ex[1], 0.0);
    }

    #[test]
    fn slot_reuse_after_wraparound() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(1, 2, &mut tr);
        rb.add(0, 0, 2, 1.0, 1);
        rb.advance(); // t=1
        rb.advance(); // t=2, current now holds the spike
        assert_eq!(rb.current().0[0], 1.0);
        rb.advance(); // consumed slot zeroed
        // wrap all the way around again: nothing ghosts
        for _ in 0..6 {
            assert_eq!(rb.current().0[0], 0.0);
            rb.advance();
        }
    }

    #[test]
    fn interval_headroom_slots_are_usable() {
        // a ring sized max_delay + interval − 1 (as the simulator does for
        // exchange batching) accepts deliveries across the whole range
        let mut tr = Tracker::new();
        let (max_delay, interval) = (6u16, 4u16);
        let mut rb = RingBuffers::new(2, max_delay + interval - 1, &mut tr);
        assert_eq!(rb.n_slots(), (max_delay + interval) as usize);
        assert!(rb.supports(1) && rb.supports(max_delay + interval - 1));
        assert!(!rb.supports(0) && !rb.supports(max_delay + interval));
        rb.add(1, 0, max_delay + interval - 1, 2.5, 1);
        for _ in 0..(max_delay + interval - 1) {
            assert_eq!(rb.current().0[1], 0.0);
            rb.advance();
        }
        assert_eq!(rb.current().0[1], 2.5);
    }

    #[test]
    fn max_delay_is_usable() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(1, 4, &mut tr);
        rb.add(0, 0, 4, 9.0, 1);
        for _ in 0..4 {
            rb.advance();
        }
        assert_eq!(rb.current().0[0], 9.0);
    }

    #[test]
    fn snapshot_preserves_in_flight_spikes() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(3, 6, &mut tr);
        rb.add(0, 0, 2, 1.5, 1);
        rb.add(2, 1, 5, -3.0, 2);
        rb.advance(); // move the cursor off zero
        rb.add(1, 0, 1, 7.0, 1);
        let mut enc = crate::snapshot::Encoder::new();
        rb.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let mut restored = RingBuffers::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(restored.n(), rb.n());
        assert_eq!(restored.n_slots(), rb.n_slots());
        // both must now play out identically for a full wrap-around
        for _ in 0..2 * rb.n_slots() {
            assert_eq!(restored.current(), rb.current());
            restored.advance();
            rb.advance();
        }
        assert_eq!(tr2.current(MemKind::Device), tr.current(MemKind::Device));
    }

    #[test]
    fn memory_tracked_and_released() {
        let mut tr = Tracker::new();
        let mut rb = RingBuffers::new(100, 15, &mut tr);
        assert_eq!(tr.current(MemKind::Device), 100 * 16 * 2 * 4);
        rb.release(&mut tr);
        assert_eq!(tr.current(MemKind::Device), 0);
    }
}
