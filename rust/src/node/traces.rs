//! Per-neuron plasticity trace buffers (DESIGN.md §12).
//!
//! A trace is an exponentially decaying scalar bumped by +1 at every spike
//! of its neuron: `y(t) = Σ_{t_sp ≤ t} exp(−(t − t_sp)·dt/τ)`. Instead of
//! decaying every trace every step (an O(N) pass the GPU would fuse into
//! the dynamics kernel, but which would dominate this host build), the
//! buffers store the value *at the step of the last bump* and apply the
//! exact decay `f^Δt` (f = exp(−dt/τ)) lazily at read time. This is exact
//! — not an approximation — as long as bumps arrive in non-decreasing step
//! order, which the engine's phase order guarantees (post spikes are
//! bumped in `post_update`, once per step, in step order).
//!
//! The buffers live alongside the spike ring buffers in [`crate::node`]:
//! both are per-neuron, per-step accumulation state of the propagation
//! loop, sized at `prepare()`.

use crate::memory::{MemKind, Tracker};

/// Sentinel for "never bumped" (`last` field); the trace reads as 0.
pub const NEVER: i64 = i64::MIN;

/// Exact lazy decay: the value stored at step `last`, read at step `now`.
#[inline]
pub fn decayed(value: f32, last: i64, now: i64, decay_per_step: f64) -> f32 {
    if last == NEVER {
        return 0.0;
    }
    debug_assert!(now >= last, "trace read before its last bump");
    // saturate the exponent: gaps beyond i32::MAX steps have decayed to
    // exactly 0 anyway (decay < 1), and an `as i32` wrap would turn the
    // huge positive gap into a negative exponent (an inf trace)
    let gap = (now - last).min(i32::MAX as i64) as i32;
    (value as f64 * decay_per_step.powi(gap)) as f32
}

/// One exponential trace per state slot (neuron), with lazy exact decay.
#[derive(Debug)]
pub struct TraceBuffers {
    value: Vec<f32>,
    /// step of the last bump per slot ([`NEVER`] = no bump yet)
    last: Vec<i64>,
    tracked: u64,
}

impl TraceBuffers {
    pub fn new(n: usize, tr: &mut Tracker) -> Self {
        let bytes = (n * (4 + 8)) as u64;
        tr.alloc(MemKind::Device, bytes);
        Self {
            value: vec![0.0; n],
            last: vec![NEVER; n],
            tracked: bytes,
        }
    }

    pub fn n(&self) -> usize {
        self.value.len()
    }

    /// Trace value of slot `i` at step `now`.
    #[inline]
    pub fn eval(&self, i: usize, now: i64, decay_per_step: f64) -> f32 {
        decayed(self.value[i], self.last[i], now, decay_per_step)
    }

    /// Register a spike of slot `i` at step `now`: decay to `now`, add 1.
    #[inline]
    pub fn bump(&mut self, i: usize, now: i64, decay_per_step: f64) {
        self.value[i] = decayed(self.value[i], self.last[i], now, decay_per_step) + 1.0;
        self.last[i] = now;
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(MemKind::Device, self.tracked);
        self.tracked = 0;
    }

    /// Serialize values and last-bump steps (mid-run checkpoint state).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.slice_f32(&self.value);
        enc.seq_len(self.last.len());
        for &l in &self.last {
            enc.u64(l as u64);
        }
    }

    /// Rebuild from [`TraceBuffers::snapshot_encode`] output.
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let value = dec.vec_f32()?;
        let n = dec.seq_len(8)?;
        if n != value.len() {
            anyhow::bail!(
                "trace buffers inconsistent: {} values but {n} last-bump steps",
                value.len()
            );
        }
        let mut last = Vec::with_capacity(n);
        for _ in 0..n {
            last.push(dec.u64()? as i64);
        }
        let bytes = (n * (4 + 8)) as u64;
        tr.alloc(MemKind::Device, bytes);
        Ok(Self {
            value,
            last,
            tracked: bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DECAY: f64 = 0.9; // per-step factor

    #[test]
    fn unbumped_trace_reads_zero() {
        let mut tr = Tracker::new();
        let t = TraceBuffers::new(3, &mut tr);
        assert_eq!(t.eval(0, 1_000, DECAY), 0.0);
    }

    #[test]
    fn lazy_decay_is_exact() {
        let mut tr = Tracker::new();
        let mut t = TraceBuffers::new(1, &mut tr);
        t.bump(0, 10, DECAY);
        // value 1 at step 10, read at step 15: 0.9^5
        let expect = (0.9f64).powi(5) as f32;
        assert_eq!(t.eval(0, 15, DECAY), expect);
        // second bump at 15: decayed + 1
        t.bump(0, 15, DECAY);
        assert_eq!(t.eval(0, 15, DECAY), expect + 1.0);
    }

    #[test]
    fn lazy_equals_stepwise_decay() {
        let mut tr = Tracker::new();
        let mut t = TraceBuffers::new(1, &mut tr);
        let mut reference = 0.0f64;
        let bumps = [3i64, 7, 8, 20];
        let mut b = 0;
        for step in 0..40i64 {
            if b < bumps.len() && bumps[b] == step {
                t.bump(0, step, DECAY);
                reference += 1.0;
                b += 1;
            }
            let lazy = t.eval(0, step, DECAY) as f64;
            assert!(
                (lazy - reference).abs() < 1e-5,
                "step {step}: lazy {lazy} vs stepwise {reference}"
            );
            reference *= DECAY;
        }
    }

    #[test]
    fn snapshot_roundtrip_continues_identically() {
        let mut tr = Tracker::new();
        let mut t = TraceBuffers::new(4, &mut tr);
        t.bump(1, 5, DECAY);
        t.bump(3, 9, DECAY);
        t.bump(1, 9, DECAY);
        let mut enc = crate::snapshot::Encoder::new();
        t.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let r = TraceBuffers::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(r.n(), t.n());
        for i in 0..4 {
            assert_eq!(r.eval(i, 30, DECAY).to_bits(), t.eval(i, 30, DECAY).to_bits());
        }
        assert_eq!(tr2.current(MemKind::Device), tr.current(MemKind::Device));
    }

    #[test]
    fn memory_tracked_and_released() {
        let mut tr = Tracker::new();
        let mut t = TraceBuffers::new(100, &mut tr);
        assert_eq!(tr.current(MemKind::Device), 100 * 12);
        t.release(&mut tr);
        assert_eq!(tr.current(MemKind::Device), 0);
    }
}
