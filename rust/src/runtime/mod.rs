//! Neuron-dynamics runtime: the device-kernel execution layer.
//!
//! Two interchangeable backends advance the per-rank neuron state one time
//! step at a time:
//!
//! - [`pjrt::PjrtBackend`] — loads the AOT-compiled HLO text artifacts
//!   produced by `python/compile/aot.py` (the L2 JAX model with the L1
//!   Pallas kernel inlined) and executes them through the PJRT CPU client.
//!   Python is never on this path; the artifacts are loaded once.
//! - [`native::NativeBackend`] — the pure-Rust reference implementation of
//!   the same exact-integration update; used as the correctness baseline
//!   and for large sweeps where per-call PJRT overhead would dominate.
//!
//! Both operate on [`StateChunk`]s: SoA state blocks padded to the kernel
//! block size, one chunk per neuron population (populations differ only in
//! their packed parameter vector).

pub mod native;
pub mod pjrt;

use crate::memory::{MemKind, Tracker};
use crate::node::neuron::NUM_PARAMS;

/// Minimum kernel block size; chunks are padded to a multiple of this (it
/// must match the smallest entry of `aot.BLOCK_SIZES`).
pub const MIN_BLOCK: usize = 256;

/// SoA state for one neuron population, padded to a block multiple.
///
/// Pad lanes carry `v = 0`, zero input and `i_e = 0` influence only if the
/// population's `i_e != 0`; the engine therefore never reads pad lanes —
/// spikes are collected from `spike[0..n]` only.
pub struct StateChunk {
    /// number of real neurons
    pub n: usize,
    /// padded length (multiple of MIN_BLOCK)
    pub pad_n: usize,
    /// packed parameters (see node::neuron::PARAM_ORDER)
    pub params: [f32; NUM_PARAMS],
    pub v: Vec<f32>,
    pub i_ex: Vec<f32>,
    pub i_in: Vec<f32>,
    pub r: Vec<f32>,
    /// per-step synaptic input (filled by the engine from the ring buffers)
    pub w_ex: Vec<f32>,
    pub w_in: Vec<f32>,
    /// 0/1 spike flags written by the backend
    pub spike: Vec<f32>,
    tracked: u64,
}

impl StateChunk {
    pub fn new(n: usize, params: [f32; NUM_PARAMS], tr: &mut Tracker) -> Self {
        let pad_n = n.div_ceil(MIN_BLOCK).max(1) * MIN_BLOCK;
        let bytes = (pad_n * 7 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        Self {
            n,
            pad_n,
            params,
            v: vec![0.0; pad_n],
            i_ex: vec![0.0; pad_n],
            i_in: vec![0.0; pad_n],
            r: vec![0.0; pad_n],
            w_ex: vec![0.0; pad_n],
            w_in: vec![0.0; pad_n],
            spike: vec![0.0; pad_n],
            tracked: bytes,
        }
    }

    /// Indexes (offsets within the chunk) of neurons that spiked this step.
    pub fn spiking(&self) -> impl Iterator<Item = u32> + '_ {
        self.spike[..self.n]
            .iter()
            .enumerate()
            .filter(|(_, &s)| s != 0.0)
            .map(|(i, _)| i as u32)
    }

    /// Zero the input accumulators (after a step consumed them).
    pub fn clear_inputs(&mut self) {
        self.w_ex.fill(0.0);
        self.w_in.fill(0.0);
    }

    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(MemKind::Device, self.tracked);
        self.tracked = 0;
    }

    /// Serialize the full dynamic state (all seven SoA arrays at padded
    /// length) plus the packed parameter vector.
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.u64(self.n as u64);
        enc.u64(self.pad_n as u64);
        for p in self.params {
            enc.f32(p);
        }
        enc.slice_f32(&self.v);
        enc.slice_f32(&self.i_ex);
        enc.slice_f32(&self.i_in);
        enc.slice_f32(&self.r);
        enc.slice_f32(&self.w_ex);
        enc.slice_f32(&self.w_in);
        enc.slice_f32(&self.spike);
    }

    /// Rebuild from [`StateChunk::snapshot_encode`] output.
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let n = dec.u64()? as usize;
        let pad_n = dec.u64()? as usize;
        let mut params = [0.0f32; NUM_PARAMS];
        for p in params.iter_mut() {
            *p = dec.f32()?;
        }
        let v = dec.vec_f32()?;
        let i_ex = dec.vec_f32()?;
        let i_in = dec.vec_f32()?;
        let r = dec.vec_f32()?;
        let w_ex = dec.vec_f32()?;
        let w_in = dec.vec_f32()?;
        let spike = dec.vec_f32()?;
        if n > pad_n
            || [&v, &i_ex, &i_in, &r, &w_ex, &w_in, &spike]
                .iter()
                .any(|a| a.len() != pad_n)
        {
            anyhow::bail!("state-chunk snapshot inconsistent: n={n} pad_n={pad_n}");
        }
        let bytes = (pad_n * 7 * 4) as u64;
        tr.alloc(MemKind::Device, bytes);
        Ok(Self {
            n,
            pad_n,
            params,
            v,
            i_ex,
            i_in,
            r,
            w_ex,
            w_in,
            spike,
            tracked: bytes,
        })
    }
}

/// A neuron-dynamics backend.
/// Note: not `Send` — the PJRT client is thread-local; each rank thread
/// constructs its own backend from a [`BackendKind`] (which is Send).
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Advance `chunk` one step in place: consumes `w_ex`/`w_in`, updates
    /// `v`/`i_ex`/`i_in`/`r`, writes `spike`.
    fn step(&mut self, chunk: &mut StateChunk) -> anyhow::Result<()>;
}

/// Which backend to instantiate (engine configuration).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    /// PJRT with artifacts from the given directory
    Pjrt { artifacts: std::path::PathBuf },
}

impl BackendKind {
    pub fn create(&self) -> anyhow::Result<Box<dyn Backend>> {
        match self {
            BackendKind::Native => Ok(Box::new(native::NativeBackend::new())),
            BackendKind::Pjrt { artifacts } => {
                Ok(Box::new(pjrt::PjrtBackend::load(artifacts)?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_padding_and_memory() {
        let mut tr = Tracker::new();
        let mut c = StateChunk::new(300, [0.0; NUM_PARAMS], &mut tr);
        assert_eq!(c.pad_n, 512);
        assert_eq!(tr.current(MemKind::Device), 512 * 7 * 4);
        c.release(&mut tr);
        assert_eq!(tr.current(MemKind::Device), 0);
    }

    #[test]
    fn spiking_ignores_pad_lanes() {
        let mut tr = Tracker::new();
        let mut c = StateChunk::new(2, [0.0; NUM_PARAMS], &mut tr);
        c.spike[0] = 1.0;
        c.spike[1] = 0.0;
        c.spike[2] = 1.0; // pad lane: must be ignored
        assert_eq!(c.spiking().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn chunk_snapshot_roundtrip_bitwise() {
        let mut tr = Tracker::new();
        let mut c = StateChunk::new(3, [0.25; NUM_PARAMS], &mut tr);
        c.v[0] = 1.5;
        c.i_ex[1] = -2.0;
        c.r[2] = 7.0;
        c.spike[0] = 1.0;
        let mut enc = crate::snapshot::Encoder::new();
        c.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = StateChunk::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.n, c.n);
        assert_eq!(d.pad_n, c.pad_n);
        assert_eq!(d.params, c.params);
        assert_eq!(d.v, c.v);
        assert_eq!(d.i_ex, c.i_ex);
        assert_eq!(d.r, c.r);
        assert_eq!(d.spike, c.spike);
        assert_eq!(tr2.current(MemKind::Device), tr.current(MemKind::Device));
    }

    #[test]
    fn zero_sized_chunk_still_padded() {
        let mut tr = Tracker::new();
        let c = StateChunk::new(0, [0.0; NUM_PARAMS], &mut tr);
        assert_eq!(c.pad_n, MIN_BLOCK);
        assert_eq!(c.spiking().count(), 0);
    }
}
