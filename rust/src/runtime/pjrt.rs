//! PJRT backend: load the AOT artifacts (HLO text) and execute them on the
//! PJRT CPU client from the Rust hot path.
//!
//! The interchange format is HLO *text* — the image's xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos (64-bit instruction ids), while the
//! text parser reassigns ids (see /opt/xla-example/README.md). One
//! executable is compiled per AOT block size; a chunk is processed in
//! segments using the largest block that fits, greedily.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{Backend, StateChunk};
use crate::node::neuron::PARAM_ORDER;
use crate::util::json::Json;

struct BlockExe {
    block: usize,
    exe: xla::PjRtLoadedExecutable,
    /// persistent input literals (6 state/input arrays + params), refilled
    /// in place via `copy_raw_from` — §Perf iteration 3: avoids seven host
    /// literal allocations per kernel invocation
    args: Vec<xla::Literal>,
}

/// PJRT CPU backend over the artifacts directory.
pub struct PjrtBackend {
    _client: xla::PjRtClient,
    /// executables sorted by block size, descending
    exes: Vec<BlockExe>,
    /// per-step executions (diagnostics / perf accounting)
    pub calls: u64,
}

impl PjrtBackend {
    /// Load `manifest.json` + all HLO artifacts from `dir` and compile them.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Json::parse_file(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest: {e}"))?;
        // validate the parameter packing contract with the Python side
        let order = manifest
            .get("param_order")
            .and_then(|o| o.as_arr())
            .context("manifest: param_order missing")?;
        let names: Vec<&str> = order.iter().filter_map(|x| x.as_str()).collect();
        if names != PARAM_ORDER {
            bail!(
                "parameter order mismatch: artifacts {:?} vs runtime {:?} — \
                 regenerate artifacts (make artifacts)",
                names,
                PARAM_ORDER
            );
        }
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut exes = Vec::new();
        for b in manifest
            .get("blocks")
            .and_then(|b| b.as_arr())
            .context("manifest: blocks missing")?
        {
            let block = b.get("block").and_then(|x| x.as_usize()).context("block")?;
            let file = b.get("file").and_then(|x| x.as_str()).context("file")?;
            let proto = xla::HloModuleProto::from_text_file(dir.join(file))
                .with_context(|| format!("parse {file}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {file}"))?;
            let zeros = vec![0f32; block];
            let mut args: Vec<xla::Literal> =
                (0..6).map(|_| xla::Literal::vec1(&zeros)).collect();
            args.push(xla::Literal::vec1(
                &[0f32; crate::node::neuron::NUM_PARAMS],
            ));
            exes.push(BlockExe { block, exe, args });
        }
        if exes.is_empty() {
            bail!("no artifacts in {}", dir.display());
        }
        exes.sort_by(|a, b| b.block.cmp(&a.block));
        Ok(Self {
            _client: client,
            exes,
            calls: 0,
        })
    }

    /// Smallest available block size (chunks must pad to a multiple of it).
    pub fn min_block(&self) -> usize {
        self.exes.last().map(|e| e.block).unwrap_or(0)
    }

    fn exec_segment(&mut self, c: &mut StateChunk, at: usize, len: usize) -> Result<()> {
        let exe = self
            .exes
            .iter_mut()
            .find(|e| e.block == len)
            .ok_or_else(|| anyhow!("no executable for block {len}"))?;
        // refill the persistent input literals in place
        let inputs: [&[f32]; 6] = [&c.v, &c.i_ex, &c.i_in, &c.r, &c.w_ex, &c.w_in];
        for (lit, src) in exe.args[..6].iter_mut().zip(inputs) {
            lit.copy_raw_from::<f32>(&src[at..at + len])?;
        }
        exe.args[6].copy_raw_from::<f32>(&c.params[..])?;
        let result = exe.exe.execute::<xla::Literal>(&exe.args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 5 {
            bail!("expected 5 outputs, got {}", outs.len());
        }
        let write = |dst: &mut [f32], lit: &xla::Literal| -> Result<()> {
            lit.copy_raw_to::<f32>(&mut dst[at..at + len])?;
            Ok(())
        };
        write(&mut c.v, &outs[0])?;
        write(&mut c.i_ex, &outs[1])?;
        write(&mut c.i_in, &outs[2])?;
        write(&mut c.r, &outs[3])?;
        write(&mut c.spike, &outs[4])?;
        self.calls += 1;
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn step(&mut self, chunk: &mut StateChunk) -> Result<()> {
        let min = self.min_block();
        if chunk.pad_n % min != 0 {
            bail!(
                "chunk pad_n={} is not a multiple of the smallest block {min}",
                chunk.pad_n
            );
        }
        let mut at = 0;
        while at < chunk.pad_n {
            let remaining = chunk.pad_n - at;
            // largest block that divides the remainder
            let len = self
                .exes
                .iter()
                .map(|e| e.block)
                .find(|&b| b <= remaining)
                .ok_or_else(|| anyhow!("no block fits remaining {remaining}"))?;
            self.exec_segment(chunk, at, len)?;
            at += len;
        }
        Ok(())
    }
}
