//! Pure-Rust reference backend: the same exact-integration iaf_psc_exp
//! update as the Pallas kernel (`python/compile/kernels/lif.py`), in f32.
//!
//! Semantics are kept line-for-line parallel with `_lif_kernel` so that the
//! PJRT and native paths agree to f32 rounding (checked by unit tests here
//! and by `rust/tests/it_runtime.rs` against the Python oracle's golden
//! vectors).

use super::{Backend, StateChunk};

#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn step(&mut self, c: &mut StateChunk) -> anyhow::Result<()> {
        let [p22, p21ex, p21in, p20, p11ex, p11in, theta, v_reset, t_ref, i_e] = c.params;
        for i in 0..c.pad_n {
            let v = c.v[i];
            let i_ex = c.i_ex[i];
            let i_in = c.i_in[i];
            let r = c.r[i];
            let not_ref = r <= 0.0;
            // subthreshold propagation with the previous step's currents
            let v_prop = p22 * v + p21ex * i_ex + p21in * i_in + p20 * i_e;
            let mut v_new = if not_ref { v_prop } else { v };
            c.i_ex[i] = p11ex * i_ex + c.w_ex[i];
            c.i_in[i] = p11in * i_in + c.w_in[i];
            let spike = not_ref && v_new >= theta;
            if spike {
                v_new = v_reset;
            }
            c.r[i] = if spike { t_ref } else { (r - 1.0).max(0.0) };
            c.v[i] = v_new;
            c.spike[i] = if spike { 1.0 } else { 0.0 };
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Tracker;
    use crate::node::neuron::LifParams;

    fn chunk(n: usize) -> StateChunk {
        let mut tr = Tracker::new();
        StateChunk::new(n, LifParams::default().packed(0.1), &mut tr)
    }

    #[test]
    fn decays_to_rest_without_input() {
        let mut c = chunk(4);
        let mut b = NativeBackend::new();
        c.v[..4].fill(5.0);
        for _ in 0..50 {
            b.step(&mut c).unwrap();
            assert_eq!(c.spiking().count(), 0);
        }
        let p22 = c.params[0] as f64;
        let expect = 5.0 * p22.powi(50);
        for &v in &c.v[..4] {
            assert!((v as f64 - expect).abs() < 1e-3, "v={v}, expect={expect}");
        }
    }

    #[test]
    fn spike_reset_refractory_cycle() {
        let mut c = chunk(1);
        let mut b = NativeBackend::new();
        let theta = c.params[6];
        let t_ref = c.params[8] as usize;
        c.v[0] = theta + 1.0;
        b.step(&mut c).unwrap();
        assert_eq!(c.spike[0], 1.0);
        assert_eq!(c.v[0], c.params[7]); // v_reset
        assert_eq!(c.r[0], c.params[8]);
        // refractory: huge drive does not move V or fire
        for _ in 0..t_ref {
            c.w_ex[0] = 1e5;
            b.step(&mut c).unwrap();
            assert_eq!(c.spike[0], 0.0);
            assert_eq!(c.v[0], c.params[7]);
        }
        // after refractoriness the accumulated current fires it again
        b.step(&mut c).unwrap();
        assert_eq!(c.spike[0], 1.0);
    }

    #[test]
    fn synaptic_input_jumps_then_decays() {
        let mut c = chunk(1);
        let mut b = NativeBackend::new();
        c.w_ex[0] = 40.0;
        c.w_in[0] = -10.0;
        b.step(&mut c).unwrap();
        assert_eq!(c.i_ex[0], 40.0);
        assert_eq!(c.i_in[0], -10.0);
        c.w_ex[0] = 0.0;
        c.w_in[0] = 0.0;
        b.step(&mut c).unwrap();
        let p11 = c.params[4];
        assert!((c.i_ex[0] - 40.0 * p11).abs() < 1e-4);
    }

    #[test]
    fn excitatory_drive_eventually_fires() {
        let mut c = chunk(8);
        let mut b = NativeBackend::new();
        let mut fired = false;
        for _ in 0..2000 {
            // steady-state drive: i_ex -> w/(1-p11) ~ 550 pA -> V >> theta
            c.w_ex[..8].fill(100.0);
            b.step(&mut c).unwrap();
            if c.spiking().count() > 0 {
                fired = true;
                break;
            }
        }
        assert!(fired, "constant excitatory drive must elicit spikes");
    }
}
