//! Pure-Rust reference backend: the same exact-integration iaf_psc_exp
//! update as the Pallas kernel (`python/compile/kernels/lif.py`), in f32.
//!
//! Semantics are kept line-for-line parallel with `_lif_kernel` so that the
//! PJRT and native paths agree to f32 rounding (checked by unit tests here
//! and by `rust/tests/it_runtime.rs` against the Python oracle's golden
//! vectors).
//!
//! The update loop is written branchless over fixed-width lane blocks
//! (select idioms instead of `if`, `LANES`-sized array chunks) so the
//! autovectorizer can emit SIMD for the whole chunk; `StateChunk` pads to
//! `MIN_BLOCK` (a multiple of `LANES`), so no scalar tail exists. Every
//! arithmetic operation and its order match the scalar reference exactly —
//! `step_equals_scalar_reference_bitwise` below pins that down per element.

use super::{Backend, StateChunk};

/// Fixed inner block width. 8 f32 lanes = one AVX2 register; the compiler
/// is free to fuse consecutive blocks into wider or narrower vectors.
const LANES: usize = 8;

#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn step(&mut self, c: &mut StateChunk) -> anyhow::Result<()> {
        let [p22, p21ex, p21in, p20, p11ex, p11in, theta, v_reset, t_ref, i_e] = c.params;
        // same product as the inline `p20 * i_e` per lane — hoisting a
        // constant subexpression does not change f32 results
        let drive = p20 * i_e;
        debug_assert_eq!(c.pad_n % LANES, 0, "MIN_BLOCK padding is a LANES multiple");
        for b in (0..c.pad_n).step_by(LANES) {
            let v: &mut [f32; LANES] = (&mut c.v[b..b + LANES]).try_into().unwrap();
            let i_ex: &mut [f32; LANES] = (&mut c.i_ex[b..b + LANES]).try_into().unwrap();
            let i_in: &mut [f32; LANES] = (&mut c.i_in[b..b + LANES]).try_into().unwrap();
            let r: &mut [f32; LANES] = (&mut c.r[b..b + LANES]).try_into().unwrap();
            let w_ex: &[f32; LANES] = (&c.w_ex[b..b + LANES]).try_into().unwrap();
            let w_in: &[f32; LANES] = (&c.w_in[b..b + LANES]).try_into().unwrap();
            let spike: &mut [f32; LANES] = (&mut c.spike[b..b + LANES]).try_into().unwrap();
            for l in 0..LANES {
                let (vl, iex, iin, rl) = (v[l], i_ex[l], i_in[l], r[l]);
                let not_ref = rl <= 0.0;
                // subthreshold propagation with the previous step's currents
                let v_prop = p22 * vl + p21ex * iex + p21in * iin + drive;
                let v_new = if not_ref { v_prop } else { vl };
                let spiked = not_ref && v_new >= theta;
                i_ex[l] = p11ex * iex + w_ex[l];
                i_in[l] = p11in * iin + w_in[l];
                v[l] = if spiked { v_reset } else { v_new };
                r[l] = if spiked { t_ref } else { (rl - 1.0).max(0.0) };
                spike[l] = if spiked { 1.0 } else { 0.0 };
            }
        }
        Ok(())
    }
}

/// The original scalar loop, kept verbatim as the semantic oracle for
/// `step_equals_scalar_reference_bitwise`.
#[cfg(test)]
fn step_scalar_reference(c: &mut StateChunk) {
    let [p22, p21ex, p21in, p20, p11ex, p11in, theta, v_reset, t_ref, i_e] = c.params;
    for i in 0..c.pad_n {
        let v = c.v[i];
        let i_ex = c.i_ex[i];
        let i_in = c.i_in[i];
        let r = c.r[i];
        let not_ref = r <= 0.0;
        let v_prop = p22 * v + p21ex * i_ex + p21in * i_in + p20 * i_e;
        let mut v_new = if not_ref { v_prop } else { v };
        c.i_ex[i] = p11ex * i_ex + c.w_ex[i];
        c.i_in[i] = p11in * i_in + c.w_in[i];
        let spike = not_ref && v_new >= theta;
        if spike {
            v_new = v_reset;
        }
        c.r[i] = if spike { t_ref } else { (r - 1.0).max(0.0) };
        c.v[i] = v_new;
        c.spike[i] = if spike { 1.0 } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::Tracker;
    use crate::node::neuron::LifParams;
    use crate::util::rng::Rng;

    fn chunk(n: usize) -> StateChunk {
        let mut tr = Tracker::new();
        StateChunk::new(n, LifParams::default().packed(0.1), &mut tr)
    }

    #[test]
    fn decays_to_rest_without_input() {
        let mut c = chunk(4);
        let mut b = NativeBackend::new();
        c.v[..4].fill(5.0);
        for _ in 0..50 {
            b.step(&mut c).unwrap();
            assert_eq!(c.spiking().count(), 0);
        }
        let p22 = c.params[0] as f64;
        let expect = 5.0 * p22.powi(50);
        for &v in &c.v[..4] {
            assert!((v as f64 - expect).abs() < 1e-3, "v={v}, expect={expect}");
        }
    }

    #[test]
    fn spike_reset_refractory_cycle() {
        let mut c = chunk(1);
        let mut b = NativeBackend::new();
        let theta = c.params[6];
        let t_ref = c.params[8] as usize;
        c.v[0] = theta + 1.0;
        b.step(&mut c).unwrap();
        assert_eq!(c.spike[0], 1.0);
        assert_eq!(c.v[0], c.params[7]); // v_reset
        assert_eq!(c.r[0], c.params[8]);
        // refractory: huge drive does not move V or fire
        for _ in 0..t_ref {
            c.w_ex[0] = 1e5;
            b.step(&mut c).unwrap();
            assert_eq!(c.spike[0], 0.0);
            assert_eq!(c.v[0], c.params[7]);
        }
        // after refractoriness the accumulated current fires it again
        b.step(&mut c).unwrap();
        assert_eq!(c.spike[0], 1.0);
    }

    #[test]
    fn synaptic_input_jumps_then_decays() {
        let mut c = chunk(1);
        let mut b = NativeBackend::new();
        c.w_ex[0] = 40.0;
        c.w_in[0] = -10.0;
        b.step(&mut c).unwrap();
        assert_eq!(c.i_ex[0], 40.0);
        assert_eq!(c.i_in[0], -10.0);
        c.w_ex[0] = 0.0;
        c.w_in[0] = 0.0;
        b.step(&mut c).unwrap();
        let p11 = c.params[4];
        assert!((c.i_ex[0] - 40.0 * p11).abs() < 1e-4);
    }

    #[test]
    fn excitatory_drive_eventually_fires() {
        let mut c = chunk(8);
        let mut b = NativeBackend::new();
        let mut fired = false;
        for _ in 0..2000 {
            // steady-state drive: i_ex -> w/(1-p11) ~ 550 pA -> V >> theta
            c.w_ex[..8].fill(100.0);
            b.step(&mut c).unwrap();
            if c.spiking().count() > 0 {
                fired = true;
                break;
            }
        }
        assert!(fired, "constant excitatory drive must elicit spikes");
    }

    #[test]
    fn step_equals_scalar_reference_bitwise() {
        // randomized state straddling threshold, refractoriness, and reset,
        // evolved for many steps: every array must match the scalar oracle
        // bit for bit at every step
        let mut a = chunk(700); // pad_n = 768, exercises multiple blocks
        let mut b = chunk(700);
        let mut rng = Rng::new(0x51_3D_1F);
        let theta = a.params[6] as f64;
        for i in 0..a.pad_n {
            a.v[i] = rng.uniform_range(theta - 2.0, theta + 2.0) as f32;
            a.i_ex[i] = rng.uniform_range(0.0, 300.0) as f32;
            a.i_in[i] = rng.uniform_range(-120.0, 0.0) as f32;
            a.r[i] = rng.below(4) as f32; // mix of refractory and active
        }
        let mut backend = NativeBackend::new();
        for step in 0..25 {
            for i in 0..a.pad_n {
                let wx = rng.uniform_range(0.0, 80.0) as f32;
                let wi = rng.uniform_range(-30.0, 0.0) as f32;
                a.w_ex[i] = wx;
                a.w_in[i] = wi;
                b.w_ex[i] = wx;
                b.w_in[i] = wi;
            }
            if step == 0 {
                b.v.copy_from_slice(&a.v);
                b.i_ex.copy_from_slice(&a.i_ex);
                b.i_in.copy_from_slice(&a.i_in);
                b.r.copy_from_slice(&a.r);
            }
            backend.step(&mut a).unwrap();
            step_scalar_reference(&mut b);
            for i in 0..a.pad_n {
                assert_eq!(a.v[i].to_bits(), b.v[i].to_bits(), "v[{i}] step {step}");
                assert_eq!(a.i_ex[i].to_bits(), b.i_ex[i].to_bits(), "i_ex[{i}] step {step}");
                assert_eq!(a.i_in[i].to_bits(), b.i_in[i].to_bits(), "i_in[{i}] step {step}");
                assert_eq!(a.r[i].to_bits(), b.r[i].to_bits(), "r[{i}] step {step}");
                assert_eq!(a.spike[i].to_bits(), b.spike[i].to_bits(), "spike[{i}] step {step}");
            }
        }
    }
}
