//! Device-resident connection store.
//!
//! Connections are stored as a structure-of-arrays in (simulated) GPU
//! memory, grown in fixed-size blocks, and sorted with the source-neuron
//! index as the first key at preparation time ([30], §0.3.6): with that
//! order, all connections outgoing from a node are contiguous, so spike
//! delivery only needs the node's *first connection index* plus its
//! *out-degree* (level 3) — or just the first index, with the out-degree
//! recomputed on the fly from the next node's first index (level 2).

use crate::memory::tracker::{Tracker, TrackedVec};
use crate::memory::MemKind;
use crate::plasticity::{StdpRule, NO_RULE};

/// Borrowed SoA view of one connection index range (see
/// [`Connections::view`]).
pub struct ConnView<'a> {
    pub target: &'a [u32],
    pub port: &'a [u8],
    pub delay: &'a [u16],
    pub weight: &'a [f32],
}

/// SoA connection store (one per rank).
pub struct Connections {
    pub source: TrackedVec<u32>,
    pub target: TrackedVec<u32>,
    pub weight: TrackedVec<f32>,
    pub delay: TrackedVec<u16>,
    pub port: TrackedVec<u8>,
    /// CSR offsets per node after [`Connections::sort_by_source`]:
    /// `first_out[s] .. first_out[s+1]` index this node's outgoing
    /// connections. Length = n_nodes + 1.
    first_out: Vec<u32>,
    sorted: bool,
    /// per-connection STDP rule id ([`NO_RULE`] = static), materialized
    /// lazily by the first [`Connections::attach_rule`] so purely static
    /// networks pay no per-connection overhead
    rule: Option<TrackedVec<u16>>,
    /// registered plasticity rules, referenced by `rule` ids
    rules: Vec<StdpRule>,
}

impl Connections {
    pub fn new() -> Self {
        Self {
            source: TrackedVec::new(MemKind::Device),
            target: TrackedVec::new(MemKind::Device),
            weight: TrackedVec::new(MemKind::Device),
            delay: TrackedVec::new(MemKind::Device),
            port: TrackedVec::new(MemKind::Device),
            first_out: Vec::new(),
            sorted: false,
            rule: None,
            rules: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.source.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_sorted(&self) -> bool {
        self.sorted
    }

    /// Append one connection (construction phase; invalidates sorting).
    #[inline]
    pub fn push(
        &mut self,
        source: u32,
        target: u32,
        weight: f32,
        delay: u16,
        port: u8,
        tr: &mut Tracker,
    ) {
        debug_assert!(delay >= 1, "delays are >= 1 step");
        self.source.push(source, tr);
        self.target.push(target, tr);
        self.weight.push(weight, tr);
        self.delay.push(delay, tr);
        self.port.push(port, tr);
        if let Some(r) = self.rule.as_mut() {
            r.push(NO_RULE, tr);
        }
        self.sorted = false;
    }

    /// Register a plasticity rule; returns its id (deduplicated by value).
    /// The rule parameters are validated here so a bad spec fails at the
    /// connect call, not mid-propagation.
    pub fn register_rule(&mut self, rule: StdpRule) -> u16 {
        rule.validate().expect("invalid STDP rule");
        if let Some(i) = self.rules.iter().position(|r| *r == rule) {
            return i as u16;
        }
        assert!(
            self.rules.len() < NO_RULE as usize,
            "too many distinct STDP rules"
        );
        self.rules.push(rule);
        (self.rules.len() - 1) as u16
    }

    /// Attach rule `rule_id` to the connections appended since index
    /// `start` (i.e. `[start, len)` — one connect call's worth). The
    /// per-connection id array is materialized on first use and kept
    /// aligned by [`Connections::push`] afterwards.
    pub fn attach_rule(&mut self, start: usize, rule_id: u16, tr: &mut Tracker) {
        debug_assert!(rule_id != NO_RULE && (rule_id as usize) < self.rules.len());
        let n = self.len();
        debug_assert!(start <= n);
        let arr = self.rule.get_or_insert_with(|| TrackedVec::new(MemKind::Device));
        while arr.len() < start {
            arr.push(NO_RULE, tr);
        }
        if arr.len() < n {
            while arr.len() < n {
                arr.push(rule_id, tr);
            }
        } else {
            for x in &mut arr.as_mut_slice()[start..n] {
                *x = rule_id;
            }
        }
    }

    /// Registered plasticity rules (empty = fully static network).
    pub fn rules(&self) -> &[StdpRule] {
        &self.rules
    }

    /// Per-connection rule ids, if any rule was ever attached.
    pub fn rule_slice(&self) -> Option<&[u16]> {
        self.rule.as_ref().map(|r| r.as_slice())
    }

    /// Whether any connection of this store is plastic.
    pub fn has_plasticity(&self) -> bool {
        !self.rules.is_empty() && self.rule.is_some()
    }

    /// Mutable view of the weights array (the plasticity engine's write
    /// path; everything else in the store stays read-only after
    /// `prepare()`).
    pub fn weights_mut(&mut self) -> &mut [f32] {
        self.weight.as_mut_slice()
    }

    /// Split borrow for the plastic update hot loops: mutable weights plus
    /// the (read-only) targets and ports they are keyed by.
    pub fn weights_with_targets_mut(&mut self) -> (&mut [f32], &[u32], &[u8]) {
        (
            self.weight.as_mut_slice(),
            self.target.as_slice(),
            self.port.as_slice(),
        )
    }

    /// Rewrite the source ids of connections `[start, len)` through `map`
    /// (`RemoteConnect` step: temporary source positions -> image-neuron
    /// local indexes, Eq. 5/6 final step). `u32::MAX` entries in `map` mark
    /// positions that must not occur.
    pub fn remap_sources(&mut self, start: usize, map: &[u32]) {
        for s in &mut self.source.as_mut_slice()[start..] {
            let img = map[*s as usize];
            debug_assert!(img != u32::MAX, "unmapped source position {s}");
            *s = img;
        }
        self.sorted = false;
    }

    /// Sort by source index (stable; preserves creation order within a
    /// node) and build the CSR offsets for `n_nodes` nodes. The scratch
    /// (u64 keys + u32 permutation) is accounted as a transient device
    /// allocation — it is the dominant term of the Fig. 5 memory peak.
    pub fn sort_by_source(&mut self, n_nodes: usize, tr: &mut Tracker) {
        let n = self.len();
        // §Perf iteration 2: source indexes are bounded by the node count,
        // so a single-pass stable *counting scatter* replaces the generic
        // radix argsort (one count pass + one scatter pass per array
        // instead of up to four radix passes over a permutation). The
        // scatter permutation and the per-node cursor are accounted as the
        // transient device scratch — the dominant term of the Fig. 5
        // memory peak.
        let scratch = (n * 4 + (n_nodes + 1) * 4) as u64;
        tr.alloc(MemKind::Device, scratch);
        tr.transient_events += 1;
        // counting pass -> CSR offsets (device-resident, tracked: the CSR
        // is what delivery indexes at step time)
        tr.realloc(
            MemKind::Device,
            (self.first_out.len() * 4) as u64,
            ((n_nodes + 1) * 4) as u64,
        );
        self.first_out = vec![0u32; n_nodes + 1];
        for &s in self.source.as_slice() {
            debug_assert!((s as usize) < n_nodes, "source {s} out of node space");
            self.first_out[s as usize + 1] += 1;
        }
        for i in 0..n_nodes {
            self.first_out[i + 1] += self.first_out[i];
        }
        // stable scatter permutation: destination slot per connection
        let mut cursor = self.first_out.clone();
        let mut perm: Vec<u32> = vec![0; n];
        for (i, &s) in self.source.as_slice().iter().enumerate() {
            perm[i] = cursor[s as usize];
            cursor[s as usize] += 1;
        }
        fn scatter<T: Copy + Default>(perm: &[u32], xs: &[T]) -> Vec<T> {
            let mut out = vec![T::default(); xs.len()];
            for (i, &x) in xs.iter().enumerate() {
                out[perm[i] as usize] = x;
            }
            out
        }
        let src = scatter(&perm, self.source.as_slice());
        let tgt = scatter(&perm, self.target.as_slice());
        let w = scatter(&perm, self.weight.as_slice());
        let d = scatter(&perm, self.delay.as_slice());
        let p = scatter(&perm, self.port.as_slice());
        self.source.replace(src, tr);
        self.target.replace(tgt, tr);
        self.weight.replace(w, tr);
        self.delay.replace(d, tr);
        self.port.replace(p, tr);
        if let Some(r) = self.rule.as_mut() {
            let rs = scatter(&perm, r.as_slice());
            r.replace(rs, tr);
        }
        tr.free(MemKind::Device, scratch);
        self.sorted = true;
    }

    /// First connection index of a node (valid after sorting).
    #[inline]
    pub fn first(&self, node: u32) -> u32 {
        debug_assert!(self.sorted);
        self.first_out[node as usize]
    }

    /// Out-degree of a node, computed on the fly from the CSR offsets (the
    /// level-2 representation).
    #[inline]
    pub fn out_degree(&self, node: u32) -> u32 {
        debug_assert!(self.sorted);
        self.first_out[node as usize + 1] - self.first_out[node as usize]
    }

    /// The connection index range outgoing from `node`.
    #[inline]
    pub fn outgoing(&self, node: u32) -> std::ops::Range<usize> {
        debug_assert!(self.sorted, "outgoing() requires sort_by_source()");
        self.first_out[node as usize] as usize..self.first_out[node as usize + 1] as usize
    }

    /// Borrow the full CSR offsets (n_nodes + 1 entries).
    pub fn first_out(&self) -> &[u32] {
        &self.first_out
    }

    /// Borrowed SoA view of a connection index range — the shared access
    /// path of everything that walks a node's outgoing block (delivery-plan
    /// construction, benches, equivalence tests).
    #[inline]
    pub fn view(&self, rng: std::ops::Range<usize>) -> ConnView<'_> {
        ConnView {
            target: &self.target.as_slice()[rng.clone()],
            port: &self.port.as_slice()[rng.clone()],
            delay: &self.delay.as_slice()[rng.clone()],
            weight: &self.weight.as_slice()[rng],
        }
    }

    /// Serialize the full store (SoA arrays, CSR offsets, sort flag; since
    /// format v3 also the rule registry and per-connection rule ids — the
    /// v3 fields are strictly appended, so a v2 payload is a prefix of the
    /// v3 payload of the same static store).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.bool(self.sorted);
        enc.slice_u32(self.source.as_slice());
        enc.slice_u32(self.target.as_slice());
        enc.slice_f32(self.weight.as_slice());
        enc.slice_u16(self.delay.as_slice());
        enc.slice_u8(self.port.as_slice());
        enc.slice_u32(&self.first_out);
        enc.seq_len(self.rules.len());
        for r in &self.rules {
            r.encode(enc);
        }
        match self.rule.as_ref() {
            None => enc.bool(false),
            Some(r) => {
                enc.bool(true);
                enc.slice_u16(r.as_slice());
            }
        }
    }

    /// Rebuild a store from [`Connections::snapshot_encode`] output; the
    /// SoA arrays are re-registered with `tr` as device allocations.
    /// `with_rules` says whether the payload carries the v3 plasticity
    /// block (format-v2 files predate it and load as all-static).
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
        with_rules: bool,
    ) -> anyhow::Result<Self> {
        let sorted = dec.bool()?;
        let mut c = Connections::new();
        c.sorted = sorted;
        c.source.extend_from_slice(&dec.vec_u32()?, tr);
        c.target.extend_from_slice(&dec.vec_u32()?, tr);
        c.weight.extend_from_slice(&dec.vec_f32()?, tr);
        c.delay.extend_from_slice(&dec.vec_u16()?, tr);
        c.port.extend_from_slice(&dec.vec_u8()?, tr);
        c.first_out = dec.vec_u32()?;
        tr.alloc(MemKind::Device, (c.first_out.len() * 4) as u64);
        let n = c.source.len();
        if c.target.len() != n || c.weight.len() != n || c.delay.len() != n || c.port.len() != n
        {
            anyhow::bail!("connection snapshot has mismatched SoA array lengths");
        }
        if with_rules {
            let n_rules = dec.seq_len(crate::plasticity::RULE_ENCODED_BYTES)?;
            for _ in 0..n_rules {
                c.rules.push(StdpRule::decode(dec)?);
            }
            if dec.bool()? {
                let ids = dec.vec_u16()?;
                if ids.len() != n {
                    anyhow::bail!(
                        "per-connection rule ids cover {} of {n} connections",
                        ids.len()
                    );
                }
                if let Some(&bad) =
                    ids.iter().find(|&&id| id != NO_RULE && id as usize >= n_rules)
                {
                    anyhow::bail!("connection references unknown STDP rule {bad}");
                }
                let mut arr = TrackedVec::new(MemKind::Device);
                arr.extend_from_slice(&ids, tr);
                c.rule = Some(arr);
            }
        }
        Ok(c)
    }

    /// Total device bytes of the SoA arrays, the CSR offsets built by
    /// [`Connections::sort_by_source`], and the per-connection rule-id
    /// slice (when materialized).
    pub fn device_bytes(&self) -> u64 {
        self.source.bytes()
            + self.target.bytes()
            + self.weight.bytes()
            + self.delay.bytes()
            + self.port.bytes()
            + (self.first_out.len() * 4) as u64
            + self.rule.as_ref().map_or(0, |r| r.bytes())
    }
}

impl Default for Connections {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(conns: &[(u32, u32)]) -> (Connections, Tracker) {
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        for &(s, t) in conns {
            c.push(s, t, 1.0, 1, 0, &mut tr);
        }
        (c, tr)
    }

    #[test]
    fn sort_groups_by_source_and_builds_csr() {
        let (mut c, mut tr) = store_with(&[(2, 0), (0, 1), (2, 2), (1, 3), (0, 4)]);
        c.sort_by_source(3, &mut tr);
        assert_eq!(c.source.as_slice(), &[0, 0, 1, 2, 2]);
        // stable: creation order preserved within node 0 and node 2
        assert_eq!(c.target.as_slice(), &[1, 4, 3, 0, 2]);
        assert_eq!(c.outgoing(0), 0..2);
        assert_eq!(c.outgoing(1), 2..3);
        assert_eq!(c.outgoing(2), 3..5);
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(1), 1);
        assert_eq!(c.first(2), 3);
    }

    #[test]
    fn nodes_without_connections_have_empty_ranges() {
        let (mut c, mut tr) = store_with(&[(3, 0)]);
        c.sort_by_source(5, &mut tr);
        assert_eq!(c.outgoing(0), 0..0);
        assert_eq!(c.outgoing(4), 1..1);
        assert_eq!(c.out_degree(4), 0);
    }

    #[test]
    fn remap_sources_rewrites_tail() {
        let (mut c, mut tr) = store_with(&[(9, 0)]);
        // two "remote" connections with temporary source positions 0 and 1
        c.push(0, 5, 1.0, 1, 0, &mut tr);
        c.push(1, 6, 1.0, 1, 0, &mut tr);
        let map = vec![100, 200];
        c.remap_sources(1, &map);
        assert_eq!(c.source.as_slice(), &[9, 100, 200]);
    }

    #[test]
    fn sort_accounts_transient_peak() {
        let (mut c, mut tr) = store_with(&[(1, 0), (0, 0)]);
        let before_peak = tr.peak(MemKind::Device);
        c.sort_by_source(2, &mut tr);
        assert!(tr.peak(MemKind::Device) > before_peak);
        // steady state unchanged by the transient
        assert_eq!(tr.current(MemKind::Device), c.device_bytes());
    }

    #[test]
    fn snapshot_roundtrip_preserves_everything() {
        let (mut c, mut tr) = store_with(&[(2, 0), (0, 1), (2, 2), (1, 3), (0, 4)]);
        c.sort_by_source(3, &mut tr);
        let mut enc = crate::snapshot::Encoder::new();
        c.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = Connections::snapshot_decode(&mut dec, &mut tr2, true).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.source.as_slice(), c.source.as_slice());
        assert_eq!(d.target.as_slice(), c.target.as_slice());
        assert_eq!(d.weight.as_slice(), c.weight.as_slice());
        assert_eq!(d.delay.as_slice(), c.delay.as_slice());
        assert_eq!(d.port.as_slice(), c.port.as_slice());
        assert_eq!(d.first_out(), c.first_out());
        assert!(d.is_sorted());
        assert_eq!(d.outgoing(2), c.outgoing(2));
        assert_eq!(tr2.current(MemKind::Device), d.device_bytes());
    }

    #[test]
    fn empty_store_sorts() {
        let (mut c, mut tr) = store_with(&[]);
        c.sort_by_source(4, &mut tr);
        assert_eq!(c.outgoing(3), 0..0);
        assert!(c.is_sorted());
    }

    fn test_rule(a_plus: f32) -> crate::plasticity::StdpRule {
        crate::plasticity::StdpRule {
            tau_plus_ms: 20.0,
            tau_minus_ms: 20.0,
            a_plus,
            a_minus: 0.5,
            w_min: 0.0,
            w_max: 10.0,
            bound: crate::plasticity::WeightBound::Additive,
        }
    }

    #[test]
    fn rules_attach_dedup_and_ride_through_sort() {
        let (mut c, mut tr) = store_with(&[(2, 0), (0, 1)]);
        assert!(!c.has_plasticity());
        let r0 = c.register_rule(test_rule(1.0));
        // the first two connections stay static; the next two are plastic
        let start = c.len();
        c.push(1, 3, 1.0, 1, 0, &mut tr);
        c.push(0, 4, 1.0, 1, 0, &mut tr);
        c.attach_rule(start, r0, &mut tr);
        assert!(c.has_plasticity());
        // identical rule deduplicates, a different one gets a new id
        assert_eq!(c.register_rule(test_rule(1.0)), r0);
        assert_ne!(c.register_rule(test_rule(2.0)), r0);
        // later pushes stay aligned as static
        c.push(2, 5, 1.0, 1, 0, &mut tr);
        assert_eq!(c.rule_slice().unwrap(), &[NO_RULE, NO_RULE, r0, r0, NO_RULE]);
        // sorting scatters the rule ids with their connections
        c.sort_by_source(3, &mut tr);
        let expect: Vec<u16> = c
            .target
            .as_slice()
            .iter()
            .map(|&t| if t == 3 || t == 4 { r0 } else { NO_RULE })
            .collect();
        assert_eq!(c.rule_slice().unwrap(), expect.as_slice());
        assert_eq!(tr.current(MemKind::Device), c.device_bytes());
    }

    #[test]
    fn rules_snapshot_roundtrip_and_v2_prefix() {
        let (mut c, mut tr) = store_with(&[(0, 1), (1, 0)]);
        let r = c.register_rule(test_rule(1.5));
        c.attach_rule(1, r, &mut tr);
        c.sort_by_source(2, &mut tr);
        let mut enc = crate::snapshot::Encoder::new();
        c.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = Connections::snapshot_decode(&mut dec, &mut tr2, true).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.rules(), c.rules());
        assert_eq!(d.rule_slice(), c.rule_slice());
        assert_eq!(tr2.current(MemKind::Device), d.device_bytes());

        // a static store's v3 payload is its v2 payload + the empty rules
        // block, so a v2 reader (with_rules = false) must accept the prefix
        let (mut s, mut tr3) = store_with(&[(0, 1)]);
        s.sort_by_source(2, &mut tr3);
        let mut enc = crate::snapshot::Encoder::new();
        s.snapshot_encode(&mut enc);
        let v3 = enc.into_bytes();
        let mut empty_rules = crate::snapshot::Encoder::new();
        empty_rules.seq_len(0);
        empty_rules.bool(false);
        let v2 = &v3[..v3.len() - empty_rules.len()];
        let mut tr4 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(v2);
        let back = Connections::snapshot_decode(&mut dec, &mut tr4, false).unwrap();
        dec.finish().unwrap();
        assert!(!back.has_plasticity());
        assert_eq!(back.target.as_slice(), s.target.as_slice());
    }
}
