//! Device-resident connection store.
//!
//! Connections are stored as a structure-of-arrays in (simulated) GPU
//! memory, grown in fixed-size blocks, and sorted with the source-neuron
//! index as the first key at preparation time ([30], §0.3.6): with that
//! order, all connections outgoing from a node are contiguous, so spike
//! delivery only needs the node's *first connection index* plus its
//! *out-degree* (level 3) — or just the first index, with the out-degree
//! recomputed on the fly from the next node's first index (level 2).

use crate::memory::tracker::{Tracker, TrackedVec};
use crate::memory::MemKind;

/// SoA connection store (one per rank).
pub struct Connections {
    pub source: TrackedVec<u32>,
    pub target: TrackedVec<u32>,
    pub weight: TrackedVec<f32>,
    pub delay: TrackedVec<u16>,
    pub port: TrackedVec<u8>,
    /// CSR offsets per node after [`Connections::sort_by_source`]:
    /// `first_out[s] .. first_out[s+1]` index this node's outgoing
    /// connections. Length = n_nodes + 1.
    first_out: Vec<u32>,
    sorted: bool,
}

impl Connections {
    pub fn new() -> Self {
        Self {
            source: TrackedVec::new(MemKind::Device),
            target: TrackedVec::new(MemKind::Device),
            weight: TrackedVec::new(MemKind::Device),
            delay: TrackedVec::new(MemKind::Device),
            port: TrackedVec::new(MemKind::Device),
            first_out: Vec::new(),
            sorted: false,
        }
    }

    pub fn len(&self) -> usize {
        self.source.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_sorted(&self) -> bool {
        self.sorted
    }

    /// Append one connection (construction phase; invalidates sorting).
    #[inline]
    pub fn push(
        &mut self,
        source: u32,
        target: u32,
        weight: f32,
        delay: u16,
        port: u8,
        tr: &mut Tracker,
    ) {
        debug_assert!(delay >= 1, "delays are >= 1 step");
        self.source.push(source, tr);
        self.target.push(target, tr);
        self.weight.push(weight, tr);
        self.delay.push(delay, tr);
        self.port.push(port, tr);
        self.sorted = false;
    }

    /// Rewrite the source ids of connections `[start, len)` through `map`
    /// (`RemoteConnect` step: temporary source positions -> image-neuron
    /// local indexes, Eq. 5/6 final step). `u32::MAX` entries in `map` mark
    /// positions that must not occur.
    pub fn remap_sources(&mut self, start: usize, map: &[u32]) {
        for s in &mut self.source.as_mut_slice()[start..] {
            let img = map[*s as usize];
            debug_assert!(img != u32::MAX, "unmapped source position {s}");
            *s = img;
        }
        self.sorted = false;
    }

    /// Sort by source index (stable; preserves creation order within a
    /// node) and build the CSR offsets for `n_nodes` nodes. The scratch
    /// (u64 keys + u32 permutation) is accounted as a transient device
    /// allocation — it is the dominant term of the Fig. 5 memory peak.
    pub fn sort_by_source(&mut self, n_nodes: usize, tr: &mut Tracker) {
        let n = self.len();
        // §Perf iteration 2: source indexes are bounded by the node count,
        // so a single-pass stable *counting scatter* replaces the generic
        // radix argsort (one count pass + one scatter pass per array
        // instead of up to four radix passes over a permutation). The
        // scatter permutation is accounted as the transient device scratch
        // — the dominant term of the Fig. 5 memory peak.
        let scratch = (n * 4) as u64;
        tr.alloc(MemKind::Device, scratch);
        tr.transient_events += 1;
        // counting pass -> CSR offsets
        self.first_out = vec![0u32; n_nodes + 1];
        for &s in self.source.as_slice() {
            debug_assert!((s as usize) < n_nodes, "source {s} out of node space");
            self.first_out[s as usize + 1] += 1;
        }
        for i in 0..n_nodes {
            self.first_out[i + 1] += self.first_out[i];
        }
        // stable scatter permutation: destination slot per connection
        let mut cursor = self.first_out.clone();
        let mut perm: Vec<u32> = vec![0; n];
        for (i, &s) in self.source.as_slice().iter().enumerate() {
            perm[i] = cursor[s as usize];
            cursor[s as usize] += 1;
        }
        fn scatter<T: Copy + Default>(perm: &[u32], xs: &[T]) -> Vec<T> {
            let mut out = vec![T::default(); xs.len()];
            for (i, &x) in xs.iter().enumerate() {
                out[perm[i] as usize] = x;
            }
            out
        }
        let src = scatter(&perm, self.source.as_slice());
        let tgt = scatter(&perm, self.target.as_slice());
        let w = scatter(&perm, self.weight.as_slice());
        let d = scatter(&perm, self.delay.as_slice());
        let p = scatter(&perm, self.port.as_slice());
        self.source.replace(src, tr);
        self.target.replace(tgt, tr);
        self.weight.replace(w, tr);
        self.delay.replace(d, tr);
        self.port.replace(p, tr);
        tr.free(MemKind::Device, scratch);
        self.sorted = true;
    }

    /// First connection index of a node (valid after sorting).
    #[inline]
    pub fn first(&self, node: u32) -> u32 {
        debug_assert!(self.sorted);
        self.first_out[node as usize]
    }

    /// Out-degree of a node, computed on the fly from the CSR offsets (the
    /// level-2 representation).
    #[inline]
    pub fn out_degree(&self, node: u32) -> u32 {
        debug_assert!(self.sorted);
        self.first_out[node as usize + 1] - self.first_out[node as usize]
    }

    /// The connection index range outgoing from `node`.
    #[inline]
    pub fn outgoing(&self, node: u32) -> std::ops::Range<usize> {
        debug_assert!(self.sorted, "outgoing() requires sort_by_source()");
        self.first_out[node as usize] as usize..self.first_out[node as usize + 1] as usize
    }

    /// Borrow the full CSR offsets (n_nodes + 1 entries).
    pub fn first_out(&self) -> &[u32] {
        &self.first_out
    }

    /// Serialize the full store (SoA arrays, CSR offsets, sort flag).
    pub fn snapshot_encode(&self, enc: &mut crate::snapshot::Encoder) {
        enc.bool(self.sorted);
        enc.slice_u32(self.source.as_slice());
        enc.slice_u32(self.target.as_slice());
        enc.slice_f32(self.weight.as_slice());
        enc.slice_u16(self.delay.as_slice());
        enc.slice_u8(self.port.as_slice());
        enc.slice_u32(&self.first_out);
    }

    /// Rebuild a store from [`Connections::snapshot_encode`] output; the
    /// SoA arrays are re-registered with `tr` as device allocations.
    pub fn snapshot_decode(
        dec: &mut crate::snapshot::Decoder,
        tr: &mut Tracker,
    ) -> anyhow::Result<Self> {
        let sorted = dec.bool()?;
        let mut c = Connections::new();
        c.sorted = sorted;
        c.source.extend_from_slice(&dec.vec_u32()?, tr);
        c.target.extend_from_slice(&dec.vec_u32()?, tr);
        c.weight.extend_from_slice(&dec.vec_f32()?, tr);
        c.delay.extend_from_slice(&dec.vec_u16()?, tr);
        c.port.extend_from_slice(&dec.vec_u8()?, tr);
        c.first_out = dec.vec_u32()?;
        let n = c.source.len();
        if c.target.len() != n || c.weight.len() != n || c.delay.len() != n || c.port.len() != n
        {
            anyhow::bail!("connection snapshot has mismatched SoA array lengths");
        }
        Ok(c)
    }

    /// Total device bytes of the SoA arrays.
    pub fn device_bytes(&self) -> u64 {
        self.source.bytes()
            + self.target.bytes()
            + self.weight.bytes()
            + self.delay.bytes()
            + self.port.bytes()
    }
}

impl Default for Connections {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(conns: &[(u32, u32)]) -> (Connections, Tracker) {
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        for &(s, t) in conns {
            c.push(s, t, 1.0, 1, 0, &mut tr);
        }
        (c, tr)
    }

    #[test]
    fn sort_groups_by_source_and_builds_csr() {
        let (mut c, mut tr) = store_with(&[(2, 0), (0, 1), (2, 2), (1, 3), (0, 4)]);
        c.sort_by_source(3, &mut tr);
        assert_eq!(c.source.as_slice(), &[0, 0, 1, 2, 2]);
        // stable: creation order preserved within node 0 and node 2
        assert_eq!(c.target.as_slice(), &[1, 4, 3, 0, 2]);
        assert_eq!(c.outgoing(0), 0..2);
        assert_eq!(c.outgoing(1), 2..3);
        assert_eq!(c.outgoing(2), 3..5);
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(1), 1);
        assert_eq!(c.first(2), 3);
    }

    #[test]
    fn nodes_without_connections_have_empty_ranges() {
        let (mut c, mut tr) = store_with(&[(3, 0)]);
        c.sort_by_source(5, &mut tr);
        assert_eq!(c.outgoing(0), 0..0);
        assert_eq!(c.outgoing(4), 1..1);
        assert_eq!(c.out_degree(4), 0);
    }

    #[test]
    fn remap_sources_rewrites_tail() {
        let (mut c, mut tr) = store_with(&[(9, 0)]);
        // two "remote" connections with temporary source positions 0 and 1
        c.push(0, 5, 1.0, 1, 0, &mut tr);
        c.push(1, 6, 1.0, 1, 0, &mut tr);
        let map = vec![100, 200];
        c.remap_sources(1, &map);
        assert_eq!(c.source.as_slice(), &[9, 100, 200]);
    }

    #[test]
    fn sort_accounts_transient_peak() {
        let (mut c, mut tr) = store_with(&[(1, 0), (0, 0)]);
        let before_peak = tr.peak(MemKind::Device);
        c.sort_by_source(2, &mut tr);
        assert!(tr.peak(MemKind::Device) > before_peak);
        // steady state unchanged by the transient
        assert_eq!(tr.current(MemKind::Device), c.device_bytes());
    }

    #[test]
    fn snapshot_roundtrip_preserves_everything() {
        let (mut c, mut tr) = store_with(&[(2, 0), (0, 1), (2, 2), (1, 3), (0, 4)]);
        c.sort_by_source(3, &mut tr);
        let mut enc = crate::snapshot::Encoder::new();
        c.snapshot_encode(&mut enc);
        let bytes = enc.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = crate::snapshot::Decoder::new(&bytes);
        let d = Connections::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(d.source.as_slice(), c.source.as_slice());
        assert_eq!(d.target.as_slice(), c.target.as_slice());
        assert_eq!(d.weight.as_slice(), c.weight.as_slice());
        assert_eq!(d.delay.as_slice(), c.delay.as_slice());
        assert_eq!(d.port.as_slice(), c.port.as_slice());
        assert_eq!(d.first_out(), c.first_out());
        assert!(d.is_sorted());
        assert_eq!(d.outgoing(2), c.outgoing(2));
        assert_eq!(tr2.current(MemKind::Device), d.device_bytes());
    }

    #[test]
    fn empty_store_sorts() {
        let (mut c, mut tr) = store_with(&[]);
        c.sort_by_source(4, &mut tr);
        assert_eq!(c.outgoing(3), 0..0);
        assert!(c.is_sorted());
    }
}
