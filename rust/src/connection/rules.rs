//! Connection rules and their aligned source-index streams.
//!
//! The paper's construction correctness hinges on one invariant: for a
//! remote connect call, the *source* MPI process must regenerate exactly
//! the sequence of source-neuron indexes that the *target* MPI process
//! draws while creating the connections (§0.3.1, the `RemoteConnect` source
//! variant). We enforce that by construction: each rule has one generator
//! that emits `(source_pos, target_pos)` pairs, drawing source positions
//! from the **aligned** generator and target positions from the **local**
//! generator; the sources-only replay runs the same code with a sink that
//! ignores targets and a dummy local generator is never consumed for source
//! positions.

use crate::util::rng::Rng;

/// Deterministic and probabilistic connection rules (cf. connectivity
/// concepts of [44] and §0.3.3/§0.3.5).
#[derive(Clone, Debug)]
pub enum ConnRule {
    /// position i -> position i (requires equal set sizes)
    OneToOne,
    /// every source to every target
    AllToAll,
    /// for each target, `k` sources drawn uniformly (multapses allowed)
    FixedIndegree { k: u32 },
    /// for each source, `k` targets drawn uniformly (multapses allowed)
    FixedOutdegree { k: u32 },
    /// `n` connections with both endpoints drawn uniformly
    FixedTotalNumber { n: u64 },
    /// §0.3.5 assigned-nodes: endpoints already drawn by the distributed
    /// fixed-in-degree driver, given as (source_pos, target_pos) pairs
    AssignedNodes(Vec<(u32, u32)>),
}

impl ConnRule {
    /// Can the rule leave some positions of the source set without any
    /// connection? (Those rules benefit from the ξ-flagging of §0.3.3.)
    pub fn may_skip_sources(&self) -> bool {
        matches!(
            self,
            ConnRule::FixedIndegree { .. }
                | ConnRule::FixedTotalNumber { .. }
                | ConnRule::AssignedNodes(_)
        )
    }

    /// Number of connections the call will create (exact for every rule).
    pub fn conn_count(&self, n_source: usize, n_target: usize) -> u64 {
        match self {
            ConnRule::OneToOne => n_source.min(n_target) as u64,
            ConnRule::AllToAll => n_source as u64 * n_target as u64,
            ConnRule::FixedIndegree { k } => *k as u64 * n_target as u64,
            ConnRule::FixedOutdegree { k } => *k as u64 * n_source as u64,
            ConnRule::FixedTotalNumber { n } => *n,
            ConnRule::AssignedNodes(pairs) => pairs.len() as u64,
        }
    }

    /// The ξ heuristic of §0.3.3: the ratio between the estimated number of
    /// newly created connections and the size of the source set; flagging
    /// pays off when this is below the threshold.
    pub fn source_use_ratio(&self, n_source: usize, n_target: usize) -> f64 {
        if n_source == 0 {
            return f64::INFINITY;
        }
        self.conn_count(n_source, n_target) as f64 / n_source as f64
    }

    /// Generate the full `(source_pos, target_pos)` stream.
    ///
    /// `aligned` is the per-(σ,τ) generator `RNG[σ,τ]` — consumed *only*
    /// for source positions; `local` is the target process's private
    /// generator — consumed for target positions.
    pub fn generate(
        &self,
        n_source: usize,
        n_target: usize,
        aligned: &mut Rng,
        local: &mut Rng,
        mut sink: impl FnMut(u32, u32),
    ) {
        match self {
            ConnRule::OneToOne => {
                assert_eq!(
                    n_source, n_target,
                    "one-to-one requires equal source/target sizes"
                );
                for i in 0..n_source as u32 {
                    sink(i, i);
                }
            }
            ConnRule::AllToAll => {
                for j in 0..n_target as u32 {
                    for i in 0..n_source as u32 {
                        sink(i, j);
                    }
                }
            }
            ConnRule::FixedIndegree { k } => {
                for j in 0..n_target as u32 {
                    for _ in 0..*k {
                        sink(aligned.below(n_source as u32), j);
                    }
                }
            }
            ConnRule::FixedOutdegree { k } => {
                for i in 0..n_source as u32 {
                    for _ in 0..*k {
                        sink(i, local.below(n_target as u32));
                    }
                }
            }
            ConnRule::FixedTotalNumber { n } => {
                for _ in 0..*n {
                    let i = aligned.below(n_source as u32);
                    let j = local.below(n_target as u32);
                    sink(i, j);
                }
            }
            ConnRule::AssignedNodes(pairs) => {
                for &(i, j) in pairs {
                    sink(i, j);
                }
            }
        }
    }

    /// Source-only replay (the `RemoteConnect` *source variant*): consumes
    /// the aligned generator identically to [`generate`], emitting only the
    /// source positions. Must never touch a local generator.
    pub fn replay_sources(
        &self,
        n_source: usize,
        n_target: usize,
        aligned: &mut Rng,
        mut sink: impl FnMut(u32),
    ) {
        match self {
            ConnRule::OneToOne => {
                for i in 0..n_source.min(n_target) as u32 {
                    sink(i);
                }
            }
            ConnRule::AllToAll => {
                for _ in 0..n_target as u32 {
                    for i in 0..n_source as u32 {
                        sink(i);
                    }
                }
            }
            ConnRule::FixedIndegree { k } => {
                for _ in 0..n_target as u32 {
                    for _ in 0..*k {
                        sink(aligned.below(n_source as u32));
                    }
                }
            }
            ConnRule::FixedOutdegree { k } => {
                // target draws happen on the target process only (local
                // generator); the aligned stream is untouched for this rule
                for i in 0..n_source as u32 {
                    for _ in 0..*k {
                        sink(i);
                    }
                }
            }
            ConnRule::FixedTotalNumber { n } => {
                for _ in 0..*n {
                    sink(aligned.below(n_source as u32));
                }
            }
            ConnRule::AssignedNodes(pairs) => {
                for &(i, _) in pairs {
                    sink(i);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The core alignment invariant: generate() and replay_sources() emit
    /// the same source-position stream from the same aligned generator.
    fn assert_aligned(rule: ConnRule, ns: usize, nt: usize) {
        let mut a1 = Rng::new(99);
        let mut a2 = Rng::new(99);
        let mut local = Rng::new(7);
        let mut gen_src = Vec::new();
        rule.generate(ns, nt, &mut a1, &mut local, |s, _| gen_src.push(s));
        let mut rep_src = Vec::new();
        rule.replay_sources(ns, nt, &mut a2, |s| rep_src.push(s));
        // fixed-outdegree consumes local randomness for targets; source
        // streams must match for every rule regardless
        assert_eq!(gen_src, rep_src, "{rule:?}");
        // and the aligned generators end in the same state
        assert_eq!(a1.next_u64(), a2.next_u64(), "{rule:?}");
    }

    #[test]
    fn alignment_all_rules() {
        assert_aligned(ConnRule::OneToOne, 13, 13);
        assert_aligned(ConnRule::AllToAll, 5, 7);
        assert_aligned(ConnRule::FixedIndegree { k: 9 }, 31, 17);
        assert_aligned(ConnRule::FixedOutdegree { k: 4 }, 11, 23);
        assert_aligned(ConnRule::FixedTotalNumber { n: 101 }, 19, 29);
        assert_aligned(
            ConnRule::AssignedNodes(vec![(0, 1), (5, 2), (0, 0)]),
            7,
            3,
        );
    }

    #[test]
    fn local_rng_does_not_affect_alignment() {
        // different local generators must not change the source stream
        let rule = ConnRule::FixedTotalNumber { n: 50 };
        let collect = |local_seed: u64| {
            let mut a = Rng::new(5);
            let mut l = Rng::new(local_seed);
            let mut src = Vec::new();
            rule.generate(10, 10, &mut a, &mut l, |s, _| src.push(s));
            src
        };
        assert_eq!(collect(1), collect(999));
    }

    #[test]
    fn conn_counts_exact() {
        assert_eq!(ConnRule::OneToOne.conn_count(5, 5), 5);
        assert_eq!(ConnRule::AllToAll.conn_count(4, 6), 24);
        assert_eq!(ConnRule::FixedIndegree { k: 3 }.conn_count(100, 7), 21);
        assert_eq!(ConnRule::FixedOutdegree { k: 3 }.conn_count(7, 100), 21);
        assert_eq!(ConnRule::FixedTotalNumber { n: 42 }.conn_count(9, 9), 42);
    }

    #[test]
    fn generated_counts_match_conn_count() {
        for rule in [
            ConnRule::OneToOne,
            ConnRule::AllToAll,
            ConnRule::FixedIndegree { k: 5 },
            ConnRule::FixedOutdegree { k: 5 },
            ConnRule::FixedTotalNumber { n: 77 },
        ] {
            let (ns, nt) = (12, 12);
            let mut count = 0u64;
            rule.generate(ns, nt, &mut Rng::new(1), &mut Rng::new(2), |_, _| {
                count += 1
            });
            assert_eq!(count, rule.conn_count(ns, nt), "{rule:?}");
        }
    }

    #[test]
    fn fixed_indegree_gives_each_target_k_inputs() {
        let k = 8;
        let (ns, nt) = (50usize, 20usize);
        let mut indeg = vec![0u32; nt];
        ConnRule::FixedIndegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(3),
            &mut Rng::new(4),
            |s, t| {
                assert!((s as usize) < ns);
                indeg[t as usize] += 1;
            },
        );
        assert!(indeg.iter().all(|&d| d == k));
    }

    #[test]
    fn fixed_outdegree_gives_each_source_k_outputs() {
        let k = 6;
        let (ns, nt) = (15usize, 40usize);
        let mut outdeg = vec![0u32; ns];
        ConnRule::FixedOutdegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(3),
            &mut Rng::new(4),
            |s, t| {
                assert!((t as usize) < nt);
                outdeg[s as usize] += 1;
            },
        );
        assert!(outdeg.iter().all(|&d| d == k));
    }

    #[test]
    fn fixed_indegree_sources_roughly_uniform() {
        let (ns, nt, k) = (20usize, 500usize, 40u32);
        let mut hits = vec![0u32; ns];
        ConnRule::FixedIndegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(8),
            &mut Rng::new(9),
            |s, _| hits[s as usize] += 1,
        );
        let expect = (nt as u32 * k) as f64 / ns as f64;
        for &h in &hits {
            assert!((h as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn may_skip_sources_classification() {
        assert!(!ConnRule::OneToOne.may_skip_sources());
        assert!(!ConnRule::AllToAll.may_skip_sources());
        assert!(!ConnRule::FixedOutdegree { k: 1 }.may_skip_sources());
        assert!(ConnRule::FixedIndegree { k: 1 }.may_skip_sources());
        assert!(ConnRule::FixedTotalNumber { n: 1 }.may_skip_sources());
    }

    #[test]
    fn xi_ratio() {
        // K_in * N_target / N_source (paper's heuristic expression)
        let r = ConnRule::FixedIndegree { k: 10 }.source_use_ratio(1000, 5);
        assert!((r - 0.05).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "one-to-one")]
    fn one_to_one_size_mismatch_panics() {
        ConnRule::OneToOne.generate(3, 4, &mut Rng::new(1), &mut Rng::new(2), |_, _| {});
    }
}
