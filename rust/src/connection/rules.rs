//! Connection rules and their aligned source-index streams.
//!
//! The paper's construction correctness hinges on one invariant: for a
//! remote connect call, the *source* MPI process must regenerate exactly
//! the sequence of source-neuron indexes that the *target* MPI process
//! draws while creating the connections (§0.3.1, the `RemoteConnect` source
//! variant). We enforce that by construction: each rule has one generator
//! that emits `(source_pos, target_pos)` pairs, drawing source positions
//! from the **aligned** generator and target positions from the **local**
//! generator; the sources-only replay runs the same code with a sink that
//! ignores targets and a dummy local generator is never consumed for source
//! positions.

use crate::util::rng::Rng;

/// Deterministic and probabilistic connection rules (cf. connectivity
/// concepts of [44] and §0.3.3/§0.3.5).
#[derive(Clone, Debug)]
pub enum ConnRule {
    /// position i -> position i (requires equal set sizes)
    OneToOne,
    /// every source to every target
    AllToAll,
    /// for each target, `k` sources drawn uniformly (multapses allowed)
    FixedIndegree { k: u32 },
    /// for each source, `k` targets drawn uniformly (multapses allowed)
    FixedOutdegree { k: u32 },
    /// `n` connections with both endpoints drawn uniformly
    FixedTotalNumber { n: u64 },
    /// §0.3.5 assigned-nodes: endpoints already drawn by the distributed
    /// fixed-in-degree driver, given as (source_pos, target_pos) pairs
    AssignedNodes(Vec<(u32, u32)>),
    /// §0.3.5 distributed fixed-in-degree, replayed from the stream seed:
    /// a self-contained triplet stream draws, per target position, `k`
    /// (source-rank, source-pos) pairs; the call keeps the pairs whose
    /// drawn rank equals `sigma`, sorted ascending by (source, target).
    ///
    /// `state` is the raw xoshiro state of the per-(pass, τ) stream,
    /// captured by the driver (`models/balanced.rs`) *before* any draw.
    /// The rule consumes neither the aligned nor the local generator, so
    /// the same call is bit-identical on every rank — and, unlike
    /// [`ConnRule::AssignedNodes`], its descriptor is constant-size, which
    /// is what makes procedural connectivity pay off for the balanced
    /// model (the pairs would otherwise dominate descriptor memory).
    TripletBucket {
        /// raw xoshiro256** state of the triplet stream
        state: [u64; 4],
        /// in-degree drawn per target position
        k: u32,
        /// world size the source-rank draws range over
        n_ranks: u32,
        /// the source rank whose bucket this call materializes
        sigma: u32,
    },
}

/// Replay a [`ConnRule::TripletBucket`] stream, emitting this bucket's
/// (source_pos, target_pos) pairs sorted ascending — the single
/// implementation behind `generate`, `replay_sources` and `conn_count`,
/// so the three can never drift on stream consumption.
fn triplet_bucket_pairs(
    state: [u64; 4],
    k: u32,
    n_ranks: u32,
    sigma: u32,
    n_source: usize,
    n_target: usize,
    mut sink: impl FnMut(u32, u32),
) -> u64 {
    let mut rng = Rng::from_raw_state(state, None);
    let mut bucket: Vec<(u32, u32)> = Vec::new();
    for j in 0..n_target as u32 {
        for _ in 0..k {
            // both draws always consumed, keeping the stream position
            // identical for every sigma (Lemire rejection draws a
            // variable number of words)
            let sg = rng.below(n_ranks);
            let sp = rng.below(n_source as u32);
            if sg == sigma {
                bucket.push((sp, j));
            }
        }
    }
    bucket.sort_unstable();
    let n = bucket.len() as u64;
    for (i, j) in bucket {
        sink(i, j);
    }
    n
}

impl ConnRule {
    /// Can the rule leave some positions of the source set without any
    /// connection? (Those rules benefit from the ξ-flagging of §0.3.3.)
    pub fn may_skip_sources(&self) -> bool {
        matches!(
            self,
            ConnRule::FixedIndegree { .. }
                | ConnRule::FixedTotalNumber { .. }
                | ConnRule::AssignedNodes(_)
                | ConnRule::TripletBucket { .. }
        )
    }

    /// Number of connections the call will create (exact for every rule;
    /// for [`ConnRule::TripletBucket`] this replays the stream).
    pub fn conn_count(&self, n_source: usize, n_target: usize) -> u64 {
        match self {
            ConnRule::OneToOne => n_source.min(n_target) as u64,
            ConnRule::AllToAll => n_source as u64 * n_target as u64,
            ConnRule::FixedIndegree { k } => *k as u64 * n_target as u64,
            ConnRule::FixedOutdegree { k } => *k as u64 * n_source as u64,
            ConnRule::FixedTotalNumber { n } => *n,
            ConnRule::AssignedNodes(pairs) => pairs.len() as u64,
            ConnRule::TripletBucket {
                state,
                k,
                n_ranks,
                sigma,
            } => triplet_bucket_pairs(
                *state,
                *k,
                *n_ranks,
                *sigma,
                n_source,
                n_target,
                |_, _| {},
            ),
        }
    }

    /// The ξ heuristic of §0.3.3: the ratio between the estimated number of
    /// newly created connections and the size of the source set; flagging
    /// pays off when this is below the threshold.
    pub fn source_use_ratio(&self, n_source: usize, n_target: usize) -> f64 {
        if n_source == 0 {
            return f64::INFINITY;
        }
        self.conn_count(n_source, n_target) as f64 / n_source as f64
    }

    /// Generate the full `(source_pos, target_pos)` stream.
    ///
    /// `aligned` is the per-(σ,τ) generator `RNG[σ,τ]` — consumed *only*
    /// for source positions; `local` is the target process's private
    /// generator — consumed for target positions.
    pub fn generate(
        &self,
        n_source: usize,
        n_target: usize,
        aligned: &mut Rng,
        local: &mut Rng,
        mut sink: impl FnMut(u32, u32),
    ) {
        match self {
            ConnRule::OneToOne => {
                assert_eq!(
                    n_source, n_target,
                    "one-to-one requires equal source/target sizes"
                );
                for i in 0..n_source as u32 {
                    sink(i, i);
                }
            }
            ConnRule::AllToAll => {
                for j in 0..n_target as u32 {
                    for i in 0..n_source as u32 {
                        sink(i, j);
                    }
                }
            }
            ConnRule::FixedIndegree { k } => {
                for j in 0..n_target as u32 {
                    for _ in 0..*k {
                        sink(aligned.below(n_source as u32), j);
                    }
                }
            }
            ConnRule::FixedOutdegree { k } => {
                for i in 0..n_source as u32 {
                    for _ in 0..*k {
                        sink(i, local.below(n_target as u32));
                    }
                }
            }
            ConnRule::FixedTotalNumber { n } => {
                for _ in 0..*n {
                    let i = aligned.below(n_source as u32);
                    let j = local.below(n_target as u32);
                    sink(i, j);
                }
            }
            ConnRule::AssignedNodes(pairs) => {
                for &(i, j) in pairs {
                    sink(i, j);
                }
            }
            ConnRule::TripletBucket {
                state,
                k,
                n_ranks,
                sigma,
            } => {
                triplet_bucket_pairs(
                    *state, *k, *n_ranks, *sigma, n_source, n_target, sink,
                );
            }
        }
    }

    /// Source-only replay (the `RemoteConnect` *source variant*): consumes
    /// the aligned generator identically to [`generate`], emitting only the
    /// source positions. Must never touch a local generator.
    pub fn replay_sources(
        &self,
        n_source: usize,
        n_target: usize,
        aligned: &mut Rng,
        mut sink: impl FnMut(u32),
    ) {
        match self {
            ConnRule::OneToOne => {
                for i in 0..n_source.min(n_target) as u32 {
                    sink(i);
                }
            }
            ConnRule::AllToAll => {
                for _ in 0..n_target as u32 {
                    for i in 0..n_source as u32 {
                        sink(i);
                    }
                }
            }
            ConnRule::FixedIndegree { k } => {
                for _ in 0..n_target as u32 {
                    for _ in 0..*k {
                        sink(aligned.below(n_source as u32));
                    }
                }
            }
            ConnRule::FixedOutdegree { k } => {
                // target draws happen on the target process only (local
                // generator); the aligned stream is untouched for this rule
                for i in 0..n_source as u32 {
                    for _ in 0..*k {
                        sink(i);
                    }
                }
            }
            ConnRule::FixedTotalNumber { n } => {
                for _ in 0..*n {
                    sink(aligned.below(n_source as u32));
                }
            }
            ConnRule::AssignedNodes(pairs) => {
                for &(i, _) in pairs {
                    sink(i);
                }
            }
            ConnRule::TripletBucket {
                state,
                k,
                n_ranks,
                sigma,
            } => {
                // the triplet stream is self-seeded: neither the aligned
                // nor any local generator is consumed on either side
                triplet_bucket_pairs(
                    *state,
                    *k,
                    *n_ranks,
                    *sigma,
                    n_source,
                    n_target,
                    |i, _| sink(i),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The core alignment invariant: generate() and replay_sources() emit
    /// the same source-position stream from the same aligned generator.
    fn assert_aligned(rule: ConnRule, ns: usize, nt: usize) {
        let mut a1 = Rng::new(99);
        let mut a2 = Rng::new(99);
        let mut local = Rng::new(7);
        let mut gen_src = Vec::new();
        rule.generate(ns, nt, &mut a1, &mut local, |s, _| gen_src.push(s));
        let mut rep_src = Vec::new();
        rule.replay_sources(ns, nt, &mut a2, |s| rep_src.push(s));
        // fixed-outdegree consumes local randomness for targets; source
        // streams must match for every rule regardless
        assert_eq!(gen_src, rep_src, "{rule:?}");
        // and the aligned generators end in the same state
        assert_eq!(a1.next_u64(), a2.next_u64(), "{rule:?}");
    }

    #[test]
    fn alignment_all_rules() {
        assert_aligned(ConnRule::OneToOne, 13, 13);
        assert_aligned(ConnRule::AllToAll, 5, 7);
        assert_aligned(ConnRule::FixedIndegree { k: 9 }, 31, 17);
        assert_aligned(ConnRule::FixedOutdegree { k: 4 }, 11, 23);
        assert_aligned(ConnRule::FixedTotalNumber { n: 101 }, 19, 29);
        assert_aligned(
            ConnRule::AssignedNodes(vec![(0, 1), (5, 2), (0, 0)]),
            7,
            3,
        );
        assert_aligned(
            ConnRule::TripletBucket {
                state: Rng::new(41).raw_state().0,
                k: 5,
                n_ranks: 4,
                sigma: 2,
            },
            9,
            6,
        );
    }

    /// Property test over randomized sizes/seeds: for every rule, the
    /// sources-only replay emits exactly the full stream's source sequence
    /// and ends the aligned generator in the same state — the invariant
    /// procedural regeneration (and the RemoteConnect source variant)
    /// leans on.
    #[test]
    fn replay_matches_generate_randomized() {
        let mut meta = Rng::new(0xCA5E);
        for round in 0..40 {
            let ns = 1 + meta.below(64) as usize;
            let nt = 1 + meta.below(64) as usize;
            let k = 1 + meta.below(8);
            let n = meta.below_u64(200);
            let n_ranks = 1 + meta.below(6);
            let rules = [
                ConnRule::OneToOne,
                ConnRule::AllToAll,
                ConnRule::FixedIndegree { k },
                ConnRule::FixedOutdegree { k },
                ConnRule::FixedTotalNumber { n },
                ConnRule::AssignedNodes(
                    (0..meta.below(32))
                        .map(|_| (meta.below(ns as u32), meta.below(nt as u32)))
                        .collect(),
                ),
                ConnRule::TripletBucket {
                    state: Rng::new(meta.next_u64()).raw_state().0,
                    k,
                    n_ranks,
                    sigma: meta.below(n_ranks),
                },
            ];
            for rule in rules {
                let (ns, nt) = match rule {
                    ConnRule::OneToOne => (ns, ns),
                    _ => (ns, nt),
                };
                let seed = meta.next_u64();
                let mut a1 = Rng::new(seed);
                let mut a2 = Rng::new(seed);
                let mut local = Rng::new(meta.next_u64());
                let mut gen_src = Vec::new();
                rule.generate(ns, nt, &mut a1, &mut local, |s, _| {
                    gen_src.push(s)
                });
                let mut rep_src = Vec::new();
                rule.replay_sources(ns, nt, &mut a2, |s| rep_src.push(s));
                assert_eq!(gen_src, rep_src, "round {round}: {rule:?}");
                assert_eq!(
                    a1.raw_state().0,
                    a2.raw_state().0,
                    "round {round}: aligned stream positions diverged: {rule:?}"
                );
                assert_eq!(
                    gen_src.len() as u64,
                    rule.conn_count(ns, nt),
                    "round {round}: {rule:?}"
                );
            }
        }
    }

    #[test]
    fn triplet_bucket_partitions_the_stream_across_sigmas() {
        // the union of every sigma's bucket is exactly the full triplet
        // stream: each target position gets k connections world-wide, and
        // every bucket is sorted (the AssignedNodes contract)
        let (ns, nt, k, n_ranks) = (37usize, 11usize, 6u32, 4u32);
        let state = Rng::new(77).raw_state().0;
        let mut total = 0u64;
        let mut indeg = vec![0u32; nt];
        for sigma in 0..n_ranks {
            let rule = ConnRule::TripletBucket {
                state,
                k,
                n_ranks,
                sigma,
            };
            let mut pairs = Vec::new();
            rule.generate(ns, nt, &mut Rng::new(1), &mut Rng::new(2), |s, t| {
                assert!((s as usize) < ns && (t as usize) < nt);
                pairs.push((s, t));
            });
            assert!(pairs.windows(2).all(|w| w[0] <= w[1]), "bucket sorted");
            for &(_, t) in &pairs {
                indeg[t as usize] += 1;
            }
            total += pairs.len() as u64;
            assert_eq!(pairs.len() as u64, rule.conn_count(ns, nt));
        }
        assert_eq!(total, k as u64 * nt as u64);
        assert!(indeg.iter().all(|&d| d == k));
    }

    #[test]
    fn triplet_bucket_ignores_passed_generators() {
        let rule = ConnRule::TripletBucket {
            state: Rng::new(5).raw_state().0,
            k: 3,
            n_ranks: 2,
            sigma: 0,
        };
        let collect = |a_seed: u64, l_seed: u64| {
            let mut out = Vec::new();
            let mut a = Rng::new(a_seed);
            let mut l = Rng::new(l_seed);
            rule.generate(10, 10, &mut a, &mut l, |s, t| out.push((s, t)));
            // neither generator may have been consumed
            assert_eq!(a.raw_state().0, Rng::new(a_seed).raw_state().0);
            assert_eq!(l.raw_state().0, Rng::new(l_seed).raw_state().0);
            out
        };
        assert_eq!(collect(1, 2), collect(900, 901));
    }

    #[test]
    fn local_rng_does_not_affect_alignment() {
        // different local generators must not change the source stream
        let rule = ConnRule::FixedTotalNumber { n: 50 };
        let collect = |local_seed: u64| {
            let mut a = Rng::new(5);
            let mut l = Rng::new(local_seed);
            let mut src = Vec::new();
            rule.generate(10, 10, &mut a, &mut l, |s, _| src.push(s));
            src
        };
        assert_eq!(collect(1), collect(999));
    }

    #[test]
    fn conn_counts_exact() {
        assert_eq!(ConnRule::OneToOne.conn_count(5, 5), 5);
        assert_eq!(ConnRule::AllToAll.conn_count(4, 6), 24);
        assert_eq!(ConnRule::FixedIndegree { k: 3 }.conn_count(100, 7), 21);
        assert_eq!(ConnRule::FixedOutdegree { k: 3 }.conn_count(7, 100), 21);
        assert_eq!(ConnRule::FixedTotalNumber { n: 42 }.conn_count(9, 9), 42);
    }

    #[test]
    fn generated_counts_match_conn_count() {
        for rule in [
            ConnRule::OneToOne,
            ConnRule::AllToAll,
            ConnRule::FixedIndegree { k: 5 },
            ConnRule::FixedOutdegree { k: 5 },
            ConnRule::FixedTotalNumber { n: 77 },
        ] {
            let (ns, nt) = (12, 12);
            let mut count = 0u64;
            rule.generate(ns, nt, &mut Rng::new(1), &mut Rng::new(2), |_, _| {
                count += 1
            });
            assert_eq!(count, rule.conn_count(ns, nt), "{rule:?}");
        }
    }

    #[test]
    fn fixed_indegree_gives_each_target_k_inputs() {
        let k = 8;
        let (ns, nt) = (50usize, 20usize);
        let mut indeg = vec![0u32; nt];
        ConnRule::FixedIndegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(3),
            &mut Rng::new(4),
            |s, t| {
                assert!((s as usize) < ns);
                indeg[t as usize] += 1;
            },
        );
        assert!(indeg.iter().all(|&d| d == k));
    }

    #[test]
    fn fixed_outdegree_gives_each_source_k_outputs() {
        let k = 6;
        let (ns, nt) = (15usize, 40usize);
        let mut outdeg = vec![0u32; ns];
        ConnRule::FixedOutdegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(3),
            &mut Rng::new(4),
            |s, t| {
                assert!((t as usize) < nt);
                outdeg[s as usize] += 1;
            },
        );
        assert!(outdeg.iter().all(|&d| d == k));
    }

    #[test]
    fn fixed_indegree_sources_roughly_uniform() {
        let (ns, nt, k) = (20usize, 500usize, 40u32);
        let mut hits = vec![0u32; ns];
        ConnRule::FixedIndegree { k }.generate(
            ns,
            nt,
            &mut Rng::new(8),
            &mut Rng::new(9),
            |s, _| hits[s as usize] += 1,
        );
        let expect = (nt as u32 * k) as f64 / ns as f64;
        for &h in &hits {
            assert!((h as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn may_skip_sources_classification() {
        assert!(!ConnRule::OneToOne.may_skip_sources());
        assert!(!ConnRule::AllToAll.may_skip_sources());
        assert!(!ConnRule::FixedOutdegree { k: 1 }.may_skip_sources());
        assert!(ConnRule::FixedIndegree { k: 1 }.may_skip_sources());
        assert!(ConnRule::FixedTotalNumber { n: 1 }.may_skip_sources());
    }

    #[test]
    fn xi_ratio() {
        // K_in * N_target / N_source (paper's heuristic expression)
        let r = ConnRule::FixedIndegree { k: 10 }.source_use_ratio(1000, 5);
        assert!((r - 0.05).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "one-to-one")]
    fn one_to_one_size_mismatch_panics() {
        ConnRule::OneToOne.generate(3, 4, &mut Rng::new(1), &mut Rng::new(2), |_, _| {});
    }
}
