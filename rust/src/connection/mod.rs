//! Connection substrate: node sets, synapse specifications, connection
//! rules ([`rules`]), the device-resident connection store ([`store`]) and
//! the offboard (host-built) baseline ([`offboard`]).

pub mod offboard;
pub mod procedural;
pub mod rules;
pub mod store;

pub use procedural::{
    ConnCallDescriptor, Connectivity, DescSources, DescriptorStore, ProceduralState,
};
pub use rules::ConnRule;
pub use store::Connections;

use crate::plasticity::StdpRule;
use crate::util::rng::Rng;

/// A set of node indexes used as sources or targets of a connect call.
///
/// The contiguous-range case is the paper's fast path (§0.3.3: "special
/// cases arise when s and/or t are sequences of consecutive integers").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeSet {
    Range { start: u32, n: u32 },
    List(Vec<u32>),
}

impl NodeSet {
    pub fn range(start: u32, n: u32) -> Self {
        NodeSet::Range { start, n }
    }

    pub fn len(&self) -> usize {
        match self {
            NodeSet::Range { n, .. } => *n as usize,
            NodeSet::List(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Node id at position `i`.
    #[inline]
    pub fn get(&self, i: u32) -> u32 {
        match self {
            NodeSet::Range { start, n } => {
                debug_assert!(i < *n);
                start + i
            }
            NodeSet::List(v) => v[i as usize],
        }
    }

    /// Whether positions are already ordered by node id (ranges are; lists
    /// only if sorted).
    pub fn is_sorted(&self) -> bool {
        match self {
            NodeSet::Range { .. } => true,
            NodeSet::List(v) => v.windows(2).all(|w| w[0] < w[1]),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.len() as u32).map(move |i| self.get(i))
    }
}

/// Scalar distribution for synaptic parameters.
#[derive(Clone, Copy, Debug)]
pub enum Dist {
    Const(f64),
    /// normal with optional clipping
    Normal { mean: f64, sd: f64 },
    Uniform { lo: f64, hi: f64 },
}

impl Dist {
    pub fn draw(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Const(x) => x,
            Dist::Normal { mean, sd } => rng.normal_ms(mean, sd),
            Dist::Uniform { lo, hi } => rng.uniform_range(lo, hi),
        }
    }

    /// Whether drawing consumes randomness (Const does not).
    pub fn is_random(&self) -> bool {
        !matches!(self, Dist::Const(_))
    }
}

/// Synapse specification for a connect call.
///
/// Weights/delays are drawn with the *local* generator of the target
/// process — the aligned per-(σ,τ) generator is used exclusively for source
/// neuron indexes (§0.3.1), so synaptic parameter draws never perturb map
/// alignment.
///
/// An optional [`StdpRule`] makes every synapse of the call plastic: the
/// rule is registered once in the connection store and referenced per
/// connection, and the [`crate::plasticity`] subsystem evolves the weights
/// during propagation (DESIGN.md §12). Attaching a rule consumes no
/// randomness, so a plastic build constructs the exact same network as its
/// static twin.
#[derive(Clone, Copy, Debug)]
pub struct SynSpec {
    pub weight: Dist,
    /// transmission delay in time steps (≥ 1)
    pub delay: Dist,
    /// receptor port: 0 = excitatory, 1 = inhibitory
    pub port: u8,
    /// trace-based STDP rule shared by every synapse of this call
    /// (`None` = static)
    pub stdp: Option<StdpRule>,
}

impl SynSpec {
    pub fn new(weight: f64, delay_steps: u32) -> Self {
        SynSpec {
            weight: Dist::Const(weight),
            delay: Dist::Const(delay_steps as f64),
            port: if weight < 0.0 { 1 } else { 0 },
            stdp: None,
        }
    }

    /// Attach a plasticity rule (builder style).
    pub fn with_stdp(mut self, rule: StdpRule) -> Self {
        self.stdp = Some(rule);
        self
    }

    pub fn draw(&self, rng: &mut Rng) -> (f32, u16) {
        let w = self.weight.draw(rng) as f32;
        let d = self.delay.draw(rng).round().max(1.0) as u16;
        (w, d)
    }

    /// Conservative lower bound on any delay `draw` can return (draws are
    /// clamped to ≥ 1 step, so the bound is ≥ 1). Because model scripts
    /// are SPMD, folding this bound over every `RemoteConnect` call yields
    /// the same minimum remote delay on every rank without communication —
    /// the exchange-batching interval bound of DESIGN.md §11.
    pub fn min_delay_steps(&self) -> u16 {
        let lo = match self.delay {
            Dist::Const(x) => x,
            Dist::Uniform { lo, .. } => lo,
            // unbounded below; the clamp in draw() makes 1 the true bound
            Dist::Normal { .. } => 1.0,
        };
        let lo = lo.round().max(1.0);
        if lo >= f64::from(u16::MAX) {
            u16::MAX
        } else {
            lo as u16
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nodeset_range_access() {
        let s = NodeSet::range(10, 5);
        assert_eq!(s.len(), 5);
        assert_eq!(s.get(0), 10);
        assert_eq!(s.get(4), 14);
        assert!(s.is_sorted());
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![10, 11, 12, 13, 14]);
    }

    #[test]
    fn nodeset_list_access() {
        let s = NodeSet::List(vec![7, 3, 9]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.get(1), 3);
        assert!(!s.is_sorted());
        assert!(NodeSet::List(vec![1, 5, 8]).is_sorted());
    }

    #[test]
    fn dist_const_is_deterministic() {
        let mut rng = Rng::new(1);
        let d = Dist::Const(2.5);
        assert!(!d.is_random());
        assert_eq!(d.draw(&mut rng), 2.5);
        // no randomness consumed
        let mut rng2 = Rng::new(1);
        assert_eq!(rng.next_u64(), rng2.next_u64());
    }

    #[test]
    fn synspec_min_delay_bound_holds_for_draws() {
        let mut rng = Rng::new(9);
        for syn in [
            SynSpec::new(1.0, 15),
            SynSpec {
                weight: Dist::Const(1.0),
                delay: Dist::Uniform { lo: 3.2, hi: 9.0 },
                port: 0,
                stdp: None,
            },
            SynSpec {
                weight: Dist::Const(1.0),
                delay: Dist::Normal { mean: 4.0, sd: 2.0 },
                port: 0,
                stdp: None,
            },
        ] {
            let bound = syn.min_delay_steps();
            assert!(bound >= 1);
            for _ in 0..500 {
                let (_, d) = syn.draw(&mut rng);
                assert!(d >= bound, "draw {d} below bound {bound}");
            }
        }
        assert_eq!(SynSpec::new(1.0, 15).min_delay_steps(), 15);
    }

    #[test]
    fn dist_normal_statistics() {
        let mut rng = Rng::new(2);
        let d = Dist::Normal { mean: 5.0, sd: 2.0 };
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| d.draw(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05);
    }

    #[test]
    fn synspec_delay_clamped_to_one_step() {
        let mut rng = Rng::new(3);
        let s = SynSpec {
            weight: Dist::Const(1.0),
            delay: Dist::Const(0.0),
            port: 0,
            stdp: None,
        };
        let (_, d) = s.draw(&mut rng);
        assert_eq!(d, 1);
    }

    #[test]
    fn synspec_port_inferred_from_sign() {
        assert_eq!(SynSpec::new(1.0, 1).port, 0);
        assert_eq!(SynSpec::new(-4.0, 1).port, 1);
    }
}
