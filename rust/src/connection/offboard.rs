//! *Offboard* construction baseline (Fig. 3).
//!
//! Before the onboard method of this paper, NEST GPU built the network in
//! CPU memory and transferred it to the GPU afterwards ([15], [30]). This
//! module reproduces that baseline so the Fig. 3 comparison can be
//! regenerated: connections are accumulated as a host-side
//! array-of-structures (the layout used by the CPU code path), then
//! *transferred* — converted chunk-by-chunk into the device SoA store, with
//! the host staging accounted in host memory and the extra copy pass being
//! the measured cost of the offboard path.

use super::store::Connections;
use crate::memory::{MemKind, Tracker};

/// One host-side connection record (AoS, as built by the CPU path).
#[derive(Clone, Copy, Debug)]
pub struct HostConn {
    pub source: u32,
    pub target: u32,
    pub weight: f32,
    pub delay: u16,
    pub port: u8,
}

const HOST_CONN_BYTES: u64 = std::mem::size_of::<HostConn>() as u64;

/// Transfer chunk: 1 MiB of records per host->device copy, mimicking the
/// staged cudaMemcpy of the offboard implementation.
pub const TRANSFER_CHUNK: usize = 65_536;

/// Host-side builder used by the offboard path.
pub struct OffboardBuilder {
    conns: Vec<HostConn>,
    tracked: u64,
}

impl OffboardBuilder {
    pub fn new() -> Self {
        Self {
            conns: Vec::new(),
            tracked: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    #[inline]
    pub fn push(&mut self, c: HostConn, tr: &mut Tracker) {
        if self.conns.len() == self.conns.capacity() {
            let new_cap = (self.conns.capacity() * 2).max(1024);
            let new_bytes = new_cap as u64 * HOST_CONN_BYTES;
            tr.realloc(MemKind::Host, self.tracked, new_bytes);
            self.tracked = new_bytes;
            self.conns.reserve_exact(new_cap - self.conns.len());
        }
        self.conns.push(c);
    }

    /// Transfer all host records into the device store in chunks, freeing
    /// the host staging afterwards. Returns the number transferred.
    ///
    /// As in the historical CPU path ([15], [30]): the host first
    /// *organizes* the AoS (comparison sort by source — the GPU path defers
    /// this to the device radix sort at preparation), then copies it over
    /// in staged chunks. Both passes are the measured offboard overhead.
    pub fn transfer(mut self, dev: &mut Connections, tr: &mut Tracker) -> usize {
        let n = self.conns.len();
        // host-side organization pass (the old CPU code path)
        self.conns
            .sort_by(|a, b| a.source.cmp(&b.source).then(a.target.cmp(&b.target)));
        // device-side staging buffer for one chunk (transient)
        let chunk_bytes = (TRANSFER_CHUNK.min(n.max(1)) as u64) * HOST_CONN_BYTES;
        tr.alloc(MemKind::Device, chunk_bytes);
        tr.transient_events += 1;
        for chunk in self.conns.chunks(TRANSFER_CHUNK) {
            // one extra full pass over the data (host AoS -> staging ->
            // device SoA)
            let staged: Vec<HostConn> = chunk.to_vec();
            for c in staged {
                dev.push(c.source, c.target, c.weight, c.delay, c.port, tr);
            }
        }
        tr.free(MemKind::Device, chunk_bytes);
        tr.free(MemKind::Host, self.tracked);
        self.tracked = 0;
        self.conns = Vec::new();
        n
    }
}

impl Default for OffboardBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_preserves_content_and_sorts_by_source() {
        let mut tr = Tracker::new();
        let mut b = OffboardBuilder::new();
        for i in 0..100u32 {
            b.push(
                HostConn {
                    source: i % 7,
                    target: i,
                    weight: i as f32,
                    delay: 1 + (i % 3) as u16,
                    port: (i % 2) as u8,
                },
                &mut tr,
            );
        }
        let mut dev = Connections::new();
        let n = b.transfer(&mut dev, &mut tr);
        assert_eq!(n, 100);
        assert_eq!(dev.len(), 100);
        // host path pre-sorts by source (the historical CPU organization)
        assert!(dev.source.as_slice().windows(2).all(|w| w[0] <= w[1]));
        // content preserved: every (target, weight) pair still present
        let mut pairs: Vec<(u32, u32)> = dev
            .target
            .as_slice()
            .iter()
            .map(|&t| (t, t))
            .collect();
        pairs.sort_unstable();
        assert_eq!(pairs.len(), 100);
        assert!(pairs.iter().enumerate().all(|(i, &(t, _))| t == i as u32));
    }

    #[test]
    fn host_memory_freed_after_transfer() {
        let mut tr = Tracker::new();
        let mut b = OffboardBuilder::new();
        for i in 0..10_000u32 {
            b.push(
                HostConn {
                    source: i,
                    target: i,
                    weight: 0.0,
                    delay: 1,
                    port: 0,
                },
                &mut tr,
            );
        }
        assert!(tr.current(MemKind::Host) > 0);
        let host_peak = tr.peak(MemKind::Host);
        let mut dev = Connections::new();
        b.transfer(&mut dev, &mut tr);
        assert_eq!(tr.current(MemKind::Host), 0, "host staging must be freed");
        assert!(tr.peak(MemKind::Host) >= host_peak);
        assert_eq!(tr.current(MemKind::Device), dev.device_bytes());
    }

    #[test]
    fn chunked_transfer_spans_multiple_chunks() {
        let mut tr = Tracker::new();
        let mut b = OffboardBuilder::new();
        let n = TRANSFER_CHUNK + 17;
        for i in 0..n as u32 {
            b.push(
                HostConn {
                    source: 0,
                    target: i,
                    weight: 0.0,
                    delay: 1,
                    port: 0,
                },
                &mut tr,
            );
        }
        let mut dev = Connections::new();
        assert_eq!(b.transfer(&mut dev, &mut tr), n);
        assert_eq!(dev.len(), n);
    }
}
