//! Procedural connectivity: regenerate static synapses from RNG state at
//! spike time instead of storing them (DESIGN.md §16).
//!
//! The construction algorithm is bit-reproducible from its seeds: every
//! connect call forks a source-position generator from the rank's
//! construction stream (or consumes the aligned per-(σ,τ) stream for
//! remote calls) and draws synaptic parameters from the local stream in a
//! fixed two-phase order — first the full `(source_pos, target_pos)` pair
//! stream, then one `SynSpec::draw` per pair. Capturing the raw states of
//! both generators *before* the call therefore suffices to rematerialize
//! the call's connections, bit-identically, at any later time.
//!
//! In procedural mode the simulator records each static connect call as a
//! [`ConnCallDescriptor`] (rule + node sets + synapse spec + the two
//! captured RNG states) instead of pushing rows into
//! [`crate::connection::Connections`]. When a source neuron spikes, the
//! descriptors covering it are rematerialized on demand into a
//! [`DescFanout`] — the same per-node, delay-merged run layout the
//! materialized [`crate::engine::delivery::DeliveryPlan`] uses — and the
//! fanout is accumulated straight into the ring buffers. A byte-capped
//! LRU [`FanoutCache`] memoizes regenerated fanouts; because a fanout is a
//! pure function of its descriptor, cache policy cannot affect results.
//!
//! Plastic (STDP) synapses mutate their weights and therefore stay fully
//! materialized; so do device-sourced calls (Poisson input is delivered
//! from the materialized plan every step, not at spike events).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::connection::{ConnRule, Dist, NodeSet, SynSpec};
use crate::memory::{MemKind, Tracker};
use crate::node::RingBuffers;
use crate::snapshot::{Decoder, Encoder};
use crate::util::lru::TickLru;
use crate::util::rng::Rng;

/// How static connectivity is held between construction and delivery.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Connectivity {
    /// every synapse stored in `Connections` + the `DeliveryPlan`
    #[default]
    Materialized,
    /// static calls stored as descriptors, fanouts regenerated on spike
    Procedural,
}

impl Connectivity {
    pub fn name(self) -> &'static str {
        match self {
            Connectivity::Materialized => "materialized",
            Connectivity::Procedural => "procedural",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "materialized" => Some(Connectivity::Materialized),
            "procedural" => Some(Connectivity::Procedural),
            _ => None,
        }
    }
}

/// Where a descriptor's source positions resolve to node ids.
#[derive(Clone, Debug)]
pub enum DescSources {
    /// local connect call: position `i` is `set.get(i)`
    Local(NodeSet),
    /// remote target-side call: the `l` array of §0.3.1 — position `i` is
    /// the image node `l[i]` (`u32::MAX` marks positions the rule never
    /// emitted, which by construction are never queried)
    RemoteImages(Vec<u32>),
}

impl DescSources {
    pub fn len(&self) -> usize {
        match self {
            DescSources::Local(s) => s.len(),
            DescSources::RemoteImages(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Node id of source position `sp`.
    #[inline]
    pub fn node_at(&self, sp: u32) -> u32 {
        match self {
            DescSources::Local(s) => s.get(sp),
            DescSources::RemoteImages(l) => {
                let node = l[sp as usize];
                debug_assert!(node != u32::MAX, "unused l position queried");
                node
            }
        }
    }

    pub fn is_remote(&self) -> bool {
        matches!(self, DescSources::RemoteImages(_))
    }
}

/// One recorded static connect call: everything needed to rematerialize
/// its connections bit-identically (DESIGN.md §16).
#[derive(Clone, Debug)]
pub struct ConnCallDescriptor {
    pub sources: DescSources,
    pub targets: NodeSet,
    pub rule: ConnRule,
    pub syn: SynSpec,
    /// raw xoshiro state of the source-position generator at call time
    /// (the `Rng::new(src_seed)` fork for local calls, the aligned
    /// `RNG[σ,τ]` stream for remote calls), captured before `generate`
    pub src_state: [u64; 4],
    pub src_gauss: Option<f64>,
    /// raw state of the target rank's private stream, captured before
    /// `generate` (it feeds target-position draws *and* parameter draws)
    pub local_state: [u64; 4],
    pub local_gauss: Option<f64>,
    /// exact connection count of the call (known at record time)
    pub n_conns: u64,
}

impl ConnCallDescriptor {
    /// Resident bytes of the descriptor (struct + owned heap).
    pub fn bytes(&self) -> u64 {
        let heap = match &self.sources {
            DescSources::Local(NodeSet::List(v)) => v.len() * 4,
            DescSources::Local(NodeSet::Range { .. }) => 0,
            DescSources::RemoteImages(l) => l.len() * 4,
        } + match &self.targets {
            NodeSet::List(v) => v.len() * 4,
            NodeSet::Range { .. } => 0,
        } + match &self.rule {
            ConnRule::AssignedNodes(pairs) => pairs.len() * 8,
            _ => 0,
        };
        (std::mem::size_of::<Self>() + heap) as u64
    }
}

// ---------------------------------------------------------------------------
// descriptor codec (snapshot v4 PROC section)

fn encode_dist(d: &Dist, e: &mut Encoder) {
    match *d {
        Dist::Const(x) => {
            e.u8(0);
            e.f64(x);
        }
        Dist::Normal { mean, sd } => {
            e.u8(1);
            e.f64(mean);
            e.f64(sd);
        }
        Dist::Uniform { lo, hi } => {
            e.u8(2);
            e.f64(lo);
            e.f64(hi);
        }
    }
}

fn decode_dist(d: &mut Decoder) -> Result<Dist> {
    Ok(match d.u8()? {
        0 => Dist::Const(d.f64()?),
        1 => Dist::Normal {
            mean: d.f64()?,
            sd: d.f64()?,
        },
        2 => Dist::Uniform {
            lo: d.f64()?,
            hi: d.f64()?,
        },
        tag => bail!("unknown distribution tag {tag} in descriptor"),
    })
}

fn encode_nodeset(s: &NodeSet, e: &mut Encoder) {
    match s {
        NodeSet::Range { start, n } => {
            e.u8(0);
            e.u32(*start);
            e.u32(*n);
        }
        NodeSet::List(v) => {
            e.u8(1);
            e.slice_u32(v);
        }
    }
}

fn decode_nodeset(d: &mut Decoder) -> Result<NodeSet> {
    Ok(match d.u8()? {
        0 => NodeSet::Range {
            start: d.u32()?,
            n: d.u32()?,
        },
        1 => NodeSet::List(d.vec_u32()?),
        tag => bail!("unknown node-set tag {tag} in descriptor"),
    })
}

fn encode_rule(r: &ConnRule, e: &mut Encoder) {
    match r {
        ConnRule::OneToOne => e.u8(0),
        ConnRule::AllToAll => e.u8(1),
        ConnRule::FixedIndegree { k } => {
            e.u8(2);
            e.u32(*k);
        }
        ConnRule::FixedOutdegree { k } => {
            e.u8(3);
            e.u32(*k);
        }
        ConnRule::FixedTotalNumber { n } => {
            e.u8(4);
            e.u64(*n);
        }
        ConnRule::AssignedNodes(pairs) => {
            e.u8(5);
            e.seq_len(pairs.len());
            for &(i, j) in pairs {
                e.u32(i);
                e.u32(j);
            }
        }
        ConnRule::TripletBucket {
            state,
            k,
            n_ranks,
            sigma,
        } => {
            e.u8(6);
            for w in state {
                e.u64(*w);
            }
            e.u32(*k);
            e.u32(*n_ranks);
            e.u32(*sigma);
        }
    }
}

fn decode_rule(d: &mut Decoder) -> Result<ConnRule> {
    Ok(match d.u8()? {
        0 => ConnRule::OneToOne,
        1 => ConnRule::AllToAll,
        2 => ConnRule::FixedIndegree { k: d.u32()? },
        3 => ConnRule::FixedOutdegree { k: d.u32()? },
        4 => ConnRule::FixedTotalNumber { n: d.u64()? },
        5 => {
            let n = d.seq_len(8)?;
            let mut pairs = Vec::with_capacity(n);
            for _ in 0..n {
                pairs.push((d.u32()?, d.u32()?));
            }
            ConnRule::AssignedNodes(pairs)
        }
        6 => ConnRule::TripletBucket {
            state: [d.u64()?, d.u64()?, d.u64()?, d.u64()?],
            k: d.u32()?,
            n_ranks: d.u32()?,
            sigma: d.u32()?,
        },
        tag => bail!("unknown connection-rule tag {tag} in descriptor"),
    })
}

fn encode_raw_rng(s: &[u64; 4], gauss: Option<f64>, e: &mut Encoder) {
    for w in s {
        e.u64(*w);
    }
    match gauss {
        None => e.bool(false),
        Some(z) => {
            e.bool(true);
            e.f64(z);
        }
    }
}

fn decode_raw_rng(d: &mut Decoder) -> Result<([u64; 4], Option<f64>)> {
    let s = [d.u64()?, d.u64()?, d.u64()?, d.u64()?];
    let gauss = if d.bool()? { Some(d.f64()?) } else { None };
    Ok((s, gauss))
}

fn encode_descriptor(desc: &ConnCallDescriptor, e: &mut Encoder) {
    debug_assert!(
        desc.syn.stdp.is_none(),
        "plastic calls must stay materialized, never become descriptors"
    );
    match &desc.sources {
        DescSources::Local(s) => {
            e.u8(0);
            encode_nodeset(s, e);
        }
        DescSources::RemoteImages(l) => {
            e.u8(1);
            e.slice_u32(l);
        }
    }
    encode_nodeset(&desc.targets, e);
    encode_rule(&desc.rule, e);
    encode_dist(&desc.syn.weight, e);
    encode_dist(&desc.syn.delay, e);
    e.u8(desc.syn.port);
    encode_raw_rng(&desc.src_state, desc.src_gauss, e);
    encode_raw_rng(&desc.local_state, desc.local_gauss, e);
    e.u64(desc.n_conns);
}

fn decode_descriptor(d: &mut Decoder) -> Result<ConnCallDescriptor> {
    let sources = match d.u8()? {
        0 => DescSources::Local(decode_nodeset(d)?),
        1 => DescSources::RemoteImages(d.vec_u32()?),
        tag => bail!("unknown descriptor-sources tag {tag}"),
    };
    let targets = decode_nodeset(d)?;
    let rule = decode_rule(d)?;
    let weight = decode_dist(d)?;
    let delay = decode_dist(d)?;
    let port = d.u8()?;
    let (src_state, src_gauss) = decode_raw_rng(d)?;
    let (local_state, local_gauss) = decode_raw_rng(d)?;
    let n_conns = d.u64()?;
    Ok(ConnCallDescriptor {
        sources,
        targets,
        rule,
        syn: SynSpec {
            weight,
            delay,
            port,
            stdp: None,
        },
        src_state,
        src_gauss,
        local_state,
        local_gauss,
        n_conns,
    })
}

// ---------------------------------------------------------------------------
// descriptor store

/// All recorded connect calls of a rank, plus the node → descriptor CSR
/// built at prepare time. Descriptors are looked up in *creation order*
/// per node — that order is what makes procedural delivery bit-identical
/// to the materialized plan (see [`DescFanout`]).
#[derive(Default)]
pub struct DescriptorStore {
    descs: Vec<ConnCallDescriptor>,
    /// CSR offsets: descriptors covering node `v` are
    /// `node_descs[node_first[v]..node_first[v+1]]`, ascending by id
    node_first: Vec<u32>,
    node_descs: Vec<u32>,
    desc_bytes: u64,
    index_bytes: u64,
    total_conns: u64,
}

impl DescriptorStore {
    pub fn len(&self) -> usize {
        self.descs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.descs.is_empty()
    }

    pub fn desc(&self, id: u32) -> &ConnCallDescriptor {
        &self.descs[id as usize]
    }

    /// Total connections across all descriptors (the procedural share of
    /// `SimResult::n_connections`).
    pub fn total_conns(&self) -> u64 {
        self.total_conns
    }

    /// Resident bytes: descriptors + the node → descriptor index.
    pub fn device_bytes(&self) -> u64 {
        self.desc_bytes + self.index_bytes
    }

    /// Record a call; returns its descriptor id.
    pub fn push(&mut self, desc: ConnCallDescriptor, tr: &mut Tracker) -> u32 {
        let id = self.descs.len() as u32;
        let b = desc.bytes();
        tr.alloc(MemKind::Device, b);
        self.desc_bytes += b;
        self.total_conns += desc.n_conns;
        self.descs.push(desc);
        id
    }

    fn covered_nodes(desc: &ConnCallDescriptor, mut f: impl FnMut(u32)) {
        match &desc.sources {
            DescSources::Local(s) => {
                for node in s.iter() {
                    f(node);
                }
            }
            DescSources::RemoteImages(l) => {
                for &node in l {
                    if node != u32::MAX {
                        f(node);
                    }
                }
            }
        }
    }

    /// Build the node → descriptor CSR (call once, after construction or
    /// after a snapshot restore). Per node, descriptor ids come out
    /// ascending — i.e. in creation order.
    pub fn build_index(&mut self, n_nodes: u32, tr: &mut Tracker) {
        let mut counts = vec![0u32; n_nodes as usize + 1];
        for desc in &self.descs {
            Self::covered_nodes(desc, |node| counts[node as usize + 1] += 1);
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let total = counts[n_nodes as usize] as usize;
        let mut node_descs = vec![0u32; total];
        let mut cursor = counts.clone();
        for (id, desc) in self.descs.iter().enumerate() {
            Self::covered_nodes(desc, |node| {
                node_descs[cursor[node as usize] as usize] = id as u32;
                cursor[node as usize] += 1;
            });
        }
        self.node_first = counts;
        self.node_descs = node_descs;
        let b = ((self.node_first.len() + self.node_descs.len()) * 4) as u64;
        tr.alloc(MemKind::Device, b);
        self.index_bytes = b;
    }

    /// Index range into the descriptor-id array for `node` (empty when the
    /// node is covered by no descriptor or the index is not built).
    #[inline]
    pub fn desc_span(&self, node: u32) -> (usize, usize) {
        let v = node as usize;
        if v + 1 >= self.node_first.len() {
            return (0, 0);
        }
        (self.node_first[v] as usize, self.node_first[v + 1] as usize)
    }

    #[inline]
    pub fn node_desc(&self, idx: usize) -> u32 {
        self.node_descs[idx]
    }

    /// Descriptor ids covering `node`, in creation order.
    pub fn descs_of(&self, node: u32) -> &[u32] {
        let (lo, hi) = self.desc_span(node);
        &self.node_descs[lo..hi]
    }

    /// Minimum possible delay over remote-origin descriptors (folds into
    /// the exchange-batching bound exactly like materialized image
    /// connections do).
    pub fn min_remote_delay(&self) -> Option<u16> {
        self.descs
            .iter()
            .filter(|d| d.sources.is_remote())
            .map(|d| d.syn.min_delay_steps())
            .min()
    }

    /// Estimated bytes a full materialization of every fanout would take —
    /// the reference the cache budget is derived from.
    pub fn est_fanout_bytes(&self) -> u64 {
        // per connection: dest u32 + weight f32; runs/node directory are
        // secondary and covered by the same estimate's slack
        self.total_conns * 8
    }

    pub fn snapshot_encode(&self, e: &mut Encoder) {
        e.seq_len(self.descs.len());
        for desc in &self.descs {
            encode_descriptor(desc, e);
        }
    }

    /// Decode descriptors (the CSR index is derived state: call
    /// [`DescriptorStore::build_index`] after).
    pub fn snapshot_decode(d: &mut Decoder, tr: &mut Tracker) -> Result<Self> {
        let n = d.seq_len(1)?;
        let mut store = Self::default();
        for _ in 0..n {
            let desc = decode_descriptor(d)?;
            store.push(desc, tr);
        }
        Ok(store)
    }
}

// ---------------------------------------------------------------------------
// fanout regeneration

/// One descriptor's rematerialized fanout, in the materialized plan's
/// delivery layout: per covered source node, delay-merged runs of
/// port-baked destinations and weights.
///
/// Bit-identity argument (DESIGN.md §16): a ring-buffer cell is addressed
/// by (slot, destination); f32 accumulation order only matters *within* a
/// cell. The materialized plan stable-sorts each node's connections by
/// (delay, port), so same-cell entries keep creation order — descriptor
/// order, then `generate` emission order. Regeneration reproduces exactly
/// that: descriptors are walked in creation order, and each fanout is
/// stable-sorted by (node, delay, port), preserving emission order within
/// equal keys. Direct accumulation therefore adds every cell's terms in
/// the same sequence the queue drain would.
#[derive(Clone, Debug, Default)]
pub struct DescFanout {
    dest: Vec<u32>,
    weight: Vec<f32>,
    /// delay-merged runs `(delay, start, end)` into `dest`/`weight`
    runs: Vec<(u16, u32, u32)>,
    /// per covered node `(node, run_lo, run_hi)`, ascending by node
    node_runs: Vec<(u32, u32, u32)>,
}

impl DescFanout {
    pub fn bytes(&self) -> u64 {
        (self.dest.len() * 4
            + self.weight.len() * 4
            + self.runs.len() * std::mem::size_of::<(u16, u32, u32)>()
            + self.node_runs.len() * std::mem::size_of::<(u32, u32, u32)>()) as u64
    }

    pub fn n_entries(&self) -> usize {
        self.dest.len()
    }

    /// Accumulate `node`'s runs into the ring buffers, matching the
    /// delivery queue's drain arithmetic exactly (`+= w` for mult 1, else
    /// `+= w * mult`). `shift` is the exchange-batching lag shift (0 for
    /// the local plane).
    pub fn deliver(&self, node: u32, mult: u16, shift: i32, rb: &mut RingBuffers) {
        let Ok(ix) = self.node_runs.binary_search_by_key(&node, |&(n, _, _)| n) else {
            return;
        };
        let (_, lo, hi) = self.node_runs[ix];
        for &(delay, start, end) in &self.runs[lo as usize..hi as usize] {
            let d = delay as i32 + shift;
            debug_assert!(
                d >= 1 && rb.supports(d as u16),
                "shifted delay {d} outside ring of {} slots",
                rb.n_slots()
            );
            let slot = rb.slot_of(d as u16);
            let row = rb.row_mut(slot);
            let dests = &self.dest[start as usize..end as usize];
            let weights = &self.weight[start as usize..end as usize];
            if mult == 1 {
                for (&dst, &w) in dests.iter().zip(weights) {
                    row[dst as usize] += w;
                }
            } else {
                let m = mult as f32;
                for (&dst, &w) in dests.iter().zip(weights) {
                    row[dst as usize] += w * m;
                }
            }
        }
    }
}

/// entry during fanout construction: (source node, delay, port, dest, w)
type Entry = (u32, u16, u8, u32, f32);

/// Rematerialize a descriptor's connections. Replays the exact two-phase
/// order of construction — the full pair stream first, then one parameter
/// draw per pair — from the captured RNG states, then groups by source
/// node with the plan's stable (delay, port) ordering.
pub fn build_fanout(
    desc: &ConnCallDescriptor,
    state_lut: &[u32],
    n_state: u32,
    pairs: &mut Vec<(u32, u32)>,
    entries: &mut Vec<Entry>,
) -> DescFanout {
    let mut src = Rng::from_raw_state(desc.src_state, desc.src_gauss);
    let mut local = Rng::from_raw_state(desc.local_state, desc.local_gauss);
    pairs.clear();
    pairs.reserve(desc.n_conns as usize);
    desc.rule.generate(
        desc.sources.len(),
        desc.targets.len(),
        &mut src,
        &mut local,
        |sp, tp| pairs.push((sp, tp)),
    );
    debug_assert_eq!(pairs.len() as u64, desc.n_conns);
    entries.clear();
    entries.reserve(pairs.len());
    for &(sp, tp) in pairs.iter() {
        let (w, delay) = desc.syn.draw(&mut local);
        let node = desc.sources.node_at(sp);
        let state = state_lut[desc.targets.get(tp) as usize];
        debug_assert!(state != u32::MAX, "descriptor targets a non-neuron node");
        let dest = u32::from(desc.syn.port) * n_state + state;
        entries.push((node, delay, desc.syn.port, dest, w));
    }
    // stable: same-cell entries keep generate order (the bit-identity
    // invariant above)
    entries.sort_by_key(|&(node, delay, port, _, _)| (node, delay, port));

    let mut fo = DescFanout::default();
    fo.dest.reserve(entries.len());
    fo.weight.reserve(entries.len());
    let mut i = 0;
    while i < entries.len() {
        let node = entries[i].0;
        let run_lo = fo.runs.len() as u32;
        while i < entries.len() && entries[i].0 == node {
            let (_, delay, _, dest, w) = entries[i];
            let pos = fo.dest.len() as u32;
            fo.dest.push(dest);
            fo.weight.push(w);
            let cur_runs = fo.runs.len() as u32;
            match fo.runs.last_mut() {
                Some(r) if cur_runs > run_lo && r.0 == delay => r.2 = pos + 1,
                _ => fo.runs.push((delay, pos, pos + 1)),
            }
            i += 1;
        }
        fo.node_runs.push((node, run_lo, fo.runs.len() as u32));
    }
    fo
}

// ---------------------------------------------------------------------------
// fanout cache

/// Byte-capped memo of regenerated fanouts, keyed by descriptor id.
///
/// Deterministic by construction: a dense slot per descriptor (no
/// hashing) and strict tick-LRU eviction ([`TickLru`]) — and since a
/// fanout is a pure function of its descriptor, even a *wrong* eviction
/// choice could only cost time, never correctness.
pub struct FanoutCache {
    lru: TickLru<DescFanout>,
}

impl FanoutCache {
    /// Floor so tiny models still get a working cache.
    pub const MIN_CAP_BYTES: u64 = 64 * 1024;

    /// Budget policy: a quarter of the estimated full-materialization
    /// bytes, so the resident procedural footprint (descriptors + cache)
    /// stays well under the ≥5× reduction bar while hot fanouts persist.
    pub fn cap_for(est_fanout_bytes: u64) -> u64 {
        (est_fanout_bytes / 4).max(Self::MIN_CAP_BYTES)
    }

    pub fn new(n_descs: usize, cap: u64) -> Self {
        Self {
            lru: TickLru::new(n_descs, cap),
        }
    }

    pub fn cap_bytes(&self) -> u64 {
        self.lru.cap_bytes()
    }

    pub fn used_bytes(&self) -> u64 {
        self.lru.used_bytes()
    }

    /// Cached fanout for a descriptor, refreshing its LRU tick.
    pub fn touch(&mut self, id: u32) -> Option<&DescFanout> {
        self.lru.touch(id as usize)
    }

    /// Insert a freshly regenerated fanout, evicting least-recently-used
    /// entries until it fits. A fanout larger than the whole budget is
    /// dropped (it was already delivered from; only reuse is lost).
    pub fn admit(&mut self, id: u32, fo: DescFanout, tr: &mut Tracker) {
        let b = fo.bytes();
        if self
            .lru
            .admit(id as usize, fo, b, |_, _, ob| tr.free(MemKind::Device, ob))
        {
            tr.alloc(MemKind::Device, b);
        }
    }
}

// ---------------------------------------------------------------------------
// per-rank procedural state

/// Descriptor store + fanout cache + regeneration statistics: the
/// procedural counterpart of the materialized `DeliveryPlan`.
pub struct ProceduralState {
    pub store: DescriptorStore,
    cache: FanoutCache,
    /// fanout served from cache
    pub cache_hits: u64,
    /// fanout rematerialized
    pub cache_misses: u64,
    /// wall-clock nanoseconds spent rematerializing (the `regen` phase)
    pub regen_ns: u64,
    scratch_pairs: Vec<(u32, u32)>,
    scratch_entries: Vec<Entry>,
}

impl ProceduralState {
    pub fn new(store: DescriptorStore) -> Self {
        let cache = FanoutCache::new(0, FanoutCache::MIN_CAP_BYTES);
        Self {
            store,
            cache,
            cache_hits: 0,
            cache_misses: 0,
            regen_ns: 0,
            scratch_pairs: Vec::new(),
            scratch_entries: Vec::new(),
        }
    }

    /// Build the node index and size the cache (prepare/restore time).
    pub fn prepare(&mut self, n_nodes: u32, tr: &mut Tracker) {
        self.store.build_index(n_nodes, tr);
        self.cache = FanoutCache::new(
            self.store.len(),
            FanoutCache::cap_for(self.store.est_fanout_bytes()),
        );
    }

    pub fn cache_used_bytes(&self) -> u64 {
        self.cache.used_bytes()
    }

    /// Deliver `node`'s procedural fanout into the ring buffers:
    /// descriptors in creation order, each fanout cached or rematerialized
    /// on the spot. `shift` is 0 for the local plane and the exchange
    /// lag shift (`lag + 1 − interval_len`) for the remote plane.
    #[allow(clippy::too_many_arguments)]
    pub fn deliver(
        &mut self,
        node: u32,
        mult: u16,
        shift: i32,
        state_lut: &[u32],
        n_state: u32,
        rb: &mut RingBuffers,
        tr: &mut Tracker,
    ) {
        let (lo, hi) = self.store.desc_span(node);
        for idx in lo..hi {
            let di = self.store.node_desc(idx);
            if let Some(fo) = self.cache.touch(di) {
                self.cache_hits += 1;
                fo.deliver(node, mult, shift, rb);
                continue;
            }
            self.cache_misses += 1;
            let t0 = Instant::now();
            let fo = build_fanout(
                self.store.desc(di),
                state_lut,
                n_state,
                &mut self.scratch_pairs,
                &mut self.scratch_entries,
            );
            self.regen_ns += t0.elapsed().as_nanos() as u64;
            fo.deliver(node, mult, shift, rb);
            self.cache.admit(di, fo, tr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ident_lut(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    fn desc_with(
        sources: DescSources,
        targets: NodeSet,
        rule: ConnRule,
        syn: SynSpec,
        src_seed: u64,
        local_seed: u64,
    ) -> ConnCallDescriptor {
        let src = Rng::new(src_seed);
        let local = Rng::new(local_seed);
        let (src_state, src_gauss) = src.raw_state();
        let (local_state, local_gauss) = local.raw_state();
        let mut a = src.clone();
        let mut l = local.clone();
        let mut n = 0u64;
        rule.generate(sources.len(), targets.len(), &mut a, &mut l, |_, _| n += 1);
        ConnCallDescriptor {
            sources,
            targets,
            rule,
            syn,
            src_state,
            src_gauss,
            local_state,
            local_gauss,
            n_conns: n,
        }
    }

    #[test]
    fn fanout_replays_two_phase_construction_order() {
        // FixedOutdegree consumes the local stream during generate AND for
        // random weights after — the regeneration must interleave exactly
        // as construction did (all pairs first, then all parameter draws).
        let syn = SynSpec {
            weight: Dist::Normal { mean: 2.0, sd: 0.5 },
            delay: Dist::Uniform { lo: 1.0, hi: 4.0 },
            port: 0,
            stdp: None,
        };
        let rule = ConnRule::FixedOutdegree { k: 7 };
        let (ns, nt) = (11usize, 13usize);
        let desc = desc_with(
            DescSources::Local(NodeSet::range(0, ns as u32)),
            NodeSet::range(0, nt as u32),
            rule.clone(),
            syn,
            42,
            77,
        );

        // reference: the materialized construction sequence
        let mut a = Rng::from_raw_state(desc.src_state, desc.src_gauss);
        let mut l = Rng::from_raw_state(desc.local_state, desc.local_gauss);
        let mut pairs = Vec::new();
        rule.generate(ns, nt, &mut a, &mut l, |i, j| pairs.push((i, j)));
        let mut expect: Vec<(u32, u16, u32, f32)> = Vec::new(); // node, delay, dest, w
        for &(sp, tp) in &pairs {
            let (w, d) = syn.draw(&mut l);
            expect.push((sp, d, tp, w));
        }
        expect.sort_by_key(|&(n, d, _, _)| (n, d)); // stable, port constant

        let lut = ident_lut(nt as u32);
        let (mut sp_, mut se_) = (Vec::new(), Vec::new());
        let fo = build_fanout(&desc, &lut, nt as u32, &mut sp_, &mut se_);
        assert_eq!(fo.n_entries(), expect.len());
        // flatten the fanout back to (node, delay, dest, weight) sequence
        let mut got = Vec::new();
        for &(node, rlo, rhi) in &fo.node_runs {
            for &(delay, s, e) in &fo.runs[rlo as usize..rhi as usize] {
                for k in s as usize..e as usize {
                    got.push((node, delay, fo.dest[k], fo.weight[k]));
                }
            }
        }
        // port 0 → dest == state == target position under the identity LUT
        assert_eq!(got.len(), expect.len());
        for (g, x) in got.iter().zip(expect.iter()) {
            assert_eq!((g.0, g.1, g.2), (x.0, x.1, x.2));
            assert_eq!(g.3.to_bits(), x.3.to_bits(), "weights must be bit-identical");
        }
    }

    #[test]
    fn fanout_delivery_matches_queue_drain_arithmetic() {
        let syn = SynSpec::new(1.5, 2);
        let desc = desc_with(
            DescSources::Local(NodeSet::range(0, 6)),
            NodeSet::range(0, 9),
            ConnRule::FixedIndegree { k: 4 },
            syn,
            3,
            4,
        );
        let lut = ident_lut(9);
        let (mut sp_, mut se_) = (Vec::new(), Vec::new());
        let fo = build_fanout(&desc, &lut, 9, &mut sp_, &mut se_);

        let mut tr = Tracker::new();
        let mut rb_a = RingBuffers::new(9, 5, &mut tr);
        let mut rb_b = RingBuffers::new(9, 5, &mut tr);
        // reference: per-entry add_dest in fanout order (mult folds in)
        for &(node, rlo, rhi) in &fo.node_runs {
            let _ = node;
            for &(delay, s, e) in &fo.runs[rlo as usize..rhi as usize] {
                for k in s as usize..e as usize {
                    rb_a.add_dest(fo.dest[k], delay, fo.weight[k], 3);
                }
            }
        }
        for node in 0..6 {
            fo.deliver(node, 3, 0, &mut rb_b);
        }
        for _ in 0..6 {
            let (ea, ia) = rb_a.current();
            let (eb, ib) = rb_b.current();
            assert_eq!(
                ea.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                eb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                ia.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                ib.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            rb_a.advance();
            rb_b.advance();
        }
    }

    #[test]
    fn descriptor_codec_roundtrips_every_variant() {
        let descs = vec![
            desc_with(
                DescSources::Local(NodeSet::range(5, 4)),
                NodeSet::range(0, 4),
                ConnRule::OneToOne,
                SynSpec::new(1.0, 1),
                1,
                2,
            ),
            desc_with(
                DescSources::Local(NodeSet::List(vec![9, 2, 5])),
                NodeSet::List(vec![1, 0]),
                ConnRule::AllToAll,
                SynSpec {
                    weight: Dist::Normal { mean: 1.0, sd: 0.1 },
                    delay: Dist::Uniform { lo: 1.0, hi: 3.0 },
                    port: 1,
                    stdp: None,
                },
                3,
                4,
            ),
            desc_with(
                DescSources::RemoteImages(vec![7, u32::MAX, 8]),
                NodeSet::range(0, 5),
                ConnRule::FixedIndegree { k: 2 },
                SynSpec::new(-2.0, 2),
                5,
                6,
            ),
            desc_with(
                DescSources::Local(NodeSet::range(0, 3)),
                NodeSet::range(0, 3),
                ConnRule::AssignedNodes(vec![(0, 1), (2, 2)]),
                SynSpec::new(0.5, 3),
                7,
                8,
            ),
            desc_with(
                DescSources::Local(NodeSet::range(0, 10)),
                NodeSet::range(0, 10),
                ConnRule::TripletBucket {
                    state: Rng::new(99).raw_state().0,
                    k: 3,
                    n_ranks: 4,
                    sigma: 2,
                },
                SynSpec::new(1.0, 1),
                9,
                10,
            ),
            desc_with(
                DescSources::Local(NodeSet::range(0, 8)),
                NodeSet::range(0, 8),
                ConnRule::FixedTotalNumber { n: 12 },
                SynSpec::new(1.0, 1),
                11,
                12,
            ),
            desc_with(
                DescSources::Local(NodeSet::range(0, 8)),
                NodeSet::range(0, 8),
                ConnRule::FixedOutdegree { k: 2 },
                SynSpec::new(1.0, 1),
                13,
                14,
            ),
        ];
        let mut tr = Tracker::new();
        let mut store = DescriptorStore::default();
        for d in descs {
            store.push(d, &mut tr);
        }
        assert_eq!(tr.current(MemKind::Device), store.desc_bytes);

        let mut e = Encoder::new();
        store.snapshot_encode(&mut e);
        let bytes = e.into_bytes();
        let mut tr2 = Tracker::new();
        let mut dec = Decoder::new(&bytes);
        let back = DescriptorStore::snapshot_decode(&mut dec, &mut tr2).unwrap();
        dec.finish().unwrap();
        assert_eq!(back.len(), store.len());
        assert_eq!(back.total_conns(), store.total_conns());
        assert_eq!(back.desc_bytes, store.desc_bytes);
        // regenerated fanouts must agree descriptor by descriptor
        let lut = ident_lut(16);
        let (mut p1, mut e1) = (Vec::new(), Vec::new());
        let (mut p2, mut e2) = (Vec::new(), Vec::new());
        for id in 0..store.len() as u32 {
            let a = build_fanout(store.desc(id), &lut, 16, &mut p1, &mut e1);
            let b = build_fanout(back.desc(id), &lut, 16, &mut p2, &mut e2);
            assert_eq!(a.dest, b.dest);
            assert_eq!(
                a.weight.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
                b.weight.iter().map(|w| w.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(a.runs, b.runs);
            assert_eq!(a.node_runs, b.node_runs);
        }
    }

    #[test]
    fn index_lists_descriptors_in_creation_order() {
        let mut tr = Tracker::new();
        let mut store = DescriptorStore::default();
        // both descriptors cover node 1; id order must be preserved
        store.push(
            desc_with(
                DescSources::Local(NodeSet::range(0, 3)),
                NodeSet::range(0, 3),
                ConnRule::AllToAll,
                SynSpec::new(1.0, 1),
                1,
                2,
            ),
            &mut tr,
        );
        store.push(
            desc_with(
                DescSources::RemoteImages(vec![u32::MAX, 1]),
                NodeSet::range(0, 3),
                ConnRule::FixedIndegree { k: 1 },
                SynSpec::new(1.0, 1),
                3,
                4,
            ),
            &mut tr,
        );
        store.build_index(4, &mut tr);
        assert_eq!(store.descs_of(1), &[0, 1]);
        assert_eq!(store.descs_of(0), &[0]);
        assert_eq!(store.descs_of(3), &[] as &[u32]);
        assert_eq!(
            tr.current(MemKind::Device),
            store.device_bytes(),
            "tracker and store byte accounting must agree"
        );
    }

    #[test]
    fn min_remote_delay_folds_remote_descriptors_only() {
        let mut tr = Tracker::new();
        let mut store = DescriptorStore::default();
        store.push(
            desc_with(
                DescSources::Local(NodeSet::range(0, 2)),
                NodeSet::range(0, 2),
                ConnRule::AllToAll,
                SynSpec::new(1.0, 1), // local delay 1 must NOT count
                1,
                2,
            ),
            &mut tr,
        );
        assert_eq!(store.min_remote_delay(), None);
        store.push(
            desc_with(
                DescSources::RemoteImages(vec![5]),
                NodeSet::range(0, 2),
                ConnRule::AllToAll,
                SynSpec::new(1.0, 3),
                3,
                4,
            ),
            &mut tr,
        );
        assert_eq!(store.min_remote_delay(), Some(3));
    }

    #[test]
    fn cache_lru_eviction_is_deterministic_and_tracked() {
        let lut = ident_lut(64);
        let mut tr = Tracker::new();
        let mut store = DescriptorStore::default();
        for seed in 0..6u64 {
            store.push(
                desc_with(
                    DescSources::Local(NodeSet::range(0, 16)),
                    NodeSet::range(0, 64),
                    ConnRule::FixedIndegree { k: 32 },
                    SynSpec::new(1.0, 2),
                    seed * 2 + 1,
                    seed * 2 + 2,
                ),
                &mut tr,
            );
        }
        let (mut sp_, mut se_) = (Vec::new(), Vec::new());
        let one = build_fanout(store.desc(0), &lut, 64, &mut sp_, &mut se_).bytes();
        // room for exactly three fanouts
        let mut cache = FanoutCache::new(store.len(), one * 3 + one / 2);
        let mut ctr = Tracker::new();
        for id in 0..6u32 {
            assert!(cache.touch(id).is_none());
            let fo = build_fanout(store.desc(id), &lut, 64, &mut sp_, &mut se_);
            cache.admit(id, fo, &mut ctr);
        }
        // LRU keeps the three most recently admitted: 3, 4, 5
        assert!(cache.touch(0).is_none());
        assert!(cache.touch(1).is_none());
        assert!(cache.touch(2).is_none());
        assert!(cache.touch(3).is_some());
        assert!(cache.touch(4).is_some());
        assert!(cache.touch(5).is_some());
        assert!(cache.used_bytes() <= cache.cap_bytes());
        assert_eq!(ctr.current(MemKind::Device), cache.used_bytes());
        // touching 3 makes 4 the eviction victim on the next admit
        assert!(cache.touch(3).is_some());
        let fo = build_fanout(store.desc(0), &lut, 64, &mut sp_, &mut se_);
        cache.admit(0, fo, &mut ctr);
        assert!(cache.touch(4).is_none(), "LRU victim must be the stalest");
        assert!(cache.touch(3).is_some());
        assert!(cache.touch(5).is_some());
        assert!(cache.touch(0).is_some());
    }

    #[test]
    fn procedural_delivery_is_cache_invariant() {
        // same spikes delivered twice: cold cache vs warmed cache must be
        // bitwise identical (memoization cannot affect results)
        let lut = ident_lut(32);
        let mut tr = Tracker::new();
        let mut store = DescriptorStore::default();
        for seed in 0..3u64 {
            store.push(
                desc_with(
                    DescSources::Local(NodeSet::range(0, 8)),
                    NodeSet::range(0, 32),
                    ConnRule::FixedIndegree { k: 5 },
                    SynSpec {
                        weight: Dist::Uniform { lo: 0.5, hi: 2.0 },
                        delay: Dist::Uniform { lo: 1.0, hi: 4.0 },
                        port: 0,
                        stdp: None,
                    },
                    seed + 10,
                    seed + 20,
                ),
                &mut tr,
            );
        }
        let mut ps = ProceduralState::new(store);
        ps.prepare(8, &mut tr);
        let mut rb_a = RingBuffers::new(32, 6, &mut tr);
        let mut rb_b = RingBuffers::new(32, 6, &mut tr);
        for node in [3u32, 1, 3, 7] {
            ps.deliver(node, 1, 0, &lut, 32, &mut rb_a, &mut tr);
        }
        let (hits_a, misses_a) = (ps.cache_hits, ps.cache_misses);
        assert!(misses_a > 0);
        for node in [3u32, 1, 3, 7] {
            ps.deliver(node, 1, 0, &lut, 32, &mut rb_b, &mut tr);
        }
        assert!(ps.cache_hits > hits_a, "second pass must hit the cache");
        for _ in 0..7 {
            let (ea, ia) = rb_a.current();
            let (eb, ib) = rb_b.current();
            assert_eq!(
                ea.iter().chain(ia).map(|x| x.to_bits()).collect::<Vec<_>>(),
                eb.iter().chain(ib).map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            rb_a.advance();
            rb_b.advance();
        }
    }

    #[test]
    fn connectivity_parse_and_name() {
        assert_eq!(
            Connectivity::parse("procedural"),
            Some(Connectivity::Procedural)
        );
        assert_eq!(
            Connectivity::parse("materialized"),
            Some(Connectivity::Materialized)
        );
        assert_eq!(Connectivity::parse("nope"), None);
        assert_eq!(Connectivity::default(), Connectivity::Materialized);
        assert_eq!(Connectivity::Procedural.name(), "procedural");
    }
}
