//! Trace-based STDP plasticity (DESIGN.md §12).
//!
//! The first subsystem that mutates construction-time state during
//! propagation: per-synapse-group [`StdpRule`]s (attached through
//! [`crate::connection::SynSpec::stdp`]) evolve the connection store's
//! weights while spikes flow. Two new pipeline phases do the work:
//!
//! - **pre_update** — a presynaptic spike *arrives* at a plastic synapse:
//!   the weight is depressed against the postsynaptic neuron's trace, the
//!   synapse's presynaptic trace is bumped, and the PSP is deposited with
//!   the *post-depression* weight;
//! - **post_update** — a neuron spikes: every incoming plastic synapse is
//!   potentiated against its presynaptic trace, then the neuron's
//!   postsynaptic trace is bumped.
//!
//! Traces are exponential: the postsynaptic trace lives per neuron in
//! [`TraceBuffers`]; the presynaptic trace lives per *synapse* (bumped at
//! arrival, i.e. the per-neuron emission trace seen through that synapse's
//! own delay — the delay-aware formulation, exactly NEST's
//! `stdp_synapse` bookkeeping).
//!
//! **Delay-aware remote updates.** Plastic deliveries are not applied when
//! a spike is routed or exchanged but when it *arrives*: every delivery
//! enqueues a [`PlasticEvent`] into an arrival-step ring ([`EventRing`]),
//! and `pre_update` drains the current step's slot. Remote records carry
//! their emission `lag`, so a batched exchange (any
//! `exchange_interval ≤ min remote delay`) enqueues into exactly the same
//! arrival slots as per-step exchange — and because events are replayed in
//! the canonical `(emission step, local-before-remote, push order)` order,
//! every weight update and every f32 deposit happens at the same step, in
//! the same order, with the same operands. Plastic runs are therefore
//! bit-identical across exchange intervals, extending PR 2's
//! canonical-replay argument to mutable weights.

use anyhow::{bail, Result};

use crate::connection::Connections;
use crate::memory::{MemKind, Tracker};
use crate::node::traces::{decayed, TraceBuffers, NEVER};
use crate::node::{NodeKind, NodeSpace};
use crate::snapshot::{Decoder, Encoder};
use crate::stats::weights::WeightSummary;

/// Per-connection rule id meaning "static synapse".
pub const NO_RULE: u16 = u16::MAX;

/// Weight-update bound handling of an STDP rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightBound {
    /// `Δw⁺ = a_plus·K`, `Δw⁻ = −a_minus·y`, clamped to `[w_min, w_max]`
    Additive,
    /// soft bounds: `Δw⁺ = a_plus·(w_max − w)·K`,
    /// `Δw⁻ = −a_minus·(w − w_min)·y`
    Multiplicative,
}

/// One trace-based STDP rule, shared by every synapse of a connect call
/// (registered in the connection store, referenced per connection by id).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StdpRule {
    /// presynaptic (potentiation) trace time constant (ms)
    pub tau_plus_ms: f32,
    /// postsynaptic (depression) trace time constant (ms); must be the
    /// same for every rule of a rank — the post trace is per *neuron*
    pub tau_minus_ms: f32,
    /// potentiation amplitude (pA for [`WeightBound::Additive`];
    /// dimensionless for [`WeightBound::Multiplicative`])
    pub a_plus: f32,
    /// depression amplitude (same units as `a_plus`)
    pub a_minus: f32,
    pub w_min: f32,
    pub w_max: f32,
    pub bound: WeightBound,
}

impl StdpRule {
    /// Potentiation at a postsynaptic spike, given the synapse's
    /// presynaptic trace value `k_pre`.
    #[inline]
    pub fn potentiate(&self, w: f32, k_pre: f32) -> f32 {
        let dw = match self.bound {
            WeightBound::Additive => self.a_plus * k_pre,
            WeightBound::Multiplicative => self.a_plus * (self.w_max - w) * k_pre,
        };
        (w + dw).clamp(self.w_min, self.w_max)
    }

    /// Depression at a presynaptic spike arrival, given the target
    /// neuron's postsynaptic trace value `y_post`.
    #[inline]
    pub fn depress(&self, w: f32, y_post: f32) -> f32 {
        let dw = match self.bound {
            WeightBound::Additive => self.a_minus * y_post,
            WeightBound::Multiplicative => self.a_minus * (w - self.w_min) * y_post,
        };
        (w - dw).clamp(self.w_min, self.w_max)
    }

    /// Parameter sanity (checked when a rule is registered and when one is
    /// decoded from a snapshot).
    pub fn validate(&self) -> Result<()> {
        for x in [
            self.tau_plus_ms,
            self.tau_minus_ms,
            self.a_plus,
            self.a_minus,
            self.w_min,
            self.w_max,
        ] {
            if !x.is_finite() {
                bail!("STDP rule has a non-finite parameter: {self:?}");
            }
        }
        if self.tau_plus_ms <= 0.0 || self.tau_minus_ms <= 0.0 {
            bail!("STDP time constants must be positive: {self:?}");
        }
        if self.w_min > self.w_max {
            bail!("STDP bounds inverted: w_min {} > w_max {}", self.w_min, self.w_max);
        }
        if self.a_plus < 0.0 || self.a_minus < 0.0 {
            bail!("STDP amplitudes must be non-negative: {self:?}");
        }
        Ok(())
    }

    /// Serialize the rule (snapshot CONN section, format v3).
    pub fn encode(&self, enc: &mut Encoder) {
        enc.f32(self.tau_plus_ms);
        enc.f32(self.tau_minus_ms);
        enc.f32(self.a_plus);
        enc.f32(self.a_minus);
        enc.f32(self.w_min);
        enc.f32(self.w_max);
        enc.u8(match self.bound {
            WeightBound::Additive => 0,
            WeightBound::Multiplicative => 1,
        });
    }

    /// Rebuild from [`StdpRule::encode`] output.
    pub fn decode(dec: &mut Decoder) -> Result<Self> {
        let r = StdpRule {
            tau_plus_ms: dec.f32()?,
            tau_minus_ms: dec.f32()?,
            a_plus: dec.f32()?,
            a_minus: dec.f32()?,
            w_min: dec.f32()?,
            w_max: dec.f32()?,
            bound: match dec.u8()? {
                0 => WeightBound::Additive,
                1 => WeightBound::Multiplicative,
                tag => bail!("unknown STDP bound tag {tag} in snapshot"),
            },
        };
        r.validate()?;
        Ok(r)
    }
}

/// Encoded bytes of one [`StdpRule`] (6 f32 fields + 1 bound tag).
pub const RULE_ENCODED_BYTES: usize = 6 * 4 + 1;

/// One pending presynaptic arrival at a plastic synapse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlasticEvent {
    /// plastic-synapse slot (index into the engine's per-slot arrays)
    pub slot: u32,
    /// absolute emission step of the presynaptic spike
    pub emit: u32,
    /// push order within the slot (canonical-order tiebreaker)
    pub seq: u32,
    /// spike multiplicity (scales the deposited PSP; the STDP update is
    /// applied once per arrival — neuron sources always have mult 1)
    pub mult: u16,
    /// enqueued by the remote-delivery path (exchanged records)
    pub remote: bool,
}

/// Arrival-step ring of pending plastic events, advanced once per step in
/// lockstep with the spike ring buffers. Enqueue offsets are relative to
/// the *post-advance* cursor of the current step (exactly the ring-buffer
/// `delay + shift` convention), so an event lands in the `pre_update` of
/// the same step whose dynamics would consume the equivalent ring deposit.
#[derive(Debug)]
pub struct EventRing {
    slots: Vec<Vec<PlasticEvent>>,
    cursor: usize,
}

impl EventRing {
    pub fn new(depth: usize) -> Self {
        Self {
            slots: vec![Vec::new(); depth.max(1)],
            cursor: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.slots.len()
    }

    /// Queue an arrival `offset ≥ 1` steps ahead of the current cursor.
    #[inline]
    pub fn enqueue(&mut self, offset: usize, slot: u32, emit: u32, mult: u16, remote: bool) {
        debug_assert!(
            offset >= 1 && offset < self.slots.len(),
            "plastic arrival offset {offset} outside the event ring"
        );
        let i = (self.cursor + offset) % self.slots.len();
        let seq = self.slots[i].len() as u32;
        self.slots[i].push(PlasticEvent {
            slot,
            emit,
            seq,
            mult,
            remote,
        });
    }

    /// Take the current step's events (capacity is given back by
    /// [`EventRing::put_back`] so the loop stays allocation-free).
    pub fn take_due(&mut self) -> Vec<PlasticEvent> {
        std::mem::take(&mut self.slots[self.cursor])
    }

    /// Return the (cleared) buffer taken by [`EventRing::take_due`].
    pub fn put_back(&mut self, mut buf: Vec<PlasticEvent>) {
        buf.clear();
        self.slots[self.cursor] = buf;
    }

    /// Advance to the next step's slot.
    pub fn advance(&mut self) {
        debug_assert!(
            self.slots[self.cursor].is_empty(),
            "advancing the event ring over unprocessed plastic events"
        );
        self.cursor = (self.cursor + 1) % self.slots.len();
    }

    /// Total queued events (all future slots).
    pub fn pending(&self) -> usize {
        self.slots.iter().map(|s| s.len()).sum()
    }

    /// Slots in arrival order starting at the cursor, with their offsets.
    fn iter_from_cursor(&self) -> impl Iterator<Item = (usize, &[PlasticEvent])> + '_ {
        (0..self.slots.len())
            .map(move |o| (o, self.slots[(self.cursor + o) % self.slots.len()].as_slice()))
    }
}

/// The per-rank plasticity engine: plastic-synapse index structures,
/// traces, the arrival event ring and the per-step deposit plane. Built at
/// `prepare()` (or snapshot restore) when the connection store carries any
/// registered rule.
#[derive(Debug)]
pub struct PlasticityEngine {
    /// rules copied out of the connection store at build time
    rules: Vec<StdpRule>,
    /// per-rule presynaptic decay factor per step, `exp(−dt/τ₊)`
    decay_plus: Vec<f64>,
    /// shared postsynaptic decay factor per step, `exp(−dt/τ₋)`
    decay_minus: f64,
    /// connection index → plastic slot (`u32::MAX` = static)
    slot_of: Vec<u32>,
    /// plastic slot → connection index (ascending in connection index)
    conn_of: Vec<u32>,
    /// plastic slot → rule index
    rule_of: Vec<u16>,
    /// per-slot presynaptic trace value (at the step of its last arrival)
    k_pre: Vec<f32>,
    /// per-slot step of the last presynaptic arrival ([`NEVER`] = none)
    pre_last: Vec<i64>,
    /// incoming-plastic CSR offsets per node (len = n_nodes + 1)
    in_first: Vec<u32>,
    /// CSR payload: plastic slots grouped by target node
    in_slots: Vec<u32>,
    /// per-neuron postsynaptic traces (state-index addressed)
    post: TraceBuffers,
    events: EventRing,
    /// current-step plastic PSP deposits per state slot, merged by the
    /// dynamics phase after the local and remote planes
    plane_ex: Vec<f32>,
    plane_in: Vec<f32>,
    /// state slots touched this step (sparse zeroing in `end_step`)
    touched: Vec<u32>,
    plane_used: bool,
    tracked: u64,
}

impl PlasticityEngine {
    /// Build the engine for a prepared connection store. Validates that
    /// plastic sources are neurons or images (devices deliver through a
    /// path with no arrival events), that targets are local neurons, and
    /// that every rule shares one `tau_minus` (the post trace is per
    /// neuron, as in NEST, so its decay cannot vary per synapse).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        conns: &Connections,
        nodes: &NodeSpace,
        state_lut: &[u32],
        n_state: usize,
        max_delay_steps: u16,
        exchange_interval: u16,
        dt_ms: f64,
        tr: &mut Tracker,
    ) -> Result<Self> {
        let rules = conns.rules().to_vec();
        if rules.is_empty() {
            bail!("plasticity engine built without any registered rule");
        }
        for r in &rules {
            r.validate()?;
        }
        let tau_minus = rules[0].tau_minus_ms;
        if rules.iter().any(|r| r.tau_minus_ms.to_bits() != tau_minus.to_bits()) {
            bail!(
                "heterogeneous tau_minus across STDP rules is unsupported: the \
                 postsynaptic trace is per neuron and shares one decay"
            );
        }
        let rule_ids = conns
            .rule_slice()
            .expect("rules registered but no per-connection rule array");
        if rule_ids.len() != conns.len() {
            bail!(
                "per-connection rule array covers {} of {} connections",
                rule_ids.len(),
                conns.len()
            );
        }

        let src = conns.source.as_slice();
        let tgt = conns.target.as_slice();
        let mut slot_of = vec![u32::MAX; conns.len()];
        let mut conn_of: Vec<u32> = Vec::new();
        let mut rule_of: Vec<u16> = Vec::new();
        for (k, &rid) in rule_ids.iter().enumerate() {
            if rid == NO_RULE {
                continue;
            }
            if rid as usize >= rules.len() {
                bail!("connection {k} references unknown STDP rule {rid}");
            }
            if matches!(nodes.kind(src[k]), NodeKind::Device { .. }) {
                bail!(
                    "connection {k} attaches an STDP rule to a device source \
                     (node {}); only neuron and image sources can be plastic",
                    src[k]
                );
            }
            if state_lut[tgt[k] as usize] == u32::MAX {
                bail!(
                    "plastic connection {k} targets node {} which is not a neuron",
                    tgt[k]
                );
            }
            slot_of[k] = conn_of.len() as u32;
            conn_of.push(k as u32);
            rule_of.push(rid);
        }
        let n_plastic = conn_of.len();

        // incoming-plastic CSR by target node (counting scatter; slots stay
        // ascending per target — the canonical potentiation order)
        let m = nodes.m() as usize;
        let mut in_first = vec![0u32; m + 1];
        for &k in &conn_of {
            in_first[tgt[k as usize] as usize + 1] += 1;
        }
        for i in 0..m {
            in_first[i + 1] += in_first[i];
        }
        let mut cursor = in_first.clone();
        let mut in_slots = vec![0u32; n_plastic];
        for (slot, &k) in conn_of.iter().enumerate() {
            let t = tgt[k as usize] as usize;
            in_slots[cursor[t] as usize] = slot as u32;
            cursor[t] += 1;
        }

        let decay_plus: Vec<f64> = rules
            .iter()
            .map(|r| (-(dt_ms / r.tau_plus_ms as f64)).exp())
            .collect();
        let decay_minus = (-(dt_ms / tau_minus as f64)).exp();

        let depth = max_delay_steps as usize + exchange_interval as usize;
        let bytes = (slot_of.len() * 4
            + n_plastic * (4 + 2 + 4 + 8)
            + in_first.len() * 4
            + in_slots.len() * 4
            + n_state * 8) as u64;
        tr.alloc(MemKind::Device, bytes);
        Ok(Self {
            rules,
            decay_plus,
            decay_minus,
            slot_of,
            conn_of,
            rule_of,
            k_pre: vec![0.0; n_plastic],
            pre_last: vec![NEVER; n_plastic],
            in_first,
            in_slots,
            post: TraceBuffers::new(n_state, tr),
            events: EventRing::new(depth),
            plane_ex: vec![0.0; n_state],
            plane_in: vec![0.0; n_state],
            touched: Vec::new(),
            plane_used: false,
            tracked: bytes,
        })
    }

    pub fn n_plastic(&self) -> usize {
        self.conn_of.len()
    }

    pub fn rules(&self) -> &[StdpRule] {
        &self.rules
    }

    /// Plastic slot of connection `k`, if it carries a rule.
    #[inline]
    pub fn plastic_slot(&self, k: usize) -> Option<u32> {
        let s = self.slot_of[k];
        (s != u32::MAX).then_some(s)
    }

    /// Queue a presynaptic arrival `offset` steps ahead (delivery paths).
    #[inline]
    pub fn enqueue(&mut self, offset: usize, slot: u32, emit: u32, mult: u16, remote: bool) {
        self.events.enqueue(offset, slot, emit, mult, remote);
    }

    /// Pending arrival events queued for future steps.
    pub fn pending_events(&self) -> usize {
        self.events.pending()
    }

    /// The current step's plastic deposit plane `(excitatory, inhibitory)`.
    pub fn plane(&self) -> (&[f32], &[f32]) {
        (&self.plane_ex, &self.plane_in)
    }

    /// Whether this step deposited anything (skip the merge otherwise).
    pub fn plane_used(&self) -> bool {
        self.plane_used
    }

    /// **pre_update** phase at step `now`: drain the current arrival slot
    /// in canonical `(emission step, local-before-remote, push order)`
    /// order; for each arrival, depress the weight against the target's
    /// post trace, bump the synapse's pre trace, and deposit the PSP with
    /// the post-depression weight into the plastic plane.
    pub fn pre_update(&mut self, now: i64, conns: &mut Connections, state_lut: &[u32]) {
        let mut evs = self.events.take_due();
        if evs.is_empty() {
            self.events.put_back(evs);
            return;
        }
        evs.sort_unstable_by_key(|e| (e.emit, e.remote, e.seq));
        let (weights, targets, ports) = conns.weights_with_targets_mut();
        for ev in &evs {
            let slot = ev.slot as usize;
            let k = self.conn_of[slot] as usize;
            let rid = self.rule_of[slot] as usize;
            let state = state_lut[targets[k] as usize] as usize;
            let y = self.post.eval(state, now, self.decay_minus);
            let w = self.rules[rid].depress(weights[k], y);
            weights[k] = w;
            self.k_pre[slot] =
                decayed(self.k_pre[slot], self.pre_last[slot], now, self.decay_plus[rid]) + 1.0;
            self.pre_last[slot] = now;
            let psp = w * ev.mult as f32;
            if ports[k] == 0 {
                self.plane_ex[state] += psp;
            } else {
                self.plane_in[state] += psp;
            }
            self.touched.push(state as u32);
        }
        self.plane_used = true;
        self.events.put_back(evs);
    }

    /// **post_update** phase at step `now`: for every neuron that spiked
    /// this step (ascending node order), potentiate its incoming plastic
    /// synapses against their pre traces, then bump its post trace.
    pub fn post_update(
        &mut self,
        now: i64,
        spiking: &[u32],
        conns: &mut Connections,
        state_lut: &[u32],
    ) {
        if self.conn_of.is_empty() {
            return;
        }
        let weights = conns.weights_mut();
        for &node in spiking {
            let a = self.in_first[node as usize] as usize;
            let b = self.in_first[node as usize + 1] as usize;
            for &slot in &self.in_slots[a..b] {
                let slot = slot as usize;
                let rid = self.rule_of[slot] as usize;
                let k = self.conn_of[slot] as usize;
                let kp =
                    decayed(self.k_pre[slot], self.pre_last[slot], now, self.decay_plus[rid]);
                weights[k] = self.rules[rid].potentiate(weights[k], kp);
            }
            let state = state_lut[node as usize] as usize;
            self.post.bump(state, now, self.decay_minus);
        }
    }

    /// End-of-step bookkeeping: zero the touched plane entries and advance
    /// the event ring (called once per step, after the dynamics merge).
    pub fn end_step(&mut self) {
        if self.plane_used {
            for &s in &self.touched {
                self.plane_ex[s as usize] = 0.0;
                self.plane_in[s as usize] = 0.0;
            }
            self.touched.clear();
            self.plane_used = false;
        }
        self.events.advance();
    }

    /// Distribution summary (and order-sensitive hash) of the current
    /// plastic weights, in plastic-slot order.
    pub fn weight_summary(&self, conns: &Connections) -> WeightSummary {
        let w = conns.weight.as_slice();
        WeightSummary::from_weights(self.conn_of.iter().map(|&k| w[k as usize]))
    }

    /// Every plastic weight honors its rule's `[w_min, w_max]` bounds.
    pub fn bounds_ok(&self, conns: &Connections) -> bool {
        let w = conns.weight.as_slice();
        self.conn_of.iter().zip(self.rule_of.iter()).all(|(&k, &rid)| {
            let r = &self.rules[rid as usize];
            let x = w[k as usize];
            x >= r.w_min && x <= r.w_max
        })
    }

    /// Release the engine's tracked device allocations (teardown
    /// symmetry with the other per-subsystem `release` methods).
    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(MemKind::Device, self.tracked);
        self.tracked = 0;
        self.post.release(tr);
    }

    /// Serialize the mutable mid-run state (PLAS snapshot section):
    /// per-synapse pre traces, per-neuron post traces, pending arrival
    /// events. Index structures and decay factors are derived from the
    /// CONN section at restore and are not persisted.
    pub fn snapshot_encode(&self, enc: &mut Encoder) {
        enc.u32(self.conn_of.len() as u32);
        enc.slice_f32(&self.k_pre);
        enc.seq_len(self.pre_last.len());
        for &l in &self.pre_last {
            enc.u64(l as u64);
        }
        self.post.snapshot_encode(enc);
        enc.u64(self.events.depth() as u64);
        enc.seq_len(self.events.pending());
        for (off, evs) in self.events.iter_from_cursor() {
            for ev in evs {
                enc.u32(off as u32);
                enc.u32(ev.slot);
                enc.u32(ev.emit);
                enc.u32(ev.seq);
                enc.u16(ev.mult);
                enc.bool(ev.remote);
            }
        }
    }

    /// Overwrite a freshly built engine's mutable state from
    /// [`PlasticityEngine::snapshot_encode`] output.
    pub fn snapshot_restore(&mut self, dec: &mut Decoder, tr: &mut Tracker) -> Result<()> {
        let n = dec.u32()? as usize;
        if n != self.conn_of.len() {
            bail!(
                "snapshot carries {n} plastic synapses, the connection store \
                 implies {}",
                self.conn_of.len()
            );
        }
        let k_pre = dec.vec_f32()?;
        let n_last = dec.seq_len(8)?;
        if k_pre.len() != n || n_last != n {
            bail!("plastic trace arrays inconsistent with {n} plastic synapses");
        }
        let mut pre_last = Vec::with_capacity(n);
        for _ in 0..n {
            pre_last.push(dec.u64()? as i64);
        }
        let post = TraceBuffers::snapshot_decode(dec, tr)?;
        if post.n() != self.post.n() {
            bail!(
                "post-trace buffers cover {} state slots, the engine expects {}",
                post.n(),
                self.post.n()
            );
        }
        let depth = dec.u64()? as usize;
        if depth != self.events.depth() {
            bail!(
                "snapshot event ring depth {depth} differs from the rebuilt \
                 depth {} (config mismatch)",
                self.events.depth()
            );
        }
        let n_events = dec.seq_len(4 + 4 + 4 + 4 + 2 + 1)?;
        let mut events = EventRing::new(depth);
        for _ in 0..n_events {
            let off = dec.u32()? as usize;
            let slot = dec.u32()?;
            let emit = dec.u32()?;
            let seq = dec.u32()?;
            let mult = dec.u16()?;
            let remote = dec.bool()?;
            // offset 0 is legal here (unlike at enqueue time): an event
            // enqueued k steps before the checkpoint with offset k is due
            // at the very next step's pre_update and sits at the cursor
            if off >= depth {
                bail!("plastic event offset {off} outside the ring of {depth}");
            }
            if slot as usize >= n {
                bail!("plastic event references slot {slot} of {n}");
            }
            let i = (events.cursor + off) % depth;
            events.slots[i].push(PlasticEvent {
                slot,
                emit,
                seq,
                mult,
                remote,
            });
        }
        // swap in: release the build-time traces so the tracker stays
        // balanced (the decoded buffers carry their own accounting)
        let mut old_post = std::mem::replace(&mut self.post, post);
        old_post.release(tr);
        self.k_pre = k_pre;
        self.pre_last = pre_last;
        self.events = events;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rule(bound: WeightBound) -> StdpRule {
        StdpRule {
            tau_plus_ms: 20.0,
            tau_minus_ms: 20.0,
            a_plus: 1.0,
            a_minus: 1.2,
            w_min: 0.0,
            w_max: 10.0,
            bound,
        }
    }

    #[test]
    fn additive_updates_and_clamping() {
        let r = rule(WeightBound::Additive);
        assert_eq!(r.potentiate(5.0, 1.0), 6.0);
        assert_eq!(r.depress(5.0, 1.0), 5.0 - 1.2);
        // clamped at both ends
        assert_eq!(r.potentiate(9.9, 5.0), 10.0);
        assert_eq!(r.depress(0.5, 5.0), 0.0);
    }

    #[test]
    fn multiplicative_soft_bounds() {
        let r = StdpRule {
            a_plus: 0.5,
            a_minus: 0.5,
            ..rule(WeightBound::Multiplicative)
        };
        // Δw⁺ shrinks as w -> w_max, Δw⁻ as w -> w_min
        assert!(r.potentiate(9.0, 1.0) - 9.0 < r.potentiate(1.0, 1.0) - 1.0);
        assert!(5.0 - r.depress(5.0, 1.0) > 1.0 - r.depress(1.0, 1.0));
        assert!((r.potentiate(10.0, 1.0) - 10.0).abs() < 1e-6);
        assert!((r.depress(0.0, 1.0)).abs() < 1e-6);
    }

    #[test]
    fn bounds_hold_under_random_update_sequences() {
        // property: any sequence of depress/potentiate with any trace
        // values keeps w within [w_min, w_max], for both bound modes
        let mut rng = Rng::new(42);
        for trial in 0..200 {
            let lo = (rng.uniform_range(-5.0, 0.0)) as f32;
            let hi = (rng.uniform_range(0.5, 20.0)) as f32;
            let r = StdpRule {
                tau_plus_ms: 15.0,
                tau_minus_ms: 30.0,
                a_plus: rng.uniform_range(0.0, 3.0) as f32,
                a_minus: rng.uniform_range(0.0, 3.0) as f32,
                w_min: lo,
                w_max: hi,
                bound: if trial % 2 == 0 {
                    WeightBound::Additive
                } else {
                    WeightBound::Multiplicative
                },
            };
            r.validate().unwrap();
            let mut w = rng.uniform_range(lo as f64, hi as f64) as f32;
            for _ in 0..100 {
                let trace = rng.uniform_range(0.0, 4.0) as f32;
                w = if rng.next_u64() % 2 == 0 {
                    r.potentiate(w, trace)
                } else {
                    r.depress(w, trace)
                };
                assert!(
                    w >= lo && w <= hi,
                    "w {w} escaped [{lo}, {hi}] ({:?})",
                    r.bound
                );
            }
        }
    }

    #[test]
    fn rule_codec_roundtrip() {
        for bound in [WeightBound::Additive, WeightBound::Multiplicative] {
            let r = rule(bound);
            let mut e = Encoder::new();
            r.encode(&mut e);
            let bytes = e.into_bytes();
            assert_eq!(bytes.len(), RULE_ENCODED_BYTES);
            let mut d = Decoder::new(&bytes);
            let back = StdpRule::decode(&mut d).unwrap();
            d.finish().unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn invalid_rules_rejected() {
        let mut r = rule(WeightBound::Additive);
        r.w_min = 5.0;
        r.w_max = 1.0;
        assert!(r.validate().is_err());
        let mut r = rule(WeightBound::Additive);
        r.tau_plus_ms = 0.0;
        assert!(r.validate().is_err());
        let mut r = rule(WeightBound::Additive);
        r.a_plus = -1.0;
        assert!(r.validate().is_err());
    }

    #[test]
    fn event_ring_delivers_at_offset_in_canonical_order() {
        let mut ring = EventRing::new(8);
        // step t: a local delivery 2 ahead and remote records (older
        // emissions) arriving in the same slot via a later exchange
        ring.enqueue(2, 0, 10, 1, false);
        ring.enqueue(2, 1, 9, 1, true);
        ring.enqueue(2, 2, 9, 1, false);
        ring.enqueue(2, 3, 10, 1, true);
        ring.advance();
        assert!(ring.take_due().is_empty());
        let empty = ring.take_due();
        ring.put_back(empty);
        ring.advance();
        let mut due = ring.take_due();
        assert_eq!(due.len(), 4);
        due.sort_unstable_by_key(|e| (e.emit, e.remote, e.seq));
        // canonical: emission ascending, local before remote within a step
        let order: Vec<u32> = due.iter().map(|e| e.slot).collect();
        assert_eq!(order, vec![2, 1, 0, 3]);
        ring.put_back(due);
        assert_eq!(ring.pending(), 0);
    }

    #[test]
    fn engine_memory_tracked_and_released() {
        use crate::connection::Connections;
        let mut tr = Tracker::new();
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 2);
        let mut conns = Connections::new();
        conns.push(0, 1, 1.0, 2, 0, &mut tr);
        let rid = conns.register_rule(rule(WeightBound::Additive));
        conns.attach_rule(0, rid, &mut tr);
        conns.sort_by_source(2, &mut tr);
        let state_lut = vec![0u32, 1u32];
        let before = tr.current(MemKind::Device);
        let mut eng =
            PlasticityEngine::build(&conns, &nodes, &state_lut, 2, 8, 1, 0.1, &mut tr).unwrap();
        assert_eq!(eng.n_plastic(), 1);
        assert!(tr.current(MemKind::Device) > before);
        eng.release(&mut tr);
        assert_eq!(tr.current(MemKind::Device), before);
    }

    #[test]
    fn event_ring_wraps() {
        let mut ring = EventRing::new(3);
        for step in 0..10u32 {
            ring.enqueue(1, step, step, 1, false);
            ring.advance();
            let due = ring.take_due();
            assert_eq!(due.len(), 1);
            assert_eq!(due[0].emit, step);
            ring.put_back(due);
        }
    }
}
