//! The construction-cache daemon (DESIGN.md §17).
//!
//! One handler thread per client connection; each `SubmitJob` frame
//! becomes a job that either *resumes* from the snapshot cache (warm:
//! construction skipped entirely) or *constructs* through
//! [`run_cluster_construct_save`] and admits the resulting snapshot
//! world (cold). Three coordination pieces keep a multi-tenant daemon
//! honest:
//!
//! - **single-flight**: identical concurrent submits (same cache key)
//!   trigger exactly one construction — the first submitter builds, the
//!   rest wait on its [`Flight`] and then re-check the cache, landing as
//!   hits. If the builder fails, one waiter is promoted to builder.
//! - **bounded concurrency**: a [`Semaphore`] caps the number of
//!   simulations (cold or warm) running at once; each simulation is a
//!   thread-per-rank cluster, so admission control is what keeps N
//!   clients from forking N·ranks threads.
//! - **pinning**: a warm job pins its cache entry for the duration of
//!   the resume, so LRU eviction can never delete snapshot files under
//!   a running simulation (see `cache.rs`).
//!
//! Bit-identity of warm vs cold runs holds by construction: the cold
//! path saves the post-`prepare()` state (step 0) and then simulates in
//! the same prepared simulators, while the warm path restores exactly
//! that state — the snapshot subsystem's resume-equivalence invariant
//! (`tests/it_snapshot.rs`) does the rest. The world spike hash in every
//! [`JobOutcome`] is the client-checkable witness.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Context;

use crate::comm::wire::{read_frame, MsgType, WireError};
use crate::engine::Simulator;
use crate::harness::{run_cluster_construct_save, run_cluster_from_snapshot};
use crate::models::balanced::build_balanced;
use crate::util::json::Json;

use super::cache::SnapshotCache;
use super::proto::{self, JobOutcome, JobSpec};

/// Daemon configuration (`nestgpu serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// bind address; port 0 picks an ephemeral port (tests/benches)
    pub listen: String,
    pub cache_dir: PathBuf,
    pub cache_bytes: u64,
    /// max simulations (cold or warm) running concurrently
    pub max_jobs: usize,
    /// write a `nestgpu report`-readable trace with the cache counters
    /// here at shutdown
    pub obs_dir: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".to_string(),
            cache_dir: PathBuf::from("serve-cache"),
            cache_bytes: 256 << 20,
            max_jobs: 2,
            obs_dir: None,
        }
    }
}

/// One in-flight construction; waiters block until the builder calls
/// [`finish`](Flight::finish) (after the cache admit).
#[derive(Default)]
struct Flight {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Flight {
    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }

    fn finish(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Counting semaphore (the offline crate set has no tokio/parking_lot;
/// a mutex + condvar is all a blocking daemon needs).
struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

struct SemPermit<'a> {
    sem: &'a Semaphore,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Self {
            permits: Mutex::new(n),
            cv: Condvar::new(),
        }
    }

    fn acquire(&self) -> SemPermit<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        SemPermit { sem: self }
    }
}

impl Drop for SemPermit<'_> {
    fn drop(&mut self) {
        *self.sem.permits.lock().unwrap() += 1;
        self.sem.cv.notify_one();
    }
}

/// Shared daemon state.
struct State {
    cache: Mutex<SnapshotCache>,
    inflight: Mutex<HashMap<u64, Arc<Flight>>>,
    /// live client sockets (clones), force-closed at shutdown so
    /// handlers parked in `read_frame` on idle connections unblock
    conns: Mutex<HashMap<u64, TcpStream>>,
    sem: Semaphore,
    next_job: AtomicU32,
    next_conn: AtomicU64,
    constructions: AtomicU64,
    coalesced: AtomicU64,
    jobs_done: AtomicU64,
    proto_errors: AtomicU64,
    shutdown: AtomicBool,
    addr: SocketAddr,
    obs_dir: Option<PathBuf>,
}

impl State {
    /// `CacheStats` reply body: cache counters plus executor totals.
    fn stats_json(&self) -> Json {
        let mut fields = self.cache.lock().unwrap().stats_json();
        let load = |a: &AtomicU64| Json::num(a.load(Ordering::SeqCst) as f64);
        fields.push(("coalesced", load(&self.coalesced)));
        fields.push(("constructions", load(&self.constructions)));
        fields.push(("jobs_done", load(&self.jobs_done)));
        fields.push(("proto_errors", load(&self.proto_errors)));
        Json::obj(fields)
    }

    fn begin_shutdown(&self) {
        if !self.shutdown.swap(true, Ordering::SeqCst) {
            // unblock handlers parked in read_frame on idle connections
            // (running jobs still finish; their send just fails)
            for c in self.conns.lock().unwrap().values() {
                let _ = c.shutdown(Shutdown::Both);
            }
            // wake the accept loop with a throwaway connection
            let _ = TcpStream::connect(self.addr);
        }
    }

    /// Write a single-rank trace (`rank0000.jsonl` with one summary
    /// record carrying the cache registry) that `nestgpu report` and
    /// `obs::report::read_trace_dir` understand.
    fn write_obs_trace(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("cannot create obs dir {}", dir.display()))?;
        let registry = self.cache.lock().unwrap().registry().to_json();
        let line = Json::obj(vec![
            ("t", Json::str("summary")),
            ("schema", Json::num(1.0)),
            ("rank", Json::num(0.0)),
            ("registry", registry),
        ]);
        let mut text = line.to_string();
        text.push('\n');
        let path = dir.join("rank0000.jsonl");
        std::fs::write(&path, text).with_context(|| format!("cannot write {}", path.display()))?;
        Ok(())
    }
}

/// A bound daemon: listener plus shared state. [`run`](Server::run)
/// blocks (the CLI); [`spawn`](Server::spawn) runs it on a thread
/// (tests and benches).
pub struct Server {
    listener: TcpListener,
    state: Arc<State>,
}

impl Server {
    pub fn bind(cfg: ServeConfig) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("cannot listen on {}", cfg.listen))?;
        let addr = listener.local_addr().context("read listen addr")?;
        let cache = SnapshotCache::open(&cfg.cache_dir, cfg.cache_bytes)?;
        let state = Arc::new(State {
            cache: Mutex::new(cache),
            inflight: Mutex::new(HashMap::new()),
            conns: Mutex::new(HashMap::new()),
            sem: Semaphore::new(cfg.max_jobs.max(1)),
            next_job: AtomicU32::new(0),
            next_conn: AtomicU64::new(0),
            constructions: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            jobs_done: AtomicU64::new(0),
            proto_errors: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            addr,
            obs_dir: cfg.obs_dir,
        });
        Ok(Server { listener, state })
    }

    /// The actual bound address (resolves a `:0` ephemeral port).
    pub fn local_addr(&self) -> String {
        self.state.addr.to_string()
    }

    /// Accept clients until a `Shutdown` frame arrives, then drain the
    /// handler threads, dump the obs trace (if configured) and return.
    pub fn run(self) -> anyhow::Result<()> {
        let state = self.state;
        let mut handlers = Vec::new();
        for stream in self.listener.incoming() {
            if state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let id = state.next_conn.fetch_add(1, Ordering::SeqCst);
                    if let Ok(clone) = s.try_clone() {
                        state.conns.lock().unwrap().insert(id, clone);
                    }
                    let st = Arc::clone(&state);
                    handlers.push(thread::spawn(move || {
                        handle_conn(s, &st);
                        st.conns.lock().unwrap().remove(&id);
                    }));
                }
                Err(e) => eprintln!("serve: accept failed: {e}"),
            }
        }
        for h in handlers {
            let _ = h.join();
        }
        if let Some(dir) = state.obs_dir.clone() {
            if let Err(e) = state.write_obs_trace(&dir) {
                eprintln!("serve: {e:#}");
            }
        }
        let stats = state.stats_json().to_string();
        println!("serve: shutdown; final stats: {stats}");
        Ok(())
    }

    /// Run the daemon on a background thread; returns a handle carrying
    /// the bound address.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local_addr();
        ServerHandle {
            addr,
            thread: thread::spawn(move || self.run()),
        }
    }
}

pub struct ServerHandle {
    addr: String,
    thread: thread::JoinHandle<anyhow::Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Wait for the daemon to shut down (send it a `Shutdown` frame
    /// first, e.g. via `ServeClient::shutdown`).
    pub fn join(self) -> anyhow::Result<()> {
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("server thread panicked")),
        }
    }
}

/// Write one reply frame; `false` = the client is gone (drop the
/// connection, never the daemon).
fn send(
    stream: &mut TcpStream,
    out: &mut Vec<u8>,
    t: MsgType,
    chan: u32,
    seq: u64,
    body: &Json,
) -> bool {
    proto::send_json(stream, out, t, chan, seq, body).is_ok()
}

/// Serve one client connection until it closes, errors, or asks for
/// shutdown. Malformed frames are counted, logged and terminate only
/// this connection — a hostile or buggy client must never take the
/// daemon down.
fn handle_conn(mut stream: TcpStream, state: &Arc<State>) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    let mut payload = Vec::new();
    let mut out = Vec::new();
    let mut seq = 0u64;
    loop {
        let hdr = match read_frame(&mut stream, &mut payload) {
            Ok(h) => h,
            Err(WireError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(WireError::Io(e)) => {
                eprintln!("serve: client {peer}: i/o error: {e}");
                break;
            }
            Err(e) => {
                state.proto_errors.fetch_add(1, Ordering::SeqCst);
                eprintln!("serve: client {peer}: rejecting malformed frame: {e}");
                break;
            }
        };
        let keep = match hdr.msg_type {
            MsgType::SubmitJob => {
                handle_submit(&mut stream, &mut out, &mut seq, state, &payload, &peer)
            }
            MsgType::CacheStats => {
                let body = state.stats_json();
                send(&mut stream, &mut out, MsgType::CacheStats, 0, seq, &body)
            }
            MsgType::Shutdown => {
                let body = proto::status_json(0, "shutting-down", "");
                let _ = send(&mut stream, &mut out, MsgType::JobStatus, 0, seq, &body);
                state.begin_shutdown();
                false
            }
            other => {
                state.proto_errors.fetch_add(1, Ordering::SeqCst);
                eprintln!("serve: client {peer}: unexpected {other:?} frame; closing");
                false
            }
        };
        seq += 1;
        if !keep {
            break;
        }
    }
}

/// One `SubmitJob` request end to end; returns whether the connection
/// is still usable.
fn handle_submit(
    stream: &mut TcpStream,
    out: &mut Vec<u8>,
    seq: &mut u64,
    state: &Arc<State>,
    payload: &[u8],
    peer: &str,
) -> bool {
    let parsed = proto::parse_body(payload).and_then(|j| JobSpec::from_json(&j));
    let spec = match parsed {
        Ok(spec) => spec,
        Err(e) => {
            // a well-framed but invalid spec: report it and keep the
            // connection — this is the client's bug, not a wire fault
            state.proto_errors.fetch_add(1, Ordering::SeqCst);
            eprintln!("serve: client {peer}: bad job spec: {e:#}");
            let body = proto::status_json(0, "error", &format!("{e:#}"));
            return send(stream, out, MsgType::JobStatus, 0, *seq, &body);
        }
    };
    let job_id = state.next_job.fetch_add(1, Ordering::SeqCst) + 1;
    println!("serve: job {job_id} ({peer}): {}", spec.describe());
    // best-effort: even if the client is already gone, run the job to
    // completion so the cache still fills
    let body = proto::status_json(job_id, "running", "");
    let _ = send(stream, out, MsgType::JobStatus, job_id, *seq, &body);
    *seq += 1;
    match run_job(state, &spec, job_id) {
        Ok(outcome) => {
            println!(
                "serve: job {job_id}: {} in {:.3}s (world spike hash {:016x})",
                if outcome.hit { "hit" } else { "miss" },
                outcome.wall_s,
                outcome.world_hash
            );
            state.jobs_done.fetch_add(1, Ordering::SeqCst);
            let sent = send(stream, out, MsgType::JobResult, job_id, *seq, &outcome.to_json());
            if !sent {
                eprintln!(
                    "serve: job {job_id}: client {peer} went away before the result; \
                     job is cached regardless"
                );
            }
            sent
        }
        Err(e) => {
            eprintln!("serve: job {job_id} failed: {e:#}");
            let body = proto::status_json(job_id, "error", &format!("{e:#}"));
            send(stream, out, MsgType::JobStatus, job_id, *seq, &body)
        }
    }
}

/// Execute one job: warm fast path, else single-flight construction.
fn run_job(state: &Arc<State>, spec: &JobSpec, job_id: u32) -> anyhow::Result<JobOutcome> {
    let key = spec.cache_key();
    let t0 = Instant::now();
    let mut coalesced = false;
    loop {
        let warm = state.cache.lock().unwrap().acquire(key);
        if let Some(dir) = warm {
            return warm_job(state, spec, job_id, t0, coalesced, &dir, key);
        }
        // single-flight: first submitter of this key builds; identical
        // concurrent submits wait, then loop back to the cache check
        let flight = {
            let mut inflight = state.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(f) => Some(Arc::clone(f)),
                None => {
                    inflight.insert(key, Arc::new(Flight::default()));
                    None
                }
            }
        };
        if let Some(f) = flight {
            coalesced = true;
            state.coalesced.fetch_add(1, Ordering::SeqCst);
            f.wait();
            // on builder success the next acquire hits; on builder
            // failure the flight is gone and one waiter rebuilds
            continue;
        }
        // we won the builder slot — but a previous builder may have
        // admitted between our cache miss and the flight insert (it
        // clears its flight only after the admit, so seeing no flight
        // means any earlier admit is visible). Re-check before paying
        // a construction twice.
        let raced = state.cache.lock().unwrap().acquire(key);
        let outcome = match raced {
            Some(dir) => warm_job(state, spec, job_id, t0, coalesced, &dir, key),
            None => build_job(state, spec, key, job_id, t0, coalesced),
        };
        // clear the flight only after the cache admit, so woken waiters
        // cannot re-miss on a success
        if let Some(f) = state.inflight.lock().unwrap().remove(&key) {
            f.finish();
        }
        return outcome;
    }
}

/// The warm path: resume the pinned cache entry at `dir`, release the
/// pin, and report a hit with zero construction time.
fn warm_job(
    state: &Arc<State>,
    spec: &JobSpec,
    job_id: u32,
    t0: Instant,
    coalesced: bool,
    dir: &Path,
    key: u64,
) -> anyhow::Result<JobOutcome> {
    let run = {
        let _permit = state.sem.acquire();
        run_cluster_from_snapshot(dir, spec.t_ms)
    };
    state.cache.lock().unwrap().release(key);
    let results =
        run.with_context(|| format!("warm job {job_id}: resume from {}", dir.display()))?;
    Ok(JobOutcome {
        job_id,
        hit: true,
        coalesced,
        world_hash: proto::world_hash(&results),
        construction_s: 0.0,
        wall_s: t0.elapsed().as_secs_f64(),
        result: proto::results_json(&results),
    })
}

/// The cold path: construct, save into staging, simulate, admit.
fn build_job(
    state: &Arc<State>,
    spec: &JobSpec,
    key: u64,
    job_id: u32,
    t0: Instant,
    coalesced: bool,
) -> anyhow::Result<JobOutcome> {
    let staging = {
        let mut cache = state.cache.lock().unwrap();
        cache.note_miss();
        cache.staging_dir(key, job_id)
    };
    state.constructions.fetch_add(1, Ordering::SeqCst);
    let bal = spec.balanced();
    let cfg = spec.sim_config()?;
    let run = {
        let _permit = state.sem.acquire();
        run_cluster_construct_save(
            spec.ranks,
            &cfg,
            &move |sim: &mut Simulator| build_balanced(sim, &bal),
            spec.t_ms,
            &staging,
        )
    };
    let results = match run {
        Ok(r) => r,
        Err(e) => {
            let _ = std::fs::remove_dir_all(&staging);
            return Err(e.context(format!("cold job {job_id}: construct {}", spec.describe())));
        }
    };
    let construction_s = results
        .iter()
        .map(|r| r.phases.construction().as_secs_f64())
        .fold(0.0, f64::max);
    match state.cache.lock().unwrap().admit(key, &staging) {
        Ok(true) => {}
        Ok(false) => {
            println!("serve: job {job_id}: snapshot exceeds the cache budget; not cached")
        }
        Err(e) => {
            eprintln!("serve: job {job_id}: cache admit failed: {e:#}");
            let _ = std::fs::remove_dir_all(&staging);
        }
    }
    Ok(JobOutcome {
        job_id,
        hit: false,
        coalesced,
        world_hash: proto::world_hash(&results),
        construction_s,
        wall_s: t0.elapsed().as_secs_f64(),
        result: proto::results_json(&results),
    })
}
