//! Content-addressed snapshot cache (DESIGN.md §17).
//!
//! One cache entry = one complete world of construction snapshots
//! (`rank_<r>.snap`, step 0) living in `cache_dir/<key:016x>/`, where
//! `key` is [`JobSpec::cache_key`](super::proto::JobSpec::cache_key).
//! Entries are admitted by *renaming* a fully written staging directory
//! into place — atomic on one filesystem — so the cache never holds a
//! half-written world; anything left under `cache_dir/staging/` is a
//! crashed job and is swept at open.
//!
//! Eviction is byte-capped LRU over [`TickLru`] (the policy shared with
//! the procedural fanout cache), with one serve-specific twist: entries
//! a warm job is currently resuming from are *pinned* and skipped when
//! choosing a victim, so a running simulation never has its snapshot
//! files deleted underneath it. Hit/miss/eviction counts and resident
//! bytes are kept in an [`MetricsRegistry`] (`cache_hits` /
//! `cache_misses` / `cache_evictions` / `cache_bytes`), the same
//! catalog `nestgpu report` renders.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::obs::{CounterId, GaugeId, MetricsRegistry};
use crate::util::json::Json;
use crate::util::lru::TickLru;

/// Subdirectory for in-progress (not yet admitted) job snapshots.
pub const STAGING_DIR: &str = "staging";

struct Entry {
    key: u64,
    /// warm jobs currently resuming from this entry (eviction shield)
    pins: u32,
}

/// Byte-capped LRU of snapshot worlds on disk, keyed by construction
/// content hash. Not internally synchronized — the server wraps it in a
/// mutex and keeps simulations *outside* that lock (pinning bridges the
/// gap).
pub struct SnapshotCache {
    dir: PathBuf,
    lru: TickLru<Entry>,
    slot_of: HashMap<u64, usize>,
    free_slots: Vec<usize>,
    metrics: MetricsRegistry,
}

impl SnapshotCache {
    /// Open (or create) a cache directory, sweep stale staging debris,
    /// and re-index any complete entries a previous daemon left behind —
    /// restarts start warm. Entries beyond `cap_bytes` are evicted
    /// oldest-name-first (no access history survives a restart).
    pub fn open(dir: &Path, cap_bytes: u64) -> anyhow::Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("cannot create cache directory {}", dir.display()))?;
        let staging = dir.join(STAGING_DIR);
        if staging.exists() {
            std::fs::remove_dir_all(&staging)
                .with_context(|| format!("cannot sweep staging {}", staging.display()))?;
        }
        std::fs::create_dir_all(&staging)
            .with_context(|| format!("cannot create staging {}", staging.display()))?;

        let mut found: Vec<(u64, u64)> = Vec::new(); // (key, bytes)
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("cannot read cache directory {}", dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy().into_owned();
            if name == STAGING_DIR || !entry.path().is_dir() {
                continue;
            }
            let Some(key) = parse_key(&name) else {
                continue; // not ours; leave foreign files alone
            };
            // an admitted entry is complete by construction (atomic
            // rename), but guard against manual tampering
            if !entry.path().join(crate::snapshot::rank_file_name(0)).is_file() {
                eprintln!(
                    "serve: cache: dropping incomplete entry {}",
                    entry.path().display()
                );
                let _ = std::fs::remove_dir_all(entry.path());
                continue;
            }
            found.push((key, dir_bytes(&entry.path())?));
        }
        found.sort_unstable(); // deterministic slot/tick assignment

        let mut cache = Self {
            dir: dir.to_path_buf(),
            lru: TickLru::new(found.len(), cap_bytes),
            slot_of: HashMap::new(),
            free_slots: Vec::new(),
            metrics: MetricsRegistry::new(),
        };
        for (slot, (key, bytes)) in found.into_iter().enumerate() {
            cache.lru.insert(slot, Entry { key, pins: 0 }, bytes);
            cache.slot_of.insert(key, slot);
        }
        while cache.lru.used_bytes() > cap_bytes {
            match cache.lru.victim(|_, _| false) {
                Some(v) => cache.evict_slot(v),
                None => break,
            }
        }
        cache.update_bytes_gauge();
        Ok(cache)
    }

    /// Look up `key`; on a hit, refresh its LRU tick, pin it against
    /// eviction and return its directory. Counts a `cache_hits` event.
    /// The caller must [`release`](Self::release) after the warm run.
    pub fn acquire(&mut self, key: u64) -> Option<PathBuf> {
        let slot = *self.slot_of.get(&key)?;
        self.lru.touch(slot)?;
        if let Some(e) = self.lru.peek_mut(slot) {
            e.pins += 1;
        }
        self.metrics.add(CounterId::CacheHits, 1);
        Some(self.entry_dir(key))
    }

    /// Drop one pin on `key` (no-op if the entry is gone).
    pub fn release(&mut self, key: u64) {
        if let Some(&slot) = self.slot_of.get(&key) {
            if let Some(e) = self.lru.peek_mut(slot) {
                e.pins = e.pins.saturating_sub(1);
            }
        }
    }

    /// Count a `cache_misses` event (the job is going to construct).
    pub fn note_miss(&mut self) {
        self.metrics.add(CounterId::CacheMisses, 1);
    }

    /// Admit the fully written snapshot world at `staged` as `key`:
    /// evict unpinned LRU victims until it fits, then rename it into
    /// place. Returns `false` (and removes `staged`) if the entry is
    /// larger than the whole budget — the job itself already ran, it is
    /// just not cacheable. A concurrent duplicate admit is a no-op.
    pub fn admit(&mut self, key: u64, staged: &Path) -> anyhow::Result<bool> {
        if self.slot_of.contains_key(&key) {
            std::fs::remove_dir_all(staged).ok();
            return Ok(true);
        }
        let bytes = dir_bytes(staged)?;
        if bytes > self.lru.cap_bytes() {
            std::fs::remove_dir_all(staged).ok();
            return Ok(false);
        }
        while self.lru.used_bytes() + bytes > self.lru.cap_bytes() {
            match self.lru.victim(|_, e| e.pins > 0) {
                Some(v) => self.evict_slot(v),
                // everything live is pinned: admit over budget rather
                // than delete files under a running job; the next admit
                // or release re-converges
                None => break,
            }
        }
        let target = self.entry_dir(key);
        if target.exists() {
            std::fs::remove_dir_all(&target)
                .with_context(|| format!("cannot clear stale entry {}", target.display()))?;
        }
        std::fs::rename(staged, &target).with_context(|| {
            format!("cannot admit {} -> {}", staged.display(), target.display())
        })?;
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                let s = self.lru.n_slots();
                self.lru.ensure_slots(s + 1);
                s
            }
        };
        self.lru.insert(slot, Entry { key, pins: 0 }, bytes);
        self.slot_of.insert(key, slot);
        self.update_bytes_gauge();
        Ok(true)
    }

    /// A fresh staging directory path for a job about to construct.
    pub fn staging_dir(&self, key: u64, job_id: u32) -> PathBuf {
        self.dir.join(STAGING_DIR).join(format!("{key:016x}.{job_id}"))
    }

    fn evict_slot(&mut self, slot: usize) {
        let Some((entry, _)) = self.lru.remove(slot) else {
            return;
        };
        self.slot_of.remove(&entry.key);
        self.free_slots.push(slot);
        let dir = self.entry_dir(entry.key);
        if let Err(e) = std::fs::remove_dir_all(&dir) {
            eprintln!("serve: cache: cannot evict {}: {e}", dir.display());
        }
        self.metrics.add(CounterId::CacheEvictions, 1);
        self.update_bytes_gauge();
    }

    fn update_bytes_gauge(&mut self) {
        self.metrics.set(GaugeId::CacheBytes, self.lru.used_bytes());
    }

    fn entry_dir(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}"))
    }

    pub fn len(&self) -> usize {
        self.lru.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lru.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.lru.used_bytes()
    }

    pub fn cap_bytes(&self) -> u64 {
        self.lru.cap_bytes()
    }

    pub fn hits(&self) -> u64 {
        self.metrics.counter(CounterId::CacheHits)
    }

    pub fn misses(&self) -> u64 {
        self.metrics.counter(CounterId::CacheMisses)
    }

    pub fn evictions(&self) -> u64 {
        self.metrics.counter(CounterId::CacheEvictions)
    }

    /// The cache's metrics registry (hit/miss/eviction counters and the
    /// resident-bytes gauge) — merged into obs traces and `CacheStats`.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Cache-local part of the `CacheStats` reply body.
    pub fn stats_json(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("entries", Json::num(self.len() as f64)),
            ("used_bytes", Json::num(self.used_bytes() as f64)),
            ("cap_bytes", Json::num(self.cap_bytes() as f64)),
            ("hits", Json::num(self.hits() as f64)),
            ("misses", Json::num(self.misses() as f64)),
            ("evictions", Json::num(self.evictions() as f64)),
        ]
    }
}

/// Parse a 16-hex-digit entry directory name back into its key.
fn parse_key(name: &str) -> Option<u64> {
    if name.len() != 16 || !name.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(name, 16).ok()
}

/// Total size of the regular files directly inside `dir` (snapshot
/// worlds are flat: one `rank_<r>.snap` per rank).
fn dir_bytes(dir: &Path) -> anyhow::Result<u64> {
    let mut total = 0u64;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("cannot size cache entry {}", dir.display()))?
    {
        let entry = entry?;
        let meta = entry.metadata()?;
        if meta.is_file() {
            total += meta.len();
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "nestgpu_serve_cache_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Write a fake staged snapshot world of `bytes` total size.
    fn stage(cache: &SnapshotCache, key: u64, job: u32, bytes: usize) -> PathBuf {
        let dir = cache.staging_dir(key, job);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(crate::snapshot::rank_file_name(0)), vec![0u8; bytes]).unwrap();
        dir
    }

    #[test]
    fn admit_acquire_evict_cycle() {
        let root = temp_dir("cycle");
        let mut cache = SnapshotCache::open(&root, 100).unwrap();
        assert!(cache.is_empty());
        assert_eq!(cache.acquire(1), None, "cold cache has no entries");

        let staged = stage(&cache, 1, 1, 60);
        assert!(cache.admit(1, &staged).unwrap());
        assert!(!staged.exists(), "staging dir is renamed away");
        let hit = cache.acquire(1).expect("admitted entry hits");
        assert!(hit.join(crate::snapshot::rank_file_name(0)).is_file());
        cache.release(1);

        // a second entry that does not fit evicts the (unpinned) first
        let staged = stage(&cache, 2, 2, 60);
        assert!(cache.admit(2, &staged).unwrap());
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.acquire(1), None, "evicted entry misses");
        cache.note_miss();
        assert!(cache.acquire(2).is_some());
        cache.release(2);
        assert_eq!((cache.hits(), cache.misses()), (2, 1));
        assert_eq!(cache.used_bytes(), 60);

        // oversized entries are rejected and swept, cache untouched
        let staged = stage(&cache, 3, 3, 200);
        assert!(!cache.admit(3, &staged).unwrap());
        assert!(!staged.exists());
        assert_eq!(cache.len(), 1);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let root = temp_dir("pins");
        let mut cache = SnapshotCache::open(&root, 100).unwrap();
        let staged = stage(&cache, 7, 1, 80);
        cache.admit(7, &staged).unwrap();
        let pinned = cache.acquire(7).unwrap();

        // over-budget admit while the only victim is pinned: the new
        // entry still lands and the pinned files stay on disk
        let staged = stage(&cache, 8, 2, 80);
        cache.admit(8, &staged).unwrap();
        assert_eq!(cache.evictions(), 0);
        assert!(pinned.join(crate::snapshot::rank_file_name(0)).is_file());
        assert_eq!(cache.len(), 2);
        assert!(cache.used_bytes() > cache.cap_bytes());

        // once released, the LRU entry becomes evictable again
        cache.release(7);
        let staged = stage(&cache, 9, 3, 80);
        cache.admit(9, &staged).unwrap();
        assert!(cache.evictions() >= 1);
        assert!(cache.acquire(7).is_none(), "7 was the LRU victim");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_reindexes_entries_and_sweeps_staging() {
        let root = temp_dir("reopen");
        {
            let mut cache = SnapshotCache::open(&root, 1000).unwrap();
            let staged = stage(&cache, 11, 1, 40);
            cache.admit(11, &staged).unwrap();
            let _ = stage(&cache, 12, 2, 40); // crashed job: never admitted
        }
        let mut cache = SnapshotCache::open(&root, 1000).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.used_bytes(), 40);
        assert!(cache.acquire(11).is_some());
        assert!(
            !root.join(STAGING_DIR).join(format!("{:016x}.2", 12)).exists(),
            "stale staging is swept at open"
        );
        // reopening with a smaller budget evicts down to fit
        cache.release(11);
        drop(cache);
        let cache = SnapshotCache::open(&root, 10).unwrap();
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.evictions(), 1);
        let _ = std::fs::remove_dir_all(&root);
    }
}
