//! Serve-protocol message bodies (DESIGN.md §17).
//!
//! Every serve message is one NGS1 frame (`comm::wire`) whose payload is
//! a JSON document (`util::json`) — the daemon reuses the socket
//! transport's framing, validation and size limits rather than inventing
//! a second wire format. Frame `msg_type` selects the message
//! ([`MsgType::SubmitJob`] / `JobStatus` / `JobResult` / `CacheStats` /
//! `Shutdown`); `channel` carries the job id on job-scoped replies.
//!
//! [`JobSpec`] is the unit of content addressing: its
//! [`cache_key`](JobSpec::cache_key) folds every construction-relevant
//! parameter (model, rank layout, `SimConfig` knobs, connectivity mode,
//! snapshot format version) through FNV-1a 64 — deliberately *excluding*
//! the simulated duration `t_ms`, because the cached artifact is the
//! post-`prepare()` construction snapshot (step 0), which jobs of any
//! duration share.

use std::io::Write;

use anyhow::Context;

use crate::comm::wire::{begin_frame, finish_frame, MsgType};
use crate::connection::Connectivity;
use crate::engine::{SimConfig, SimResult};
use crate::models::balanced::{BalancedConfig, StdpScenario};
use crate::remote::levels::ALL_LEVELS;
use crate::remote::GpuMemLevel;
use crate::snapshot::format::fnv1a64;
use crate::snapshot::FORMAT_VERSION;
use crate::stats::{combine_rank_hashes, spike_hash};
use crate::util::json::Json;

/// Bump on any change to the canonical key string below: old cache
/// directories must miss, never alias, after a key-derivation change.
pub const CACHE_KEY_VERSION: u32 = 1;

/// Upper bound on the rank count a daemon will run for one job — each
/// rank is a live thread with its own engine state, so an unchecked
/// client integer must not fork a thousand threads.
pub const MAX_JOB_RANKS: usize = 64;

/// One simulation request: the balanced model plus the
/// construction-relevant `SimConfig` knobs a client may vary.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub ranks: usize,
    /// simulated model time (ms). *Not* part of the cache key (see the
    /// module docs).
    pub t_ms: f64,
    pub scale: f64,
    pub k_scale: f64,
    pub seed: u64,
    /// GPU memory level index (0..=3)
    pub level: usize,
    /// spike-exchange batching interval; `None` = auto (min delay)
    pub exchange_interval: Option<u16>,
    pub connectivity: Connectivity,
    /// collective (true) vs point-to-point spike exchange
    pub collective: bool,
    pub stdp: Option<StdpScenario>,
}

impl Default for JobSpec {
    fn default() -> Self {
        let bal = BalancedConfig::default();
        let sim = SimConfig::default();
        Self {
            ranks: 2,
            t_ms: 100.0,
            scale: bal.scale,
            k_scale: bal.k_scale,
            seed: sim.seed,
            level: ALL_LEVELS
                .iter()
                .position(|&l| l == sim.level)
                .expect("default level is in ALL_LEVELS"),
            exchange_interval: sim.exchange_interval,
            connectivity: sim.connectivity,
            collective: bal.collective,
            stdp: None,
        }
    }
}

impl JobSpec {
    /// Content-address of this spec's construction: FNV-1a 64 over a
    /// canonical string of every parameter that changes the constructed
    /// network or the snapshot bytes. Floats are keyed by their exact
    /// bit patterns, so two specs collide only if they construct
    /// bit-identical networks.
    pub fn cache_key(&self) -> u64 {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = write!(
            s,
            "serve-key-v{CACHE_KEY_VERSION};snap-v{FORMAT_VERSION};model=balanced;\
             ranks={};seed={};level={};interval={};conn={};collective={};\
             scale={:016x};k_scale={:016x}",
            self.ranks,
            self.seed,
            self.level,
            match self.exchange_interval {
                Some(i) => i.to_string(),
                None => "auto".to_string(),
            },
            self.connectivity.name(),
            self.collective,
            self.scale.to_bits(),
            self.k_scale.to_bits(),
        );
        match &self.stdp {
            None => s.push_str(";stdp=none"),
            Some(st) => {
                let _ = write!(
                    s,
                    ";stdp={:016x},{:016x},{:016x},{:016x},{:016x},{}",
                    st.lambda.to_bits(),
                    st.alpha.to_bits(),
                    st.tau_plus_ms.to_bits(),
                    st.tau_minus_ms.to_bits(),
                    st.w_max_factor.to_bits(),
                    st.multiplicative,
                );
            }
        }
        fnv1a64(s.as_bytes())
    }

    /// The balanced-model configuration this spec constructs.
    pub fn balanced(&self) -> BalancedConfig {
        BalancedConfig {
            scale: self.scale,
            k_scale: self.k_scale,
            collective: self.collective,
            stdp: self.stdp.clone(),
            ..Default::default()
        }
    }

    /// The engine configuration this spec runs under (spike recording
    /// on: the world spike hash is the bit-identity witness).
    pub fn sim_config(&self) -> anyhow::Result<SimConfig> {
        let level = GpuMemLevel::from_index(self.level).ok_or_else(|| {
            anyhow::anyhow!(
                "level index {} out of range (0..={})",
                self.level,
                ALL_LEVELS.len() - 1
            )
        })?;
        Ok(SimConfig {
            seed: self.seed,
            level,
            exchange_interval: self.exchange_interval,
            connectivity: self.connectivity,
            ..Default::default()
        })
    }

    /// One-line description for server logs.
    pub fn describe(&self) -> String {
        format!(
            "balanced ranks={} scale={} k_scale={} seed={} t_ms={} conn={}{}",
            self.ranks,
            self.scale,
            self.k_scale,
            self.seed,
            self.t_ms,
            self.connectivity.name(),
            if self.stdp.is_some() { " stdp" } else { "" },
        )
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::str("balanced")),
            ("ranks", Json::num(self.ranks as f64)),
            ("t_ms", Json::num(self.t_ms)),
            ("scale", Json::num(self.scale)),
            ("k_scale", Json::num(self.k_scale)),
            ("seed", Json::num(self.seed as f64)),
            ("level", Json::num(self.level as f64)),
            ("connectivity", Json::str(self.connectivity.name())),
            ("collective", Json::Bool(self.collective)),
        ];
        if let Some(i) = self.exchange_interval {
            pairs.push(("exchange_interval", Json::num(f64::from(i))));
        }
        if let Some(st) = &self.stdp {
            pairs.push((
                "stdp",
                Json::obj(vec![
                    ("lambda", Json::num(st.lambda)),
                    ("alpha", Json::num(st.alpha)),
                    ("tau_plus_ms", Json::num(st.tau_plus_ms)),
                    ("tau_minus_ms", Json::num(st.tau_minus_ms)),
                    ("w_max_factor", Json::num(st.w_max_factor)),
                    ("multiplicative", Json::Bool(st.multiplicative)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Decode and validate a client-submitted spec. Absent fields take
    /// the [`Default`] values; out-of-range ones are rejected here, at
    /// the trust boundary, before any engine state exists.
    pub fn from_json(j: &Json) -> anyhow::Result<JobSpec> {
        let model = j.get("model").and_then(Json::as_str).unwrap_or("balanced");
        if model != "balanced" {
            anyhow::bail!("unknown model {model:?} (this server serves \"balanced\")");
        }
        let d = JobSpec::default();
        let num = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
        let spec = JobSpec {
            ranks: num("ranks", d.ranks as f64) as usize,
            t_ms: num("t_ms", d.t_ms),
            scale: num("scale", d.scale),
            k_scale: num("k_scale", d.k_scale),
            seed: num("seed", d.seed as f64) as u64,
            level: num("level", d.level as f64) as usize,
            exchange_interval: j.get("exchange_interval").and_then(Json::as_f64).map(|x| x as u16),
            connectivity: match j.get("connectivity").and_then(Json::as_str) {
                None => d.connectivity,
                Some(s) => Connectivity::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("unknown connectivity {s:?}"))?,
            },
            collective: match j.get("collective") {
                Some(Json::Bool(b)) => *b,
                _ => d.collective,
            },
            stdp: match j.get("stdp") {
                None => None,
                Some(st) => {
                    let ds = StdpScenario::default();
                    let snum =
                        |key: &str, dv: f64| st.get(key).and_then(Json::as_f64).unwrap_or(dv);
                    Some(StdpScenario {
                        lambda: snum("lambda", ds.lambda),
                        alpha: snum("alpha", ds.alpha),
                        tau_plus_ms: snum("tau_plus_ms", ds.tau_plus_ms),
                        tau_minus_ms: snum("tau_minus_ms", ds.tau_minus_ms),
                        w_max_factor: snum("w_max_factor", ds.w_max_factor),
                        multiplicative: matches!(st.get("multiplicative"), Some(Json::Bool(true))),
                    })
                }
            },
        };
        if spec.ranks == 0 || spec.ranks > MAX_JOB_RANKS {
            anyhow::bail!("ranks must be in 1..={MAX_JOB_RANKS} (got {})", spec.ranks);
        }
        if !spec.t_ms.is_finite() || spec.t_ms < 0.0 {
            anyhow::bail!("t_ms must be finite and >= 0 (got {})", spec.t_ms);
        }
        if !(spec.scale.is_finite() && spec.scale > 0.0)
            || !(spec.k_scale.is_finite() && spec.k_scale > 0.0)
        {
            anyhow::bail!(
                "scale and k_scale must be finite and > 0 (got {} / {})",
                spec.scale,
                spec.k_scale
            );
        }
        spec.sim_config()?; // validates the level index
        Ok(spec)
    }
}

/// Final reply to one `SubmitJob`.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub job_id: u32,
    /// served from the snapshot cache — construction skipped entirely
    pub hit: bool,
    /// waited on an identical in-flight construction (single-flight)
    pub coalesced: bool,
    /// world-combined spike hash — the bit-identity witness
    pub world_hash: u64,
    /// max-over-ranks construction wall time (0 on the warm path)
    pub construction_s: f64,
    /// end-to-end job wall time as measured by the server
    pub wall_s: f64,
    /// world totals + per-rank rows (see [`results_json`])
    pub result: Json,
}

impl JobOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job_id", Json::num(f64::from(self.job_id))),
            ("cache", Json::str(if self.hit { "hit" } else { "miss" })),
            ("coalesced", Json::Bool(self.coalesced)),
            ("world_spike_hash", Json::str(&format!("{:016x}", self.world_hash))),
            ("construction_s", Json::num(self.construction_s)),
            ("wall_s", Json::num(self.wall_s)),
            ("result", self.result.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<JobOutcome> {
        let hash = j
            .get("world_spike_hash")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("JobResult without world_spike_hash"))?;
        let world_hash = u64::from_str_radix(hash, 16)
            .with_context(|| format!("bad world_spike_hash {hash:?}"))?;
        Ok(JobOutcome {
            job_id: j.get("job_id").and_then(Json::as_f64).unwrap_or(0.0) as u32,
            hit: j.get("cache").and_then(Json::as_str) == Some("hit"),
            coalesced: matches!(j.get("coalesced"), Some(Json::Bool(true))),
            world_hash,
            construction_s: j.get("construction_s").and_then(Json::as_f64).unwrap_or(0.0),
            wall_s: j.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
            result: j.get("result").cloned().unwrap_or(Json::Null),
        })
    }
}

/// World-combined spike hash of a cluster run: per-rank
/// [`spike_hash`] folded through [`combine_rank_hashes`] — the same
/// derivation every simulation subcommand prints.
pub fn world_hash(results: &[SimResult]) -> u64 {
    let hashes: Vec<u64> = results.iter().map(|r| spike_hash(&r.spikes)).collect();
    combine_rank_hashes(&hashes)
}

/// Compact result summary shipped inside a [`JobOutcome`]: world totals
/// plus one small row per rank.
pub fn results_json(results: &[SimResult]) -> Json {
    let n_neurons: u64 = results.iter().map(|r| r.n_neurons).sum();
    let n_connections: u64 = results.iter().map(|r| r.n_connections).sum();
    let n_spikes: u64 = results.iter().map(|r| r.n_spikes).sum();
    let ranks: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("rank", Json::num(r.rank as f64)),
                ("n_neurons", Json::num(r.n_neurons as f64)),
                ("n_connections", Json::num(r.n_connections as f64)),
                ("n_spikes", Json::num(r.n_spikes as f64)),
                ("rtf", Json::num(r.rtf)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n_ranks", Json::num(results.len() as f64)),
        ("n_neurons", Json::num(n_neurons as f64)),
        ("n_connections", Json::num(n_connections as f64)),
        ("n_spikes", Json::num(n_spikes as f64)),
        ("model_time_ms", Json::num(results.first().map_or(0.0, |r| r.model_time_ms))),
        ("ranks", Json::Arr(ranks)),
    ])
}

/// `JobStatus` body: a state transition ("running") or an error report
/// (state "error" with the failure in `detail`).
pub fn status_json(job_id: u32, state: &str, detail: &str) -> Json {
    Json::obj(vec![
        ("job_id", Json::num(f64::from(job_id))),
        ("state", Json::str(state)),
        ("detail", Json::str(detail)),
    ])
}

/// Serialize one JSON-bodied frame into `buf` (cleared first) and write
/// it to `w` whole.
pub fn send_json<W: Write>(
    w: &mut W,
    buf: &mut Vec<u8>,
    msg_type: MsgType,
    channel: u32,
    seq: u64,
    body: &Json,
) -> std::io::Result<()> {
    buf.clear();
    let start = begin_frame(buf, msg_type, channel, seq);
    buf.extend_from_slice(body.to_string().as_bytes());
    finish_frame(buf, start);
    w.write_all(buf)
}

/// Parse a frame payload as a JSON document.
pub fn parse_body(payload: &[u8]) -> anyhow::Result<Json> {
    let text = std::str::from_utf8(payload).context("frame payload is not UTF-8")?;
    Json::parse(text).map_err(|e| anyhow::anyhow!("frame payload is not JSON: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_roundtrips_through_json() {
        let spec = JobSpec {
            ranks: 3,
            t_ms: 40.0,
            scale: 0.02,
            k_scale: 0.03,
            seed: 777,
            level: 1,
            exchange_interval: Some(5),
            connectivity: Connectivity::Procedural,
            collective: false,
            stdp: Some(StdpScenario {
                lambda: 0.05,
                multiplicative: true,
                ..Default::default()
            }),
        };
        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.cache_key(), spec.cache_key());
        assert_eq!(back.ranks, 3);
        assert_eq!(back.t_ms, 40.0);
        assert_eq!(back.exchange_interval, Some(5));
        assert_eq!(back.connectivity, Connectivity::Procedural);
        assert!(!back.collective);
        let st = back.stdp.expect("stdp survives the roundtrip");
        assert_eq!(st.lambda, 0.05);
        assert!(st.multiplicative);
    }

    #[test]
    fn cache_key_ignores_t_ms_but_not_construction_params() {
        let a = JobSpec::default();
        let longer = JobSpec {
            t_ms: a.t_ms * 10.0,
            ..a.clone()
        };
        assert_eq!(a.cache_key(), longer.cache_key(), "t_ms must not key");
        for other in [
            JobSpec { ranks: a.ranks + 1, ..a.clone() },
            JobSpec { seed: a.seed + 1, ..a.clone() },
            JobSpec { scale: a.scale * 2.0, ..a.clone() },
            JobSpec { k_scale: a.k_scale * 2.0, ..a.clone() },
            JobSpec { level: 0, ..a.clone() },
            JobSpec { exchange_interval: Some(1), ..a.clone() },
            JobSpec { connectivity: Connectivity::Procedural, ..a.clone() },
            JobSpec { collective: !a.collective, ..a.clone() },
            JobSpec { stdp: Some(StdpScenario::default()), ..a.clone() },
        ] {
            assert_ne!(a.cache_key(), other.cache_key(), "{other:?}");
        }
    }

    #[test]
    fn bad_specs_are_rejected_at_the_trust_boundary() {
        for (field, value) in [
            ("ranks", Json::num(0.0)),
            ("ranks", Json::num(1e9)),
            ("t_ms", Json::num(-1.0)),
            ("scale", Json::num(0.0)),
            ("level", Json::num(99.0)),
            ("connectivity", Json::str("quantum")),
            ("model", Json::str("mam")),
        ] {
            let body = Json::obj(vec![(field, value)]);
            assert!(JobSpec::from_json(&body).is_err(), "{field} must reject");
        }
    }

    #[test]
    fn job_outcome_roundtrips_through_json() {
        let out = JobOutcome {
            job_id: 9,
            hit: true,
            coalesced: true,
            world_hash: 0xDEAD_BEEF_0123_4567,
            construction_s: 0.0,
            wall_s: 1.5,
            result: Json::obj(vec![("n_spikes", Json::num(42.0))]),
        };
        let back = JobOutcome::from_json(&out.to_json()).unwrap();
        assert_eq!(back.job_id, 9);
        assert!(back.hit && back.coalesced);
        assert_eq!(back.world_hash, out.world_hash);
        assert_eq!(back.result.get("n_spikes").and_then(Json::as_f64), Some(42.0));
    }
}
