//! Construction-cache service (DESIGN.md §17): `nestgpu serve`.
//!
//! The paper's bottom line is that network construction dominates
//! repeated-run workflows; snapshots (DESIGN.md §10) already make it a
//! payable-once cost for one user. This subsystem composes the shelf —
//! versioned snapshots, the framed `NGS1` wire protocol, the obs metrics
//! registry and the tick-LRU — into a multi-tenant daemon that makes
//! construction payable-once *per content hash across users*:
//!
//! - [`cache`]: a content-addressed, byte-capped LRU of snapshot worlds
//!   on disk ([`SnapshotCache`]), keyed by
//!   [`JobSpec::cache_key`] — an FNV-1a 64 fold of every
//!   construction-relevant parameter.
//! - [`server`]: the job executor ([`Server`]) — single-flight
//!   deduplication of identical in-flight constructions, a concurrency
//!   bound, cold construct-then-save vs warm resume, all through the
//!   existing `harness` entry points.
//! - [`client`] / [`proto`]: the framed JSON protocol
//!   (`SubmitJob` / `JobStatus` / `JobResult` / `CacheStats` /
//!   `Shutdown`) and the blocking [`ServeClient`] behind
//!   `nestgpu submit`.
//!
//! Every job outcome carries the world spike hash, so a client can
//! verify that a cache hit reproduced the cold run bit-identically.

pub mod cache;
pub mod client;
pub mod proto;
pub mod server;

pub use cache::SnapshotCache;
pub use client::ServeClient;
pub use proto::{JobOutcome, JobSpec};
pub use server::{ServeConfig, Server, ServerHandle};
