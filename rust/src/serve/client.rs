//! Blocking client of the construction-cache daemon (`nestgpu submit`).

use std::io::Write;
use std::net::TcpStream;

use anyhow::Context;

use crate::comm::wire::{read_frame, FrameHeader, MsgType};
use crate::util::json::Json;

use super::proto::{self, JobOutcome, JobSpec};

/// One connection to a `nestgpu serve` daemon. Submissions are
/// synchronous: [`submit`](Self::submit) blocks until the job's final
/// `JobResult` (or error status) arrives.
pub struct ServeClient {
    stream: TcpStream,
    payload: Vec<u8>,
    out: Vec<u8>,
    seq: u64,
}

impl ServeClient {
    pub fn connect(server: &str) -> anyhow::Result<ServeClient> {
        let stream = TcpStream::connect(server)
            .with_context(|| format!("cannot connect to serve daemon at {server}"))?;
        Ok(ServeClient {
            stream,
            payload: Vec::new(),
            out: Vec::new(),
            seq: 0,
        })
    }

    fn send(&mut self, t: MsgType, body: &Json) -> anyhow::Result<()> {
        proto::send_json(&mut self.stream, &mut self.out, t, 0, self.seq, body)
            .context("send to serve daemon")?;
        self.seq += 1;
        self.stream.flush().ok();
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<(FrameHeader, Json)> {
        let hdr = read_frame(&mut self.stream, &mut self.payload)
            .map_err(|e| anyhow::anyhow!("serve daemon connection: {e}"))?;
        let body = proto::parse_body(&self.payload)?;
        Ok((hdr, body))
    }

    /// Submit a job and block until its outcome. Intermediate
    /// `JobStatus` updates are reported through `on_status`; an error
    /// status terminates the job as an `Err`.
    pub fn submit_with(
        &mut self,
        spec: &JobSpec,
        mut on_status: impl FnMut(&str, &str),
    ) -> anyhow::Result<JobOutcome> {
        self.send(MsgType::SubmitJob, &spec.to_json())?;
        loop {
            let (hdr, body) = self.recv()?;
            match hdr.msg_type {
                MsgType::JobStatus => {
                    let state = body.get("state").and_then(Json::as_str).unwrap_or("?");
                    let detail = body.get("detail").and_then(Json::as_str).unwrap_or("");
                    if state == "error" {
                        anyhow::bail!("job failed on the server: {detail}");
                    }
                    on_status(state, detail);
                }
                MsgType::JobResult => return JobOutcome::from_json(&body),
                other => anyhow::bail!("unexpected {other:?} reply to SubmitJob"),
            }
        }
    }

    /// [`submit_with`](Self::submit_with) discarding status updates.
    pub fn submit(&mut self, spec: &JobSpec) -> anyhow::Result<JobOutcome> {
        self.submit_with(spec, |_, _| {})
    }

    /// Fetch the daemon's cache/executor statistics.
    pub fn stats(&mut self) -> anyhow::Result<Json> {
        self.send(MsgType::CacheStats, &Json::obj(Vec::new()))?;
        let (hdr, body) = self.recv()?;
        if hdr.msg_type != MsgType::CacheStats {
            anyhow::bail!("unexpected {:?} reply to CacheStats", hdr.msg_type);
        }
        Ok(body)
    }

    /// Ask the daemon to shut down (acknowledged before it exits).
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        self.send(MsgType::Shutdown, &Json::obj(Vec::new()))?;
        let _ = self.recv(); // best-effort ack; the daemon is going away
        Ok(())
    }
}
