//! Persistent per-step scratch state of the propagation pipeline.
//!
//! Every buffer the hot loop writes — the spiking-node list, the per-rank
//! p2p packets, the per-group collective payloads, the staged-translation
//! buffer, the allgather receive buffers and the canonical-replay cursors
//! — lives here and is reused across steps, so `step_once` performs no
//! steady-state heap allocation (buffers grow to the high-water mark of
//! the run and stay there).

use super::delivery::DeliveryQueue;
use crate::comm::SpikeRecord;

/// Reusable buffers of the step pipeline, owned by the `Simulator` and
/// sized once at `prepare()` (or snapshot restore).
#[derive(Debug, Default)]
pub struct StepScratch {
    /// node ids that spiked in the current step (collect phase)
    pub spiking: Vec<u32>,
    /// outgoing p2p packet per destination rank; accumulates lag-tagged
    /// records across the exchange interval, recycled through the
    /// communicator's mailbox after every exchange
    pub packets: Vec<Vec<SpikeRecord>>,
    /// outgoing collective payload per group (`[pos, (lag<<16)|mult]`
    /// word pairs), accumulated across the exchange interval
    pub group_bufs: Vec<Vec<u32>>,
    /// allgather receive buffers: per group, per member, reused forever
    pub gathered: Vec<Vec<Vec<u32>>>,
    /// canonical-replay cursor per source rank (records)
    pub pkt_cursor: Vec<usize>,
    /// canonical-replay cursor per group per member (payload words)
    pub coll_cursor: Vec<Vec<usize>>,
    /// staged host translation of incoming records:
    /// (image node, multiplicity, lag)
    pub staged: Vec<(u32, u16, u16)>,
    /// per-chunk first state index (fixed after `prepare()`; avoids
    /// re-deriving it from the chunk metadata every step)
    pub state_bases: Vec<usize>,
    /// steps accumulated since the last exchange (< exchange interval,
    /// except transiently inside `step_once`)
    pub interval_pos: u32,
    /// slot-bucketed run batches for local delivery (drained every step)
    pub local_q: DeliveryQueue,
    /// slot-bucketed run batches for remote delivery (drained per exchange)
    pub remote_q: DeliveryQueue,
}

impl StepScratch {
    /// Size the scratch for a prepared world (`group_sizes[g]` = member
    /// count of group `g`).
    pub fn for_world(n_ranks: usize, group_sizes: &[usize], state_bases: Vec<usize>) -> Self {
        Self {
            spiking: Vec::new(),
            packets: vec![Vec::new(); n_ranks],
            group_bufs: vec![Vec::new(); group_sizes.len()],
            gathered: group_sizes.iter().map(|&m| vec![Vec::new(); m]).collect(),
            pkt_cursor: vec![0; n_ranks],
            coll_cursor: group_sizes.iter().map(|&m| vec![0; m]).collect(),
            staged: Vec::new(),
            state_bases,
            interval_pos: 0,
            local_q: DeliveryQueue::default(),
            remote_q: DeliveryQueue::default(),
        }
    }

    /// Whether any routed spike records are waiting for the next exchange.
    /// (A nonzero `interval_pos` with no pending records is harmless: the
    /// exchange cadence restarts, which cannot change delivery slots.)
    pub fn has_pending_records(&self) -> bool {
        self.packets.iter().any(|p| !p.is_empty())
            || self.group_bufs.iter().any(|b| !b.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sized_for_world() {
        let s = StepScratch::for_world(4, &[3, 2], vec![0, 10]);
        assert_eq!(s.packets.len(), 4);
        assert_eq!(s.pkt_cursor.len(), 4);
        assert_eq!(s.group_bufs.len(), 2);
        assert_eq!(s.gathered[0].len(), 3);
        assert_eq!(s.gathered[1].len(), 2);
        assert_eq!(s.coll_cursor[1].len(), 2);
        assert_eq!(s.state_bases, vec![0, 10]);
        assert_eq!(s.interval_pos, 0);
        assert!(!s.has_pending_records());
    }

    #[test]
    fn pending_tracks_both_paths() {
        let mut s = StepScratch::for_world(2, &[2], vec![0]);
        assert!(!s.has_pending_records());
        s.packets[1].push(SpikeRecord {
            pos: 0,
            mult: 1,
            lag: 0,
        });
        assert!(s.has_pending_records());
        s.packets[1].clear();
        s.group_bufs[0].push(7);
        assert!(s.has_pending_records());
    }
}
