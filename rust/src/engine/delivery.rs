//! Cache-aware spike delivery: the per-node delivery plan, the slot-sorted
//! delivery queue, and the fused accumulation-plane merge (DESIGN.md §14).
//!
//! The naive delivery loop walks a node's outgoing connections in creation
//! order and, per record, re-derives the target's state index through the
//! node→state LUT, branches on the receptor port, and `%`-wraps the ring
//! cursor — a scattered, branchy access pattern that Pronold et al. (PAPERS
//! .md) identify as the cache bottleneck of NEST-style delivery. The
//! [`DeliveryPlan`] moves all of that to `prepare()` time:
//!
//! - every static connection is lowered to a *port-baked destination index*
//!   `port · n_state + state` into a merged `[slot][port][neuron]` ring row,
//!   eliminating the port branch and the LUT lookup from the hot loop;
//! - each node's block is reordered by a **stable** `(delay, port)` sort and
//!   summarized by a run directory, so delivery becomes branch-free runs of
//!   contiguous `row[dest] += w · mult` writes into a single ring slot;
//! - plastic connections are split into a per-node creation-order side list
//!   ([`PlasticLink`]): their arrival events must enqueue in creation order
//!   (the event ring's canonical-order key includes push order, DESIGN.md
//!   §12), so they are excluded from the sorted runs entirely;
//! - device (Poisson) blocks keep creation order — the input loop draws one
//!   RNG multiplicity per connection in creation order, which a sort would
//!   permute — and are served by the creation-order SoA view
//!   ([`DeliveryPlan::entries_of`]).
//!
//! Bit-identity argument: two entries that land in the *same* accumulator
//! cell share (target, port, delay), hence the same sort key, and a stable
//! sort preserves their relative (creation) order; entries landing in
//! different cells are independent f32 accumulators, so reordering across
//! cells cannot change any sum. The same argument covers the
//! [`DeliveryQueue`]: runs are pushed in canonical order and drained in
//! push order per slot bucket, and a cell lives in exactly one slot, so the
//! per-cell addition order is exactly the naive order.

use crate::connection::Connections;
use crate::memory::{MemKind, Tracker};
use crate::node::{NodeKind, NodeSpace, RingBuffers};
use crate::plasticity::PlasticityEngine;

/// One branch-free delivery run: a contiguous range of plan entries that
/// share a delay (and therefore a ring slot).
#[derive(Clone, Copy, Debug)]
pub struct Run {
    pub delay: u16,
    /// plan-global entry range `[start, end)` into the dest/weight SoA
    pub start: u32,
    pub end: u32,
}

/// One plastic connection of a node, in creation order: the plastic-slot
/// index of the arrival-event ring plus the synaptic delay.
#[derive(Clone, Copy, Debug)]
pub struct PlasticLink {
    pub slot: u32,
    pub delay: u16,
}

/// Prepared per-node delivery layout (derived state: rebuilt at
/// `prepare()` and at snapshot restore, never persisted — like the
/// node→state LUT it replaces in the hot loop). Its device residency IS
/// tracked ([`DeliveryPlan::bytes`]): the plan mirrors the connection
/// store entry-for-entry, so omitting it would halve the apparent
/// per-rank connectivity footprint in `fig5_memory_peak`.
#[derive(Debug, Default)]
pub struct DeliveryPlan {
    /// port-baked destination `port · n_state + state`, plan order
    dest: Vec<u32>,
    weight: Vec<f32>,
    delay: Vec<u16>,
    /// CSR into the entry SoA per node (`m + 1` offsets)
    first: Vec<u32>,
    runs: Vec<Run>,
    /// CSR into `runs` per node (`m + 1` offsets)
    run_first: Vec<u32>,
    /// plastic side lists, creation order within each node
    plastic: Vec<PlasticLink>,
    /// CSR into `plastic` per node (`m + 1` offsets)
    plastic_first: Vec<u32>,
}

impl DeliveryPlan {
    /// Lower a sorted connection store into the plan. `plast` marks the
    /// plastic connections (excluded from the sorted runs); device blocks
    /// keep creation order (see the module docs for both constraints).
    pub fn build(
        conns: &Connections,
        nodes: &NodeSpace,
        state_lut: &[u32],
        n_state: u32,
        plast: Option<&PlasticityEngine>,
    ) -> Self {
        let m = nodes.m() as usize;
        let mut plan = DeliveryPlan::default();
        plan.dest.reserve(conns.len());
        plan.weight.reserve(conns.len());
        plan.delay.reserve(conns.len());
        plan.first.reserve(m + 1);
        plan.run_first.reserve(m + 1);
        plan.plastic_first.reserve(m + 1);
        plan.first.push(0);
        plan.run_first.push(0);
        plan.plastic_first.push(0);
        let mut order: Vec<usize> = Vec::new();
        for node in 0..m as u32 {
            let rng = conns.outgoing(node);
            let v = conns.view(rng.clone());
            // devices keep creation order: the Poisson input loop draws
            // one RNG multiplicity per connection, in creation order, and
            // never takes the plastic path (matching the input phase)
            let is_device = matches!(nodes.kind(node), NodeKind::Device { .. });
            order.clear();
            for (i, k) in rng.enumerate() {
                let plastic = if is_device {
                    None
                } else {
                    plast.and_then(|p| p.plastic_slot(k))
                };
                match plastic {
                    Some(slot) => plan.plastic.push(PlasticLink {
                        slot,
                        delay: v.delay[i],
                    }),
                    None => order.push(i),
                }
            }
            if !is_device {
                // stable: entries of one accumulator cell share the key
                // (same target/port/delay), so their creation order — the
                // f32 addition order — is preserved
                order.sort_by_key(|&i| (v.delay[i], v.port[i]));
            }
            let block_start = plan.dest.len();
            for &i in &order {
                let state = state_lut[v.target[i] as usize];
                debug_assert!(state != u32::MAX, "connection targets a non-neuron");
                let pos = plan.dest.len() as u32;
                plan.dest.push(u32::from(v.port[i]) * n_state + state);
                plan.weight.push(v.weight[i]);
                plan.delay.push(v.delay[i]);
                match plan.runs.last_mut() {
                    Some(last) if pos as usize > block_start && last.delay == v.delay[i] => {
                        last.end = pos + 1;
                    }
                    _ => plan.runs.push(Run {
                        delay: v.delay[i],
                        start: pos,
                        end: pos + 1,
                    }),
                }
            }
            plan.first.push(plan.dest.len() as u32);
            plan.run_first.push(plan.runs.len() as u32);
            plan.plastic_first.push(plan.plastic.len() as u32);
        }
        plan
    }

    /// The delivery runs of one node's static connections (plan order).
    #[inline]
    pub fn runs_of(&self, node: u32) -> &[Run] {
        let a = self.run_first[node as usize] as usize;
        let b = self.run_first[node as usize + 1] as usize;
        &self.runs[a..b]
    }

    /// The plastic links of one node, in creation order.
    #[inline]
    pub fn plastic_of(&self, node: u32) -> &[PlasticLink] {
        let a = self.plastic_first[node as usize] as usize;
        let b = self.plastic_first[node as usize + 1] as usize;
        &self.plastic[a..b]
    }

    /// The `(dest, weight)` entry slices of one run.
    #[inline]
    pub fn run_entries(&self, start: u32, end: u32) -> (&[u32], &[f32]) {
        (
            &self.dest[start as usize..end as usize],
            &self.weight[start as usize..end as usize],
        )
    }

    /// The `(dest, weight, delay)` SoA of one node's full static block —
    /// creation order for device nodes (the Poisson input path).
    #[inline]
    pub fn entries_of(&self, node: u32) -> (&[u32], &[f32], &[u16]) {
        let a = self.first[node as usize] as usize;
        let b = self.first[node as usize + 1] as usize;
        (&self.dest[a..b], &self.weight[a..b], &self.delay[a..b])
    }

    /// Total static entries in the plan (bench/test introspection).
    pub fn n_entries(&self) -> usize {
        self.dest.len()
    }

    /// Total runs in the plan (bench/test introspection).
    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }

    /// Device bytes of the plan: entry SoA, per-node CSR offsets, run
    /// directory, and plastic side lists. Registered with the tracker by
    /// the owner at build time so the procedural-vs-materialized memory
    /// comparison counts delivery state on both sides.
    pub fn bytes(&self) -> u64 {
        (self.dest.len() * 4
            + self.weight.len() * 4
            + self.delay.len() * 2
            + self.first.len() * 4
            + self.runs.len() * std::mem::size_of::<Run>()
            + self.run_first.len() * 4
            + self.plastic.len() * std::mem::size_of::<PlasticLink>()
            + self.plastic_first.len() * 4) as u64
    }
}

/// Slot-bucketed batch of delivery runs: the step's (or the exchange
/// round's) deliveries are collected per ring slot and drained in one
/// sweep, so writes stream through each slot row instead of hopping
/// between slots per record. Buckets are pushed in canonical delivery
/// order and drained in push order, which preserves the per-cell f32
/// addition order (a cell lives in exactly one slot).
#[derive(Debug, Default)]
pub struct DeliveryQueue {
    /// per ring slot: queued `(start, end, mult)` runs
    buckets: Vec<Vec<(u32, u32, u16)>>,
    /// bytes currently registered with the memory tracker
    tracked: u64,
}

impl DeliveryQueue {
    /// Host bytes held by the queue's buckets (capacities, not lengths —
    /// the buckets persist across steps at their high-water capacity).
    pub fn bytes(&self) -> u64 {
        let inner: usize = self
            .buckets
            .iter()
            .map(|b| b.capacity() * std::mem::size_of::<(u32, u32, u16)>())
            .sum();
        (self.buckets.capacity() * std::mem::size_of::<Vec<(u32, u32, u16)>>() + inner) as u64
    }

    /// Re-register the queue's current footprint with the tracker. Only
    /// touches the tracker when the byte count actually changed — an
    /// unconditional realloc would momentarily double-count and inflate
    /// the peak on every call.
    pub fn sync_tracker(&mut self, tr: &mut Tracker) {
        let now = self.bytes();
        if now != self.tracked {
            tr.realloc(MemKind::Host, self.tracked, now);
            self.tracked = now;
        }
    }
    /// Grow to cover `slots` ring slots (idempotent; buckets persist
    /// across steps, so this is allocation-free at steady state).
    pub fn ensure_slots(&mut self, slots: usize) {
        if self.buckets.len() < slots {
            self.buckets.resize_with(slots, Vec::new);
        }
    }

    /// Queue one run for `slot` with multiplicity `mult`.
    #[inline]
    pub fn push(&mut self, slot: usize, start: u32, end: u32, mult: u16) {
        self.buckets[slot].push((start, end, mult));
    }

    /// Deliver everything queued, slot by slot, and clear the buckets.
    pub fn drain_into(&mut self, rb: &mut RingBuffers, plan: &DeliveryPlan) {
        for (slot, bucket) in self.buckets.iter_mut().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let row = rb.row_mut(slot);
            for &(start, end, mult) in bucket.iter() {
                let (dest, weight) = plan.run_entries(start, end);
                if mult == 1 {
                    // w * 1.0 is bitwise w for every non-NaN weight
                    for (&d, &w) in dest.iter().zip(weight) {
                        row[d as usize] += w;
                    }
                } else {
                    let m = mult as f32;
                    for (&d, &w) in dest.iter().zip(weight) {
                        row[d as usize] += w * m;
                    }
                }
            }
            bucket.clear();
        }
    }
}

/// Fused accumulation-plane merge of the dynamics phase: one pass writing
/// `dst = local (+ remote) (+ plastic)` with the additions left-associated
/// exactly as the former copy-then-add-then-add sequence — bit-identical,
/// but one store per element instead of up to three read-modify-writes.
pub fn merge_planes(
    dst: &mut [f32],
    local: &[f32],
    remote: Option<&[f32]>,
    plastic: Option<&[f32]>,
) {
    match (remote, plastic) {
        (None, None) => dst.copy_from_slice(local),
        (Some(r), None) => {
            for ((d, &l), &r) in dst.iter_mut().zip(local).zip(r) {
                *d = l + r;
            }
        }
        (None, Some(p)) => {
            for ((d, &l), &p) in dst.iter_mut().zip(local).zip(p) {
                *d = l + p;
            }
        }
        (Some(r), Some(p)) => {
            for (((d, &l), &r), &p) in dst.iter_mut().zip(local).zip(r).zip(p) {
                *d = (l + r) + p;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// 3 neurons + 1 device; node→state identity for the neurons.
    fn world() -> (NodeSpace, Vec<u32>) {
        let mut nodes = NodeSpace::new();
        nodes.create_neurons(0, 3);
        nodes.create_device(0);
        (nodes, vec![0, 1, 2, u32::MAX])
    }

    #[test]
    fn queue_bytes_tracked_without_peak_inflation() {
        let mut tr = Tracker::new();
        let mut q = DeliveryQueue::default();
        q.sync_tracker(&mut tr);
        assert_eq!(tr.current(MemKind::Host), 0);
        q.ensure_slots(8);
        for _ in 0..100 {
            q.push(3, 0, 10, 1);
        }
        q.sync_tracker(&mut tr);
        let b = q.bytes();
        assert!(b > 0);
        assert_eq!(tr.current(MemKind::Host), b);
        let peak = tr.peak(MemKind::Host);
        // repeated syncs with unchanged capacity must not move the peak
        // (an unconditional realloc would double-count old + new)
        for _ in 0..10 {
            q.sync_tracker(&mut tr);
        }
        assert_eq!(tr.current(MemKind::Host), b);
        assert_eq!(tr.peak(MemKind::Host), peak);
    }

    #[test]
    fn runs_are_delay_sorted_and_port_baked() {
        let (nodes, lut) = world();
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        // node 0: mixed delays/ports, creation order deliberately shuffled
        c.push(0, 1, 1.0, 3, 0, &mut tr);
        c.push(0, 2, 2.0, 1, 1, &mut tr);
        c.push(0, 0, 3.0, 1, 0, &mut tr);
        c.push(0, 1, 4.0, 3, 0, &mut tr);
        c.sort_by_source(4, &mut tr);
        let plan = DeliveryPlan::build(&c, &nodes, &lut, 3, None);
        assert_eq!(plan.n_entries(), 4);
        // sorted (delay, port): (1,0)->n0, (1,1)->n2, (3,0)->n1, (3,0)->n1
        let (dest, weight, delay) = plan.entries_of(0);
        assert_eq!(dest, &[0, 3 + 2, 1, 1]); // port 1 bakes +n_state
        assert_eq!(weight, &[3.0, 2.0, 1.0, 4.0]);
        assert_eq!(delay, &[1, 1, 3, 3]);
        // two runs: delay 1 (both ports merged) and delay 3
        let runs = plan.runs_of(0);
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].delay, runs[0].start, runs[0].end), (1, 0, 2));
        assert_eq!((runs[1].delay, runs[1].start, runs[1].end), (3, 2, 4));
        assert!(plan.runs_of(1).is_empty() && plan.plastic_of(0).is_empty());
    }

    #[test]
    fn device_blocks_keep_creation_order() {
        let (nodes, lut) = world();
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        // device node 3: delays out of order must NOT be sorted
        c.push(3, 0, 1.0, 5, 0, &mut tr);
        c.push(3, 1, 2.0, 1, 1, &mut tr);
        c.push(3, 2, 3.0, 5, 0, &mut tr);
        c.sort_by_source(4, &mut tr);
        let plan = DeliveryPlan::build(&c, &nodes, &lut, 3, None);
        let (dest, weight, delay) = plan.entries_of(3);
        assert_eq!(delay, &[5, 1, 5]);
        assert_eq!(weight, &[1.0, 2.0, 3.0]);
        assert_eq!(dest, &[0, 3 + 1, 2]);
        // run directory still segments by contiguous delay
        assert_eq!(plan.runs_of(3).len(), 3);
    }

    #[test]
    fn queue_drain_matches_direct_adds_bitwise() {
        let (nodes, lut) = world();
        let mut tr = Tracker::new();
        let mut c = Connections::new();
        let mut rng = Rng::new(11);
        for _ in 0..40 {
            c.push(
                rng.below(3),
                rng.below(3),
                rng.uniform_range(-2.0, 2.0) as f32,
                1 + rng.below(6) as u16,
                rng.below(2) as u8,
                &mut tr,
            );
        }
        c.sort_by_source(4, &mut tr);
        let plan = DeliveryPlan::build(&c, &nodes, &lut, 3, None);
        let mut rb_naive = RingBuffers::new(3, 6, &mut tr);
        let mut rb_plan = RingBuffers::new(3, 6, &mut tr);
        let mut q = DeliveryQueue::default();
        q.ensure_slots(rb_plan.n_slots());
        for step in 0..20u32 {
            for node in 0..3u32 {
                if (step + node) % 3 != 0 {
                    continue;
                }
                let mult = 1 + (step % 3) as u16;
                let v = c.view(c.outgoing(node));
                for i in 0..v.target.len() {
                    let state = lut[v.target[i] as usize];
                    rb_naive.add(state, v.port[i], v.delay[i], v.weight[i], mult);
                }
                for run in plan.runs_of(node) {
                    q.push(rb_plan.slot_of(run.delay), run.start, run.end, mult);
                }
            }
            q.drain_into(&mut rb_plan, &plan);
            let (ea, ia) = rb_naive.current();
            let (eb, ib) = rb_plan.current();
            let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(ea), bits(eb), "ex plane diverged at step {step}");
            assert_eq!(bits(ia), bits(ib), "inh plane diverged at step {step}");
            rb_naive.advance();
            rb_plan.advance();
        }
    }

    #[test]
    fn merge_planes_is_bit_identical_to_sequential_adds() {
        let mut rng = Rng::new(5);
        let n = 97;
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.uniform_range(-3.0, 3.0) as f32).collect()
        };
        let (l, r, p) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        for (rem, pla) in [
            (None, None),
            (Some(&r), None),
            (None, Some(&p)),
            (Some(&r), Some(&p)),
        ] {
            let mut want = l.clone();
            if let Some(r) = rem {
                for (w, &x) in want.iter_mut().zip(r.iter()) {
                    *w += x;
                }
            }
            if let Some(p) = pla {
                for (w, &x) in want.iter_mut().zip(p.iter()) {
                    *w += x;
                }
            }
            let mut got = vec![0.0f32; n];
            merge_planes(&mut got, &l, rem.map(|v| v.as_slice()), pla.map(|v| v.as_slice()));
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }
}
