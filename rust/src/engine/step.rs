//! State propagation: the per-step pipeline with spike routing and
//! delivery (Appendix F; Figs. 1–2).
//!
//! Per time step:
//! 1. service Poisson generators into the ring buffers;
//! 2. hand the current ring-buffer slots to the dynamics backend (the
//!    AOT-compiled Pallas kernel via PJRT, or the native reference);
//! 3. collect spikes; deliver locally through the source-sorted connection
//!    array; route remotely by map *positions* via the (T, P) tables
//!    (point-to-point) and the (G, Q) tables (collective);
//! 4. exchange: all-to-all-v of p2p packets + one Allgather per group;
//! 5. deliver incoming remote spikes through the image neurons' outgoing
//!    connections (host-staged on GPU memory levels 0/1).

use std::time::Instant;

use crate::comm::SpikeRecord;
use crate::memory::MemKind;
use crate::node::RingBuffers;
use crate::remote::GpuMemLevel;

use super::simulator::{SimResult, Simulator};
use crate::connection::Connections;
use crate::util::timer::Phase;

/// Deliver through `node`'s outgoing connections into the ring buffers.
/// Free function over the split-out pieces so the borrows stay field-local.
#[inline]
fn deliver_outgoing(
    conns: &Connections,
    state_lut: &[u32],
    rb: &mut RingBuffers,
    node: u32,
    mult: u16,
) {
    let rng = conns.outgoing(node);
    let targets = &conns.target.as_slice()[rng.clone()];
    let ports = &conns.port.as_slice()[rng.clone()];
    let delays = &conns.delay.as_slice()[rng.clone()];
    let weights = &conns.weight.as_slice()[rng];
    for i in 0..targets.len() {
        let state = state_lut[targets[i] as usize];
        debug_assert!(state != u32::MAX, "connection targets a non-neuron");
        rb.add(state, ports[i], delays[i], weights[i], mult);
    }
}

impl Simulator {
    /// Run the propagation loop for `t_ms` of model time; returns the
    /// per-rank metrics including the real-time factor (Eq. 21).
    pub fn simulate(&mut self, t_ms: f64) -> anyhow::Result<SimResult> {
        assert!(self.is_prepared(), "call prepare() before simulate()");
        let steps = (t_ms / self.cfg.dt_ms).round() as u32;
        self.timer.enter(Phase::Propagation);
        let t0 = Instant::now();
        for _ in 0..steps {
            self.step_once()?;
        }
        let wall = t0.elapsed().as_secs_f64();
        self.timer.stop();
        let rtf = if t_ms > 0.0 { wall / (t_ms / 1e3) } else { 0.0 };
        Ok(self.result(rtf, t_ms))
    }

    /// One integration step.
    pub fn step_once(&mut self) -> anyhow::Result<()> {
        assert!(self.is_prepared(), "call prepare() before stepping");
        let dt = self.cfg.dt_ms;
        let n_ranks = self.n_ranks();

        // ---- 1) devices: Poisson input through their outgoing connections
        {
            let rb = self.buffers.as_mut().unwrap();
            let conns = &self.conns;
            let lut = &self.state_lut;
            for g in self.poissons.iter_mut() {
                for k in conns.outgoing(g.node) {
                    let mult = g.draw_mult(dt);
                    if mult > 0 {
                        let state = lut[conns.target.as_slice()[k] as usize];
                        rb.add(
                            state,
                            conns.port.as_slice()[k],
                            conns.delay.as_slice()[k],
                            conns.weight.as_slice()[k],
                            mult,
                        );
                    }
                }
            }
        }

        // ---- 2) dynamics: ring-buffer slots -> backend -> spike flags
        {
            let state_bases: Vec<usize> = (0..self.n_chunks())
                .map(|i| self.chunk_info(i).1 as usize)
                .collect();
            let rb = self.buffers.as_mut().unwrap();
            let (ex, inh) = rb.current();
            let backend = self.backend.as_mut().unwrap();
            for (i, chunk) in self.chunks.iter_mut().enumerate() {
                let n = chunk.n;
                let a = state_bases[i];
                chunk.w_ex[..n].copy_from_slice(&ex[a..a + n]);
                chunk.w_in[..n].copy_from_slice(&inh[a..a + n]);
                backend.step(chunk)?;
            }
            rb.advance();
        }

        // ---- 3) collect spikes, record, deliver locally, route remotely
        let mut spiking_nodes: Vec<u32> = Vec::new();
        for i in 0..self.n_chunks() {
            let (node_base, _, _) = self.chunk_info(i);
            for off in self.chunks[i].spiking() {
                spiking_nodes.push(node_base + off);
            }
        }
        let step_now = self.step_now;
        for &node in &spiking_nodes {
            self.recorder.record(step_now, node);
        }

        {
            let rb = self.buffers.as_mut().unwrap();
            for &node in &spiking_nodes {
                deliver_outgoing(&self.conns, &self.state_lut, rb, node, 1);
            }
        }

        // p2p routing: map positions into per-target packets (Fig. 15b)
        let mut packets: Vec<Vec<SpikeRecord>> = vec![Vec::new(); n_ranks];
        if let Some(tp) = self.remote.tp.as_ref() {
            for &node in &spiking_nodes {
                for (tau, pos) in tp.route(node) {
                    packets[tau as usize].push(SpikeRecord { pos, mult: 1 });
                }
            }
        }

        // collective routing: positions in H per group (Fig. 2)
        let n_groups = self.remote.groups.len();
        let mut group_bufs: Vec<Vec<u32>> = vec![Vec::new(); n_groups];
        if let Some(gq) = self.remote.gq.as_ref() {
            for &node in &spiking_nodes {
                for (g, pos) in gq.route(node) {
                    group_bufs[g as usize].push(pos);
                }
            }
        }

        // ---- 4) exchange + 5) remote delivery
        if n_ranks > 1 {
            let incoming = self.comm_mut().exchange(packets);
            for (sigma, pkt) in incoming.into_iter().enumerate() {
                if pkt.is_empty() {
                    continue;
                }
                self.deliver_p2p_packet(sigma, &pkt);
            }
        }
        for g in 0..n_groups {
            if self.remote.groups[g].member_index(self.rank()).is_none() {
                continue;
            }
            let comm_group = self.remote.groups[g].comm_group;
            let data = std::mem::take(&mut group_bufs[g]);
            let all = self.comm_mut().allgather(comm_group, &data);
            for (mi, positions) in all.into_iter().enumerate() {
                if self.remote.groups[g].members[mi] == self.rank() {
                    continue; // own spikes were delivered locally
                }
                self.deliver_collective(g, mi, &positions);
            }
        }

        self.step_now += 1;
        Ok(())
    }


    /// Deliver an incoming p2p packet from rank σ: positions -> L (image
    /// index) -> outgoing connections. On GPU memory levels 0/1 the map and
    /// the first/count structures live in host memory, so the translation
    /// is staged through the host before the device delivery pass (the
    /// measured cost of the lower levels).
    fn deliver_p2p_packet(&mut self, sigma: usize, pkt: &[SpikeRecord]) {
        let host_staged = matches!(self.cfg.level, GpuMemLevel::L0 | GpuMemLevel::L1);
        if host_staged {
            let bytes = (pkt.len() * 8) as u64;
            self.tracker.alloc(MemKind::Host, bytes);
            self.tracker.transient_events += 1;
            self.tracker.free(MemKind::Host, bytes);
        }
        let map = &self.remote.p2p_maps[sigma];
        let staged: Vec<(u32, u16)> = pkt.iter().map(|r| (map.l_at(r.pos), r.mult)).collect();
        let rb = self.buffers.as_mut().unwrap();
        if host_staged {
            // the host mirror of (first, count) drives the lookup
            let (first, count) = self.host_first_count.as_ref().unwrap();
            for (image, mult) in staged {
                debug_assert!(self.nodes.is_image(image));
                let a = first[image as usize] as usize;
                let b = a + count[image as usize] as usize;
                for k in a..b {
                    let state = self.state_lut[self.conns.target.as_slice()[k] as usize];
                    rb.add(
                        state,
                        self.conns.port.as_slice()[k],
                        self.conns.delay.as_slice()[k],
                        self.conns.weight.as_slice()[k],
                        mult,
                    );
                }
            }
        } else {
            for (image, mult) in staged {
                debug_assert!(self.nodes.is_image(image));
                deliver_outgoing(&self.conns, &self.state_lut, rb, image, mult);
            }
        }
    }

    /// Deliver collective spikes from group member `mi`: positions in H ->
    /// I image array (−1 = no image here) -> outgoing connections (Fig. 2).
    fn deliver_collective(&mut self, g: usize, mi: usize, positions: &[u32]) {
        let gs = &self.remote.groups[g];
        let images: Vec<u32> = positions
            .iter()
            .filter_map(|&pos| {
                let img = gs.i_arr[mi][pos as usize];
                (img >= 0).then_some(img as u32)
            })
            .collect();
        if matches!(self.cfg.level, GpuMemLevel::L0 | GpuMemLevel::L1) {
            let bytes = (images.len() * 4) as u64;
            self.tracker.alloc(MemKind::Host, bytes);
            self.tracker.transient_events += 1;
            self.tracker.free(MemKind::Host, bytes);
        }
        let rb = self.buffers.as_mut().unwrap();
        for image in images {
            deliver_outgoing(&self.conns, &self.state_lut, rb, image, 1);
        }
    }
}
