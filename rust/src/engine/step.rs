//! State propagation: the phase-structured per-step pipeline with
//! min-delay exchange batching (Appendix F; Figs. 1–2; DESIGN.md §11).
//!
//! Per time step, in named stages the timer attributes individually:
//!
//! 1. **input** — service Poisson generators into the local ring buffers;
//! 2. **pre_update** — plasticity (when STDP rules are attached): drain
//!    this step's plastic arrival events in canonical order, depress each
//!    weight against its target's post trace, bump the synapse's pre
//!    trace, deposit the PSP with the post-depression weight into the
//!    plastic plane (DESIGN.md §12);
//! 3. **dynamics** — merge the local, remote and plastic accumulation
//!    planes in one fused pass and hand the result to the dynamics
//!    backend (the AOT-compiled Pallas kernel via PJRT, or the native
//!    reference);
//! 4. **collect** — gather spike flags into the spiking-node list, record;
//! 5. **post_update** — plasticity: potentiate the spiking neurons'
//!    incoming plastic synapses against their pre traces, then bump the
//!    post traces;
//! 6. **route** — route remotely by map *positions* via the (T, P) tables
//!    (point-to-point) and (G, Q) tables (collective), tagging every
//!    record with its emission `lag` within the current exchange interval;
//! 7. **exchange** — once per `exchange_interval` steps: all-to-all-v of
//!    p2p packets + one Allgather per group (the interval bound
//!    `exchange_interval ≤ min remote delay` keeps results bit-identical
//!    to per-step exchange);
//! 8. **deliver** — through the prepared [`super::delivery::DeliveryPlan`]
//!    (per-node (delay, port)-sorted runs with port-baked destinations,
//!    DESIGN.md §14): each spiking node's runs are batched into a
//!    slot-bucketed [`super::delivery::DeliveryQueue`] and drained as
//!    streaming `row[dest] += w·mult` passes — local spikes each step into
//!    the local plane; incoming remote records at exchange time into the
//!    *remote* plane, enqueued in canonical (lag, σ, group) order with
//!    each run re-slotted by `delay + lag + 1 − interval_len`. Plastic
//!    synapses enqueue arrival events instead of depositing (their PSP
//!    uses the weight at arrival).
//!
//! Keeping remote deliveries in their own accumulation plane — merged with
//! the local plane only at consumption — pins down the f32 summation
//! order, so batched exchange is bit-identical to per-step exchange even
//! though it moves remote additions to a later wall-clock point. The same
//! argument extends to plastic runs: arrival events carry their absolute
//! emission step and replay in the canonical (emission, local-before-
//! remote, push-order) order, so weight updates and deposits are
//! step-for-step identical for every admissible exchange interval. The
//! slot-sorted queue preserves all of this because entries that land in
//! the same accumulator cell share a ring slot and drain in push
//! (canonical) order — see `engine/delivery.rs`.
//!
//! All per-step buffers live in the persistent [`StepScratch`], so the
//! loop performs no steady-state heap allocation.

use std::time::Instant;

use crate::comm::{
    coll_pack, coll_unpack, SpikeRecord, COLL_WORDS_PER_SPIKE, COLL_WORD_BYTES,
    SPIKE_RECORD_BYTES,
};
use crate::memory::MemKind;
use crate::remote::GpuMemLevel;

use super::delivery::merge_planes;
use super::scratch::StepScratch;
use super::simulator::{SimResult, Simulator};
use crate::util::timer::{Phase, StepPhase};

impl Simulator {
    /// Run the propagation loop for `t_ms` of model time; returns the
    /// per-rank metrics including the real-time factor (Eq. 21).
    pub fn simulate(&mut self, t_ms: f64) -> anyhow::Result<SimResult> {
        assert!(self.is_prepared(), "call prepare() before simulate()");
        let steps = (t_ms / self.cfg.dt_ms).round() as u32;
        self.timer.enter(Phase::Propagation);
        let t0 = Instant::now();
        for _ in 0..steps {
            self.step_once()?;
        }
        let wall = t0.elapsed().as_secs_f64();
        self.timer.stop();
        let rtf = if t_ms > 0.0 { wall / (t_ms / 1e3) } else { 0.0 };
        // collect the result BEFORE the observability finalize: its
        // cross-rank aggregation allgather must not leak into the run's
        // own comm metrics, so results match obs-off runs exactly
        let mut res = self.result(rtf, t_ms);
        self.obs_finalize(&mut res, t_ms)?;
        Ok(res)
    }

    /// Charge one pipeline phase's elapsed time to both the cumulative
    /// [`crate::util::timer::StepTimes`] and (when on) the observability
    /// histograms.
    #[inline]
    fn note_phase(&mut self, p: StepPhase, elapsed: std::time::Duration) {
        self.step_times.accumulate(p, elapsed);
        if let Some(o) = self.obs.as_mut() {
            o.phase(p, elapsed.as_nanos() as u64);
        }
    }

    /// Snapshot of the procedural regeneration counters (`regen_ns`,
    /// `cache_hits`, `cache_misses`), taken before a delivery block so its
    /// elapsed time can be split into `deliver` + `regen` and the cache
    /// counter deltas flushed to the metrics registry.
    #[inline]
    fn regen_marks(&self) -> (u64, u64, u64) {
        self.procedural
            .as_ref()
            .map_or((0, 0, 0), |p| (p.regen_ns, p.cache_hits, p.cache_misses))
    }

    /// Charge a delivery block's elapsed time: the rematerialization time
    /// accumulated by `ProceduralState::deliver` since `marks` goes to the
    /// `regen` phase, the remainder to `deliver`. Materialized mode
    /// reduces to a plain `deliver` charge (no zero-valued `regen`
    /// samples in the histograms).
    fn note_deliver_split(&mut self, elapsed: std::time::Duration, marks: (u64, u64, u64)) {
        let Some(p) = self.procedural.as_ref() else {
            self.note_phase(StepPhase::Deliver, elapsed);
            return;
        };
        let regen = std::time::Duration::from_nanos(p.regen_ns - marks.0);
        let (hits, misses) = (p.cache_hits - marks.1, p.cache_misses - marks.2);
        self.note_phase(StepPhase::Deliver, elapsed.saturating_sub(regen));
        self.note_phase(StepPhase::Regen, regen);
        if let Some(o) = self.obs.as_mut() {
            o.registry.add(crate::obs::CounterId::RegenCacheHits, hits);
            o.registry
                .add(crate::obs::CounterId::RegenCacheMisses, misses);
        }
    }

    /// One integration step of the pipeline described in the module docs.
    pub fn step_once(&mut self) -> anyhow::Result<()> {
        assert!(self.is_prepared(), "call prepare() before stepping");
        let dt = self.cfg.dt_ms;
        // emission step within the current exchange interval
        let lag = self.scratch.interval_pos as u16;
        if let Some(o) = self.obs.as_mut() {
            o.begin_step();
        }

        // ---- input: Poisson devices through their outgoing connections.
        // Device blocks keep creation order in the plan (one RNG draw per
        // connection, in push order), served through the same SoA view as
        // spike delivery.
        let t0 = Instant::now();
        {
            let rb = self.buffers.as_mut().unwrap();
            let plan = &self.plan;
            for g in self.poissons.iter_mut() {
                let (dest, weight, delay) = plan.entries_of(g.node);
                for ((&dst, &w), &d) in dest.iter().zip(weight).zip(delay) {
                    let mult = g.draw_mult(dt);
                    if mult > 0 {
                        rb.add_dest(dst, d, w, mult);
                    }
                }
            }
        }
        self.note_phase(StepPhase::Input, t0.elapsed());

        // ---- pre_update: plastic presynaptic arrivals due this step, in
        // canonical order — depression + deposits into the plastic plane
        if let Some(pl) = self.plasticity.as_mut() {
            let t0 = Instant::now();
            pl.pre_update(self.step_now as i64, &mut self.conns, &self.state_lut);
            self.note_phase(StepPhase::PreUpdate, t0.elapsed());
        }

        // ---- dynamics: local + remote + plastic planes -> backend ->
        // spike flags
        let t0 = Instant::now();
        {
            let rb = self.buffers.as_mut().unwrap();
            let (ex, inh) = rb.current();
            // ranks without image neurons never receive remote spikes and
            // carry no remote plane
            let remote_cur = self.remote_buffers.as_ref().map(|r| r.current());
            // third accumulation plane: this step's plastic deposits (made
            // by pre_update with post-depression weights)
            let plastic_cur = self
                .plasticity
                .as_ref()
                .filter(|p| p.plane_used())
                .map(|p| p.plane());
            let backend = self.backend.as_mut().unwrap();
            let state_bases = &self.scratch.state_bases;
            for (i, chunk) in self.chunks.iter_mut().enumerate() {
                let n = chunk.n;
                let a = state_bases[i];
                // fused canonical merge: local, then remote, then plastic
                // (left-associated adds — same per-element order as the
                // former copy + zip-add passes)
                merge_planes(
                    &mut chunk.w_ex[..n],
                    &ex[a..a + n],
                    remote_cur.map(|(re, _)| &re[a..a + n]),
                    plastic_cur.map(|(pe, _)| &pe[a..a + n]),
                );
                merge_planes(
                    &mut chunk.w_in[..n],
                    &inh[a..a + n],
                    remote_cur.map(|(_, ri)| &ri[a..a + n]),
                    plastic_cur.map(|(_, pi)| &pi[a..a + n]),
                );
                backend.step(chunk)?;
            }
            rb.advance();
            if let Some(rrb) = self.remote_buffers.as_mut() {
                rrb.advance();
            }
            if let Some(pl) = self.plasticity.as_mut() {
                // zero the consumed plane, advance the arrival event ring
                pl.end_step();
            }
        }
        self.note_phase(StepPhase::Dynamics, t0.elapsed());

        // ---- collect: spike flags -> spiking-node list, record
        let t0 = Instant::now();
        self.scratch.spiking.clear();
        for i in 0..self.chunks.len() {
            let node_base = self.chunk_meta[i].0;
            for off in self.chunks[i].spiking() {
                self.scratch.spiking.push(node_base + off);
            }
        }
        let step_now = self.step_now;
        for &node in &self.scratch.spiking {
            self.recorder.record(step_now, node);
        }
        self.note_phase(StepPhase::Collect, t0.elapsed());

        // ---- post_update: potentiate the spiking neurons' incoming
        // plastic synapses, then bump their postsynaptic traces
        if let Some(pl) = self.plasticity.as_mut() {
            let t0 = Instant::now();
            pl.post_update(
                step_now as i64,
                &self.scratch.spiking,
                &mut self.conns,
                &self.state_lut,
            );
            self.note_phase(StepPhase::PostUpdate, t0.elapsed());
        }

        // ---- route: map positions into lag-tagged packets (Fig. 15b) and
        // collective word pairs (Fig. 2); records to the same target
        // position in the same step aggregate via `mult` before send
        let t0 = Instant::now();
        {
            let StepScratch {
                spiking,
                packets,
                group_bufs,
                ..
            } = &mut self.scratch;
            if let Some(tp) = self.remote.tp.as_ref() {
                for &node in spiking.iter() {
                    tp.route_into(node, |tau, pos| {
                        let pkt = &mut packets[tau as usize];
                        match pkt.last_mut() {
                            Some(last) if last.pos == pos && last.lag == lag => last.mult += 1,
                            _ => pkt.push(SpikeRecord { pos, mult: 1, lag }),
                        }
                    });
                }
            }
            if let Some(gq) = self.remote.gq.as_ref() {
                for &node in spiking.iter() {
                    gq.route_into(node, |g, pos| {
                        let buf = &mut group_bufs[g as usize];
                        let n = buf.len();
                        if n >= COLL_WORDS_PER_SPIKE
                            && buf[n - 2] == pos
                            && buf[n - 1] >> 16 == lag as u32
                        {
                            buf[n - 1] += 1; // aggregate mult (low half-word)
                        } else {
                            buf.push(pos);
                            buf.push(coll_pack(lag, 1));
                        }
                    });
                }
            }
        }
        self.note_phase(StepPhase::Route, t0.elapsed());

        // ---- deliver (local): own spikes through the delivery plan —
        // plastic links enqueue arrival events in creation order, static
        // runs batch into the slot-bucketed queue and drain as streaming
        // contiguous adds. In procedural mode static fanouts are
        // regenerated (or cache-served) and accumulated directly; the
        // spiking nodes then have no materialized runs, so the queue path
        // is a no-op and the two modes never interleave on a cell.
        let t0 = Instant::now();
        let regen0 = self.regen_marks();
        {
            let rb = self.buffers.as_mut().unwrap();
            let plan = &self.plan;
            let q = &mut self.scratch.local_q;
            q.ensure_slots(rb.n_slots());
            let mut pl = self.plasticity.as_mut();
            let mut ps = self.procedural.as_mut();
            let emit = self.step_now;
            for &node in &self.scratch.spiking {
                if let Some(p) = pl.as_deref_mut() {
                    for l in plan.plastic_of(node) {
                        debug_assert!(rb.supports(l.delay));
                        p.enqueue(l.delay as usize, l.slot, emit, 1, false);
                    }
                }
                for run in plan.runs_of(node) {
                    debug_assert!(rb.supports(run.delay));
                    q.push(rb.slot_of(run.delay), run.start, run.end, 1);
                }
                if let Some(p) = ps.as_deref_mut() {
                    p.deliver(
                        node,
                        1,
                        0,
                        &self.state_lut,
                        self.n_state,
                        rb,
                        &mut self.tracker,
                    );
                }
            }
            q.drain_into(rb, plan);
            q.sync_tracker(&mut self.tracker);
        }
        self.note_deliver_split(t0.elapsed(), regen0);

        // ---- exchange + deliver (remote), once per interval
        self.scratch.interval_pos += 1;
        if self.scratch.interval_pos >= self.exchange_every as u32 {
            self.do_exchange(self.step_now)?;
        }

        // ---- observability: close out the step (counters, gauges, and —
        // on the sampling cadence — one JSONL record into the sink buffer)
        if self.obs.is_some() {
            let sample = crate::obs::StepSample {
                step: self.step_now,
                time_ms: self.step_now as f64 * dt,
                spikes: self.scratch.spiking.len() as u64,
                pkt_backlog: self.scratch.packets.iter().map(|p| p.len() as u64).sum(),
                grp_backlog: self
                    .scratch
                    .group_bufs
                    .iter()
                    .map(|b| (b.len() / COLL_WORDS_PER_SPIKE) as u64)
                    .sum(),
                dev_current: self.tracker.current(MemKind::Device),
                dev_peak: self.tracker.peak(MemKind::Device),
                host_current: self.tracker.current(MemKind::Host),
                host_peak: self.tracker.peak(MemKind::Host),
                traffic: self.comm.traffic(),
            };
            if let Some(o) = self.obs.as_mut() {
                o.end_step(&sample);
            }
        }

        self.step_now += 1;
        Ok(())
    }

    /// Exchange whatever the current interval has accumulated and deliver
    /// it, then restart the interval. Safe at any point inside an interval
    /// because records target absolute ring slots (via their lag), so an
    /// early exchange cannot change any delivery slot or summation order.
    ///
    /// Collective: in a multi-rank world every rank must call this at the
    /// same step (as [`Simulator::save_snapshot`] does before writing).
    pub fn flush_exchange(&mut self) -> anyhow::Result<()> {
        if self.scratch.interval_pos == 0 {
            return Ok(());
        }
        // a flush runs *between* steps, so the last step of the pending
        // interval is the one `step_once` already completed
        let last_step = self.step_now - 1;
        self.do_exchange(last_step)
    }

    /// The exchange + remote-delivery phases over the records accumulated
    /// since the last exchange (`interval_pos` steps); `last_step` is the
    /// final step of that interval (`step_now` when called inside
    /// `step_once`, `step_now − 1` from a flush), from which each record's
    /// absolute emission step `last_step + lag + 1 − interval_len` is
    /// reconstructed for the plastic arrival events.
    ///
    /// Delivery enqueues the received records in canonical
    /// (lag, σ, group-member) order — exactly the order per-step exchange
    /// produces — then drains the slot-bucketed queue into the remote
    /// accumulation plane once per exchange. Entries landing in the same
    /// accumulator cell share a ring slot and drain in enqueue order, so
    /// the f32 sums stay bit-identical for every
    /// `1 ≤ interval ≤ min remote delay` (DESIGN.md §14).
    fn do_exchange(&mut self, last_step: u32) -> anyhow::Result<()> {
        let interval_len = self.scratch.interval_pos;
        debug_assert!(interval_len >= 1);
        let n_ranks = self.n_ranks();
        let me = self.rank();
        let n_groups = self.remote.groups.len();

        // observability: outgoing record count + comm counters before the
        // round (pure reads — the exchange itself is untouched)
        let obs_on = self.obs.is_some();
        let (obs_records_out, obs_traffic_before) = if obs_on {
            let p2p: u64 = self.scratch.packets.iter().map(|p| p.len() as u64).sum();
            let coll: u64 = self
                .scratch
                .group_bufs
                .iter()
                .map(|b| (b.len() / COLL_WORDS_PER_SPIKE) as u64)
                .sum();
            (p2p + coll, self.comm.traffic())
        } else {
            (0, crate::comm::TrafficStats::default())
        };

        // ---- communication: one all-to-all-v + one allgather per group
        let t0 = Instant::now();
        let incoming = if n_ranks > 1 {
            let outgoing = std::mem::take(&mut self.scratch.packets);
            Some(self.comm_mut().exchange(outgoing))
        } else {
            None
        };
        let mut gathered = std::mem::take(&mut self.scratch.gathered);
        for g in 0..n_groups {
            if self.remote.groups[g].member_index(me).is_none() {
                continue;
            }
            let comm_group = self.remote.groups[g].comm_group;
            let data = std::mem::take(&mut self.scratch.group_bufs[g]);
            self.comm_mut().allgather_into(comm_group, &data, &mut gathered[g]);
            let mut data = data;
            data.clear();
            self.scratch.group_bufs[g] = data;
        }
        self.note_phase(StepPhase::Exchange, t0.elapsed());

        // observability: incoming record count (own collective slot is
        // excluded, mirroring delivery below) + this round's byte delta;
        // also the trace sink's flush point, off the per-step path
        if obs_on {
            let mut records_in: u64 = incoming
                .as_ref()
                .map_or(0, |inc| inc.iter().map(|p| p.len() as u64).sum());
            for g in 0..n_groups {
                if let Some(my_mi) = self.remote.groups[g].member_index(me) {
                    for (mi, payload) in gathered[g].iter().enumerate() {
                        if mi != my_mi {
                            records_in += (payload.len() / COLL_WORDS_PER_SPIKE) as u64;
                        }
                    }
                }
            }
            let delta_bytes =
                self.comm.traffic().total_bytes() - obs_traffic_before.total_bytes();
            if let Some(o) = self.obs.as_mut() {
                o.on_exchange(obs_records_out, records_in, delta_bytes);
            }
        }

        // ---- delivery enqueue in canonical (lag, σ, group-member) order
        let t0 = Instant::now();
        let regen0 = self.regen_marks();
        let mut pkt_cursor = std::mem::take(&mut self.scratch.pkt_cursor);
        let mut coll_cursor = std::mem::take(&mut self.scratch.coll_cursor);
        pkt_cursor.clear();
        pkt_cursor.resize(n_ranks, 0);
        for c in coll_cursor.iter_mut() {
            for x in c.iter_mut() {
                *x = 0;
            }
        }
        for l in 0..interval_len {
            if let Some(incoming) = incoming.as_ref() {
                for (sigma, pkt) in incoming.iter().enumerate() {
                    let start = pkt_cursor[sigma];
                    let mut end = start;
                    while end < pkt.len() && pkt[end].lag as u32 == l {
                        end += 1;
                    }
                    pkt_cursor[sigma] = end;
                    if end > start {
                        self.deliver_p2p_records(sigma, &pkt[start..end], interval_len, last_step);
                    }
                }
            }
            for g in 0..n_groups {
                if self.remote.groups[g].member_index(me).is_none() {
                    continue;
                }
                let n_members = self.remote.groups[g].members.len();
                for mi in 0..n_members {
                    if self.remote.groups[g].members[mi] == me {
                        continue; // own spikes were delivered locally
                    }
                    let payload = &gathered[g][mi];
                    let start = coll_cursor[g][mi];
                    let mut end = start;
                    while end + 1 < payload.len() && coll_unpack(payload[end + 1]).0 as u32 == l {
                        end += COLL_WORDS_PER_SPIKE;
                    }
                    coll_cursor[g][mi] = end;
                    if end > start {
                        // split the borrow: the payload slice lives in the
                        // locally-owned `gathered`, not in `self`
                        let records = &gathered[g][mi][start..end];
                        self.deliver_collective_records(g, mi, records, interval_len, last_step);
                    }
                }
            }
        }
        if let Some(incoming) = incoming.as_ref() {
            for (sigma, pkt) in incoming.iter().enumerate() {
                debug_assert_eq!(
                    pkt_cursor[sigma],
                    pkt.len(),
                    "p2p record with lag >= interval_len from rank {sigma}"
                );
            }
        }
        #[cfg(debug_assertions)]
        for g in 0..n_groups {
            if self.remote.groups[g].member_index(me).is_none() {
                continue;
            }
            for (mi, &member) in self.remote.groups[g].members.iter().enumerate() {
                if member == me {
                    continue; // own slot is never consumed by delivery
                }
                debug_assert_eq!(
                    coll_cursor[g][mi],
                    gathered[g][mi].len(),
                    "collective record with lag >= interval_len in group {g} member {mi}"
                );
            }
        }
        // one streaming drain for the whole exchange: the ring cursor is
        // constant between steps, so batching the writes cannot move any
        // entry to a different slot, and per-cell enqueue order is the
        // canonical replay order established above
        if let Some(rb) = self.remote_buffers.as_mut() {
            self.scratch.remote_q.drain_into(rb, &self.plan);
        }
        self.scratch.remote_q.sync_tracker(&mut self.tracker);
        self.note_deliver_split(t0.elapsed(), regen0);

        // recycle all buffers: incoming packets become the next interval's
        // outgoing packets (steady-state allocation-free)
        if let Some(mut incoming) = incoming {
            for p in incoming.iter_mut() {
                p.clear();
            }
            self.scratch.packets = incoming;
        }
        self.scratch.gathered = gathered;
        self.scratch.pkt_cursor = pkt_cursor;
        self.scratch.coll_cursor = coll_cursor;
        self.scratch.interval_pos = 0;
        Ok(())
    }

    /// Enqueue translated remote records — (image node, mult, lag) triples
    /// in canonical order — onto the remote delivery queue, re-slotting
    /// every run by `lag + 1 − interval_len` (which re-anchors the record
    /// at its emission step). Plastic links enqueue arrival events instead:
    /// their PSP must use the weight at *arrival*, which is what keeps
    /// batched exchange bit-identical once weights mutate mid-run (`emit`
    /// is the absolute emission step, the canonical-order key; `remote`
    /// replays after local events of the same emission step, DESIGN.md §12).
    fn queue_remote_records(
        &mut self,
        staged: &[(u32, u16, u16)],
        interval_len: u32,
        last_step: u32,
    ) {
        let rb = self
            .remote_buffers
            .as_mut()
            .expect("remote spike record arrived on a rank without image neurons");
        let plan = &self.plan;
        let q = &mut self.scratch.remote_q;
        q.ensure_slots(rb.n_slots());
        let mut pl = self.plasticity.as_mut();
        let mut ps = self.procedural.as_mut();
        for &(image, mult, lag) in staged {
            debug_assert!(self.nodes.is_image(image));
            let shift = lag as i32 + 1 - interval_len as i32;
            let emit = (last_step as i32 + shift) as u32;
            if let Some(p) = pl.as_deref_mut() {
                for link in plan.plastic_of(image) {
                    let d = link.delay as i32 + shift;
                    debug_assert!(
                        d >= 1 && rb.supports(d as u16),
                        "shifted delay {d} outside the ring (interval exceeds a remote delay?)"
                    );
                    p.enqueue(d as usize, link.slot, emit, mult, true);
                }
            }
            for run in plan.runs_of(image) {
                let d = run.delay as i32 + shift;
                debug_assert!(
                    d >= 1 && rb.supports(d as u16),
                    "shifted delay {d} outside the ring (interval exceeds a remote delay?)"
                );
                q.push(rb.slot_of(d as u16), run.start, run.end, mult);
            }
            // procedural: the image's static fanout accumulates directly,
            // re-slotted by the same lag shift; records arrive here in
            // canonical order, and `runs_of(image)` is empty in this mode,
            // so per-cell summation order matches the materialized drain
            if let Some(p) = ps.as_deref_mut() {
                p.deliver(
                    image,
                    mult,
                    shift,
                    &self.state_lut,
                    self.n_state,
                    rb,
                    &mut self.tracker,
                );
            }
        }
    }

    /// Translate incoming p2p records (one source rank σ, one lag):
    /// positions -> L (image index) -> delivery-plan runs onto the remote
    /// queue. On GPU memory levels 0/1 the translation is staged through
    /// host memory before the device delivery pass (the measured cost of
    /// the lower levels), modeled as a transient host allocation.
    fn deliver_p2p_records(
        &mut self,
        sigma: usize,
        pkt: &[SpikeRecord],
        interval_len: u32,
        last_step: u32,
    ) {
        if matches!(self.cfg.level, GpuMemLevel::L0 | GpuMemLevel::L1) {
            let bytes = pkt.len() as u64 * SPIKE_RECORD_BYTES;
            self.tracker.alloc(MemKind::Host, bytes);
            self.tracker.transient_events += 1;
            self.tracker.free(MemKind::Host, bytes);
        }
        let mut staged = std::mem::take(&mut self.scratch.staged);
        staged.clear();
        let map = &self.remote.p2p_maps[sigma];
        staged.extend(pkt.iter().map(|r| (map.l_at(r.pos), r.mult, r.lag)));
        self.queue_remote_records(&staged, interval_len, last_step);
        self.scratch.staged = staged;
    }

    /// Translate incoming collective records (one group member, one lag):
    /// word pairs `[pos, (lag<<16)|mult]` -> position in H -> I image
    /// array (−1 = no image here) -> delivery-plan runs onto the remote
    /// queue (Fig. 2), with the same lag shift as the p2p path.
    fn deliver_collective_records(
        &mut self,
        g: usize,
        mi: usize,
        payload: &[u32],
        interval_len: u32,
        last_step: u32,
    ) {
        let mut staged = std::mem::take(&mut self.scratch.staged);
        staged.clear();
        {
            let gs = &self.remote.groups[g];
            for rec in payload.chunks_exact(COLL_WORDS_PER_SPIKE) {
                let pos = rec[0];
                let (lag, mult) = coll_unpack(rec[1]);
                let img = gs.i_arr[mi][pos as usize];
                if img >= 0 {
                    staged.push((img as u32, mult, lag));
                }
            }
        }
        if matches!(self.cfg.level, GpuMemLevel::L0 | GpuMemLevel::L1) {
            let bytes = staged.len() as u64 * COLL_WORD_BYTES;
            self.tracker.alloc(MemKind::Host, bytes);
            self.tracker.transient_events += 1;
            self.tracker.free(MemKind::Host, bytes);
        }
        // every position may resolve to -1 here (no image on this rank)
        if !staged.is_empty() {
            self.queue_remote_records(&staged, interval_len, last_step);
        }
        self.scratch.staged = staged;
    }
}
