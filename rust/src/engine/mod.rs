//! The per-rank simulation engine: construction facade (`Create`,
//! `Connect`, `RemoteConnect`), simulation preparation, and the state
//! propagation loop with point-to-point and collective spike exchange.

pub mod delivery;
mod scratch;
pub mod simulator;
pub mod snapshot;
mod step;

pub use simulator::{SimConfig, SimResult, Simulator};
pub use snapshot::peek_world;
