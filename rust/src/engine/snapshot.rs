//! Simulator checkpoint/restore: assembles the per-rank snapshot container
//! from every state-owning subsystem and rebuilds a ready-to-step
//! [`Simulator`] from one.
//!
//! Saving is legal at any step boundary once `prepare()` has run; the same
//! file serves as a *construction cache* (saved right after `prepare()`)
//! or a *mid-run checkpoint* (saved after propagation steps). See
//! `rust/DESIGN.md` §10 for the on-disk format and
//! [`crate::snapshot`] for the container/codec layers.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::comm::Communicator;
use crate::connection::{
    Connections, Connectivity, DescSources, DescriptorStore, ProceduralState,
};
use crate::memory::{MemKind, Tracker};
use crate::node::device::{PoissonGenerator, SpikeRecorder};
use crate::node::{NodeKind, NodeSpace, RingBuffers};
use crate::plasticity::PlasticityEngine;
use crate::remote::levels::ALL_LEVELS;
use crate::remote::{GpuMemLevel, RemoteState};
use crate::runtime::{BackendKind, StateChunk};
use crate::snapshot::format::tags;
use crate::snapshot::{Decoder, Encoder, SnapshotReader, SnapshotWriter};
use crate::util::timer::{Phase, PhaseTimer};

use super::simulator::{Population, SimConfig, Simulator};

fn encode_config(cfg: &SimConfig, enc: &mut Encoder) {
    enc.f64(cfg.dt_ms);
    enc.u8(ALL_LEVELS.iter().position(|&l| l == cfg.level).unwrap() as u8);
    enc.f64(cfg.xi);
    enc.u64(cfg.seed);
    match &cfg.backend {
        BackendKind::Native => enc.u8(0),
        BackendKind::Pjrt { artifacts } => {
            enc.u8(1);
            enc.string(&artifacts.to_string_lossy());
        }
    }
    enc.bool(cfg.record_spikes);
    enc.u16(cfg.max_delay_steps);
    enc.bool(cfg.offboard);
    match cfg.exchange_interval {
        None => enc.bool(false),
        Some(k) => {
            enc.bool(true);
            enc.u16(k);
        }
    }
}

fn decode_config(dec: &mut Decoder) -> Result<SimConfig> {
    let dt_ms = dec.f64()?;
    let level = GpuMemLevel::from_index(dec.u8()? as usize)
        .ok_or_else(|| anyhow::anyhow!("invalid GPU memory level in snapshot config"))?;
    let xi = dec.f64()?;
    let seed = dec.u64()?;
    let backend = match dec.u8()? {
        0 => BackendKind::Native,
        1 => BackendKind::Pjrt {
            artifacts: std::path::PathBuf::from(dec.string()?),
        },
        tag => bail!("unknown backend tag {tag} in snapshot config"),
    };
    let record_spikes = dec.bool()?;
    let max_delay_steps = dec.u16()?;
    let offboard = dec.bool()?;
    let exchange_interval = if dec.bool()? { Some(dec.u16()?) } else { None };
    Ok(SimConfig {
        dt_ms,
        level,
        xi,
        seed,
        backend,
        record_spikes,
        max_delay_steps,
        offboard,
        exchange_interval,
        // telemetry is per-run, not simulation state: a restored run
        // re-enables it by setting `cfg.obs` before `prepare()`-equivalent
        // use, never from the snapshot
        obs: None,
        // appended at the very end of CONF in v4; the caller overrides
        // this after reading the trailing byte (v2/v3 files are
        // materialized by construction)
        connectivity: Connectivity::Materialized,
    })
}

/// Read only the world header of a snapshot file:
/// `(rank, n_ranks, step_now)`. Used by the harness to size the restored
/// cluster without deserializing any state — only the small CONF section
/// is read and checksummed, not the (potentially huge) state sections.
pub fn peek_world(path: &Path) -> Result<(usize, usize, u32)> {
    let conf = crate::snapshot::format::read_section_from_file(path, tags::CONF)?;
    let mut dec = Decoder::new(&conf);
    let rank = dec.u64()? as usize;
    let n_ranks = dec.u64()? as usize;
    let step_now = dec.u32()?;
    Ok((rank, n_ranks, step_now))
}

impl Simulator {
    /// Serialize this rank's full post-`prepare()` state into the
    /// versioned snapshot container (§DESIGN.md §10).
    pub fn snapshot_to_bytes(&self) -> Result<Vec<u8>> {
        if !self.prepared {
            bail!("save_snapshot requires prepare() to have run (snapshots capture the prepared network)");
        }
        if self.scratch.has_pending_records() {
            bail!(
                "snapshot requested mid-exchange-interval with routed spike records \
                 still in flight; call flush_exchange() on every rank first (or use \
                 save_snapshot, which does)"
            );
        }
        let mut w = SnapshotWriter::new();

        // CONF — world identity + engine configuration + effective
        // exchange-batching interval (world-consistent, resolved at prepare)
        let mut e = Encoder::new();
        e.u64(self.rank() as u64);
        e.u64(self.n_ranks() as u64);
        e.u32(self.step_now);
        e.u32(self.n_state);
        encode_config(&self.cfg, &mut e);
        e.u16(self.exchange_every);
        // v4 append: connectivity mode — last in CONF, so a v3 payload is
        // a strict prefix of a v4 one
        e.u8(match self.cfg.connectivity {
            Connectivity::Materialized => 0,
            Connectivity::Procedural => 1,
        });
        w.section(tags::CONF, e.into_bytes());

        // NODE — node index space
        let mut e = Encoder::new();
        self.nodes.snapshot_encode(&mut e);
        w.section(tags::NODE, e.into_bytes());

        // POPS — population table (chunk-grouping keys + state bases)
        let mut e = Encoder::new();
        e.seq_len(self.pops.len());
        for p in &self.pops {
            e.u32(p.node_base);
            e.u32(p.state_base);
            e.u32(p.n);
            for x in p.packed {
                e.f32(x);
            }
        }
        w.section(tags::POPS, e.into_bytes());

        // CONN — connection store
        let mut e = Encoder::new();
        self.conns.snapshot_encode(&mut e);
        w.section(tags::CONN, e.into_bytes());

        // REMT — remote routing state
        let mut e = Encoder::new();
        self.remote.snapshot_encode(&mut e);
        w.section(tags::REMT, e.into_bytes());

        // CHNK — dynamic neuron state, one record per state chunk
        let mut e = Encoder::new();
        e.seq_len(self.chunks.len());
        for (chunk, &(node_base, state_base, n)) in
            self.chunks.iter().zip(self.chunk_meta.iter())
        {
            e.u32(node_base);
            e.u32(state_base);
            e.u32(n);
            chunk.snapshot_encode(&mut e);
        }
        w.section(tags::CHNK, e.into_bytes());

        // BUFS — spike ring buffers: local plane, then the optional remote
        // plane (absent on ranks without image neurons); in-flight spikes
        // of both planes included
        let mut e = Encoder::new();
        self.buffers
            .as_ref()
            .expect("prepared simulator has ring buffers")
            .snapshot_encode(&mut e);
        match self.remote_buffers.as_ref() {
            None => e.bool(false),
            Some(rb) => {
                e.bool(true);
                rb.snapshot_encode(&mut e);
            }
        }
        w.section(tags::BUFS, e.into_bytes());

        // DEVS — Poisson generators (with consumed RNG streams) + recorder
        let mut e = Encoder::new();
        e.seq_len(self.poissons.len());
        for g in &self.poissons {
            g.snapshot_encode(&mut e);
        }
        self.recorder.snapshot_encode(&mut e);
        w.section(tags::DEVS, e.into_bytes());

        // RNGS — rank-private construction stream
        let mut e = Encoder::new();
        e.rng(&self.local_rng);
        w.section(tags::RNGS, e.into_bytes());

        // PLAS — plasticity traces + pending arrival events (only when
        // the network has plastic synapses; the rules and evolved weights
        // themselves live in CONN)
        if let Some(pl) = self.plasticity.as_ref() {
            let mut e = Encoder::new();
            pl.snapshot_encode(&mut e);
            w.section(tags::PLAS, e.into_bytes());
        }

        // PROC — procedural connect-call descriptors (v4; present iff the
        // run is procedural — the node index and fanout cache are derived
        // state, rebuilt at restore)
        if let Some(ps) = self.procedural.as_ref() {
            let mut e = Encoder::new();
            ps.store.snapshot_encode(&mut e);
            w.section(tags::PROC, e.into_bytes());
        }

        Ok(w.finish())
    }

    /// Write this rank's snapshot to `path` (atomic: temp file + rename,
    /// so a crash mid-write never leaves a half-snapshot under the final
    /// name — the checksums catch the rest).
    ///
    /// If the exchange interval is mid-flight, pending spike records are
    /// flushed first (an early exchange is bit-identical — records target
    /// absolute ring slots), so in a multi-rank world every rank must call
    /// this at the same step, as the harness save paths do.
    pub fn save_snapshot(&mut self, path: &Path) -> Result<()> {
        self.flush_exchange()?;
        let bytes = self.snapshot_to_bytes()?;
        let tmp = path.with_extension("snap.tmp");
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("cannot write snapshot {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("cannot move snapshot into place at {}", path.display()))?;
        Ok(())
    }

    /// Restore a rank from a snapshot file. The communicator supplies the
    /// live world; its rank/size must match the snapshot's. Construction
    /// and preparation are skipped entirely — the returned simulator is
    /// ready to `simulate()`/`step_once()` and continues bit-identically.
    pub fn load_snapshot(comm: Box<dyn Communicator>, path: &Path) -> Result<Simulator> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("cannot read snapshot {}", path.display()))?;
        Self::load_snapshot_bytes(comm, &bytes)
            .with_context(|| format!("while restoring snapshot {}", path.display()))
    }

    /// [`Simulator::load_snapshot`] over an in-memory buffer.
    pub fn load_snapshot_bytes(
        mut comm: Box<dyn Communicator>,
        bytes: &[u8],
    ) -> Result<Simulator> {
        let mut timer = PhaseTimer::new();
        timer.enter(Phase::Initialization);
        let reader = SnapshotReader::open(bytes)?;

        let mut dec = Decoder::new(reader.section(tags::CONF)?);
        let rank = dec.u64()? as usize;
        let n_ranks = dec.u64()? as usize;
        let step_now = dec.u32()?;
        let n_state = dec.u32()?;
        let mut cfg = decode_config(&mut dec)?;
        let exchange_every = dec.u16()?;
        if reader.version() >= 4 {
            cfg.connectivity = match dec.u8()? {
                0 => Connectivity::Materialized,
                1 => Connectivity::Procedural,
                tag => bail!("unknown connectivity tag {tag} in snapshot config"),
            };
        }
        dec.finish()?;
        if exchange_every == 0 {
            bail!("snapshot carries an exchange interval of 0 (must be >= 1)");
        }
        if comm.rank() != rank || comm.size() != n_ranks {
            bail!(
                "snapshot was taken by rank {rank} of {n_ranks}, but the live communicator \
                 is rank {} of {}",
                comm.rank(),
                comm.size()
            );
        }

        let mut tracker = Tracker::new();

        let mut dec = Decoder::new(reader.section(tags::NODE)?);
        let nodes = NodeSpace::snapshot_decode(&mut dec)?;
        dec.finish()?;

        let mut dec = Decoder::new(reader.section(tags::POPS)?);
        let n_pops = dec.seq_len(12 + 4 * crate::node::neuron::NUM_PARAMS)?;
        let mut pops = Vec::with_capacity(n_pops);
        for _ in 0..n_pops {
            let node_base = dec.u32()?;
            let state_base = dec.u32()?;
            let n = dec.u32()?;
            let mut packed = [0.0f32; crate::node::neuron::NUM_PARAMS];
            for x in packed.iter_mut() {
                *x = dec.f32()?;
            }
            pops.push(Population {
                node_base,
                state_base,
                n,
                packed,
            });
        }
        dec.finish()?;

        let mut dec = Decoder::new(reader.section(tags::CONN)?);
        // the v3 plasticity block (rule registry + per-connection rule
        // ids) is appended to CONN; v2 files predate it and are all-static
        let conns = Connections::snapshot_decode(&mut dec, &mut tracker, reader.version() >= 3)?;
        dec.finish()?;

        let mut dec = Decoder::new(reader.section(tags::REMT)?);
        let remote = RemoteState::snapshot_decode(&mut dec, &mut tracker, &mut |members| {
            comm.register_group(members)
        })?;
        dec.finish()?;
        if remote.me() != rank || remote.n_ranks() != n_ranks {
            bail!("remote-state world identity disagrees with the snapshot header");
        }

        let mut dec = Decoder::new(reader.section(tags::CHNK)?);
        let n_chunks = dec.seq_len(12)?;
        let mut chunks = Vec::with_capacity(n_chunks);
        let mut chunk_meta = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            let node_base = dec.u32()?;
            let state_base = dec.u32()?;
            let n = dec.u32()?;
            chunk_meta.push((node_base, state_base, n));
            chunks.push(StateChunk::snapshot_decode(&mut dec, &mut tracker)?);
        }
        dec.finish()?;

        let mut dec = Decoder::new(reader.section(tags::BUFS)?);
        let buffers = RingBuffers::snapshot_decode(&mut dec, &mut tracker)?;
        let remote_buffers = if dec.bool()? {
            Some(RingBuffers::snapshot_decode(&mut dec, &mut tracker)?)
        } else {
            None
        };
        dec.finish()?;
        if buffers.n() != n_state as usize {
            bail!(
                "ring buffers cover {} state slots, snapshot header says {n_state}",
                buffers.n()
            );
        }
        if let Some(rb) = remote_buffers.as_ref() {
            if rb.n() != n_state as usize {
                bail!(
                    "remote ring plane covers {} state slots, snapshot header says {n_state}",
                    rb.n()
                );
            }
        }

        let mut dec = Decoder::new(reader.section(tags::DEVS)?);
        let n_poissons = dec.seq_len(8 + 4)?;
        let mut poissons = Vec::with_capacity(n_poissons);
        for _ in 0..n_poissons {
            poissons.push(PoissonGenerator::snapshot_decode(&mut dec)?);
        }
        let recorder = SpikeRecorder::snapshot_decode(&mut dec)?;
        dec.finish()?;

        let mut dec = Decoder::new(reader.section(tags::RNGS)?);
        let local_rng = dec.rng()?;
        dec.finish()?;

        // PROC — descriptor store, present exactly when the run was
        // procedural (CSR index + fanout cache are rebuilt below)
        let procedural = match (cfg.connectivity, reader.try_section(tags::PROC)) {
            (Connectivity::Materialized, None) => None,
            (Connectivity::Procedural, Some(payload)) => {
                let mut dec = Decoder::new(payload);
                let store = DescriptorStore::snapshot_decode(&mut dec, &mut tracker)?;
                dec.finish()?;
                Some(ProceduralState::new(store))
            }
            (Connectivity::Procedural, None) => {
                bail!("snapshot config is procedural but the snapshot has no PROC section")
            }
            (Connectivity::Materialized, Some(_)) => {
                bail!("snapshot has a PROC section but a materialized config")
            }
        };

        // Cross-section consistency: the checksums only catch accidental
        // corruption, not a buggy or mismatched writer. Every structure
        // this rank indexes unchecked in the step hot loop — CSR offsets,
        // population/chunk state ranges, (R, L) image indexes, device
        // bindings — is range-checked here so an inconsistent snapshot
        // fails the load instead of panicking mid-simulation. (Map
        // *positions* arriving over the wire are a cross-rank property and
        // cannot be validated from one rank's file.)
        let m = nodes.m();
        if conns.is_sorted() {
            let fo = conns.first_out();
            if fo.len() != m as usize + 1 {
                bail!(
                    "connection CSR covers {} nodes, node space has {m}",
                    fo.len().saturating_sub(1)
                );
            }
            if fo.windows(2).any(|w| w[0] > w[1]) || fo[m as usize] as usize != conns.len() {
                bail!("connection CSR offsets are not a valid prefix table");
            }
        }
        if let Some(&bad) = conns
            .source
            .as_slice()
            .iter()
            .chain(conns.target.as_slice())
            .find(|&&x| x >= m)
        {
            bail!("connection endpoint {bad} outside node space of {m}");
        }
        for (i, p) in pops.iter().enumerate() {
            let node_end = p.node_base.checked_add(p.n);
            let state_end = p.state_base.checked_add(p.n);
            if node_end.is_none()
                || node_end.unwrap() > m
                || state_end.is_none()
                || state_end.unwrap() > n_state
            {
                bail!("population {i} exceeds the node or state space");
            }
        }
        for (i, (chunk, &(node_base, state_base, n))) in
            chunks.iter().zip(chunk_meta.iter()).enumerate()
        {
            let node_end = node_base.checked_add(n);
            let state_end = state_base.checked_add(n);
            if chunk.n != n as usize
                || node_end.is_none()
                || node_end.unwrap() > m
                || state_end.is_none()
                || state_end.unwrap() > n_state
            {
                bail!("state chunk {i} metadata inconsistent with the node/state space");
            }
        }
        for node in 0..m {
            if let NodeKind::Neuron { chunk, offset } = nodes.kind(node) {
                if chunk as usize >= pops.len() || offset >= pops[chunk as usize].n {
                    bail!("node {node} references population {chunk}/{offset} out of range");
                }
            }
        }
        for map in remote
            .p2p_maps
            .iter()
            .chain(remote.groups.iter().flat_map(|g| g.maps.iter()))
        {
            if let Some(&bad) = map.l_slice().iter().find(|&&l| l >= m) {
                bail!("(R, L) map image index {bad} outside node space of {m}");
            }
        }
        for gs in &remote.groups {
            for i_arr in &gs.i_arr {
                if i_arr.iter().any(|&i| i >= 0 && i as u32 >= m) {
                    bail!("collective image array entry outside node space of {m}");
                }
            }
        }
        for g in &poissons {
            if g.node >= m {
                bail!("Poisson device bound to node {} outside node space of {m}", g.node);
            }
        }
        if let Some(ps) = procedural.as_ref() {
            for id in 0..ps.store.len() as u32 {
                let d = ps.store.desc(id);
                let src_ok = match &d.sources {
                    DescSources::Local(s) => s.iter().all(|n| n < m),
                    DescSources::RemoteImages(l) => l.iter().all(|&n| n == u32::MAX || n < m),
                };
                if !src_ok || d.targets.iter().any(|n| n >= m) {
                    bail!(
                        "procedural descriptor {id} references nodes outside node space of {m}"
                    );
                }
            }
        }
        if remote_buffers.is_some() != (nodes.n_images() > 0) {
            bail!(
                "snapshot {} a remote ring plane but the node space has {} image neurons",
                if remote_buffers.is_some() { "carries" } else { "lacks" },
                nodes.n_images()
            );
        }

        let backend = cfg.backend.create()?;
        let mut sim = Simulator {
            cfg,
            comm,
            nodes,
            conns,
            remote,
            tracker,
            timer,
            chunks,
            chunk_meta,
            pops,
            buffers: Some(buffers),
            remote_buffers,
            poissons,
            recorder,
            local_rng,
            backend: Some(backend),
            offboard_local: None,
            plan: Default::default(),
            state_lut: Vec::new(),
            plasticity: None,
            procedural,
            scratch: Default::default(),
            obs: None,
            step_times: Default::default(),
            exchange_every,
            step_now,
            prepared: true,
            n_state,
        };
        // derived structures are recomputed, not persisted (the hot-loop
        // scratch is always empty at save time: save_snapshot flushes)
        sim.rebuild_state_lut();
        sim.alloc_level_structures();
        sim.init_scratch();
        if let Some(ps) = sim.procedural.as_mut() {
            // node → descriptor index + fanout cache (derived, like the plan)
            ps.prepare(sim.nodes.m(), &mut sim.tracker);
        }
        // plasticity: rebuild the index structures from CONN, then restore
        // the mutable state (traces + pending arrival events) from PLAS
        match (sim.conns.has_plasticity(), reader.try_section(tags::PLAS)) {
            (false, None) => {}
            (true, Some(payload)) => {
                let mut pl = PlasticityEngine::build(
                    &sim.conns,
                    &sim.nodes,
                    &sim.state_lut,
                    sim.n_state as usize,
                    sim.cfg.max_delay_steps,
                    sim.exchange_every,
                    sim.cfg.dt_ms,
                    &mut sim.tracker,
                )?;
                let mut dec = Decoder::new(payload);
                pl.snapshot_restore(&mut dec, &mut sim.tracker)?;
                dec.finish()?;
                sim.plasticity = Some(pl);
            }
            (true, None) => {
                bail!("connection store carries STDP rules but the snapshot has no PLAS section");
            }
            (false, Some(_)) => {
                bail!("snapshot has a PLAS section but no plastic connections");
            }
        }
        // the delivery plan is derived from the (restored) connection store
        // and plastic index, so it is rebuilt last
        sim.plan = super::delivery::DeliveryPlan::build(
            &sim.conns,
            &sim.nodes,
            &sim.state_lut,
            sim.n_state,
            sim.plasticity.as_ref(),
        );
        sim.tracker.alloc(MemKind::Device, sim.plan.bytes());
        sim.timer.stop();
        Ok(sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommWorld;
    use crate::connection::{ConnRule, SynSpec};
    use crate::node::LifParams;

    fn build_single() -> Simulator {
        let world = CommWorld::new(1);
        let comm = world.communicators().pop().unwrap();
        let mut sim = Simulator::new(Box::new(comm), SimConfig::default());
        let n = sim.create_neurons(20, &LifParams::default());
        let g = sim.create_poisson(25_000.0);
        sim.connect(&g, &n, &ConnRule::AllToAll, &SynSpec::new(300.0, 1));
        sim.connect(&n, &n, &ConnRule::FixedIndegree { k: 3 }, &SynSpec::new(15.0, 2));
        sim.prepare().unwrap();
        sim
    }

    #[test]
    fn save_requires_prepare() {
        let world = CommWorld::new(1);
        let comm = world.communicators().pop().unwrap();
        let sim = Simulator::new(Box::new(comm), SimConfig::default());
        let err = sim.snapshot_to_bytes().unwrap_err();
        assert!(err.to_string().contains("prepare"), "{err}");
    }

    #[test]
    fn midstream_snapshot_continues_bit_identically() {
        let mut sim = build_single();
        for _ in 0..50 {
            sim.step_once().unwrap();
        }
        let bytes = sim.snapshot_to_bytes().unwrap();

        let world = CommWorld::new(1);
        let comm = world.communicators().pop().unwrap();
        let mut restored = Simulator::load_snapshot_bytes(Box::new(comm), &bytes).unwrap();

        assert_eq!(restored.step_now, sim.step_now);
        assert_eq!(restored.n_state, sim.n_state);
        assert_eq!(restored.recorder.events, sim.recorder.events);
        assert_eq!(restored.nodes.m(), sim.nodes.m());
        assert_eq!(restored.conns.len(), sim.conns.len());
        assert_eq!(restored.state_lut, sim.state_lut);

        // both continue, step by step, with identical spike output
        for _ in 0..100 {
            sim.step_once().unwrap();
            restored.step_once().unwrap();
            assert_eq!(restored.recorder.events, sim.recorder.events);
        }
        assert!(
            sim.recorder.events.len() > 5,
            "test network should actually spike ({} events)",
            sim.recorder.events.len()
        );
    }

    #[test]
    fn load_rejects_wrong_world_shape() {
        let sim = build_single();
        let bytes = sim.snapshot_to_bytes().unwrap();
        let world = CommWorld::new(2);
        let comm = world.communicators().pop().unwrap(); // rank 1 of 2
        let err = Simulator::load_snapshot_bytes(Box::new(comm), &bytes).unwrap_err();
        assert!(err.to_string().contains("rank"), "{err}");
    }
}
