//! The per-rank simulator facade.
//!
//! Mirrors the NEST GPU lifecycle (§0.5): initialization → neuron and
//! device creation → local/remote connection → simulation preparation →
//! state propagation, with each phase timed for the Fig. 3/6 breakdowns.

use crate::comm::Communicator;
use crate::connection::offboard::{HostConn, OffboardBuilder};
use crate::connection::{
    ConnCallDescriptor, ConnRule, Connections, Connectivity, DescSources, DescriptorStore,
    NodeSet, ProceduralState, SynSpec,
};
use crate::memory::{MemKind, Tracker};
use crate::node::device::{PoissonGenerator, SpikeRecorder};
use crate::node::{LifParams, NodeKind, NodeSpace, RingBuffers};
use crate::plasticity::PlasticityEngine;
use crate::remote::{GpuMemLevel, RemoteState};
use crate::runtime::{Backend, BackendKind, StateChunk};
use crate::stats::weights::WeightSummary;
use crate::util::rng::Rng;
use crate::util::timer::{Phase, PhaseTimer, PhaseTimes, StepTimes};

use super::delivery::DeliveryPlan;
use super::scratch::StepScratch;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// integration step (ms); the paper uses 0.1
    pub dt_ms: f64,
    /// GPU memory level (§0.3.6); NEST GPU default is level 2
    pub level: GpuMemLevel,
    /// ξ threshold for used-source flagging (§0.3.3); paper default 1.0
    pub xi: f64,
    /// master seed (construction + devices)
    pub seed: u64,
    pub backend: BackendKind,
    /// disabled for benchmarking runs, as in the paper
    pub record_spikes: bool,
    /// ring-buffer depth in steps (max supported delay)
    pub max_delay_steps: u16,
    /// use the offboard (CPU-built) construction baseline of Fig. 3
    pub offboard: bool,
    /// spike-exchange batching interval in steps: remote exchange runs
    /// once every `exchange_interval` steps instead of every `dt`.
    /// `None` (the default) resolves to the minimum remote synaptic delay
    /// at `prepare()`; an explicit value is clamped to `[1, min_delay]`
    /// so batching can never reorder deliveries (DESIGN.md §11).
    pub exchange_interval: Option<u16>,
    /// observability: per-step metrics, JSONL tracing and run manifests
    /// (DESIGN.md §13); `None` disables the whole layer. Not persisted in
    /// snapshots — telemetry is per-run, not simulation state.
    pub obs: Option<crate::obs::ObsConfig>,
    /// static-connectivity representation (DESIGN.md §16): `Materialized`
    /// stores every synapse; `Procedural` records connect calls as compact
    /// RNG-seeded descriptors and rematerializes a neuron's fanout when it
    /// spikes. Plastic, device-sourced and offboard-built synapses are
    /// always materialized. Incompatible with `offboard`.
    pub connectivity: Connectivity,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            dt_ms: 0.1,
            level: GpuMemLevel::default(),
            xi: 1.0,
            seed: 123,
            backend: BackendKind::Native,
            record_spikes: true,
            max_delay_steps: 32,
            offboard: false,
            exchange_interval: None,
            obs: None,
            connectivity: Connectivity::Materialized,
        }
    }
}

/// Outcome of one rank's run (metrics of the paper's figures).
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub rank: usize,
    pub phases: PhaseTimes,
    /// per-stage breakdown of the propagation pipeline (input →
    /// pre_update → dynamics → collect → post_update → route → exchange
    /// → deliver), summed over all steps; dump as JSON with
    /// `nestgpu phases`
    pub step_phases: StepTimes,
    /// wall-clock propagation time / model time (Eq. 21)
    pub rtf: f64,
    pub model_time_ms: f64,
    pub n_neurons: u64,
    pub n_images: u64,
    pub n_connections: u64,
    pub map_entries: u64,
    pub device_peak: u64,
    pub device_current: u64,
    /// device bytes held by connectivity state at the end of the run:
    /// materialized store + delivery plan, plus (procedural mode) the
    /// descriptor store and the current fanout-cache residency — the
    /// quantity the procedural mode exists to shrink
    pub conn_bytes: u64,
    /// host-memory peak/current from `memory/tracker.rs` (per rank)
    pub host_peak: u64,
    pub host_current: u64,
    pub spikes: Vec<(u32, u32)>,
    pub n_spikes: u64,
    pub p2p_messages: u64,
    pub p2p_bytes: u64,
    pub coll_calls: u64,
    pub coll_bytes: u64,
    /// effective exchange-batching interval resolved at `prepare()`
    pub exchange_interval: u16,
    /// plastic synapses on this rank (0 = fully static run)
    pub n_plastic: u64,
    /// distribution summary of the plastic weights after the run
    /// (`None` on static runs); the hash is the bit-identity witness of
    /// the STDP determinism tests
    pub plastic: Option<WeightSummary>,
    /// merged cross-rank metrics summary; `Some` only on rank 0 of a run
    /// with observability enabled (DESIGN.md §13)
    pub obs: Option<crate::obs::ObsSummary>,
}

/// One population of neurons created by a `create_neurons` call.
pub(super) struct Population {
    /// first node index
    pub(super) node_base: u32,
    /// first state index (ring buffer space)
    pub(super) state_base: u32,
    pub(super) n: u32,
    /// packed kernel parameters (chunk-grouping key)
    pub(super) packed: [f32; crate::node::neuron::NUM_PARAMS],
}

/// The per-rank simulator.
pub struct Simulator {
    pub cfg: SimConfig,
    pub(super) comm: Box<dyn Communicator>,
    pub nodes: NodeSpace,
    pub conns: Connections,
    pub remote: RemoteState,
    pub tracker: Tracker,
    pub timer: PhaseTimer,
    /// state chunks, materialized at prepare(): consecutive populations
    /// with identical packed parameters and contiguous node/state ranges
    /// share one chunk (§Perf iteration 4 — fewer, larger kernel calls)
    pub(super) chunks: Vec<StateChunk>,
    /// per chunk: (first node index, first state index, total neurons)
    pub(super) chunk_meta: Vec<(u32, u32, u32)>,
    pub(super) pops: Vec<Population>,
    /// input accumulation for per-step (Poisson + local) deliveries
    pub(super) buffers: Option<RingBuffers>,
    /// separate accumulation plane for batched remote deliveries, merged
    /// with `buffers` at consumption — keeping the two delivery classes in
    /// distinct accumulators is what makes min-delay exchange batching
    /// bit-identical to per-step exchange despite f32 non-associativity
    /// (DESIGN.md §11). `None` on ranks without image neurons, which can
    /// never receive remote spikes.
    pub(super) remote_buffers: Option<RingBuffers>,
    pub(super) poissons: Vec<PoissonGenerator>,
    pub recorder: SpikeRecorder,
    pub(super) local_rng: Rng,
    pub(super) backend: Option<Box<dyn Backend>>,
    pub(super) offboard_local: Option<OffboardBuilder>,
    /// prepared delivery layout: per-node (delay, port)-sorted runs with
    /// port-baked destinations + creation-order plastic links (DESIGN.md
    /// §14). Derived state — rebuilt at `prepare()` and snapshot restore,
    /// never persisted; its device residency is tracked (it is the bulk of
    /// a materialized rank's connectivity footprint).
    pub(super) plan: DeliveryPlan,
    /// node index -> state index (u32::MAX for non-neurons); built at prepare
    pub(super) state_lut: Vec<u32>,
    /// the STDP subsystem (`Some` iff any connect call attached a rule);
    /// owns the plastic-synapse index, traces, arrival events and the
    /// per-step deposit plane (DESIGN.md §12)
    pub(super) plasticity: Option<PlasticityEngine>,
    /// procedural connectivity (`Some` iff `cfg.connectivity` is
    /// [`Connectivity::Procedural`]): the descriptor store filled by
    /// connect calls, plus the fanout cache and regeneration counters.
    /// The store is persisted in snapshots (format v4); the node index
    /// and cache are derived state, rebuilt by `ProceduralState::prepare`.
    pub(super) procedural: Option<ProceduralState>,
    /// persistent hot-loop buffers (see [`StepScratch`]); sized at prepare
    pub(super) scratch: StepScratch,
    /// observability state (`Some` iff `cfg.obs` is set; built at
    /// `prepare()`, like the plasticity engine)
    pub(super) obs: Option<crate::obs::ObsState>,
    /// per-stage pipeline times, accumulated by `step_once`
    pub(super) step_times: StepTimes,
    /// effective exchange-batching interval (resolved at prepare; 1 until then)
    pub(super) exchange_every: u16,
    pub(super) step_now: u32,
    pub(super) prepared: bool,
    pub(super) n_state: u32,
}

impl Simulator {
    /// Initialization phase: simulator state, communicator binding.
    pub fn new(comm: Box<dyn Communicator>, cfg: SimConfig) -> Self {
        assert!(
            !(cfg.offboard && cfg.connectivity == Connectivity::Procedural),
            "the offboard construction baseline materializes every synapse \
             on the host and cannot run with procedural connectivity"
        );
        let mut timer = PhaseTimer::new();
        timer.enter(Phase::Initialization);
        let rank = comm.rank();
        let n_ranks = comm.size();
        let remote = RemoteState::new(cfg.seed, rank, n_ranks, cfg.level, cfg.xi);
        let local_rng = Rng::stream(cfg.seed, &[0x6C6F63616C, rank as u64]); // "local"
        let offboard_local = cfg.offboard.then(OffboardBuilder::new);
        let procedural = (cfg.connectivity == Connectivity::Procedural)
            .then(|| ProceduralState::new(DescriptorStore::default()));
        let record = cfg.record_spikes;
        let mut sim = Self {
            cfg,
            comm,
            nodes: NodeSpace::new(),
            conns: Connections::new(),
            remote,
            tracker: Tracker::new(),
            timer,
            chunks: Vec::new(),
            chunk_meta: Vec::new(),
            pops: Vec::new(),
            buffers: None,
            remote_buffers: None,
            poissons: Vec::new(),
            recorder: SpikeRecorder::new(record),
            local_rng,
            backend: None,
            offboard_local,
            plan: DeliveryPlan::default(),
            state_lut: Vec::new(),
            plasticity: None,
            procedural,
            scratch: StepScratch::default(),
            obs: None,
            step_times: StepTimes::default(),
            exchange_every: 1,
            step_now: 0,
            prepared: false,
            n_state: 0,
        };
        sim.timer.stop();
        sim
    }

    pub fn rank(&self) -> usize {
        self.comm.rank()
    }
    pub fn n_ranks(&self) -> usize {
        self.comm.size()
    }

    /// Neuron and device creation phase: one population per call.
    pub fn create_neurons(&mut self, n: u32, params: &LifParams) -> NodeSet {
        assert!(!self.prepared);
        self.timer.enter(Phase::NodeCreation);
        let pop_idx = self.pops.len() as u16;
        let node_base = self.nodes.create_neurons(pop_idx, n);
        let packed = params.packed(self.cfg.dt_ms);
        if self.cfg.offboard {
            // offboard baseline: state initialized on the host, then copied
            // to the device (the extra pass is the measured cost of the old
            // path; onboard initializes in place at prepare time)
            let host_bytes = (n as u64) * 7 * 4;
            self.tracker.alloc(MemKind::Host, host_bytes);
            let staged: Vec<f32> = vec![0.0; n as usize * 7];
            std::hint::black_box(&staged);
            self.tracker.free(MemKind::Host, host_bytes);
        }
        self.pops.push(Population {
            node_base,
            state_base: self.n_state,
            n,
            packed,
        });
        self.n_state += n;
        self.timer.stop();
        NodeSet::range(node_base, n)
    }

    /// Create a Poisson generator device firing at `rate_hz` into each of
    /// its future targets independently.
    pub fn create_poisson(&mut self, rate_hz: f64) -> NodeSet {
        assert!(!self.prepared);
        self.timer.enter(Phase::NodeCreation);
        let dev = self.poissons.len() as u16;
        let node = self.nodes.create_device(dev);
        let rng = Rng::stream(self.cfg.seed, &[0x706F6973, self.rank() as u64, dev as u64]);
        self.poissons.push(PoissonGenerator::new(node, rate_hz, rng));
        self.timer.stop();
        NodeSet::range(node, 1)
    }

    /// Local connection phase (both endpoints on this rank).
    pub fn connect(&mut self, s: &NodeSet, t: &NodeSet, rule: &ConnRule, syn: &SynSpec) {
        assert!(!self.prepared);
        assert!(
            syn.stdp.is_none() || self.offboard_local.is_none(),
            "the offboard construction baseline does not support plastic synapses"
        );
        self.timer.enter(Phase::LocalConnection);
        // procedural mode records neuron-sourced static calls as
        // descriptors; plastic calls and device-sourced calls (delivered
        // outside the spike path) stay materialized
        let descriptor_eligible = self.procedural.is_some()
            && syn.stdp.is_none()
            && s.iter()
                .all(|n| matches!(self.nodes.kind(n), NodeKind::Neuron { .. }));
        if descriptor_eligible {
            // capture-then-replay (DESIGN.md §16): fork the source stream
            // off the local one exactly as the materialized path below
            // does, capture both raw states, then consume the same
            // randomness a materialized build would — first the full pair
            // stream, then one parameter draw per pair — so later calls
            // see an identical generator and the descriptor replays
            // bit-for-bit
            let src_seed = self.local_rng.next_u64();
            let (src_state, src_gauss) = Rng::new(src_seed).raw_state();
            let (local_state, local_gauss) = self.local_rng.raw_state();
            let mut n_conns = 0u64;
            {
                let mut src_rng = Rng::new(src_seed);
                rule.generate(s.len(), t.len(), &mut src_rng, &mut self.local_rng, |_, _| {
                    n_conns += 1;
                });
            }
            if syn.weight.is_random() || syn.delay.is_random() {
                for _ in 0..n_conns {
                    syn.draw(&mut self.local_rng);
                }
            }
            let ps = self.procedural.as_mut().expect("checked eligible above");
            ps.store.push(
                ConnCallDescriptor {
                    sources: DescSources::Local(s.clone()),
                    targets: t.clone(),
                    rule: rule.clone(),
                    syn: *syn,
                    src_state,
                    src_gauss,
                    local_state,
                    local_gauss,
                    n_conns,
                },
                &mut self.tracker,
            );
            self.timer.stop();
            return;
        }
        let conn_start = self.conns.len();
        // local draws use the rank-private generator; the rule API takes
        // separate source/target generators (needed for the aligned remote
        // path), so fork an independent source stream off the local one
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        {
            let mut src_rng = Rng::new(self.local_rng.next_u64());
            rule.generate(s.len(), t.len(), &mut src_rng, &mut self.local_rng, |sp, tp| {
                pairs.push((sp, tp));
            });
        }
        if let Some(builder) = self.offboard_local.as_mut() {
            for (sp, tp) in pairs {
                let (w, d) = syn.draw(&mut self.local_rng);
                builder.push(
                    HostConn {
                        source: s.get(sp),
                        target: t.get(tp),
                        weight: w,
                        delay: d,
                        port: syn.port,
                    },
                    &mut self.tracker,
                );
            }
        } else {
            for (sp, tp) in pairs {
                let (w, d) = syn.draw(&mut self.local_rng);
                self.conns
                    .push(s.get(sp), t.get(tp), w, d, syn.port, &mut self.tracker);
            }
        }
        if let Some(stdp) = syn.stdp {
            let rid = self.conns.register_rule(stdp);
            self.conns.attach_rule(conn_start, rid, &mut self.tracker);
        }
        self.timer.stop();
    }

    /// Fold a synapse spec's minimum possible delay into the
    /// exchange-batching bound *without* performing a remote connection.
    /// Models that legitimately skip `RemoteConnect` replays they are not
    /// part of (e.g. the balanced model's point-to-point mode) must call
    /// this for the skipped calls so the bound — and hence the collective
    /// exchange cadence — stays identical on every rank.
    pub fn note_remote_delay(&mut self, syn: &SynSpec) {
        assert!(!self.prepared);
        self.remote.note_remote_delay_bound(syn.min_delay_steps());
    }

    /// Register an MPI group for collective communication (collective call:
    /// all ranks, same order, same members).
    pub fn register_group(&mut self, members: Vec<usize>) -> usize {
        let comm_group = self.comm.register_group(members.clone());
        self.remote.register_group(comm_group, members)
    }

    /// Remote connection phase: SPMD `RemoteConnect(σ, s, τ, t, …)`.
    ///
    /// Every rank calls this with identical arguments; each rank performs
    /// its part (target-side map+connection construction, source-side
    /// replay, or collective H bookkeeping) without any communication.
    #[allow(clippy::too_many_arguments)]
    pub fn remote_connect(
        &mut self,
        src_rank: usize,
        s: &NodeSet,
        tgt_rank: usize,
        t: &NodeSet,
        rule: &ConnRule,
        syn: &SynSpec,
        group: Option<usize>,
    ) {
        assert!(!self.prepared);
        if src_rank == tgt_rank {
            if src_rank == self.rank() {
                self.connect(s, t, rule, syn);
            }
            return;
        }
        self.timer.enter(Phase::RemoteConnection);
        // every rank executes every RemoteConnect call (SPMD), so folding
        // the call's minimum possible delay here yields a world-consistent
        // exchange-batching bound without any communication
        self.remote.note_remote_delay_bound(syn.min_delay_steps());
        let me = self.rank();
        if let Some(g) = group {
            // Eq. 12: every member mirrors H
            if self.remote.groups[g].member_index(me).is_some() {
                self.remote
                    .note_group_call(g, src_rank, s, &mut self.tracker);
            }
        }
        if me == tgt_rank {
            // procedural mode: static remote calls become descriptors with
            // image-neuron sources; plastic remote synapses stay
            // materialized (the STDP engine owns their weights)
            if self.procedural.is_some() && syn.stdp.is_none() {
                let call = self.remote.connect_target_procedural(
                    src_rank,
                    s,
                    t,
                    rule,
                    syn,
                    group,
                    &mut self.nodes,
                    &mut self.local_rng,
                    &mut self.tracker,
                );
                let ps = self.procedural.as_mut().expect("checked above");
                ps.store.push(
                    ConnCallDescriptor {
                        sources: DescSources::RemoteImages(call.images),
                        targets: t.clone(),
                        rule: rule.clone(),
                        syn: *syn,
                        src_state: call.src_state,
                        src_gauss: call.src_gauss,
                        local_state: call.local_state,
                        local_gauss: call.local_gauss,
                        n_conns: call.outcome.conns_created,
                    },
                    &mut self.tracker,
                );
                self.timer.stop();
                return;
            }
            let conn_start = self.conns.len();
            let out = self.remote.connect_target(
                src_rank,
                s,
                t,
                rule,
                syn,
                group,
                &mut self.nodes,
                &mut self.conns,
                &mut self.local_rng,
                &mut self.tracker,
            );
            if self.cfg.offboard && out.conns_created > 0 {
                // offboard baseline: the previous implementation assembled
                // remote connections and maps on the host and copied them
                // over — a full AoS round-trip (device SoA -> host AoS ->
                // host organization sort -> device SoA), the measured
                // overhead of the old path
                let bytes = out.conns_created * 16;
                self.tracker.alloc(MemKind::Host, bytes);
                let end = self.conns.len();
                let mut staged: Vec<HostConn> = Vec::with_capacity(end - conn_start);
                for k in conn_start..end {
                    staged.push(HostConn {
                        source: self.conns.source.as_slice()[k],
                        target: self.conns.target.as_slice()[k],
                        weight: self.conns.weight.as_slice()[k],
                        delay: self.conns.delay.as_slice()[k],
                        port: self.conns.port.as_slice()[k],
                    });
                }
                staged
                    .sort_by(|a, b| a.source.cmp(&b.source).then(a.target.cmp(&b.target)));
                for (k, c) in (conn_start..end).zip(staged.into_iter()) {
                    self.conns.source.as_mut_slice()[k] = c.source;
                    self.conns.target.as_mut_slice()[k] = c.target;
                    self.conns.weight.as_mut_slice()[k] = c.weight;
                    self.conns.delay.as_mut_slice()[k] = c.delay;
                    self.conns.port.as_mut_slice()[k] = c.port;
                }
                self.tracker.free(MemKind::Host, bytes);
            }
            if let Some(stdp) = syn.stdp {
                assert!(
                    !self.cfg.offboard,
                    "the offboard construction baseline does not support plastic synapses"
                );
                let rid = self.conns.register_rule(stdp);
                self.conns.attach_rule(conn_start, rid, &mut self.tracker);
            }
        } else if me == src_rank {
            self.remote
                .connect_source(tgt_rank, s, t.len(), rule, group, &mut self.tracker);
        }
        self.timer.stop();
    }

    /// Simulation preparation (§0.5): sort connections, build routing
    /// tables, allocate ring buffers, load the dynamics backend.
    pub fn prepare(&mut self) -> anyhow::Result<()> {
        assert!(!self.prepared, "prepare() called twice");
        self.timer.enter(Phase::Preparation);
        if let Some(builder) = self.offboard_local.take() {
            builder.transfer(&mut self.conns, &mut self.tracker);
        }
        let m = self.nodes.m() as usize;
        self.conns.sort_by_source(m, &mut self.tracker);
        self.remote.prepare(m, &mut self.tracker);
        if let Some(ps) = self.procedural.as_mut() {
            // node → descriptor index + fanout cache sizing
            ps.prepare(m as u32, &mut self.tracker);
        }

        self.alloc_level_structures();
        self.build_chunks();
        self.rebuild_state_lut();
        self.resolve_exchange_interval();
        self.init_scratch();
        if self.conns.has_plasticity() {
            self.plasticity = Some(PlasticityEngine::build(
                &self.conns,
                &self.nodes,
                &self.state_lut,
                self.n_state as usize,
                self.cfg.max_delay_steps,
                self.exchange_every,
                self.cfg.dt_ms,
                &mut self.tracker,
            )?);
        }
        self.plan = DeliveryPlan::build(
            &self.conns,
            &self.nodes,
            &self.state_lut,
            self.n_state,
            self.plasticity.as_ref(),
        );
        self.tracker.alloc(MemKind::Device, self.plan.bytes());

        self.buffers = Some(RingBuffers::new(
            self.n_state as usize,
            self.cfg.max_delay_steps,
            &mut self.tracker,
        ));
        // the remote plane covers max_delay + interval slots. Strictly,
        // the lag shift keeps every effective delay <= max_delay (the
        // shift is always <= 0), so the last interval - 1 slots are
        // defensive headroom: they turn an interval/delay accounting bug
        // anywhere in the batching path into a debug assert (ring too
        // small would silently alias the current slot instead). Remote
        // spikes are delivered through image neurons' outgoing
        // connections, so a rank without images never receives any and
        // skips the plane (and its per-step merge) entirely.
        let n_state = self.n_state as usize;
        let remote_slots = self.cfg.max_delay_steps.saturating_add(self.exchange_every - 1);
        self.remote_buffers = (self.nodes.n_images() > 0)
            .then(|| RingBuffers::new(n_state, remote_slots, &mut self.tracker));
        self.backend = Some(self.cfg.backend.create()?);
        if let Some(ocfg) = self.cfg.obs.clone() {
            let mut obs = crate::obs::ObsState::new(ocfg, self.rank())?;
            obs.set_ring_gauges(
                self.buffers.as_ref().map_or(0, |b| b.n_slots() as u64),
                self.remote_buffers.as_ref().map_or(0, |b| b.n_slots() as u64),
            );
            // group for the end-of-run aggregation allgather. Registered on
            // the raw communicator, NOT via `Simulator::register_group` —
            // this group must not appear in `remote.groups`, or every
            // exchange round would allgather over it. Collective-safe: the
            // obs config is part of the SPMD-identical SimConfig, so every
            // rank registers it here, in the same position.
            obs.world_group = Some(self.comm.register_group((0..self.n_ranks()).collect()));
            self.obs = Some(obs);
        }
        self.prepared = true;
        self.timer.stop();
        Ok(())
    }

    /// End-of-run observability: write this rank's summary trace record,
    /// merge every rank's registry through one world allgather, attach the
    /// merged [`crate::obs::ObsSummary`] to rank 0's result, and write the
    /// run manifest. Called by `simulate()` *after* the result is
    /// collected, so the aggregation traffic never pollutes the run's own
    /// comm metrics (results stay identical with observability on or off).
    pub(super) fn obs_finalize(
        &mut self,
        res: &mut SimResult,
        t_ms: f64,
    ) -> anyhow::Result<()> {
        let Some(mut obs) = self.obs.take() else {
            return Ok(());
        };
        obs.finalize(self.rank());
        let n_ranks = self.n_ranks();
        let merged = if n_ranks > 1 {
            let group = obs
                .world_group
                .expect("obs world group is registered at prepare()");
            let words = obs.registry.encode_words();
            let all = self.comm.allgather(group, &words);
            let mut merged = crate::obs::MetricsRegistry::new();
            for payload in &all {
                merged.merge(&crate::obs::MetricsRegistry::decode_words(payload)?);
            }
            merged
        } else {
            obs.registry.clone()
        };
        if self.rank() == 0 {
            if let Some(dir) = obs.cfg.trace_dir.clone() {
                let info = crate::obs::manifest::ManifestInfo {
                    label: obs.cfg.label.clone(),
                    n_ranks,
                    t_ms,
                    dt_ms: self.cfg.dt_ms as f32,
                    seed: self.cfg.seed,
                    level: crate::remote::levels::ALL_LEVELS
                        .iter()
                        .position(|&l| l == self.cfg.level)
                        .unwrap_or(0) as u8,
                    backend: format!("{:?}", self.cfg.backend),
                    exchange_interval: self.exchange_every,
                    sample_interval: obs.cfg.sample_interval,
                    max_delay_steps: self.cfg.max_delay_steps,
                    record_spikes: self.cfg.record_spikes,
                    connectivity: self.cfg.connectivity.name().to_string(),
                    transport: self.comm.transport_name().to_string(),
                    endpoints: self.comm.endpoints(),
                };
                crate::obs::manifest::write_manifest(&dir, &info)?;
            }
            res.obs = Some(crate::obs::ObsSummary { n_ranks, merged });
        }
        self.obs = Some(obs);
        Ok(())
    }

    /// Minimum synaptic delay of any connection outgoing from an *image*
    /// neuron on this rank — the receiver-side delay of every remote spike
    /// this rank delivers. `None` if this rank delivers no remote spikes.
    /// Used to sanity-check the SPMD delay bound against the delays that
    /// were actually drawn.
    pub(super) fn min_remote_delay_local(&self) -> Option<u16> {
        let src = self.conns.source.as_slice();
        let del = self.conns.delay.as_slice();
        let materialized = src
            .iter()
            .zip(del.iter())
            .filter(|&(&s, _)| self.nodes.is_image(s))
            .map(|(_, &d)| d)
            .min();
        // procedural remote descriptors contribute their spec's lower
        // bound (their delays are not drawn until a spike arrives; the
        // bound is what the SPMD fold used, so the assert below still
        // certifies the batching interval)
        let procedural = self
            .procedural
            .as_ref()
            .and_then(|p| p.store.min_remote_delay());
        match (materialized, procedural) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Resolve the effective exchange-batching interval from the minimum
    /// remote synaptic delay, optionally capped by the user's
    /// `cfg.exchange_interval`. The minimum is the SPMD bound folded over
    /// every `RemoteConnect` call — identical on every rank by
    /// construction — so preparation stays communication-free (the paper's
    /// invariant, and what keeps estimation mode exact).
    pub(super) fn resolve_exchange_interval(&mut self) {
        // no remote delivery anywhere: any cadence is safe, batch maximally
        let auto = match self.remote.remote_delay_bound() {
            None => self.cfg.max_delay_steps as u32,
            Some(d) => d as u32,
        };
        let auto = auto.clamp(1, self.cfg.max_delay_steps as u32) as u16;
        self.exchange_every = match self.cfg.exchange_interval {
            None => auto,
            Some(k) => k.clamp(1, auto),
        };
        debug_assert!(
            match self.min_remote_delay_local() {
                None => true,
                Some(d) => d >= self.exchange_every,
            },
            "drawn remote delay below the SPMD delay bound"
        );
    }

    /// (Re)build the persistent hot-loop scratch for the current world
    /// shape; called from `prepare()` and from a snapshot restore.
    pub(super) fn init_scratch(&mut self) {
        let state_bases: Vec<usize> =
            self.chunk_meta.iter().map(|&(_, sb, _)| sb as usize).collect();
        let group_sizes: Vec<usize> =
            self.remote.groups.iter().map(|g| g.members.len()).collect();
        self.scratch = StepScratch::for_world(self.n_ranks(), &group_sizes, state_bases);
    }

    /// Effective exchange-batching interval in steps (valid after
    /// `prepare()`): remote spike exchange runs once per this many steps.
    pub fn exchange_interval(&self) -> u16 {
        self.exchange_every
    }

    /// Level-dependent residency of the per-node first/count structures
    /// (§0.3.6). Requires the connection store to be source-sorted; called
    /// from `prepare()` and again when restoring from a snapshot.
    pub(super) fn alloc_level_structures(&mut self) {
        let m = self.nodes.m() as usize;
        match self.cfg.level {
            GpuMemLevel::L0 | GpuMemLevel::L1 => {
                // host mirrors of the per-node (first, count) structures:
                // image spike delivery is staged through the host on these
                // levels. Delivery itself goes through the prepared plan
                // (identical on every level), so the mirrors are modeled as
                // resident host bytes only — same accounting as holding the
                // `m + 1` first indices and `m` counts.
                self.tracker
                    .alloc(MemKind::Host, ((m + 1) * 4 + m * 4) as u64);
            }
            GpuMemLevel::L2 => {
                // first index on device (part of the CSR); count on the fly
                self.tracker.alloc(MemKind::Device, ((m + 1) * 4) as u64);
            }
            GpuMemLevel::L3 => {
                // first + count on device
                self.tracker
                    .alloc(MemKind::Device, ((m + 1) * 4 + m * 4) as u64);
            }
        }
    }

    /// Node -> state translation table for the delivery hot loop; derived
    /// from the population table, so a snapshot restore recomputes it
    /// instead of persisting it.
    pub(super) fn rebuild_state_lut(&mut self) {
        self.state_lut = (0..self.nodes.m())
            .map(|node| self.state_of(node).unwrap_or(u32::MAX))
            .collect();
    }

    /// State index of a neuron node (ring-buffer addressing).
    #[inline]
    pub(super) fn state_of(&self, node: u32) -> Option<u32> {
        match self.nodes.kind(node) {
            NodeKind::Neuron { chunk: pop, offset } => {
                Some(self.pops[pop as usize].state_base + offset)
            }
            _ => None,
        }
    }


    pub(super) fn comm_mut(&mut self) -> &mut dyn Communicator {
        self.comm.as_mut()
    }
    pub(super) fn is_prepared(&self) -> bool {
        self.prepared
    }
    pub(super) fn n_chunks(&self) -> usize {
        self.chunks.len()
    }
    pub(super) fn chunk_info(&self, i: usize) -> (u32, u32, u32) {
        self.chunk_meta[i]
    }

    /// Materialize the state chunks: group consecutive populations with
    /// identical packed parameters and contiguous node/state ranges into
    /// one chunk each — fewer, larger device-kernel invocations per step
    /// (§Perf iteration 4).
    fn build_chunks(&mut self) {
        debug_assert!(self.chunks.is_empty());
        let mut i = 0usize;
        while i < self.pops.len() {
            let first = &self.pops[i];
            let (node_base, state_base) = (first.node_base, first.state_base);
            let packed = first.packed;
            let mut n = first.n;
            let mut j = i + 1;
            while j < self.pops.len() {
                let p = &self.pops[j];
                let contiguous = p.node_base == node_base + n
                    && p.state_base == state_base + n;
                if contiguous && p.packed == packed {
                    n += p.n;
                    j += 1;
                } else {
                    break;
                }
            }
            self.chunks
                .push(StateChunk::new(n as usize, packed, &mut self.tracker));
            self.chunk_meta.push((node_base, state_base, n));
            i = j;
        }
    }

    /// Collect the run metrics (after `simulate`, or after `prepare` in
    /// estimation mode).
    pub fn result(&self, rtf: f64, model_time_ms: f64) -> SimResult {
        let tr = &self.tracker;
        SimResult {
            rank: self.rank(),
            phases: self.timer.times,
            step_phases: self.step_times,
            rtf,
            model_time_ms,
            n_neurons: self.nodes.n_neurons() as u64,
            n_images: self.nodes.n_images() as u64,
            n_connections: self.conns.len() as u64
                + self.procedural.as_ref().map_or(0, |p| p.store.total_conns()),
            map_entries: self.remote.total_map_entries() as u64,
            device_peak: tr.peak(MemKind::Device),
            device_current: tr.current(MemKind::Device),
            conn_bytes: self.conns.device_bytes()
                + self.plan.bytes()
                + self
                    .procedural
                    .as_ref()
                    .map_or(0, |p| p.store.device_bytes() + p.cache_used_bytes()),
            host_peak: tr.peak(MemKind::Host),
            host_current: tr.current(MemKind::Host),
            spikes: self.recorder.events.clone(),
            n_spikes: self.recorder.events.len() as u64,
            p2p_messages: self.comm.traffic().p2p_messages,
            p2p_bytes: self.comm.traffic().p2p_bytes,
            coll_calls: self.comm.traffic().coll_calls,
            coll_bytes: self.comm.traffic().coll_bytes,
            exchange_interval: self.exchange_every,
            n_plastic: self.plasticity.as_ref().map_or(0, |p| p.n_plastic() as u64),
            plastic: self
                .plasticity
                .as_ref()
                .map(|p| p.weight_summary(&self.conns)),
            obs: None,
        }
    }

    /// The plasticity engine, when any connect call attached an STDP rule
    /// (valid after `prepare()`).
    pub fn plasticity_engine(&self) -> Option<&PlasticityEngine> {
        self.plasticity.as_ref()
    }

    /// World-combined spike-train hash: every rank contributes the
    /// order-sensitive hash of its recorded `(step, node)` events through
    /// one allgather, and all ranks return the identical rank-ordered fold
    /// ([`crate::stats::combine_rank_hashes`]). Collective call — every
    /// rank must reach it at the same point (normally right after
    /// `simulate`); like the obs world group, the group is registered on
    /// the raw communicator so it never joins the exchange rounds.
    ///
    /// This is the cross-process bit-identity witness: a multi-process
    /// socket run and a thread-comm run of the same model agree on this
    /// value iff every rank's spike train matched.
    pub fn world_spike_hash(&mut self) -> u64 {
        let local = crate::stats::spike_hash(&self.recorder.events);
        let n = self.n_ranks();
        if n <= 1 {
            return crate::stats::combine_rank_hashes(&[local]);
        }
        let group = self.comm.register_group((0..n).collect());
        let words = [(local >> 32) as u32, local as u32];
        let all = self.comm.allgather(group, &words);
        let hashes: Vec<u64> = all
            .iter()
            .map(|w| ((w[0] as u64) << 32) | w[1] as u64)
            .collect();
        crate::stats::combine_rank_hashes(&hashes)
    }
}
