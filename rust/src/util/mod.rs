//! Self-contained utility substrate (the offline crate set has no rand,
//! serde, or criterion — these modules replace them).

pub mod json;
pub mod lru;
pub mod rng;
pub mod sort;
pub mod table;
pub mod timer;
