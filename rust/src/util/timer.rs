//! Phase timers: the paper reports the time-to-solution split into the
//! construction subtasks of §0.5 (initialization, neuron & device creation,
//! local connection, remote connection, simulation preparation) plus state
//! propagation. `PhaseTimes` is that exact breakdown; `PhaseTimer`
//! accumulates into it.

use std::time::{Duration, Instant};

/// The simulation phases of §0.5 (Fig. 3a / Fig. 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Initialization,
    NodeCreation,
    LocalConnection,
    RemoteConnection,
    Preparation,
    Propagation,
}

pub const ALL_PHASES: [Phase; 6] = [
    Phase::Initialization,
    Phase::NodeCreation,
    Phase::LocalConnection,
    Phase::RemoteConnection,
    Phase::Preparation,
    Phase::Propagation,
];

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Initialization => "initialization",
            Phase::NodeCreation => "node_creation",
            Phase::LocalConnection => "local_connection",
            Phase::RemoteConnection => "remote_connection",
            Phase::Preparation => "preparation",
            Phase::Propagation => "propagation",
        }
    }
}

/// Accumulated wall-clock time per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    pub initialization: Duration,
    pub node_creation: Duration,
    pub local_connection: Duration,
    pub remote_connection: Duration,
    pub preparation: Duration,
    pub propagation: Duration,
}

impl PhaseTimes {
    pub fn get(&self, p: Phase) -> Duration {
        match p {
            Phase::Initialization => self.initialization,
            Phase::NodeCreation => self.node_creation,
            Phase::LocalConnection => self.local_connection,
            Phase::RemoteConnection => self.remote_connection,
            Phase::Preparation => self.preparation,
            Phase::Propagation => self.propagation,
        }
    }

    fn slot(&mut self, p: Phase) -> &mut Duration {
        match p {
            Phase::Initialization => &mut self.initialization,
            Phase::NodeCreation => &mut self.node_creation,
            Phase::LocalConnection => &mut self.local_connection,
            Phase::RemoteConnection => &mut self.remote_connection,
            Phase::Preparation => &mut self.preparation,
            Phase::Propagation => &mut self.propagation,
        }
    }

    /// Total network-construction time (everything except propagation).
    pub fn construction(&self) -> Duration {
        self.initialization
            + self.node_creation
            + self.local_connection
            + self.remote_connection
            + self.preparation
    }

    /// "Neuron and device creation and connection" aggregate of Fig. 6a.
    pub fn creation_and_connection(&self) -> Duration {
        self.node_creation + self.local_connection + self.remote_connection
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        for p in ALL_PHASES {
            *self.slot(p) += other.get(p);
        }
    }

    /// Element-wise mean over a set of per-rank phase breakdowns.
    pub fn mean(all: &[PhaseTimes]) -> PhaseTimes {
        let mut out = PhaseTimes::default();
        if all.is_empty() {
            return out;
        }
        for t in all {
            out.add(t);
        }
        for p in ALL_PHASES {
            *out.slot(p) = out.get(p) / all.len() as u32;
        }
        out
    }
}

/// Accumulating stopwatch over `PhaseTimes`.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    pub times: PhaseTimes,
    current: Option<(Phase, Instant)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or switch to) a phase; accumulates the previous one.
    pub fn enter(&mut self, p: Phase) {
        self.stop();
        self.current = Some((p, Instant::now()));
    }

    /// Stop timing without entering a new phase.
    pub fn stop(&mut self) {
        if let Some((p, t0)) = self.current.take() {
            *self.times.slot(p) += t0.elapsed();
        }
    }

    /// Time a closure under a phase (restores the previous phase after).
    ///
    /// Panic-safe: the accumulate-and-restore runs from a drop guard, so a
    /// panic inside `f` (e.g. a rank assert surfacing through
    /// `join_ranks`) still charges the elapsed time to `p` and leaves the
    /// timer in the enclosing phase instead of stuck in `p`.
    pub fn scope<T>(&mut self, p: Phase, f: impl FnOnce() -> T) -> T {
        struct Restore<'a> {
            timer: &'a mut PhaseTimer,
            prev: Option<Phase>,
        }
        impl Drop for Restore<'_> {
            fn drop(&mut self) {
                self.timer.stop();
                if let Some(ph) = self.prev {
                    self.timer.enter(ph);
                }
            }
        }
        let prev = self.current.map(|(ph, _)| ph);
        self.enter(p);
        let _restore = Restore { timer: self, prev };
        f()
    }
}

/// The named stages of the state-propagation pipeline (one `step_once`):
/// input → pre_update → dynamics → collect → post_update → route →
/// exchange → deliver. Unlike [`Phase`], these nest *inside*
/// `Phase::Propagation`, so they are accumulated separately and never
/// contribute to `construction()`. The two plasticity phases stay at zero
/// on fully static runs (DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StepPhase {
    /// device input (Poisson generators) into the ring buffers
    Input,
    /// plasticity: presynaptic arrivals — depression + plastic deposits
    PreUpdate,
    /// ring-buffer hand-off to the dynamics backend + spike flags
    Dynamics,
    /// spike collection and recording
    Collect,
    /// plasticity: postsynaptic spikes — potentiation + trace bumps
    PostUpdate,
    /// remote routing: map positions into p2p packets / group buffers
    Route,
    /// communication: all-to-all-v + per-group allgathers
    Exchange,
    /// ring-buffer delivery (local spikes + incoming remote spikes)
    Deliver,
    /// procedural connectivity: fanout rematerialization on cache miss
    /// (carved out of Deliver so regeneration cost is visible per rank;
    /// zero in materialized mode)
    Regen,
}

pub const ALL_STEP_PHASES: [StepPhase; 9] = [
    StepPhase::Input,
    StepPhase::PreUpdate,
    StepPhase::Dynamics,
    StepPhase::Collect,
    StepPhase::PostUpdate,
    StepPhase::Route,
    StepPhase::Exchange,
    StepPhase::Deliver,
    StepPhase::Regen,
];

impl StepPhase {
    /// Position in [`ALL_STEP_PHASES`] — dense array index for metric
    /// catalogs (`obs::metrics`).
    #[inline]
    pub fn index(self) -> usize {
        match self {
            StepPhase::Input => 0,
            StepPhase::PreUpdate => 1,
            StepPhase::Dynamics => 2,
            StepPhase::Collect => 3,
            StepPhase::PostUpdate => 4,
            StepPhase::Route => 5,
            StepPhase::Exchange => 6,
            StepPhase::Deliver => 7,
            StepPhase::Regen => 8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StepPhase::Input => "input",
            StepPhase::PreUpdate => "pre_update",
            StepPhase::Dynamics => "dynamics",
            StepPhase::Collect => "collect",
            StepPhase::PostUpdate => "post_update",
            StepPhase::Route => "route",
            StepPhase::Exchange => "exchange",
            StepPhase::Deliver => "deliver",
            StepPhase::Regen => "regen",
        }
    }
}

/// Accumulated wall-clock time per pipeline stage, over all steps.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTimes {
    pub input: Duration,
    pub pre_update: Duration,
    pub dynamics: Duration,
    pub collect: Duration,
    pub post_update: Duration,
    pub route: Duration,
    pub exchange: Duration,
    pub deliver: Duration,
    pub regen: Duration,
}

impl StepTimes {
    pub fn get(&self, p: StepPhase) -> Duration {
        match p {
            StepPhase::Input => self.input,
            StepPhase::PreUpdate => self.pre_update,
            StepPhase::Dynamics => self.dynamics,
            StepPhase::Collect => self.collect,
            StepPhase::PostUpdate => self.post_update,
            StepPhase::Route => self.route,
            StepPhase::Exchange => self.exchange,
            StepPhase::Deliver => self.deliver,
            StepPhase::Regen => self.regen,
        }
    }

    fn slot(&mut self, p: StepPhase) -> &mut Duration {
        match p {
            StepPhase::Input => &mut self.input,
            StepPhase::PreUpdate => &mut self.pre_update,
            StepPhase::Dynamics => &mut self.dynamics,
            StepPhase::Collect => &mut self.collect,
            StepPhase::PostUpdate => &mut self.post_update,
            StepPhase::Route => &mut self.route,
            StepPhase::Exchange => &mut self.exchange,
            StepPhase::Deliver => &mut self.deliver,
            StepPhase::Regen => &mut self.regen,
        }
    }

    /// Accumulate `elapsed` into stage `p`.
    pub fn accumulate(&mut self, p: StepPhase, elapsed: Duration) {
        *self.slot(p) += elapsed;
    }

    /// Sum over all pipeline stages.
    pub fn total(&self) -> Duration {
        ALL_STEP_PHASES.iter().map(|&p| self.get(p)).sum()
    }

    pub fn add(&mut self, other: &StepTimes) {
        for p in ALL_STEP_PHASES {
            *self.slot(p) += other.get(p);
        }
    }

    /// Element-wise mean over a set of per-rank stage breakdowns.
    pub fn mean(all: &[StepTimes]) -> StepTimes {
        let mut out = StepTimes::default();
        if all.is_empty() {
            return out;
        }
        for t in all {
            out.add(t);
        }
        for p in ALL_STEP_PHASES {
            *out.slot(p) = out.get(p) / all.len() as u32;
        }
        out
    }
}

/// Simple wall-clock stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut t = PhaseTimer::new();
        t.enter(Phase::NodeCreation);
        std::thread::sleep(Duration::from_millis(2));
        t.enter(Phase::LocalConnection);
        std::thread::sleep(Duration::from_millis(2));
        t.stop();
        assert!(t.times.node_creation >= Duration::from_millis(1));
        assert!(t.times.local_connection >= Duration::from_millis(1));
        assert_eq!(t.times.propagation, Duration::ZERO);
    }

    #[test]
    fn scope_restores_previous_phase() {
        let mut t = PhaseTimer::new();
        t.enter(Phase::Propagation);
        t.scope(Phase::Preparation, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        std::thread::sleep(Duration::from_millis(1));
        t.stop();
        assert!(t.times.preparation >= Duration::from_millis(1));
        assert!(t.times.propagation >= Duration::from_millis(1));
    }

    #[test]
    fn scope_restores_previous_phase_on_panic() {
        let mut t = PhaseTimer::new();
        t.enter(Phase::Propagation);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.scope(Phase::Preparation, || {
                std::thread::sleep(Duration::from_millis(1));
                panic!("rank failure inside scope");
            })
        }));
        assert!(caught.is_err());
        // the panicking scope still charged its elapsed time...
        assert!(t.times.preparation >= Duration::from_millis(1));
        // ...and the timer resumed the enclosing phase, so later time
        // lands in propagation, not preparation
        std::thread::sleep(Duration::from_millis(1));
        t.stop();
        assert!(t.times.propagation >= Duration::from_millis(1));
    }

    #[test]
    fn scope_returns_value_and_restores_nesting() {
        let mut t = PhaseTimer::new();
        t.enter(Phase::Propagation);
        let v = t.scope(Phase::Preparation, || {
            std::thread::sleep(Duration::from_millis(1));
            7u32
        });
        assert_eq!(v, 7);
        std::thread::sleep(Duration::from_millis(1));
        t.stop();
        assert!(t.times.preparation >= Duration::from_millis(1));
        assert!(t.times.propagation >= Duration::from_millis(1));
    }

    #[test]
    fn scope_without_enclosing_phase_leaves_timer_idle() {
        let mut t = PhaseTimer::new();
        t.scope(Phase::NodeCreation, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        // nothing enclosing to restore: time after the scope is uncharged
        std::thread::sleep(Duration::from_millis(1));
        t.stop();
        assert!(t.times.node_creation >= Duration::from_millis(1));
        assert_eq!(t.times.propagation, Duration::ZERO);
        assert_eq!(t.times.preparation, Duration::ZERO);
    }

    #[test]
    fn step_phase_index_matches_catalog_order() {
        for (i, p) in ALL_STEP_PHASES.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }

    #[test]
    fn construction_sum() {
        let mut pt = PhaseTimes::default();
        pt.node_creation = Duration::from_secs(1);
        pt.preparation = Duration::from_secs(2);
        pt.propagation = Duration::from_secs(10);
        assert_eq!(pt.construction(), Duration::from_secs(3));
        assert_eq!(pt.creation_and_connection(), Duration::from_secs(1));
    }

    #[test]
    fn step_times_accumulate_and_total() {
        let mut st = StepTimes::default();
        st.accumulate(StepPhase::Route, Duration::from_millis(2));
        st.accumulate(StepPhase::Exchange, Duration::from_millis(3));
        st.accumulate(StepPhase::Exchange, Duration::from_millis(1));
        assert_eq!(st.route, Duration::from_millis(2));
        assert_eq!(st.exchange, Duration::from_millis(4));
        assert_eq!(st.total(), Duration::from_millis(6));
        let m = StepTimes::mean(&[st, StepTimes::default()]);
        assert_eq!(m.exchange, Duration::from_millis(2));
    }

    #[test]
    fn mean_over_ranks() {
        let mut a = PhaseTimes::default();
        a.preparation = Duration::from_secs(2);
        let mut b = PhaseTimes::default();
        b.preparation = Duration::from_secs(4);
        let m = PhaseTimes::mean(&[a, b]);
        assert_eq!(m.preparation, Duration::from_secs(3));
    }
}
