//! ASCII table rendering for the bench harness: every bench prints
//! paper-style rows (criterion is not in the offline crate set, so benches
//! are plain binaries that format their own results).

/// A simple column-aligned table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = w
            .iter()
            .map(|&wi| "-".repeat(wi + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:>width$} ", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format a byte count adaptively (B/KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let bf = b as f64;
    if bf < KIB {
        format!("{b}B")
    } else if bf < KIB * KIB {
        format!("{:.1}KiB", bf / KIB)
    } else if bf < KIB * KIB * KIB {
        format!("{:.1}MiB", bf / KIB / KIB)
    } else {
        format!("{:.2}GiB", bf / KIB / KIB / KIB)
    }
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// mean / std over a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// median / interquartile range (linear interpolation, like numpy).
pub fn median_iqr(xs: &[f64]) -> (f64, f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        let idx = p * (v.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
        }
    };
    (q(0.5), q(0.25), q(0.75))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        Table::new("", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(5e-6), "5.0us");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(999), "999");
    }

    #[test]
    fn stats_helpers() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (med, q1, q3) = median_iqr(&[1.0, 2.0, 3.0, 4.0]);
        assert!((med - 2.5).abs() < 1e-12);
        assert!(q1 < med && med < q3);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
